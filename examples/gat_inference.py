"""Paper application 2: GAT forward pass via the r=2-SDDMM score trick.

  PYTHONPATH=src python examples/gat_inference.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import gat

if __name__ == "__main__":
    n, d, heads = 8192, 64, 4
    S = gat.make_graph(n, nnz_per_row=16, seed=0)
    rng = np.random.default_rng(0)
    H = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    layers = [gat.init_gat_layer(jax.random.PRNGKey(i), d, d)
              for i in range(2)]
    out = gat.gat_forward(S, H, layers, n_heads=heads)
    print("GAT output:", out.shape, "finite:",
          bool(np.isfinite(np.asarray(out)).all()))
