"""Paper application 2: GAT forward pass via the r=2-SDDMM score trick.

  PYTHONPATH=src python examples/gat_inference.py [--distributed|--serve]

With --distributed the score SDDMM and aggregation SpMM run through the
unified repro.core.api (cost-model-chosen algorithm), with the row
softmax between them applied on completed rows (paper Fig. 9).

With --serve the layer is DEPLOYED into the serving pool and queried by
several concurrent clients, each asking for a different node set: the
continuous batcher coalesces every client's edge-score query into one
union-of-patterns SDDMM round per tick (all clients share the deployed
A*/B* operands), and the answers match the full distributed forward
bitwise on the queried rows (docs/serving.md).
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import gat

if __name__ == "__main__":
    distributed = "--distributed" in sys.argv[1:]
    serve = "--serve" in sys.argv[1:]
    n, d, heads = 8192, 64, 4
    rng = np.random.default_rng(0)
    H = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    layers = [gat.init_gat_layer(jax.random.PRNGKey(i), d, d)
              for i in range(2)]
    if serve:
        from repro import serving
        pool = serving.SessionPool(capacity=4)
        rows, cols, _ = gat.graph_coo(n, nnz_per_row=16, seed=0)
        dep = gat.gat_deploy_layer(pool, rows, cols, n, np.asarray(H),
                                   layers[0], n_heads=heads)
        engine = serving.ServingEngine(pool, max_batch=32)
        print(f"deployed head 0 on {dep.problem.alg.name} "
              f"(p={dep.problem.p})")
        # several clients queue score queries; ONE coalesced round
        clients = [rng.choice(n, size=64, replace=False)
                   for _ in range(6)]
        phase1 = [gat.gat_submit_scores(engine, dep, ids)
                  for ids in clients]
        report = engine.tick()
        print(f"scores: {report['requests']} client queries -> "
              f"{report['rounds']} coalesced round(s)")
        aggs = [gat.gat_submit_aggregate(engine, dep, ids,
                                         ticket.result())
                for ids, (ticket, _) in zip(clients, phase1)]
        engine.tick()
        out0 = aggs[0].result()[np.unique(clients[0])]
        print("client 0 head-0 rows:", out0.shape, "finite:",
              bool(np.isfinite(out0).all()))
        print("pool:", pool.stats())
    elif distributed:
        graph = gat.make_dist_graph(n, nnz_per_row=16, r=d // heads,
                                    seed=0)
        print(f"distributed on {graph.alg.name} (c={graph.c})")
        out = gat.gat_forward_distributed(graph, H, layers, n_heads=heads)
        print("GAT output:", out.shape, "finite:",
              bool(np.isfinite(np.asarray(out)).all()))
    else:
        S = gat.make_graph(n, nnz_per_row=16, seed=0)
        out = gat.gat_forward(S, H, layers, n_heads=heads)
        print("GAT output:", out.shape, "finite:",
              bool(np.isfinite(np.asarray(out)).all()))
