"""Paper application 2: GAT forward pass via the r=2-SDDMM score trick.

  PYTHONPATH=src python examples/gat_inference.py [--distributed]

With --distributed the score SDDMM and aggregation SpMM run through the
unified repro.core.api (cost-model-chosen algorithm), with the row
softmax between them applied on completed rows (paper Fig. 9).
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import gat

if __name__ == "__main__":
    distributed = "--distributed" in sys.argv[1:]
    n, d, heads = 8192, 64, 4
    rng = np.random.default_rng(0)
    H = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    layers = [gat.init_gat_layer(jax.random.PRNGKey(i), d, d)
              for i in range(2)]
    if distributed:
        graph = gat.make_dist_graph(n, nnz_per_row=16, r=d // heads,
                                    seed=0)
        print(f"distributed on {graph.alg.name} (c={graph.c})")
        out = gat.gat_forward_distributed(graph, H, layers, n_heads=heads)
    else:
        S = gat.make_graph(n, nnz_per_row=16, seed=0)
        out = gat.gat_forward(S, H, layers, n_heads=heads)
    print("GAT output:", out.shape, "finite:",
          bool(np.isfinite(np.asarray(out)).all()))
