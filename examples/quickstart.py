"""Quickstart: the paper's kernels in five minutes (single device).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import costmodel, sparse
from repro.kernels import ops

# 1. build a sparse matrix S (Erdos-Renyi, like the paper's weak scaling)
m = n = 2048
r = 64
rows, cols, vals = sparse.erdos_renyi(m, n, nnz_per_row=8, seed=0)
S = sparse.pack_row_tiled(rows, cols, vals, (m, n))
print(f"S: {m}x{n}, nnz={len(vals)}, phi=nnz/(n*r)={len(vals)/(n*r):.3f}")

# 2. dense embeddings
rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((m, r)), jnp.float32)
B = jnp.asarray(rng.standard_normal((n, r)), jnp.float32)

# 3. the three kernels (Pallas, interpret mode on CPU)
R = ops.sddmm(A, B, S)                   # R = S * (A @ B^T)
Y = ops.spmm(R, B)                       # Y = R @ B
F, R2 = ops.fusedmm(A, B, S)             # fused: same as the two above
print("fused == sddmm;spmm:",
      bool(jnp.allclose(F, Y, rtol=1e-4, atol=1e-4)))

# 4. which distributed algorithm would the paper pick at p=256?
ranking = costmodel.select_algorithm(p=256, n=n, r=r, nnz=len(vals))
print("algorithm ranking at p=256 (words/proc):")
for name, cost in ranking.items():
    print(f"  {name:28s} c*={cost.c:3d}  words={cost.words:,.0f}")

# 5. the unified distributed entrypoint: every algorithm family behind
# one signature, dispatched by the same cost model (repro.core.api)
from repro.core import api
prob = api.make_problem(rows, cols, vals, (m, n), r)     # algorithm="auto"
print(f"auto dispatch on {len(jax.devices())} device(s): "
      f"{prob.alg.name} c={prob.c} elision={prob.resolve_elision()}")
out, _ = prob.fusedmm(A, B)
print("api fusedmm == local fused:",
      bool(np.allclose(out, np.asarray(F), rtol=1e-3, atol=1e-3)))
