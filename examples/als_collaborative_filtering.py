"""Paper application 1: ALS collaborative filtering with batched-CG FusedMM.

  PYTHONPATH=src python examples/als_collaborative_filtering.py
"""
from repro.apps.als import run_als

if __name__ == "__main__":
    A, B, hist = run_als(m=2048, n=2048, nnz_per_row=12, r=32, rounds=3,
                         cg_iters=10)
    print("loss history:", [round(h, 1) for h in hist])
    assert hist[-1] < hist[0]
    print("OK: every CG matvec ran as one FusedMM call")
