"""Paper application 1: ALS collaborative filtering with batched-CG FusedMM.

  PYTHONPATH=src python examples/als_collaborative_filtering.py [--distributed]

With --distributed every kernel call (SpMM right-hand sides, FusedMM CG
matvecs, SDDMM loss) runs through the unified repro.core.api on the
cost-model-chosen algorithm, with Session-cached replication in the CG
loop.  On a single device the distributed path degenerates to a 1x1
grid — same math, same entrypoint.
"""
import sys

from repro.apps.als import run_als, run_als_distributed

if __name__ == "__main__":
    distributed = "--distributed" in sys.argv[1:]
    runner = run_als_distributed if distributed else run_als
    A, B, hist = runner(m=2048, n=2048, nnz_per_row=12, r=32, rounds=3,
                        cg_iters=10)
    print("loss history:", [round(h, 1) for h in hist])
    assert hist[-1] < hist[0]
    print("OK: every CG matvec ran as one FusedMM call"
          + (" through repro.core.api" if distributed else ""))
