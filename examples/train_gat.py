"""Distributed GAT layer training (paper §VI-E, made differentiable).

  PYTHONPATH=src python examples/train_gat.py

Trains a single-head GAT layer by SGD: per step, the score SDDMM and the
aggregation SpMM (with differentiable attention values) run as
distributed primitives, and their backwards are the dual primitives on
the same grid — SpMM/SpMM-transpose for the SDDMM, SDDMM for the SpMM's
values-gradient (repro.core.grads).  The row softmax sits between the
kernels on completed rows, in both passes (Fig. 9's no-fusion barrier).
"""
import jax
import numpy as np

from repro.apps import gat

if __name__ == "__main__":
    n, d = 512, 16
    graphP = gat.make_dist_graph(n, 6, d, seed=0)
    rng = np.random.default_rng(0)
    H = rng.standard_normal((n, d)).astype(np.float32)
    # regression target: a "teacher" layer's output
    teacher = gat.init_gat_layer(jax.random.PRNGKey(7), d, d)
    target = np.asarray(gat.gat_layer_distributed(graphP, H, teacher))
    params, hist = gat.train_gat_distributed(graphP, H, target, steps=25,
                                             lr=0.1, seed=1)
    print("loss history:", [round(h, 4) for h in hist])
    assert hist[-1] < hist[0]
    print("OK: GAT layer trained through the distributed dual-primitive VJPs")
