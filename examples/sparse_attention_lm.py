"""Beyond-paper: LM attention as the paper's SDDMM->softmax->SpMM pattern.

A sliding-window + global-token causal mask makes long-context attention a
sparse-kernel problem; at seq=1024 with a 128-token window the mask holds
~3% of the dense score matrix, and phi = nnz/(S*hd) tells you which of the
paper's distributed algorithms to use for it.

  PYTHONPATH=src python examples/sparse_attention_lm.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import costmodel
from repro.core.sparse_attention import (build_causal_block_mask,
                                         dense_reference, sparsity_stats,
                                         sparse_attention_head)

if __name__ == "__main__":
    seq, hd = 1024, 64
    mask = build_causal_block_mask(seq, block=64, window_blocks=2,
                                   global_blocks=1)
    stats = sparsity_stats(mask, seq, hd)
    print(f"mask: {stats['nnz']} nnz = {stats['fraction']:.1%} of dense, "
          f"phi={stats['phi']:.2f}")

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((seq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((seq, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((seq, hd)), jnp.float32)
    out = sparse_attention_head(q, k, v, mask)
    want = dense_reference(q, k, v, np.asarray(mask.to_dense()))
    err = float(jnp.abs(out - want).max())
    print(f"sparse vs dense-masked reference: max err {err:.2e}")
    assert err < 1e-4

    ranking = costmodel.select_algorithm(p=256, n=seq, r=hd,
                                         nnz=stats["nnz"])
    best = next(iter(ranking))
    print("best distributed algorithm for this attention layer at p=256:",
          best)
