"""Serving demo: concurrent sparse-attention queries, one round per tick.

  PYTHONPATH=src python examples/serve_lm.py

A block-causal attention mask is deployed ONCE into the Session pool;
then several concurrent "clients" — each owning a disjoint block of
query rows with its own Q projection — submit attention-score queries
(``<Q_i, K_j>`` at the mask's positions) plus a value-aggregation
request.  The continuous batcher coalesces every client's score query
into ONE union-of-patterns SDDMM round per tick — disjoint query rows
let different Q operands share the round — so the expensive phase costs
one distributed round no matter how many clients arrive; aggregations
group by their sample-values key (per-client softmaxed attention stays
per-client here, the deployed-values case batches fully —
docs/serving.md).

The greedy LM decode demo that used to live here is still available as
the local path: ``python examples/serve_lm.py --decode``.
"""
import sys

import numpy as np
import jax
import jax.numpy as jnp


def decode_demo():
    from repro.config import ParallelConfig
    from repro.configs import llama32_1b
    from repro.models import model as M
    from repro.serving import decode
    cfg = llama32_1b.reduced()
    pcfg = ParallelConfig(compute_dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    out = decode.greedy_generate(cfg, pcfg, params, {"tokens": prompts},
                                 steps=16)
    print("generated:", out.shape)
    print(np.asarray(out[:2]))


def serving_demo():
    from repro import serving

    seq, d, n_clients, block = 256, 32, 8, 32
    rng = np.random.default_rng(0)

    # block-causal mask: token i attends within its block and the one
    # before it — the local-attention sparsity pattern, as a graph
    rows, cols = [], []
    for i in range(seq):
        b = i // block
        lo = max(0, (b - 1) * block)
        js = np.arange(lo, i + 1)
        rows.append(np.full(len(js), i))
        cols.append(js)
    rows = np.concatenate(rows).astype(np.int64)
    cols = np.concatenate(cols).astype(np.int64)

    K = rng.standard_normal((seq, d)).astype(np.float32)
    V = rng.standard_normal((seq, d)).astype(np.float32)
    pool = serving.SessionPool(capacity=4)
    dep = pool.deploy(rows, cols, np.ones(len(rows), np.float32),
                      (seq, seq), d, operands={"K": K, "V": V})
    engine = serving.ServingEngine(pool, max_batch=64)
    print(f"deployed block-causal mask ({len(rows)} positions) on "
          f"{dep.problem.alg.name}, p={dep.problem.p}")

    # each client: its own rows (disjoint blocks) and its own Q
    tickets = []
    for cl in range(n_clients):
        q_rows = np.arange(cl * block, (cl + 1) * block)
        sel = np.isin(rows, q_rows)
        Q = np.zeros((seq, d), np.float32)
        Q[q_rows] = rng.standard_normal((block, d)).astype(np.float32)
        t = engine.submit_score(dep, rows[sel], cols[sel], Q, "K")
        tickets.append((cl, q_rows, sel, Q, t))
    report = engine.tick()
    print(f"scores: {report['requests']} client queries -> "
          f"{report['rounds']} coalesced round(s)")

    # per-client softmax, then everyone's attn @ V in one batched round
    agg = []
    for cl, q_rows, sel, Q, t in tickets:
        from repro.apps.gat import row_softmax_coo
        scale = np.float32(1.0 / np.sqrt(d))
        attn = row_softmax_coo(rows[sel], t.result() * scale, seq)
        vals = np.zeros(len(rows), np.float32)
        vals[sel] = attn
        agg.append((q_rows, engine.submit_aggregate(dep, V, vals=vals)))
    report = engine.tick()
    print(f"aggregation: {len(agg)} requests -> "
          f"{report['rounds']} round(s)")
    for q_rows, t in agg[:2]:
        out = t.result()[q_rows]
        print(f"  client rows {q_rows[0]}..{q_rows[-1]}: "
              f"out {out.shape}, finite={bool(np.isfinite(out).all())}")
    print("engine:", {k: v for k, v in engine.stats().items()
                      if k in ("rounds", "served")})
    print("pool:", pool.stats())


if __name__ == "__main__":
    if "--decode" in sys.argv[1:]:
        decode_demo()
    else:
        serving_demo()
