"""Batched serving demo: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.config import ParallelConfig
from repro.configs import llama32_1b
from repro.models import model as M
from repro.serving import engine

if __name__ == "__main__":
    cfg = llama32_1b.reduced()
    pcfg = ParallelConfig(compute_dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    out = engine.greedy_generate(cfg, pcfg, params, {"tokens": prompts},
                                 steps=16)
    print("generated:", out.shape)
    print(np.asarray(out[:2]))
