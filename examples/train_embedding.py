"""Distributed embedding training on the sampled loss (SGD).

  PYTHONPATH=src python examples/train_embedding.py [--mtx path/to/file.mtx]

Every SGD step runs one distributed SDDMM forward and its dual-primitive
backward (SpMM + SpMM-transpose on the same grid) through the
``jax.custom_vjp`` rules of repro.core.grads; an api.Session replays the
forward's fiber replication in the backward, so no dense factor is
gathered twice per step.  With ``--mtx`` the ratings matrix is loaded
from a Matrix Market file (the bundled ``tests/fixtures/tiny.mtx`` works)
instead of the seeded Erdos-Renyi generator.
"""
import sys

from repro.apps.als import train_embedding_distributed

if __name__ == "__main__":
    args = sys.argv[1:]
    kw = dict(m=512, n=512, nnz_per_row=8, r=16, steps=25, lr=0.05)
    if "--mtx" in args:
        from repro.core.mtx import load_mtx
        rows, cols, vals, (m, n) = load_mtx(args[args.index("--mtx") + 1])
        kw.update(m=m, n=n, rows=rows, cols=cols, vals=vals)
    X, Y, hist = train_embedding_distributed(**kw)
    print("loss history:", [round(h, 2) for h in hist])
    assert hist[-1] < hist[0]
    print("OK: sampled-loss SGD through the distributed dual-primitive VJPs")
