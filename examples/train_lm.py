"""End-to-end driver: train the ~126M-param LM on synthetic data.

  PYTHONPATH=src python examples/train_lm.py          # short demo
  PYTHONPATH=src python -m repro.launch.train \
      --arch lm-100m --steps 300 --seq 128 --batch 4 \
      --ckpt-dir results/ckpt_100m                    # the full run

The full 300-step run's loss curve is recorded in
results/train_100m.jsonl (see EXPERIMENTS.md).
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.exit(main([
        "--arch", "lm-100m", "--steps", "8", "--seq", "128",
        "--batch", "2", "--log-every", "2",
    ]))
