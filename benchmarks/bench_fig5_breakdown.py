"""Paper Fig. 5: weak-scaling time breakdown (communication vs compute).

Per (p, algorithm): the predicted communication seconds on the paper's
hardware model (beta = 1/ICI link bw) vs the local-kernel compute seconds
(gamma = 1/peak), from the same cost model the paper uses — plus the
measured wire bytes from the compiled HLO as ground truth for the
communication volume.
"""
from benchmarks import common
from repro.core import costmodel, d15

LINK_BW = 50e9      # B/s
PEAK = 197e12       # FLOP/s


def run(out):
    r, nnz_row = 64, 8
    for p in (2, 4, 8):
        m = n = 1024 * p
        rows, cols, vals, A, B = common.er_problem(m, n, r, nnz_row, seed=p)
        nnz = len(vals)
        for cm_name, elis, transpose in (
                ("d15_no_elision", "none", False),
                ("d15_replication_reuse", "reuse", True),
                ("d15_local_fusion", "fused", False)):
            best = costmodel.best_c(cm_name, p=p, n=n, r=r, nnz=nnz)
            comm_s = best.words * 4 / LINK_BW
            comp_s = costmodel.flops_fusedmm(nnz, r) / p / PEAK
            g, plan, Ash, Bsh = common.build_d15(
                best.c, rows, cols, vals, m, n, r, A, B, transpose=transpose)
            low = d15.fusedmm_d15.lower(g, plan, Ash, Bsh, elision=elis)
            gb = common.wire_gb(low)
            frac = comm_s / (comm_s + comp_s)
            out(common.csv_line(
                f"fig5.p{p}.{cm_name}", comm_s + comp_s,
                f"comm_frac={frac:.3f};hlo_wireGB={gb:.4f}"))


if __name__ == "__main__":
    run(print)
