"""Shared benchmark utilities: timing, grids, problem builders, CSV/JSON."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def emit_json(path: str, records: list, meta: dict | None = None) -> str:
    """Write a machine-readable benchmark artifact (list of dict records).

    Every record should carry at least {"name": ..., "seconds": ...}; extra
    keys (config knobs, derived metrics) ride along.  The artifact makes
    the perf trajectory diffable across PRs.
    """
    doc = {
        "schema": "repro-bench-v1",
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "meta": meta or {},
        "records": records,
    }
    path = os.path.abspath(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall time (s) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_line(name, seconds, derived=""):
    return f"{name},{seconds * 1e6:.1f},{derived}"


def wire_gb(lowered):
    from repro.roofline.hlo_parse import collective_summary
    s = collective_summary(lowered.compile().as_text())
    return s["total_wire_bytes"] / 1e9


def build_d15(c, rows, cols, vals, m, n, r, A, B, transpose=False,
              row_tile=64, nz_block=64):
    from repro.core import d15
    from repro.core.grid import make_grid15
    g = make_grid15(c)
    Ash = jax.device_put(jnp.asarray(A), g.sharding(("layer", "fiber")))
    Bsh = jax.device_put(jnp.asarray(B), g.sharding(("layer", "fiber")))
    plan = d15.plan_d15(g, rows, cols, vals, m, n, r, transpose=transpose,
                        row_tile=row_tile, nz_block=nz_block)
    return g, plan, Ash, Bsh


def build_s15(c, rows, cols, vals, m, n, r, A, B, row_tile=64,
              nz_block=64):
    from repro.core import s15
    from repro.core.grid import make_grid15
    g = make_grid15(c)
    Ash = jax.device_put(jnp.asarray(A), g.sharding(None, ("layer", "fiber")))
    Bsh = jax.device_put(jnp.asarray(B), g.sharding(None, ("layer", "fiber")))
    plan = s15.plan_s15(g, rows, cols, vals, m, n, r, row_tile=row_tile,
                        nz_block=nz_block)
    return g, plan, Ash, Bsh


def er_problem(m, n, r, nnz_per_row, seed=0):
    """Seeded (rows, cols, vals, A, B) bundle — one shared generator
    (repro.core.sparse.random_problem) serves benchmarks, tests and
    dist_scripts; identical streams to the historical local copy."""
    from repro.core import sparse
    return sparse.random_problem(m, n, r, nnz_per_row, seed=seed)
