"""Serving-engine benchmark: batched+pooled vs per-request baseline.

Replays deterministic open-loop score traffic (seeded bursts over a
small catalog of hot query patterns against deployed CF factors)
through two engines on the SAME deployment:

* ``batched`` — the continuous batcher + Session pool path: every
  burst coalesces into one union-of-patterns SDDMM round, the pattern
  cache reuses packed structure across bursts, and the Session serves
  the deployed factors' replication from cache;
* ``solo`` — the per-request baseline (``batching=False``, no
  Session): one kernel round per request, replication re-paid.

Latency methodology (docs/serving.md): arrivals are fixed simulated
timestamps, service is measured wall time per tick, completion =
tick-start + wall — so p50/p99 include queueing delay under bursts and
the distribution is reproducible up to machine timing noise.  A second
section times one batched-RHS SpMM round against per-request SpMMs for
the aggregation path.

Writes ``BENCH_serving.json`` (p50/p99/throughput per concurrency x
mode, pool + Session hit rates) and asserts the acceptance bar: at >= 8
concurrent requests the batched+pooled engine's throughput strictly
beats the per-request baseline.
"""
import numpy as np

from benchmarks import common
from repro import obs, serving
from repro.apps import als

JSON_PATH = "BENCH_serving.json"

M, N, R = 256, 192, 16
NNZ = 6000
CATALOG = 4          # distinct hot query patterns
QUERY_LEN = 24       # (user, item) pairs per score request
BURSTS = 4           # measured bursts per concurrency level
PERIOD = 0.01        # open-loop burst period (simulated seconds)
CONCURRENCY = (1, 4, 8, 16)


def _int_graph(rng, m, n, nnz):
    key = np.unique(rng.integers(0, m * n, nnz))
    rows = (key // n).astype(np.int64)
    cols = (key % n).astype(np.int64)
    vals = (rng.integers(1, 4, len(key))
            * rng.choice([-1.0, 1.0], len(key))).astype(np.float32)
    return rows, cols, vals


def _make_trace(dep, concurrency, bursts, catalog, t0=0.0):
    trace = []
    for b in range(bursts):
        t = t0 + b * PERIOD
        for j in range(concurrency):
            qr, qc = catalog[(b * concurrency + j) % len(catalog)]

            def submit(engine, arrival, qr=qr, qc=qc):
                return engine.submit_score(dep, qr, qc, "U", "V",
                                           arrival=arrival)

            trace.append((t, submit))
    return trace


def run(out, json_path=JSON_PATH):
    rng = np.random.default_rng(0)
    rows, cols, vals = _int_graph(rng, M, N, NNZ)
    U = rng.standard_normal((M, R)).astype(np.float32)
    V = rng.standard_normal((N, R)).astype(np.float32)

    pool = serving.SessionPool(capacity=4)
    dep = als.deploy_factors(pool, rows, cols, vals, (M, N), U, V)
    # an identical re-deploy is the pool's content-hit path — recorded
    # so the artifact's pool hit rate is non-trivial
    assert als.deploy_factors(pool, rows, cols, vals, (M, N), U, V) is dep
    catalog = [(rng.integers(0, M, QUERY_LEN),
                rng.integers(0, N, QUERY_LEN)) for _ in range(CATALOG)]
    records = []
    metrics_reg = obs.MetricsRegistry()   # sweep-wide METRICS_serving.json

    for conc in CONCURRENCY:
        results = {}
        for mode in ("batched", "solo"):
            batched = mode == "batched"
            eng = serving.ServingEngine(
                pool, max_batch=32, batching=batched,
                use_session=batched)
            # warmup: compile every pattern/union this concurrency
            # level will see, so the measured replay is steady-state
            serving.replay_trace(
                eng, _make_trace(dep, conc, 2, catalog))
            # each measured replay collects into its own registry, so
            # the per-row pool/session/latency fields are the obs
            # surface's numbers, not hand-maintained counters
            with obs.metrics.collect() as reg:
                res = serving.replay_trace(
                    eng, _make_trace(dep, conc, BURSTS, catalog))
            results[mode] = res
            tick_h = reg.histogram("serving.tick_seconds") or {}
            sh = reg.value("serving.pool.session.hits") or 0.0
            sm = reg.value("serving.pool.session.misses") or 0.0
            records.append(dict(
                kind="serving", mode=mode, concurrency=conc,
                m=M, n=N, r=R, nnz=len(vals),
                served=res["served"], shed=res["shed"],
                p50=res["p50"], p99=res["p99"], mean=res["mean"],
                throughput=res["throughput"],
                rounds=eng.rounds,
                ticks=reg.value("serving.ticks"),
                tick_seconds_mean=tick_h.get("mean"),
                batch_occupancy_mean=(
                    reg.histogram("serving.batch_occupancy") or {}
                ).get("mean"),
                pool_hit_rate=reg.value("serving.pool.hit_rate"),
                session_hits=sh, session_misses=sm,
                session_hit_rate=sh / max(sh + sm, 1.0)))
            metrics_reg.merge(reg, conc=conc, mode=mode)
            out(common.csv_line(
                f"serving.score.c{conc}.{mode}", res["p50"],
                f"p99={res['p99'] * 1e6:.0f}us;"
                f"tput={res['throughput']:.1f}/s;"
                f"rounds={eng.rounds}"))
        if conc >= 8:
            assert (results["batched"]["throughput"]
                    > results["solo"]["throughput"]), (
                f"batched serving must beat per-request baseline at "
                f"concurrency {conc}: "
                f"{results['batched']['throughput']:.1f}/s vs "
                f"{results['solo']['throughput']:.1f}/s")

    # --- aggregation path: one batched-RHS SpMM vs per-request SpMMs ---
    Ys = [rng.standard_normal((N, 4)).astype(np.float32)
          for _ in range(8)]
    prob = dep.problem
    t_batched = common.timeit(
        lambda: prob.spmm_batched(Ys, session=dep.session)[0], iters=3)
    t_solo = common.timeit(
        lambda: [prob.spmm_batched([Y])[0] for Y in Ys][0], iters=3)
    records.append(dict(kind="serving-agg", mode="batched", width=4,
                        rhs=len(Ys), seconds=t_batched))
    records.append(dict(kind="serving-agg", mode="solo", width=4,
                        rhs=len(Ys), seconds=t_solo))
    out(common.csv_line("serving.agg.batched8", t_batched,
                        f"solo={t_solo * 1e6:.0f}us;"
                        f"speedup={t_solo / t_batched:.2f}x"))

    path = common.emit_json(
        json_path, records,
        meta=dict(bench="serving", m=M, n=N, r=R, nnz=len(vals),
                  catalog=CATALOG, query_len=QUERY_LEN, bursts=BURSTS,
                  period=PERIOD, pool=pool.stats()))
    out(f"# wrote {path}")
    arts = obs.write_artifacts(".", "serving", registry=metrics_reg)
    out(f"# wrote {arts['metrics']}")


if __name__ == "__main__":
    run(print)
