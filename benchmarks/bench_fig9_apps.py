"""Paper Fig. 9: ALS and GAT application performance.

Timed end-to-end on the CPU scale-down, split into time inside the FusedMM
/ SDDMM / SpMM kernels vs the rest of the application (CG vector algebra,
softmax, activations) — the same decomposition the paper plots.
"""
import time

import jax
import numpy as np

from benchmarks import common
from repro.apps import als, gat
from repro.kernels import ops


def run(out):
    # --- ALS: 20 CG iterations (10 for A, 10 for B), paper's setting
    prob = als.make_problem(2048, 2048, 16, 64, seed=0)
    rng = np.random.default_rng(0)
    import jax.numpy as jnp
    A = jnp.asarray(rng.standard_normal((2048, 64)) * 0.1, jnp.float32)
    B = jnp.asarray(rng.standard_normal((2048, 64)) * 0.1, jnp.float32)

    # kernel-only time: the FusedMM matvecs of 20 CG iterations
    t_kernel = common.timeit(
        lambda: als.fusedmm_matvec(prob.mask, A, B, prob.reg, prob.m),
        iters=3) * 20
    t_total = common.timeit(
        lambda: als.als_round(prob, A, B, cg_iters=10), iters=1)
    out(common.csv_line("fig9.als.total", t_total,
                        f"fusedmm_frac={min(t_kernel / t_total, 1.0):.2f}"))
    out(common.csv_line("fig9.als.fusedmm", t_kernel, "20 CG matvecs"))

    # --- GAT forward (2 layers, 4 heads), paper's workload
    S = gat.make_graph(4096, 16, seed=1)
    H = jnp.asarray(rng.standard_normal((4096, 64)), jnp.float32)
    layers = [gat.init_gat_layer(jax.random.PRNGKey(i), 64, 64)
              for i in range(2)]
    t_gat = common.timeit(
        lambda: gat.gat_forward(S, H, layers, n_heads=4), iters=2)
    # kernel-only: SDDMM + SpMM per head per layer
    Wh = H @ layers[0].W[:, :16]
    u = Wh @ layers[0].a1[:16]
    v = Wh @ layers[0].a2[:16]
    t_k = (common.timeit(lambda: gat.attention_scores(S, u, v), iters=3)
           + common.timeit(lambda: ops.spmm(S, Wh, m=4096), iters=3)) * 8
    out(common.csv_line("fig9.gat.total", t_gat,
                        f"kernel_frac={min(t_k / t_gat, 1.0):.2f}"))
    out(common.csv_line("fig9.gat.kernels", t_k, "8 head-layers"))


if __name__ == "__main__":
    run(print)
