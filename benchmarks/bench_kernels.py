"""Local kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp oracle.

On-CPU interpret timings are functional, not TPU projections; the derived
column reports useful GFLOP/s and the Pallas/ref ratio so regressions in
the kernel structure show up in CI.

Besides the fixed-shape baseline rows, this sweeps the VMEM tiling knobs
(``r_tile`` x ``blocks_per_step``, see DESIGN.md) over grouped packs and
writes the full record set to ``BENCH_kernels.json`` so the perf
trajectory of the tiled kernels is machine-readable from PR to PR.
"""
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import costmodel, sparse
from repro.kernels import ops, ref

JSON_PATH = "BENCH_kernels.json"


def _kernel_cases(S, Aj, Bj, nnz, r, tiling=None):
    kw = {} if tiling is None else dict(r_tile=tiling[0],
                                        blocks_per_step=tiling[1])
    return (
        ("sddmm", lambda: ops.sddmm(Aj, Bj, S, **kw),
         lambda: ref.sddmm(Aj, Bj, S), 2 * nnz * r),
        ("spmm", lambda: ops.spmm(S, Bj, **kw),
         lambda: ref.spmm(S, Bj), 2 * nnz * r),
        ("fusedmm", lambda: ops.fusedmm(Aj, Bj, S, **kw),
         lambda: ref.fusedmm(Aj, Bj, S), 4 * nnz * r),
    )


def run(out, json_path=JSON_PATH):
    records = []

    # --- fixed-shape baseline (cost-model default tiling)
    for (m, n, r, k) in ((2048, 2048, 64, 8), (4096, 4096, 128, 16)):
        rows, cols, vals, A, B = common.er_problem(m, n, r, k, seed=0)
        S = sparse.pack_row_tiled(rows, cols, vals, (m, n), row_tile=256,
                                  nz_block=256)
        Aj, Bj = jnp.asarray(A), jnp.asarray(B)
        nnz = len(vals)
        for name, fn_p, fn_r, flops in _kernel_cases(S, Aj, Bj, nnz, r):
            tp = common.timeit(fn_p, iters=2)
            tr = common.timeit(fn_r, iters=2)
            out(common.csv_line(
                f"kernel.{name}.m{m}.r{r}", tp,
                f"gflops={flops / tp / 1e9:.2f};ref_ratio={tp / tr:.2f}"))
            records.append(dict(name=name, m=m, n=n, r=r, nnz=nnz,
                                seconds=tp, ref_seconds=tr, flops=flops,
                                r_tile=None, blocks_per_step=None,
                                sweep="baseline"))

    # --- tiling-knob sweep on a grouped pack
    m = n = 2048
    r, k = 256, 8
    rows, cols, vals, A, B = common.er_problem(m, n, r, k, seed=1)
    S = sparse.pack_row_tiled(rows, cols, vals, (m, n), row_tile=256,
                              nz_block=128, group=4)
    Aj, Bj = jnp.asarray(A), jnp.asarray(B)
    nnz = len(vals)
    max_bps = costmodel.groupable_blocks_per_step(
        np.asarray(S.tile_base), S.nz_block, cap=4)
    for r_tile in (r, r // 2, r // 4):
        for bps in (1, 2, 4):
            if bps > max_bps or S.nblocks % bps:
                continue
            tiling = (r_tile, bps)
            for name, fn_p, fn_r, flops in _kernel_cases(
                    S, Aj, Bj, nnz, r, tiling):
                tp = common.timeit(fn_p, iters=2)
                out(common.csv_line(
                    f"kernel.{name}.rt{r_tile}.bps{bps}", tp,
                    f"gflops={flops / tp / 1e9:.2f}"))
                records.append(dict(name=name, m=m, n=n, r=r, nnz=nnz,
                                    seconds=tp, flops=flops, r_tile=r_tile,
                                    blocks_per_step=bps, sweep="tiling"))

    path = common.emit_json(json_path, records,
                            meta=dict(bench="kernels",
                                      nz_block=int(S.nz_block),
                                      max_bps=int(max_bps)))
    out(f"# wrote {path}")


if __name__ == "__main__":
    run(print)
