"""Local kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp oracle.

On-CPU interpret timings are functional, not TPU projections; the derived
column reports useful GFLOP/s and the Pallas/ref ratio so regressions in
the kernel structure show up in CI.
"""
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import sparse
from repro.kernels import ops, ref


def run(out):
    for (m, n, r, k) in ((2048, 2048, 64, 8), (4096, 4096, 128, 16)):
        rows, cols, vals, A, B = common.er_problem(m, n, r, k, seed=0)
        S = sparse.pack_row_tiled(rows, cols, vals, (m, n), row_tile=256,
                                  nz_block=256)
        Aj, Bj = jnp.asarray(A), jnp.asarray(B)
        nnz = len(vals)
        for name, fn_p, fn_r, flops in (
            ("sddmm", lambda: ops.sddmm(Aj, Bj, S),
             lambda: ref.sddmm(Aj, Bj, S), 2 * nnz * r),
            ("spmm", lambda: ops.spmm(S, Bj),
             lambda: ref.spmm(S, Bj), 2 * nnz * r),
            ("fusedmm", lambda: ops.fusedmm(Aj, Bj, S),
             lambda: ref.fusedmm(Aj, Bj, S), 4 * nnz * r),
        ):
            tp = common.timeit(fn_p, iters=2)
            tr = common.timeit(fn_r, iters=2)
            out(common.csv_line(
                f"kernel.{name}.m{m}.r{r}", tp,
                f"gflops={flops / tp / 1e9:.2f};ref_ratio={tp / tr:.2f}"))


if __name__ == "__main__":
    run(print)
