"""Distributed-algorithm benchmarks through the unified repro.core.api.

Sweeps algorithm x elision x replication-caching (Session on/off) on
Erdos-Renyi inputs over the 8-device host mesh, timing the full
FusedMM path (device kernels + host assembly — the api contract).  The
session rows measure the across-call replication-reuse elision: the
second-and-later calls of an iterative solver, with the stationary
operand's fiber gather served from cache.

Writes ``BENCH_dist.json`` so the perf trajectory of the distributed
layer is machine-readable from PR to PR.
"""
import numpy as np

from benchmarks import common
from repro import obs
from repro.core import api, costmodel, sparse

JSON_PATH = "BENCH_dist.json"

M = N = 1024
R = 64
NNZ_ROW = 8


def run(out, json_path=JSON_PATH):
    rows, cols, vals, X, Y = sparse.random_problem(M, N, R, NNZ_ROW,
                                                   seed=0)
    records = []
    # one sweep-wide registry + tracer: every timed cell also runs one
    # traced round, so each row carries its live cost-model drift
    # (schedule_words vs compiled-HLO wire words; docs/observability.md)
    metrics_reg = obs.MetricsRegistry()
    tracer = obs.Tracer(registry=metrics_reg)

    for name in sorted(api.ALGORITHMS):
        prob = api.make_problem(rows, cols, vals, (M, N), R,
                                algorithm=name)
        for elision in prob.alg.elisions:
            # modeled per-processor comm words (Table-III grid row) so
            # the elision win is machine-readable even where the 8-host-
            # device wall times are compile-bound; session rows get the
            # steady-state (cached) model per docs/choosing.md
            cm_kw = dict(p=prob.p, c=prob.c, n=N, r=R, nnz=prob.nnz)
            cm_name = costmodel.ELISION_COST_NAME[(name, elision)]
            model_words = {
                False: costmodel.words_fusedmm(cm_name, **cm_kw).words,
                True: costmodel.words_fusedmm_cached(cm_name,
                                                     **cm_kw).words}
            # uncached: every call pays the full gather
            t_plain = common.timeit(
                lambda: prob.fusedmm(X, Y, elision=elision)[0], iters=2)
            # session-cached steady state: fill once, then time hits
            sess = api.Session()
            prob.fusedmm(X, Y, elision=elision, session=sess)
            t_cached = common.timeit(
                lambda: prob.fusedmm(X, Y, elision=elision,
                                     session=sess)[0], iters=2)
            out(common.csv_line(
                f"dist.{name}.{elision}", t_plain,
                f"c={prob.c};cached_ratio={t_cached / t_plain:.2f}"))
            for cached, t in ((False, t_plain), (True, t_cached)):
                with obs.trace(tracer):
                    prob.fusedmm(X, Y, elision=elision,
                                 session=sess if cached else None)
                rnd = tracer.rounds[-1]
                metrics_reg.gather("session", sess.stats(), family=name,
                                   elision=elision)
                hits = metrics_reg.value("session.hits", family=name,
                                         elision=elision) or 0.0
                miss = metrics_reg.value("session.misses", family=name,
                                         elision=elision) or 0.0
                records.append(dict(
                    name=name, elision=elision, session_cached=cached,
                    c=prob.c, m=M, n=N, r=R, nnz=prob.nnz,
                    phi=prob.phi, seconds=t,
                    model_words=model_words[cached],
                    schedule_words=rnd.modeled_words,
                    measured_words=(rnd.measured_words or {}).get(
                        "total"),
                    drift=rnd.drift,
                    session_hit_rate=hits / max(hits + miss, 1.0)))

        t_sddmm = common.timeit(lambda: prob.sddmm(X, Y).to_dense(),
                                iters=2)
        t_spmm = common.timeit(lambda: prob.spmm(Y), iters=2)
        out(common.csv_line(f"dist.{name}.sddmm", t_sddmm, f"c={prob.c}"))
        out(common.csv_line(f"dist.{name}.spmm", t_spmm, f"c={prob.c}"))
        drifts = {}
        with obs.trace(tracer):
            prob.sddmm(X, Y)
            drifts["sddmm"] = tracer.rounds[-1].drift
            prob.spmm(Y)
            drifts["spmm"] = tracer.rounds[-1].drift
        records.append(dict(name=name, elision=None, kernel="sddmm",
                            session_cached=False, c=prob.c, m=M, n=N,
                            r=R, nnz=prob.nnz, phi=prob.phi,
                            seconds=t_sddmm, drift=drifts["sddmm"]))
        records.append(dict(name=name, elision=None, kernel="spmm",
                            session_cached=False, c=prob.c, m=M, n=N,
                            r=R, nnz=prob.nnz, phi=prob.phi,
                            seconds=t_spmm, drift=drifts["spmm"]))

    # --- training-step rows: fwd-only vs fwd+bwd vs session-reused ---
    # Per registry cell, the extended cost model's per-step words
    # (words_fusedmm / words_trainstep) — the backward is the dual
    # primitive on the same cell, so these are exact model sums, checked
    # against measured HLO wire words by dist_scripts/check_grad_costs.
    # One wall-timed jax.grad step per family (the auto-resolved cell)
    # keeps the compile cost bounded.
    import jax
    import jax.numpy as jnp
    from repro.core import grads
    from repro.distributed.elastic import StepMonitor

    for name in sorted(api.ALGORITHMS):
        prob = api.make_problem(rows, cols, vals, (M, N), R,
                                algorithm=name)
        cm_kw = dict(p=prob.p, c=prob.c, n=N, r=R, nnz=prob.nnz)
        timed_el = prob.resolve_elision("auto")
        for elision in prob.alg.elisions:
            cm_name = costmodel.ELISION_COST_NAME[(name, elision)]
            words_fwd = costmodel.words_fusedmm(cm_name, **cm_kw).words
            words_step = costmodel.words_trainstep(cm_name, **cm_kw).words
            words_step_sess = costmodel.words_trainstep(
                cm_name, session=True, **cm_kw).words
            rec = dict(name=name, elision=elision, kind="trainstep",
                       c=prob.c, m=M, n=N, r=R, nnz=prob.nnz,
                       phi=prob.phi, model_words_fwd=words_fwd,
                       model_words_fwdbwd=words_step,
                       model_words_fwdbwd_session=words_step_sess)
            if elision == timed_el:
                sess = api.Session()

                def step(X, Y):
                    g = jax.grad(lambda X, Y: jnp.sum(
                        grads.fusedmm(prob, X, Y, elision=elision,
                                      session=sess)))(X, Y)
                    return g

                Xj, Yj = jnp.asarray(X), jnp.asarray(Y)
                step(Xj, Yj)                      # fill session + compile
                # timed steps run under the straggler monitor so the
                # bench records which steps blew past the rolling median
                # (the production cordon signal, docs/robustness.md)
                mon = StepMonitor(straggler_factor=3.0)
                steps = iter(range(1 << 20))
                rec["seconds"] = common.timeit(
                    lambda: mon.timed(next(steps), step, Xj, Yj),
                    iters=2)
                rec["straggler_steps"] = list(mon.flagged)
                # cache health for the step: a mis-keyed session shows
                # up as hits=0 right here in the artifact
                rec["session_stats"] = sess.stats()
                out(common.csv_line(
                    f"dist.{name}.{elision}.trainstep", rec["seconds"],
                    f"c={prob.c};words_fwdbwd={words_step:.0f};"
                    f"session={words_step_sess:.0f};"
                    f"stragglers={len(mon.flagged)};"
                    f"session_hits={rec['session_stats']['hits']}"))
            records.append(rec)

    # --- comm-mode rows: dense vs support-pruned wire words per cell ---
    # Measured (compiled-HLO) and modeled words for both wire formats,
    # on the ER problem (near-full supports: the crossover keeps most
    # channels dense) and a seeded power-law problem (skewed supports:
    # pruning beats the dense Table-III optimum outright).  The bf16
    # rows cast the pruned payloads to half width; on this CPU mesh
    # XLA's float-normalization legalizes the bf16 collectives back to
    # f32 (docs/algorithms.md), so their measured words match "sparse"
    # here and halve only on backends with native bf16 collectives.
    from repro.roofline.hlo_parse import collective_summary

    def wire_words(lowered):
        txt = lowered.compile().as_text()
        return collective_summary(txt)["total_wire_bytes"] / 4

    pl_scale = 9
    problems = [
        ("er", rows, cols, vals, (M, N)),
        ("powerlaw",
         *sparse.powerlaw_problem(pl_scale, R, edge_factor=8, seed=1)[:3],
         (1 << pl_scale, 1 << pl_scale)),
    ]
    for gen, grows, gcols, gvals, (gm, gn) in problems:
        rho_row, rho_col = costmodel.support_density(grows, gcols, gm, gn)
        for name in sorted(api.ALGORITHMS):
            probs = {
                co: api.make_problem(grows, gcols, gvals, (gm, gn), R,
                                     algorithm=name, comm=co)
                for co in ("dense", "sparse")}
            prob_bf16 = api.make_problem(grows, gcols, gvals, (gm, gn), R,
                                         algorithm=name, comm="sparse",
                                         compress="bf16")
            ck = dict(p=probs["dense"].p, c=probs["dense"].c, n=gn, r=R,
                      nnz=len(gvals))
            for elision in probs["dense"].alg.elisions:
                cm_name = costmodel.ELISION_COST_NAME[(name, elision)]
                model = {
                    "dense": costmodel.words_fusedmm(cm_name, **ck).words,
                    "sparse": costmodel.words_fusedmm_sparse(
                        cm_name, m=gm, rho_row=rho_row, rho_col=rho_col,
                        **ck).words}
                meas = {co: wire_words(pr.lower_fusedmm(elision=elision))
                        for co, pr in probs.items()}
                meas["sparse_bf16"] = wire_words(
                    prob_bf16.lower_fusedmm(elision=elision))
                records.append(dict(
                    kind="comm", generator=gen, name=name,
                    elision=elision, c=probs["dense"].c, m=gm, n=gn, r=R,
                    nnz=len(gvals), rho_row=rho_row, rho_col=rho_col,
                    measured_words=meas, model_words=model))
                out(common.csv_line(
                    f"dist.comm.{gen}.{name}.{elision}",
                    meas["sparse"] / max(meas["dense"], 1.0),
                    f"dense={meas['dense']:.0f};"
                    f"sparse={meas['sparse']:.0f};"
                    f"bf16={meas['sparse_bf16']:.0f}"))

    path = common.emit_json(json_path, records,
                            meta=dict(bench="dist", m=M, n=N, r=R,
                                      nnz_row=NNZ_ROW))
    out(f"# wrote {path}")
    arts = obs.write_artifacts(".", "dist", tracer=tracer,
                               registry=metrics_reg)
    out(f"# wrote {arts['trace']}")
    out(f"# wrote {arts['metrics']}")


if __name__ == "__main__":
    run(print)
