"""Paper Fig. 8: strong scaling on real-world matrices (CPU scale-down).

The five SuiteSparse matrices are unavailable offline; RMAT surrogates
match their nnz-per-row density profiles (amazon/uk-2002 ~16/row sparse,
twitter-like ~32/row, eukarya ~110/row dense), scaled to CPU budget.
Benchmarked per matrix at p in {4, 8}: every algorithm at its best c, plus
the 1D block-row no-replication baseline (c=1, the PETSc-equivalent
layout the paper compares against).
"""
import numpy as np

from benchmarks import common
from repro.core import costmodel, d15, s15, sparse


SURROGATES = {
    # name: (scale, edge_factor) -> RMAT 2^scale nodes
    "amazon-like": (12, 8),
    "uk2002-like": (12, 16),
    "eukarya-like": (10, 64),
}


def run(out):
    r = 32
    for name, (scale, ef) in SURROGATES.items():
        rows, cols, vals = sparse.rmat(scale, ef, seed=7)
        m = n = 1 << scale
        rows, cols = sparse.random_permute(rows, cols, m, n, seed=1)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        rng = np.random.default_rng(3)
        A = rng.standard_normal((m, r)).astype(np.float32)
        B = rng.standard_normal((n, r)).astype(np.float32)
        nnz = len(vals)
        phi = nnz / (n * r)
        for p in (4, 8):
            results = {}
            # PETSc stand-in: 1D block row, no replication, no elision
            g, plan, Ash, Bsh = common.build_d15(
                1, rows, cols, vals, m, n, r, A, B)
            results["baseline_1d"] = common.timeit(
                lambda: d15.fusedmm_d15(g, plan, Ash, Bsh, elision="none"),
                iters=2)
            for cm_name, elis in (("d15_replication_reuse", "reuse"),
                                  ("d15_local_fusion", "fused"),
                                  ("s15_replication_reuse", "reuse")):
                best = costmodel.best_c(cm_name, p=p, n=n, r=r, nnz=nnz)
                if cm_name.startswith("d15"):
                    g, plan, Ash, Bsh = common.build_d15(
                        best.c, rows, cols, vals, m, n, r, A, B,
                        transpose=(elis == "reuse"))
                    fn = lambda: d15.fusedmm_d15(g, plan, Ash, Bsh,
                                                 elision=elis)
                else:
                    g, plan, Ash, Bsh = common.build_s15(
                        best.c, rows, cols, vals, m, n, r, A, B)
                    fn = lambda: s15.fusedmm_s15(g, plan, Ash, Bsh)
                results[cm_name] = common.timeit(fn, iters=2)
            base = results["baseline_1d"]
            for k, v in results.items():
                out(common.csv_line(
                    f"fig8.{name}.p{p}.{k}", v,
                    f"phi={phi:.3f};speedup_vs_1d={base / v:.2f}x"))


if __name__ == "__main__":
    run(print)
