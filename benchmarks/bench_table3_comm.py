"""Paper Table III: words communicated per FusedMM algorithm.

Measures the loop-aware wire words of every algorithm's compiled HLO on 8
devices and reports the ratio to the paper's closed-form prediction — the
quantitative faithfulness check (d15 family matches exactly; s15 carries
the documented pack-padding + dual-gather constants; see DESIGN.md).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import costmodel, d15, d25, s15, s25
from repro.core.grid import make_grid25
from repro.roofline.hlo_parse import collective_summary

W = 4


def wire_words(lowered):
    return collective_summary(
        lowered.compile().as_text())["total_wire_bytes"] / W


def run(out):
    m = n = 2048
    r, nnz_row = 64, 4
    rows, cols, vals, A, B = common.er_problem(m, n, r, nnz_row, seed=0)
    nnz = len(vals)
    p = 8

    for c in (2, 4):
        for cm_name, elis, transpose in (
                ("d15_no_elision", "none", False),
                ("d15_replication_reuse", "reuse", True),
                ("d15_local_fusion", "fused", False)):
            g, plan, Ash, Bsh = common.build_d15(
                c, rows, cols, vals, m, n, r, A, B, transpose=transpose)
            low = d15.fusedmm_d15.lower(g, plan, Ash, Bsh, elision=elis)
            meas = wire_words(low)
            paper = costmodel.words_fusedmm(cm_name, p=p, c=c, n=n, r=r,
                                            nnz=nnz).words
            out(common.csv_line(f"table3.{cm_name}.c{c}", 0.0,
                                f"measured={meas:.0f};paper={paper:.0f};"
                                f"ratio={meas / paper:.2f}"))
        g, plan, Ash, Bsh = common.build_s15(c, rows, cols, vals, m, n, r,
                                             A, B)
        low = s15.fusedmm_s15.lower(g, plan, Ash, Bsh, elision="reuse")
        meas = wire_words(low)
        paper = costmodel.words_fusedmm("s15_replication_reuse", p=p, c=c,
                                        n=n, r=r, nnz=nnz).words
        out(common.csv_line(f"table3.s15_replication_reuse.c{c}", 0.0,
                            f"measured={meas:.0f};paper={paper:.0f};"
                            f"ratio={meas / paper:.2f}"))

    # 2.5D on 2x2x2
    g25 = make_grid25(2)
    Ash = jax.device_put(jnp.asarray(A), g25.sharding(("row", "fiber"),
                                                      "col"))
    B_sk = d25.skew_b(g25, B)
    for cm_name, elis, transpose in (
            ("d25_no_elision", "none", False),
            ("d25_replication_reuse", "reuse", True)):
        plan = d25.plan_d25(g25, rows, cols, vals, m, n, r,
                            transpose=transpose, row_tile=64, nz_block=64)
        low = d25.fusedmm_d25.lower(g25, plan, Ash, B_sk, elision=elis)
        meas = wire_words(low)
        paper = costmodel.words_fusedmm(cm_name, p=p, c=2, n=n, r=r,
                                        nnz=nnz).words
        out(common.csv_line(f"table3.{cm_name}.c2", 0.0,
                            f"measured={meas:.0f};paper={paper:.0f};"
                            f"ratio={meas / paper:.2f}"))
    plan = s25.plan_s25(g25, rows, cols, vals, m, n, r, row_tile=64,
                        nz_block=64)
    A_sk = s25.skew_dense(g25, A, along="row")
    B_sk2 = s25.skew_dense(g25, B, along="col")
    low = s25.fusedmm_s25.lower(g25, plan, A_sk, B_sk2)
    meas = wire_words(low)
    paper = costmodel.words_fusedmm("s25_no_elision", p=p, c=2, n=n, r=r,
                                    nnz=nnz).words
    out(common.csv_line("table3.s25_no_elision.c2", 0.0,
                        f"measured={meas:.0f};paper={paper:.0f};"
                        f"ratio={meas / paper:.2f}"))


if __name__ == "__main__":
    run(print)
