"""Paper Fig. 6: best algorithm as a function of (r, nnz) — the phi regime.

Predicted winner from Table III at the paper's p=32, m=2^22 setting, and
the observed winner by measured wall time at the CPU scale-down (p=8).
The paper's conclusion to reproduce: 1.5D sparse-shifting wins at low
phi = nnz/(n r); 1.5D dense-shifting wins at high phi.
"""
import numpy as np

from benchmarks import common
from repro.core import costmodel, d15, s15

CANDIDATES = ("d15_replication_reuse", "d15_local_fusion",
              "s15_replication_reuse")


def observed_winner(p, rows, cols, vals, m, n, r, A, B):
    times = {}
    for name in CANDIDATES:
        best = costmodel.best_c(name, p=p, n=n, r=r, nnz=len(vals))
        if name.startswith("d15"):
            elis = "reuse" if "reuse" in name else "fused"
            g, plan, Ash, Bsh = common.build_d15(
                best.c, rows, cols, vals, m, n, r, A, B,
                transpose=(elis == "reuse"))
            fn = lambda: d15.fusedmm_d15(g, plan, Ash, Bsh, elision=elis)
        else:
            g, plan, Ash, Bsh = common.build_s15(best.c, rows, cols, vals,
                                                 m, n, r, A, B)
            fn = lambda: s15.fusedmm_s15(g, plan, Ash, Bsh)
        times[name] = common.timeit(fn, iters=2)
    return min(times, key=times.get), times


def run(out):
    p = 8
    m = n = 4096
    agree = 0
    cells = 0
    for r in (32, 128):
        for nnz_row in (2, 16, 64):
            rows, cols, vals, A, B = common.er_problem(m, n, r, nnz_row,
                                                       seed=r + nnz_row)
            nnz = len(vals)
            phi = nnz / (n * r)
            pred = next(iter(costmodel.select_algorithm(
                p=p, n=n, r=r, nnz=nnz, candidates=CANDIDATES)))
            obs, times = observed_winner(p, rows, cols, vals, m, n, r, A, B)
            # paper-scale prediction (p=32, m=2^22, same phi)
            pred32 = next(iter(costmodel.select_algorithm(
                p=32, n=1 << 22, r=r, nnz=int(phi * (1 << 22) * r),
                candidates=CANDIDATES)))
            cells += 1
            agree += (pred == obs)
            out(common.csv_line(
                f"fig6.r{r}.nnz{nnz_row}", times[obs],
                f"phi={phi:.3f};pred={pred};obs={obs};paperscale={pred32}"))
    out(common.csv_line("fig6.agreement", 0.0,
                        f"predicted==observed in {agree}/{cells} cells"))


if __name__ == "__main__":
    run(print)
