"""Paper Fig. 4: weak scaling of FusedMM algorithms (setups 1 and 2).

Setup 1: p doubles with the sparse side-length; nnz/row and r constant
         (phi constant, density decays).
Setup 2: p quadruples; side-length and nnz/row both double (density
         constant, phi doubles).

CPU-host scale-down of the paper's 2..256-node runs: p in {2, 4, 8}
forced host devices, side length 2^10 * p (setup 1).  Reported per cell:
wall time of the jitted FusedMM and the loop-aware HLO wire-GB (the
communication metric the paper plots); the costmodel projection extends
the curve to the paper's node counts.
"""
import numpy as np

from benchmarks import common
from repro.core import costmodel, d15, s15


def run(out):
    r = 64
    for setup in (1, 2):
        for p in (2, 4, 8):
            if setup == 1:
                m = n = 1024 * p
                nnz_row = 8
            else:
                if p not in (2, 8):      # quadrupling: 2 -> 8
                    continue
                scale = int(np.sqrt(p // 2))
                m = n = 2048 * scale
                nnz_row = 8 * scale
            rows, cols, vals, A, B = common.er_problem(m, n, r, nnz_row,
                                                       seed=p)
            nnz = len(vals)
            for alg, elis in (("d15", "none"), ("d15", "reuse"),
                              ("d15", "fused"), ("s15", "reuse")):
                cm_name = {("d15", "none"): "d15_no_elision",
                           ("d15", "reuse"): "d15_replication_reuse",
                           ("d15", "fused"): "d15_local_fusion",
                           ("s15", "reuse"): "s15_replication_reuse"}[
                               (alg, elis)]
                best = costmodel.best_c(cm_name, p=p, n=n, r=r, nnz=nnz)
                c = best.c
                if alg == "d15":
                    g, plan, Ash, Bsh = common.build_d15(
                        c, rows, cols, vals, m, n, r, A, B,
                        transpose=(elis == "reuse"))
                    fn = lambda: d15.fusedmm_d15(g, plan, Ash, Bsh,
                                                 elision=elis)
                    low = d15.fusedmm_d15.lower(g, plan, Ash, Bsh,
                                                elision=elis)
                else:
                    g, plan, Ash, Bsh = common.build_s15(
                        c, rows, cols, vals, m, n, r, A, B)
                    fn = lambda: s15.fusedmm_s15(g, plan, Ash, Bsh,
                                                 elision="reuse")
                    low = s15.fusedmm_s15.lower(g, plan, Ash, Bsh,
                                                elision="reuse")
                t = common.timeit(fn)
                gb = common.wire_gb(low)
                proj256 = costmodel.best_c(cm_name, p=256, n=n * 256 // p,
                                           r=r, nnz=nnz * 256 // p).words
                out(common.csv_line(
                    f"fig4.setup{setup}.p{p}.{cm_name}.c{c}", t,
                    f"wireGB={gb:.4f};proj256words={proj256:.3e}"))


if __name__ == "__main__":
    run(print)
