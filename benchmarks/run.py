import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# ^ the distributed benchmarks need 8 host devices; must precede jax init.

"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage:
    PYTHONPATH=src python -m benchmarks.run [--only fig4,fig7]

Artifacts land in the working directory: ``BENCH_<key>.json`` (perf
records) and, from the obs-instrumented benches (dist, serving), the
``TRACE_<key>.json`` / ``METRICS_<key>.json`` pair described in
docs/observability.md — Perfetto-loadable spans with per-row cost-model
drift, and the metrics-registry snapshot.
"""
import argparse
import sys
import time
import traceback

MODULES = [
    ("kernels", "benchmarks.bench_kernels"),
    ("dist", "benchmarks.bench_dist"),
    ("table3", "benchmarks.bench_table3_comm"),
    ("fig4", "benchmarks.bench_fig4_weak_scaling"),
    ("fig5", "benchmarks.bench_fig5_breakdown"),
    ("fig6", "benchmarks.bench_fig6_embedding_width"),
    ("fig7", "benchmarks.bench_fig7_replication"),
    ("fig8", "benchmarks.bench_fig8_strong_scaling"),
    ("fig9", "benchmarks.bench_fig9_apps"),
    ("serving", "benchmarks.bench_serving"),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark keys")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run(print)
            print(f"# {key} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures.append(key)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
