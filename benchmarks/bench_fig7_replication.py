"""Paper Fig. 7 / Table IV: predicted vs observed optimal replication c.

For each d15 elision strategy at p=8: Table IV's closed-form c*, the best
integer c by the cost model, and the observed best c by measured HLO wire
bytes (communication volume is the observable the theory predicts).
Reproduces the paper's ordering: c*(fused) <= c*(none) <= c*(reuse).
"""
from benchmarks import common
from repro.core import costmodel, d15


def run(out):
    p, r, nnz_row = 8, 64, 8
    m = n = 4096
    rows, cols, vals, A, B = common.er_problem(m, n, r, nnz_row, seed=0)
    nnz = len(vals)
    best_cs = {}
    for cm_name, elis, transpose in (
            ("d15_no_elision", "none", False),
            ("d15_replication_reuse", "reuse", True),
            ("d15_local_fusion", "fused", False)):
        cstar = costmodel.optimal_c(cm_name, p=p)
        model_c = costmodel.best_c(cm_name, p=p, n=n, r=r, nnz=nnz).c
        measured = {}
        for c in (1, 2, 4, 8):
            g, plan, Ash, Bsh = common.build_d15(
                c, rows, cols, vals, m, n, r, A, B, transpose=transpose)
            low = d15.fusedmm_d15.lower(g, plan, Ash, Bsh, elision=elis)
            measured[c] = common.wire_gb(low)
        obs_c = min(measured, key=measured.get)
        best_cs[cm_name] = obs_c
        out(common.csv_line(
            f"fig7.{cm_name}", measured[obs_c],
            f"cstar={cstar:.2f};model_c={model_c};observed_c={obs_c};"
            + ";".join(f"wire(c={c})={v:.4f}GB" for c, v in
                       measured.items())))
    ordered = (best_cs["d15_local_fusion"]
               <= best_cs["d15_no_elision"]
               <= best_cs["d15_replication_reuse"])
    out(common.csv_line("fig7.ordering", 0.0,
                        f"fusion<=none<=reuse holds: {ordered}"))


if __name__ == "__main__":
    run(print)
