"""Patch EXPERIMENTS.md placeholders with generated tables.

  PYTHONPATH=src python scripts/update_experiments.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.roofline.analysis import load_all, to_markdown  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def paper_kernel_table():
    d = os.path.join(ROOT, "results", "perf_fusedmm")
    if not os.path.isdir(d):
        return "(paper-kernel sweep pending)\n"
    rows = []
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(d, fn)) as f:
            r = json.load(f)
        wire_mb = r["collectives"]["total_wire_bytes"] / 1e6
        paper_mb = r.get("paper_words", 0) * 4 / 1e6
        coll_ms = r["collectives"]["total_wire_bytes"] / 50e9 * 1e3
        comp_us = r["program"]["dot_flops"] / 197e12 * 1e6
        rows.append((r["arch"], r["shape"], r.get("c"), wire_mb, paper_mb,
                     coll_ms, comp_us))
    out = ["**Paper-kernel c x elision sweep (p=256, m=n=2^18, r=256, "
           "phi=0.125; wire MB per device per FusedMM call):**", "",
           "| algo | elision | c | wire MB | Table III MB | collective ms "
           "| compute us |", "|---|---|---|---|---|---|---|"]
    best = None
    for a, s, c, w, pm, cm, cu in sorted(rows, key=lambda x: x[3]):
        out.append(f"| {a} | {s} | {c} | {w:.2f} | {pm:.2f} | {cm:.3f} | "
                   f"{cu:.1f} |")
        if best is None:
            best = (a, s, c, w)
    if best:
        out += ["", f"Best: {best[0]} elision={best[1]} c={best[2]} at "
                f"{best[3]:.2f} MB/device — vs the paper-faithful "
                "no-elision baseline at the same c (see `none_c*` rows), "
                "reproducing the ~30% communication saving the paper "
                "reports for elision at 256 nodes."]
    return "\n".join(out) + "\n"


def train_curve():
    path = os.path.join(ROOT, "results", "train_100m.jsonl")
    if not os.path.exists(path):
        return "(training run pending)\n"
    rows = [json.loads(l) for l in open(path)]
    if not rows:
        return "(training run pending)\n"
    pts = rows[:: max(len(rows) // 12, 1)] + [rows[-1]]
    lines = ["| step | loss | grad norm |", "|---|---|---|"]
    seen = set()
    for r in pts:
        if r["step"] in seen:
            continue
        seen.add(r["step"])
        lines.append(f"| {r['step']} | {r['loss']:.3f} | "
                     f"{r['grad_norm']:.2f} |")
    return "\n".join(lines) + "\n"


def lm_perf_table():
    d = os.path.join(ROOT, "results", "perf_lm")
    if not os.path.isdir(d):
        return "(LM hillclimb pending)\n"
    lines = ["**LM train-cell iterations (qwen2-vl-72b / deepseek-v2-lite "
             "train_4k, single-pod):**", "",
             "| variant | collective s | compute s | memory s | temp GB |",
             "|---|---|---|---|---|"]
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(d, fn)) as f:
            r = json.load(f)
        coll = r["collectives"]["total_wire_bytes"] / 50e9
        comp = r["program"]["dot_flops"] / 197e12
        mem = r["program"]["bytes_touched"] / 819e9
        temp = r["memory"]["temp_size_in_bytes"] / 1e9
        lines.append(f"| {fn[:-5]} | {coll:.2f} | {comp:.4f} | {mem:.3f} | "
                     f"{temp:.1f} |")
    return "\n".join(lines) + "\n"


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    try:
        table = to_markdown(load_all(os.path.join(ROOT, "results",
                                                  "dryrun")))
    except Exception as e:
        table = f"(roofline table pending: {e})\n"
    for marker, content in (
            ("<!-- ROOFLINE_TABLE -->", table),
            ("<!-- PERF_PAPER_KERNEL -->", paper_kernel_table()),
            ("<!-- PERF_LM -->", lm_perf_table()),
            ("<!-- TRAIN_CURVE -->", train_curve())):
        text = text.replace(marker, marker + "\n" + content)
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
