#!/usr/bin/env python
"""Link-check the repo docs: docs/*.md, README.md, DESIGN.md.

Validates every inline markdown link ``[text](target)``:

* relative file targets must exist (resolved against the linking file's
  directory);
* ``file#anchor`` / ``#anchor`` fragments must match a heading in the
  target file (GitHub-style slugification) — a dead anchor fails the
  build, per the CI docs job;
* ``http(s)://`` targets are recorded but not fetched (CI has no
  network guarantee).

Exit code 0 iff no dead links.  Usage:

    python scripts/check_docs.py [root]
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation (backticks
    included), spaces to hyphens."""
    text = heading.strip().lower()
    text = re.sub(r"[`*_]", "", text)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # linked headings
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set:
    out, in_fence = set(), False
    for line in path.read_text().splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if m:
            slug = slugify(m.group(1))
            n, base = 1, slug
            while slug in out:          # duplicate headings: -1, -2, ...
                slug = f"{base}-{n}"
                n += 1
            out.add(slug)
    return out


def links_of(path: pathlib.Path):
    in_fence = False
    for i, line in enumerate(path.read_text().splitlines(), 1):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            yield i, m.group(1)


def check(root: pathlib.Path):
    files = sorted(root.glob("docs/*.md"))
    for name in ("README.md", "DESIGN.md"):
        if (root / name).exists():
            files.append(root / name)
    errors, checked = [], 0
    for f in files:
        for lineno, target in links_of(f):
            checked += 1
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            dest = f if not target else (f.parent / target).resolve()
            if not dest.exists():
                errors.append(f"{f.relative_to(root)}:{lineno}: "
                              f"missing file {target!r}")
                continue
            if frag is not None:
                if dest.suffix != ".md":
                    errors.append(f"{f.relative_to(root)}:{lineno}: "
                                  f"anchor on non-markdown {target!r}")
                elif frag not in anchors_of(dest):
                    errors.append(f"{f.relative_to(root)}:{lineno}: "
                                  f"dead anchor #{frag} in "
                                  f"{dest.relative_to(root)}")
    return errors, checked, len(files)


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    root = pathlib.Path(argv[0] if argv else ".").resolve()
    errors, checked, nfiles = check(root)
    for e in errors:
        print(f"DEAD LINK: {e}")
    print(f"checked {checked} links across {nfiles} files: "
          f"{len(errors)} dead")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
