"""Matrix Market loader + the shared seeded problem generator."""
import os

import numpy as np
import pytest

from repro.core import mtx, sparse

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "tiny.mtx")


def test_fixture_roundtrip(tmp_path):
    rows, cols, vals, shape = mtx.load_mtx(FIXTURE)
    assert shape == (64, 64)
    assert len(vals) > 0 and rows.dtype == np.int32
    out = tmp_path / "copy.mtx"
    mtx.save_mtx(str(out), rows, cols, vals, shape)
    r2, c2, v2, s2 = mtx.load_mtx(str(out))
    assert s2 == shape
    np.testing.assert_array_equal(r2, rows)
    np.testing.assert_array_equal(c2, cols)
    np.testing.assert_allclose(v2, vals, rtol=1e-6)


def test_pattern_and_symmetric(tmp_path):
    p = tmp_path / "sym.mtx"
    p.write_text("%%MatrixMarket matrix coordinate pattern symmetric\n"
                 "3 3 3\n1 1\n2 1\n3 2\n")
    rows, cols, vals, shape = mtx.load_mtx(str(p))
    assert shape == (3, 3)
    dense = np.zeros((3, 3))
    dense[rows, cols] = vals
    want = np.array([[1, 1, 0], [1, 0, 1], [0, 1, 0]], float)
    np.testing.assert_array_equal(dense, want)


def test_skew_symmetric(tmp_path):
    p = tmp_path / "skew.mtx"
    p.write_text("%%MatrixMarket matrix coordinate real skew-symmetric\n"
                 "2 2 1\n2 1 3.0\n")
    rows, cols, vals, _ = mtx.load_mtx(str(p))
    dense = np.zeros((2, 2))
    dense[rows, cols] = vals
    np.testing.assert_array_equal(dense, [[0, -3], [3, 0]])


def test_duplicates_summed(tmp_path):
    p = tmp_path / "dup.mtx"
    p.write_text("%%MatrixMarket matrix coordinate real general\n"
                 "2 2 3\n1 1 1.0\n1 1 2.0\n2 2 5.0\n")
    rows, cols, vals, _ = mtx.load_mtx(str(p))
    assert len(vals) == 2
    np.testing.assert_allclose(sorted(vals), [3.0, 5.0])


def test_rejects_dense_array_format(tmp_path):
    p = tmp_path / "arr.mtx"
    p.write_text("%%MatrixMarket matrix array real general\n2 2\n1\n2\n3\n4\n")
    with pytest.raises(ValueError, match="coordinate"):
        mtx.load_mtx(str(p))


def test_loader_feeds_the_api(tmp_path):
    """A loaded matrix runs through make_problem like any generator."""
    import jax
    from repro.core import api
    rows, cols, vals, (m, n) = mtx.load_mtx(FIXTURE)
    prob = api.make_problem(rows, cols, vals, (m, n), 8,
                            devices=jax.devices()[:1])
    Sd = np.zeros((m, n), np.float32)
    Sd[rows, cols] = vals
    Y = np.random.default_rng(0).standard_normal((n, 8)).astype(np.float32)
    np.testing.assert_allclose(prob.spmm(Y), Sd @ Y, rtol=2e-4, atol=2e-4)


def test_random_problem_deterministic_and_matches_er():
    """The shared generator is seed-deterministic and preserves the
    historical (erdos_renyi(seed), default_rng(seed+1)) streams."""
    a = sparse.random_problem(32, 48, 4, 3, seed=5)
    b = sparse.random_problem(32, 48, 4, 3, seed=5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    rows, cols, vals = sparse.erdos_renyi(32, 48, 3, seed=5)
    np.testing.assert_array_equal(a[0], rows)
    np.testing.assert_array_equal(a[2], vals)
    rng = np.random.default_rng(6)
    np.testing.assert_array_equal(
        a[3], rng.standard_normal((32, 4)).astype(np.float32))
    assert a[3].shape == (32, 4) and a[4].shape == (48, 4)


def test_powerlaw_problem_deterministic_and_skewed():
    """The RMAT bundle is seed-deterministic, honors the random_problem
    contract, and produces the degree skew comm="sparse" exploits:
    partial row/col support with hub rows far above the mean degree."""
    a = sparse.powerlaw_problem(8, 16, edge_factor=8, seed=3)
    b = sparse.powerlaw_problem(8, 16, edge_factor=8, seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    rows, cols, vals, X, Y = a
    n = 1 << 8
    assert X.shape == (n, 16) and Y.shape == (n, 16)
    assert rows.max() < n and cols.max() < n and len(vals) == len(rows)
    from repro.core import costmodel
    rho_r, rho_c = costmodel.support_density(rows, cols, n, n)
    assert rho_r < 0.9 and rho_c < 0.9, (rho_r, rho_c)
    deg = np.bincount(rows, minlength=n)
    assert deg.max() > 4 * deg.mean()
    assert costmodel.choose_comm(rows, cols, n, n) == "sparse"
