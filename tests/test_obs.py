"""Observability layer: metrics registry, tracer spans, zero-cost off.

Fast tier (1 device): the multi-device traced smoke with the drift gate
lives in tests/dist_scripts/check_obs.py (slow tier).
"""
import json

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import api, sparse
from repro.obs import metrics as obs_metrics
from repro.obs import tracer as obs_tracer


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_histogram():
    reg = obs.MetricsRegistry()
    reg.inc("a")
    reg.inc("a", 2.5)
    reg.gauge("g", 7.0, family="d15")
    reg.gauge("g", 9.0, family="d15")
    for v in (0.001, 0.01, 0.5, 2.0):
        reg.observe("h", v)
    assert reg.value("a") == 3.5
    assert reg.value("g", family="d15") == 9.0
    h = reg.histogram("h")
    assert h["count"] == 4 and h["min"] == 0.001 and h["max"] == 2.0
    assert h["mean"] == pytest.approx(2.511 / 4)


def test_registry_labels_are_distinct_series():
    reg = obs.MetricsRegistry()
    reg.inc("rounds", op="sddmm")
    reg.inc("rounds", op="spmm")
    reg.inc("rounds", op="sddmm")
    assert reg.value("rounds", op="sddmm") == 2
    assert reg.value("rounds", op="spmm") == 1
    assert reg.value("rounds") is None          # unlabeled series absent


def test_registry_type_conflict_raises():
    reg = obs.MetricsRegistry()
    reg.inc("x")
    with pytest.raises(TypeError):
        reg.gauge("x", 1.0)
    with pytest.raises(TypeError):
        reg.observe("x", 1.0)


def test_registry_gather_skips_non_numeric():
    reg = obs.MetricsRegistry()
    reg.gather("s", dict(hits=3, rate=0.5, name="d15", nested=dict(a=1),
                         flag=True))
    assert reg.value("s.hits") == 3.0
    assert reg.value("s.rate") == 0.5
    assert reg.value("s.name") is None
    assert reg.value("s.nested") is None
    assert reg.value("s.flag") is None          # bools are identity, not data


def test_registry_snapshot_json_round_trip():
    reg = obs.MetricsRegistry()
    reg.inc("c", 3, op="fusedmm")
    reg.gauge("drift", 1.0, family="s25")
    reg.observe("lat", 0.25)
    reg.observe("lat", 4000.0)
    reg.observe("empty_never", 1.0, tag="x")
    blob = reg.to_json()
    back = obs.MetricsRegistry.from_snapshot(json.loads(blob))
    assert back.snapshot() == reg.snapshot()
    assert back.to_json() == blob
    # and a snapshot of a registry holding an EMPTY histogram round-trips
    reg2 = obs.MetricsRegistry()
    reg2._get("h", "histogram", {})
    back2 = obs.MetricsRegistry.from_snapshot(reg2.snapshot())
    assert back2.snapshot() == reg2.snapshot()


def test_registry_merge_adds_counters_and_labels():
    a, b = obs.MetricsRegistry(), obs.MetricsRegistry()
    a.inc("n", 1, mode="x")
    b.inc("n", 2, mode="x")
    b.observe("h", 1.0)
    out = obs.MetricsRegistry()
    out.merge(a, run=0)
    out.merge(b, run=0)
    assert out.value("n", mode="x", run=0) == 3
    assert out.histogram("h", run=0)["count"] == 1


def test_collect_context_arms_and_restores():
    assert obs_metrics.active() is None
    with obs.collect() as reg:
        assert obs_metrics.active() is reg
        with obs.collect() as inner:
            assert obs_metrics.active() is inner
        assert obs_metrics.active() is reg
    assert obs_metrics.active() is None


# ---------------------------------------------------------------------------
# schedule_words contract (1-device degenerate grids)
# ---------------------------------------------------------------------------

def _problem(**kw):
    rows, cols, vals, X, Y = sparse.random_problem(64, 64, 8, 4, seed=0)
    prob = api.make_problem(rows, cols, vals, (64, 64), 8,
                            devices=jax.devices()[:1], **kw)
    return prob, X, Y


@pytest.mark.parametrize("name", sorted(api.ALGORITHMS))
def test_schedule_words_aligns_with_schedule_events(name):
    prob, _, _ = _problem(algorithm=name)
    for op in ("sddmm", "spmm", "spmm_t"):
        ev = prob.alg.schedule_events(prob, op)
        words = prob.schedule_words(op)
        assert words is not None
        assert [(p, t) for p, t, _, _ in words] == ev
        for _, _, kind, w in words:
            assert w >= 0.0
            assert kind in (None, "all-gather", "reduce-scatter",
                            "collective-permute")
    for el in prob.alg.elisions:
        ev = prob.alg.schedule_events(prob, "fusedmm", el)
        words = prob.schedule_words("fusedmm", el)
        assert [(p, t) for p, t, _, _ in words] == ev


def test_schedule_words_none_for_sparse_wire():
    prob, _, _ = _problem(algorithm="d15", comm="sparse")
    assert prob.schedule_words("sddmm") is None


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_trace_records_round_and_event_spans():
    prob, X, Y = _problem(algorithm="d15")
    with obs.collect() as reg, obs.trace(measure_wire=False) as tr:
        prob.sddmm(X, Y)
        prob.fusedmm(X, Y, elision="fused")
    assert [r.op for r in tr.rounds] == ["sddmm", "fusedmm"]
    r0 = tr.rounds[0]
    assert r0.family == "d15" and r0.comm == "dense" and r0.p == 1
    assert len(r0.events) == len(
        prob.alg.schedule_events(prob, "sddmm"))
    assert r0.dur >= 0 and all(e.dur >= 0 for e in r0.events)
    # event spans tile the round span (modeled attribution)
    assert sum(e.dur for e in r0.events) == pytest.approx(r0.dur)
    # metrics fed live
    assert reg.value("executor.rounds", op="sddmm", family="d15") == 1
    assert reg.histogram("executor.round_seconds", op="fusedmm",
                         family="d15")["count"] == 1


def test_trace_is_bitwise_identical_and_counts_rounds():
    prob, X, Y = _problem(algorithm="s15")
    base = prob.fusedmm(X, Y, elision="none")
    with obs.trace(measure_wire=False) as tr:
        traced = prob.fusedmm(X, Y, elision="none")
        traced2 = prob.fusedmm(X, Y, elision="none")
    assert np.array_equal(base[0], traced[0])
    assert np.array_equal(base[1].values(), traced[1].values())
    assert [r.round for r in tr.rounds] == [0, 1]


def test_trace_survives_unlowerable_measurement():
    # measure_wire=True on a 1-device grid must not break tracing even
    # if lowering fails — measurement errors degrade to measured=None
    prob, X, Y = _problem(algorithm="d25")
    with obs.trace() as tr:
        prob.spmm(Y)
    assert len(tr.rounds) == 1


def test_traced_error_round_is_recorded_and_reraised():
    prob, X, Y = _problem(algorithm="d15")
    with obs.trace(measure_wire=False) as tr:
        with pytest.raises(ValueError):
            prob.fusedmm(X, Y, elision="nonsense")
    # elision validation fails before the round hook: nothing recorded
    assert tr.rounds == []
    with obs.trace(measure_wire=False) as tr:
        with pytest.raises(TypeError):
            with tr.round(prob, "sddmm"):
                raise TypeError("boom")
    assert tr.rounds[0].error == "TypeError"
    assert tr.rounds[0].drift is None


# ---------------------------------------------------------------------------
# Zero-cost when disabled (the faults.guard discipline)
# ---------------------------------------------------------------------------

def test_disabled_tracer_is_never_touched(monkeypatch):
    """With no tracer armed the executors must not construct spans,
    call any Tracer method, or change results — the disabled path is
    one `active() is None` check, like faults.guard."""
    prob, X, Y = _problem(algorithm="d15")
    base = prob.sddmm(X, Y).values()

    def explode(*a, **kw):
        raise AssertionError("obs hook ran while disabled")

    monkeypatch.setattr(obs_tracer.Tracer, "round", explode)
    monkeypatch.setattr(obs_tracer.Tracer, "_finish", explode)
    assert obs_tracer.active() is None
    got = prob.sddmm(X, Y).values()      # would raise if obs were touched
    assert np.array_equal(base, got)


def test_disabled_metrics_skip_instrumented_sites(monkeypatch):
    from repro.distributed.elastic import StepMonitor
    monkeypatch.setattr(obs.MetricsRegistry, "observe",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            AssertionError("metrics while disabled")))
    assert obs_metrics.active() is None
    mon = StepMonitor()
    assert mon.observe(0, 1.0) is False  # no registry: no metric calls


def test_trace_context_restores_previous():
    assert obs_tracer.active() is None
    with obs.trace(measure_wire=False) as tr:
        assert obs_tracer.active() is tr
    assert obs_tracer.active() is None


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------

def test_chrome_trace_structure_and_artifacts(tmp_path):
    prob, X, Y = _problem(algorithm="d15")
    with obs.collect() as reg, obs.trace(measure_wire=False) as tr:
        prob.sddmm(X, Y)
    ct = obs.chrome_trace(tr)
    evs = ct["traceEvents"]
    names = {e["name"] for e in evs}
    assert "d15.sddmm" in names and "rank 0" in str(evs)
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and all(set(e) >= {"ts", "dur", "pid", "tid"} for e in xs)
    # events nest inside their round span on the same track
    rnd = next(e for e in xs if e["cat"] == "round")
    for e in xs:
        if e["cat"] == "event" and e["tid"] == rnd["tid"]:
            assert e["ts"] >= rnd["ts"] - 1e-6
            assert e["ts"] + e["dur"] <= rnd["ts"] + rnd["dur"] + 1e-6
    paths = obs.write_artifacts(str(tmp_path), "t", tracer=tr,
                                registry=reg)
    trace_blob = json.load(open(paths["trace"]))
    assert trace_blob["traceEvents"]
    metrics_blob = json.load(open(paths["metrics"]))
    assert obs.MetricsRegistry.from_snapshot(
        metrics_blob).snapshot() == reg.snapshot()
    assert paths["trace"].endswith("TRACE_t.json")
    assert paths["metrics"].endswith("METRICS_t.json")


def test_round_summary_renders():
    prob, X, Y = _problem(algorithm="s25")
    with obs.trace(measure_wire=False) as tr:
        prob.fusedmm(X, Y, elision="reuse")
    txt = obs.round_summary(tr)
    assert "s25.fusedmm[reuse]" in txt and "drift" in txt
