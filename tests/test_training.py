"""Training-substrate tests: optimizer, loss descent, checkpointing,
gradient compression, microbatching, elasticity hooks."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.config import ParallelConfig, TrainConfig
from repro.configs import llama32_1b
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training import compression, data
from repro.training import optimizer as opt
from repro.training import train_step as ts

PCFG = ParallelConfig(compute_dtype="float32")


def small_setup(seed=0, seq=64, batch=4):
    cfg = llama32_1b.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    state = opt.init_opt_state(params)
    pipe = data.SyntheticLM(cfg.vocab, seq, batch, seed=seed)
    return cfg, params, state, pipe


def test_loss_decreases_over_steps():
    cfg, params, state, pipe = small_setup()
    tcfg = TrainConfig(seq_len=64, global_batch=4, lr=1e-3, steps=60,
                       warmup=5)
    step, _, _ = ts.make_train_step(cfg, PCFG, tcfg, mesh=None)
    fn = jax.jit(step)
    losses = []
    for i in range(60):
        batch = jax.tree.map(jnp.asarray, pipe.batch(i))
        params, state, metrics = fn(params, state, batch)
        losses.append(float(metrics["loss"]))
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.1, (first, last)


def test_microbatch_matches_full_batch_gradients():
    """Grad accumulation over microbatches == single big batch (same data)."""
    cfg, params, state, pipe = small_setup(seed=3)
    batch = jax.tree.map(jnp.asarray, pipe.batch(0))
    t_full = TrainConfig(seq_len=64, global_batch=4, microbatch=0,
                         lr=1e-3)
    t_micro = TrainConfig(seq_len=64, global_batch=4, microbatch=2,
                          lr=1e-3)
    s_full, _, _ = ts.make_train_step(cfg, PCFG, t_full, mesh=None)
    s_micro, _, _ = ts.make_train_step(cfg, PCFG, t_micro, mesh=None)
    p1, _, m1 = jax.jit(s_full)(params, state, batch)
    p2, _, m2 = jax.jit(s_micro)(params, state, batch)
    # same direction updates: params close (loss averaging differs at the
    # margin by masking, so allow small tolerance)
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)))
    assert d < 5e-4, d


def test_adamw_weight_decay_pulls_to_zero():
    ocfg = opt.AdamWConfig(lr=0.1, weight_decay=0.5, warmup=0,
                           total_steps=10, grad_clip=1e9)
    params = {"w": jnp.ones((4,))}
    state = opt.init_opt_state(params)
    grads = {"w": jnp.zeros((4,))}
    p, state, _ = opt.adamw_update(ocfg, params, grads, state)
    assert float(p["w"][0]) < 1.0


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg, params, state, pipe = small_setup(seed=1)
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, {"params": params, "opt": state})
    assert ckpt.latest_step(d) == 7
    restored = ckpt.restore(d, 7, {"params": params, "opt": state})
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_ignores_uncommitted(tmp_path):
    d = str(tmp_path / "ck")
    os.makedirs(os.path.join(d, "step_00000009"))  # no _COMMITTED marker
    ckpt.save(d, 3, {"x": jnp.ones(2)})
    assert ckpt.latest_step(d) == 3


def test_checkpoint_keep_k(tmp_path):
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, {"x": jnp.ones(1) * s}, keep=2)
    assert ckpt.latest_step(d) == 5
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2


def test_checkpoint_missing_is_typed(tmp_path):
    d = str(tmp_path / "ck")
    with pytest.raises(ckpt.CheckpointMissing):
        ckpt.restore(d, 3, {"x": jnp.ones(2)})
    # absence is a subtype of CheckpointError, so one except clause works
    assert issubclass(ckpt.CheckpointMissing, ckpt.CheckpointError)


def test_checkpoint_restore_rejects_shape_mismatch(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"x": jnp.ones((4, 2))})
    with pytest.raises(ckpt.CheckpointError, match="shape"):
        ckpt.restore(d, 1, {"x": jnp.ones((4, 3))})
    with pytest.raises(ckpt.CheckpointError, match="leaves"):
        ckpt.restore(d, 1, {"x": jnp.ones((4, 2)), "y": jnp.ones(1)})


def test_checkpoint_restore_rejects_corruption(tmp_path):
    d = str(tmp_path / "ck")
    path = ckpt.save(d, 1, {"x": jnp.ones(3)})
    os.remove(os.path.join(path, "shard_h000.npz"))
    with pytest.raises(ckpt.CheckpointError, match="shard"):
        ckpt.restore(d, 1, {"x": jnp.ones(3)})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(ckpt.CheckpointError, match="JSON"):
        ckpt.load_manifest(d, 1)


def test_checkpoint_meta_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    meta = {"family": "d15", "p": 8, "coo_digest": "abc123"}
    ckpt.save(d, 2, {"x": jnp.ones(2)}, meta=meta)
    assert ckpt.load_manifest(d, 2)["meta"] == meta


def test_exact_resume_reproduces_run(tmp_path):
    """Train 10 steps; vs train 5, checkpoint, restore, train 5 more."""
    cfg, params, state, pipe = small_setup(seed=2)
    tcfg = TrainConfig(seq_len=64, global_batch=4, lr=1e-3, steps=20)
    step, _, _ = ts.make_train_step(cfg, PCFG, tcfg, mesh=None)
    fn = jax.jit(step)

    pA, sA = params, state
    for i in range(10):
        b = jax.tree.map(jnp.asarray, pipe.batch(i))
        pA, sA, _ = fn(pA, sA, b)

    pB, sB = params, state
    for i in range(5):
        b = jax.tree.map(jnp.asarray, pipe.batch(i))
        pB, sB, _ = fn(pB, sB, b)
    d = str(tmp_path / "ck")
    ckpt.save(d, 5, {"params": pB, "opt": sB})
    tree = ckpt.restore(d, 5, {"params": pB, "opt": sB})
    pB, sB = tree["params"], tree["opt"]
    for i in range(5, 10):
        b = jax.tree.map(jnp.asarray, pipe.batch(i))
        pB, sB, _ = fn(pB, sB, b)

    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_int8_compression_error_feedback_converges():
    """Quantize-with-feedback: accumulated updates track the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((1024,)) * 1e-3, jnp.float32)
    err = None
    acc = np.zeros(1024)
    for _ in range(50):
        q, scale, meta = compression.quantize_int8(
            g_true + (0 if err is None else err))
        deq = compression.dequantize_int8(q, scale, meta)
        err = (g_true + (0 if err is None else err)) - deq
        acc += np.asarray(deq)
    np.testing.assert_allclose(acc, 50 * np.asarray(g_true),
                               rtol=0.02, atol=2e-4)


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(1)
    for shape in ((17,), (64, 33), (3, 5, 7)):
        g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        q, s, meta = compression.quantize_int8(g)
        deq = compression.dequantize_int8(q, s, meta)
        assert deq.shape == g.shape
        err = np.abs(np.asarray(deq - g))
        assert err.max() <= float(np.abs(np.asarray(g)).max()) / 127 + 1e-6


def test_straggler_monitor_flags():
    from repro.distributed.elastic import StepMonitor
    flagged = []
    mon = StepMonitor(straggler_factor=3.0,
                      on_straggler=lambda s, t, m: flagged.append(s))
    for i in range(10):
        mon.observe(i, 1.0)
    assert not flagged
    assert mon.observe(10, 10.0)
    assert flagged == [10]


def test_resilient_step_retries():
    from repro.distributed.elastic import run_step_resilient
    from repro.distributed.faults import TransientFault
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientFault("preempted")
        return x + 1

    out = run_step_resilient(flaky, None, lambda: (41,), 41, max_retries=5)
    assert out == 42 and calls["n"] == 3


def test_resilient_step_does_not_retry_caller_bugs():
    from repro.distributed.elastic import run_step_resilient
    calls = {"n": 0}

    def buggy(x):
        calls["n"] += 1
        raise TypeError("caller bug, not a device failure")

    with pytest.raises(TypeError):
        run_step_resilient(buggy, None, lambda: (41,), 41, max_retries=5)
    assert calls["n"] == 1


def test_synthetic_data_deterministic_and_sharded():
    pipe = data.SyntheticLM(1000, 32, 8, seed=5)
    b1 = pipe.batch(3)
    b2 = pipe.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    lo = pipe.batch(3, lo=2, hi=5)
    np.testing.assert_array_equal(lo["tokens"], b1["tokens"][2:5])
    assert (pipe.batch(4)["tokens"] != b1["tokens"]).any()


def test_bf16_error_feedback_beats_raw_casting():
    """The ErrorFeedback accumulator (the compress="bf16" training-side
    state) keeps the accumulated lossy-step error far below raw
    repeated bf16 casting."""
    g = jnp.asarray(np.linspace(1e-3, 1.0, 1000), jnp.float32)
    ef = compression.ErrorFeedback()
    acc_fb = np.zeros(1000)
    acc_raw = np.zeros(1000)
    for _ in range(50):
        acc_fb += np.asarray(ef(g), np.float64)
        acc_raw += np.asarray(
            compression.from_bf16(compression.to_bf16(g)), np.float64)
    exact = 50 * np.asarray(g, np.float64)
    err_fb = np.abs(acc_fb - exact).max()
    err_raw = np.abs(acc_raw - exact).max()
    assert err_fb < 0.1 * err_raw, (err_fb, err_raw)


def test_bf16_roundtrip_halves_and_restores_dtype():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(64),
                    jnp.float32)
    w = compression.to_bf16(x)
    assert w.dtype == jnp.bfloat16
    back = compression.from_bf16(w)
    assert back.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               rtol=8e-3, atol=8e-3)
