"""Fallback property-test sampler used when ``hypothesis`` is unavailable.

Provides just enough of the ``hypothesis`` surface for our test suite —
``given``, ``settings`` and the ``strategies`` used in it — backed by a
deterministic numpy sampler.  Each ``@given`` test runs ``max_examples``
times (default 12) over seeded draws, so the property tests still exercise
many random cases without the optional dependency installed.

Usage (in test modules)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _propcheck import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

_DEFAULT_EXAMPLES = 12


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:  # mirrors `hypothesis.strategies` as a namespace
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[rng.integers(len(options))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))


st = strategies


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    """Decorator recording the example budget on the test function."""
    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    """Run the test over deterministic seeded draws of the strategies."""
    def deco(fn):
        # settings() may be applied above or below @given
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_propcheck_max_examples",
                        getattr(wrapper, "_propcheck_max_examples",
                                _DEFAULT_EXAMPLES))
            # stable across processes (hash() is PYTHONHASHSEED-randomized)
            base = zlib.crc32(fn.__qualname__.encode()) % (2 ** 31)
            for ex in range(n):
                rng = np.random.default_rng(base + ex)
                drawn = {name: s.draw(rng) for name, s in strats.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on example {ex}: {drawn}"
                    ) from e
        # hide the strategy parameters from pytest's fixture resolution
        wrapper.__signature__ = inspect.Signature()
        del wrapper.__wrapped__
        return wrapper
    return deco


def _signature_check():  # pragma: no cover - sanity helper
    return inspect.signature(given)
