"""Docs integrity: link checker is sound and the repo's docs are clean.

The CI docs job runs scripts/check_docs.py standalone; these tests keep
the same guarantees in the fast tier so a dead link fails locally too.
"""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "scripts"))

import check_docs  # noqa: E402


def test_repo_docs_have_no_dead_links():
    errors, checked, nfiles = check_docs.check(ROOT)
    assert not errors, errors
    assert nfiles >= 5          # 3 guides + README + DESIGN
    assert checked > 10


def test_checker_flags_dead_file_and_anchor(tmp_path):
    d = tmp_path / "docs"
    d.mkdir()
    (d / "algorithms.md").write_text("# Real heading\n")
    (d / "choosing.md").write_text(
        "[a](missing.md)\n[b](algorithms.md#nope)\n"
        "[ok](algorithms.md#real-heading)\n")
    errors, checked, _ = check_docs.check(tmp_path)
    assert checked == 3
    assert len(errors) == 2
    assert any("missing.md" in e for e in errors)
    assert any("#nope" in e for e in errors)


def test_checker_ignores_code_fences_and_http(tmp_path):
    d = tmp_path / "docs"
    d.mkdir()
    (d / "g.md").write_text(
        "[web](https://example.com)\n```python\n# [x](dead.md)\n```\n")
    errors, checked, _ = check_docs.check(tmp_path)
    assert not errors
    assert checked == 1         # the fenced link is not a link


def test_readme_quickstart_blocks_are_selfcontained():
    """Every ```python block in README must exec in one shared namespace
    (the CI docs job runs them; this asserts they at least compile and
    reference only names defined by earlier blocks or imports)."""
    import re
    text = (ROOT / "README.md").read_text()
    blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    assert len(blocks) >= 3
    for b in blocks:
        compile(b, "README.md", "exec")   # syntax-valid


def test_docs_ci_job_exists():
    ci = (ROOT / ".github" / "workflows" / "ci.yml").read_text()
    assert "check_docs.py" in ci
    assert "README quickstart" in ci


def test_check_docs_cli():
    proc = subprocess.run([sys.executable,
                           str(ROOT / "scripts" / "check_docs.py"),
                           str(ROOT)], capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 dead" in proc.stdout
