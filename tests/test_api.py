"""Unified distributed-algorithm API: registry, dispatch, parity (1 device).

The multi-device versions of these checks live in
tests/dist_scripts/check_api.py / check_apps_dist.py (slow tier); here
every registered algorithm degenerates onto a single-device grid, which
exercises the full plan/execute/assemble path and the dispatch logic
cheaply on every PR.
"""
import inspect

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import api, costmodel, d15, d25, s15, s25, sparse
from repro.kernels import ops, ref


def _problem_data(m=64, n=64, r=8, k=4, seed=0):
    rows, cols, vals, X, Y = sparse.random_problem(m, n, r, k, seed=seed)
    Sd = np.zeros((m, n), np.float32)
    Sd[rows, cols] = vals
    return rows, cols, vals, X, Y, Sd


def _dev1():
    # other fast-tier modules (dryrun) force a huge host device count at
    # import time; the single-device degenerate grids are pinned here
    return jax.devices()[:1]


def _make(rows, cols, vals, shape, r, **kw):
    return api.make_problem(rows, cols, vals, shape, r, devices=_dev1(),
                            **kw)


# the full registry-declared (family, elision) grid: parametrizing the
# parity tests over it makes a registry entry that claims an elision it
# cannot execute fail fast, cell by cell
ELISION_CELLS = sorted((name, el) for name in costmodel.FAMILIES
                       for el in api.ALGORITHMS[name].elisions)


def test_registry_has_all_four_families():
    assert set(api.ALGORITHMS) == set(costmodel.FAMILIES)
    for name, alg in api.ALGORITHMS.items():
        assert alg.name == name
        assert alg.elisions, name


def test_registry_matrix_full_rank():
    """Every family exposes reuse; every family but s25 exposes fused
    (s25's fused cell is structurally impossible — docs/algorithms.md);
    every declared cell has a Table-III cost row and auto candidates are
    declared cells."""
    cells = set(costmodel.FAMILY_ELISION.values())
    for name, alg in api.ALGORITHMS.items():
        assert "none" in alg.elisions and "reuse" in alg.elisions, name
        assert ("fused" in alg.elisions) == (name != "s25"), name
        for el in alg.elisions:
            assert (name, el) in cells, (name, el)
        assert set(alg.auto_elisions) <= set(alg.elisions), name


def test_uniform_auto_elision_default():
    """Satellite: every family fusedmm entrypoint defaults to "auto"."""
    for fn in (d15.fusedmm_d15, s15.fusedmm_s15, d25.fusedmm_d25,
               s25.fusedmm_s25):
        sig = inspect.signature(fn)
        assert sig.parameters["elision"].default == "auto", fn


def test_choose_algorithm_regime_rule():
    """Low phi -> sparse families; high phi -> dense families (Fig. 6)."""
    kw = dict(m=1 << 16, n=1 << 16, r=128, p=64)
    lo = costmodel.choose_algorithm(nnz=int(0.02 * kw["n"] * kw["r"]), **kw)
    hi = costmodel.choose_algorithm(nnz=int(4.0 * kw["n"] * kw["r"]), **kw)
    assert lo.family.startswith("s")
    assert hi.family.startswith("d")


def test_choose_algorithm_respects_feasibility():
    # r=2 rules out s15 (needs r % p == 0) and s25/d25 at 4 procs
    ch = costmodel.choose_algorithm(m=64, n=64, nnz=256, r=2, p=4)
    assert ch.family == "d15"
    with pytest.raises(ValueError):
        costmodel.choose_algorithm(m=63, n=63, nnz=64, r=2, p=4)
    # pinned c filters candidates
    ch = costmodel.choose_algorithm(m=64, n=64, nnz=256, r=8, p=4, c=4)
    assert ch.c == 4


def test_family_feasible():
    assert costmodel.family_feasible("d15", m=64, n=64, r=2, p=8, c=2)
    assert not costmodel.family_feasible("s15", m=64, n=64, r=2, p=8, c=2)
    assert costmodel.family_feasible("d25", m=64, n=64, r=4, p=8, c=2)
    assert not costmodel.family_feasible("d25", m=64, n=64, r=4, p=8, c=4)


@pytest.mark.parametrize("name", sorted(costmodel.FAMILIES))
def test_api_parity_vs_ref(name):
    """Same problem through every registered algorithm == kernels/ref."""
    rows, cols, vals, X, Y, Sd = _problem_data()
    prob = _make(rows, cols, vals, Sd.shape, X.shape[1],
                 algorithm=name)
    wantR = np.asarray(ref.sddmm_dense(jnp.asarray(X), jnp.asarray(Y),
                                       jnp.asarray(Sd)))
    np.testing.assert_allclose(prob.sddmm(X, Y).to_dense(), wantR,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(prob.spmm(Y),
                               np.asarray(ref.spmm_dense(Sd, Y)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("comm", ("dense", "sparse"))
@pytest.mark.parametrize("name,el", ELISION_CELLS)
def test_fusedmm_parity_per_cell(name, el, comm):
    """Every registry-declared (family, elision) cell executes and
    matches the dense oracle — a declared-but-unimplemented cell fails
    exactly here.  Parametrized over the wire format: comm="sparse"
    plans and runs the support-pruned program through the same cells
    (degenerate single-device channels here; the multi-device pruning is
    tests/dist_scripts/check_comm_sparse.py)."""
    rows, cols, vals, X, Y, Sd = _problem_data()
    prob = _make(rows, cols, vals, Sd.shape, X.shape[1], algorithm=name,
                 comm=comm)
    wantR = np.asarray(ref.sddmm_dense(jnp.asarray(X), jnp.asarray(Y),
                                       jnp.asarray(Sd)))
    want_out, _ = ref.fusedmm_dense(X, Y, Sd)
    out, R = prob.fusedmm(X, Y, elision=el)
    np.testing.assert_allclose(out, want_out, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(R.to_dense(), wantR, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name,el", ELISION_CELLS)
def test_comm_sparse_bitwise_vs_dense(name, el):
    """comm="sparse" is bitwise-identical to comm="dense" at every
    registry cell (the executors prune only input-operand movements;
    every accumulation keeps its order)."""
    rows, cols, vals, X, Y, Sd = _problem_data(seed=4)
    pd = _make(rows, cols, vals, Sd.shape, 8, algorithm=name)
    ps = _make(rows, cols, vals, Sd.shape, 8, algorithm=name,
               comm="sparse")
    od, Rd = pd.fusedmm(X, Y, elision=el)
    os_, Rs = ps.fusedmm(X, Y, elision=el)
    np.testing.assert_array_equal(od, os_)
    np.testing.assert_array_equal(Rd.values(), Rs.values())
    np.testing.assert_array_equal(pd.spmm_t(X), ps.spmm_t(X))


def test_comm_mode_plumbing():
    """comm/compress validate, resolve from "auto" via the support
    densities, survive the meta_dict round-trip, and key the Session."""
    rows, cols, vals, X, Y, _ = _problem_data(seed=12)
    with pytest.raises(ValueError, match="comm"):
        _make(rows, cols, vals, (64, 64), 8, comm="nope")
    with pytest.raises(ValueError, match="compress"):
        _make(rows, cols, vals, (64, 64), 8, compress="fp4")
    auto = _make(rows, cols, vals, (64, 64), 8, comm="auto")
    assert auto.comm == costmodel.choose_comm(rows, cols, 64, 64)
    prob = _make(rows, cols, vals, (64, 64), 8, algorithm="d15",
                 comm="sparse", compress="bf16")
    meta = prob.meta_dict()
    assert meta["comm"] == "sparse" and meta["compress"] == "bf16"
    back = api.problem_from_meta(meta, rows, cols, vals,
                                 devices=_dev1())
    assert back.comm == "sparse" and back.compress == "bf16"
    # derived problems inherit the wire format
    assert prob.transposed().comm == "sparse"
    assert prob.with_values(vals * 2).comm == "sparse"
    # sessions key on comm: same operand under each mode -> two entries
    dense = _make(rows, cols, vals, (64, 64), 8, algorithm="d15")
    sess = api.Session()
    sess.replicate(dense, X, "x")
    sess.replicate(prob, X, "x")
    assert sess.stats() == dict(hits=0, misses=2, entries=2, capacity=16)
    sess.replicate(prob, X, "x")
    assert sess.stats()["hits"] == 1


def test_undeclared_elision_rejected():
    rows, cols, vals, X, Y, _ = _problem_data()
    prob = _make(rows, cols, vals, (64, 64), 8, algorithm="s25")
    with pytest.raises(ValueError, match="supports"):
        prob.fusedmm(X, Y, elision="fused")


@pytest.mark.parametrize("name,el", ELISION_CELLS)
def test_session_caching_bitwise(name, el):
    """Cached replication == uncached, bit for bit, at every cell."""
    rows, cols, vals, X, Y, _ = _problem_data(seed=2)
    prob = _make(rows, cols, vals, (64, 64), 8, algorithm=name)
    sess = api.Session()
    base, _ = prob.fusedmm(X, Y, elision=el)
    one, _ = prob.fusedmm(X, Y, elision=el, session=sess)
    two, _ = prob.fusedmm(X, Y, elision=el, session=sess)
    np.testing.assert_array_equal(base, one)
    np.testing.assert_array_equal(base, two)


def test_sparse_result_values_without_dense():
    """values()/to_coo assemble O(nnz) and match the dense view."""
    rows, cols, vals, X, Y, Sd = _problem_data(seed=5)
    wantR = Sd * (X @ Y.T)
    for name in sorted(costmodel.FAMILIES):
        prob = _make(rows, cols, vals, (64, 64), 8, algorithm=name)
        res = prob.sddmm(X, Y)
        np.testing.assert_allclose(res.values(), wantR[rows, cols],
                                   rtol=2e-4, atol=2e-4, err_msg=name)
        r, c, v = res.to_coo()
        back = np.zeros((64, 64), np.float32)
        np.add.at(back, (r, c), v)
        np.testing.assert_allclose(back, wantR, rtol=2e-4, atol=2e-4,
                                   err_msg=name)


def test_session_lru_bound():
    """The cache evicts cold iterates; the hot operand stays correct."""
    rows, cols, vals, X, Y, _ = _problem_data(seed=6)
    prob = _make(rows, cols, vals, (64, 64), 8, algorithm="s15")
    base, _ = prob.fusedmm(X, Y, elision="reuse")
    sess = api.Session(max_entries=3)
    rng = np.random.default_rng(9)
    for _ in range(8):
        it = rng.standard_normal((64, 8)).astype(np.float32)
        prob.fusedmm(it, Y, elision="reuse", session=sess)
    assert len(sess) <= 3
    out, _ = prob.fusedmm(X, Y, elision="reuse", session=sess)
    np.testing.assert_array_equal(base, out)


def test_session_aware_elision_ranking():
    """With a Session the steady-state (cached) word counts rank the
    cells; on the degenerate single-device grid (c=1, no replication to
    cache) "fused" wins everywhere it exists — fewest shift words."""
    rows, cols, vals, _, _, _ = _problem_data()
    for name in ("d15", "s15", "d25"):
        prob = _make(rows, cols, vals, (64, 64), 8, algorithm=name)
        assert prob.resolve_elision("auto") == "fused", name
        assert prob.resolve_elision("auto", api.Session()) == "fused", name
    s25p = _make(rows, cols, vals, (64, 64), 8, algorithm="s25")
    assert s25p.resolve_elision("auto") == "reuse"
    assert s25p.resolve_elision("auto", api.Session()) == "reuse"


@pytest.mark.parametrize("name", sorted(costmodel.FAMILIES))
def test_spmm_t_parity_and_vals_injection(name):
    """spmm_t == S^T @ A on every family, with cotangent-style value
    injection and a Session-replayed (bitwise-identical) path."""
    rows, cols, vals, X, Y, Sd = _problem_data(seed=7)
    prob = _make(rows, cols, vals, Sd.shape, 8, algorithm=name)
    g = np.random.default_rng(11).standard_normal((64, 8)).astype(
        np.float32)
    np.testing.assert_allclose(prob.spmm_t(g), Sd.T @ g, rtol=2e-4,
                               atol=2e-4)
    v2 = (np.arange(len(vals)) * 0.01).astype(np.float32)
    S2 = np.zeros(Sd.shape, np.float32)
    S2[rows, cols] = v2
    base = prob.spmm_t(g, vals=v2)
    np.testing.assert_allclose(base, S2.T @ g, rtol=2e-4, atol=2e-4)
    sess = api.Session()
    np.testing.assert_array_equal(base, prob.spmm_t(g, vals=v2,
                                                    session=sess))
    np.testing.assert_array_equal(base, prob.spmm_t(g, vals=v2,
                                                    session=sess))


@pytest.mark.parametrize("name", sorted(costmodel.FAMILIES))
def test_injected_values_bitwise_vs_repack(name):
    """spmm with ``vals=`` injects values into the cached structure pack
    and must be BITWISE identical to a full re-pack via with_values —
    the backward pass's hot path rides on this."""
    rows, cols, vals, X, Y, Sd = _problem_data(seed=9)
    prob = _make(rows, cols, vals, Sd.shape, 8, algorithm=name)
    v2 = np.random.default_rng(13).standard_normal(len(vals)).astype(
        np.float32)
    want = prob.with_values(v2).spmm(Y)
    got = prob.spmm(Y, vals=v2)
    np.testing.assert_array_equal(want, got)
    # structure planned once: injection must not add plan cache entries
    n_plans = len(prob._plans)
    prob.spmm(Y, vals=v2 * 2.0)
    assert len(prob._plans) == n_plans
    # transposed() is cached, and round-trips to the original
    assert prob.transposed() is prob.transposed()
    assert prob.transposed().transposed() is prob


@pytest.mark.parametrize("name", sorted(costmodel.FAMILIES))
def test_sddmm_spmm_session_bitwise(name):
    """The session paths of the single-kernel entrypoints are
    bitwise-identical to the plain paths."""
    rows, cols, vals, X, Y, Sd = _problem_data(seed=10)
    prob = _make(rows, cols, vals, Sd.shape, 8, algorithm=name)
    sess = api.Session()
    base = prob.sddmm(X, Y).values()
    np.testing.assert_array_equal(base,
                                  prob.sddmm(X, Y, session=sess).values())
    np.testing.assert_array_equal(base,
                                  prob.sddmm(X, Y, session=sess).values())
    base_s = prob.spmm(Y)
    np.testing.assert_array_equal(base_s, prob.spmm(Y, session=sess))


def test_session_content_keyed_replay():
    """The Session hits on CONTENT, not identity: a copy of a cached
    operand (what the backward pass hands the executors after a jax
    round-trip) replays the replication instead of re-gathering."""
    rows, cols, vals, X, Y, _ = _problem_data(seed=8)
    prob = _make(rows, cols, vals, (64, 64), 8, algorithm="d15")
    sess = api.Session()
    prob.fusedmm(X, Y, elision="reuse", session=sess)
    misses = sess.misses
    out2, _ = prob.fusedmm(X.copy(), Y.copy(), elision="reuse",
                           session=sess)
    assert sess.misses == misses and sess.hits >= 1
    base, _ = prob.fusedmm(X, Y, elision="reuse")
    np.testing.assert_array_equal(base, out2)
    # mutation changes the content digest -> transparent re-replication
    Ymut = Y.copy()
    prob.fusedmm(X, Ymut, elision="reuse", session=sess)
    Ymut *= 0.5
    out_mut, _ = prob.fusedmm(X, Ymut, elision="reuse", session=sess)
    want, _ = prob.fusedmm(X, Ymut, elision="reuse")
    np.testing.assert_array_equal(want, out_mut)


def test_with_values_and_transposed():
    rows, cols, vals, X, Y, Sd = _problem_data()
    prob = _make(rows, cols, vals, (64, 64), 8, algorithm="d15")
    ones = prob.with_values(np.ones_like(vals))
    want = (Sd != 0).astype(np.float32) @ Y
    np.testing.assert_allclose(ones.spmm(Y), want, rtol=2e-4, atol=2e-4)
    probT = prob.transposed()
    np.testing.assert_allclose(probT.spmm(X), Sd.T @ X, rtol=2e-4,
                               atol=2e-4)


def test_with_r_validates_divisibility():
    rows, cols, vals, _, _, Sd = _problem_data()
    prob = _make(rows, cols, vals, (64, 64), 8, algorithm="s15")
    assert prob.with_r(4).r == 4      # p=1: every width is feasible
    # the divisibility rule itself (multi-device grids are slow-tier)
    fake = type("G", (), {"p": 8, "G": 2, "c": 2})()
    assert api.ALGORITHMS["s15"].min_r_multiple(fake) == 8
    assert api.ALGORITHMS["d25"].min_r_multiple(fake) == 2
    assert api.ALGORITHMS["s25"].min_r_multiple(fake) == 4
    assert api.ALGORITHMS["d15"].min_r_multiple(fake) == 1


def test_ops_routing_when_mesh_active():
    """kernels/ops routes through the api while a problem is active."""
    rows, cols, vals, X, Y, Sd = _problem_data(seed=3)
    S = sparse.pack_row_tiled(rows, cols, vals, (64, 64), row_tile=32,
                              nz_block=32)
    prob = _make(rows, cols, vals, (64, 64), 8, algorithm="d15")
    Xj, Yj = jnp.asarray(X), jnp.asarray(Y)
    local_R = ops.sddmm(Xj, Yj, S)
    local_out = ops.spmm(S, Yj, m=64)
    local_f, local_fR = ops.fusedmm(Xj, Yj, S, m=64)
    with api.activate(prob, S):
        routed_R = ops.sddmm(Xj, Yj, S)
        routed_out = ops.spmm(S, Yj, m=64)
        routed_f, routed_fR = ops.fusedmm(Xj, Yj, S, m=64)
        # a different pack falls through to the local kernels
        other = sparse.pack_row_tiled(rows, cols, vals, (64, 64),
                                      row_tile=32, nz_block=32)
        ops.spmm(other, Yj, m=64)
        # an explicit backend request always wins over routing
        ref_out = ops.spmm(S, Yj, m=64, backend="ref")
        np.testing.assert_allclose(np.asarray(ref_out), Sd @ Y,
                                   rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(routed_R.to_dense()),
                               np.asarray(local_R.to_dense()),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(routed_out),
                               np.asarray(local_out), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(routed_f),
                               np.asarray(local_f), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(routed_fR.to_dense()),
                               np.asarray(local_fR.to_dense()),
                               rtol=2e-3, atol=2e-3)
    assert ops._DIST_ROUTER is None    # context restored


def test_distributed_als_single_device():
    from repro.apps import als
    _, _, hist = als.run_als_distributed(m=128, n=128, nnz_per_row=6,
                                         r=16, rounds=2, cg_iters=8,
                                         devices=_dev1(), verbose=False)
    assert hist[-1] < 0.3 * hist[0], hist


def test_distributed_gat_matches_local():
    from repro.apps import gat
    n, d, seed = 96, 16, 3
    S = gat.make_graph(n, 4, seed=seed, row_tile=32, nz_block=32)
    gp = gat.make_dist_graph(n, 4, d, seed=seed, devices=_dev1())
    rng = np.random.default_rng(seed)
    H = rng.standard_normal((n, d)).astype(np.float32)
    p = gat.init_gat_layer(jax.random.PRNGKey(0), d, d)
    want = np.asarray(gat.gat_layer(S, jnp.asarray(H), p))
    got = np.asarray(gat.gat_layer_distributed(gp, H, p))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
