"""Static-analysis subsystem tests (docs/static_analysis.md).

Fast tier: one synthetic violating snippet per rule R1-R5, allowlist
mechanics, report JSON round-trip, the sequence matcher and the SPMD
rendezvous simulator on hand-built programs (including deliberately
corrupted schedules), and the real tree linting clean.  The HLO-backed
conformance sweep needs the 8-device mesh and lives in
tests/dist_scripts/check_analysis.py.
"""
import json
import textwrap

import pytest

from repro.analysis import findings as F
from repro.analysis import lint
from repro.analysis.conformance import (ExpectedEvent, match_sequence,
                                        rank_programs, simulate_rendezvous)
from repro.analysis.rules import all_rules
from repro.analysis.rules.r5_registry_cells import check_registry
from repro.roofline.hlo_parse import OrderedCollective


def _lint_snippet(path, code):
    return lint.lint_file(path, textwrap.dedent(code))


# ---------------------------------------------------------------------------
# R1 - layering
# ---------------------------------------------------------------------------

def test_r1_flags_eager_upper_layer_import():
    found = _lint_snippet("repro/core/fake.py", """
        import numpy as np
        from repro.obs import tracer
    """)
    assert [f.rule for f in found] == ["R1"]
    assert found[0].line == 3 and "repro.obs" in found[0].message


def test_r1_allows_lazy_import_and_upper_layers():
    assert not _lint_snippet("repro/core/fake.py", """
        def f():
            from repro.obs import tracer
            return tracer.active()
    """)
    # the rule only binds the foundation layer
    assert not _lint_snippet("repro/training/fake.py", """
        from repro.serving import decode
    """)


def test_r1_flags_class_body_and_conditional_imports():
    found = _lint_snippet("repro/kernels/fake.py", """
        try:
            import repro.training.loop
        except ImportError:
            pass
    """)
    assert [f.rule for f in found] == ["R1"]


# ---------------------------------------------------------------------------
# R2 - round-boundary guard + tracer
# ---------------------------------------------------------------------------

_R2_BAD = """
    class DistProblem:
        def sddmm(self, X, Y):
            return self._run(X, Y)
"""

_R2_GOOD = """
    class DistProblem:
        def sddmm(self, X, Y):
            faults.guard("sddmm", self)
            tr = _tracer_active()
            return self._run(X, Y)
"""


def test_r2_flags_unguarded_executor_round():
    found = _lint_snippet("repro/core/fake.py", _R2_BAD)
    assert {f.rule for f in found} == {"R2"}
    assert len(found) == 2          # missing guard AND missing tracer
    assert all(f.symbol == "DistProblem.sddmm" for f in found)


def test_r2_accepts_guarded_round_and_other_classes():
    assert not _lint_snippet("repro/core/fake.py", _R2_GOOD)
    assert not _lint_snippet("repro/core/fake.py", """
        class Other:
            def sddmm(self):
                pass
    """)


# ---------------------------------------------------------------------------
# R3 - dense materialization
# ---------------------------------------------------------------------------

def test_r3_flags_problem_shape_zeros_and_todense():
    found = _lint_snippet("repro/kernels/fake.py", """
        def f(prob, S):
            out = np.zeros((prob.m, prob.n))
            return out + S.todense()
    """)
    assert [f.rule for f in found] == ["R3", "R3"]


def test_r3_ignores_sharded_shapes_and_cold_paths():
    assert not _lint_snippet("repro/core/fake.py", """
        def f(prob):
            return np.zeros((prob.m, prob.r))
    """)
    # (n, m) transposed materialization is still the full dense shape
    assert _lint_snippet("repro/core/fake.py", """
        def f(m, n):
            return jnp.ones((n, m))
    """)
    # outside the hot dirs the rule does not apply
    assert not _lint_snippet("repro/obs/fake.py", """
        def f(m, n):
            return np.zeros((m, n))
    """)


# ---------------------------------------------------------------------------
# R4 - pure_callback captures
# ---------------------------------------------------------------------------

def test_r4_flags_mutable_module_capture():
    found = _lint_snippet("repro/core/fake.py", """
        _cache = {}

        def f(x):
            def host(v):
                return _cache[int(v)]
            return jax.pure_callback(host, x.shape, x)
    """)
    assert [f.rule for f in found] == ["R4"]
    assert "_cache" in found[0].message


def test_r4_accepts_local_closures_and_constants():
    assert not _lint_snippet("repro/core/fake.py", """
        SCALE = 2.0

        def f(prob, x):
            def host(v):
                return prob.lookup(v) * SCALE
            return jax.pure_callback(host, x.shape, x)
    """)


def test_r4_flags_global_rebound_name_via_wrapper():
    found = _lint_snippet("repro/core/fake.py", """
        _ROUTER = None

        def activate(r):
            global _ROUTER
            _ROUTER = r

        def f(x):
            return _callback(lambda v: _ROUTER(v), x.shape, x)
    """)
    assert [f.rule for f in found] == ["R4"]


# ---------------------------------------------------------------------------
# R5 - registry cells (fake registries; the live one must be clean)
# ---------------------------------------------------------------------------

class _FakeSched:
    @staticmethod
    def schedule_events(grid, op, elision="none"):
        return [("phase", 0), ("shift", 0)]

    @staticmethod
    def schedule_words(grid, plan, op, elision="none",
                       pre_gathered=False):
        return []


class _FakeAlg:
    def __init__(self, sched):
        self._sched_mod = sched
        self.elisions = ("none",)


def test_r5_clean_on_complete_fake_registry():
    assert not check_registry({"fake": _FakeAlg(_FakeSched)})


def test_r5_flags_missing_words_and_raising_events():
    class NoWords:
        schedule_events = _FakeSched.schedule_events

    found = check_registry({"fake": _FakeAlg(NoWords)})
    assert any("schedule_words" in f.message for f in found)

    class Raises:
        @staticmethod
        def schedule_events(grid, op, elision="none"):
            raise ValueError("boom")
        schedule_words = _FakeSched.schedule_words

    found = check_registry({"fake": _FakeAlg(Raises)})
    assert any("raised" in f.message for f in found)
    assert any("fake.sddmm" in f.symbol for f in found)


def test_r5_live_registry_is_clean():
    assert check_registry() == []


# ---------------------------------------------------------------------------
# Allowlists
# ---------------------------------------------------------------------------

def test_allowlist_marks_but_keeps_findings():
    entries = F.parse_allowlist("""
        # comment
        repro/core/*.py::to_dense -- debug-only view
    """)
    hit = F.Finding("R3", "repro/core/api.py", 10, "msg",
                    symbol="SparseResult.to_dense")
    miss = F.Finding("R3", "repro/core/api.py", 20, "msg",
                     symbol="hot_path")
    out = F.apply_allowlist([hit, miss], entries)
    assert out[0].allowlisted and out[0].note == "debug-only view"
    assert not out[1].allowlisted
    assert F.violations(out) == [miss]


def test_every_rule_has_an_allowlist_file():
    for rule in all_rules().values():
        rule.allowlist()        # must parse without error (may be empty)


# ---------------------------------------------------------------------------
# The real tree lints clean (R5 included - imports the registry)
# ---------------------------------------------------------------------------

def test_repo_lints_clean_with_documented_allowlists():
    findings, scanned = lint.run_lint()
    assert scanned > 30
    bad = F.violations(findings)
    assert not bad, "\n".join(f.render() for f in bad)
    # the known debug-only densification is documented, not deleted
    assert any(f.allowlisted and f.rule == "R3"
               and "to_dense" in f.symbol for f in findings)


def test_cli_exits_nonzero_on_violating_tree(tmp_path):
    pkg = tmp_path / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "bad.py").write_text(
        "from repro.obs import tracer\n")
    from repro.analysis.__main__ import main
    assert main(["lint", "--root", str(tmp_path)]) == 1
    assert main(["lint"]) == 0          # the real tree is clean


# ---------------------------------------------------------------------------
# Report round-trip
# ---------------------------------------------------------------------------

def test_report_json_round_trip(tmp_path):
    findings, scanned = lint.run_lint(with_registry=False)
    report = {"schema": 1, "lint": F.lint_report(findings, scanned)}
    path = str(tmp_path / "ANALYSIS_report.json")
    F.write_report(report, path)
    loaded = F.load_report(path)
    assert loaded == json.loads(json.dumps(report))
    back = F.findings_from_report(loaded)
    assert [f.to_dict() for f in back] == [f.to_dict() for f in findings]


# ---------------------------------------------------------------------------
# Sequence matcher (pure - no lowering)
# ---------------------------------------------------------------------------

def _instr(kind, words, *, groups=None, pairs=None, ch=0):
    return OrderedCollective(
        kind=kind, name=f"{kind}.{ch}", channel_id=ch,
        operand_bytes=(words * 4 if kind != "all-gather" else 0),
        output_bytes=(words * 4 if kind == "all-gather" else 0),
        replica_groups=groups, source_target_pairs=pairs)


GROUPS8 = ((0, 1), (2, 3), (4, 5), (6, 7))
RING8 = tuple((i, (i + 2) % 8) for i in range(8))


def _schedule():
    return [ExpectedEvent("gather", 0, "all-gather", 64.0),
            ExpectedEvent("shift", 0, "collective-permute", 32.0),
            ExpectedEvent("shift", 1, "collective-permute", 32.0),
            ExpectedEvent("reduce", 1, "reduce-scatter", 64.0)]


def _matching_instrs():
    return [_instr("all-gather", 64, groups=GROUPS8, ch=1),
            _instr("collective-permute", 32, pairs=RING8, ch=2),
            _instr("collective-permute", 32, pairs=RING8, ch=3),
            _instr("reduce-scatter", 64, groups=GROUPS8, ch=4)]


def test_match_sequence_accepts_conforming_hlo():
    assert match_sequence(_schedule(), _matching_instrs()) == []


def test_match_sequence_catches_corrupted_schedules():
    instrs = _matching_instrs()
    # dropped event: the schedule promises one less all-gather run
    assert match_sequence(_schedule()[1:], instrs)
    # kind corruption: reduce-scatter event claimed as all-gather
    bad = _schedule()
    bad[-1] = ExpectedEvent("reduce", 1, "all-gather", 64.0)
    assert match_sequence(bad, instrs)
    # word corruption inside a run
    bad = _schedule()
    bad[1] = ExpectedEvent("shift", 0, "collective-permute", 999.0)
    errors = match_sequence(bad, instrs)
    assert errors and "words" in errors[0]
    # out-of-order runs (reduce before the shifts)
    swapped = [s for s in _schedule()]
    swapped.insert(1, swapped.pop(-1))
    assert match_sequence(swapped, instrs)


def test_match_sequence_permits_permute_legalization_split():
    """One shift event may legalize to several collective-permutes
    (one per traveling array) - only totals and a lower bound bind."""
    sched = [ExpectedEvent("shift", 0, "collective-permute", 96.0)]
    instrs = [_instr("collective-permute", 32, pairs=RING8, ch=i)
              for i in (1, 2, 3)]
    assert match_sequence(sched, instrs) == []


# ---------------------------------------------------------------------------
# Rendezvous simulation
# ---------------------------------------------------------------------------

def test_rendezvous_drains_conforming_program():
    prog = rank_programs(_matching_instrs(), 8)
    assert sorted(prog) == list(range(8))
    sim = simulate_rendezvous(prog)
    assert sim["ok"] and not sim["stuck"]
    # 2 gather-likes x 4 groups each + 2 global permutes
    assert sim["fired"] == 2 * len(GROUPS8) + 2


def test_rendezvous_catches_corrupted_event_lists():
    # a rank that never posts its collective deadlocks the group
    prog = rank_programs(_matching_instrs(), 8)
    prog[3] = prog[3][1:]
    sim = simulate_rendezvous(prog)
    assert not sim["ok"] and 3 in sim["stuck"]

    # cross-rank reordering of two overlapping collectives deadlocks
    prog = rank_programs(_matching_instrs(), 8)
    prog[5][0], prog[5][1] = prog[5][1], prog[5][0]
    assert not simulate_rendezvous(prog)["ok"]

    # duplicated post leaves an undrained queue
    prog = rank_programs(_matching_instrs(), 8)
    prog[0].append(prog[0][-1])
    sim = simulate_rendezvous(prog)
    assert not sim["ok"] and 0 in sim["stuck"]


def test_rendezvous_tolerates_disjoint_group_order():
    """Groups that share no ranks may fire in either order - only
    overlapping reorderings are deadlocks."""
    a = (0, (0, 1))
    b = (1, (2, 3))
    prog = {0: [a], 1: [a], 2: [b], 3: [b]}
    assert simulate_rendezvous(prog)["ok"]


# ---------------------------------------------------------------------------
# Group soundness
# ---------------------------------------------------------------------------

def test_check_groups_rejects_partial_mesh_and_bad_permutation():
    from repro.analysis.conformance import check_groups
    ok = _matching_instrs()
    assert check_groups(ok, 8) == []
    # groups that do not cover the mesh
    bad = [_instr("all-gather", 64, groups=((0, 1), (2, 3)), ch=1)]
    assert any("full mesh" in e for e in check_groups(bad, 8))
    # overlapping groups
    bad = [_instr("all-gather", 64, groups=((0, 1), (1, 2, 3, 4, 5, 6, 7)),
                  ch=1)]
    assert any("overlap" in e or "unequal" in e
               for e in check_groups(bad, 8))
    # duplicated permute target
    bad = [_instr("collective-permute", 32,
                  pairs=((0, 2), (1, 2)), ch=1)]
    assert any("permutation" in e for e in check_groups(bad, 8))


# ---------------------------------------------------------------------------
# Ordered-collective HLO parsing
# ---------------------------------------------------------------------------

_HLO = """\
HloModule test

ENTRY %main (p0: f32[8,16]) -> f32[16,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %cp = f32[8,16]{1,0} collective-permute(%p0), channel_id=3, source_target_pairs={{0,2},{2,4},{4,6},{6,0}}
  %ag = f32[16,16]{1,0} all-gather(%cp), channel_id=1, replica_groups={{0,1},{2,3},{4,5},{6,7}}, dimensions={0}, use_global_device_ids=true
  ROOT %out = f32[16,16]{1,0} add(%ag, %ag)
}
"""


def test_ordered_collectives_sort_by_channel_and_parse_groups():
    from repro.roofline.hlo_parse import ordered_collectives
    ops = ordered_collectives(_HLO)
    assert [o.kind for o in ops] == ["all-gather", "collective-permute"]
    assert ops[0].channel_id == 1 and ops[1].channel_id == 3
    assert ops[0].replica_groups == ((0, 1), (2, 3), (4, 5), (6, 7))
    assert ops[1].source_target_pairs == ((0, 2), (2, 4), (4, 6), (6, 0))
    assert ops[0].wire_bytes == (16 * 16 - 8 * 16) * 4
    assert ops[1].wire_bytes == 8 * 16 * 4


def test_ordered_collectives_iota_group_form():
    from repro.roofline.hlo_parse import _parse_groups
    assert _parse_groups("replica_groups=[4,2]<=[8]") == (
        (0, 1), (2, 3), (4, 5), (6, 7))


# ---------------------------------------------------------------------------
# Deprecated serving.engine shim
# ---------------------------------------------------------------------------

def test_serving_engine_shim_warns_and_reexports():
    import importlib
    import sys
    sys.modules.pop("repro.serving.engine", None)
    with pytest.warns(DeprecationWarning, match="deprecated alias"):
        mod = importlib.import_module("repro.serving.engine")
    from repro.serving import decode
    assert mod.decode_step is decode.decode_step
    assert mod.greedy_generate is decode.greedy_generate
