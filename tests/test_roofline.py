"""Unit tests for the HLO parsing + roofline machinery."""
import numpy as np
import pytest

from repro.roofline import hlo_parse
from repro.roofline.analysis import Roofline, analyse_record


def test_shape_bytes():
    assert hlo_parse.shape_bytes("f32[4,8]{1,0}") == 128
    assert hlo_parse.shape_bytes("bf16[10]") == 20
    assert hlo_parse.shape_bytes("(f32[2,2], u32[4])") == 32
    assert hlo_parse.shape_bytes("pred[]") == 1
    assert hlo_parse.shape_bytes("token[]") == 0


HLO = """
HloModule test

%wloop_body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %gte = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %perm = f32[8,128]{1,0} collective-permute(%gte), source_target_pairs={{0,1},{1,0}}
  %d = f32[8,8]{1,0} dot(%perm, %perm), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  ROOT %t = (s32[], f32[8,128]) tuple(%gte, %perm)
}

%wloop_cond (p2: (s32[], f32[8,128])) -> pred[] {
  %p2 = (s32[], f32[8,128]) parameter(0)
  ROOT %lt = pred[] compare(%p2, %p2), direction=LT
}

ENTRY %main (x: f32[8,128]) -> f32[8,128] {
  %x = f32[8,128]{1,0} parameter(0)
  %ag = f32[16,128]{1,0} all-gather(%x), dimensions={0}
  %w = (s32[], f32[8,128]) while(%x), condition=%wloop_cond, body=%wloop_body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_totals_loop_aware():
    tot = hlo_parse.collective_totals(HLO)
    # permute: 8*128*4 bytes * 5 trips; all-gather wire: out - in = 4096
    assert tot["wire_bytes"] == 5 * 4096 + 4096
    assert tot["total_count" if "total_count" in tot else "count"] == 6


def test_program_totals_loop_aware_flops():
    tot = hlo_parse.program_totals(HLO)
    # dot: 2 * 8*8 * 128 flops * 5 trips
    assert tot["dot_flops"] == 5 * 2 * 8 * 8 * 128
    assert tot["bytes_touched"] > 0


def make_rec(flops=1e12, byts=1e10, wire=1e9, shape="train_4k",
             kind="train", multi_pod=False, n=1e9):
    return dict(arch="x", shape=shape, kind=kind, multi_pod=multi_pod,
                active_params=n,
                program={"dot_flops": flops, "bytes_touched": byts},
                cost={}, collectives={"total_wire_bytes": wire},
                memory={"temp_size_in_bytes": 0})


def test_roofline_terms_and_dominant():
    r = analyse_record(make_rec(flops=197e12, byts=819e9, wire=50e9))
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
    r2 = analyse_record(make_rec(wire=500e9))
    assert r2.dominant == "collective"
    r3 = analyse_record(make_rec(byts=900e10, wire=1))
    assert r3.dominant == "memory"


def test_roofline_fraction_bounded():
    # ideal == bound -> fraction near chips-normalized value
    rec = make_rec(flops=1e12, byts=1, wire=1, n=1e9)
    r = analyse_record(rec)
    assert 0 < r.roofline_fraction
    # MODEL_FLOPS = 6*N*D; per-chip ideal seconds
    ideal = r.model_flops / (256 * 197e12)
    assert r.roofline_fraction == pytest.approx(ideal / r.compute_s)


def test_useful_ratio():
    rec = make_rec(flops=1e12, n=1e9)
    r = analyse_record(rec)
    d = 256 * 4096
    assert r.useful_ratio == pytest.approx(6 * 1e9 * d / (1e12 * 256))


RS_HLO = """
HloModule rs

ENTRY %main (x: f32[16,8]) -> f32[8,8] {
  %x = f32[16,8]{1,0} parameter(0)
  %ar = f32[16,8]{1,0} all-reduce(%x), to_apply=%add
  ROOT %rs = f32[8,8]{1,0} reduce-scatter(%ar), dimensions={0}
}
"""


def test_wire_words_element_counts_per_kind():
    # the obs tracing layer compares these against schedule_words, so
    # the unit must be ELEMENTS (wire bytes / word_bytes), per kind
    w = hlo_parse.wire_words(HLO)
    assert w["all-gather"] == 4096 / 4          # out - in
    assert w["all-gather_count"] == 1
    assert w["collective-permute"] == 5 * 4096 / 4   # x trip count
    assert w["collective-permute_count"] == 5
    assert w["total"] == w["all-gather"] + w["collective-permute"]
    assert w["count"] == 6
    assert "reduce-scatter" not in w            # only kinds that occur


def test_wire_words_reduce_scatter_all_reduce_and_word_bytes():
    w = hlo_parse.wire_words(RS_HLO)
    assert w["reduce-scatter"] == (16 * 8 - 8 * 8) * 4 / 4   # in - out
    assert w["all-reduce"] == 2 * 16 * 8                     # ring RS+AG
    assert w["total"] == w["reduce-scatter"] + w["all-reduce"]
    half = hlo_parse.wire_words(RS_HLO, word_bytes=2)
    assert half["total"] == 2 * w["total"]      # bf16 wire: same bytes,
    assert half["count"] == w["count"]          # twice the elements
