"""Verify communicated bytes of every FusedMM algorithm against theory.

Lowers each algorithm on 8 devices, parses the partitioned HLO with the
loop-aware collective counter, and checks the measured per-device wire
words against (a) an implementation-exact expectation and (b) the paper's
Table III formula.  (a) must match within 10%; (b) within a constant-factor
band (pack padding + the documented 2x on sparse-shifting gathers).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax, jax.numpy as jnp

from repro.core import sparse, costmodel, d15, s15, d25, s25
from repro.core.grid import make_grid15, make_grid25
from repro.roofline.hlo_parse import collective_summary

m = n = 512; r = 64; nnz_row = 4
rows, cols, vals, A, B = sparse.random_problem(m, n, r, nnz_row, seed=0)
nnz = len(vals)
W = 4  # bytes per word


def wire_words(lowered):
    txt = lowered.compile().as_text()
    return collective_summary(txt)["total_wire_bytes"] / W


def report(name, measured, expect_impl, paper_words):
    ratio_i = measured / expect_impl if expect_impl else float("inf")
    ratio_p = measured / paper_words if paper_words else float("inf")
    print(f"{name:34s} measured={measured:10.0f} impl={expect_impl:10.0f} "
          f"(x{ratio_i:5.2f})  paper={paper_words:10.0f} (x{ratio_p:5.2f})")
    assert 0.9 <= ratio_i <= 1.1, f"{name}: impl-model mismatch x{ratio_i}"
    assert 0.3 <= ratio_p <= 4.0, f"{name}: paper-model too far x{ratio_p}"


p = 8
for c in (2, 4):
    L = p // c
    g = make_grid15(c)
    Ash = jax.device_put(jnp.asarray(A), g.sharding(("layer", "fiber")))
    Bsh = jax.device_put(jnp.asarray(B), g.sharding(("layer", "fiber")))
    plan = d15.plan_d15(g, rows, cols, vals, m, n, r, row_tile=32, nz_block=32)
    plant = d15.plan_d15(g, rows, cols, vals, m, n, r, transpose=True, row_tile=32, nz_block=32)
    mA, nB = m // p, n // p

    for el, pl, alg in (("none", plan, "d15_no_elision"),
                        ("reuse", plant, "d15_replication_reuse"),
                        ("fused", plan, "d15_local_fusion")):
        low = d15.fusedmm_d15.lower(g, pl, Ash, Bsh, elision=el)
        n_ag_rs = {"none": 2, "reuse": 1, "fused": 2}[el]
        # Unrolled double-buffered rounds: a round whose final shifted
        # buffer is consumed costs L shifts, a round whose cycle-closing
        # shift is dead costs L-1 (XLA DCEs it) — so 2 rounds -> 2L-1,
        # the single fused round -> L-1.
        n_shifts = {"none": 2 * L - 1, "reuse": 2 * L - 1,
                    "fused": L - 1}[el]
        impl = n_ag_rs * (c - 1) * mA * r + n_shifts * nB * r
        paper = costmodel.words_fusedmm(alg, p=p, c=c, n=n, r=r, nnz=nnz).words
        report(f"{alg} c={c}", wire_words(low), impl, paper)

    # --- 1.5D sparse shifting
    As = jax.device_put(jnp.asarray(A), g.sharding(None, ("layer", "fiber")))
    Bs = jax.device_put(jnp.asarray(B), g.sharding(None, ("layer", "fiber")))
    plans = s15.plan_s15(g, rows, cols, vals, m, n, r, row_tile=32, nz_block=32)
    nb, k = plans.rows_local.shape[-2:]
    for el, n_ag in (("reuse", 2), ("none", 3), ("fused", 2)):
        low = s15.fusedmm_s15.lower(g, plans, As, Bs, elision=el)
        if el == "fused":
            # one-structure-pass: round 1 ships the structure (L-1 live
            # shifts — the cycle-closing home return is dead, round 2
            # replays the local cache) and the traveling partials (L
            # live); round 2 ships final values only, L-1 live shifts.
            shift_words = (L - 1) * (2 * nb * k + nb) + L * nb * k \
                + (L - 1) * nb * k
        else:
            # pack payload: SDDMM round L shifts (pack returns home,
            # live), SpMM round L-1 (cycle-closing shift dead, DCE'd)
            shift_words = (2 * L - 1) * (3 * nb * k + nb)
        impl = n_ag * (c - 1) * m * (r // p) + shift_words
        alg = {"none": "s15_no_elision", "reuse": "s15_replication_reuse",
               "fused": "s15_local_fusion"}[el]
        paper = costmodel.words_fusedmm(alg, p=p, c=c, n=n, r=r,
                                        nnz=nnz).words
        report(f"{alg} c={c}", wire_words(low), impl, paper)

# --- 2.5D on 2x2x2
g25 = make_grid25(2)
G, c = g25.G, g25.c
Ash = jax.device_put(jnp.asarray(A), g25.sharding(("row", "fiber"), "col"))
B_sk = d25.skew_b(g25, B)
pland = d25.plan_d25(g25, rows, cols, vals, m, n, r, row_tile=32, nz_block=32)
plandt = d25.plan_d25(g25, rows, cols, vals, m, n, r, transpose=True, row_tile=32, nz_block=32)
mA, rW, nS = m // (G * c), r // G, n // (G * c)
for el, pl, alg, n_agrs in (("none", pland, "d25_no_elision", 2),
                            ("reuse", plandt, "d25_replication_reuse", 1),
                            ("fused", pland, "d25_local_fusion", 2)):
    low = d25.fusedmm_d25.lower(g25, pl, Ash, B_sk, elision=el)
    nb, k = pl.rows_local.shape[-2:]
    pack_words = 3 * nb * k + nb
    # Unrolled double-buffered Cannon rounds: a shift whose result is
    # consumed downstream costs its payload; cycle-closing shifts of
    # buffers nobody reads again are dead and DCE'd by XLA.
    if el == "none":
        # round 1: pack coords+partials and B, G live shifts each (both
        # feed round 2); round 2: value pack + B, G-1 live shifts.
        impl_shifts = G * (pack_words + nS * rW) \
            + (G - 1) * (pack_words + nS * rW)
    elif el == "fused":
        # one-structure-pass: round 1 coords G-1 live (home return dead,
        # round 2 replays the cache), partials G, B chunks G-1 (home
        # dead); round 2 final values only, G-1 live.
        impl_shifts = (G - 1) * (2 * nb * k + nb) + G * nb * k \
            + (G - 1) * nS * rW + (G - 1) * nb * k
    else:
        # round 1: pack G, B G-1 (B home unused); round 2: traveling
        # (nS, rW) output G, contrib structure G-1.
        impl_shifts = G * pack_words + (G - 1) * nS * rW \
            + G * nS * rW + (G - 1) * pack_words
    impl = n_agrs * (c - 1) * mA * rW + impl_shifts
    paper = costmodel.words_fusedmm(alg, p=p, c=c, n=n, r=r, nnz=nnz).words
    report(f"{alg}", wire_words(low), impl, paper)

plans25 = s25.plan_s25(g25, rows, cols, vals, m, n, r, row_tile=32, nz_block=32)
A_sk = s25.skew_dense(g25, A, along="row")
B_sk2 = s25.skew_dense(g25, B, along="col")
nb, k = plans25.rows_local.shape[-2:]
mS, nS2, rc = plans25.mS, plans25.nS, plans25.rc
for el, alg in (("none", "s25_no_elision"),
                ("reuse", "s25_replication_reuse")):
    low = s25.fusedmm_s25.lower(g25, plans25, A_sk, B_sk2, elision=el)
    if el == "none":
        # dense r-chunk shifts: A G-1 (home copy dead), B G + G-1 across
        # the two rounds, traveling output G; values-only fiber traffic
        # (RS + AG)
        impl_shifts = (2 * G - 1) * (mS * rc + nS2 * rc)
    else:
        # B-chunk reuse: B travels only in round 1 (G-1 live, home copy
        # dead — round 2 replays the cache); A G-1, output G.
        impl_shifts = (2 * G - 1) * mS * rc + (G - 1) * nS2 * rc
    impl = 2 * (c - 1) / c * nb * k + impl_shifts
    paper = costmodel.words_fusedmm(alg, p=p, c=c, n=n, r=r, nnz=nnz).words
    report(alg, wire_words(low), impl, paper)

print("ALL COMM COSTS OK")
