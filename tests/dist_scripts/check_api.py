"""Cross-algorithm parity of the unified API on 8 devices.

Runs the SAME problem through every registered algorithm via
repro.core.api and asserts all of them agree with the kernels/ref dense
oracles; then asserts Session replication caching is bitwise-identical
to uncached calls (same kernels, same operand values, gather elided).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax

from repro.core import api, costmodel, sparse

assert len(jax.devices()) == 8

m = n = 256
r = 64
nnz_row = 5
# the one shared seeded generator (satellite: no per-script re-rolls)
rows, cols, vals, X, Y = sparse.random_problem(m, n, r, nnz_row, seed=0)
Sd = np.zeros((m, n), np.float32); Sd[rows, cols] = vals
wantR = Sd * (X @ Y.T)
wantF = wantR @ Y
wantS = Sd @ Y

CASES = [("d15", 2), ("d15", 4), ("s15", 2), ("s15", 4),
         ("d25", 2), ("s25", 2)]

for name, c in CASES:
    prob = api.make_problem(rows, cols, vals, (m, n), r,
                            algorithm=name, c=c)
    assert prob.alg.name == name and prob.c == c
    tag = f"{name} c={c}"

    got = prob.sddmm(X, Y).to_dense()
    np.testing.assert_allclose(got, wantR, rtol=2e-4, atol=2e-4)
    print(tag, "sddmm ok")

    np.testing.assert_allclose(prob.spmm(Y), wantS, rtol=2e-4, atol=2e-4)
    print(tag, "spmm ok")

    for el in prob.alg.elisions:
        out, R = prob.fusedmm(X, Y, elision=el)
        np.testing.assert_allclose(out, wantF, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(R.to_dense(), wantR, rtol=2e-3,
                                   atol=2e-3)
        print(tag, f"fusedmm {el} ok")

    # the uniform default resolves per the cost model, never errors
    out, _ = prob.fusedmm(X, Y)
    np.testing.assert_allclose(out, wantF, rtol=2e-3, atol=2e-3)
    print(tag, f"fusedmm auto={prob.resolve_elision()} ok")

    # --- Session replication caching: bitwise identity vs uncached, per
    # elision (the cache elides the gather, never the arithmetic)
    for el in prob.alg.elisions:
        sess = api.Session()
        base, baseR = prob.fusedmm(X, Y, elision=el)
        first, _ = prob.fusedmm(X, Y, elision=el, session=sess)   # fill
        cached, cachedR = prob.fusedmm(X, Y, elision=el,
                                       session=sess)              # hit
        np.testing.assert_array_equal(base, first, err_msg=f"{tag} {el}")
        np.testing.assert_array_equal(base, cached, err_msg=f"{tag} {el}")
        np.testing.assert_array_equal(baseR.to_dense(),
                                      cachedR.to_dense(),
                                      err_msg=f"{tag} {el}")
        print(tag, f"session bitwise ok [{el}] "
                   f"({len(sess)} cached operands)")

# --- auto dispatch picks the paper's regime (Fig. 6) and stays correct
lo = api.make_problem(rows, cols, vals, (m, n), r, algorithm="auto")
assert lo.alg.name.startswith("s"), (lo.alg.name, lo.phi)
out, _ = lo.fusedmm(X, Y)
np.testing.assert_allclose(out, wantF, rtol=2e-3, atol=2e-3)
print(f"auto (phi={lo.phi:.3f}) -> {lo.alg.name} c={lo.c} ok")

dense_rows, dense_cols, dense_vals = sparse.erdos_renyi(m, n, 128, seed=2)
hi = api.make_problem(dense_rows, dense_cols, dense_vals, (m, n), 8,
                      algorithm="auto")
assert hi.alg.name.startswith("d"), (hi.alg.name, hi.phi)
print(f"auto (phi={hi.phi:.3f}) -> {hi.alg.name} c={hi.c} ok")

print("ALL API OK")
