"""Gradient parity of the distributed autodiff layer on 8 devices.

jax.grad through grads.fusedmm / sddmm / spmm must match jax.grad of
the dense reference (fp32 allclose) on EVERY feasible registry
(family, elision) cell, with and without a threaded Session (which must
be bitwise-neutral while replaying the forward's replication in the
backward).  Also runs the trainable apps end-to-end: a GAT layer
training step and the sampled-loss embedding SGD loop.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import api, costmodel, grads, sparse

assert len(jax.devices()) == 8

m = n = 256
r = 32
rows, cols, vals, X, Y = sparse.random_problem(m, n, r, 5, seed=0)
Sd = np.zeros((m, n), np.float32); Sd[rows, cols] = vals
rng = np.random.default_rng(2)
W = rng.standard_normal((m, r)).astype(np.float32)
wv = rng.standard_normal(len(vals)).astype(np.float32)
Xj, Yj, Sdj, Wj = map(jnp.asarray, (X, Y, Sd, W))


def dense_fusedmm_loss(X, Y):
    return jnp.sum(((Sdj * (X @ Y.T)) @ Y) * Wj)


want_fx, want_fy = jax.grad(dense_fusedmm_loss, argnums=(0, 1))(Xj, Yj)

for name, c in (("d15", 2), ("d15", 4), ("s15", 2), ("d25", 2),
                ("s25", 2)):
    prob = api.make_problem(rows, cols, vals, (m, n), r,
                            algorithm=name, c=c)
    tag = f"{name} c={c}"
    for el in prob.alg.elisions:
        def loss(X, Y, session=None):
            return jnp.sum(grads.fusedmm(prob, X, Y, elision=el,
                                         session=session) * Wj)
        gx, gy = jax.grad(loss, argnums=(0, 1))(Xj, Yj)
        np.testing.assert_allclose(gx, want_fx, rtol=2e-3, atol=2e-3,
                                   err_msg=f"{tag} {el} X")
        np.testing.assert_allclose(gy, want_fy, rtol=2e-3, atol=2e-3,
                                   err_msg=f"{tag} {el} Y")
        # Session threading: bitwise-neutral, with backward replay
        sess = api.Session()
        sx, sy = jax.grad(lambda X, Y: loss(X, Y, sess),
                          argnums=(0, 1))(Xj, Yj)
        np.testing.assert_array_equal(np.asarray(gx), np.asarray(sx),
                                      err_msg=f"{tag} {el} session X")
        np.testing.assert_array_equal(np.asarray(gy), np.asarray(sy),
                                      err_msg=f"{tag} {el} session Y")
        if name != "s25":
            assert sess.hits >= 1, (tag, el, sess.hits, sess.misses)
        print(f"{tag} fusedmm[{el}] grads ok "
              f"(session {sess.hits} replays)")

    # sddmm + values-differentiable spmm duals
    def sloss(X, Y):
        return jnp.sum(grads.sddmm(prob, X, Y) * jnp.asarray(wv))

    def dense_sloss(X, Y):
        return jnp.sum((Sdj * (X @ Y.T))[rows, cols] * jnp.asarray(wv))

    gx, gy = jax.grad(sloss, argnums=(0, 1))(Xj, Yj)
    wx, wy = jax.grad(dense_sloss, argnums=(0, 1))(Xj, Yj)
    np.testing.assert_allclose(gx, wx, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(gy, wy, rtol=2e-3, atol=2e-3)

    def ploss(v, Y):
        return jnp.sum(grads.spmm(prob, v, Y) * Wj)

    def dense_ploss(v, Y):
        S2 = jnp.zeros((m, n)).at[rows, cols].set(v)
        return jnp.sum((S2 @ Y) * Wj)

    vj = jnp.asarray(vals)
    gv, gy = jax.grad(ploss, argnums=(0, 1))(vj, Yj)
    dv, dy = jax.grad(dense_ploss, argnums=(0, 1))(vj, Yj)
    np.testing.assert_allclose(gv, dv, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(gy, dy, rtol=2e-3, atol=2e-3)
    print(f"{tag} sddmm/spmm duals ok")

# --- trainable apps on the 8-device mesh -----------------------------------
from repro.apps import als, gat

_, _, hist = als.train_embedding_distributed(
    m=256, n=256, nnz_per_row=5, r=16, steps=10, lr=0.08,
    algorithm="s15", verbose=False)
assert hist[-1] < 0.5 * hist[0], hist
print(f"embedding sgd [s15]: {hist[0]:.1f} -> {hist[-1]:.2f} ok")

n_g, d = 256, 16
gp = gat.make_dist_graph(n_g, 4, d, algorithm="d15", seed=3)
H = np.random.default_rng(3).standard_normal((n_g, d)).astype(np.float32)
p0 = gat.init_gat_layer(jax.random.PRNGKey(0), d, d)
want = np.asarray(gat.gat_layer_distributed(gp, H, p0))
got = np.asarray(gat.gat_layer_trainable(
    gp, jnp.asarray(H), jnp.asarray(p0.W), jnp.asarray(p0.a1),
    jnp.asarray(p0.a2)))
np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
target = np.random.default_rng(4).standard_normal((n_g, d)).astype(
    np.float32) * 0.1
_, hist = gat.train_gat_distributed(gp, H, target, steps=4, lr=0.05,
                                    verbose=False)
assert hist[-1] < hist[0], hist
print(f"gat training [d15]: {hist[0]:.4f} -> {hist[-1]:.4f} ok")

print("ALL GRADS OK")
