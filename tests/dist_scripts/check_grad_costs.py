"""Verify the BACKWARD pass ships the words the extended model says.

For every registry (family, elision) cell on 8 devices, lowers the three
programs the dual-primitive VJP of grads.fusedmm actually invokes — the
dual FusedMM (same cell) and the two transpose-SpMMs — parses the
partitioned HLO, and checks the measured per-device wire words against
(a) an implementation-exact expectation (must match within 10%, i.e.
x1.00) and (b) the paper-level ``costmodel.words_fusedmm_bwd`` row
(constant-factor band, like check_comm_costs.py's forward check).

Also asserts the Session-replayed backward — the forward's fiber
replication replayed by the backward within one training step — ships
STRICTLY fewer words than the naive backward on every family that
replicates a dense operand (d15/s15/d25), and identical words on s25
(nothing dense is replicated there; the model says so too).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax

from repro.core import api, costmodel, sparse
from repro.roofline.hlo_parse import collective_summary

assert len(jax.devices()) == 8

m = n = 256
r = 64
rows, cols, vals, X, Y = sparse.random_problem(m, n, r, 4, seed=0)
nnz = len(vals)
p = 8
W = 4  # bytes per word


def wire_words(lowered):
    txt = lowered.compile().as_text()
    return collective_summary(txt)["total_wire_bytes"] / W


def nbk(plan):
    return plan.rows_local.shape[-2], plan.rows_local.shape[-1]


def report(name, measured, expect_impl, paper_words):
    ratio_i = measured / expect_impl if expect_impl else float("inf")
    ratio_p = measured / paper_words if paper_words else float("inf")
    print(f"{name:40s} measured={measured:10.0f} impl={expect_impl:10.0f} "
          f"(x{ratio_i:5.2f})  paper={paper_words:10.0f} (x{ratio_p:5.2f})")
    assert 0.9 <= ratio_i <= 1.1, f"{name}: impl-model mismatch x{ratio_i}"
    assert 0.2 <= ratio_p <= 5.0, f"{name}: paper-model too far x{ratio_p}"


def d15_components(prob, c):
    L = p // c
    mA, nB = m // p, n // p
    plan_n = prob.plan("normal")
    agrs = {"none": 2, "reuse": 1, "fused": 2}
    shifts = {"none": 2 * L - 1, "reuse": 2 * L - 1, "fused": L - 1}

    def fusedmm(el, sess):
        ag = agrs[el] - (1 if sess else 0)   # the AG is replayed; an RS
        return ag * (c - 1) * mA * r + shifts[el] * nB * r  # never is

    def spmmt(sess):
        ag = 0 if sess else 1
        return ag * (c - 1) * mA * r + L * nB * r

    return fusedmm, spmmt


def s15_components(prob, c):
    L = p // c
    nb, k = nbk(prob.plan("normal"))
    nbt, kt = nbk(prob.transposed().plan("normal"))
    gather = (c - 1) * m * (r // p)
    ags = {"none": 3, "reuse": 2, "fused": 2}

    def fusedmm(el, sess):
        # with a Session BOTH column-slab gathers are served from it;
        # "none"'s honest mid-call re-gather stays on the wire
        ag = (1 if el == "none" else 0) if sess else ags[el]
        if el == "fused":
            shift = (L - 1) * (2 * nb * k + nb) + L * nb * k \
                + (L - 1) * nb * k
        else:
            shift = (2 * L - 1) * (3 * nb * k + nb)
        return ag * gather + shift

    def spmmt(sess):
        ag = 0 if sess else 1
        return ag * gather + (L - 1) * (3 * nbt * kt + nbt)

    return fusedmm, spmmt


def d25_components(prob, c):
    G = prob.grid.G
    mA, rW, nS = m // (G * c), r // G, n // (G * c)
    nb, k = nbk(prob.plan("normal"))
    nbr, kr = nbk(prob.plan("transpose"))        # (S^T)'s transpose pack
    nbt, kt = nbk(prob.transposed().plan("transpose"))   # S's own
    agrs = {"none": 2, "reuse": 1, "fused": 2}

    def fusedmm(el, sess):
        ag = agrs[el] - (1 if sess else 0)
        pw = 3 * nb * k + nb
        if el == "none":
            shift = G * (pw + nS * rW) + (G - 1) * (pw + nS * rW)
        elif el == "fused":
            shift = (G - 1) * (2 * nb * k + nb) + G * nb * k \
                + (G - 1) * nS * rW + (G - 1) * nb * k
        else:
            pwr = 3 * nbr * kr + nbr
            shift = G * pwr + (G - 1) * nS * rW + G * nS * rW \
                + (G - 1) * pwr
        return ag * (c - 1) * mA * rW + shift

    def spmmt(sess):
        ag = 0 if sess else 1
        pwt = 3 * nbt * kt + nbt
        return ag * (c - 1) * mA * rW + G * nS * rW + (G - 1) * pwt

    return fusedmm, spmmt


def s25_components(prob, c):
    G = prob.grid.G
    mS, nS, rc = m // G, n // G, r // (G * c)
    nb, k = nbk(prob.plan("normal"))
    nbt, kt = nbk(prob.transposed().plan("normal"))

    def fusedmm(el, sess):
        fiber = 2 * (c - 1) / c * nb * k          # RS + AG, values only
        if el == "reuse":
            shift = (2 * G - 1) * mS * rc + (G - 1) * nS * rc
        else:
            shift = (2 * G - 1) * (mS * rc + nS * rc)
        return fiber + shift                       # sess changes nothing

    def spmmt(sess):
        fiber = (c - 1) / c * nbt * kt             # values AG
        return fiber + (G - 1) * (m // G) * rc + G * (n // G) * rc

    return fusedmm, spmmt


COMPONENTS = {"d15": d15_components, "s15": s15_components,
              "d25": d25_components, "s25": s25_components}
CASES = [("d15", 2), ("d15", 4), ("s15", 2), ("d25", 2), ("s25", 2)]

for name, c in CASES:
    prob = api.make_problem(rows, cols, vals, (m, n), r, algorithm=name,
                            c=c, row_tile=32, nz_block=32)
    fusedmm_model, spmmt_model = COMPONENTS[name](prob, c)
    sess = api.Session()
    w_spmmt = wire_words(prob.lower_spmm_t())
    w_spmmt_sess = wire_words(prob.lower_spmm_t(session=sess))
    for el in prob.alg.elisions:
        cm_name = costmodel.ELISION_COST_NAME[(name, el)]
        kw = dict(p=p, c=c, n=n, r=r, nnz=nnz)
        w_fm = wire_words(prob.lower_fusedmm(el))
        w_fm_sess = wire_words(prob.lower_fusedmm(el, session=sess))
        # the VJP's backward = dual FusedMM + 2 transpose-SpMMs; with a
        # Session the dual FusedMM and the Ghat^T X SpMM replay gathers
        measured = w_fm + 2 * w_spmmt
        measured_sess = w_fm_sess + w_spmmt + w_spmmt_sess
        impl = fusedmm_model(el, False) + 2 * spmmt_model(False)
        impl_sess = fusedmm_model(el, True) + spmmt_model(False) \
            + spmmt_model(True)
        paper = costmodel.words_fusedmm_bwd(cm_name, **kw).words
        paper_sess = costmodel.words_fusedmm_bwd(cm_name, session=True,
                                                 **kw).words
        report(f"{cm_name}_bwd c={c}", measured, impl, paper)
        report(f"{cm_name}_bwd+session c={c}", measured_sess, impl_sess,
               paper_sess)
        if name == "s25":
            assert measured_sess == measured, (name, el)
        else:
            assert measured_sess < measured, (name, el)
        # and the model agrees about the direction of the saving
        assert (paper_sess < paper) == (name != "s25")

print("ALL GRAD COSTS OK")
