"""Bitwise identity of the double-buffered (overlap) d15/d25 schedules vs
the serial compute-then-shift baseline, on an 8-device CPU mesh.

The overlap refactor only reorders *communication* issue points; every
local kernel sees the same operands in the same order, so outputs must be
bit-for-bit identical — any drift means the shift schedule changed the
math.  Runs on both kernel backends.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax, jax.numpy as jnp

from repro.core import sparse
from repro.core.grid import make_grid15, make_grid25
from repro.core import d15, d25
from repro.kernels import ops

assert len(jax.devices()) == 8


def identical(a, b, what):
    fa = [np.asarray(x) for x in jax.tree_util.tree_leaves(a)]
    fb = [np.asarray(x) for x in jax.tree_util.tree_leaves(b)]
    assert len(fa) == len(fb), what
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(x, y, err_msg=what)


def run(c, backend, m=256, n=320, r=64, nnz_row=5, seed=0):
    ops.set_default_backend(backend)
    grid = make_grid15(c)
    rows, cols, vals, A, B = sparse.random_problem(m, n, r, nnz_row,
                                                   seed=seed)
    A, B = jnp.asarray(A), jnp.asarray(B)
    Ash = jax.device_put(A, grid.sharding(("layer", "fiber")))
    Bsh = jax.device_put(B, grid.sharding(("layer", "fiber")))
    plan = d15.plan_d15(grid, rows, cols, vals, m, n, r,
                        row_tile=32, nz_block=32)
    plant = d15.plan_d15(grid, rows, cols, vals, m, n, r, transpose=True,
                         row_tile=32, nz_block=32)

    identical(d15.sddmm_d15(grid, plan, Ash, Bsh, overlap=True),
              d15.sddmm_d15(grid, plan, Ash, Bsh, overlap=False),
              f"sddmm c={c} {backend}")
    identical(d15.spmma_d15(grid, plan, Bsh, overlap=True),
              d15.spmma_d15(grid, plan, Bsh, overlap=False),
              f"spmma c={c} {backend}")
    identical(d15.spmmb_d15(grid, plant, Ash, overlap=True),
              d15.spmmb_d15(grid, plant, Ash, overlap=False),
              f"spmmb c={c} {backend}")
    for elis, pl_ in (("none", plan), ("reuse", plant), ("fused", plan)):
        identical(
            d15.fusedmm_d15(grid, pl_, Ash, Bsh, elision=elis, overlap=True),
            d15.fusedmm_d15(grid, pl_, Ash, Bsh, elision=elis,
                            overlap=False),
            f"fusedmm/{elis} c={c} {backend}")
    print(f"c={c} backend={backend} overlap==serial")


def run_d25(c, ndev, backend, m=256, n=256, r=64, nnz_row=5, seed=0):
    ops.set_default_backend(backend)
    grid = make_grid25(c, devices=jax.devices()[:ndev])
    rows, cols, vals, A, B = sparse.random_problem(m, n, r, nnz_row,
                                                   seed=seed)
    Ash = jax.device_put(jnp.asarray(A),
                         grid.sharding(("row", "fiber"), "col"))
    B_sk = d25.skew_b(grid, B)
    plan = d25.plan_d25(grid, rows, cols, vals, m, n, r,
                        row_tile=32, nz_block=32)
    plant = d25.plan_d25(grid, rows, cols, vals, m, n, r, transpose=True,
                         row_tile=32, nz_block=32)

    identical(d25.sddmm_d25(grid, plan, Ash, B_sk, overlap=True),
              d25.sddmm_d25(grid, plan, Ash, B_sk, overlap=False),
              f"d25 sddmm G={grid.G},c={c} {backend}")
    identical(d25.spmma_d25(grid, plan, B_sk, overlap=True),
              d25.spmma_d25(grid, plan, B_sk, overlap=False),
              f"d25 spmma G={grid.G},c={c} {backend}")
    for elis, pl_ in (("none", plan), ("reuse", plant), ("fused", plan)):
        identical(
            d25.fusedmm_d25(grid, pl_, Ash, B_sk, elision=elis,
                            overlap=True),
            d25.fusedmm_d25(grid, pl_, Ash, B_sk, elision=elis,
                            overlap=False),
            f"d25 fusedmm/{elis} G={grid.G},c={c} {backend}")
    print(f"G={grid.G},c={c} backend={backend} d25 overlap==serial")


try:
    for backend in ("pallas", "ref"):
        for c in (1, 2, 4):
            run(c, backend)
        run_d25(2, 8, backend)   # 2x2x2
        run_d25(1, 4, backend)   # 2x2x1 pure Cannon
finally:
    ops.set_default_backend("pallas")
print("D15 OVERLAP IDENTITY OK")
print("D25 OVERLAP IDENTITY OK")
