"""Correctness of 2.5D sparse-replicating algorithms on 8 devices vs oracle."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax, jax.numpy as jnp

from repro.core import sparse
from repro.core.grid import make_grid25
from repro.core import s25

assert len(jax.devices()) == 8

def run(c, ndev, m=256, n=256, r=64, nnz_row=5, seed=0):
    grid = make_grid25(c, devices=jax.devices()[:ndev])
    rows, cols, vals, A, B = sparse.random_problem(m, n, r, nnz_row,
                                                   seed=seed)
    Sd = np.zeros((m, n), np.float32); Sd[rows, cols] = vals
    A_sk = s25.skew_dense(grid, A, along="row")
    B_sk = s25.skew_dense(grid, B, along="col")
    plan = s25.plan_s25(grid, rows, cols, vals, m, n, r, row_tile=32, nz_block=32)
    tag = f"G={grid.G},c={c}"
    wantR = Sd * (A @ B.T)

    # SDDMM: values end fiber-sharded at home; gather on host
    rv = np.asarray(s25.sddmm_s25(grid, plan, A_sk, B_sk))  # (G,G,c,nb/c,k)
    G = grid.G
    nb = plan.rows_local.shape[3]
    full = rv.reshape(G, G, nb, rv.shape[-1])
    got = plan.meta.block_meta.to_dense(
        np.asarray(plan.rows_local)[:, :, 0], np.asarray(plan.cols)[:, :, 0],
        full, np.asarray(plan.tile_base)[:, :, 0])
    np.testing.assert_allclose(got, wantR, rtol=2e-4, atol=2e-4)
    print(tag, "sddmm ok")

    # SpMMA
    outS = s25.spmma_s25(grid, plan, B_sk)
    gotA = s25.unskew_out(grid, plan, outS)
    np.testing.assert_allclose(gotA, Sd @ B, rtol=2e-4, atol=2e-4)
    print(tag, "spmma ok")

    # FusedMM ("auto" resolves to the B-chunk-reuse cell)
    outS, rmine = s25.fusedmm_s25(grid, plan, A_sk, B_sk)
    gotF = s25.unskew_out(grid, plan, outS)
    np.testing.assert_allclose(gotF, wantR @ B, rtol=2e-3, atol=2e-3)
    print(tag, "fusedmm ok")

    # B-chunk reuse is bitwise-identical to the unfused "none" sequence
    outN, rmineN = s25.fusedmm_s25(grid, plan, A_sk, B_sk, elision="none")
    outR, rmineR = s25.fusedmm_s25(grid, plan, A_sk, B_sk, elision="reuse")
    np.testing.assert_array_equal(np.asarray(outR), np.asarray(outN))
    np.testing.assert_array_equal(np.asarray(rmineR), np.asarray(rmineN))
    print(tag, "fusedmm reuse ok (bitwise == none)")

run(c=2, ndev=8)   # 2x2x2
run(c=1, ndev=4)   # 2x2x1
run(c=2, ndev=2)   # 1x1x2
run(c=4, ndev=4)   # 1x1x4
print("ALL S25 OK")
