"""Correctness of 1.5D dense-shifting algorithms on 8 devices vs oracle."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import sparse
from repro.core.grid import make_grid15
from repro.core import d15
from repro.kernels import ref

assert len(jax.devices()) == 8

def run(c, m=256, n=320, r=64, nnz_row=5, seed=0):
    grid = make_grid15(c)
    p = grid.p
    rows, cols, vals, A, B = sparse.random_problem(m, n, r, nnz_row,
                                                   seed=seed)
    A, B = jnp.asarray(A), jnp.asarray(B)
    Sd = np.zeros((m, n), np.float32); Sd[rows, cols] = vals
    Ash = jax.device_put(A, grid.sharding(("layer", "fiber")))
    Bsh = jax.device_put(B, grid.sharding(("layer", "fiber")))

    plan = d15.plan_d15(grid, rows, cols, vals, m, n, r, row_tile=32, nz_block=32)
    plant = d15.plan_d15(grid, rows, cols, vals, m, n, r, transpose=True, row_tile=32, nz_block=32)

    # --- SDDMM
    rv = sddmm_vals = d15.sddmm_d15(grid, plan, Ash, Bsh)
    got = plan.meta.block_meta.to_dense(plan.rows_local, plan.cols, rv, plan.tile_base)
    want = np.asarray(ref.sddmm_dense(A, B, jnp.asarray(Sd)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    print(f"c={c} sddmm ok")

    # --- SpMMA
    gotA = np.asarray(d15.spmma_d15(grid, plan, Bsh))
    np.testing.assert_allclose(gotA, Sd @ np.asarray(B), rtol=2e-4, atol=2e-4)
    print(f"c={c} spmma ok")

    # --- SpMMB
    gotB = np.asarray(d15.spmmb_d15(grid, plant, Ash))
    np.testing.assert_allclose(gotB, Sd.T @ np.asarray(A), rtol=2e-4, atol=2e-4)
    print(f"c={c} spmmb ok")

    # --- FusedMMA, no elision
    out, rvals = d15.fusedmm_d15(grid, plan, Ash, Bsh, elision="none")
    wantR = Sd * (np.asarray(A) @ np.asarray(B).T)
    np.testing.assert_allclose(np.asarray(out), wantR @ np.asarray(B), rtol=2e-3, atol=2e-3)
    print(f"c={c} fusedmm none ok")

    # --- FusedMMB, replication reuse
    outB, _ = d15.fusedmm_d15(grid, plant, Ash, Bsh, elision="reuse")
    np.testing.assert_allclose(np.asarray(outB), wantR.T @ np.asarray(A), rtol=2e-3, atol=2e-3)
    print(f"c={c} fusedmm reuse ok")

    # --- FusedMMA, local kernel fusion
    outF, _ = d15.fusedmm_d15(grid, plan, Ash, Bsh, elision="fused")
    np.testing.assert_allclose(np.asarray(outF), wantR @ np.asarray(B), rtol=2e-3, atol=2e-3)
    print(f"c={c} fusedmm fused ok")

for c in (1, 2, 4, 8):
    run(c)
print("ALL D15 OK")
