"""8-device schedule-conformance sweep (docs/static_analysis.md).

Four guarantees:

1. **Full-grid verdicts** — every registry cell (family x op x elision
   x comm x session) lowers to HLO and passes verification: dense cells
   match their ``schedule_words`` sequence (kind, order, per-run words,
   gather/reduce instruction counts), every cell's replica groups
   partition the mesh, and the SPMD rendezvous simulation drains.

2. **Corruption is caught** — corrupting a cell's expected event list
   (dropping the gather, mislabeling the reduce, inflating shift words)
   flips its verdict to fail with a sequence error; corrupting one
   rank's queue in the real HLO-derived program deadlocks the
   rendezvous simulation.

3. **Registry coverage** — the verdict table contains every declared
   (family x op x elision) cell in both wire formats; dense cells are
   all mode="full" (the model is defined there), sparse cells
   mode="structural" (data-dependent volume by contract).

4. **Artifact** — ANALYSIS_report.json (the CI artifact schema) is
   written and JSON-round-trips.

Prints ALL ANALYSIS OK.
"""
import json
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import jax

from repro.analysis import conformance
from repro.core import api

assert len(jax.devices()) == 8

# --- 1+3. full grid green --------------------------------------------------
report = conformance.run_conformance(
    progress=lambda row: print(f"{row['verdict']:4s} {row['cell']:34s} "
                               f"[{row['mode']}]"))
failed = [c for c in report["cells"] if c["verdict"] != "pass"]
assert not failed, f"conformance failures: {[c['cell'] for c in failed]}"

cells = {c["cell"] for c in report["cells"]}
for name in sorted(api.ALGORITHMS):
    alg = api.ALGORITHMS[name]
    for comm in ("dense", "sparse"):
        for op in ("sddmm", "spmm", "spmm_t"):
            assert f"{name}.{op}[{comm}]" in cells, (name, op, comm)
        for el in alg.elisions:
            assert f"{name}.fusedmm[{el}][{comm}]" in cells, (name, el)
for c in report["cells"]:
    want = "full" if c["comm"] == "dense" else "structural"
    assert c["mode"] == want, c["cell"]
    assert c["checks"]["replica_groups"] == "pass", c["cell"]
    assert c["checks"]["rendezvous"] == "pass", c["cell"]
n_sess = sum(1 for c in report["cells"] if c["session"])
assert n_sess >= 10, "session-replay variants missing from the grid"
print(f"grid: {len(report['cells'])} cells "
      f"({report['structural']} structural, {n_sess} +session) all pass")

# --- 2a. corrupted expected event lists flip the verdict -------------------
prob = conformance._make_problem("d15", "dense", m=64, n=64, r=16, c=2,
                                 nnz_row=4)
good = conformance.verify_cell(prob, "sddmm")
assert good.ok and good["mode"] == "full"
expected = conformance.expected_collectives(prob, "sddmm")

dropped = expected[1:]                        # lose the fiber all-gather
bad = conformance.verify_cell(prob, "sddmm", expected_override=dropped)
assert not bad.ok and any("mismatch" in e for e in bad["errors"])

mislabeled = [conformance.ExpectedEvent(e.point, e.phase,
                                        "reduce-scatter", e.words)
              if e.kind == "all-gather" else e for e in expected]
bad = conformance.verify_cell(prob, "sddmm", expected_override=mislabeled)
assert not bad.ok

inflated = [conformance.ExpectedEvent(e.point, e.phase, e.kind,
                                      e.words * 2) for e in expected]
bad = conformance.verify_cell(prob, "sddmm", expected_override=inflated)
assert not bad.ok and any("words" in e for e in bad["errors"])
print("corrupted event lists: drop/mislabel/inflate all caught")

# --- 2b. rendezvous deadlock on the real per-rank program ------------------
from repro.roofline.hlo_parse import ordered_collectives

hlo = prob.alg.lower_fusedmm(prob, "none").compile().as_text()
instrs = ordered_collectives(hlo)
prog = conformance.rank_programs(instrs, 8)
assert conformance.simulate_rendezvous(prog)["ok"]
prog[2] = prog[2][1:]                  # rank 2 skips its first collective
sim = conformance.simulate_rendezvous(prog)
assert not sim["ok"] and 2 in sim["stuck"]
prog = conformance.rank_programs(instrs, 8)
prog[6][0], prog[6][1] = prog[6][1], prog[6][0]   # cross-rank reorder
assert not conformance.simulate_rendezvous(prog)["ok"]
print(f"rendezvous: {len(instrs)} collectives drain; "
      f"skip/reorder corruptions deadlock")

# --- 4. artifact -----------------------------------------------------------
path = conformance.write_report({"schema": 1, "conformance": report},
                                "ANALYSIS_report.json")
loaded = conformance.load_report(path)
assert loaded == json.loads(json.dumps({"schema": 1,
                                        "conformance": report}))
assert loaded["conformance"]["fail"] == 0
print(f"wrote {path} ({len(report['cells'])} cell verdicts)")
print("ALL ANALYSIS OK")
