"""Support-pruned wire formats (comm="sparse"): parity + exact words.

8-device run, three layers of assertion per feasible
(family x op x elision) cell:

1. **Bitwise parity** — the comm="sparse" executor output equals the
   comm="dense" output with ``assert_array_equal``: pruning touches only
   input-operand movements (fiber all-gathers, traveling dense input
   chunks), never a reduce-scatter, traveling output accumulator or
   partial-dot buffer, so every FP accumulation keeps its order.
2. **Plan-exact wire words at 1.00x** — measured(sparse program) ==
   measured(dense program) + delta, where delta is computed from the
   pack's SparseMeta alone (support widths x hop counts x fiber width).
   Channels that failed the SPARSE_CROSSOVER test contribute zero delta
   (their schedule IS the dense one).
3. **Analytic band** — the nnz-dependent cost-model rows
   (costmodel.words_fusedmm_sparse) band the measured sparse programs;
   they are global-rho estimates of the per-device padded supports, so
   the band is loose where the plan-exact check is exact.

A final section runs a seeded power-law (RMAT) problem through the
api layer and asserts comm="sparse" ships strictly fewer wire words
than the dense Table-III optimum cell — the headline claim — while
staying bitwise-identical.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np                                          # noqa: E402
import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402

from repro.core import api, costmodel, d15, d25, s15, s25, sparse  # noqa: E402
from repro.core.grid import make_grid15, make_grid25        # noqa: E402
from repro.roofline.hlo_parse import collective_summary     # noqa: E402

m = n = 512
r = 64
p = 8
# sparse enough that every family's crossover engages at least one
# pruned channel (d25/s25 block supports are near-dense at nnz_row=4)
rows, cols, vals, A, B = sparse.random_problem(m, n, r, 2, seed=0)
rho_row, rho_col = costmodel.support_density(rows, cols, m, n)
NNZ = len(vals)

checks = []


def wirewords(lowered):
    txt = lowered.compile().as_text()
    return collective_summary(txt)["total_wire_bytes"] / 4


def ww(fn, *a, **k):
    return wirewords(fn.lower(*a, **k))


def eq(cell, x, y):
    xs, ys = jax.tree_util.tree_leaves(x), jax.tree_util.tree_leaves(y)
    assert len(xs) == len(ys), cell
    for a_, b_ in zip(xs, ys):
        np.testing.assert_array_equal(np.asarray(a_), np.asarray(b_),
                                      err_msg=cell)


def report(cell, meas, want):
    ratio = meas / want if want else float("inf")
    checks.append((cell, meas, want, ratio))
    print(f"  {cell:28s} meas={meas:9.0f} model={want:9.0f} x{ratio:.3f}")
    assert abs(ratio - 1.0) < 2e-3, (cell, meas, want)


def band(cell, meas, alg, c):
    est = costmodel.words_fusedmm_sparse(
        alg, p=p, c=c, m=m, n=n, r=r, nnz=NNZ,
        rho_row=rho_row, rho_col=rho_col).words
    ratio = meas / est
    print(f"  {cell:28s} analytic={est:9.0f} x{ratio:.2f}")
    assert 0.4 < ratio < 2.0, (cell, meas, est)


kw = dict(row_tile=32, nz_block=32)


# ---------------------------------------------------------------------------
# d15: A fiber-gathered (pruned), B ring-shifts (pruned), outputs dense
# ---------------------------------------------------------------------------

def check_d15(c):
    L = p // c
    g = make_grid15(c)
    Ash = jax.device_put(jnp.asarray(A), g.sharding(("layer", "fiber")))
    Bsh = jax.device_put(jnp.asarray(B), g.sharding(("layer", "fiber")))
    mA, nB = m // p, n // p
    pd = d15.plan_d15(g, rows, cols, vals, m, n, r, **kw)
    ps = d15.plan_d15(g, rows, cols, vals, m, n, r, comm="sparse", **kw)
    pdt = d15.plan_d15(g, rows, cols, vals, m, n, r, transpose=True, **kw)
    pst = d15.plan_d15(g, rows, cols, vals, m, n, r, transpose=True,
                       comm="sparse", **kw)
    sm, smt = ps.smeta, pst.smeta
    print(f"d15 c={c}: gather={sm.gather} wg={sm.wg}/{mA} "
          f"shift={sm.shift} ws={sm.ws} (nB={nB})")

    def dg(smx):   # gather channel delta (pruned - dense)
        return (c - 1) * (smx.wg - mA) * r if smx.gather else 0

    def ds1(smx):  # first B-trip round delta
        return (sum(smx.ws) - (L - 1) * nB) * r if smx.shift else 0

    def ds2(smx):  # replay round ("none"): dense replay rings L hops
        return (sum(smx.ws) - L * nB) * r if smx.shift else 0

    cells = [
        ("sddmm", lambda pl: (d15.sddmm_d15, (g, pl, Ash, Bsh), {}),
         ps, dg(sm) + ds1(sm)),
        ("spmma", lambda pl: (d15.spmma_d15, (g, pl, Bsh), {}),
         ps, ds1(sm)),
        ("spmmb", lambda pl: (d15.spmmb_d15, (g, pl, Ash), {}),
         pst, dg(smt)),
        ("fusedmm none", lambda pl: (d15.fusedmm_d15, (g, pl, Ash, Bsh),
                                     dict(elision="none")),
         ps, dg(sm) + ds1(sm) + ds2(sm)),
        ("fusedmm reuse", lambda pl: (d15.fusedmm_d15, (g, pl, Ash, Bsh),
                                      dict(elision="reuse")),
         pst, dg(smt) + ds1(smt)),
        ("fusedmm fused", lambda pl: (d15.fusedmm_d15, (g, pl, Ash, Bsh),
                                      dict(elision="fused")),
         ps, dg(sm) + ds1(sm)),
    ]
    dense_plan = {id(ps): pd, id(pst): pdt}
    for name, call, sp, delta in cells:
        fn, args_s, kws = call(sp)
        _, args_d, _ = call(dense_plan[id(sp)])
        eq(f"d15 c={c} {name}", fn(*args_d, **kws), fn(*args_s, **kws))
        meas_d = ww(fn, *args_d, **kws)
        meas_s = ww(fn, *args_s, **kws)
        report(f"d15 c={c} {name}", meas_s, meas_d + delta)
    for el, alg in (("none", "d15_no_elision"),
                    ("reuse", "d15_replication_reuse"),
                    ("fused", "d15_local_fusion")):
        sp = pst if el == "reuse" else ps
        band(f"d15 c={c} fusedmm {el}",
             ww(d15.fusedmm_d15, g, sp, Ash, Bsh, elision=el), alg, c)


# ---------------------------------------------------------------------------
# s15: both dense operands column-slab-gathered (pruned); COO trips dense
# ---------------------------------------------------------------------------

def check_s15(c):
    g = make_grid15(c)
    rp = r // p
    As = jax.device_put(jnp.asarray(A), g.sharding(None, ("layer", "fiber")))
    Bs = jax.device_put(jnp.asarray(B), g.sharding(None, ("layer", "fiber")))
    pd = s15.plan_s15(g, rows, cols, vals, m, n, r, **kw)
    ps = s15.plan_s15(g, rows, cols, vals, m, n, r, comm="sparse", **kw)
    sm = ps.smeta
    print(f"s15 c={c}: gather_a={sm.gather} wA={sm.wg}/{m} "
          f"gather_b={sm.gather_b} wB={sm.wg_b}/{n}")
    dA = (c - 1) * (sm.wg - m) * rp if sm.gather else 0
    dB = (c - 1) * (sm.wg_b - n) * rp if sm.gather_b else 0
    cells = [
        ("sddmm", lambda pl: (s15.sddmm_s15, (g, pl, As, Bs), {}), dA + dB),
        ("spmma", lambda pl: (s15.spmma_s15, (g, pl, Bs), {}), dB),
        ("fusedmm none", lambda pl: (s15.fusedmm_s15, (g, pl, As, Bs),
                                     dict(elision="none")), dA + 2 * dB),
        ("fusedmm reuse", lambda pl: (s15.fusedmm_s15, (g, pl, As, Bs),
                                      dict(elision="reuse")), dA + dB),
        ("fusedmm fused", lambda pl: (s15.fusedmm_s15, (g, pl, As, Bs),
                                      dict(elision="fused")), dA + dB),
    ]
    for name, call, delta in cells:
        fn, args_s, kws = call(ps)
        _, args_d, _ = call(pd)
        eq(f"s15 c={c} {name}", fn(*args_d, **kws), fn(*args_s, **kws))
        report(f"s15 c={c} {name}", ww(fn, *args_s, **kws),
               ww(fn, *args_d, **kws) + delta)
    for el, alg in (("none", "s15_no_elision"),
                    ("reuse", "s15_replication_reuse"),
                    ("fused", "s15_local_fusion")):
        band(f"s15 c={c} fusedmm {el}",
             ww(s15.fusedmm_s15, g, ps, As, Bs, elision=el), alg, c)


# ---------------------------------------------------------------------------
# d25: A fiber-gathered (pruned), B Cannon-shifts (pruned)
# ---------------------------------------------------------------------------

def check_d25(c):
    g = make_grid25(c)
    G = g.G
    mA, nS, rW = m // (G * c), n // (G * c), r // G
    Ash = jax.device_put(jnp.asarray(A), g.sharding(("row", "fiber"), "col"))
    B_sk = d25.skew_b(g, B)
    pd = d25.plan_d25(g, rows, cols, vals, m, n, r, **kw)
    ps = d25.plan_d25(g, rows, cols, vals, m, n, r, comm="sparse", **kw)
    pdt = d25.plan_d25(g, rows, cols, vals, m, n, r, transpose=True, **kw)
    pst = d25.plan_d25(g, rows, cols, vals, m, n, r, transpose=True,
                       comm="sparse", **kw)
    sm, smt = ps.smeta, pst.smeta
    print(f"d25 c={c}: gather={sm.gather} wg={sm.wg}/{mA} "
          f"shift={sm.shift} ws={sm.ws} (nS={nS})")

    def dg(smx):
        return (c - 1) * (smx.wg - mA) * rW if smx.gather else 0

    def ds(smx):   # one B trip round
        return (sum(smx.ws) - (G - 1) * nS) * rW if smx.shift else 0

    def ds2(smx):  # replay round ("none"): dense replay rings G hops
        return (sum(smx.ws) - G * nS) * rW if smx.shift else 0

    cells = [
        ("sddmm", lambda pl: (d25.sddmm_d25, (g, pl, Ash, B_sk), {}),
         ps, dg(sm) + ds(sm)),
        ("spmma", lambda pl: (d25.spmma_d25, (g, pl, B_sk), {}),
         ps, ds(sm)),
        ("spmmb", lambda pl: (d25.spmmb_d25, (g, pl, Ash), {}),
         pst, dg(smt)),
        ("fusedmm none", lambda pl: (d25.fusedmm_d25, (g, pl, Ash, B_sk),
                                     dict(elision="none")),
         ps, dg(sm) + ds(sm) + ds2(sm)),
        ("fusedmm reuse", lambda pl: (d25.fusedmm_d25, (g, pl, Ash, B_sk),
                                      dict(elision="reuse")),
         pst, dg(smt) + ds(smt)),
        ("fusedmm fused", lambda pl: (d25.fusedmm_d25, (g, pl, Ash, B_sk),
                                      dict(elision="fused")),
         ps, dg(sm) + ds(sm)),
    ]
    dense_plan = {id(ps): pd, id(pst): pdt}
    for name, call, sp, delta in cells:
        fn, args_s, kws = call(sp)
        _, args_d, _ = call(dense_plan[id(sp)])
        eq(f"d25 {name}", fn(*args_d, **kws), fn(*args_s, **kws))
        report(f"d25 {name}", ww(fn, *args_s, **kws),
               ww(fn, *args_d, **kws) + delta)
    for el, alg in (("none", "d25_no_elision"),
                    ("reuse", "d25_replication_reuse"),
                    ("fused", "d25_local_fusion")):
        sp = pst if el == "reuse" else ps
        band(f"d25 fusedmm {el}",
             ww(d25.fusedmm_d25, g, sp, Ash, B_sk, elision=el), alg, c)


# ---------------------------------------------------------------------------
# s25: both dense chunks shift (pruned); output + fiber values dense
# ---------------------------------------------------------------------------

def check_s25(c):
    g = make_grid25(c)
    G = g.G
    mS, nS, rc = m // G, n // G, r // (G * c)
    A_sk = s25.skew_dense(g, A, along="row")
    B_sk = s25.skew_dense(g, B, along="col")
    pd = s25.plan_s25(g, rows, cols, vals, m, n, r, **kw)
    ps = s25.plan_s25(g, rows, cols, vals, m, n, r, comm="sparse", **kw)
    sm = ps.smeta
    print(f"s25 c={c}: a_sparse={sm.shift} wA={sm.ws}/{mS} "
          f"b_sparse={sm.shift_b} wB={sm.ws_b}/{nS}")
    dA = (G - 1) * (sm.ws[0] - mS) * rc if sm.shift else 0
    dB = (G - 1) * (sm.ws_b[0] - nS) * rc if sm.shift_b else 0
    # replay round ("none"): the dense replay rings G hops (restore hop)
    dB2 = ((G - 1) * sm.ws_b[0] - G * nS) * rc if sm.shift_b else 0
    cells = [
        ("sddmm", lambda pl: (s25.sddmm_s25, (g, pl, A_sk, B_sk), {}),
         dA + dB),
        ("spmma", lambda pl: (s25.spmma_s25, (g, pl, B_sk), {}), dB),
        ("fusedmm none", lambda pl: (s25.fusedmm_s25, (g, pl, A_sk, B_sk),
                                     dict(elision="none")), dA + dB + dB2),
        ("fusedmm reuse", lambda pl: (s25.fusedmm_s25, (g, pl, A_sk, B_sk),
                                      dict(elision="reuse")), dA + dB),
    ]
    for name, call, delta in cells:
        fn, args_s, kws = call(ps)
        _, args_d, _ = call(pd)
        eq(f"s25 {name}", fn(*args_d, **kws), fn(*args_s, **kws))
        report(f"s25 {name}", ww(fn, *args_s, **kws),
               ww(fn, *args_d, **kws) + delta)
    for el, alg in (("none", "s25_no_elision"),
                    ("reuse", "s25_replication_reuse")):
        band(f"s25 fusedmm {el}",
             ww(s25.fusedmm_s25, g, ps, A_sk, B_sk, elision=el), alg, c)


check_d15(2)
check_d15(4)   # the other crossover direction: gather prunes, shift doesn't
check_s15(2)
check_d25(2)
check_s25(2)


# ---------------------------------------------------------------------------
# power-law: sparse mode beats the dense Table-III optimum outright
# ---------------------------------------------------------------------------

prows, pcols, pvals, PX, PY = sparse.powerlaw_problem(9, r, edge_factor=8,
                                                      seed=1)
pm = pn = 1 << 9
assert costmodel.choose_comm(prows, pcols, pm, pn) == "sparse"
choice = costmodel.choose_algorithm(m=pm, n=pn, nnz=len(pvals), r=r, p=p)
prob_d = api.make_problem(prows, pcols, pvals, (pm, pn), r,
                          algorithm=choice.family, c=choice.c)
prob_s = api.make_problem(prows, pcols, pvals, (pm, pn), r,
                          algorithm=choice.family, c=choice.c,
                          comm="sparse")
el = prob_d.resolve_elision("auto")
out_d, R_d = prob_d.fusedmm(PX, PY, elision=el)
out_s, R_s = prob_s.fusedmm(PX, PY, elision=el)
np.testing.assert_array_equal(out_d, out_s)
np.testing.assert_array_equal(R_d.values(), R_s.values())
w_dense = wirewords(prob_d.lower_fusedmm(elision=el))
w_sparse = wirewords(prob_s.lower_fusedmm(elision=el))
print(f"power-law optimum {choice.family}/c={choice.c}/{el}: "
      f"dense={w_dense:.0f} sparse={w_sparse:.0f} "
      f"saving={1 - w_sparse / w_dense:.1%}")
assert w_sparse < w_dense, (w_sparse, w_dense)

print(f"{len(checks)} plan-exact cells at 1.00x")
print("ALL COMM SPARSE OK")
