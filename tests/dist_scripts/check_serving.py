"""Serving engine end-to-end on 8 devices under seeded synthetic traffic.

Deploys CF factors and a GAT layer into one Session pool on the 8-device
host mesh, replays seeded open-loop traffic through the continuous
batcher, and asserts every answer BITWISE against the numpy reference —
the data is integer-valued float32, so every accumulation is exact and
batching/re-meshing cannot hide behind tolerance.  Mid-stream, scripted
``DeviceLost`` faults (one during a score round, one during an
aggregation round) force the pool's elastic deployments to degrade the
mesh; the tick retries on the surviving devices and the answers stay
bitwise-correct, then steady-state traffic continues on the degraded
mesh with the Session re-warmed.

Prints ALL SERVING OK.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import numpy as np
import jax

from repro import serving
from repro.apps import als, gat
from repro.core import api
from repro.distributed import faults
from repro.serving import batcher

assert len(jax.devices()) == 8

rng = np.random.default_rng(0)


def int_mat(shape):
    return rng.integers(-3, 4, shape).astype(np.float32)


def int_graph(m, n, nnz, seed):
    r2 = np.random.default_rng(seed)
    key = np.unique(r2.integers(0, m * n, nnz))
    rows = (key // n).astype(np.int64)
    cols = (key % n).astype(np.int64)
    vals = (r2.integers(1, 4, len(key))
            * r2.choice([-1.0, 1.0], len(key))).astype(np.float32)
    return rows, cols, vals


m, n, r = 128, 96, 16
rows, cols, vals = int_graph(m, n, 2000, seed=1)
dense = np.zeros((m, n), np.float32)
dense[rows, cols] = vals
U, V = int_mat((m, r)), int_mat((n, r))

pool = serving.SessionPool(capacity=2)
dep = als.deploy_factors(pool, rows, cols, vals, (m, n), U, V)
eng = serving.ServingEngine(pool, max_batch=32)
assert dep.problem.p == 8
print(f"deployed on {dep.problem.alg.name} p={dep.problem.p} "
      f"(c={dep.problem.c})")


def check_ticket(t):
    req = t.request
    if req.kind == "score":
        ref = np.einsum("ij,ij->i", req.X[req.rows], req.Y[req.cols])
    else:
        d = dense if req.vals is None else np.zeros((m, n), np.float32)
        if req.vals is not None:
            d[rows, cols] = req.vals
        ref = d @ req.Y
    assert np.array_equal(t.result(), ref), \
        f"{req.kind} answer not bitwise vs reference"


# -- phase 1: seeded steady-state traffic, coalesced ticks -----------------
served = 0
for tick in range(3):
    tickets = []
    for _ in range(4):
        k = int(rng.integers(2, 9))
        tickets.append(als.predict_scores(
            eng, dep, rng.integers(0, m, k), rng.integers(0, n, k)))
    for _ in range(3):
        tickets.append(als.lookup_embeddings(
            eng, dep, int_mat((n, int(rng.integers(1, 5))))))
    rep = eng.tick()
    assert rep["requests"] == 7 and rep["rounds"] == 2, rep
    for t in tickets:
        check_ticket(t)
    served += len(tickets)
print(f"steady state: {served} requests bitwise ok "
      f"({eng.rounds} rounds for {served} requests)")
sess0 = dep.session.stats()
assert sess0["hits"] > 0, "steady-state ticks must hit the Session"

# -- phase 2: batched tick == solo per-request execution, bitwise ----------
tickets = []
for _ in range(5):
    k = int(rng.integers(2, 9))
    tickets.append(als.predict_scores(
        eng, dep, rng.integers(0, m, k), rng.integers(0, n, k)))
Xc = int_mat((m, r))
tickets.append(eng.submit_score(dep, [100, 101], [5, 6], Xc, "V"))
tickets.append(als.lookup_embeddings(eng, dep, int_mat((n, 3))))
eng.tick()
for t in tickets:
    ref = serving.Ticket(t.request, seq=-1)
    batcher.execute_solo(ref, use_session=False, use_elastic=False)
    assert np.array_equal(t.result(), ref.result()), \
        "batched != solo bitwise"
print("batched tick == solo per-request execution bitwise ok")

# -- phase 3: DeviceLost mid-stream, score round ---------------------------
plan = faults.FaultPlan.scripted(
    faults.FaultSpec(op="sddmm", kind="device_lost", rank=3, round=0))
with faults.inject(plan) as ctl:
    tickets = [als.predict_scores(eng, dep, rng.integers(0, m, 6),
                                  rng.integers(0, n, 6))
               for _ in range(4)]
    rep = eng.tick()
assert len(ctl.fired) == 1 and ctl.fired[0]["op"] == "sddmm"
assert dep.problem.p < 8, "deployment must have re-meshed"
rec = dep.elastic.recoveries[-1]
assert rec["remeshed_to_p"] == dep.problem.p
for t in tickets:
    check_ticket(t)
print(f"DeviceLost(rank=3) in score round: re-meshed to "
      f"{dep.problem.alg.name} p={dep.problem.p}, answers bitwise ok")

# -- phase 4: DeviceLost during an aggregation round -----------------------
p_before = dep.problem.p
plan = faults.FaultPlan.scripted(
    faults.FaultSpec(op="spmm", kind="device_lost", rank=1, round=0))
with faults.inject(plan) as ctl:
    tickets = [als.lookup_embeddings(eng, dep, int_mat((n, 2)))
               for _ in range(3)]
    eng.tick()
assert len(ctl.fired) == 1 and ctl.fired[0]["op"] == "spmm"
assert dep.problem.p < p_before
for t in tickets:
    check_ticket(t)
print(f"DeviceLost(rank=1) in aggregate round: re-meshed to "
      f"{dep.problem.alg.name} p={dep.problem.p}, answers bitwise ok")

# -- phase 5: steady state on the degraded mesh ----------------------------
for tick in range(2):
    tickets = [als.predict_scores(eng, dep, rng.integers(0, m, 5),
                                  rng.integers(0, n, 5))
               for _ in range(3)]
    eng.tick()
    for t in tickets:
        check_ticket(t)
sess1 = dep.session.stats()
assert sess1["hits"] > sess0["hits"], \
    "degraded-mesh ticks must re-warm and hit the Session"
print(f"post-remesh steady state ok (session hits "
      f"{sess0['hits']} -> {sess1['hits']})")

# -- phase 6: a second deployment (GAT) + pool churn under traffic ---------
n_g, d_g = 96, 8
H = int_mat((n_g, d_g))
gp = gat.init_gat_layer(jax.random.PRNGKey(2), d_g, d_g)
g_rows, g_cols, g_vals = gat.graph_coo(n_g, 6, seed=3)
dep_gat = gat.gat_deploy_layer(pool, g_rows, g_cols, n_g, H, gp)
assert pool.stats()["occupancy"] == 2
node_ids = np.array([5, 40, 77])
out = gat.gat_layer_served(eng, dep_gat, node_ids)
graphP = api.make_problem(g_rows, g_cols, g_vals, (n_g, n_g), d_g)
ref = gat.gat_layer_distributed(graphP, H, gp, n_heads=1)
assert np.array_equal(np.asarray(out), np.asarray(ref)[node_ids]), \
    "served GAT != distributed layer on queried rows"
print("GAT deployment served bitwise vs full distributed layer ok")

# capacity-2 pool: a third deployment evicts the LRU (the ALS one,
# which is idle), while the GAT deployment keeps serving
rows3, cols3, vals3 = int_graph(64, 64, 700, seed=4)
pool.deploy(rows3, cols3, vals3, (64, 64), 8)
stats = pool.stats()
assert stats["occupancy"] == 2 and stats["evictions"] == 1
assert dep.key not in pool.keys and dep_gat.key in pool.keys
out2 = gat.gat_layer_served(eng, dep_gat, node_ids)
assert np.array_equal(np.asarray(out2), np.asarray(out))
print(f"pool churn under traffic ok: {stats}")

# -- phase 7: deterministic open-loop replay reports latency ---------------
eng2 = serving.ServingEngine(pool, max_batch=8)


def submit_score(seed):
    def submit(engine, arrival):
        r2 = np.random.default_rng(seed)
        return engine.submit_score(
            dep_gat, r2.integers(0, n_g, 4), r2.integers(0, n_g, 4),
            "A", "B", arrival=arrival)
    return submit


trace = [(0.002 * i, submit_score(i)) for i in range(12)]
out = serving.replay_trace(eng2, trace)
assert out["served"] == 12 and out["p99"] >= out["p50"] > 0
print(f"replay: served={out['served']} p50={out['p50'] * 1e3:.2f}ms "
      f"p99={out['p99'] * 1e3:.2f}ms throughput={out['throughput']:.1f}/s")

print("ALL SERVING OK")
