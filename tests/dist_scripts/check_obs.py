"""Traced 8-device smoke with the cost-model drift gate (docs/observability.md).

Four guarantees:

1. **Drift gate** — one traced dense cell per executor family (sddmm,
   spmm, and the auto-resolved fusedmm elision, plain and +session):
   every round's measured/modeled wire-word ratio must land inside
   [0.99, 1.01].  The model is impl-exact, so the expected drift is
   exactly 1.0; the band only absorbs future backend-legalization noise.

2. **Span accounting** — per-event modeled words sum to the round's
   modeled total, spans align 1:1 with ``schedule_events``, and event
   spans tile the round span.

3. **Zero-cost parity** — the traced FusedMM result is bitwise-identical
   to the untraced call on the same mesh.

4. **Registry surface** — one smoke pass through the instrumented
   subsystems (executor rounds, Session, SessionPool/serving tick,
   ElasticProblem retry) populates the registry, and its snapshot
   JSON-round-trips exactly.

Writes TRACE_smoke.json + METRICS_smoke.json (the CI observability
artifacts; load the trace at ui.perfetto.dev) and prints ALL OBS OK.
"""
import json
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import numpy as np
import jax

from repro import obs, serving
from repro.apps import als
from repro.core import api, sparse
from repro.distributed import faults

assert len(jax.devices()) == 8

m = n = 64
r = 16
nnz_row = 4
DRIFT_BAND = (0.99, 1.01)

rng = np.random.default_rng(0)
rows, cols, _ = sparse.erdos_renyi(m, n, nnz_row, seed=0)
vals = rng.integers(1, 5, rows.shape[0]).astype(np.float32)
X = rng.integers(-3, 4, (m, r)).astype(np.float32)
Y = rng.integers(-3, 4, (n, r)).astype(np.float32)

reg = obs.MetricsRegistry()
tracer = obs.Tracer(registry=reg)

# --- 1+2. drift gate + span accounting: every family, dense comm ------------
for name in sorted(api.ALGORITHMS):
    prob = api.make_problem(rows, cols, vals, (m, n), r,
                            algorithm=name, c=2)
    el = prob.resolve_elision("auto")
    sess = api.Session()
    with obs.trace(tracer):
        prob.sddmm(X, Y)
        prob.spmm(Y)
        prob.fusedmm(X, Y, elision=el)
        prob.fusedmm(X, Y, elision=el, session=sess)
        prob.fusedmm(X, Y, elision=el, session=sess)   # cached round
    reg.gather("session", sess.stats(), family=name)
    for rnd in tracer.rounds[-5:]:
        tag = (f"{name}.{rnd.op}"
               + (f"[{rnd.elision}]" if rnd.op == "fusedmm" else "")
               + ("+sess" if rnd.session else ""))
        assert rnd.comm == "dense" and rnd.p == 8, tag
        events = prob.alg.schedule_events(prob, rnd.op, rnd.elision)
        assert [(e.point, e.phase) for e in rnd.events] == events, tag
        assert rnd.modeled_words is not None, tag
        ev_sum = sum(e.words for e in rnd.events if e.words is not None)
        assert abs(ev_sum - rnd.modeled_words) < 1e-6, (
            f"{tag}: event words {ev_sum} != round model "
            f"{rnd.modeled_words}")
        assert rnd.measured_words is not None, tag
        assert rnd.drift is not None, tag
        assert DRIFT_BAND[0] <= rnd.drift <= DRIFT_BAND[1], (
            f"{tag}: cost-model drift {rnd.drift:.6f} outside "
            f"{DRIFT_BAND} (modeled={rnd.modeled_words:.0f} "
            f"measured={rnd.measured_words['total']:.0f})")
        print(f"{tag:28s} modeled={rnd.modeled_words:8.0f} "
              f"measured={rnd.measured_words['total']:8.0f} "
              f"drift={rnd.drift:.4f}")

# --- 3. traced result is bitwise-identical to the untraced call -------------
prob = api.make_problem(rows, cols, vals, (m, n), r, algorithm="d15", c=2)
base = np.asarray(prob.fusedmm(X, Y, elision="fused")[0])
with obs.trace(tracer):
    got = np.asarray(prob.fusedmm(X, Y, elision="fused")[0])
assert np.array_equal(base, got), "tracing changed the FusedMM result"
print("traced-vs-untraced fusedmm: bitwise identical")

# --- 4a. elastic-retry metrics under an injected transient fault ------------
plan = faults.FaultPlan.scripted(
    faults.FaultSpec(op="sddmm", point="*", rank=1, phase=-1, round=0))
with obs.collect(reg), faults.inject(plan):
    ep = api.ElasticProblem(prob)
    ep.sddmm(X, Y)
assert reg.value("elastic.retries", op="sddmm") == 1
assert reg.value("elastic.faults", op="sddmm",
                 kind="TransientFault") == 1
print("elastic retry metrics ok")

# --- 4b. serving tick latency + pool/session series -------------------------
U = rng.standard_normal((m, r)).astype(np.float32)
V = rng.standard_normal((n, r)).astype(np.float32)
pool = serving.SessionPool(capacity=2)
dep = als.deploy_factors(pool, rows, cols, vals, (m, n), U, V)
eng = serving.ServingEngine(pool, max_batch=8)
with obs.collect(reg):
    for _ in range(2):
        eng.submit_score(dep, rng.integers(0, m, 8),
                         rng.integers(0, n, 8), "U", "V")
    eng.run_until_drained()
assert (reg.histogram("serving.tick_seconds") or {}).get("count"), \
    "serving tick latency series missing"
assert reg.value("serving.pool.hits") is not None
assert reg.value("serving.pool.session.hits") is not None
print("serving metrics ok")

# --- registry snapshot round-trips; required series present -----------------
for series in ("session.hits", "serving.pool.hits", "elastic.retries",
               "costmodel.drift"):
    assert any(s["name"] == series for s in reg.series()), \
        f"registry missing {series}"
snap = reg.snapshot()
assert obs.MetricsRegistry.from_snapshot(
    json.loads(json.dumps(snap))).snapshot() == snap, \
    "metrics snapshot does not round-trip"

# --- chrome-trace artifact: one track per rank, events nested ---------------
ct = obs.chrome_trace(tracer)
evs = ct["traceEvents"]
assert evs, "empty trace"
tids = {e["tid"] for e in evs if e.get("ph") == "X"}
assert tids == set(range(8)), f"expected one track per rank, got {tids}"
threads = [e for e in evs if e.get("ph") == "M"
           and e["name"] == "thread_name"]
assert len(threads) == 8
paths = obs.write_artifacts(".", "smoke", tracer=tracer, registry=reg)
json.load(open(paths["trace"]))          # artifacts must be valid JSON
json.load(open(paths["metrics"]))
print("wrote", paths["trace"], "and", paths["metrics"],
      f"({len(evs)} trace events, {len(reg.series())} metric series)")
print(obs.round_summary(tracer))
print("ALL OBS OK")
