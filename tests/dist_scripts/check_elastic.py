"""Elastic scaling: train on 8 devices, checkpoint, resume on 4 devices.

Proves the shardings are re-derivable for a different mesh shape and the
checkpoint is mesh-independent — the slice-resize flow a 1000-node job
uses after losing a slice.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile

import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ParallelConfig, TrainConfig
from repro.distributed import sharding as shmod
from repro.configs import llama32_1b
from repro.distributed.elastic import remesh
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training import data as data_mod
from repro.training import optimizer as opt
from repro.training import train_step as ts

assert len(jax.devices()) == 8
cfg = llama32_1b.reduced()
pcfg = ParallelConfig(compute_dtype="float32")
tcfg = TrainConfig(seq_len=64, global_batch=8, lr=1e-3, steps=10)
pipe = data_mod.SyntheticLM(cfg.vocab, 64, 8, seed=0)

def shardings(mesh, params):
    pspec = M.param_specs(cfg, pcfg, params)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                       is_leaf=lambda x: isinstance(x, P))
    osh = {"mu": psh, "nu": psh, "step": NamedSharding(mesh, P())}
    return psh, osh

# --- phase 1: 8 devices (4 data x 2 model)
mesh8 = remesh(8, model_parallel=2)
shmod.set_mesh(mesh8)
params = M.init_params(cfg, jax.random.PRNGKey(0))
state = opt.init_opt_state(params)
psh8, osh8 = shardings(mesh8, params)
params = jax.device_put(params, psh8)
state = jax.device_put(state, osh8)
step_fn, _, jit_step = ts.make_train_step(cfg, pcfg, tcfg, mesh8)
fn8 = jit_step(psh8, osh8, None)
for i in range(3):
    batch = jax.tree.map(jnp.asarray, pipe.batch(i))
    params, state, m = fn8(params, state, batch)
loss8 = float(m["loss"])
d = tempfile.mkdtemp()
ckpt.save(d, 3, {"params": jax.device_get(params),
                 "opt": jax.device_get(state)})
print("phase1 done on 8 devices, loss", loss8)

# --- phase 2: resume on 4 devices (2 data x 2 model) — simulated shrink
mesh4 = remesh(4, model_parallel=2)
shmod.set_mesh(mesh4)
tree = ckpt.restore(d, 3, {"params": jax.device_get(params),
                           "opt": jax.device_get(state)})
psh4, osh4 = shardings(mesh4, tree["params"])
params4 = jax.device_put(tree["params"], psh4)
state4 = jax.device_put(tree["opt"], osh4)
fn4 = ts.make_train_step(cfg, pcfg, tcfg, mesh4)[2](psh4, osh4, None)
for i in range(3, 6):
    batch = jax.tree.map(jnp.asarray, pipe.batch(i))
    params4, state4, m4 = fn4(params4, state4, batch)
print("phase2 done on 4 devices, loss", float(m4["loss"]))
assert np.isfinite(float(m4["loss"]))
assert int(state4["step"]) == 6
print("ELASTIC OK")
