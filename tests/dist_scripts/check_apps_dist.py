"""Distributed ALS + GAT end-to-end on 8 devices (paper §VI-E, Fig. 9).

ALS: the batched-CG solver with every matvec a distributed FusedMM and
Session-cached replication must converge, and the Session must change
nothing numerically (bitwise identity vs a session-free run).
GAT: the distributed layer (score SDDMM -> row softmax on completed
rows -> aggregation SpMM) must match the single-device layer.
Both run on multiple registered algorithms through the SAME app code —
no per-family branching anywhere in the applications.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax
import jax.numpy as jnp

from repro.apps import als, gat
from repro.core import api

assert len(jax.devices()) == 8

# --- ALS -------------------------------------------------------------------
for algorithm in ("d15", "s15", "auto"):
    A, B, hist = als.run_als_distributed(
        m=256, n=256, nnz_per_row=6, r=16, rounds=2, cg_iters=8, seed=0,
        algorithm=algorithm, verbose=False)
    assert hist[-1] < 0.3 * hist[0], (algorithm, hist)
    print(f"als[{algorithm}] loss {hist[0]:.1f} -> {hist[-1]:.3f} ok")

# Session caching changes nothing: one CG solve with and without, at the
# same pinned elision (the cache elides the gather, not the arithmetic)
dp = als.make_dist_problem(256, 256, 6, 16, seed=1, algorithm="d15", c=2)
rng = np.random.default_rng(1)
B0 = (rng.standard_normal((256, 16)) * 0.1).astype(np.float32)
rhs = dp.ratings.spmm(B0)
X_plain = als.dist_cg_solve(dp.mask, B0, rhs, dp.reg, iters=6,
                            session=None, elision="reuse")
sess = api.Session()
X_sess = als.dist_cg_solve(dp.mask, B0, rhs, dp.reg, iters=6,
                           session=sess, elision="reuse")
np.testing.assert_array_equal(X_plain, X_sess)
# with "reuse" the gathered operand is the stationary B: ONE cache entry
# serves every CG matvec
assert len(sess) == 1, len(sess)
# session-aware auto resolution ranks by steady-state words: on this
# grid (p=8, c=2) the fused cell's halved shift words (1/c) undercut
# even the cache-elided reuse gather (2/c), so auto stays on "fused";
# the flip to "reuse" happens at larger c — docs/choosing.md, asserted
# at the cost-model level in tests/test_costmodel.py
assert dp.mask.resolve_elision("auto", sess) == "fused"
assert dp.mask.resolve_elision("auto") == "fused"
print("als session bitwise ok (1 cached stationary operand, "
      "hit by every matvec)")

# --- GAT -------------------------------------------------------------------
n, d, seed = 256, 16, 3
S = gat.make_graph(n, 4, seed=seed, row_tile=32, nz_block=32)
H = np.asarray(np.random.default_rng(seed).standard_normal((n, d)),
               np.float32)
params = [gat.init_gat_layer(jax.random.PRNGKey(i), d, d)
          for i in range(2)]
want1 = np.asarray(gat.gat_layer(S, jnp.asarray(H), params[0]))
want2h = np.asarray(gat.gat_layer(S, jnp.asarray(H), params[0],
                                  n_heads=2))
want_fwd = np.asarray(gat.gat_forward(S, jnp.asarray(H), params))

for algorithm in ("d15", "s15", "d25", "s25"):
    gp = gat.make_dist_graph(n, 4, d, algorithm=algorithm, seed=seed)
    got = np.asarray(gat.gat_layer_distributed(gp, H, params[0]))
    np.testing.assert_allclose(got, want1, rtol=5e-4, atol=5e-4)
    got2 = np.asarray(gat.gat_layer_distributed(gp, H, params[0],
                                                n_heads=2))
    np.testing.assert_allclose(got2, want2h, rtol=5e-4, atol=5e-4)
    print(f"gat[{algorithm}] c={gp.c} layer + 2-head ok")

gp = gat.make_dist_graph(n, 4, d, algorithm="auto", seed=seed)
got = np.asarray(gat.gat_forward_distributed(gp, H, params))
np.testing.assert_allclose(got, want_fwd, rtol=2e-3, atol=2e-3)
print(f"gat[auto->{gp.alg.name}] 2-layer forward ok")

print("ALL APPS DIST OK")
