"""Bitwise parity of every registry elision cell vs the unfused sequence.

For each registered family, composes the unfused two-launch sequence
through the api — R = sddmm(X, Y), then out = S.with_values(R).spmm(Y) —
and compares every registry-declared fusedmm elision cell against it on
8 devices.

The communication-eliding cells added for the completed matrix (s15
"fused", d25 "fused", s25 "reuse") replay locally cached structure /
operand chunks instead of re-communicating them, so every local kernel
sees bit-identical operands in the same order as the unfused sequence:
their outputs must be BITWISE identical — any drift means the elided
schedule changed the math.  Cells that legitimately reassociate the
output accumulation (the FusedMMB "reuse" form on the transpose pack,
and d15's genuinely fused local kernel) are held to allclose instead.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax

from repro.core import api, sparse

assert len(jax.devices()) == 8

m = n = 256
r = 64
nnz_row = 5
rows, cols, vals, X, Y = sparse.random_problem(m, n, r, nnz_row, seed=0)

# cells that run the exact unfused kernel sequence (communication elided,
# arithmetic untouched) -> bitwise; the rest reassociate -> allclose
BITWISE = {("s15", "none"), ("s15", "reuse"), ("s15", "fused"),
           ("d25", "none"), ("d25", "fused"),
           ("s25", "none"), ("s25", "reuse"),
           ("d15", "none")}

for name, c in (("d15", 2), ("s15", 2), ("d25", 2), ("s25", 2)):
    prob = api.make_problem(rows, cols, vals, (m, n), r,
                            algorithm=name, c=c)
    tag = f"{name} c={c}"

    # the unfused two-launch sequence through the same executors
    R_seq = prob.sddmm(X, Y)
    out_seq = prob.with_values(R_seq.values()).spmm(Y)

    for el in prob.alg.elisions:
        out, R = prob.fusedmm(X, Y, elision=el)
        if (name, el) in BITWISE:
            np.testing.assert_array_equal(
                out, out_seq, err_msg=f"{tag} {el}: out not bitwise")
            np.testing.assert_array_equal(
                R.values(), R_seq.values(),
                err_msg=f"{tag} {el}: R not bitwise")
            print(tag, f"fusedmm {el} == sddmm;spmm BITWISE")
        else:
            np.testing.assert_allclose(out, out_seq, rtol=2e-3, atol=2e-3,
                                       err_msg=f"{tag} {el}")
            np.testing.assert_allclose(R.values(), R_seq.values(),
                                       rtol=2e-3, atol=2e-3,
                                       err_msg=f"{tag} {el}")
            print(tag, f"fusedmm {el} == sddmm;spmm (allclose; "
                       f"reassociating cell)")

print("ALL ELISION PARITY OK")
