"""Recovery parity under injected faults on 8 devices (docs/robustness.md).

Three guarantees, all asserted bitwise (np.array_equal, no tolerance):

1. **Transient recovery parity** — every (family x op x elision x
   session) cell: a scripted ``TransientFault`` mid-schedule, recovered
   by :class:`api.ElasticProblem` (Session invalidated, round retried),
   yields results bitwise-identical to the fault-free call on the same
   mesh.

2. **Replayability** — ``FaultPlan.random(seed)`` scripts identical
   coordinates for identical seeds, and two injected runs of the same
   plan against the same call sequence produce identical fired logs.

3. **DeviceLost re-mesh parity** — a mid-training ``DeviceLost`` in
   ``train_embedding_distributed`` (8 -> degraded 4-device mesh,
   cost-model re-dispatch) finishes with factors bitwise-identical to a
   fault-free run that checkpointed before the fault and resumed from
   that checkpoint onto the same 4-device mesh: recovery produces
   exactly what a clean restart on the degraded mesh produces.

Writes FAULTS_summary.json (the CI fault-injection artifact) and prints
ALL FAULTS OK.
"""
import json
import os
import shutil
import tempfile

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import numpy as np
import jax

from repro.apps import als
from repro.core import api, sparse
from repro.distributed import faults

assert len(jax.devices()) == 8

m = n = 64
r = 16
nnz_row = 4

# integer-valued float32 data: every accumulation is exact, so recovered
# results can be compared bitwise even across meshes
rng = np.random.default_rng(0)
rows, cols, _ = sparse.erdos_renyi(m, n, nnz_row, seed=0)
vals = rng.integers(1, 5, rows.shape[0]).astype(np.float32)
X = rng.integers(-3, 4, (m, r)).astype(np.float32)
Y = rng.integers(-3, 4, (n, r)).astype(np.float32)

summary = {"transient_cells": [], "replay": {}, "device_lost": {}}

# --- 1. transient recovery parity: family x op x elision x session ---------
CASES = [("d15", 2), ("s15", 2), ("d25", 2), ("s25", 2)]
for name, c in CASES:
    prob = api.make_problem(rows, cols, vals, (m, n), r,
                            algorithm=name, c=c)
    ops = [("sddmm", None, lambda p: np.asarray(p.sddmm(X, Y).values())),
           ("spmm", None, lambda p: np.asarray(p.spmm(Y))),
           ("spmm_t", None, lambda p: np.asarray(p.spmm_t(
               np.ones((m, r), np.float32))))]
    for el in prob.alg.elisions:
        ops.append(("fusedmm", el,
                    lambda p, el=el: np.asarray(
                        p.fusedmm(X, Y, elision=el)[0])))
    for op, el, call in ops:
        base = call(prob)
        for use_session in (False, True):
            session = api.Session() if use_session else None
            if session is not None:
                call(api.ElasticProblem(prob, session=session))  # warm
            plan = faults.FaultPlan.scripted(
                faults.FaultSpec(op=op, point="*", rank=1, phase=-1,
                                 round=0))
            with faults.inject(plan) as ctl:
                ep = api.ElasticProblem(prob, session=session)
                got = call(ep)
            tag = (f"{name} {op}" + (f"[{el}]" if el else "")
                   + (" +session" if use_session else ""))
            assert len(ctl.fired) == 1, f"{tag}: fault did not fire"
            assert len(ep.recoveries) == 1, f"{tag}: no recovery recorded"
            assert np.array_equal(got, base), f"{tag}: parity broken"
            summary["transient_cells"].append(
                dict(family=name, op=op, elision=el,
                     session=use_session, fired=ctl.fired,
                     recovered=True, bitwise=True))
            print(tag, "ok")

# --- 2. seeded-plan replayability ------------------------------------------
planA = faults.FaultPlan.random(7, n_faults=3, p=8, max_round=2)
planB = faults.FaultPlan.random(7, n_faults=3, p=8, max_round=2)
assert planA.specs == planB.specs, "random plans not replayable"
prob = api.make_problem(rows, cols, vals, (m, n), r, algorithm="d15", c=2)
logs = []
for plan in (planA, planB):
    with faults.inject(plan) as ctl:
        ep = api.ElasticProblem(prob, policy=api.RetryPolicy(max_retries=4))
        for _ in range(2):
            out = np.asarray(ep.sddmm(X, Y).values())
            ep.spmm(Y)
            ep.fusedmm(X, Y)
    assert np.array_equal(out, np.asarray(prob.sddmm(X, Y).values()))
    logs.append(ctl.summary())
assert logs[0]["fired"] == logs[1]["fired"], "fired logs not replayable"
summary["replay"] = dict(specs=len(planA), fired=logs[0]["fired"])
print("replayability ok:", len(logs[0]["fired"]), "faults replayed")

# --- 3. DeviceLost -> 8->4 re-mesh vs checkpoint-resume reference ----------
tmp = tempfile.mkdtemp()
common = dict(m=m, n=n, nnz_per_row=nnz_row, r=8, lr=0.05, seed=3,
              reg=0.0, verbose=False)
try:
    # base: 3 fault-free steps on 8 devices, checkpoint at step 3
    dirA = os.path.join(tmp, "A")
    als.train_embedding_distributed(steps=3, ckpt_dir=dirA, ckpt_every=3,
                                    **common)
    # reference: resume that checkpoint onto a 4-device mesh, fault-free
    dirB = os.path.join(tmp, "B")
    shutil.copytree(dirA, dirB)
    X_ref, Y_ref, h_ref = als.train_embedding_distributed(
        steps=6, ckpt_dir=dirB, ckpt_every=3,
        devices=jax.devices()[:4], **common)
    # recovered: full 6-step run on 8 devices, rank 7 dies at the step-3
    # forward; the trainer degrades onto the same 4-device mesh mid-run
    dirC = os.path.join(tmp, "C")
    plan = faults.FaultPlan.scripted(
        faults.FaultSpec(op="sddmm", point="*", rank=7, phase=-1,
                         round=3, kind="device_lost"))
    with faults.inject(plan) as ctl:
        X_rec, Y_rec, h_rec = als.train_embedding_distributed(
            steps=6, ckpt_dir=dirC, ckpt_every=3, **common)
    assert len(ctl.fired) == 1 and ctl.fired[0]["rank"] == 7
    assert np.array_equal(np.asarray(X_rec), np.asarray(X_ref)), \
        "re-mesh parity broken: recovered X != checkpoint-resumed X"
    assert np.array_equal(np.asarray(Y_rec), np.asarray(Y_ref))
    assert h_rec[3:] == h_ref, "post-fault losses diverge from reference"
    # the recovered run's later checkpoints record the degraded mesh
    from repro.training import checkpoint
    meta = checkpoint.load_manifest(dirC, 6)["meta"]
    assert meta["p"] == 4, f"checkpoint meta still on p={meta['p']}"
    summary["device_lost"] = dict(fired=ctl.fired, remeshed_to_p=meta["p"],
                                  family_after=meta["family"],
                                  bitwise=True)
    print(f"device-lost re-mesh ok: 8 -> {meta['p']} "
          f"({meta['family']}), bitwise parity with resume")
finally:
    shutil.rmtree(tmp, ignore_errors=True)

with open("FAULTS_summary.json", "w") as f:
    json.dump(summary, f, indent=1)
print("wrote FAULTS_summary.json:",
      len(summary["transient_cells"]), "transient cells")
print("ALL FAULTS OK")
