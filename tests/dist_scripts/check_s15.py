"""Correctness of 1.5D sparse-shifting algorithms on 8 devices vs oracle."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax, jax.numpy as jnp

from repro.core import sparse
from repro.core.grid import make_grid15
from repro.core import s15

assert len(jax.devices()) == 8

def run(c, m=256, n=256, r=64, nnz_row=5, seed=0):
    grid = make_grid15(c)
    rows, cols, vals, A, B = sparse.random_problem(m, n, r, nnz_row,
                                                   seed=seed)
    A, B = jnp.asarray(A), jnp.asarray(B)
    Sd = np.zeros((m, n), np.float32); Sd[rows, cols] = vals
    Ash = jax.device_put(A, grid.sharding(None, ("layer", "fiber")))
    Bsh = jax.device_put(B, grid.sharding(None, ("layer", "fiber")))
    plan = s15.plan_s15(grid, rows, cols, vals, m, n, r, row_tile=32, nz_block=32)

    # SDDMM
    rv = s15.sddmm_s15(grid, plan, Ash, Bsh)
    got = plan.meta.block_meta.to_dense(plan.rows_local, plan.cols, np.asarray(rv), plan.tile_base)
    wantR = Sd * (np.asarray(A) @ np.asarray(B).T)
    np.testing.assert_allclose(got, wantR, rtol=2e-4, atol=2e-4)
    print(f"c={c} sddmm ok")

    # SpMMA
    slabs = s15.spmma_s15(grid, plan, Bsh)
    gotA = s15.assemble_spmm_out(grid, plan, slabs)
    np.testing.assert_allclose(gotA, Sd @ np.asarray(B), rtol=2e-4, atol=2e-4)
    print(f"c={c} spmma ok")

    # FusedMM (all three cells must agree with the oracle; the
    # one-structure-pass "fused" cell is bitwise-identical to "reuse" —
    # same kernel sequence, structure replayed instead of re-shifted)
    got_by_el = {}
    for el in ("reuse", "none", "fused"):
        slabs, rvals = s15.fusedmm_s15(grid, plan, Ash, Bsh, elision=el)
        gotF = s15.assemble_spmm_out(grid, plan, slabs)
        np.testing.assert_allclose(gotF, wantR @ np.asarray(B), rtol=2e-3, atol=2e-3)
        got_by_el[el] = (np.asarray(slabs), np.asarray(rvals))
        print(f"c={c} fusedmm {el} ok")
    np.testing.assert_array_equal(got_by_el["fused"][0], got_by_el["reuse"][0])
    np.testing.assert_array_equal(got_by_el["fused"][1], got_by_el["reuse"][1])
    print(f"c={c} fusedmm fused bitwise == reuse")

for c in (1, 2, 4, 8):
    run(c)
print("ALL S15 OK")
