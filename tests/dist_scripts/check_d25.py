"""Correctness of 2.5D dense-replicating algorithms on 8 devices vs oracle."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax, jax.numpy as jnp

from repro.core import sparse
from repro.core.grid import make_grid25
from repro.core import d25

assert len(jax.devices()) == 8

def run(c, ndev, m=256, n=256, r=64, nnz_row=5, seed=0):
    grid = make_grid25(c, devices=jax.devices()[:ndev])
    rows, cols, vals, A, B = sparse.random_problem(m, n, r, nnz_row,
                                                   seed=seed)
    Sd = np.zeros((m, n), np.float32); Sd[rows, cols] = vals
    Ash = jax.device_put(jnp.asarray(A), grid.sharding(("row", "fiber"), "col"))
    B_sk = d25.skew_b(grid, B)
    plan = d25.plan_d25(grid, rows, cols, vals, m, n, r, row_tile=32, nz_block=32)
    plant = d25.plan_d25(grid, rows, cols, vals, m, n, r, transpose=True, row_tile=32, nz_block=32)
    tag = f"G={grid.G},c={c}"

    wantR = Sd * (A @ B.T)

    rv = d25.sddmm_d25(grid, plan, Ash, B_sk)
    got = plan.meta.block_meta.to_dense(plan.rows_local, plan.cols, np.asarray(rv), plan.tile_base)
    np.testing.assert_allclose(got, wantR, rtol=2e-4, atol=2e-4)
    print(tag, "sddmm ok")

    gotA = np.asarray(d25.spmma_d25(grid, plan, B_sk))
    np.testing.assert_allclose(gotA, Sd @ B, rtol=2e-4, atol=2e-4)
    print(tag, "spmma ok")

    out, rvals = d25.fusedmm_d25(grid, plan, Ash, B_sk, elision="none")
    np.testing.assert_allclose(np.asarray(out), wantR @ B, rtol=2e-3, atol=2e-3)
    print(tag, "fusedmm none ok")

    # one-structure-pass cell: bitwise-identical to the unfused sequence
    outF, rvalsF = d25.fusedmm_d25(grid, plan, Ash, B_sk, elision="fused")
    np.testing.assert_array_equal(np.asarray(outF), np.asarray(out))
    np.testing.assert_array_equal(np.asarray(rvalsF), np.asarray(rvals))
    print(tag, "fusedmm fused ok (bitwise == none)")

    outS, rvals = d25.fusedmm_d25(grid, plant, Ash, B_sk, elision="reuse")
    gotB = d25.unskew_out(grid, plant, outS)
    np.testing.assert_allclose(gotB, wantR.T @ A, rtol=2e-3, atol=2e-3)
    print(tag, "fusedmm reuse ok")

run(c=2, ndev=8)   # 2x2x2
run(c=1, ndev=4)   # 2x2x1 (pure 2D Cannon)
run(c=8, ndev=8)   # 1x1x8 (degenerate fully-replicated)
run(c=2, ndev=2)   # 1x1x2
print("ALL D25 OK")
