"""Elastic re-mesh of a DistProblem: 8 -> 4 devices mid-run, bitwise.

Shrinks a live problem onto half the mesh via ``DistProblem.replan`` and
``api.degrade`` and asserts SDDMM / SpMM / SpMM^T / FusedMM outputs are
**bitwise identical** before and after — possible because the test data
is integer-valued float32, so every accumulation is exact and the
summation-order changes of a different p cannot perturb the results
(docs/robustness.md).  Also asserts the failure mode: re-planning onto a
device count no family's divisibility constraints admit raises
``ValueError`` naming the constraint trail, never a silent wrong answer.

Prints ALL REMESH OK.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import numpy as np
import jax

from repro.core import api, sparse

assert len(jax.devices()) == 8

m = n = 64
r = 16
rng = np.random.default_rng(1)
rows, cols, _ = sparse.erdos_renyi(m, n, 4, seed=1)
vals = rng.integers(1, 5, rows.shape[0]).astype(np.float32)
X = rng.integers(-3, 4, (m, r)).astype(np.float32)
Y = rng.integers(-3, 4, (n, r)).astype(np.float32)

prob8 = api.make_problem(rows, cols, vals, (m, n), r, algorithm="auto")
assert prob8.p == 8
base = dict(sddmm=np.asarray(prob8.sddmm(X, Y).values()),
            spmm=np.asarray(prob8.spmm(Y)),
            spmm_t=np.asarray(prob8.spmm_t(np.ones((m, r), np.float32))),
            fusedmm=np.asarray(prob8.fusedmm(X, Y)[0]))
print(f"baseline on p=8 ({prob8.alg.name}) ok")

# -- mid-run shrink: same COO, half the devices, cost-model re-dispatch ----
for label, prob4 in [
        ("replan", prob8.replan(devices=jax.devices()[:4])),
        ("degrade(lost_rank=7)", api.degrade(prob8, lost_rank=7))]:
    assert prob4.p == 4, f"{label}: expected p=4, got {prob4.p}"
    assert np.array_equal(np.asarray(prob4.sddmm(X, Y).values()),
                          base["sddmm"]), f"{label}: sddmm parity"
    assert np.array_equal(np.asarray(prob4.spmm(Y)),
                          base["spmm"]), f"{label}: spmm parity"
    assert np.array_equal(
        np.asarray(prob4.spmm_t(np.ones((m, r), np.float32))),
        base["spmm_t"]), f"{label}: spmm_t parity"
    assert np.array_equal(np.asarray(prob4.fusedmm(X, Y)[0]),
                          base["fusedmm"]), f"{label}: fusedmm parity"
    print(f"{label} -> p=4 ({prob4.alg.name}): "
          "sddmm/spmm/spmm_t/fusedmm bitwise ok")

# the degraded problem's checkpoint metadata rebuilds the same plan
meta = api.degrade(prob8, lost_rank=7).meta_dict()
re = api.problem_from_meta(meta, rows, cols, vals,
                           devices=jax.devices()[:4])
assert (re.alg.name, re.p, re.c) == (meta["family"], 4, meta["c"])
assert np.array_equal(np.asarray(re.sddmm(X, Y).values()), base["sddmm"])
print("meta round-trip onto degraded mesh ok")

# -- non-divisible device counts fail loudly -------------------------------
try:
    prob8.replan(devices=jax.devices()[:7])
except ValueError as e:
    assert "7" in str(e), f"error does not name the device count: {e}"
    print("non-divisible p=7 rejected:", str(e).splitlines()[0][:70])
else:
    raise AssertionError("replan onto 7 devices must raise ValueError")

try:
    api.degrade(prob8, lost_rank=99)
except ValueError as e:
    print("bad lost_rank rejected:", str(e)[:60])
else:
    raise AssertionError("degrade with rank outside mesh must raise")

print("ALL REMESH OK")
