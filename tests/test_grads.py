"""Distributed autodiff: gradient parity vs jax.grad on the dense
reference, for every registry (family, elision) cell (single device).

The 8-device versions (plus measured backward wire words vs the
extended cost model) live in tests/dist_scripts/check_grads.py and
check_grad_costs.py (slow tier); here every cell degenerates onto a
1-device grid, which exercises the full custom_vjp -> pure_callback ->
executor -> dual-primitive path cheaply on every PR.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import api, costmodel, grads, sparse


def _dev1():
    return jax.devices()[:1]


def _data(m=64, n=64, r=8, k=4, seed=0):
    rows, cols, vals, X, Y = sparse.random_problem(m, n, r, k, seed=seed)
    Sd = np.zeros((m, n), np.float32)
    Sd[rows, cols] = vals
    return rows, cols, vals, X, Y, Sd


def _make(rows, cols, vals, shape, r, **kw):
    return api.make_problem(rows, cols, vals, shape, r, devices=_dev1(),
                            **kw)


ELISION_CELLS = sorted((name, el) for name in costmodel.FAMILIES
                       for el in api.ALGORITHMS[name].elisions)


@pytest.mark.parametrize("name,el", ELISION_CELLS)
def test_fusedmm_grad_matches_dense(name, el):
    """jax.grad through the distributed FusedMM == jax.grad of the dense
    formula, per registry cell — the backward (the SAME cell + two
    transpose-SpMMs) must be a faithful VJP."""
    rows, cols, vals, X, Y, Sd = _data()
    prob = _make(rows, cols, vals, Sd.shape, X.shape[1], algorithm=name)
    W = np.random.default_rng(9).standard_normal(
        (Sd.shape[0], X.shape[1])).astype(np.float32)
    Sdj, Wj = jnp.asarray(Sd), jnp.asarray(W)

    def dist_loss(X, Y):
        return jnp.sum(grads.fusedmm(prob, X, Y, elision=el) * Wj)

    def dense_loss(X, Y):
        return jnp.sum(((Sdj * (X @ Y.T)) @ Y) * Wj)

    Xj, Yj = jnp.asarray(X), jnp.asarray(Y)
    np.testing.assert_allclose(dist_loss(Xj, Yj), dense_loss(Xj, Yj),
                               rtol=2e-3, atol=2e-3)
    gx, gy = jax.grad(dist_loss, argnums=(0, 1))(Xj, Yj)
    wx, wy = jax.grad(dense_loss, argnums=(0, 1))(Xj, Yj)
    np.testing.assert_allclose(gx, wx, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(gy, wy, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", sorted(costmodel.FAMILIES))
def test_sddmm_grad_matches_dense(name):
    rows, cols, vals, X, Y, Sd = _data(seed=1)
    prob = _make(rows, cols, vals, Sd.shape, X.shape[1], algorithm=name)
    w = np.random.default_rng(3).standard_normal(len(vals)).astype(
        np.float32)
    Sdj, wj = jnp.asarray(Sd), jnp.asarray(w)

    def dist_loss(X, Y):
        return jnp.sum(grads.sddmm(prob, X, Y) * wj)

    def dense_loss(X, Y):
        return jnp.sum((Sdj * (X @ Y.T))[rows, cols] * wj)

    Xj, Yj = jnp.asarray(X), jnp.asarray(Y)
    gx, gy = jax.grad(dist_loss, argnums=(0, 1))(Xj, Yj)
    wx, wy = jax.grad(dense_loss, argnums=(0, 1))(Xj, Yj)
    np.testing.assert_allclose(gx, wx, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(gy, wy, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", sorted(costmodel.FAMILIES))
def test_spmm_vals_grad_matches_dense(name):
    """The sample values are a first-class differentiable input — the
    vals-grad is the dual SDDMM (what GAT's attention training needs)."""
    rows, cols, vals, X, Y, Sd = _data(seed=2)
    m, n = Sd.shape
    prob = _make(rows, cols, vals, Sd.shape, X.shape[1], algorithm=name)
    W = np.random.default_rng(4).standard_normal(
        (m, X.shape[1])).astype(np.float32)
    Wj = jnp.asarray(W)

    def dist_loss(v, Y):
        return jnp.sum(grads.spmm(prob, v, Y) * Wj)

    def dense_loss(v, Y):
        S2 = jnp.zeros((m, n)).at[rows, cols].set(v)
        return jnp.sum((S2 @ Y) * Wj)

    vj, Yj = jnp.asarray(vals), jnp.asarray(Y)
    gv, gy = jax.grad(dist_loss, argnums=(0, 1))(vj, Yj)
    wv, wy = jax.grad(dense_loss, argnums=(0, 1))(vj, Yj)
    np.testing.assert_allclose(gv, wv, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(gy, wy, rtol=2e-3, atol=2e-3)


def test_grads_work_under_jit():
    """The callback-backed VJPs must compose with jit (training loops
    jit their step functions)."""
    rows, cols, vals, X, Y, Sd = _data(seed=3)
    prob = _make(rows, cols, vals, Sd.shape, X.shape[1], algorithm="d15")

    @jax.jit
    def step(X, Y):
        return jax.grad(
            lambda X, Y: jnp.sum(grads.fusedmm(prob, X, Y)))(X, Y)

    eager = jax.grad(
        lambda X, Y: jnp.sum(grads.fusedmm(prob, X, Y)))(
            jnp.asarray(X), jnp.asarray(Y))
    jitted = step(jnp.asarray(X), jnp.asarray(Y))
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))


def test_session_replay_bitwise_and_hits():
    """Threading the forward's Session through the backward changes
    nothing numerically, and the stationary operand's replication is
    REPLAYED (content-keyed hits), not re-gathered."""
    rows, cols, vals, X, Y, Sd = _data(seed=4)
    prob = _make(rows, cols, vals, Sd.shape, X.shape[1], algorithm="d15")

    def loss(X, Y, session=None):
        return jnp.sum(grads.fusedmm(prob, X, Y, elision="reuse",
                                     session=session))

    Xj, Yj = jnp.asarray(X), jnp.asarray(Y)
    plain = jax.grad(loss, argnums=(0, 1))(Xj, Yj)
    sess = api.Session()
    cached = jax.grad(lambda X, Y: loss(X, Y, sess),
                      argnums=(0, 1))(Xj, Yj)
    np.testing.assert_array_equal(np.asarray(plain[0]),
                                  np.asarray(cached[0]))
    np.testing.assert_array_equal(np.asarray(plain[1]),
                                  np.asarray(cached[1]))
    # step 1: fwd fills Y, bwd's dual FusedMM replays it
    assert sess.hits >= 1, (sess.hits, sess.misses)
    h1 = sess.hits
    # step 2, same stationary Y, fresh X: Y replays in fwd AND bwd
    jax.grad(lambda X, Y: loss(X, Y, sess), argnums=(0, 1))(
        Xj * 0.5, Yj)
    assert sess.hits >= h1 + 2, (sess.hits, h1)


def test_gat_layer_trains():
    from repro.apps import gat
    n, d = 64, 8
    gp = gat.make_dist_graph(n, 4, d, seed=3, devices=_dev1())
    rng = np.random.default_rng(3)
    H = rng.standard_normal((n, d)).astype(np.float32)
    p = gat.init_gat_layer(jax.random.PRNGKey(0), d, d)
    # the trainable layer IS the distributed layer, differentiably
    want = np.asarray(gat.gat_layer_distributed(gp, H, p))
    got = np.asarray(gat.gat_layer_trainable(
        gp, jnp.asarray(H), jnp.asarray(p.W), jnp.asarray(p.a1),
        jnp.asarray(p.a2)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
    target = rng.standard_normal((n, d)).astype(np.float32) * 0.1
    _, hist = gat.train_gat_distributed(gp, H, target, steps=6, lr=0.05,
                                        verbose=False)
    assert hist[-1] < hist[0], hist


def test_embedding_sgd_converges():
    from repro.apps import als
    _, _, hist = als.train_embedding_distributed(
        m=96, n=96, nnz_per_row=5, r=8, steps=12, lr=0.08,
        devices=_dev1(), verbose=False)
    assert hist[-1] < 0.5 * hist[0], hist
