"""Serving tests.

Local LM path: decode == teacher-forcing across all model families,
cache extension, greedy generation determinism.

Distributed engine (docs/serving.md): batching parity — coalesced
union-of-patterns SDDMM and batched-RHS SpMM must BITWISE-match solo
per-request execution across families, comm wire formats and the
Session elision (property-based, hypothesis or the _propcheck
fallback) — plus Session-pool churn/LRU/pinning, admission shedding,
transient-fault recovery mid-tick, and the deterministic replay driver.
"""
import importlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _propcheck import given, settings, strategies as st

from repro import serving
from repro.apps import als, gat
from repro.core import api
from repro.distributed import faults
from repro.serving import batcher
from repro.serving import decode
from repro.config import ParallelConfig
from repro.models import model as M

PCFG = ParallelConfig(compute_dtype="float32")

FAMILIES = ["llama32_1b", "qwen3_1_7b", "mamba2_1_3b",
            "deepseek_v2_lite_16b", "jamba_v01_52b", "phi35_moe_42b"]


def reduced(name):
    return importlib.import_module("repro.configs." + name).reduced()


@pytest.mark.parametrize("name", FAMILIES)
def test_decode_matches_teacher_forcing(name):
    cfg = reduced(name)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full_logits, _, _ = M.forward(cfg, PCFG, params, {"tokens": toks},
                                  want_cache=False)
    half = S // 2
    logits_p, cache = decode.prefill(cfg, PCFG, params,
                                     {"tokens": toks[:, :half]})
    cache = decode.extend_cache(cache, S - half)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(full_logits[:, half - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(half, S):
        logits_d, cache = decode.decode_step(
            cfg, PCFG, params, {"tokens": toks[:, t:t + 1]}, cache)
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   rtol=5e-3, atol=5e-3)


def test_greedy_generate_deterministic():
    cfg = reduced("llama32_1b")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(1)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)),
                                    jnp.int32)}
    out1 = decode.greedy_generate(cfg, PCFG, params, prompt, steps=6)
    out2 = decode.greedy_generate(cfg, PCFG, params, prompt, steps=6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 6)


def test_cache_specs_cover_cache_tree():
    """Every cache leaf gets a PartitionSpec of matching rank."""
    from jax.sharding import PartitionSpec
    cfg = reduced("jamba_v01_52b")
    cache = M.init_cache(cfg, B=2, S=16)
    specs = M.cache_specs(cfg, PCFG, cache)
    flat_c = jax.tree.leaves(cache)
    flat_s = jax.tree.leaves(specs,
                             is_leaf=lambda x: isinstance(x, PartitionSpec))
    assert len(flat_c) == len(flat_s)
    for c, s in zip(flat_c, flat_s):
        assert len(s) <= c.ndim


def test_sanitize_specs_drops_indivisible():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import sanitize_spec
    sizes = {"data": 16, "model": 16}
    assert sanitize_spec(P("data", None), (1, 5), sizes) == P(None, None)
    assert sanitize_spec(P("model", None), (50280, 8), sizes) == \
        P(None, None)
    assert sanitize_spec(P("model", None), (128, 8), sizes) == \
        P("model", None)
    assert sanitize_spec(P(("data", "model"), None), (512, 8), sizes) == \
        P(("data", "model"), None)


def test_fsdp_extend_picks_free_divisible_dim():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import fsdp_extend_spec
    sizes = {"data": 16, "model": 16}
    out = fsdp_extend_spec(P(None, "model"), (4096, 4096), sizes, "data")
    assert out == P("data", "model")
    # too small -> untouched
    out = fsdp_extend_spec(P(None,), (128,), sizes, "data")
    assert out == P(None)


# ===========================================================================
# Distributed serving engine (docs/serving.md)
# ===========================================================================

def _dev1():
    # other test modules force host device counts at import; one device
    # keeps the fast tier independent of import order
    return jax.devices()[:1]


def _graph(m, n, nnz, seed=0):
    """Integer-exact random COO (no duplicate coordinates)."""
    rng = np.random.default_rng(seed)
    key = np.unique(rng.integers(0, m * n, nnz))
    rows = (key // n).astype(np.int64)
    cols = (key % n).astype(np.int64)
    vals = (rng.integers(1, 4, len(key))
            * rng.choice([-1.0, 1.0], len(key))).astype(np.float32)
    return rows, cols, vals


def _int_mat(rng, shape):
    return rng.integers(-3, 4, shape).astype(np.float32)


def _deploy(pool, m=48, n=40, r=8, seed=0, algorithm="d15",
            comm="dense", operands=None, nnz=260):
    rows, cols, vals = _graph(m, n, nnz, seed)
    return pool.deploy(rows, cols, vals, (m, n), r,
                       operands=operands or {}, algorithm=algorithm,
                       comm=comm, devices=_dev1())


def _solo_results(tickets, use_session=False):
    """Re-run each ticket's request alone (fresh tickets) — the parity
    reference for the coalesced tick."""
    outs = []
    for t in tickets:
        ref = serving.Ticket(t.request, seq=-1)
        batcher.execute_solo(ref, use_session=use_session,
                             use_elastic=False)
        outs.append(ref.result())
    return outs


# -- core parity: coalesced tick == per-request execution, bitwise ---------

def test_score_batching_bitwise_matches_solo():
    rng = np.random.default_rng(0)
    pool = serving.SessionPool(capacity=4)
    m, n, w = 48, 40, 5
    U = _int_mat(rng, (m, w))
    V = _int_mat(rng, (n, w))
    dep = _deploy(pool, m=m, n=n, operands={"U": U, "V": V})
    eng = serving.ServingEngine(pool, max_batch=32)
    tickets = []
    # three same-X clients (merge freely, overlapping rows allowed) ...
    for seed in range(3):
        r2 = np.random.default_rng(seed)
        tickets.append(eng.submit_score(
            dep, r2.integers(0, m, 7), r2.integers(0, n, 7), "U", "V"))
    # ... plus two different-X clients on disjoint row blocks (scatter)
    for lo in (0, 24):
        Xc = _int_mat(rng, (m, w))
        qr = rng.integers(lo, lo + 24, 6)
        tickets.append(eng.submit_score(dep, qr, rng.integers(0, n, 6),
                                        Xc, "V"))
    report = eng.tick()
    assert report["requests"] == 5
    # same-X unit + scatter unit: at most 2 rounds for 5 requests
    assert report["rounds"] <= 2
    for got, ref in zip([t.result() for t in tickets],
                        _solo_results(tickets)):
        np.testing.assert_array_equal(got, ref)


def test_aggregate_batching_bitwise_matches_solo():
    rng = np.random.default_rng(1)
    pool = serving.SessionPool(capacity=4)
    dep = _deploy(pool, seed=1)
    n, nnz = dep.problem.n, dep.problem.nnz
    eng = serving.ServingEngine(pool, max_batch=32)
    override = _int_mat(rng, nnz)
    tickets = [eng.submit_aggregate(dep, _int_mat(rng, (n, wi)))
               for wi in (3, 5, 2)]
    tickets += [eng.submit_aggregate(dep, _int_mat(rng, (n, 4)),
                                     vals=override) for _ in range(2)]
    report = eng.tick()
    # one deployed-values round + one override round
    assert report["rounds"] == 2
    for got, ref in zip([t.result() for t in tickets],
                        _solo_results(tickets)):
        np.testing.assert_array_equal(got, ref)


def test_duplicate_query_pairs_dedup_across_requests():
    """The union round computes each distinct (i, j) once; every request
    still gets its own (duplicated) samples back, bitwise."""
    rng = np.random.default_rng(2)
    pool = serving.SessionPool(capacity=2)
    m, n, w = 48, 40, 4
    U, V = _int_mat(rng, (m, w)), _int_mat(rng, (n, w))
    dep = _deploy(pool, operands={"U": U, "V": V})
    eng = serving.ServingEngine(pool)
    qr = np.array([3, 3, 7, 3]); qc = np.array([5, 5, 1, 5])
    t1 = eng.submit_score(dep, qr, qc, "U", "V")
    t2 = eng.submit_score(dep, qr[:2], qc[:2], "U", "V")
    rep = eng.tick()
    assert rep["rounds"] == 1
    ref = np.einsum("ij,ij->i", U[qr], V[qc])
    np.testing.assert_array_equal(t1.result(), ref)
    np.testing.assert_array_equal(t2.result(), ref[:2])


@settings(max_examples=5, deadline=None)
@given(family=st.sampled_from(["d15", "s15", "d25", "s25"]),
       comm=st.sampled_from(["dense", "sparse"]),
       use_session=st.booleans(),
       w=st.integers(2, 9),
       n_score=st.integers(0, 3),
       n_agg=st.integers(0, 3),
       seed=st.integers(0, 10 ** 6))
def test_property_batching_parity(family, comm, use_session, w,
                                  n_score, n_agg, seed):
    """Random request mixes: the coalesced tick bitwise-matches solo
    per-request execution on every (family x comm x session) cell."""
    if n_score + n_agg == 0:
        n_score = 1
    rng = np.random.default_rng(seed)
    m, n = 48, 40
    pool = serving.SessionPool(capacity=4)
    U, V = _int_mat(rng, (m, w)), _int_mat(rng, (n, w))
    dep = _deploy(pool, m=m, n=n, seed=seed % 97, algorithm=family,
                  comm=comm, operands={"U": U, "V": V})
    eng = serving.ServingEngine(pool, max_batch=32,
                                use_session=use_session)
    tickets = []
    for i in range(n_score):
        k = int(rng.integers(1, 8))
        if rng.integers(2):        # shared deployed X
            tickets.append(eng.submit_score(
                dep, rng.integers(0, m, k), rng.integers(0, n, k),
                "U", "V"))
        else:                      # client-private X, random rows
            tickets.append(eng.submit_score(
                dep, rng.integers(0, m, k), rng.integers(0, n, k),
                _int_mat(rng, (m, w)), "V"))
    override = _int_mat(rng, dep.problem.nnz)
    for i in range(n_agg):
        wi = int(rng.integers(1, 6))
        vals = override if rng.integers(2) else None
        tickets.append(eng.submit_aggregate(dep, _int_mat(rng, (n, wi)),
                                            vals=vals))
    eng.tick()
    for got, ref in zip([t.result() for t in tickets],
                        _solo_results(tickets,
                                      use_session=use_session)):
        np.testing.assert_array_equal(got, ref)


# -- api-level entry points ------------------------------------------------

def test_spmm_batched_parity_and_validation():
    rng = np.random.default_rng(3)
    m, n, r = 48, 40, 8
    rows, cols, vals = _graph(m, n, 260, seed=3)
    prob = api.make_problem(rows, cols, vals, (m, n), r,
                            algorithm="d15", devices=_dev1())
    Ys = [_int_mat(rng, (n, wi)) for wi in (3, 1, 6)]
    outs = prob.spmm_batched(Ys)
    assert [o.shape for o in outs] == [(m, 3), (m, 1), (m, 6)]
    for Y, out in zip(Ys, outs):
        mult = prob.alg.min_r_multiple(prob.grid)
        w_pad = -(-Y.shape[1] // mult) * mult
        Yp = np.zeros((n, max(w_pad, mult)), np.float32)
        Yp[:, :Y.shape[1]] = Y
        ref_prob = prob if Yp.shape[1] == prob.r \
            else prob.with_r(Yp.shape[1])
        np.testing.assert_array_equal(
            out, ref_prob.spmm(Yp)[:, :Y.shape[1]])
    assert prob.spmm_batched([]) == []
    with pytest.raises(ValueError, match="every RHS"):
        prob.spmm_batched([np.zeros((n + 1, 2), np.float32)])
    with pytest.raises(ValueError, match="pad_to"):
        prob.spmm_batched(Ys, pad_to=1)
    # pad_to buckets the compiled width without changing answers
    outs2 = prob.spmm_batched(Ys, pad_to=16)
    for a, b in zip(outs, outs2):
        np.testing.assert_array_equal(a, b)


def test_with_pattern_validation():
    rows, cols, vals = _graph(48, 40, 260, seed=4)
    prob = api.make_problem(rows, cols, vals, (48, 40), 8,
                            algorithm="d15", devices=_dev1())
    qp = prob.with_pattern([1, 2], [3, 4])
    assert qp.grid is prob.grid and qp.nnz == 2
    np.testing.assert_array_equal(qp.vals, [1.0, 1.0])
    with pytest.raises(ValueError, match="matching 1-D"):
        prob.with_pattern([1, 2], [3])
    with pytest.raises(ValueError, match="empty"):
        prob.with_pattern([], [])
    with pytest.raises(ValueError, match="outside"):
        prob.with_pattern([0], [40])
    with pytest.raises(ValueError, match="vals length"):
        prob.with_pattern([0], [0], vals=[1.0, 2.0])


# -- admission + tickets ---------------------------------------------------

def test_queue_admission_shedding():
    pool = serving.SessionPool(capacity=2)
    dep = _deploy(pool, operands={"U": np.ones((48, 4), np.float32),
                                  "V": np.ones((40, 4), np.float32)})
    eng = serving.ServingEngine(pool, max_pending=2)
    eng.submit_score(dep, [0], [0], "U", "V")
    eng.submit_score(dep, [1], [1], "U", "V")
    with pytest.raises(serving.AdmissionError):
        eng.submit_score(dep, [2], [2], "U", "V")
    assert eng.queue.stats()["rejected"] == 1
    eng.tick()
    # queue drained: admission reopens
    eng.submit_score(dep, [2], [2], "U", "V")
    assert len(eng.queue) == 1


def test_ticket_lifecycle():
    pool = serving.SessionPool(capacity=2)
    dep = _deploy(pool, operands={"U": np.ones((48, 4), np.float32),
                                  "V": np.ones((40, 4), np.float32)})
    eng = serving.ServingEngine(pool)
    t = eng.submit_score(dep, [0], [0], "U", "V", arrival=1.5)
    with pytest.raises(RuntimeError, match="pending"):
        t.result()
    assert t.latency is None
    eng.tick()
    t.completion = 2.0
    assert t.result().shape == (1,)
    assert t.latency == pytest.approx(0.5)


# -- Session-pool churn (satellite: LRU, stats, pinning) -------------------

def test_pool_lru_eviction_order_and_stats():
    pool = serving.SessionPool(capacity=2)
    deps = [_deploy(pool, seed=i) for i in range(4)]
    # capacity 2: deployments 0 and 1 evicted in insertion (LRU) order
    assert pool.stats()["occupancy"] == 2
    assert pool.stats()["evictions"] == 2
    assert pool.keys == [deps[2].key, deps[3].key]
    # re-deploying a resident digest is a hit and refreshes recency
    dep2b = _deploy(pool, seed=2)
    assert dep2b is deps[2]
    assert pool.stats()["hits"] == 1
    assert pool.keys == [deps[3].key, deps[2].key]
    # a fresh digest now evicts deployment 3, not the refreshed 2
    _deploy(pool, seed=9)
    assert deps[2].key in pool.keys and deps[3].key not in pool.keys
    s = pool.stats()
    assert s["misses"] == 5 and s["evictions"] == 3
    assert 0.0 < s["hit_rate"] < 1.0


def test_pool_redeploy_with_refreshed_operands_is_miss():
    """Same graph, refreshed factors -> new digest -> fresh deployment
    (stale factors must never serve a post-refresh query)."""
    pool = serving.SessionPool(capacity=4)
    U1 = np.ones((48, 4), np.float32)
    U2 = 2 * U1
    V = np.ones((40, 4), np.float32)
    d1 = _deploy(pool, operands={"U": U1, "V": V})
    d2 = _deploy(pool, operands={"U": U2, "V": V})
    assert d1 is not d2 and d1.key != d2.key
    assert pool.stats()["misses"] == 2 and pool.stats()["hits"] == 0


def test_pool_pinned_never_evicted_and_inflight_survives():
    rng = np.random.default_rng(5)
    pool = serving.SessionPool(capacity=1)
    m, n, w = 48, 40, 4
    U, V = _int_mat(rng, (m, w)), _int_mat(rng, (n, w))
    dep = _deploy(pool, operands={"U": U, "V": V})
    eng = serving.ServingEngine(pool)
    with pool.pin(dep):
        # churn past capacity while pinned: dep must survive (the pool
        # overshoots instead of corrupting in-flight state)
        others = [_deploy(pool, seed=10 + i) for i in range(3)]
        assert dep.key in pool.keys
        assert pool.stats()["occupancy"] >= 1
        t = eng.submit_score(dep, [1, 2], [3, 4], "U", "V")
        eng.tick()
        np.testing.assert_array_equal(
            t.result(), np.einsum("ij,ij->i", U[[1, 2]], V[[3, 4]]))
    # unpinned: the next deploy can evict it
    _deploy(pool, seed=20)
    assert pool.stats()["occupancy"] == 1
    assert dep.key not in pool.keys


def test_pool_session_accounting_across_ticks():
    """Tick after tick against one deployment: the stationary operands'
    replication is served from the Session cache (hits grow, misses
    stay put) and the pattern cache pins repeated hot queries."""
    rng = np.random.default_rng(6)
    pool = serving.SessionPool(capacity=2)
    m, n, w = 48, 40, 4
    U, V = _int_mat(rng, (m, w)), _int_mat(rng, (n, w))
    dep = _deploy(pool, operands={"U": U, "V": V})
    eng = serving.ServingEngine(pool)
    qr, qc = rng.integers(0, m, 6), rng.integers(0, n, 6)
    t0 = eng.submit_score(dep, qr, qc, "U", "V")
    eng.tick()
    miss0 = dep.session.stats()["misses"]
    results = [t0.result()]
    for _ in range(3):
        t = eng.submit_score(dep, qr, qc, "U", "V")
        eng.tick()
        results.append(t.result())
    s = dep.session.stats()
    assert s["misses"] == miss0, "steady-state ticks must not re-replicate"
    assert s["hits"] > 0
    assert len(dep._pattern_cache) == 1   # one hot pattern, reused
    for r in results[1:]:
        np.testing.assert_array_equal(r, results[0])


# -- elastic serving (transient fault mid-tick) ----------------------------

def test_tick_recovers_from_transient_fault():
    rng = np.random.default_rng(7)
    pool = serving.SessionPool(capacity=2)
    m, n, w = 48, 40, 4
    U, V = _int_mat(rng, (m, w)), _int_mat(rng, (n, w))
    dep = _deploy(pool, operands={"U": U, "V": V})
    eng = serving.ServingEngine(pool)
    qr, qc = rng.integers(0, m, 6), rng.integers(0, n, 6)
    plan = faults.FaultPlan.scripted(
        faults.FaultSpec(op="sddmm", kind="transient", round=0))
    with faults.inject(plan) as ctl:
        t = eng.submit_score(dep, qr, qc, "U", "V")
        eng.tick()
    assert len(ctl.fired) == 1
    assert len(dep.elastic.recoveries) == 1
    np.testing.assert_array_equal(
        t.result(), np.einsum("ij,ij->i", U[qr], V[qc]))


def test_tick_fails_tickets_when_retries_exhausted():
    pool = serving.SessionPool(
        capacity=2, policy=api.RetryPolicy(max_retries=1))
    dep = _deploy(pool, operands={"U": np.ones((48, 4), np.float32),
                                  "V": np.ones((40, 4), np.float32)})
    eng = serving.ServingEngine(pool)
    plan = faults.FaultPlan.scripted(
        *[faults.FaultSpec(op="sddmm", kind="transient", round=i)
          for i in range(3)])
    with faults.inject(plan):
        t = eng.submit_score(dep, [0], [0], "U", "V")
        eng.tick()
    assert t.done and eng.failed == 1
    with pytest.raises(api.FaultRecoveryError):
        t.result()
    # the engine survives: the next fault-free tick serves normally
    t2 = eng.submit_score(dep, [1], [1], "U", "V")
    eng.tick()
    assert t2.result().shape == (1,)


# -- deterministic replay (latency methodology) ----------------------------

def test_replay_trace_latency_accounting():
    rng = np.random.default_rng(8)
    pool = serving.SessionPool(capacity=2)
    m, n, w = 48, 40, 4
    U, V = _int_mat(rng, (m, w)), _int_mat(rng, (n, w))
    dep = _deploy(pool, operands={"U": U, "V": V})
    eng = serving.ServingEngine(pool, max_batch=4)

    def make_submit(seed):
        def submit(engine, arrival):
            r2 = np.random.default_rng(seed)
            return engine.submit_score(
                dep, r2.integers(0, m, 4), r2.integers(0, n, 4),
                "U", "V", arrival=arrival)
        return submit

    trace = [(0.001 * i, make_submit(i)) for i in range(8)]
    out = serving.replay_trace(eng, trace)
    assert out["served"] == 8 and out["shed"] == 0
    assert out["p50"] > 0 and out["p99"] >= out["p50"]
    assert out["throughput"] > 0
    for t in out["tickets"]:
        assert t.completion is not None and t.latency > 0


# -- served app query modes ------------------------------------------------

def test_als_predict_scores_served():
    rng = np.random.default_rng(9)
    m, n, r = 48, 40, 8
    rows, cols, vals = _graph(m, n, 260, seed=9)
    U, V = _int_mat(rng, (m, r)), _int_mat(rng, (n, r))
    pool = serving.SessionPool(capacity=2)
    dep = als.deploy_factors(pool, rows, cols, vals, (m, n), U, V,
                             algorithm="d15", devices=_dev1())
    eng = serving.ServingEngine(pool)
    users, items = rng.integers(0, m, 6), rng.integers(0, n, 6)
    t1 = als.predict_scores(eng, dep, users, items)
    W = _int_mat(rng, (n, 3))
    t2 = als.lookup_embeddings(eng, dep, W)
    eng.tick()
    np.testing.assert_array_equal(
        t1.result(), np.einsum("ij,ij->i", U[users], V[items]))
    dense = np.zeros((m, n), np.float32)
    dense[rows, cols] = vals
    np.testing.assert_array_equal(t2.result(), dense @ W)


def test_gat_layer_served_matches_distributed():
    """The served GAT query path == the full distributed layer, bitwise
    on the queried rows (one head)."""
    rng = np.random.default_rng(10)
    n, d = 64, 8
    H = _int_mat(rng, (n, d))
    p = gat.init_gat_layer(jax.random.PRNGKey(3), d, d)
    rows, cols, vals = gat.graph_coo(n, 6, seed=10)
    pool = serving.SessionPool(capacity=2)
    dep = gat.gat_deploy_layer(pool, rows, cols, n, H, p,
                               algorithm="d15", devices=_dev1())
    eng = serving.ServingEngine(pool)
    node_ids = np.array([3, 17, 50])
    out = gat.gat_layer_served(eng, dep, node_ids)
    graphP = api.make_problem(rows, cols, vals, (n, n), d,
                              algorithm="d15", devices=_dev1())
    ref = gat.gat_layer_distributed(graphP, H, p, n_heads=1)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref)[node_ids])


# -- batcher unit planning -------------------------------------------------

def test_score_unit_planning_rules():
    rng = np.random.default_rng(11)
    pool = serving.SessionPool(capacity=2)
    m, n, w = 48, 40, 4
    U, V = _int_mat(rng, (m, w)), _int_mat(rng, (n, w))
    dep = _deploy(pool, operands={"U": U, "V": V})
    eng = serving.ServingEngine(pool)
    # same X, overlapping rows: one unit
    t_a = eng.submit_score(dep, [1, 2], [0, 1], "U", "V")
    t_b = eng.submit_score(dep, [2, 3], [1, 2], "U", "V")
    # different X, rows disjoint from everything above: joins via scatter
    X2 = _int_mat(rng, (m, w))
    t_c = eng.submit_score(dep, [30, 31], [0, 1], X2, "V")
    # different X, rows OVERLAP the scatter unit: must start a new unit
    X3 = _int_mat(rng, (m, w))
    t_d = eng.submit_score(dep, [31, 40], [2, 3], X3, "V")
    tickets = eng.queue.drain()
    units = batcher.plan_score_units(tickets)
    assert len(units) == 2
    assert sorted(len(u.tickets) for u in units) == [1, 3]
    for u in units:
        batcher.execute_score_unit(u)
    for t, ref in zip((t_a, t_b, t_c, t_d),
                      _solo_results((t_a, t_b, t_c, t_d))):
        np.testing.assert_array_equal(t.result(), ref)
