"""Serving tests: decode == teacher-forcing across all model families,
cache extension, greedy generation determinism."""
import importlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.config import ParallelConfig
from repro.models import model as M
from repro.serving import engine

PCFG = ParallelConfig(compute_dtype="float32")

FAMILIES = ["llama32_1b", "qwen3_1_7b", "mamba2_1_3b",
            "deepseek_v2_lite_16b", "jamba_v01_52b", "phi35_moe_42b"]


def reduced(name):
    return importlib.import_module("repro.configs." + name).reduced()


@pytest.mark.parametrize("name", FAMILIES)
def test_decode_matches_teacher_forcing(name):
    cfg = reduced(name)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full_logits, _, _ = M.forward(cfg, PCFG, params, {"tokens": toks},
                                  want_cache=False)
    half = S // 2
    logits_p, cache = engine.prefill(cfg, PCFG, params,
                                     {"tokens": toks[:, :half]})
    cache = engine.extend_cache(cache, S - half)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(full_logits[:, half - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(half, S):
        logits_d, cache = engine.decode_step(
            cfg, PCFG, params, {"tokens": toks[:, t:t + 1]}, cache)
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   rtol=5e-3, atol=5e-3)


def test_greedy_generate_deterministic():
    cfg = reduced("llama32_1b")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(1)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)),
                                    jnp.int32)}
    out1 = engine.greedy_generate(cfg, PCFG, params, prompt, steps=6)
    out2 = engine.greedy_generate(cfg, PCFG, params, prompt, steps=6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 6)


def test_cache_specs_cover_cache_tree():
    """Every cache leaf gets a PartitionSpec of matching rank."""
    from jax.sharding import PartitionSpec
    cfg = reduced("jamba_v01_52b")
    cache = M.init_cache(cfg, B=2, S=16)
    specs = M.cache_specs(cfg, PCFG, cache)
    flat_c = jax.tree.leaves(cache)
    flat_s = jax.tree.leaves(specs,
                             is_leaf=lambda x: isinstance(x, PartitionSpec))
    assert len(flat_c) == len(flat_s)
    for c, s in zip(flat_c, flat_s):
        assert len(s) <= c.ndim


def test_sanitize_specs_drops_indivisible():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import sanitize_spec
    sizes = {"data": 16, "model": 16}
    assert sanitize_spec(P("data", None), (1, 5), sizes) == P(None, None)
    assert sanitize_spec(P("model", None), (50280, 8), sizes) == \
        P(None, None)
    assert sanitize_spec(P("model", None), (128, 8), sizes) == \
        P("model", None)
    assert sanitize_spec(P(("data", "model"), None), (512, 8), sizes) == \
        P(("data", "model"), None)


def test_fsdp_extend_picks_free_divisible_dim():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import fsdp_extend_spec
    sizes = {"data": 16, "model": 16}
    out = fsdp_extend_spec(P(None, "model"), (4096, 4096), sizes, "data")
    assert out == P("data", "model")
    # too small -> untouched
    out = fsdp_extend_spec(P(None,), (128,), sizes, "data")
    assert out == P(None)
