"""Multi-device correctness + comm-cost tests.

XLA fixes the host device count at first backend init, so these run as
subprocesses that force 8 CPU devices before importing jax.  Each script
asserts internally and exits non-zero on failure.
"""
import os
import subprocess
import sys

import pytest

SCRIPTS = os.path.join(os.path.dirname(__file__), "dist_scripts")
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def run_script(name):
    env = dict(os.environ)
    # drop any inherited device-count flags (e.g. from importing
    # repro.launch.dryrun in-process) — the scripts set their own
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, name)],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, (
        f"{name} failed:\nstdout:\n{proc.stdout[-4000:]}\n"
        f"stderr:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.mark.slow
def test_d15_all_modes_all_c():
    out = run_script("check_d15.py")
    assert "ALL D15 OK" in out


@pytest.mark.slow
def test_s15_all_modes_all_c():
    out = run_script("check_s15.py")
    assert "ALL S15 OK" in out


@pytest.mark.slow
def test_d25_all_modes():
    out = run_script("check_d25.py")
    assert "ALL D25 OK" in out


@pytest.mark.slow
def test_s25_all_modes():
    out = run_script("check_s25.py")
    assert "ALL S25 OK" in out


@pytest.mark.slow
def test_d15_overlap_matches_serial_bitwise():
    out = run_script("check_d15_overlap.py")
    assert "D15 OVERLAP IDENTITY OK" in out


@pytest.mark.slow
def test_comm_costs_match_table3():
    out = run_script("check_comm_costs.py")
    assert "ALL COMM COSTS OK" in out


@pytest.mark.slow
def test_comm_sparse_pruned_wire_formats():
    """comm="sparse" bitwise == comm="dense" on every feasible cell,
    measured wire words == the plan-exact pruned-channel model at 1.00x,
    and the power-law problem ships strictly fewer words than the dense
    Table-III optimum."""
    out = run_script("check_comm_sparse.py")
    assert "ALL COMM SPARSE OK" in out
    assert "at 1.00x" in out


@pytest.mark.slow
def test_elastic_remesh_8_to_4():
    out = run_script("check_elastic.py")
    assert "ELASTIC OK" in out


@pytest.mark.slow
def test_elision_cells_match_unfused_sequence():
    """Every registry elision cell vs the unfused sddmm;spmm sequence —
    bitwise for the communication-replaying cells (s15/d25 "fused",
    s25 "reuse", and every "none"), allclose for reassociating ones."""
    out = run_script("check_elision_parity.py")
    assert "ALL ELISION PARITY OK" in out


@pytest.mark.slow
def test_unified_api_cross_algorithm_parity():
    """Every registered algorithm through repro.core.api == kernels/ref,
    plus bitwise-identical Session replication caching."""
    out = run_script("check_api.py")
    assert "ALL API OK" in out


@pytest.mark.slow
def test_distributed_als_and_gat():
    """Paper §VI-E applications end-to-end on the unified API."""
    out = run_script("check_apps_dist.py")
    assert "ALL APPS DIST OK" in out


@pytest.mark.slow
def test_gradients_match_dense_reference():
    """jax.grad through the distributed sddmm/spmm/fusedmm == the dense
    reference on every feasible registry cell (8 devices), Session
    threading bitwise-neutral, trainable apps converge."""
    out = run_script("check_grads.py")
    assert "ALL GRADS OK" in out


@pytest.mark.slow
def test_backward_wire_words_match_extended_model():
    """Measured backward wire words == the impl-exact extended cost
    model at 1.00x per cell, with the Session-replayed backward strictly
    cheaper wherever a dense operand is replicated."""
    out = run_script("check_grad_costs.py")
    assert "ALL GRAD COSTS OK" in out


@pytest.mark.slow
def test_fault_injected_recovery_parity():
    """Every (family x op x elision x session) cell recovers from an
    injected transient fault with bitwise-identical results; seeded
    fault plans replay; a mid-training DeviceLost degrades 8 -> 4 and
    matches a checkpoint-resume onto the same mesh bitwise.  Writes the
    FAULTS_summary.json CI artifact."""
    out = run_script("check_faults.py")
    assert "ALL FAULTS OK" in out
    assert "device-lost re-mesh ok" in out


@pytest.mark.slow
def test_serving_engine_elastic_8dev():
    """Continuous-batching serving engine under seeded traffic on the
    8-device mesh: coalesced ticks bitwise vs solo and vs the numpy
    reference, mid-stream DeviceLost re-meshing the pool's deployments
    (score AND aggregate rounds), pool churn under traffic, and the
    deterministic open-loop latency replay."""
    out = run_script("check_serving.py")
    assert "ALL SERVING OK" in out
    assert "re-meshed to" in out


@pytest.mark.slow
def test_remesh_8_to_4_bitwise():
    """DistProblem.replan / api.degrade shrink 8 -> 4 mid-run with
    bitwise-identical kernel results (integer-exact data); non-divisible
    device counts fail with the constraint trail."""
    out = run_script("check_remesh.py")
    assert "ALL REMESH OK" in out


@pytest.mark.slow
def test_static_schedule_conformance_8dev():
    """Every registry cell's lowered HLO collective sequence matches its
    published schedule (kind, order, replica groups) with the SPMD
    rendezvous simulation deadlock-free; corrupted event lists and
    per-rank programs are caught.  Writes ANALYSIS_report.json."""
    out = run_script("check_analysis.py")
    assert "ALL ANALYSIS OK" in out
    assert "all pass" in out


@pytest.mark.slow
def test_obs_traced_smoke_8dev():
    """Traced 8-device smoke across all four families: every dense
    round's measured/modeled wire-word ratio inside [0.99, 1.01] (the
    impl-exact model lands at 1.0000), per-event word sums equal the
    round model, traced results bitwise vs untraced, and the
    TRACE_smoke.json / METRICS_smoke.json CI artifacts written."""
    out = run_script("check_obs.py")
    assert "ALL OBS OK" in out
    assert "drift=1.0000" in out
