"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting output shapes and finiteness."""
import importlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.config import ParallelConfig, TrainConfig
from repro.models import model as M
from repro.training import optimizer as opt
from repro.training import train_step as ts

ARCH_MODULES = [
    "jamba_v01_52b", "stablelm_1_6b", "llama32_1b", "qwen3_1_7b",
    "qwen3_4b", "qwen2_vl_72b", "mamba2_1_3b", "deepseek_v2_lite_16b",
    "phi35_moe_42b", "hubert_xlarge",
]

PCFG = ParallelConfig(compute_dtype="float32")


def reduced(name):
    return importlib.import_module("repro.configs." + name).reduced()


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.embed_inputs:
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        batch = {"tokens": tok}
    else:
        batch = {"embeds": jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)}
        if cfg.pos_dims == 3:
            batch["positions"] = jnp.asarray(
                rng.integers(0, S, (B, S, 3)), jnp.int32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32)
    return batch


@pytest.mark.parametrize("name", ARCH_MODULES)
def test_forward_shapes_finite(name):
    cfg = reduced(name)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, _, aux = M.forward(cfg, PCFG, params, batch, want_cache=False)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCH_MODULES)
def test_train_step_runs(name):
    cfg = reduced(name)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    state = opt.init_opt_state(params)
    batch = make_batch(cfg, 2, 32, seed=1)
    tcfg = TrainConfig(seq_len=32, global_batch=2, steps=10)
    step, _, _ = ts.make_train_step(cfg, PCFG, tcfg, mesh=None)
    new_params, new_state, metrics = jax.jit(step)(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # parameters actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), params, new_params))
    assert max(moved) > 0


def test_param_count_matches_init():
    """Analytic param_count must equal the actual initialized tree."""
    for name in ("llama32_1b", "mamba2_1_3b", "deepseek_v2_lite_16b",
                 "jamba_v01_52b"):
        cfg = reduced(name)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert actual == cfg.param_count(), (name, actual, cfg.param_count())


def test_active_params_less_for_moe():
    cfg = reduced("phi35_moe_42b")
    assert cfg.active_param_count() < cfg.param_count()
    dense = reduced("llama32_1b")
    assert dense.active_param_count() == dense.param_count()


def test_full_config_param_counts():
    """Full (non-reduced) configs must be near their advertised sizes."""
    from repro.config import get_config
    approx = {
        "llama3.2-1b": (1.0e9, 1.7e9),
        "stablelm-1.6b": (1.4e9, 2.1e9),
        "qwen3-1.7b": (1.5e9, 2.4e9),
        "qwen3-4b": (3.5e9, 5.0e9),
        "mamba2-1.3b": (1.1e9, 1.7e9),
        "deepseek-v2-lite-16b": (13e9, 19e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "qwen2-vl-72b": (63e9, 80e9),
        "hubert-xlarge": (0.7e9, 1.3e9),
    }
    for name, (lo, hi) in approx.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, (name, n)


def test_mamba2_ssd_matches_naive_recurrence():
    from repro.models.ssm import _ssd_chunked
    rng = np.random.default_rng(0)
    B, S, H, P, N, chunk = 2, 64, 3, 8, 4, 16
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.3, (B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.3, 1.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    y, fin = _ssd_chunked(x, dt, A, Bm, Cm, chunk)
    s = np.zeros((B, H, P, N))
    ys = []
    xn, dtn, Bn, Cn, An = map(np.asarray, (x, dt, Bm, Cm, A))
    for t in range(S):
        dA = np.exp(dtn[:, t] * An[None])
        s = s * dA[:, :, None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dtn[:, t], Bn[:, t], xn[:, t])
        ys.append(np.einsum("bn,bhpn->bhp", Cn[:, t], s))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), s, rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_plain():
    from repro.models.attention import flash_attention
    rng = np.random.default_rng(0)
    B, S, H, Kv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Kv, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block=16)
    # plain reference
    qe = q.reshape(B, S, Kv, H // Kv, hd)
    s = np.einsum("bqgrh,bkgh->bqgrk", np.asarray(qe), np.asarray(k)) \
        / np.sqrt(hd)
    mask = np.tril(np.ones((S, S), bool))
    s = np.where(mask[None, :, None, None, :], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bqgrk,bkgh->bqgrh", p, np.asarray(v)).reshape(
        B, S, H, hd)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_moe_spmm_dispatch_matches_einsum():
    """The paper-integration path (SpMM dispatch) must agree with einsum."""
    from repro.models import moe as moe_mod
    cfg = reduced("phi35_moe_42b")
    rng = np.random.default_rng(3)
    p = moe_mod.init_moe(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    out_e, _ = moe_mod.moe(cfg, PCFG, p, x, dispatch="einsum")
    out_s, _ = moe_mod.moe(cfg, PCFG, p, x, dispatch="spmm")
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_s),
                               rtol=2e-4, atol=2e-4)
