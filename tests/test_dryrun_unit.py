"""Unit tests for the dry-run machinery that don't require 512 devices."""
import jax
import jax.numpy as jnp
import pytest

import repro.launch.dryrun as dr
from repro.config import get_config


def test_skip_ledger():
    ok, _ = dr.runnable("hubert-xlarge", "decode_32k")
    assert not ok
    ok, _ = dr.runnable("hubert-xlarge", "long_500k")
    assert not ok
    ok, _ = dr.runnable("llama3.2-1b", "long_500k")
    assert not ok
    ok, _ = dr.runnable("jamba-v0.1-52b", "long_500k")
    assert ok
    ok, _ = dr.runnable("mamba2-1.3b", "long_500k")
    assert ok
    for shape in ("train_4k", "prefill_32k"):
        for arch in dr.ARCHS if hasattr(dr, "ARCHS") else []:
            assert dr.runnable(arch, shape)[0]


def test_runnable_cell_count():
    """31 runnable cells per mesh (20 train/prefill + 9 decode + 2 long)."""
    from repro.configs import ARCH_IDS
    n = sum(dr.runnable(a, s)[0] for a in ARCH_IDS for s in dr.SHAPES)
    assert n == 31


def test_input_specs_shapes():
    cfg = get_config("llama3.2-1b")
    s = dr.input_specs(cfg, "train_4k")
    assert s["tokens"].shape == (256, 4096)
    assert s["labels"].shape == (256, 4096)
    s = dr.input_specs(cfg, "decode_32k")
    assert s["tokens"].shape == (128, 1)

    vlm = get_config("qwen2-vl-72b")
    s = dr.input_specs(vlm, "prefill_32k")
    assert s["embeds"].shape == (32, 32768, 8192)
    assert s["positions"].shape == (32, 32768, 3)

    audio = get_config("hubert-xlarge")
    s = dr.input_specs(audio, "train_4k")
    assert s["embeds"].shape == (256, 4096, 1280)
    assert s["labels"].shape == (256, 4096)


def test_input_specs_are_abstract():
    cfg = get_config("qwen3-1.7b")
    for v in dr.input_specs(cfg, "train_4k").values():
        assert isinstance(v, jax.ShapeDtypeStruct)   # no allocation


def test_mesh_factories_are_functions():
    """Importing mesh.py must not touch device state (module-level)."""
    import importlib
    import repro.launch.mesh as mesh_mod
    importlib.reload(mesh_mod)   # would fail if devices were created at
    assert callable(mesh_mod.make_production_mesh)


def test_shapes_table_matches_assignment():
    assert dr.SHAPES["train_4k"] == dict(kind="train", seq=4096, batch=256)
    assert dr.SHAPES["prefill_32k"] == dict(kind="prefill", seq=32768,
                                            batch=32)
    assert dr.SHAPES["decode_32k"] == dict(kind="decode", seq=32768,
                                           batch=128)
    assert dr.SHAPES["long_500k"] == dict(kind="decode", seq=524288,
                                          batch=1)


def test_fusedmm_sweep_grid_reports_every_cell(tmp_path, capsys):
    """Satellite: the --fusedmm sweep covers the FULL algo x elision grid
    and renders unsupported/skipped cells in its summary table instead of
    omitting them — docs/algorithms.md's feasibility table regenerates
    from this output."""
    import json
    from repro.core import api
    from repro.launch import sweep_dryrun as sw

    cells = sw.fusedmm_cells()
    assert len(cells) == len(api.ALGORITHMS) * len(sw.ELISIONS)
    by_cell = {(a, el): sup for a, el, sup in cells}
    assert by_cell[("s25", "fused")] is False        # structurally impossible
    assert by_cell[("s15", "fused")] is True
    assert by_cell[("d25", "fused")] is True
    assert by_cell[("s25", "reuse")] is True

    summary = tmp_path / "summary_fusedmm.jsonl"
    with open(summary, "w") as f:
        for algo, el, sup in cells:
            rec = dict(algo=algo, elision=el, ok=True, c=2)
            if not sup:
                rec["skipped"] = "unsupported elision"
            f.write(json.dumps(rec) + "\n")
    sw._print_fusedmm_summary(summary)
    out = capsys.readouterr().out
    assert "skipped" in out
    for algo in api.ALGORITHMS:
        assert algo in out
