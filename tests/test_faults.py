"""Single-device tests for the fault-injection harness and the elastic
recovery layer (docs/robustness.md).

The 8-device recovery-parity sweep lives in
tests/dist_scripts/check_faults.py (run via test_distributed.py); these
cover the host-side machinery — plans, controllers, retry policies,
typed errors, metadata — plus single-mesh ElasticProblem recovery, which
needs no multi-device mesh.
"""
import jax
import numpy as np
import pytest

from repro.core import api, sparse
from repro.distributed import elastic, faults


def _dev1():
    # pin to one device: in-suite the process may expose 512 forced
    # host devices (test_dryrun_unit), which no tiny problem can split
    return jax.devices()[:1]


def tiny_problem(seed=0, m=32, n=32, r=8):
    rng = np.random.default_rng(seed)
    rows, cols, _ = sparse.erdos_renyi(m, n, 3, seed=seed)
    vals = rng.integers(1, 5, rows.shape[0]).astype(np.float32)
    X = rng.integers(-3, 4, (m, r)).astype(np.float32)
    Y = rng.integers(-3, 4, (n, r)).astype(np.float32)
    prob = api.make_problem(rows, cols, vals, (m, n), r, devices=_dev1())
    return prob, X, Y


# --- plans and controllers --------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        faults.FaultSpec(kind="meteor_strike")
    with pytest.raises(ValueError, match="op"):
        faults.FaultSpec(op="gemm")
    with pytest.raises(ValueError, match="point"):
        faults.FaultSpec(point="handshake")


def test_random_plan_replayable():
    a = faults.FaultPlan.random(42, n_faults=5, p=8)
    b = faults.FaultPlan.random(42, n_faults=5, p=8)
    c = faults.FaultPlan.random(43, n_faults=5, p=8)
    assert a.specs == b.specs
    assert a.specs != c.specs


def test_controller_fires_once_and_logs():
    ctl = faults.FaultController(faults.FaultPlan.scripted(
        faults.FaultSpec(op="sddmm", point="shift", phase=1, round=1)))
    events = [("gather", 0), ("phase", 0), ("shift", 0),
              ("phase", 1), ("shift", 1)]
    ctl.guard("sddmm", "d15", 4, events)          # round 0: no match
    with pytest.raises(faults.TransientFault) as ei:
        ctl.guard("sddmm", "d15", 4, events)      # round 1: fires
    assert ei.value.coord["point"] == "shift"
    assert ei.value.coord["phase"] == 1
    ctl.guard("sddmm", "d15", 4, events)          # consumed: no re-fire
    s = ctl.summary()
    assert s["rounds"] == {"sddmm": 3} and len(s["fired"]) == 1
    assert not s["pending"]


def test_controller_unreachable_spec_stays_pending():
    ctl = faults.FaultController(faults.FaultPlan.scripted(
        faults.FaultSpec(op="spmm", point="gather", rank=7)))
    ctl.guard("spmm", "s25", 4, [("phase", 0), ("reduce", 0)])  # no gather
    assert len(ctl.summary()["pending"]) == 1


def test_inject_nests_and_restores():
    assert faults.active() is None
    with faults.inject(faults.FaultPlan.scripted()) as outer:
        assert faults.active() is outer
        with faults.inject(faults.FaultPlan.scripted()) as inner:
            assert faults.active() is inner
        assert faults.active() is outer
    assert faults.active() is None


def test_unwrap_recovers_laundered_fault():
    plan = faults.FaultPlan.scripted(
        faults.FaultSpec(kind="device_lost", rank=0))
    with faults.inject(plan) as ctl:
        with pytest.raises(faults.DeviceLost):
            ctl.guard("sddmm", "d15", 1, [("gather", 0)])
        laundered = RuntimeError("INTERNAL: ... CpuCallback error")
        typed = faults.unwrap(laundered)
        assert isinstance(typed, faults.DeviceLost) and typed.rank == 0
        # reclaimed once: a second unrelated error passes through
        assert faults.unwrap(laundered) is laundered
    assert faults.unwrap(laundered) is laundered  # no armed controller


# --- retry policies ---------------------------------------------------------

def test_backoff_delays_deterministic_and_bounded():
    a = list(elastic.backoff_delays(5, base=0.1, max_delay=0.3, seed=4))
    b = list(elastic.backoff_delays(5, base=0.1, max_delay=0.3, seed=4))
    assert a == b and len(a) == 5
    assert all(d <= 0.3 * 1.25 for d in a)
    assert a[0] < a[1]   # exponential growth until the cap
    assert list(elastic.backoff_delays(3)) == [0.0, 0.0, 0.0]  # no base


def test_retry_policy_delays_deterministic():
    pol = api.RetryPolicy(max_retries=4, base_delay=0.5, seed=9)
    assert list(pol.delays()) == list(
        api.RetryPolicy(max_retries=4, base_delay=0.5, seed=9).delays())


def test_run_step_resilient_backoff_sleeps():
    slept = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise faults.TransientFault("hiccup")
        return "done"

    out = elastic.run_step_resilient(
        flaky, None, None, max_retries=3,
        backoff=iter([0.01, 0.02, 0.04]), sleep=slept.append)
    assert out == "done" and slept == [0.01, 0.02]


# --- ElasticProblem recovery on a single-device mesh ------------------------

def test_elastic_problem_recovers_bitwise():
    prob, X, Y = tiny_problem()
    base = np.asarray(prob.sddmm(X, Y).values())
    plan = faults.FaultPlan.scripted(faults.FaultSpec(op="sddmm"))
    with faults.inject(plan) as ctl:
        ep = api.ElasticProblem(prob, session=api.Session())
        got = np.asarray(ep.sddmm(X, Y).values())
    assert np.array_equal(got, base)
    assert len(ep.recoveries) == 1 and len(ctl.fired) == 1
    assert ep.recoveries[0]["coord"]["op"] == "sddmm"


def test_elastic_problem_exhausts_budget():
    prob, X, Y = tiny_problem()
    plan = faults.FaultPlan.scripted(
        *[faults.FaultSpec(op="spmm", round=i) for i in range(5)])
    with faults.inject(plan):
        ep = api.ElasticProblem(prob,
                                policy=api.RetryPolicy(max_retries=2))
        with pytest.raises(api.FaultRecoveryError) as ei:
            ep.spmm(Y)
    assert len(ei.value.history) == 3   # initial + 2 retries, all faulted


def test_elastic_problem_propagates_caller_bugs():
    prob, X, Y = tiny_problem()
    ep = api.ElasticProblem(prob)
    with pytest.raises((TypeError, ValueError)):
        ep.spmm(None)            # wrong operand, not a device failure
    assert not ep.recoveries


def test_session_invalidate_is_grid_scoped():
    prob, X, Y = tiny_problem(seed=0)
    other, X2, Y2 = tiny_problem(seed=1)
    sess = api.Session()
    prob.fusedmm(X, Y, elision="reuse", session=sess)
    other.fusedmm(X2, Y2, elision="reuse", session=sess)
    n_before = len(sess._cache)
    evicted = sess.invalidate(prob)
    assert evicted >= 1
    assert len(sess._cache) == n_before - evicted
    # other problem's entries survive, and the evicted ones refill
    out, _ = prob.fusedmm(X, Y, elision="reuse", session=sess)
    assert np.array_equal(np.asarray(out),
                          np.asarray(prob.fusedmm(X, Y, elision="reuse")[0]))


# --- checkpoint metadata ----------------------------------------------------

def test_meta_roundtrip_and_digest_guard():
    prob, X, Y = tiny_problem()
    meta = prob.meta_dict()
    re = api.problem_from_meta(meta, prob.rows, prob.cols, prob.vals,
                               devices=_dev1())
    assert (re.alg.name, re.p, re.c) == (meta["family"], prob.p, prob.c)
    bad = prob.vals.copy()
    bad[0] += 1.0
    with pytest.raises(ValueError, match="wrong matrix"):
        api.problem_from_meta(meta, prob.rows, prob.cols, bad,
                              devices=_dev1())


def test_replan_same_mesh_bitwise():
    prob, X, Y = tiny_problem()
    re = prob.replan()
    assert np.array_equal(np.asarray(re.sddmm(X, Y).values()),
                          np.asarray(prob.sddmm(X, Y).values()))


def test_schedule_events_cover_all_ops():
    prob, _, _ = tiny_problem()
    for op in faults.OPS:
        els = prob.alg.elisions if op == "fusedmm" else ("none",)
        for el in els:
            ev = prob.alg.schedule_events(prob, op, el)
            assert ev, f"{prob.alg.name}.{op}[{el}] has an empty schedule"
            assert all(pt in faults.POINTS for pt, _ in ev)


# --- trainer wiring ---------------------------------------------------------

def test_trainer_monitor_checkpoint_and_fault(tmp_path):
    """train_embedding_distributed drives the whole stack on one device:
    StepMonitor observes every step, checkpoints carry meta_dict, an
    injected transient fault is recovered, and the run resumes from the
    committed step."""
    from repro.apps import als
    from repro.training import checkpoint

    mon = elastic.StepMonitor()
    d = str(tmp_path / "ck")
    plan = faults.FaultPlan.scripted(
        faults.FaultSpec(op="sddmm", round=1))
    with faults.inject(plan) as ctl:
        X, Y, hist = als.train_embedding_distributed(
            m=32, n=32, nnz_per_row=3, r=4, steps=4, monitor=mon,
            ckpt_dir=d, ckpt_every=2, devices=_dev1(), verbose=False)
    assert len(ctl.fired) == 1 and len(hist) == 4
    assert len(mon._times) >= 4          # every step (incl. retry) timed
    meta = checkpoint.load_manifest(d, 4)["meta"]
    assert meta["p"] == 1 and "coo_digest" in meta
    # resume: nothing left to do, factors restored bitwise
    X2, Y2, h2 = als.train_embedding_distributed(
        m=32, n=32, nnz_per_row=3, r=4, steps=4, ckpt_dir=d,
        devices=_dev1(), verbose=False)
    assert h2 == [] and np.array_equal(np.asarray(X), np.asarray(X2))


def test_gat_trainer_checkpoint_and_fault(tmp_path):
    from repro.apps import gat
    from repro.training import checkpoint

    prob, _, _ = tiny_problem(m=32, n=32, r=4)
    rng = np.random.default_rng(2)
    H = rng.standard_normal((32, 6)).astype(np.float32)
    target = rng.standard_normal((32, 4)).astype(np.float32)
    d = str(tmp_path / "ck")
    plan = faults.FaultPlan.scripted(faults.FaultSpec(op="spmm", round=0))
    with faults.inject(plan) as ctl:
        params, hist = gat.train_gat_distributed(
            prob, H, target, steps=4, ckpt_dir=d, ckpt_every=2,
            verbose=False)
    assert len(ctl.fired) == 1 and len(hist) == 4
    assert checkpoint.load_manifest(d, 4)["meta"]["family"] == prob.alg.name
    params2, h2 = gat.train_gat_distributed(
        prob, H, target, steps=4, ckpt_dir=d, verbose=False)
    assert h2 == []
    for a, b in zip(params, params2):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# --- straggler monitor (fake clock) ----------------------------------------

def test_step_monitor_timed_fake_clock():
    t = {"now": 0.0}

    def clock():
        return t["now"]

    flagged = []
    mon = elastic.StepMonitor(straggler_factor=2.0, clock=clock,
                              on_straggler=lambda s, sec, med:
                              flagged.append((s, sec)))

    def work(cost):
        t["now"] += cost
        return np.zeros(1)

    for i in range(5):
        mon.timed(i, work, 1.0)
    mon.timed(5, work, 5.0)        # 5x the median: flagged
    mon.timed(6, work, 1.0)
    assert flagged == [(5, 5.0)]
    assert mon.flagged == [5]
