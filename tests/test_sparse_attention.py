"""Block-sparse attention (beyond-paper integration) tests."""
import numpy as np
import jax.numpy as jnp

from repro.core.sparse_attention import (build_causal_block_mask,
                                         dense_reference,
                                         sparse_attention_head,
                                         sparsity_stats)


def test_mask_is_causal_and_windowed():
    seq, block, w = 256, 32, 2
    mask = build_causal_block_mask(seq, block, w, global_blocks=1)
    d = np.asarray(mask.to_dense())
    # causal
    assert np.triu(d, 1).sum() == 0
    # every row attends to itself
    assert all(d[i, i] != 0 for i in range(seq))
    # window bound: beyond window+global, nothing
    assert d[200, 64] == 0          # outside window, not global
    assert d[200, 10] != 0          # global block 0
    stats = sparsity_stats(mask, seq, 64)
    assert 0 < stats["fraction"] < 0.5


def test_sparse_attention_matches_dense_masked():
    seq, hd = 256, 32
    mask = build_causal_block_mask(seq, 32, 2, row_tile=64, nz_block=64)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((seq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((seq, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((seq, hd)), jnp.float32)
    out = sparse_attention_head(q, k, v, mask)
    want = dense_reference(q, k, v, np.asarray(mask.to_dense()))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_probs_rows_sum_to_one():
    from repro.core.sparse_attention import row_softmax
    from repro.kernels import ops
    seq, hd = 128, 16
    mask = build_causal_block_mask(seq, 16, 2, row_tile=32, nz_block=32)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((seq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((seq, hd)), jnp.float32)
    probs = row_softmax(ops.sddmm(q, k, mask))
    sums = np.asarray(probs.to_dense()).sum(1)
    np.testing.assert_allclose(sums, 1.0, rtol=1e-5)
