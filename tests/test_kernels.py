"""Per-kernel validation: Pallas (interpret) vs pure-jnp oracle.

Sweeps shapes/dtypes and runs hypothesis property tests on the kernel
invariants (duality, linearity, masking).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to the deterministic sampler
    from _propcheck import given, settings, strategies as st

from repro.core import sparse
from repro.kernels import ops, ref


def make_problem(m, n, r, nnz_per_row, seed, dtype=jnp.float32,
                 row_tile=128, nz_block=64):
    rng = np.random.default_rng(seed)
    rows, cols, vals = sparse.erdos_renyi(m, n, nnz_per_row, seed=seed)
    S = sparse.pack_row_tiled(rows, cols, vals, (m, n),
                              row_tile=row_tile, nz_block=nz_block)
    A = jnp.asarray(rng.standard_normal((m, r)), dtype)
    B = jnp.asarray(rng.standard_normal((n, r)), dtype)
    Sd = np.zeros((m, n), np.float32)
    Sd[rows, cols] = vals
    return S, A, B, jnp.asarray(Sd)


SHAPES = [
    (128, 128, 64, 4),
    (256, 128, 128, 8),
    (512, 384, 128, 8),
    (384, 512, 256, 2),
    (128, 640, 32, 16),
]


@pytest.mark.parametrize("m,n,r,k", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sddmm_matches_oracle(m, n, r, k, dtype):
    S, A, B, Sd = make_problem(m, n, r, k, seed=m + r, dtype=dtype)
    got = ops.sddmm(A, B, S).to_dense().astype(jnp.float32)
    want = ref.sddmm_dense(A.astype(jnp.float32), B.astype(jnp.float32), Sd)
    tol = 2e-5 if dtype == jnp.float32 else 0.12 * np.sqrt(r) / 8
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("m,n,r,k", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_matches_oracle(m, n, r, k, dtype):
    S, A, B, Sd = make_problem(m, n, r, k, seed=2 * m + r, dtype=dtype)
    got = ops.spmm(S, B).astype(jnp.float32)
    want = Sd @ B.astype(jnp.float32)
    tol = 2e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("m,n,r,k", SHAPES[:3])
def test_fusedmm_matches_composition(m, n, r, k):
    S, A, B, Sd = make_problem(m, n, r, k, seed=3 * m + r)
    got_out, got_R = ops.fusedmm(A, B, S)
    # fused == explicit SDDMM followed by explicit SpMM
    R2 = ops.sddmm(A, B, S)
    out2 = ops.spmm(R2, B)
    np.testing.assert_allclose(np.asarray(got_out), np.asarray(out2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_R.vals), np.asarray(R2.vals),
                               rtol=2e-5, atol=2e-5)
    # ... and matches the dense oracle
    want_out, _ = ref.fusedmm_dense(A, B, Sd)
    np.testing.assert_allclose(np.asarray(got_out), np.asarray(want_out),
                               rtol=2e-3, atol=2e-3)


def test_spmmb_via_transpose_pack():
    """SpMMB(S, A) == SpMMA(S^T, A): the paper stores a transposed copy."""
    m, n, r = 256, 384, 64
    rng = np.random.default_rng(7)
    rows, cols, vals = sparse.erdos_renyi(m, n, 6, seed=7)
    St = sparse.pack_row_tiled(cols, rows, vals, (n, m), row_tile=128,
                               nz_block=64)
    A = jnp.asarray(rng.standard_normal((m, r)), jnp.float32)
    Sd = np.zeros((m, n), np.float32)
    Sd[rows, cols] = vals
    got = ops.spmm(St, A)
    np.testing.assert_allclose(np.asarray(got), Sd.T @ np.asarray(A),
                               rtol=2e-4, atol=2e-4)


def test_empty_rows_are_zero():
    """Row tiles with no nonzeros must produce exact zeros."""
    m, n, r = 512, 128, 64
    rows = np.array([0, 1, 2], np.int32)       # only tile 0 touched
    cols = np.array([5, 6, 7], np.int32)
    vals = np.ones(3, np.float32)
    S = sparse.pack_row_tiled(rows, cols, vals, (m, n), row_tile=128,
                              nz_block=64)
    B = jnp.ones((n, r), jnp.float32)
    out = np.asarray(ops.spmm(S, B))
    assert np.all(out[128:] == 0.0)
    assert np.all(out[:3] == 1.0)


# ---------------------------------------------------------------------------
# Property-based tests (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30),
       m=st.sampled_from([128, 256]),
       n=st.sampled_from([128, 256]),
       r=st.sampled_from([32, 64, 128]),
       k=st.integers(1, 12))
def test_property_sddmm_equals_masked_gemm(seed, m, n, r, k):
    S, A, B, Sd = make_problem(m, n, r, k, seed=seed)
    got = ops.sddmm(A, B, S).to_dense()
    want = Sd * (A @ B.T)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=5e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30), alpha=st.floats(-2, 2))
def test_property_spmm_linearity(seed, alpha):
    """SpMM(alpha*S, B) == alpha * SpMM(S, B) (linearity in values)."""
    S, A, B, Sd = make_problem(256, 128, 64, 4, seed=seed)
    lhs = ops.spmm(S.with_vals(S.vals * alpha), B)
    rhs = alpha * ops.spmm(S, B)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_property_sddmm_mask_idempotent(seed):
    """SDDMM with vals=1 then re-sample == same sample values scaled."""
    S, A, B, Sd = make_problem(128, 128, 32, 4, seed=seed)
    ones = S.with_vals(jnp.where(S.vals != 0, 1.0, 0.0).astype(jnp.float32))
    R1 = ops.sddmm(A, B, ones)
    R2 = ops.sddmm(A, B, R1)  # samples (A B^T) again, scaled by R1
    want = np.asarray(R1.vals) ** 2 / np.where(np.asarray(ones.vals) == 0, 1,
                                               np.asarray(ones.vals))
    np.testing.assert_allclose(np.asarray(R2.vals), want, rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_property_fused_equals_unfused(seed):
    S, A, B, Sd = make_problem(256, 256, 64, 6, seed=seed)
    fused_out, fused_R = ops.fusedmm(A, B, S)
    R = ops.sddmm(A, B, S)
    unfused = ops.spmm(R, B)
    np.testing.assert_allclose(np.asarray(fused_out), np.asarray(unfused),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# VMEM tiling knobs: r_tile / blocks_per_step (see DESIGN.md)
# ---------------------------------------------------------------------------

TILINGS = [  # (r_tile, blocks_per_step) against r=128 packs with group=4
    (128, 1),   # whole-r residency, single block per step (baseline)
    (64, 1),    # r tiled into 2 VMEM slabs
    (32, 2),    # 4 slabs x 2-block steps
    (32, 4),    # 4 slabs x 4-block steps
]


def make_tiled_problem(m, n, k, seed, dtype, r=128):
    rng = np.random.default_rng(seed)
    rows, cols, vals = sparse.erdos_renyi(m, n, k, seed=seed)
    S = sparse.pack_row_tiled(rows, cols, vals, (m, n), row_tile=64,
                              nz_block=32, group=4)
    A = jnp.asarray(rng.standard_normal((m, r)), dtype)
    B = jnp.asarray(rng.standard_normal((n, r)), dtype)
    return S, A, B


@pytest.mark.parametrize("r_tile,bps", TILINGS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sddmm_tiling_equivalence(r_tile, bps, dtype):
    S, A, B = make_tiled_problem(256, 192, 6, seed=11, dtype=dtype)
    got = ops.sddmm(A, B, S, r_tile=r_tile, blocks_per_step=bps).vals
    want = ref.sddmm(A, B, S).vals
    tol = 2e-4 if dtype == jnp.float32 else 0.12 * np.sqrt(128) / 8
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("r_tile,bps", TILINGS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_tiling_equivalence(r_tile, bps, dtype):
    S, A, B = make_tiled_problem(256, 192, 6, seed=13, dtype=dtype)
    got = ops.spmm(S, B, r_tile=r_tile, blocks_per_step=bps)
    want = ref.spmm(S, B)
    tol = 2e-4 if dtype == jnp.float32 else 0.2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("r_tile,bps", TILINGS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fusedmm_tiling_equivalence(r_tile, bps, dtype):
    """Covers both fused paths: single-phase (r_tile==r) and two-phase."""
    S, A, B = make_tiled_problem(256, 192, 6, seed=17, dtype=dtype)
    got_out, got_R = ops.fusedmm(A, B, S, r_tile=r_tile, blocks_per_step=bps)
    want_out, want_R = ref.fusedmm(A, B, S)
    tol = 2e-3 if dtype == jnp.float32 else 0.5
    np.testing.assert_allclose(np.asarray(got_out, np.float32),
                               np.asarray(want_out, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got_R.vals, np.float32),
                               np.asarray(want_R.vals, np.float32),
                               rtol=tol, atol=tol)


def test_grouped_pack_feasibility():
    """group=g packing must make every blocks_per_step dividing g legal."""
    from repro.core import costmodel
    rows, cols, vals = sparse.erdos_renyi(512, 256, 3, seed=5)
    S = sparse.pack_row_tiled(rows, cols, vals, (512, 256), row_tile=64,
                              nz_block=32, group=4)
    assert S.nblocks % 4 == 0
    tb = np.asarray(S.tile_base)
    for g in (2, 4):
        groups = tb.reshape(-1, g)
        assert (groups == groups[:, :1]).all()
    assert costmodel.groupable_blocks_per_step(tb, S.nz_block, cap=4) == 4
    # and the matrix survives the padding round-trip
    dense = np.zeros((512, 256), np.float32)
    dense[rows, cols] = vals
    np.testing.assert_array_equal(np.asarray(S.to_dense()), dense)


def test_choose_tiling_respects_vmem_budget():
    from repro.core import costmodel
    t = costmodel.choose_tiling(n_b=1 << 16, r=1024, nb=64, k=256,
                                row_tile=256,
                                vmem_budget=8 * 1024 * 1024)
    assert 1024 % t.r_tile == 0 and t.r_tile < 1024
    assert 2 * (1 << 16) * t.r_tile * 4 <= 8 * 1024 * 1024 or t.r_tile <= 128
    # small problems keep full-r residency
    t2 = costmodel.choose_tiling(n_b=256, r=128, nb=8, k=32, row_tile=64)
    assert t2.r_tile == 128


def test_packer_roundtrip():
    """pack_row_tiled must preserve the matrix exactly."""
    rows, cols, vals = sparse.erdos_renyi(384, 256, 5, seed=3)
    S = sparse.pack_row_tiled(rows, cols, vals, (384, 256), row_tile=128,
                              nz_block=32)
    dense = np.zeros((384, 256), np.float32)
    dense[rows, cols] = vals
    np.testing.assert_array_equal(np.asarray(S.to_dense()), dense)
    # row-window invariant
    rg = np.asarray(S.rows_global())
    base = np.asarray(S.tile_base)[:, None]
    mask = np.asarray(S.vals) != 0
    assert np.all((rg >= base)[mask] & (rg < base + S.row_tile)[mask])
    # tile bases non-decreasing (Pallas revisit requirement)
    assert np.all(np.diff(np.asarray(S.tile_base)) >= 0)
