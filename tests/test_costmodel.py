"""Unit + property tests for the alpha-beta-gamma cost model (Tables III/IV)."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to the deterministic sampler
    from _propcheck import given, settings, strategies as st

from repro.core import costmodel as cm


def test_optimal_c_closed_forms():
    p = 256
    assert cm.optimal_c("d15_no_elision", p=p) == pytest.approx(16.0)
    assert cm.optimal_c("d15_replication_reuse", p=p) == pytest.approx(
        math.sqrt(512))
    assert cm.optimal_c("d15_local_fusion", p=p) == pytest.approx(
        math.sqrt(128))
    # reuse raises the optimal c, fusion lowers it (paper Fig. 1 insight)
    assert (cm.optimal_c("d15_local_fusion", p=p)
            < cm.optimal_c("d15_no_elision", p=p)
            < cm.optimal_c("d15_replication_reuse", p=p))


@settings(max_examples=40, deadline=None)
@given(p=st.sampled_from([16, 64, 256, 1024]),
       phi=st.floats(0.01, 8.0))
def test_property_closed_form_c_minimizes_words(p, phi):
    """Table IV's c* must (approximately) minimize Table III's words."""
    n, r = 1 << 20, 128
    nnz = int(phi * n * r)
    for alg in ("d15_no_elision", "d15_replication_reuse",
                "d15_local_fusion", "s15_replication_reuse"):
        cstar = cm.optimal_c(alg, p=p, phi=phi)
        best = cm.best_c(alg, p=p, n=n, r=r, nnz=nnz)
        # the best integer c must be within a factor ~2.1 of the continuous
        # optimum (integrality + divisibility gaps)
        if 1.0 <= cstar <= p:
            assert best.c / cstar < 4.0 and cstar / best.c < 4.0


@settings(max_examples=30, deadline=None)
@given(p=st.sampled_from([64, 256, 1024]), phi=st.floats(0.005, 4.0))
def test_property_elision_saves_communication(p, phi):
    """At their best c, both elision strategies beat the plain sequence."""
    n, r = 1 << 20, 128
    nnz = int(phi * n * r)
    base = cm.best_c("d15_no_elision", p=p, n=n, r=r, nnz=nnz).words
    reuse = cm.best_c("d15_replication_reuse", p=p, n=n, r=r, nnz=nnz).words
    fused = cm.best_c("d15_local_fusion", p=p, n=n, r=r, nnz=nnz).words
    assert reuse <= base + 1e-6
    assert fused <= base + 1e-6


def test_elision_limit_ratio():
    """Paper: both strategies tend to 1/sqrt(2) of the unfused cost."""
    p, n, r = 2 ** 16, 1 << 22, 256
    nnz = n * 32
    base = cm.best_c("d15_no_elision", p=p, n=n, r=r, nnz=nnz).words
    reuse = cm.best_c("d15_replication_reuse", p=p, n=n, r=r, nnz=nnz).words
    fused = cm.best_c("d15_local_fusion", p=p, n=n, r=r, nnz=nnz).words
    assert reuse / base == pytest.approx(1 / math.sqrt(2), rel=0.08)
    assert fused / base == pytest.approx(1 / math.sqrt(2), rel=0.08)


def test_regime_rule_phi():
    """Low phi -> sparse shifting wins; high phi -> dense shifting wins
    (paper Fig. 6)."""
    p, n, r = 32, 1 << 22, 128
    lo = cm.select_algorithm(p=p, n=n, r=r, nnz=int(0.02 * n * r),
                             candidates=("d15_replication_reuse",
                                         "s15_replication_reuse"))
    hi = cm.select_algorithm(p=p, n=n, r=r, nnz=int(2.0 * n * r),
                             candidates=("d15_replication_reuse",
                                         "s15_replication_reuse"))
    assert next(iter(lo)) == "s15_replication_reuse"
    assert next(iter(hi)) == "d15_replication_reuse"


def test_weak_scaling_setup1_projection():
    """Setup 1: communication time scales ~sqrt(p) for 1.5D algorithms."""
    r, nnz_row = 256, 32
    words = {}
    for p in (4, 16, 64, 256):
        n = 65536 * p
        nnz = n * nnz_row
        words[p] = cm.best_c("d15_no_elision", p=p, n=n, r=r, nnz=nnz).words
    # words per proc ~ n*r/sqrt(p) ~ 65536*r*sqrt(p)
    g1 = words[64] / words[16]
    g2 = words[256] / words[64]
    assert g1 == pytest.approx(2.0, rel=0.35)
    assert g2 == pytest.approx(2.0, rel=0.35)


def test_full_elision_grid_has_cost_rows():
    """Every Table-III row evaluates at a legal (p, c) and the grid
    covers every (family, elision) pair the executors implement."""
    for alg in cm.ALGORITHMS:
        cost = cm.words_fusedmm(alg, p=16, c=4, n=1 << 12, r=64,
                                nnz=1 << 14)
        assert cost.words > 0 and cost.messages > 0, alg
        assert cm.optimal_c(alg, p=256, phi=0.25) > 0, alg
    fams = {fam for fam, _ in cm.FAMILY_ELISION.values()}
    assert fams == set(cm.FAMILIES)
    for fam in cm.FAMILIES:
        els = {el for f, el in cm.FAMILY_ELISION.values() if f == fam}
        assert "none" in els and "reuse" in els, fam
        # s25 local fusion is structurally impossible (docs/algorithms.md)
        assert ("fused" in els) == (fam != "s25"), fam


@settings(max_examples=30, deadline=None)
@given(p=st.sampled_from([16, 64, 256]), phi=st.floats(0.005, 4.0))
def test_property_new_cells_elide_communication(p, phi):
    """The one-structure-pass / B-chunk-reuse cells beat their family's
    unoptimized sequence at every common feasible c."""
    n, r = 1 << 20, 128
    nnz = int(phi * n * r)
    for base_alg, better in (("s15_no_elision", "s15_replication_reuse"),
                             ("s15_no_elision", "s15_local_fusion"),
                             ("s15_replication_reuse", "s15_local_fusion"),
                             ("d25_no_elision", "d25_local_fusion"),
                             ("s25_no_elision", "s25_replication_reuse")):
        for c in cm.feasible_cs(base_alg, p):
            w0 = cm.words_fusedmm(base_alg, p=p, c=c, n=n, r=r, nnz=nnz)
            w1 = cm.words_fusedmm(better, p=p, c=c, n=n, r=r, nnz=nnz)
            assert w1.words <= w0.words + 1e-6, (base_alg, better, c)


def test_optimal_c_2_5d_closed_forms_minimize_words():
    """The 2.5D closed forms must equal the analytic argmin of their own
    words row (regression: s25_no_elision once inverted the fraction)."""
    p, phi = 256, 0.25
    assert cm.optimal_c("s25_no_elision", p=p, phi=phi) == pytest.approx(
        (4 * p / (9 * phi ** 2)) ** (1 / 3))
    assert cm.optimal_c("s25_replication_reuse", p=p, phi=phi) == \
        pytest.approx((p / (4 * phi ** 2)) ** (1 / 3))
    assert cm.optimal_c("d25_local_fusion", p=p, phi=phi) == pytest.approx(
        (p * (1 + 4 * phi) ** 2 / 16) ** (1 / 3))
    # numeric sanity: on a dense feasible grid the words at the nearest
    # feasible c to c* are no worse than at the farthest
    n, r = 1 << 16, 128
    nnz = int(phi * n * r)
    for alg in ("s25_no_elision", "s25_replication_reuse",
                "d25_local_fusion"):
        cstar = cm.optimal_c(alg, p=p, phi=phi)
        cs = cm.feasible_cs(alg, p)
        near = min(cs, key=lambda c: abs(c - cstar))
        far = max(cs, key=lambda c: abs(c - cstar))
        w = {c: cm.words_fusedmm(alg, p=p, c=c, n=n, r=r, nnz=nnz).words
             for c in (near, far)}
        assert w[near] <= w[far], (alg, cstar, w)


def test_choose_algorithm_prefers_fused_at_low_phi():
    """Satellite: the completed grid lets algorithm="auto" land on a
    fused cell in the sparse regime (s15 one-structure-pass) instead of
    degenerating to the paper's reuse-only s15 row."""
    kw = dict(m=1 << 16, n=1 << 16, r=128, p=64)
    ch = cm.choose_algorithm(nnz=int(0.02 * kw["n"] * kw["r"]), **kw)
    assert (ch.family, ch.elision) == ("s15", "fused"), ch
    # and in the dense regime the d15 fused cell keeps its Table-III win
    hi = cm.choose_algorithm(nnz=int(4.0 * kw["n"] * kw["r"]), **kw)
    assert hi.family == "d15", hi


def test_session_cached_words_flip_to_reuse():
    """Inside a cached loop (api.Session steady state) d15 "reuse" drops
    to its shift words alone and overtakes "fused" at large c, flipping
    the auto choice — the documented rule of docs/choosing.md."""
    kw = dict(p=16, c=4, n=1 << 16, r=128, nnz=1 << 20)
    fused_u = cm.words_fusedmm("d15_local_fusion", **kw).words
    reuse_u = cm.words_fusedmm("d15_replication_reuse", **kw).words
    assert fused_u < reuse_u          # uncached: fused wins
    fused_c = cm.words_fusedmm_cached("d15_local_fusion", **kw).words
    reuse_c = cm.words_fusedmm_cached("d15_replication_reuse", **kw).words
    assert reuse_c < fused_c          # Session steady state: reuse wins
    assert fused_c == fused_u         # fused gathers the changing operand
    # on s15 both operands replicate through the Session and "fused"
    # keeps its 4phi-vs-6phi shift advantage: no flip
    sf = cm.words_fusedmm_cached("s15_local_fusion", **kw).words
    sr = cm.words_fusedmm_cached("s15_replication_reuse", **kw).words
    assert sf < sr


def test_words_spmm_is_half_the_unfused_fusedmm():
    """FusedMM "none" is exactly two kernel rounds, so each family's
    single-SpMM row must be half its no-elision FusedMM row (that is the
    decomposition words_fusedmm_bwd builds on).  The one exception is
    s25, whose 3 fiber value trips split 2 (SDDMM: partial RS + home
    scatter) / 1 (SpMM: values AG) rather than 1.5/1.5 — the SpMM row
    carries exactly one phi trip."""
    kw = dict(p=16, c=4, n=1 << 14, r=64, nnz=1 << 16)
    for fam in ("d15", "s15", "d25"):
        sp = cm.words_spmm(fam, **kw).words
        fm = cm.words_fusedmm(f"{fam}_no_elision", **kw).words
        assert sp == pytest.approx(fm / 2, rel=1e-6), fam
    import math as _m
    p, c, n, r, nnz = (kw[k] for k in ("p", "c", "n", "r", "nnz"))
    want = n * r * 2 / _m.sqrt(p * c) \
        + (nnz / (n * r)) * n * r * (c - 1) / p
    assert cm.words_spmm("s25", **kw).words == pytest.approx(want)


def test_words_fusedmm_bwd_composition_and_session():
    """bwd = dual FusedMM (same cell) + two transpose-SpMMs; a threaded
    Session elides SESSION_BWD_ELIDED replication units, strictly
    lowering the backward everywhere a dense operand is replicated."""
    kw = dict(p=16, c=4, n=1 << 14, r=64, nnz=1 << 16)
    for alg in cm.ALGORITHMS:
        fam, _ = cm.FAMILY_ELISION[alg]
        bwd = cm.words_fusedmm_bwd(alg, **kw)
        want = cm.words_fusedmm(alg, **kw).words \
            + 2 * cm.words_spmm(fam, **kw).words
        assert bwd.words == pytest.approx(want, rel=1e-6), alg
        cached = cm.words_fusedmm_bwd(alg, session=True, **kw)
        saved = cm.SESSION_BWD_ELIDED[fam] * kw["n"] * kw["r"] \
            * (kw["c"] - 1) / kw["p"]
        assert cached.words == pytest.approx(want - saved, rel=1e-6), alg
        if fam == "s25":
            assert cached.words == bwd.words      # nothing replicated
        else:
            assert cached.words < bwd.words, alg


def test_words_trainstep_fwd_plus_bwd():
    kw = dict(p=16, c=4, n=1 << 14, r=64, nnz=1 << 16)
    for alg in cm.ALGORITHMS:
        step = cm.words_trainstep(alg, **kw)
        want = cm.words_fusedmm(alg, **kw).words \
            + cm.words_fusedmm_bwd(alg, **kw).words
        assert step.words == pytest.approx(want, rel=1e-6), alg
        # the forward always pays its gather (it fills the Session) —
        # only the backward is credited
        sess = cm.words_trainstep(alg, session=True, **kw)
        bwd_saving = cm.words_fusedmm_bwd(alg, **kw).words \
            - cm.words_fusedmm_bwd(alg, session=True, **kw).words
        assert sess.words == pytest.approx(want - bwd_saving, rel=1e-6)


@settings(max_examples=25, deadline=None)
@given(p=st.sampled_from([16, 64, 256]), phi=st.floats(0.01, 4.0))
def test_property_optimal_c_trainstep_minimizes_trainstep_words(p, phi):
    """The closed-form training-step c* must (approximately) minimize the
    summed fwd+bwd words — the doubled dense traffic shifts it away from
    Table IV's forward-only optimum."""
    n, r = 1 << 18, 128
    nnz = int(phi * n * r)
    for alg in ("d15_no_elision", "d15_replication_reuse",
                "d15_local_fusion", "s15_local_fusion",
                "d25_replication_reuse", "s25_replication_reuse"):
        cstar = cm.optimal_c_trainstep(alg, p=p, phi=phi)
        cs = cm.feasible_cs(alg, p)
        words = {c: cm.words_trainstep(alg, p=p, c=c, n=n, r=r,
                                       nnz=nnz).words for c in cs}
        best = min(words, key=words.get)
        if 1.0 <= cstar <= p:
            assert best / cstar < 4.0 and cstar / best < 4.0, (alg, cstar)


def test_trainstep_coef_table_matches_word_counts_exactly():
    """_TRAINSTEP_COEFS must reproduce words_trainstep EXACTLY at every
    cell — a drifted coefficient (e.g. after a future words_fusedmm
    change) fails here, not inside the wide property-test band."""
    n, r = 1 << 14, 64
    for alg, (a0, a_phi, b0, b_phi) in cm._TRAINSTEP_COEFS.items():
        fam, _ = cm.FAMILY_ELISION[alg]
        for p, c in ((16, 4), (64, 4), (16, 2)):
            for nnz in (1 << 14, 1 << 18):
                phi = nnz / (n * r)
                a = a0 + a_phi * phi
                b = b0 + b_phi * phi
                lead = a / c if fam in ("d15", "s15") \
                    else a / math.sqrt(p * c)
                want = n * r * (lead + b * (c - 1) / p)
                got = cm.words_trainstep(alg, p=p, c=c, n=n, r=r,
                                         nnz=nnz).words
                assert got == pytest.approx(want, rel=1e-9), (alg, p, c)


def test_optimal_c_trainstep_shifts_from_forward_only():
    """The documented example: d15 "reuse" moves from sqrt(2p) (fwd-only)
    to sqrt(1.5p) for a training step, and a Session pushes it back up."""
    p = 256
    fwd = cm.optimal_c("d15_replication_reuse", p=p)
    step = cm.optimal_c_trainstep("d15_replication_reuse", p=p)
    assert fwd == pytest.approx(math.sqrt(2 * p))
    assert step == pytest.approx(math.sqrt(1.5 * p))
    assert step < fwd
    sess = cm.optimal_c_trainstep("d15_replication_reuse", p=p,
                                  session=True)
    assert sess > step
    assert sess == pytest.approx(math.sqrt(3 * p))


def test_message_counts():
    c1 = cm.words_fusedmm("d15_no_elision", p=64, c=4, n=1 << 16, r=64,
                          nnz=1 << 18)
    assert c1.messages == 2 * 64 / 4 + 2 * 3
    c2 = cm.words_fusedmm("d15_local_fusion", p=64, c=4, n=1 << 16, r=64,
                          nnz=1 << 18)
    assert c2.messages == 64 / 4 + 2 * 3


def test_support_density_and_choose_comm_rule():
    import numpy as np
    rows = np.array([0, 1, 2, 3])
    cols = np.array([0, 0, 1, 1])
    assert cm.support_density(rows, cols, 8, 8) == (0.5, 0.25)
    assert cm.choose_comm(rows, cols, 8, 8) == "sparse"
    # full support on both axes: index+pad overhead loses -> dense
    full = np.arange(8)
    assert cm.support_density(full, full, 8, 8) == (1.0, 1.0)
    assert cm.choose_comm(full, full, 8, 8) == "dense"
    # ONE sparse side is enough (channels fall back independently)
    assert cm.choose_comm(full, np.zeros(8, int), 8, 8) == "sparse"


def test_words_sparse_monotone_in_support_density():
    """The nnz-dependent word formulas shrink monotonically with the
    support densities and beat the dense Table-III rows outright in the
    skewed regime (rho = 0.1) — the comm="auto" premise."""
    kw = dict(p=64, c=4, m=1 << 14, n=1 << 14, r=128, nnz=1 << 18)
    dkw = dict(p=64, c=4, n=1 << 14, r=128, nnz=1 << 18)
    for alg in sorted(cm.FAMILY_ELISION):
        dense = cm.words_fusedmm(alg, **dkw).words
        prev = None
        for rho in (1.0, 0.7, 0.5, 0.3, 0.1):
            w = cm.words_fusedmm_sparse(alg, rho_row=rho, rho_col=rho,
                                        **kw).words
            assert w > 0
            if prev is not None:
                assert w <= prev + 1e-6, (alg, rho)
            prev = w
        assert prev < dense, alg
    for fam in cm.FAMILIES:
        dense = cm.words_spmm(fam, **dkw).words
        hi = cm.words_spmm_sparse(fam, rho_row=1.0, rho_col=1.0, **kw).words
        lo = cm.words_spmm_sparse(fam, rho_row=0.1, rho_col=0.1, **kw).words
        assert lo <= hi + 1e-6 and lo < dense, fam
