"""Unit + property tests for the alpha-beta-gamma cost model (Tables III/IV)."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to the deterministic sampler
    from _propcheck import given, settings, strategies as st

from repro.core import costmodel as cm


def test_optimal_c_closed_forms():
    p = 256
    assert cm.optimal_c("d15_no_elision", p=p) == pytest.approx(16.0)
    assert cm.optimal_c("d15_replication_reuse", p=p) == pytest.approx(
        math.sqrt(512))
    assert cm.optimal_c("d15_local_fusion", p=p) == pytest.approx(
        math.sqrt(128))
    # reuse raises the optimal c, fusion lowers it (paper Fig. 1 insight)
    assert (cm.optimal_c("d15_local_fusion", p=p)
            < cm.optimal_c("d15_no_elision", p=p)
            < cm.optimal_c("d15_replication_reuse", p=p))


@settings(max_examples=40, deadline=None)
@given(p=st.sampled_from([16, 64, 256, 1024]),
       phi=st.floats(0.01, 8.0))
def test_property_closed_form_c_minimizes_words(p, phi):
    """Table IV's c* must (approximately) minimize Table III's words."""
    n, r = 1 << 20, 128
    nnz = int(phi * n * r)
    for alg in ("d15_no_elision", "d15_replication_reuse",
                "d15_local_fusion", "s15_replication_reuse"):
        cstar = cm.optimal_c(alg, p=p, phi=phi)
        best = cm.best_c(alg, p=p, n=n, r=r, nnz=nnz)
        # the best integer c must be within a factor ~2.1 of the continuous
        # optimum (integrality + divisibility gaps)
        if 1.0 <= cstar <= p:
            assert best.c / cstar < 4.0 and cstar / best.c < 4.0


@settings(max_examples=30, deadline=None)
@given(p=st.sampled_from([64, 256, 1024]), phi=st.floats(0.005, 4.0))
def test_property_elision_saves_communication(p, phi):
    """At their best c, both elision strategies beat the plain sequence."""
    n, r = 1 << 20, 128
    nnz = int(phi * n * r)
    base = cm.best_c("d15_no_elision", p=p, n=n, r=r, nnz=nnz).words
    reuse = cm.best_c("d15_replication_reuse", p=p, n=n, r=r, nnz=nnz).words
    fused = cm.best_c("d15_local_fusion", p=p, n=n, r=r, nnz=nnz).words
    assert reuse <= base + 1e-6
    assert fused <= base + 1e-6


def test_elision_limit_ratio():
    """Paper: both strategies tend to 1/sqrt(2) of the unfused cost."""
    p, n, r = 2 ** 16, 1 << 22, 256
    nnz = n * 32
    base = cm.best_c("d15_no_elision", p=p, n=n, r=r, nnz=nnz).words
    reuse = cm.best_c("d15_replication_reuse", p=p, n=n, r=r, nnz=nnz).words
    fused = cm.best_c("d15_local_fusion", p=p, n=n, r=r, nnz=nnz).words
    assert reuse / base == pytest.approx(1 / math.sqrt(2), rel=0.08)
    assert fused / base == pytest.approx(1 / math.sqrt(2), rel=0.08)


def test_regime_rule_phi():
    """Low phi -> sparse shifting wins; high phi -> dense shifting wins
    (paper Fig. 6)."""
    p, n, r = 32, 1 << 22, 128
    lo = cm.select_algorithm(p=p, n=n, r=r, nnz=int(0.02 * n * r),
                             candidates=("d15_replication_reuse",
                                         "s15_replication_reuse"))
    hi = cm.select_algorithm(p=p, n=n, r=r, nnz=int(2.0 * n * r),
                             candidates=("d15_replication_reuse",
                                         "s15_replication_reuse"))
    assert next(iter(lo)) == "s15_replication_reuse"
    assert next(iter(hi)) == "d15_replication_reuse"


def test_weak_scaling_setup1_projection():
    """Setup 1: communication time scales ~sqrt(p) for 1.5D algorithms."""
    r, nnz_row = 256, 32
    words = {}
    for p in (4, 16, 64, 256):
        n = 65536 * p
        nnz = n * nnz_row
        words[p] = cm.best_c("d15_no_elision", p=p, n=n, r=r, nnz=nnz).words
    # words per proc ~ n*r/sqrt(p) ~ 65536*r*sqrt(p)
    g1 = words[64] / words[16]
    g2 = words[256] / words[64]
    assert g1 == pytest.approx(2.0, rel=0.35)
    assert g2 == pytest.approx(2.0, rel=0.35)


def test_message_counts():
    c1 = cm.words_fusedmm("d15_no_elision", p=64, c=4, n=1 << 16, r=64,
                          nnz=1 << 18)
    assert c1.messages == 2 * 64 / 4 + 2 * 3
    c2 = cm.words_fusedmm("d15_local_fusion", p=64, c=4, n=1 << 16, r=64,
                          nnz=1 << 18)
    assert c2.messages == 64 / 4 + 2 * 3
