"""Application-level tests: ALS converges; GAT forward matches dense ref."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.apps import als, gat
from repro.kernels import ops


def test_als_loss_decreases():
    _, _, hist = als.run_als(m=256, n=256, nnz_per_row=6, r=16, rounds=3,
                             cg_iters=8, verbose=False)
    assert hist[-1] < 0.2 * hist[0], hist


def test_als_cg_solves_normal_equations():
    """CG result must satisfy the per-row normal equations approximately."""
    prob = als.make_problem(128, 128, 5, 8, seed=1)
    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((128, 8)), jnp.float32)
    rhs = ops.spmm(prob.S, B, m=128)
    X = als.cg_solve(prob.mask, B, rhs, prob.reg, 128, iters=40)
    resid = rhs - als.fusedmm_matvec(prob.mask, X, B, prob.reg, 128)
    assert float(jnp.linalg.norm(resid)) < 1e-2 * max(
        float(jnp.linalg.norm(rhs)), 1.0)


def test_gat_row_softmax():
    S = gat.make_graph(64, 4, seed=2)
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.standard_normal(S.vals.shape), jnp.float32)
    vals = jnp.where(S.vals != 0, vals, 0.0)
    sm = gat.row_softmax(S.with_vals(vals))
    dense = np.asarray(sm.to_dense())
    rows_with_nnz = np.asarray(S.to_dense()).sum(1) > 0
    sums = dense.sum(1)
    np.testing.assert_allclose(sums[rows_with_nnz], 1.0, rtol=1e-5)
    assert (dense >= 0).all()


def test_gat_matches_dense_reference():
    n, d, seed = 96, 16, 3
    S = gat.make_graph(n, 4, seed=seed, row_tile=32, nz_block=32)
    rng = np.random.default_rng(seed)
    H = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    p = gat.init_gat_layer(jax.random.PRNGKey(0), d, d)
    out = gat.gat_layer(S, H, p)

    # dense reference
    Sd = np.asarray(S.to_dense()) != 0
    Wh = np.asarray(H @ p.W)
    u = Wh @ np.asarray(p.a1)
    v = Wh @ np.asarray(p.a2)
    e = u[:, None] + v[None, :]
    e = np.where(e >= 0, e, 0.2 * e)
    e = np.where(Sd, e, -np.inf)
    e = e - e.max(axis=1, keepdims=True)
    w = np.exp(e)
    w = np.nan_to_num(w / w.sum(axis=1, keepdims=True))
    want = np.asarray(jax.nn.elu(jnp.asarray(w @ Wh)))
    np.testing.assert_allclose(np.asarray(out), want, rtol=5e-4, atol=5e-4)


def test_gat_multihead_shapes():
    S = gat.make_graph(64, 4, seed=4)
    H = jnp.ones((64, 8), jnp.float32)
    p = gat.init_gat_layer(jax.random.PRNGKey(1), 8, 8)
    out = gat.gat_layer(S, H, p, n_heads=2)
    assert out.shape == (64, 8)
    assert np.isfinite(np.asarray(out)).all()
