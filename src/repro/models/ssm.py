"""Mamba2 block via the SSD (state-space duality) chunked algorithm.

Training computes the sequence in chunks: a quadratic attention-like
intra-chunk term plus an inter-chunk state recurrence carried by
``lax.scan`` — the chunked SSD formulation of Dao & Gu (arXiv:2405.21060),
which maps onto the MXU as batched matmuls.  Decode keeps a recurrent state
(B, H, P, N) and a small conv window, updated in O(1) per token.

Shapes: d_inner = expand*d_model, H = d_inner/head_dim heads, state N.
Single B/C group (G=1), scalar A per head (Mamba2 simplification).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_mamba2(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * N
    ks = jax.random.split(key, 4)
    return {
        # in_proj emits [z (gate), x, B, C, dt]
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * din + 2 * N + H), dtype) * 0.02,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                    dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), dtype),          # A = -exp(A_log) in (-1,0]
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.full((H,), -2.0, dtype),   # softplus(-2) ~ 0.13
        "out_proj": jax.random.normal(ks[3], (din, d), dtype) * 0.02,
        "norm": jnp.ones((din,), dtype),
    }


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv, kernel K: xBC (B, S, C).  state: (B, K-1, C)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(xBC[:, :K - 1])
    else:
        pad = state
    xp = jnp.concatenate([pad, xBC], axis=1)            # (B, S+K-1, C)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i][None, None, :]
              for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu((out + b[None, None, :]).astype(jnp.float32)).astype(
        xBC.dtype), new_state


def _ssd_chunked(x, dt, A, Bm, Cm, chunk):
    """Chunked SSD scan.

    x (B,S,H,P), dt (B,S,H) positive, A (H,) negative, Bm/Cm (B,S,N).
    Returns y (B,S,H,P), final state (B,H,P,N).
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    xc = x.reshape(Bb, nc, chunk, H, P)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = Bm.reshape(Bb, nc, chunk, N)
    Cc = Cm.reshape(Bb, nc, chunk, N)

    dA = dtc * A[None, None, None, :]                   # (B,nc,Q,H) negative
    cum = jnp.cumsum(dA, axis=2)                        # within-chunk cumsum
    total = cum[:, :, -1]                               # (B,nc,H)

    # intra-chunk (quadratic) term: attention-like with decay kernel
    # L[q1,q2] = exp(cum[q1]-cum[q2]) for q1 >= q2
    # NOTE: decomposed into explicit batched matmuls.  A single 4-operand
    # einsum here lowers to broadcast-multiply-reduce with 6-D f32
    # intermediates (gigabytes/device at production shapes) — §Perf log.
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)          # (B,nc,Q,Q)
    W = scores[..., None] * L * dtc[:, :, None, :, :]       # (B,nc,Q,K,H)
    Wt = jnp.moveaxis(W, -1, 2)                             # (B,nc,H,Q,K)
    xt = jnp.moveaxis(xc, 3, 2)                             # (B,nc,H,K,P)
    y_intra = jnp.moveaxis(Wt @ xt, 2, 3)                   # (B,nc,Q,H,P)

    # chunk summaries -> inter-chunk recurrence
    # state_c = sum_q exp(total - cum[q]) * dt[q] * B[q] (x) x[q]
    # NOTE einsum path matters: contracting q FIRST keeps intermediates at
    # (B,nc,H,P,N); a naive 4-operand einsum materializes a 6-D
    # (B,nc,Q,H,P,N) tensor — gigabytes per device (see §Perf log).
    w_end = jnp.exp(total[:, :, None, :] - cum)             # (B,nc,Q,H)
    xw = xc * (w_end * dtc)[..., None]                      # (B,nc,Q,H,P)
    summary = jnp.einsum("bcqn,bcqhp->bchpn", Bc, xw)       # (B,nc,H,P,N)

    def step(state, inp):
        summ, tot = inp                                     # (B,H,P,N),(B,H)
        y_state = state                                     # state BEFORE
        state = state * jnp.exp(tot)[:, :, None, None] + summ
        return state, y_state

    s0 = jnp.zeros((Bb, H, P, N), x.dtype)
    summary_t = jnp.moveaxis(summary, 1, 0)
    total_t = jnp.moveaxis(total, 1, 0)
    final, states = jax.lax.scan(step, s0, (summary_t, total_t))
    states = jnp.moveaxis(states, 0, 1)                     # (B,nc,H,P,N)

    # inter-chunk contribution: y[q] += C[q] . state_begin * exp(cum[q])
    # (contract n first; scaling by exp(cum) afterwards is elementwise)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc, states) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y, final


def mamba2(cfg, pcfg, p, x, batch, cache=None, layer_id=0):
    """Returns (out, new_cache).  cache: dict(conv (B,K-1,C), ssm (B,H,P,N))."""
    B, S, d = x.shape
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xr, Bm, Cm, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + p["dt_bias"][None, None, :].astype(
            jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xBC = jnp.concatenate([xr, Bm, Cm], axis=-1)
    conv_state = None if cache is None else cache["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_state)
    xr, Bm, Cm = jnp.split(xBC, [din, din + N], axis=-1)
    xh = xr.reshape(B, S, H, P)

    if cache is None:
        chunk = min(cfg.ssm_chunk, S)
        y, final = _ssd_chunked(xh.astype(jnp.float32), dt, A,
                                Bm.astype(jnp.float32),
                                Cm.astype(jnp.float32), chunk)
        new_cache = {"conv": new_conv, "ssm": final,
                     "pos": jnp.full((B,), S, jnp.int32)}
    else:
        # O(1) recurrent update: s = s*exp(dt*A) + dt * B (x) x ; y = C.s
        s = cache["ssm"].astype(jnp.float32)                # (B,H,P,N)
        dA = jnp.exp(dt[:, 0] * A[None, :])                 # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                         Bm[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        s = s * dA[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32),
                       s)[:, None]                          # (B,1,H,P)
        new_cache = {"conv": new_conv, "ssm": s.astype(cache["ssm"].dtype),
                     "pos": cache["pos"] + 1}

    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None,
                                                                :, None]
    y = y.reshape(B, S, din).astype(x.dtype)
    # gated RMSNorm (Mamba2's norm-then-gate)
    from repro.models.layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype)), \
        new_cache


def init_mamba2_cache(cfg, B, dtype=jnp.bfloat16):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((B, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), dtype),
        "pos": jnp.zeros((B,), jnp.int32),
    }
