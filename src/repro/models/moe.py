"""Mixture-of-Experts with top-k routing, static capacity, shared experts.

Dispatch is sort-free and static-shape: (token, k)-assignments are ranked
per expert with a cumulative-sum position (drop on overflow — standard
capacity-factor semantics), scattered to (E, C, d) expert buffers, run as a
single grouped einsum (sharded over the "model" axis = expert parallelism),
and combined with the gate weights.

``dispatch="spmm"`` exposes the paper's integration point: the dispatch and
combine are *sparse matrices* (token x (E*C) one-hot with gate values), so
they can run through the repro SpMM kernels.  That path is exercised at
smoke-test scale; the einsum path is the production default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_moe(key, cfg, dtype=jnp.float32):
    d, E, ff = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(ks[0], (d, E), dtype) * 0.02,
        "w1": jax.random.normal(ks[1], (E, d, ff), dtype) * 0.02,
        "w3": jax.random.normal(ks[2], (E, d, ff), dtype) * 0.02,
        "w2": jax.random.normal(ks[3], (E, ff, d), dtype) * 0.02,
    }
    if cfg.moe_shared:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, cfg.moe_shared * ff, dtype)
    return p


def moe(cfg, pcfg, p, x, dispatch: str = "einsum"):
    """x (B, S, d) -> (B, S, d).  Also returns aux losses dict."""
    B, S, d = x.shape
    E, k, ff = cfg.moe_experts, cfg.moe_top_k, cfg.moe_d_ff
    T = B * S
    xf = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_v, gate_i = jax.lax.top_k(probs, k)            # (T, k)
    gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_i.reshape(-1)].add(
        jnp.ones((T * k,), jnp.float32)) / (T * k)
    aux = {"lb_loss": E * jnp.sum(me * ce)}

    C = int(cfg.capacity_factor * T * k / E) or 1
    # rank of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(gate_i, E, dtype=jnp.int32)       # (T, k, E)
    flat = onehot.reshape(T * k, E)
    ranks = (jnp.cumsum(flat, axis=0) - flat)                 # exclusive
    rank = (ranks * flat).sum(-1).reshape(T, k)               # (T, k)
    keep = rank < C
    slot = gate_i * C + jnp.minimum(rank, C - 1)              # (T, k)

    if dispatch == "spmm":
        return _moe_spmm(cfg, p, xf, gate_v, slot, keep, C, B, S), aux

    # scatter tokens into expert buffers (E*C, d)
    buf = jnp.zeros((E * C, d), x.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k))
    buf = buf.at[jnp.where(keep, slot, E * C - 1).reshape(-1)].add(
        jnp.where(keep.reshape(-1, 1), xf[tok_idx.reshape(-1)], 0.0))
    buf = buf.reshape(E, C, d)
    from repro.models.model import constrain
    buf = constrain(buf, pcfg.model_axis, None, None)   # expert parallelism

    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["w3"].astype(x.dtype))
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(x.dtype))
    y = y.reshape(E * C, d)

    # combine in compute dtype: f32 gates here would promote the whole
    # (T*k, d) combine chain AND its backward to f32 (2x the bytes)
    gates = (gate_v * keep).astype(x.dtype)
    out = (y[slot.reshape(-1)].reshape(T, k, d)
           * gates[..., None]).sum(1)
    if cfg.moe_shared:
        from repro.models.layers import swiglu
        out = out + swiglu(xf[None], p["shared"]["w1"], p["shared"]["w3"],
                           p["shared"]["w2"])[0]
    return out.reshape(B, S, d), aux


def _moe_spmm(cfg, p, xf, gate_v, slot, keep, C, B, S):
    """Dispatch/combine as SpMM through the repro sparse kernels.

    dispatch matrix D: (E*C, T) with D[slot, t] = 1      -> buf = D @ x
    combine  matrix G: (T, E*C) with G[t, slot] = gate   -> out = G @ y
    """
    from repro.core.sparse import RowTiledCOO
    from repro.kernels import ops
    import numpy as np  # noqa: F401  (static shapes only)

    T, d = xf.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    K = T * k
    # one nonzero block stream; row-tiling degenerates to one big window
    # (fine at smoke scale; production path is the einsum dispatch)
    disp = RowTiledCOO(
        rows_local=slot.reshape(1, K),
        cols=jnp.broadcast_to(jnp.arange(T)[:, None], (T, k)).reshape(1, K),
        vals=keep.reshape(1, K).astype(xf.dtype),
        tile_base=jnp.zeros((1,), jnp.int32),
        shape=(E * C, T), row_tile=E * C)
    buf = ops.spmm(disp, xf, m=E * C, backend="ref").reshape(E, C, d)
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(xf.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["w3"].astype(xf.dtype))
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(xf.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(xf.dtype)).reshape(
        E * C, d)
    comb = RowTiledCOO(
        rows_local=jnp.broadcast_to(jnp.arange(T)[:, None],
                                    (T, k)).reshape(1, K),
        cols=slot.reshape(1, K),
        vals=(gate_v * keep).reshape(1, K).astype(xf.dtype),
        tile_base=jnp.zeros((1,), jnp.int32),
        shape=(T, E * C), row_tile=T)
    out = ops.spmm(comb, y, m=T, backend="ref")
    if cfg.moe_shared:
        from repro.models.layers import swiglu
        out = out + swiglu(xf[None], p["shared"]["w1"], p["shared"]["w3"],
                           p["shared"]["w2"])[0]
    return out.reshape(B, S, d)
