"""Attention variants: GQA (optional qk_norm), MLA, flash-style chunking.

Training/prefill attention is computed with an online-softmax scan over KV
blocks (pure-JAX flash attention) so the compiled memory footprint is
O(S * block) instead of O(S^2) — this is what lets the 32k prefill cells
fit in the dry-run memory analysis.  Decode attends one query against a
static KV cache with a fill-mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_mrope, apply_rope, rms_norm

NEG_INF = -1e30


def _positions(cfg, batch, B, S, offset=None):
    pos = batch.get("positions")
    if pos is None:
        pos = jnp.arange(S, dtype=jnp.int32)[None, :] + (
            0 if offset is None else offset)
        pos = jnp.broadcast_to(pos, (B, S))
        if cfg.pos_dims == 3:
            pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
    return pos


def _rope(cfg, x, pos):
    if cfg.rope == "none":
        return x
    if cfg.rope == "mrope":
        half = x.shape[-1] // 2
        t = half - 2 * (half // 3)
        return apply_mrope(x, pos, cfg.rope_theta,
                           sections=(t, half // 3, half // 3))
    return apply_rope(x, pos, cfg.rope_theta)


def plain_decode_attention(q, k, v, kv_len):
    """Single-query attention without the KV-block scan.

    Used on the decode path: with a sequence-sharded KV cache the softmax
    normalizer and the value contraction become psum-style collectives
    under GSPMD — the flash-decode pattern, synthesized by the partitioner
    instead of a hand-rolled shard_map (the baseline we then hillclimb).
    """
    B, Sq, H, hd = q.shape
    Sk, KvH = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    rep = H // KvH
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(B, Sq, KvH, rep, hd)
    s = jnp.einsum("bqgrh,bkgh->bqgrk", qf, k.astype(jnp.float32))
    mask = jnp.arange(Sk)[None, :] < kv_len[:, None]          # (B, Sk)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqgrk,bkgh->bqgrh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd_v).astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool, block: int, q_offset=0,
                    kv_len=None):
    """Online-softmax attention, scanning KV in blocks.

    q: (B, Sq, H, hd)   k, v: (B, Sk, KvH, hd) with H % KvH == 0.
    kv_len: optional (B,) valid-length mask for cached decode.
    """
    B, Sq, H, hd = q.shape
    Sk, KvH = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]          # may differ from hd (MLA rope concat)
    rep = H // KvH
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KvH, rep, hd)
    nblk = max(Sk // block, 1)
    block = Sk // nblk
    kb = k.astype(jnp.float32).reshape(B, nblk, block, KvH, hd)
    vb = v.astype(jnp.float32).reshape(B, nblk, block, KvH, hd_v)
    q_idx = jnp.arange(Sq) + q_offset

    def step(carry, inp):
        m, l, acc = carry
        kt, vt, blk_i = inp
        s = jnp.einsum("bqgrh,bkgh->bqgrk", qf, kt)       # (B,Sq,KvH,rep,blk)
        k_idx = blk_i * block + jnp.arange(block)
        mask = jnp.ones((Sq, block), bool)
        if causal:
            mask = q_idx[:, None] >= k_idx[None, :]
        if kv_len is not None:
            mask = mask[None] & (k_idx[None, None, :] < kv_len[:, None, None])
            s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        else:
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum("bqgrk,bkgh->bqgrh", p, vt)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Sq, KvH, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KvH, rep), jnp.float32)
    a0 = jnp.zeros((B, Sq, KvH, rep, hd_v), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)
    vb_t = jnp.moveaxis(vb, 1, 0)
    # flash-style backward: recompute block scores instead of saving the
    # (B,Sq,...,block) probability tensors per step — O(S*block) residuals
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (kb_t, vb_t, jnp.arange(nblk)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd_v).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg, dtype=jnp.float32):
    d, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": jax.random.normal(ks[0], (d, H * hd), dtype) * 0.02,
        "wk": jax.random.normal(ks[1], (d, Kv * hd), dtype) * 0.02,
        "wv": jax.random.normal(ks[2], (d, Kv * hd), dtype) * 0.02,
        "wo": jax.random.normal(ks[3], (H * hd, d), dtype) * 0.02,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def gqa(cfg, pcfg, p, x, batch, cache=None, layer_id=0):
    """Returns (out, new_cache_entry).  cache entry: dict(k, v, pos)."""
    B, S, d = x.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"].astype(x.dtype))
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Kv, hd)
    v = v.reshape(B, S, Kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if cache is None:                      # train / full prefill
        pos = _positions(cfg, batch, B, S)
        q = _rope(cfg, q, pos)
        k = _rope(cfg, k, pos)
        out = flash_attention(q, k, v, causal=cfg.causal,
                              block=pcfg.flash_block)
        new_cache = {"k": k, "v": v,
                     "pos": jnp.full((B,), S, jnp.int32)}
    else:                                  # single-token decode
        fill = cache["pos"]                # (B,)
        pos = fill[:, None]
        if cfg.pos_dims == 3:
            pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
        q = _rope(cfg, q, pos)
        k = _rope(cfg, k, pos)
        # write the new token at its slot via one-hot (position is traced)
        Sc = cache["k"].shape[1]
        onehot = (jnp.arange(Sc)[None, :] == fill[:, None])
        ck = jnp.where(onehot[:, :, None, None], k.astype(cache["k"].dtype),
                       cache["k"])
        cv = jnp.where(onehot[:, :, None, None], v.astype(cache["v"].dtype),
                       cache["v"])
        out = plain_decode_attention(q, ck, cv, fill + 1)
        new_cache = {"k": ck, "v": cv, "pos": fill + 1}

    out = out.reshape(B, S, H * hd)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"].astype(x.dtype)), new_cache


def init_gqa_cache(cfg, B, S, dtype=jnp.bfloat16):
    return {"k": jnp.zeros((B, S, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((B, S, cfg.n_kv_heads, cfg.hd), dtype),
            "pos": jnp.zeros((B,), jnp.int32)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV cache
# ---------------------------------------------------------------------------

def init_mla(key, cfg, dtype=jnp.float32):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    kvl, rd = cfg.mla_kv_lora, cfg.mla_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": jax.random.normal(ks[0], (d, H * (hd + rd)), dtype) * 0.02,
        "wdkv": jax.random.normal(ks[1], (d, kvl), dtype) * 0.02,
        "wkpe": jax.random.normal(ks[2], (d, rd), dtype) * 0.02,
        "wuk": jax.random.normal(ks[3], (kvl, H * hd), dtype) * 0.02,
        "wuv": jax.random.normal(ks[4], (kvl, H * hd), dtype) * 0.02,
        "wo": jax.random.normal(ks[5], (H * hd, d), dtype) * 0.02,
    }


def mla(cfg, pcfg, p, x, batch, cache=None, layer_id=0):
    """Multi-head Latent Attention.  Cache holds only (c_kv, k_pe) —
    (kv_lora + rope_dim) floats per token instead of 2*Kv*hd."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    kvl, rd = cfg.mla_kv_lora, cfg.mla_rope_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, S, H, hd + rd)
    q_nope, q_pe = q[..., :hd], q[..., hd:]
    c_kv = jnp.einsum("bsd,dl->bsl", x, p["wdkv"].astype(x.dtype))
    k_pe = jnp.einsum("bsd,dr->bsr", x, p["wkpe"].astype(x.dtype))

    if cache is None:
        pos = _positions(cfg, batch, B, S)
        fill = jnp.full((B,), S, jnp.int32)
        kv_len = None
    else:
        fill = cache["pos"]
        pos = fill[:, None]
        Sc = cache["c_kv"].shape[1]
        onehot = (jnp.arange(Sc)[None, :] == fill[:, None])
        c_kv = jnp.where(onehot[:, :, None],
                         c_kv.astype(cache["c_kv"].dtype), cache["c_kv"])
        k_pe_new = k_pe
        kv_len = fill + 1

    q_pe = _rope(cfg, q_pe, pos)
    if cache is None:
        k_pe = _rope(cfg, k_pe[:, :, None, :], pos)[:, :, 0]
        new_cache = {"c_kv": c_kv, "k_pe": k_pe, "pos": fill}
        kc, pe_c = c_kv, k_pe
    else:
        k_pe_new = _rope(cfg, k_pe_new[:, :, None, :], pos)[:, :, 0]
        Sc = cache["k_pe"].shape[1]
        onehot = (jnp.arange(Sc)[None, :] == fill[:, None])
        pe_c = jnp.where(onehot[:, :, None],
                         k_pe_new.astype(cache["k_pe"].dtype),
                         cache["k_pe"])
        new_cache = {"c_kv": c_kv, "k_pe": pe_c, "pos": fill + 1}
        kc = c_kv

    # decompress K/V from the latent cache
    k_nope = jnp.einsum("btl,lq->btq", kc,
                        p["wuk"].astype(x.dtype)).reshape(
                            B, -1, H, hd)
    v = jnp.einsum("btl,lq->btq", kc, p["wuv"].astype(x.dtype)).reshape(
        B, -1, H, hd)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(pe_c[:, :, None, :],
                                  k_nope.shape[:3] + (rd,))], -1)
    qf = jnp.concatenate([q_nope, q_pe], -1)
    if kv_len is None:
        out = flash_attention(qf, k, v, causal=cfg.causal,
                              block=pcfg.flash_block)
    else:
        out = plain_decode_attention(qf, k, v, kv_len)
    out = out.reshape(B, S, H * hd)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"].astype(x.dtype)), new_cache


def init_mla_cache(cfg, B, S, dtype=jnp.bfloat16):
    return {"c_kv": jnp.zeros((B, S, cfg.mla_kv_lora), dtype),
            "k_pe": jnp.zeros((B, S, cfg.mla_rope_dim), dtype),
            "pos": jnp.zeros((B,), jnp.int32)}
