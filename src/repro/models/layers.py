"""Common layers: RMSNorm, RoPE / M-RoPE, SwiGLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def init_rms(d, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


def _rope_angles(positions, dim, theta):
    """positions (...,) -> cos/sin (..., dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta=1e4):
    """x (B, S, H, hd), positions (B, S) -> rotated x."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)      # (B, S, hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta=1e4, sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE: positions3 (B, S, 3) = (t, h, w) ids.

    The hd/2 frequency slots are split into ``sections`` (t/h/w); each
    section rotates by its own position stream.  sections must sum to hd/2.
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)       # (half,)
    pos = positions3.astype(jnp.float32)[..., sec_id]   # (B, S, half)
    ang = pos * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def swiglu(x, w1, w3, w2):
    """SwiGLU MLP: (x@w1 * silu(x@w3)) @ w2, f32 accumulation on the MXU."""
    h = jnp.einsum("bsd,df->bsf", x, w1.astype(x.dtype))
    g = jnp.einsum("bsd,df->bsf", x, w3.astype(x.dtype))
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, w2.astype(x.dtype))


def init_mlp(key, d, ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 0.02
    s_out = 0.02
    return {
        "w1": jax.random.normal(k1, (d, ff), dtype) * s_in,
        "w3": jax.random.normal(k2, (d, ff), dtype) * s_in,
        "w2": jax.random.normal(k3, (ff, d), dtype) * s_out,
    }
