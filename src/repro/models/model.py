"""Model assembly: segments of super-blocks scanned over repeats.

A config's layer stack is a list of (super_block, repeat) segments; the
super-block is applied layer-by-layer inside a ``jax.lax.scan`` body whose
xs are the stacked per-repeat params (and KV/SSM caches).  HLO size is thus
independent of depth, which keeps 80-layer dry-run compiles tractable and
matches production practice (MaxText-style scanned layers).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import init_mlp, init_rms, rms_norm, swiglu


def _dtype(name):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def batch_axes(pcfg):
    axes = ((pcfg.pod_axis, pcfg.data_axis) if pcfg.pod_axis
            else (pcfg.data_axis,))
    if pcfg.dp_over_model:
        axes = axes + (pcfg.model_axis,)
    return axes


def constrain(x, *spec):
    """Best-effort activation sharding constraint.

    Applies when an ambient mesh is installed (jax.set_mesh, as done by the
    launchers / dryrun); no-ops in plain single-device tests."""
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, spec, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm1": init_rms(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        if cfg.mla_kv_lora:
            p["attn"] = attn_mod.init_mla(ks[0], cfg, dtype)
        else:
            p["attn"] = attn_mod.init_gqa(ks[0], cfg, dtype)
    else:
        p["mamba"] = ssm_mod.init_mamba2(ks[0], cfg, dtype)
    if spec.ffn != "none":
        p["norm2"] = init_rms(cfg.d_model, dtype)
        if spec.ffn == "dense":
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        else:
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key, param_dtype: str = "float32"):
    dtype = _dtype(param_dtype)
    keys = jax.random.split(key, len(cfg.segments) + 2)
    params = {}
    if cfg.embed_inputs:
        params["embed"] = jax.random.normal(
            keys[0], (cfg.vocab, cfg.d_model), dtype) * 0.02
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab), dtype) * 0.02
    params["final_norm"] = init_rms(cfg.d_model, dtype)
    segs = []
    for si, (sb, cnt) in enumerate(cfg.segments):
        reps = []
        for rkey in jax.random.split(keys[2 + si], cnt):
            blk_keys = jax.random.split(rkey, len(sb))
            reps.append({f"blk{i}": _init_block(bk, cfg, spec, dtype)
                         for i, (spec, bk) in enumerate(zip(sb, blk_keys))})
        segs.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
                    if cnt > 1 else reps[0])
    params["segments"] = segs
    return params


def init_cache(cfg: ModelConfig, B: int, S: int, dtype=jnp.bfloat16):
    """Static KV/SSM cache pytree mirroring the segment structure."""
    segs = []
    for sb, cnt in cfg.segments:
        blks = {}
        for i, spec in enumerate(sb):
            if spec.mixer == "attn":
                if cfg.mla_kv_lora:
                    c = attn_mod.init_mla_cache(cfg, B, S, dtype)
                else:
                    c = attn_mod.init_gqa_cache(cfg, B, S, dtype)
            else:
                c = ssm_mod.init_mamba2_cache(cfg, B, dtype)
            blks[f"blk{i}"] = c
        if cnt > 1:
            blks = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (cnt,) + x.shape), blks)
        segs.append(blks)
    return {"segments": segs}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_block(cfg, pcfg, spec, p, x, batch, cache, aux,
                 want_cache=True):
    ba = batch_axes(pcfg)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if pcfg.seq_parallel:
        # Megatron-SP: gather the sequence ONCE here (the boundary AG);
        # without this GSPMD re-gathers per consuming matmul
        h = constrain(h, ba, None, None)
    if spec.mixer == "attn":
        fn = attn_mod.mla if cfg.mla_kv_lora else attn_mod.gqa
        out, new_cache = fn(cfg, pcfg, p["attn"], h, batch, cache)
    else:
        out, new_cache = ssm_mod.mamba2(cfg, pcfg, p["mamba"], h, batch,
                                        cache)
    if not want_cache:
        new_cache = None
    ba = batch_axes(pcfg)
    seq = pcfg.model_axis if pcfg.seq_parallel else None
    x = constrain(x + out, ba, seq, None)
    if spec.ffn != "none":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if pcfg.seq_parallel:
            h = constrain(h, ba, None, None)
        if spec.ffn == "dense":
            x = x + swiglu(h, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"])
        else:
            out, moe_aux = moe_mod.moe(cfg, pcfg, p["moe"], h)
            x = x + out
            aux = aux + moe_aux["lb_loss"]
        x = constrain(x, ba, seq, None)
    return x, new_cache, aux


def _apply_superblock(cfg, pcfg, sb, params, x, batch, caches, aux,
                      want_cache=True):
    new_caches = {}
    for i, spec in enumerate(sb):
        cache_i = None if caches is None else caches[f"blk{i}"]

        def one(p_i, xx, c_i, aa, _spec=spec):
            return _apply_block(cfg, pcfg, _spec, p_i, xx, batch, c_i, aa,
                                want_cache)

        if pcfg.remat != "none":
            # per-LAYER remat: the backward pass recomputes one block at a
            # time, so peak residency is a single block's internals
            one = jax.checkpoint(one)
        x, nc, aux = one(params[f"blk{i}"], x, cache_i, aux)
        new_caches[f"blk{i}"] = nc
    return x, (new_caches if want_cache else None), aux


def forward(cfg: ModelConfig, pcfg: ParallelConfig, params, batch,
            cache: Optional[dict] = None, want_cache: bool = True,
            return_hidden: bool = False):
    """Returns (logits f32, new_cache, aux_loss).

    batch: {"tokens": (B,S) int32} or {"embeds": (B,S,d)}; optional
    "positions" ((B,S) or (B,S,3) for M-RoPE).  want_cache=False (training)
    skips KV materialization entirely.
    """
    cdt = _dtype(pcfg.compute_dtype)
    if cfg.embed_inputs:
        tok = batch["tokens"]
        x = params["embed"].astype(cdt)[tok]
        B, S = tok.shape
    else:
        x = batch["embeds"].astype(cdt)
        B, S = x.shape[:2]
    x = constrain(x, batch_axes(pcfg), None, None)

    use_cache = cache is not None
    new_segs = []
    aux = jnp.zeros((), jnp.float32)
    for si, (sb, cnt) in enumerate(cfg.segments):
        seg_p = params["segments"][si]
        seg_c = cache["segments"][si] if use_cache else None

        if cnt == 1:
            x, nc, aux = _apply_superblock(cfg, pcfg, sb, seg_p, x, batch,
                                           seg_c, aux, want_cache)
            new_segs.append(nc)
            continue

        def body(carry, xs):
            xx, aa = carry
            p_t, c_t = xs
            xx, nc, aa = _apply_superblock(cfg, pcfg, sb, p_t, xx, batch,
                                           c_t, aa, want_cache)
            return (xx, aa), nc

        # (per-layer checkpointing happens inside _apply_superblock; the
        # scan body itself stays plain so residuals are just block inputs)
        (x, aux), nc = jax.lax.scan(body, (x, aux), (seg_p, seg_c))
        new_segs.append(nc)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        # caller projects (chunked CE / last-token-only prefill) — the full
        # (B, S, vocab) logits tensor is never materialized
        return x, ({"segments": new_segs} if want_cache else None), aux
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(cdt)
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return logits, ({"segments": new_segs} if want_cache else None), aux


# ---------------------------------------------------------------------------
# partition specs
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, pcfg: ParallelConfig, params):
    """PartitionSpec pytree: Megatron-style TP over the "model" axis
    (or fully replicated + FSDP when dp_over_model re-purposes the axis
    as data parallelism)."""
    from jax.sharding import PartitionSpec as P
    mdl = None if pcfg.dp_over_model else pcfg.model_axis

    def rule(path, x):
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        rank = x.ndim
        joined = "/".join(str(n) for n in names)

        def lead(spec2):
            return P(*((None,) * (rank - len(spec2)) + spec2))

        if "embed" in names:
            return P(mdl, None)
        if "head" in names:
            return P(None, mdl)
        if "moe" in names:
            if names[-1] in ("w1", "w3", "w2"):          # (E, d, ff)
                return lead((mdl, None, None))
            return lead((None,))                         # router, shared
        if names[-1] in ("wq", "wk", "wv", "w1", "w3", "in_proj",
                         "wuk", "wuv"):
            return lead((None, mdl))
        if names[-1] in ("wo", "w2", "out_proj"):
            return lead((mdl, None))
        if names[-1] in ("wdkv", "wkpe"):
            return lead((None, None))
        return lead(())                                  # norms, scalars

    return jax.tree_util.tree_map_with_path(rule, params)


def cache_specs(cfg: ModelConfig, pcfg: ParallelConfig, cache):
    """Shard caches: batch over data(+pod); seq-shard long caches if asked."""
    from jax.sharding import PartitionSpec as P
    batch_axes = ((pcfg.pod_axis, pcfg.data_axis) if pcfg.pod_axis
                  else (pcfg.data_axis,))

    def rule(path, x):
        names = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
        rank = x.ndim
        leaf = names[-1]
        if leaf == "pos":
            return P(*((None,) * (rank - 1) + (batch_axes,)))
        lead = (None,) * (rank - 4)      # stacked segment dims
        seq = pcfg.model_axis if pcfg.seq_shard_decode else None
        if leaf in ("k", "v"):           # (B, S, Kv, hd)
            return P(*lead, batch_axes, seq, None, None)
        if leaf in ("c_kv", "k_pe"):     # (B, S, l)
            lead3 = (None,) * (rank - 3)
            return P(*lead3, batch_axes, seq, None)
        if leaf == "ssm":                # (B, H, P, N)
            return P(*lead, batch_axes, pcfg.model_axis, None, None)
        if leaf == "conv":               # (B, K-1, C)
            lead3 = (None,) * (rank - 3)
            return P(*lead3, batch_axes, None, pcfg.model_axis)
        return P(*((None,) * rank))

    return jax.tree_util.tree_map_with_path(rule, cache)
