"""Continuous batcher: coalesce a tick's tickets into few kernel rounds.

Two coalescing transforms, both **bitwise-identical** to running each
request alone (the parity contract of docs/serving.md, enforced by the
property tests in tests/test_serving.py):

* **Union-of-patterns SDDMM** for score requests.  All (i, j) pairs of
  a merge unit are concatenated, deduplicated (``np.unique`` over
  ``i * n + j`` with ``return_inverse`` for the scatter-back), and run
  as ONE sampled round via :meth:`DistProblem.with_pattern`.  Each
  sample's value is a dot over the operand width ``w``; the kernels'
  r-tiling depends only on (r, local width, VMEM budget) — never on the
  pattern's nonzero count — so adding samples to the pattern cannot
  change any individual sample's accumulation order.
* **Batched-RHS SpMM** for aggregate requests sharing a values key:
  column-concatenated through :meth:`DistProblem.spmm_batched`, which
  is column-independent (``out[:, j]`` consumes only ``Y[:, j]``).

Score merge rule (X side; the group already fixed the Y operand and
width): requests with the SAME ``x_key`` share the operand verbatim;
requests with DIFFERENT X operands merge only when their queried row
sets are disjoint — an SDDMM sample (i, j) reads row ``X[i]`` only, so
scattering each request's queried rows into one combined X is exact.
Requests that fit neither rule start a new merge unit (still one round
each, never dropped).

Every round runs through the deployment's :class:`api.ElasticProblem`
(``run_round``): the round-builder receives the CURRENT problem, so a
mid-round ``DeviceLost`` re-plans the deployment and the union problem
is rebuilt on the degraded mesh before the retry.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List

import numpy as np

from repro.serving.requests import Ticket


def _roundup(w: int, mult: int) -> int:
    return -(-w // mult) * mult


def _pattern_key(u_key: np.ndarray) -> str:
    return hashlib.blake2b(np.ascontiguousarray(u_key).tobytes(),
                           digest_size=16).hexdigest()


@dataclasses.dataclass
class ScoreUnit:
    """One union-of-patterns SDDMM round in the making."""
    m: int
    tickets: List[Ticket] = dataclasses.field(default_factory=list)
    x_key: str = ""
    scatter: bool = False
    _used: np.ndarray = None   # bool mask over m: rows any member queries

    def try_add(self, t: Ticket) -> bool:
        r = t.request
        if not self.tickets:
            self.tickets.append(t)
            self.x_key = r.x_key
            self._used = np.zeros(self.m, bool)
            self._used[r.rows] = True
            return True
        if not self.scatter and r.x_key == self.x_key:
            self.tickets.append(t)
            self._used[r.rows] = True
            return True
        # different X: admissible only on disjoint queried rows — the
        # combined X then carries each member's rows unclobbered
        if self._used[r.rows].any():
            return False
        self.tickets.append(t)
        self._used[r.rows] = True
        self.scatter = True
        return True


def plan_score_units(tickets: List[Ticket]) -> List[ScoreUnit]:
    """Group score tickets into merge units.

    Outer grouping: (deployment, y_key, width) — a unit's members share
    the stationary operand and query width exactly.  Inner: greedy
    first-fit into :class:`ScoreUnit` under the X merge rule.
    """
    groups: dict = {}
    for t in tickets:
        r = t.request
        groups.setdefault((id(r.deployment), r.y_key, r.width),
                          []).append(t)
    units: List[ScoreUnit] = []
    for group in groups.values():
        g_units: List[ScoreUnit] = []
        for t in group:
            if not any(u.try_add(t) for u in g_units):
                u = ScoreUnit(m=t.request.deployment.problem.m)
                u.try_add(t)
                g_units.append(u)
        units.extend(g_units)
    return units


def execute_score_unit(unit: ScoreUnit, *, use_session: bool = True,
                       use_elastic: bool = True,
                       use_caches: bool = True) -> int:
    """Run one union round and fulfill every member ticket.

    Returns the number of kernel rounds executed (1).  The round
    builder derives everything — padding width, operands, the union
    problem — from the problem it is HANDED, so an elastic retry after
    ``DeviceLost`` rebuilds on the degraded mesh (whose r-multiple may
    differ) and stays correct.
    """
    dep = unit.tickets[0].request.deployment
    reqs = [t.request for t in unit.tickets]
    w = reqs[0].width
    n = dep.problem.n
    key = np.concatenate([r.rows.astype(np.int64) * n + r.cols
                          for r in reqs])
    u_key, inv = np.unique(key, return_inverse=True)
    u_rows = (u_key // n).astype(np.int64)
    u_cols = (u_key % n).astype(np.int64)
    pkey = _pattern_key(u_key)

    if unit.scatter:
        X = np.zeros((dep.problem.m, w), np.float32)
        for r in reqs:
            qr = np.unique(r.rows)
            X[qr] = r.X[qr]
        x_cache_key = None           # per-tick operand, never cached
    else:
        X = reqs[0].X
        x_cache_key = reqs[0].x_key

    def round_fn(prob):
        mult = prob.alg.min_r_multiple(prob.grid)
        w_pad = max(_roundup(w, mult), mult)
        if use_caches:
            qp = dep.pattern_problem(u_rows, u_cols, w_pad, pkey)
            Xp = dep.padded(X, w_pad, key=x_cache_key)
            Yp = dep.padded(reqs[0].Y, w_pad, key=reqs[0].y_key)
        else:
            qp = prob.with_pattern(u_rows, u_cols)
            if w_pad != qp.r:
                qp = qp.with_r(w_pad)
            Xp = dep.padded(X, w_pad, key=None)
            Yp = dep.padded(reqs[0].Y, w_pad, key=None)
        session = dep.session if use_session else None
        return qp.sddmm(Xp, Yp, session=session).values()

    if use_elastic:
        vals = dep.elastic.run_round("serve.score", round_fn)
    else:
        vals = round_fn(dep.problem)
    vals = np.asarray(vals)
    off = 0
    for t in unit.tickets:
        k = len(t.request.rows)
        t.batched_with = len(unit.tickets) - 1
        t.fulfill(vals[inv[off:off + k]].copy())
        off += k
    return 1


def plan_aggregate_groups(tickets: List[Ticket]) -> List[List[Ticket]]:
    """Group aggregate tickets by (deployment, values key): each group
    is one batched-RHS SpMM round regardless of member widths."""
    groups: dict = {}
    for t in tickets:
        r = t.request
        groups.setdefault((id(r.deployment), r.vals_key), []).append(t)
    return list(groups.values())


def execute_aggregate_group(group: List[Ticket], *,
                            use_session: bool = True,
                            use_elastic: bool = True) -> int:
    """One batched-RHS SpMM round for a values-keyed group."""
    dep = group[0].request.deployment
    Ys = [t.request.Y for t in group]
    vals = group[0].request.vals
    if use_elastic:
        outs = dep.elastic.spmm_batched(Ys, vals=vals)
    else:
        outs = dep.problem.spmm_batched(
            Ys, vals=vals, session=dep.session if use_session else None)
    for t, out in zip(group, outs):
        t.batched_with = len(group) - 1
        t.fulfill(np.asarray(out))
    return 1


def execute_solo(t: Ticket, *, use_session: bool = False,
                 use_elastic: bool = True) -> int:
    """The per-request path: one round per ticket, no coalescing and no
    pattern/padding caches — the baseline the batched engine is raced
    against (bench_serving.py) and the parity reference the property
    tests compare coalesced answers to bitwise."""
    r = t.request
    dep = r.deployment
    session = dep.session if use_session else None
    if r.kind == "score":
        n = dep.problem.n
        key = r.rows.astype(np.int64) * n + r.cols
        u_key, inv = np.unique(key, return_inverse=True)
        u_rows = (u_key // n).astype(np.int64)
        u_cols = (u_key % n).astype(np.int64)

        def round_fn(prob):
            mult = prob.alg.min_r_multiple(prob.grid)
            w_pad = max(_roundup(r.width, mult), mult)
            qp = prob.with_pattern(u_rows, u_cols)
            if w_pad != qp.r:
                qp = qp.with_r(w_pad)
            Xp = dep.padded(r.X, w_pad, key=None)
            Yp = dep.padded(r.Y, w_pad, key=None)
            return qp.sddmm(Xp, Yp, session=session).values()

        vals = (dep.elastic.run_round("serve.score", round_fn)
                if use_elastic else round_fn(dep.problem))
        t.fulfill(np.asarray(vals)[inv].copy())
    else:

        def round_fn(prob):
            return prob.spmm_batched([r.Y], vals=r.vals,
                                     session=session)[0]

        out = (dep.elastic.run_round("serve.aggregate", round_fn)
               if use_elastic else round_fn(dep.problem))
        t.fulfill(np.asarray(out))
    return 1
