"""Content-keyed deployment pool: long-lived Sessions per served graph.

A *deployment* is a sparse graph plus its stationary dense operands
(factor matrices, projected embeddings) made ready to serve: a
``DistProblem`` planned onto the mesh, wrapped in an ``ElasticProblem``
so serving rounds survive ``DeviceLost`` mid-stream, and paired with a
dedicated ``api.Session`` whose replication cache amortizes the
stationary operands' fiber gathers across every tick that touches the
deployment (SpComm3D's observation — amortized setup state, not
per-call kernel speed, dominates serving throughput).

The pool is keyed by CONTENT digest — the COO structure+values, the
shape/width, the algorithm/comm choice, and every named operand — so
re-deploying the same graph with refreshed factors is a *miss* (new
digest, fresh replication) while an identical re-deploy is a *hit*
(same live deployment, warm Session).  Eviction is LRU over
deployments, bounded by ``capacity``; a deployment *pinned* by an
in-flight tick is never evicted (the pool overshoots capacity rather
than corrupt live work, and evicts at the next opportunity) — the
admission/eviction rule in docs/serving.md.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
from typing import Dict, Optional

import numpy as np

from repro.core import api


def content_key(rows, cols, vals, shape, r, *, algorithm="auto",
                comm="dense", operands=None) -> str:
    """The pool's deployment digest.  Everything that changes what a
    serving round would answer — structure, values, width, family and
    wire-format choice, and each named stationary operand — feeds the
    digest; two deployments answering identically share a key."""
    h = hashlib.blake2b(digest_size=16)
    h.update(f"{shape[0]}x{shape[1]}:r{r}:{algorithm}:{comm}".encode())
    for a in (rows, cols, np.asarray(vals, np.float32)):
        h.update(np.ascontiguousarray(a).tobytes())
    for name in sorted(operands or {}):
        a = np.ascontiguousarray(np.asarray(operands[name], np.float32))
        h.update(name.encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class Deployment:
    """One served graph: elastic problem + Session + stationary operands."""
    key: str
    elastic: api.ElasticProblem
    session: api.Session
    operands: Dict[str, np.ndarray]
    pins: int = 0
    #: zero-padded copies of stationary operands, keyed (digest, width).
    #: Returning the SAME array object across ticks is what lets the
    #: Session's identity fast path skip re-hashing the operand per tick.
    _pad_cache: dict = dataclasses.field(default_factory=dict)
    #: union-pattern problems from recent ticks, keyed
    #: (pattern digest, width) and validated against the CURRENT elastic
    #: problem — a repeated hot query reuses packed structure and
    #: compiled kernels instead of re-planning (bounded LRU).
    _pattern_cache: "collections.OrderedDict" = dataclasses.field(
        default_factory=collections.OrderedDict)
    pattern_cache_max: int = 8

    @property
    def problem(self) -> api.DistProblem:
        """The CURRENT problem — after a mid-stream DeviceLost the
        elastic facade has re-planned onto the degraded mesh and this
        reflects it."""
        return self.elastic.problem

    def operand(self, name: str) -> np.ndarray:
        return self.operands[name]

    def padded(self, arr, width: int, key: Optional[str] = None):
        """``arr`` zero-padded to ``width`` columns, cached by content
        key so ticks hand the Session a stable array object."""
        arr = np.asarray(arr, np.float32)
        if arr.shape[1] == width:
            return arr
        if arr.shape[1] > width:
            raise ValueError(f"cannot pad width {arr.shape[1]} down "
                             f"to {width}")
        if key is None:
            out = np.zeros((arr.shape[0], width), np.float32)
            out[:, :arr.shape[1]] = arr
            return out
        ck = (key, width)
        if ck not in self._pad_cache:
            out = np.zeros((arr.shape[0], width), np.float32)
            out[:, :arr.shape[1]] = arr
            self._pad_cache[ck] = out
        return self._pad_cache[ck]

    def pattern_problem(self, u_rows, u_cols, width: int,
                        pattern_key: str) -> api.DistProblem:
        """The union-pattern problem at ``width``, LRU-cached while the
        underlying deployment problem is unchanged (a re-mesh naturally
        invalidates: the cached entry's base problem is no longer the
        elastic facade's current one)."""
        base = self.problem
        ck = (pattern_key, width)
        hit = self._pattern_cache.get(ck)
        if hit is not None and hit[0] is base:
            self._pattern_cache.move_to_end(ck)
            return hit[1]
        qp = base.with_pattern(u_rows, u_cols)
        if width != qp.r:
            qp = qp.with_r(width)
        self._pattern_cache[ck] = (base, qp)
        while len(self._pattern_cache) > self.pattern_cache_max:
            self._pattern_cache.popitem(last=False)
        return qp


class SessionPool:
    """LRU pool of live deployments, keyed by content digest.

    ``deploy`` is idempotent on content: a digest already resident is a
    *hit* (the live deployment, Session intact); a new digest plans the
    problem, builds its Session, and — once over ``capacity`` — evicts
    the least-recently-used UNPINNED deployment.  ``stats()`` reports
    hit/miss/eviction counts, occupancy, and the aggregated Session
    replication stats of resident deployments.
    """

    def __init__(self, capacity: int = 4, session_entries: int = 32,
                 policy: Optional[api.RetryPolicy] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.session_entries = session_entries
        self.policy = policy
        self._deployments: "collections.OrderedDict[str, Deployment]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._deployments)

    def __contains__(self, key: str) -> bool:
        return key in self._deployments

    @property
    def keys(self):
        """Resident digests, least- to most-recently-used."""
        return list(self._deployments)

    def get(self, key: str) -> Optional[Deployment]:
        dep = self._deployments.get(key)
        if dep is not None:
            self._deployments.move_to_end(key)
        return dep

    def deploy(self, rows, cols, vals, shape, r, *, operands=None,
               algorithm: str = "auto", c: Optional[int] = None,
               devices=None, comm: str = "dense",
               row_tile: int = 32, nz_block: int = 32) -> Deployment:
        key = content_key(rows, cols, vals, shape, r,
                          algorithm=algorithm, comm=comm,
                          operands=operands)
        dep = self._deployments.get(key)
        if dep is not None:
            self.hits += 1
            self._deployments.move_to_end(key)
            return dep
        self.misses += 1
        prob = api.make_problem(rows, cols, vals, shape, r,
                                algorithm=algorithm, c=c, devices=devices,
                                comm=comm, row_tile=row_tile,
                                nz_block=nz_block)
        session = api.Session(max_entries=self.session_entries)
        dep = Deployment(
            key,
            api.ElasticProblem(prob, session=session, policy=self.policy),
            session,
            {k: np.asarray(v, np.float32)
             for k, v in (operands or {}).items()})
        self._deployments[key] = dep
        self._evict_over_capacity()
        return dep

    def _evict_over_capacity(self):
        # LRU order, skipping pinned deployments: in-flight ticks hold a
        # pin, so eviction can never pull a Session out from under a
        # round that is mid-execution.  If everything is pinned the pool
        # overshoots capacity and retries on the next deploy.
        while len(self._deployments) > self.capacity:
            victim = next((k for k, d in self._deployments.items()
                           if d.pins == 0), None)
            if victim is None:
                return
            del self._deployments[victim]
            self.evictions += 1

    @contextlib.contextmanager
    def pin(self, *deployments: Deployment):
        """Hold the given deployments un-evictable for a tick's scope."""
        for d in deployments:
            d.pins += 1
        try:
            yield
        finally:
            for d in deployments:
                d.pins -= 1
            self._evict_over_capacity()

    def stats(self) -> dict:
        sess = dict(hits=0, misses=0, entries=0)
        for d in self._deployments.values():
            s = d.session.stats()
            sess["hits"] += s["hits"]
            sess["misses"] += s["misses"]
            sess["entries"] += s["entries"]
        total = self.hits + self.misses
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions,
                    occupancy=len(self._deployments),
                    capacity=self.capacity,
                    pinned=sum(1 for d in self._deployments.values()
                               if d.pins),
                    hit_rate=(self.hits / total) if total else 0.0,
                    session=sess)
