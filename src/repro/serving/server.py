"""The serving loop: admission queue -> per-tick coalesced rounds.

``ServingEngine`` ties the layers together: requests enter through the
bounded :class:`~repro.serving.requests.RequestQueue`, each ``tick``
drains up to ``max_batch`` tickets, pins their deployments against pool
eviction, plans the tick's merge units
(:mod:`repro.serving.batcher`) and executes them — union-of-patterns
SDDMM rounds for scores, batched-RHS SpMM rounds for aggregates — all
through each deployment's ``ElasticProblem`` so a ``DeviceLost``
mid-tick degrades the mesh and retries without the caller noticing.
``batching=False`` turns the same engine into the per-request baseline
(one round per ticket, no Session, no caches) that ``bench_serving``
races the batched engine against.

``replay_trace`` is the latency methodology (docs/serving.md): an
open-loop arrival trace in *simulated* seconds is replayed
deterministically — the driver admits every request whose arrival
precedes the current simulated time, runs one tick, measures the
tick's WALL duration, and stamps each served ticket's completion as
tick-start + wall.  Arrivals are fixed by the trace and service times
are measured, so the p50/p99 distribution is reproducible run to run
up to machine timing noise, and queueing delay under bursts is modeled
faithfully (a request arriving mid-tick waits for the next tick).
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.serving import batcher
from repro.serving.pool import SessionPool
from repro.serving.requests import (AdmissionError, AggregateRequest,
                                    RequestQueue, ScoreRequest, Ticket)


class ServingEngine:
    """Continuous-batching server over a deployment pool."""

    def __init__(self, pool: SessionPool, *, max_batch: int = 64,
                 max_pending: int = 256, batching: bool = True,
                 use_session: bool = True, use_elastic: bool = True):
        self.pool = pool
        self.queue = RequestQueue(max_pending)
        self.max_batch = max_batch
        self.batching = batching
        self.use_session = use_session
        self.use_elastic = use_elastic
        self.rounds = 0
        self.served = 0
        self.failed = 0

    # -- submission ----------------------------------------------------------
    def submit_score(self, deployment, rows, cols, X, Y=None, *,
                     x_key: Optional[str] = None,
                     y_key: Optional[str] = None,
                     arrival: float = 0.0) -> Ticket:
        """Queue an SDDMM score query.  ``X`` / ``Y`` may be host arrays
        or NAMES of deployment operands (the common case — stationary
        factors deployed with the graph), in which case the digest key
        is the operand name and the Session's identity fast path
        applies across ticks."""
        if isinstance(X, str):
            name = X
            X = deployment.operand(name)
            x_key = x_key or f"operand:{name}"
        if isinstance(Y, str) or Y is None:
            name = Y or "Y"
            Y = deployment.operand(name)
            y_key = y_key or f"operand:{name}"
        req = ScoreRequest.make(deployment, rows, cols, X, Y,
                                x_key=x_key, y_key=y_key)
        return self.queue.submit(req, arrival=arrival)

    def submit_aggregate(self, deployment, Y, vals=None, *,
                         arrival: float = 0.0) -> Ticket:
        """Queue an SpMM aggregation/lookup: ``deployment_graph @ Y``."""
        req = AggregateRequest.make(deployment, Y, vals=vals)
        return self.queue.submit(req, arrival=arrival)

    # -- the tick ------------------------------------------------------------
    def tick(self) -> dict:
        """Drain one batch, execute its coalesced rounds, fulfill
        tickets.  Returns the tick report (counts + wall seconds)."""
        tickets = self.queue.drain(self.max_batch)
        report = dict(requests=len(tickets), rounds=0, wall=0.0,
                      tickets=tickets)
        if not tickets:
            return report
        deployments = {id(t.request.deployment): t.request.deployment
                       for t in tickets}
        t0 = time.perf_counter()
        with self.pool.pin(*deployments.values()):
            try:
                if self.batching:
                    report["rounds"] = self._run_batched(tickets)
                else:
                    report["rounds"] = self._run_solo(tickets)
            except BaseException as e:
                # a round that exhausts its retry budget fails the
                # tickets still pending, never the whole server
                for t in tickets:
                    if not t.done:
                        t.fail(e)
                        self.failed += 1
        self.rounds += report["rounds"]
        self.served += sum(1 for t in tickets
                           if t.done and t._error is None)
        report["wall"] = time.perf_counter() - t0
        reg = obs_metrics.active()
        if reg is not None:
            reg.observe("serving.tick_seconds", report["wall"])
            reg.observe("serving.batch_occupancy",
                        len(tickets) / max(self.max_batch, 1))
            reg.inc("serving.ticks")
            reg.inc("serving.requests", len(tickets))
            reg.gather("serving", dict(rounds=self.rounds,
                                       served=self.served,
                                       failed=self.failed))
            reg.gather("serving.queue", self.queue.stats())
            pstats = self.pool.stats()
            reg.gather("serving.pool", pstats)
            reg.gather("serving.pool.session", pstats["session"])
        return report

    def _run_batched(self, tickets: List[Ticket]) -> int:
        scores = [t for t in tickets if t.request.kind == "score"]
        aggs = [t for t in tickets if t.request.kind == "aggregate"]
        rounds = 0
        for unit in batcher.plan_score_units(scores):
            rounds += batcher.execute_score_unit(
                unit, use_session=self.use_session,
                use_elastic=self.use_elastic)
        for group in batcher.plan_aggregate_groups(aggs):
            rounds += batcher.execute_aggregate_group(
                group, use_session=self.use_session,
                use_elastic=self.use_elastic)
        return rounds

    def _run_solo(self, tickets: List[Ticket]) -> int:
        rounds = 0
        for t in tickets:
            rounds += batcher.execute_solo(
                t, use_session=self.use_session,
                use_elastic=self.use_elastic)
        return rounds

    def run_until_drained(self, max_ticks: int = 1000) -> int:
        """Tick until the queue is empty; returns ticks executed."""
        ticks = 0
        while len(self.queue) and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks

    def stats(self) -> dict:
        return dict(rounds=self.rounds, served=self.served,
                    failed=self.failed, queue=self.queue.stats(),
                    pool=self.pool.stats())


def replay_trace(engine: ServingEngine,
                 trace: List[Tuple[float, Callable]]) -> dict:
    """Deterministically replay an open-loop arrival trace.

    ``trace`` is a list of ``(arrival_sim_seconds, submit_fn)`` where
    ``submit_fn(engine, arrival)`` submits one request and returns its
    :class:`Ticket` (raise-through of :class:`AdmissionError` is caught
    and counted as shed load).  Simulated time advances by each tick's
    measured wall duration; a ticket's completion is stamped
    tick-start + wall, so ``latency = queueing delay + service time``
    exactly as an open-loop client would observe.  Returns the latency
    summary (p50/p99/mean seconds, throughput in requests per simulated
    second, shed count) plus the fulfilled tickets.
    """
    trace = sorted(trace, key=lambda item: item[0])
    sim = trace[0][0] if trace else 0.0
    i = 0
    tickets: List[Ticket] = []
    shed = 0
    while i < len(trace) or len(engine.queue):
        if not len(engine.queue) and i < len(trace) and trace[i][0] > sim:
            sim = trace[i][0]          # idle server: jump to next arrival
        while i < len(trace) and trace[i][0] <= sim:
            arrival, submit_fn = trace[i]
            try:
                tickets.append(submit_fn(engine, arrival))
            except AdmissionError:
                shed += 1
            i += 1
        report = engine.tick()
        for t in report["tickets"]:
            t.completion = sim + report["wall"]
        sim += report["wall"]
    lats = sorted(t.latency for t in tickets
                  if t.done and t._error is None)
    summary = dict(served=len(lats), shed=shed,
                   sim_seconds=sim - (trace[0][0] if trace else 0.0),
                   tickets=tickets)
    if lats:
        summary.update(
            p50=float(np.percentile(lats, 50)),
            p99=float(np.percentile(lats, 99)),
            mean=float(np.mean(lats)),
            max=float(lats[-1]),
            throughput=len(lats) / max(summary["sim_seconds"], 1e-12))
    return summary
