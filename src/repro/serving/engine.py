"""Deprecated alias for :mod:`repro.serving.decode` — will be removed.

This module historically held the local LM decode path under a name
that collided with the distributed :class:`repro.serving.ServingEngine`
(``server.py``) — two unrelated things both called "engine".  The decode
path now lives in :mod:`repro.serving.decode`; this shim re-exports it
unchanged but warns on import, and is scheduled for removal once no
caller trips the warning (tracked in docs/static_analysis.md's stale-
export note).  Import ``repro.serving.decode`` (LM prefill/decode) or
``repro.serving`` (the distributed ServingEngine) instead.
"""
import warnings

from repro.serving.decode import (decode_step, extend_cache,
                                  greedy_generate, prefill)

warnings.warn(
    "repro.serving.engine is a deprecated alias; import "
    "repro.serving.decode instead (removal tracked in "
    "docs/static_analysis.md)",
    DeprecationWarning, stacklevel=2)

__all__ = ["decode_step", "extend_cache", "greedy_generate", "prefill"]
