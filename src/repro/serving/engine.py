"""Back-compat alias for :mod:`repro.serving.decode`.

This module historically held the local LM decode path under a name
that collided with the distributed :class:`repro.serving.ServingEngine`
(``server.py``) — two unrelated things both called "engine".  The decode
path now lives in :mod:`repro.serving.decode`; this alias re-exports it
unchanged so existing imports keep working.  New code should import
``repro.serving.decode`` (LM prefill/decode) or ``repro.serving``
(the distributed ServingEngine) directly.
"""
from repro.serving.decode import (decode_step, extend_cache,
                                  greedy_generate, prefill)

__all__ = ["decode_step", "extend_cache", "greedy_generate", "prefill"]
