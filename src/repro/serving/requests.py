"""Request, ticket and admission-queue layer of the serving engine.

The distributed serving engine (docs/serving.md) speaks two request
shapes, chosen because they are the two kernel shapes inference traffic
over a deployed sparse graph actually takes (paper §VII):

* :class:`ScoreRequest` — "score these (i, j) pairs": an SDDMM sampled
  at the request's coordinate list, ``<X_i, Y_j>`` per pair.  The CF
  prediction query (user-item scores against deployed factors) and the
  GAT/attention edge-score query are both this shape.
* :class:`AggregateRequest` — "push this dense block through the
  graph": an SpMM right-hand side against the deployment's sparse
  values (optionally overridden per request, e.g. softmaxed attention).
  Embedding lookups and neighborhood aggregation are this shape.

Both carry content digests of their dense operands so the batcher can
group mergeable work without comparing arrays, and the Session can
serve repeated operands from its content-keyed replication cache.

:class:`RequestQueue` is the admission policy: a bounded FIFO that
fails fast (:class:`AdmissionError`) once ``max_pending`` requests are
waiting — open-loop traffic beyond the server's capacity is shed at the
door instead of growing an unbounded backlog (the rejection count is
part of the queue's stats, so the bench records shed load explicitly).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import itertools
from typing import List, Optional

import numpy as np


class AdmissionError(RuntimeError):
    """The queue is full: the request was rejected at admission."""


def digest(arr) -> str:
    """Content digest of a host array (the batcher's grouping key)."""
    a = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.blake2b(digest_size=16)
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class ScoreRequest:
    """SDDMM samples ``<X_i, Y_j>`` at the request's (rows, cols) pairs.

    ``X (m, w)`` / ``Y (n, w)`` are host operands on the deployment's
    shape; ``w`` is the query width (padded to the family's feasible
    width inside the round, zero columns contribute nothing to any
    dot).  ``x_key`` / ``y_key`` are content digests used for merge
    grouping — requests sharing ``y_key`` and width can coalesce into
    one union-of-patterns SDDMM; see :mod:`repro.serving.batcher` for
    the X-side merge rule (identical digest, or disjoint row sets).
    """
    deployment: object
    rows: np.ndarray
    cols: np.ndarray
    X: np.ndarray
    Y: np.ndarray
    x_key: str
    y_key: str
    kind = "score"

    @classmethod
    def make(cls, deployment, rows, cols, X, Y,
             x_key: Optional[str] = None,
             y_key: Optional[str] = None) -> "ScoreRequest":
        prob = deployment.problem
        rows = np.asarray(rows).reshape(-1)
        cols = np.asarray(cols).reshape(-1)
        if rows.shape != cols.shape or len(rows) == 0:
            raise ValueError("score query needs matching non-empty "
                             "rows/cols")
        X = np.asarray(X, np.float32)
        Y = np.asarray(Y, np.float32)
        if X.ndim != 2 or X.shape[0] != prob.m:
            raise ValueError(f"X must be (m={prob.m}, w), got {X.shape}")
        if Y.ndim != 2 or Y.shape != (prob.n, X.shape[1]):
            raise ValueError(f"Y must be (n={prob.n}, w={X.shape[1]}), "
                             f"got {Y.shape}")
        if (int(rows.min()) < 0 or int(rows.max()) >= prob.m
                or int(cols.min()) < 0 or int(cols.max()) >= prob.n):
            raise ValueError("query coordinates outside the deployment "
                             f"shape ({prob.m}, {prob.n})")
        return cls(deployment, rows, cols, X, Y,
                   x_key=x_key if x_key is not None else digest(X),
                   y_key=y_key if y_key is not None else digest(Y))

    @property
    def width(self) -> int:
        return int(self.X.shape[1])


@dataclasses.dataclass
class AggregateRequest:
    """SpMM right-hand side ``Y (n, w)`` against the deployment's values.

    ``vals=None`` uses the deployed sample values (the coalescible
    common case: every such request in a tick rides one batched-RHS
    SpMM); a per-request ``vals`` override (host COO order of the
    deployment, e.g. a client's softmaxed attention) groups only with
    requests carrying the identical override.
    """
    deployment: object
    Y: np.ndarray
    vals: Optional[np.ndarray]
    vals_key: str
    kind = "aggregate"

    @classmethod
    def make(cls, deployment, Y, vals=None) -> "AggregateRequest":
        prob = deployment.problem
        Y = np.asarray(Y, np.float32)
        if Y.ndim != 2 or Y.shape[0] != prob.n:
            raise ValueError(f"Y must be (n={prob.n}, w), got {Y.shape}")
        if vals is not None:
            vals = np.asarray(vals, np.float32)
            if vals.shape != (prob.nnz,):
                raise ValueError(f"vals override must be ({prob.nnz},) "
                                 f"in host COO order, got {vals.shape}")
        return cls(deployment, Y, vals,
                   vals_key="deployed" if vals is None else digest(vals))

    @property
    def width(self) -> int:
        return int(self.Y.shape[1])


@dataclasses.dataclass
class Ticket:
    """The caller's handle on a submitted request (a synchronous future).

    ``arrival`` / ``completion`` are *trace timestamps* in the caller's
    clock (the replay driver's simulated seconds) — the engine never
    reads wall time from them; :func:`repro.serving.server.replay_trace`
    stamps completion as tick-start + measured tick wall time, which is
    what makes the latency distribution deterministic to re-derive.
    """
    request: object
    seq: int
    arrival: float = 0.0
    completion: Optional[float] = None
    done: bool = False
    batched_with: int = 0
    _result: object = None
    _error: Optional[BaseException] = None

    def fulfill(self, result):
        self._result = result
        self.done = True

    def fail(self, error: BaseException):
        self._error = error
        self.done = True

    def result(self):
        if not self.done:
            raise RuntimeError(f"ticket {self.seq} still pending — "
                               "run engine.tick() first")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency(self) -> Optional[float]:
        if self.completion is None:
            return None
        return self.completion - self.arrival


class RequestQueue:
    """Bounded FIFO with fail-fast admission.

    Admission rule: a request is accepted iff fewer than ``max_pending``
    tickets are waiting; otherwise :class:`AdmissionError` — the caller
    (or the open-loop replay driver) decides whether to retry later.
    ``rejected`` counts shed requests so saturation is observable.
    """

    def __init__(self, max_pending: int = 256):
        if max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self.max_pending = max_pending
        self._pending: collections.deque = collections.deque()
        self._seq = itertools.count()
        self.admitted = 0
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, request, arrival: float = 0.0) -> Ticket:
        if len(self._pending) >= self.max_pending:
            self.rejected += 1
            raise AdmissionError(
                f"queue full ({self.max_pending} pending); request "
                "rejected at admission")
        t = Ticket(request, next(self._seq), arrival=arrival)
        self._pending.append(t)
        self.admitted += 1
        return t

    def drain(self, max_requests: Optional[int] = None) -> List[Ticket]:
        """Pop up to ``max_requests`` tickets in FIFO order (one tick's
        worth of work)."""
        k = len(self._pending) if max_requests is None else \
            min(max_requests, len(self._pending))
        return [self._pending.popleft() for _ in range(k)]

    def stats(self) -> dict:
        return dict(pending=len(self._pending), admitted=self.admitted,
                    rejected=self.rejected,
                    max_pending=self.max_pending)
