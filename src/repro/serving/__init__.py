"""Serving layer: continuous batching over the distributed api.

Two sub-stacks share this package:

* the distributed serving engine (requests/pool/batcher/server) —
  coalesced SDDMM/SpMM rounds over pooled graph deployments, the
  docs/serving.md subsystem.  :class:`ServingEngine` (``server.py``) is
  the one canonical engine export;
* the local LM decode path (:mod:`repro.serving.decode`) — prefill +
  greedy decode on the single-process model, imported explicitly so
  this package does not pull the model stack in for graph serving
  (``repro.serving.engine`` is a deprecated alias that warns on
  import; see docs/static_analysis.md for the removal note).
"""
from repro.serving.pool import Deployment, SessionPool, content_key
from repro.serving.requests import (AdmissionError, AggregateRequest,
                                    RequestQueue, ScoreRequest, Ticket)
from repro.serving.server import ServingEngine, replay_trace

__all__ = [
    "AdmissionError", "AggregateRequest", "Deployment", "RequestQueue",
    "ScoreRequest", "ServingEngine", "SessionPool", "Ticket",
    "content_key", "replay_trace",
]
