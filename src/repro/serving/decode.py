"""Serving steps: prefill (build KV cache) and decode (one token).

``serve_step`` is the function the decode_* / long_* dry-run shapes lower:
one new token against a KV cache of ``seq_len``, returning next-token
logits and the updated cache.  Cache shardings come from
``model.cache_specs`` (batch over data/pod, optional sequence sharding for
the long-context path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.models import model as M


def prefill(cfg: ModelConfig, pcfg: ParallelConfig, params, batch):
    """Full-sequence forward returning (last_logits, cache).

    Only the final position is projected through the LM head — the full
    (B, S, vocab) logits tensor is never materialized."""
    hidden, cache, _ = M.forward(cfg, pcfg, params, batch, want_cache=True,
                                 return_hidden=True)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(hidden.dtype)
    logits = jnp.einsum("bsd,dv->bsv", hidden[:, -1:], head).astype(
        jnp.float32)
    return logits, cache


def decode_step(cfg: ModelConfig, pcfg: ParallelConfig, params, token_batch,
                cache):
    """One decode step.  token_batch: {"tokens": (B, 1)} (or embeds)."""
    logits, cache, _ = M.forward(cfg, pcfg, params, token_batch, cache=cache,
                                 want_cache=True)
    return logits, cache


def extend_cache(cache, extra: int):
    """Pad the sequence axis of attention caches by `extra` slots."""
    def pad(path, x):
        names = [str(getattr(k, "key", "")) for k in path]
        if names[-1] in ("k", "v"):          # (..., B, S, Kv, hd)
            cfgpad = [(0, 0)] * x.ndim
            cfgpad[-3] = (0, extra)
            return jnp.pad(x, cfgpad)
        if names[-1] in ("c_kv", "k_pe"):    # (..., B, S, l)
            cfgpad = [(0, 0)] * x.ndim
            cfgpad[-2] = (0, extra)
            return jnp.pad(x, cfgpad)
        return x
    return jax.tree_util.tree_map_with_path(pad, cache)


def greedy_generate(cfg, pcfg, params, prompt_batch, steps: int):
    """Host-driven greedy loop (examples / tests; not the hot path)."""
    logits, cache = prefill(cfg, pcfg, params, prompt_batch)
    cache = extend_cache(cache, steps)
    toks = [jnp.argmax(logits[:, -1], -1)]
    for _ in range(steps - 1):
        logits, cache = decode_step(
            cfg, pcfg, params, {"tokens": toks[-1][:, None]}, cache)
        toks.append(jnp.argmax(logits[:, -1], -1))
    return jnp.stack(toks, axis=1)
