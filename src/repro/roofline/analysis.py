"""Three-term roofline analysis from the dry-run artifacts.

Hardware model (assignment constants, TPU v5e):
    peak bf16 compute : 197e12 FLOP/s per chip
    HBM bandwidth     : 819e9  B/s  per chip
    ICI link bandwidth: 50e9   B/s  per chip-link

Terms (seconds, per step, per chip):
    compute    = HLO_FLOPs / (chips * PEAK)
    memory     = HLO_bytes / (chips * HBM)
    collective = collective_bytes / (chips * LINK)

``cost_analysis()`` of the SPMD executable reports the PER-DEVICE
partitioned module, so FLOPs/bytes are divided by chips=1 here (we record
both conventions; ``per_device=True`` is the default and documented in
EXPERIMENTS.md).  collective_bytes uses the loop-aware wire model
(all-gather: recv bytes, reduce-scatter: sent, all-reduce: 2x, permutes:
payload), also per device.

MODEL_FLOPS = 6*N*D for training (2*N*D forward-only for serving), with
N = active params (MoE) and D = tokens per step.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128 * 1,
    "long_500k": 1 * 1,
}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    multi_pod: bool
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    temp_gb: float
    wire_gb: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step's bound spent on useful model FLOPs."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.bound_time if self.bound_time else 0.0

    @property
    def frac_cc(self) -> float:
        """Roofline fraction vs the compute/collective bound only — the
        memory term is a stated UPPER BOUND (operand+output of every
        instruction, ignoring fusion reuse), so this is the fraction the
        fused TPU execution is expected to achieve."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        bound = max(self.compute_s, self.collective_s)
        return ideal / bound if bound else 0.0


def analyse_record(rec: dict) -> Roofline:
    chips = 512 if rec["multi_pod"] else 256
    kind = rec["kind"]
    tokens = TOKENS[rec["shape"]]
    n = rec["active_params"]
    model_flops = (6 if kind == "train" else 2) * n * tokens
    # loop-aware per-device totals from the HLO walk (cost_analysis does
    # NOT multiply while-loop bodies by their trip counts)
    prog = rec.get("program", {})
    flops_dev = prog.get("dot_flops") or rec["cost"].get("flops", 0.0)
    bytes_dev = prog.get("bytes_touched") or rec["cost"].get(
        "bytes accessed", 0.0)
    coll_dev = rec["collectives"].get("total_wire_bytes", 0.0)
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / LINK_BW
    hlo_total = flops_dev * chips
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], multi_pod=rec["multi_pod"],
        chips=chips, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, model_flops=model_flops,
        hlo_flops=hlo_total,
        useful_ratio=model_flops / hlo_total if hlo_total else 0.0,
        temp_gb=rec["memory"]["temp_size_in_bytes"] / 1e9,
        wire_gb=coll_dev / 1e9)


def fix_suggestion(r: Roofline) -> str:
    if r.dominant == "collective":
        if r.shape == "train_4k":
            return ("overlap FSDP all-gathers with layer compute / shrink "
                    "grad all-reduce via int8 compression")
        return "reduce KV/cache collectives: shard-local decode attention"
    if r.dominant == "memory":
        if r.shape.startswith("decode") or r.shape.startswith("long"):
            return ("decode is KV-bandwidth-bound by nature; raise batch "
                    "or quantize KV cache to int8")
        return "fuse elementwise chains; bf16 residuals; larger microbatch"
    if r.useful_ratio < 0.5:
        return ("compiled FLOPs >> model FLOPs: cut remat recompute or "
                "one-hot/matmul waste in MoE dispatch")
    return "raise arithmetic intensity (larger microbatch per chip)"


def load_all(outdir: str = "results/dryrun",
             fallback: str = "results/dryrun_v2") -> List[Roofline]:
    """Load cell records, preferring `outdir`; per-cell fallback to an
    earlier sweep's records (older bytes-touched convention) if present."""
    files = {}
    for d in (fallback, outdir):
        if not os.path.isdir(d):
            continue
        for fn in os.listdir(d):
            if fn.endswith(".json") and not fn.startswith("summary"):
                files[fn] = os.path.join(d, fn)
    rows = []
    for fn in sorted(files):
        with open(files[fn]) as f:
            rec = json.load(f)
        if "skipped" in rec:
            continue
        rows.append(analyse_record(rec))
    return rows


def to_markdown(rows: List[Roofline]) -> str:
    head = ("| arch | shape | mesh | compute s | memory s | collective s |"
            " dominant | MODEL/HLO | frac(all) | frac(c+c) | temp GB |"
            " fix |\n"
            "|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda x: (x.multi_pod, x.arch, x.shape)):
        mesh = "2x16x16" if r.multi_pod else "16x16"
        lines.append(
            f"| {r.arch} | {r.shape} | {mesh} | {r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | {r.dominant} | "
            f"{r.useful_ratio:.2f} | {r.roofline_fraction:.3f} | "
            f"{r.frac_cc:.3f} | {r.temp_gb:.1f} | {fix_suggestion(r)} |")
    return head + "\n".join(lines) + "\n"


if __name__ == "__main__":
    rows = load_all()
    print(to_markdown(rows))
