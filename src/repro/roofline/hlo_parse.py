"""Parse collective-communication traffic out of compiled HLO text.

``compiled.cost_analysis()`` has no collective-bytes entry, so we walk the
partitioned HLO module: every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` instruction is
recorded with its operand and output byte sizes (per-device, since the SPMD
module is the per-device program).

Two aggregation policies:

  * ``operand_bytes``  — sum of operand sizes (the roofline spec's metric).
  * ``wire_bytes``     — a words-on-the-wire model per primitive, matching
    the alpha-beta costs the paper uses:
      all-gather         output - operand   (received words)
      reduce-scatter     operand - output   (sent words)
      all-reduce         2 * operand        (ring RS + AG)
      all-to-all         operand            (everything leaves)
      collective-permute operand            (point-to-point send)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^)=]*?\)?)\s*([\w\-]+)\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string, incl. tuples: '(f32[2,3], u32[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        cnt = 1
        for d in dims.split(","):
            if d:
                cnt *= int(d)
        total += cnt * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    name: str
    operand_bytes: int
    output_bytes: int

    @property
    def wire_bytes(self) -> int:
        if self.kind == "all-gather":
            return max(self.output_bytes - self.operand_bytes, 0)
        if self.kind == "reduce-scatter":
            return max(self.operand_bytes - self.output_bytes, 0)
        if self.kind == "all-reduce":
            return 2 * self.operand_bytes
        return self.operand_bytes   # all-to-all, collective-permute


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    # pass 1: name -> shape table
    shapes: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)
    # pass 2: collective instructions
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, out_shape, op = m.group(1), m.group(2), m.group(3)
        if op not in _COLLECTIVES:
            continue
        if "-start" in line and op + "-start" in line:
            continue  # paired with -done; avoid double counting
        args = line[line.index(op + "(") + len(op) + 1:]
        # split top-level commas only: shape strings ("f32[64,64]{1,0}")
        # and nested calls carry commas of their own
        depth, arglist, cur = 0, [], ""
        for ch in args:
            if ch in "([{":
                depth += 1
                cur += ch
            elif ch == ")":
                if depth == 0:
                    arglist.append(cur)
                    break
                depth -= 1
                cur += ch
            elif ch in "]}":
                depth -= 1
                cur += ch
            elif ch == "," and depth == 0:
                arglist.append(cur)
                cur = ""
            else:
                cur += ch
        op_bytes = 0
        for a in arglist:
            a = a.strip().lstrip("%")
            if a in shapes:
                op_bytes += shape_bytes(shapes[a])
            elif _SHAPE_RE.search(a):       # inline-typed operand
                op_bytes += shape_bytes(a)
        out.append(CollectiveOp(op, name, op_bytes, shape_bytes(out_shape)))
    return out


# --- ordered collectives (schedule-conformance view) -----------------------
#
# ``parse_collectives`` aggregates traffic; the conformance verifier
# (repro.analysis.conformance) additionally needs the *issue order* and
# the group structure of each instruction.  XLA assigns collectives a
# monotonically increasing ``channel_id`` in lowering order (gaps mark
# DCE'd instructions), so sorting on it recovers the schedule the
# backend will rendezvous in.

_CHANNEL_RE = re.compile(r"channel_id=(\d+)")
_GROUPSET_RE = re.compile(r"replica_groups=\{((?:\{[\d,]*\},?)*)\}")
_GROUP_RE = re.compile(r"\{([\d,]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")


@dataclasses.dataclass
class OrderedCollective:
    """One collective instruction with its schedule position and groups."""

    kind: str
    name: str
    channel_id: int                       # -1 when the attr is absent
    operand_bytes: int
    output_bytes: int
    replica_groups: Optional[Tuple[Tuple[int, ...], ...]] = None
    source_target_pairs: Optional[Tuple[Tuple[int, int], ...]] = None

    @property
    def wire_bytes(self) -> int:
        return CollectiveOp(self.kind, self.name, self.operand_bytes,
                            self.output_bytes).wire_bytes


def _parse_groups(line: str) -> Optional[Tuple[Tuple[int, ...], ...]]:
    m = _GROUPSET_RE.search(line)
    if m:
        groups = []
        for g in _GROUP_RE.finditer(m.group(1)):
            ids = tuple(int(x) for x in g.group(1).split(",") if x)
            if ids:
                groups.append(ids)
        return tuple(groups) if groups else None
    m = _IOTA_GROUPS_RE.search(line)
    if m:   # iota form [g,s]<=[n]: reshape(arange(n), (g, s)) rows
        g, s, n = (int(m.group(i)) for i in (1, 2, 3))
        if g * s == n:
            return tuple(tuple(range(i * s, (i + 1) * s))
                         for i in range(g))
    return None


def _parse_pairs(line: str) -> Optional[Tuple[Tuple[int, int], ...]]:
    m = _PAIRS_RE.search(line)
    if not m:
        return None
    pairs = []
    for g in _GROUP_RE.finditer(m.group(1)):
        ids = [int(x) for x in g.group(1).split(",") if x]
        if len(ids) == 2:
            pairs.append((ids[0], ids[1]))
    return tuple(pairs) if pairs else None


def ordered_collectives(hlo_text: str) -> List[OrderedCollective]:
    """Every collective instruction sorted into backend issue order.

    Sort key is (channel_id, appearance); instructions without a
    channel_id (not SPMD-partitioned) sort after those with one, in
    textual order.  Async ``-start``/``-done`` pairs are collapsed onto
    the ``-start`` line (the one carrying the attributes); the CPU
    backend this repo verifies on emits only the sync forms.
    """
    flat = parse_collectives(hlo_text)
    byte_table = {c.name: c for c in flat}
    out: List[OrderedCollective] = []
    seen: set = set()
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, out_shape, op = m.group(1), m.group(2), m.group(3)
        base = op[:-len("-start")] if op.endswith("-start") else op
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        if name in seen:
            continue
        seen.add(name)
        cm = _CHANNEL_RE.search(line)
        ref = byte_table.get(name)
        out.append(OrderedCollective(
            kind=base, name=name,
            channel_id=int(cm.group(1)) if cm else -1,
            operand_bytes=ref.operand_bytes if ref else 0,
            output_bytes=ref.output_bytes if ref else shape_bytes(out_shape),
            replica_groups=_parse_groups(line),
            source_target_pairs=_parse_pairs(line)))
    out.sort(key=lambda c: (c.channel_id < 0, c.channel_id))
    return out


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n":"(\d+)"')
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(
    r"(?:true_computation|false_computation|branch_computations=\{[^}]*)"
    r"=?%?([\w.\-]+)")


def _split_computations(hlo_text: str):
    """Yield (name, lines, is_entry) per HLO computation."""
    name, lines, entry = None, [], False
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            if name is not None:
                yield name, lines, entry
            name, lines = m.group(1), []
            entry = line.lstrip().startswith("ENTRY")
        elif name is not None:
            lines.append(line)
    if name is not None:
        yield name, lines, entry


def collective_totals(hlo_text: str) -> Dict[str, float]:
    """Loop-aware totals: collectives inside `while` bodies are multiplied
    by the statically-known trip count (scan phases, layer loops)."""
    comps: Dict[str, dict] = {}
    entry = None
    for name, lines, is_entry in _split_computations(hlo_text):
        body = "\n".join(lines)
        ops = parse_collectives(body)
        edges = []   # (callee, multiplier)
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                edges.append((wm.group(1), 1))
                edges.append((wm.group(2), trips))
                continue
            for cm in _CALL_RE.finditer(line):
                edges.append((cm.group(1), 1))
        comps[name] = dict(ops=ops, edges=edges)
        if is_entry:
            entry = name

    memo: Dict[str, Dict[str, float]] = {}

    def visit(name: str) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        memo[name] = {"operand_bytes": 0.0, "wire_bytes": 0.0, "count": 0.0}
        info = comps.get(name)
        if info is None:
            return memo[name]
        tot = {"operand_bytes": float(sum(o.operand_bytes
                                          for o in info["ops"])),
               "wire_bytes": float(sum(o.wire_bytes for o in info["ops"])),
               "count": float(len(info["ops"]))}
        for kind in _COLLECTIVES:
            sel = [o for o in info["ops"] if o.kind == kind]
            if sel:
                tot[f"{kind}_wire_bytes"] = float(
                    sum(o.wire_bytes for o in sel))
                tot[f"{kind}_count"] = float(len(sel))
        for callee, mult in info["edges"]:
            sub = visit(callee)
            for key, v in sub.items():
                tot[key] = tot.get(key, 0.0) + mult * v
        memo[name] = tot
        return tot

    if entry is None:
        return {"operand_bytes": 0.0, "wire_bytes": 0.0, "count": 0.0}
    return visit(entry)


_DOT_RE = re.compile(r"\bdot\(")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"dot\(([^)]*)\)")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "broadcast", "reshape",
}


def _instruction_stats(lines, shapes) -> Dict[str, float]:
    """Dot FLOPs + bytes-touched for one computation's instructions."""
    flops = 0.0
    byt = 0.0
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, out_shape, op = m.group(1), m.group(2), m.group(3)
        if op == "dot":
            out_elems = 1
            sm = _SHAPE_RE.search(out_shape)
            if sm:
                for d in sm.group(2).split(","):
                    if d:
                        out_elems *= int(d)
            cdims = _LHS_C_RE.search(line)
            lhs_name = None
            om = _OPERANDS_RE.search(line)
            if om:
                lhs_name = om.group(1).split(",")[0].strip().lstrip("%")
            k = 1
            if cdims and lhs_name and lhs_name in shapes:
                lm = _SHAPE_RE.search(shapes[lhs_name])
                if lm:
                    dims = [int(d) for d in lm.group(2).split(",") if d]
                    for ci in cdims.group(1).split(","):
                        if ci:
                            k *= dims[int(ci)]
            flops += 2.0 * out_elems * k
        if op not in _SKIP_BYTES_OPS:
            byt += shape_bytes(out_shape)
            om2 = line[line.index(op + "(") + len(op) + 1:] \
                if op + "(" in line else ""
            for ref in re.findall(r"%([\w.\-]+)", om2.split(")")[0]):
                if ref in shapes:
                    byt += shape_bytes(shapes[ref])
    return {"dot_flops": flops, "bytes_touched": byt}


def program_totals(hlo_text: str) -> Dict[str, float]:
    """Loop-aware per-device totals: dot FLOPs, bytes touched, collectives.

    Instructions inside `while` bodies are multiplied by the statically
    known trip count (scan layers / microbatches).  FLOPs counts
    dot_general only (the MFU convention); bytes sums operand+output sizes
    of every non-trivial instruction (an upper bound that ignores fusion
    reuse — stated convention for the memory roofline term).
    """
    # global shape table across all computations
    shapes: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    comps: Dict[str, dict] = {}
    entry = None
    for name, lines, is_entry in _split_computations(hlo_text):
        stats = _instruction_stats(lines, shapes)
        ops = parse_collectives("\n".join(lines))
        # control edges (while bodies/conds, branches) carry trip
        # multipliers and contribute BYTES; fusion/to_apply edges are
        # descended for FLOPs only — fusion interiors stay in registers,
        # so HBM traffic is counted at fusion boundaries (the fusion
        # instruction's own operands/outputs in the parent computation).
        control_edges, fusion_edges = [], []
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                control_edges.append((wm.group(1), 1))
                control_edges.append((wm.group(2), trips))
                continue
            for cm in _CALL_RE.finditer(line):
                fusion_edges.append((cm.group(1), 1))
        comps[name] = dict(stats=stats, ops=ops,
                           control_edges=control_edges,
                           fusion_edges=fusion_edges)
        if is_entry:
            entry = name

    memo: Dict[str, Dict[str, float]] = {}

    def visit(name):
        if name in memo:
            return memo[name]
        memo[name] = {"dot_flops": 0.0, "bytes_touched": 0.0,
                      "wire_bytes": 0.0}
        info = comps.get(name)
        if info is None:
            return memo[name]
        tot = dict(info["stats"])
        tot["wire_bytes"] = float(sum(o.wire_bytes for o in info["ops"]))
        for callee, mult in info["control_edges"]:
            sub = visit(callee)
            for key, v in sub.items():
                tot[key] = tot.get(key, 0.0) + mult * v
        for callee, mult in info["fusion_edges"]:
            sub = visit(callee)
            tot["dot_flops"] += mult * sub.get("dot_flops", 0.0)
            tot["wire_bytes"] += mult * sub.get("wire_bytes", 0.0)
        memo[name] = tot
        return tot

    if entry is None:
        return {"dot_flops": 0.0, "bytes_touched": 0.0, "wire_bytes": 0.0}
    return visit(entry)


def wire_words(hlo_text: str, *, word_bytes: int = 4) -> Dict[str, float]:
    """Loop-aware per-device wire traffic in ELEMENT counts per collective.

    The cost model (``repro.core.costmodel``) and the tracing layer
    (``repro.obs``) both speak *words* — float32 elements — while the HLO
    walk naturally yields bytes.  This converts the loop-aware
    ``collective_totals`` to element counts so measured traffic and the
    Table-III formulas compare in the same unit: ``{"total": words,
    "count": collectives, "<kind>": words, "<kind>_count": n}`` with one
    entry per collective kind that actually occurs.  ``word_bytes``
    rescales for non-f32 payloads (e.g. 2 for a bf16-compressed wire).
    """
    totals = collective_totals(hlo_text)
    out: Dict[str, float] = {
        "total": totals.get("wire_bytes", 0.0) / word_bytes,
        "count": totals.get("count", 0.0),
    }
    for kind in _COLLECTIVES:
        wb = totals.get(f"{kind}_wire_bytes")
        if wb is not None:
            out[kind] = wb / word_bytes
            out[f"{kind}_count"] = totals.get(f"{kind}_count", 0.0)
    return out


def collective_summary(hlo_text: str) -> Dict[str, float]:
    """Aggregate per-device collective traffic from an HLO module.

    Flat (loop-unaware) counts plus loop-aware ``total_*`` entries.
    """
    ops = parse_collectives(hlo_text)
    summary: Dict[str, float] = {
        "collective_op_count": len(ops),
        "operand_bytes": float(sum(o.operand_bytes for o in ops)),
        "wire_bytes": float(sum(o.wire_bytes for o in ops)),
    }
    for kind in _COLLECTIVES:
        sel = [o for o in ops if o.kind == kind]
        if sel:
            summary[f"{kind}_count"] = len(sel)
            summary[f"{kind}_operand_bytes"] = float(
                sum(o.operand_bytes for o in sel))
            summary[f"{kind}_wire_bytes"] = float(
                sum(o.wire_bytes for o in sel))
    for key, v in collective_totals(hlo_text).items():
        summary[f"total_{key}"] = v
    return summary
