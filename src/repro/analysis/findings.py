"""Structured findings + allowlists shared by the linter and conformance.

A :class:`Finding` is one violation: rule id, repo-relative path,
1-based line, message, and an optional ``symbol`` (dotted context such
as ``SparseResult.to_dense``) that allowlists can match on.

Allowlists are plain-text files (one per rule, under
``repro/analysis/rules/allow/``).  Each non-comment line is::

    <path-glob>[::<symbol-substring>]  --  <reason>

A finding is *allowlisted* (reported but not a failure) when its path
matches the glob (``fnmatch`` on the repo-relative posix path) and, if
the entry names a symbol, that substring occurs in the finding's
symbol.  The reason travels with the finding into the report so every
suppression stays self-documenting.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Dict, Iterable, List, Optional, Sequence


@dataclasses.dataclass
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str            # repo-relative posix path
    line: int
    message: str
    symbol: str = ""     # dotted context, e.g. "SparseResult.to_dense"
    allowlisted: bool = False
    note: str = ""       # allowlist reason when allowlisted

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "Finding":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)
                      if f.name in d})

    def render(self) -> str:
        tail = f"  [allowlisted: {self.note}]" if self.allowlisted else ""
        sym = f" ({self.symbol})" if self.symbol else ""
        return f"{self.rule} {self.location}{sym}: {self.message}{tail}"


@dataclasses.dataclass
class AllowEntry:
    """One allowlist line: path glob, optional symbol substring, reason."""

    path_glob: str
    symbol: str
    reason: str

    def matches(self, finding: Finding) -> bool:
        if not fnmatch.fnmatch(finding.path, self.path_glob):
            return False
        if self.symbol and self.symbol not in finding.symbol:
            return False
        return True


def parse_allowlist(text: str) -> List[AllowEntry]:
    entries: List[AllowEntry] = []
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if "--" in line:
            pattern, reason = line.split("--", 1)
        else:
            pattern, reason = line, ""
        pattern = pattern.strip()
        if "::" in pattern:
            glob, symbol = pattern.split("::", 1)
        else:
            glob, symbol = pattern, ""
        entries.append(AllowEntry(glob.strip(), symbol.strip(),
                                  reason.strip()))
    return entries


def apply_allowlist(findings: Iterable[Finding],
                    entries: Sequence[AllowEntry]) -> List[Finding]:
    """Mark (not drop) findings matched by allowlist entries."""
    out = []
    for f in findings:
        for e in entries:
            if e.matches(f):
                f.allowlisted = True
                f.note = e.reason or "allowlisted"
                break
        out.append(f)
    return out


def violations(findings: Iterable[Finding]) -> List[Finding]:
    return [f for f in findings if not f.allowlisted]


def lint_report(findings: Sequence[Finding],
                files_scanned: int) -> Dict[str, object]:
    return {
        "files_scanned": files_scanned,
        "violations": len(violations(findings)),
        "allowlisted": sum(1 for f in findings if f.allowlisted),
        "findings": [f.to_dict() for f in findings],
    }


def write_report(report: Dict[str, object], path: str) -> str:
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def load_report(path: str) -> Dict[str, object]:
    with open(path) as fh:
        return json.load(fh)


def findings_from_report(report: Dict[str, object]) -> List[Finding]:
    lint = report.get("lint", report)
    raw: Optional[List[Dict[str, object]]] = lint.get("findings")  # type: ignore[union-attr]
    return [Finding.from_dict(d) for d in (raw or [])]
