"""Static schedule-conformance verifier (docs/static_analysis.md).

For every registry cell (family x op x elision x comm x session) this
lowers the executor to partitioned HLO *without executing it* and
checks that the backend will communicate exactly what the family's
published schedule promises:

1. **Sequence** (dense cells) - the ordered collective instructions
   (sorted by XLA ``channel_id``) match the ``schedule_words`` event
   list one-to-one after collapsing both sides into maximal same-kind
   runs: same run kinds in the same order, identical per-run wire-word
   totals (the model is impl-exact, so comparison is exact up to
   float round-off), and for all-gather/reduce-scatter runs the exact
   instruction count.  Collective-permutes may legalize one schedule
   shift into several instructions (one per traveling array / ring), so
   only their run totals are pinned, plus a lower bound of one
   instruction per live shift event.
2. **Replica groups** - every all-gather/reduce-scatter partitions the
   mesh exactly: disjoint, equal-sized groups whose union is
   ``{0..p-1}``; every collective-permute's source-target pairs form a
   partial permutation (no duplicated source or target, all in range).
3. **Rendezvous** - an SPMD simulation over per-rank event queues: each
   rank posts its collectives in channel order; a collective fires only
   when *all* declared group members have it at the head of their
   queue.  The cell passes only if the simulation drains every queue -
   any omission, duplication, or cross-rank reordering deadlocks.

``comm="sparse"`` cells have data-dependent wire volume
(``schedule_words`` returns None by contract), so they get the
structural checks (2)+(3) only - their verdict rows carry
``mode="structural"``.

This is the static complement of the dynamic drift gate in
``repro.obs`` (PR 9): the tracer proves the *measured words* of an
executed round match the model; this proves the *structure* - kind,
order, group soundness, deadlock-freedom - before anything runs.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["ExpectedEvent", "CellVerdict", "expected_collectives",
           "match_sequence", "check_groups", "rank_programs",
           "simulate_rendezvous", "verify_cell", "conformance_cells",
           "run_conformance", "write_report", "load_report"]

WORD_BYTES = 4            # f32 wire words, the repo-wide unit
GATHERLIKE = ("all-gather", "reduce-scatter", "all-reduce")


# ---------------------------------------------------------------------------
# Expected sequence from the family's published schedule
# ---------------------------------------------------------------------------

class ExpectedEvent(tuple):
    """(point, phase, kind, words) of one wire-visible schedule event."""

    __slots__ = ()

    def __new__(cls, point: str, phase: int, kind: str, words: float):
        return tuple.__new__(cls, (point, phase, kind, words))

    point = property(lambda self: self[0])
    phase = property(lambda self: self[1])
    kind = property(lambda self: self[2])
    words = property(lambda self: self[3])


def expected_collectives(prob, op: str, elision: str = "none",
                         session=None) -> Optional[List[ExpectedEvent]]:
    """Wire-visible events of one cell, in schedule order.

    Derived from ``Algorithm.schedule_words``: events with ``kind=None``
    (compute phases) or zero words (shifts XLA dead-code-eliminates)
    emit no HLO instruction and are dropped.  Family modules may declare
    ``WIRE_EXPANSIONS`` mapping ``(op, point)`` to a kind tuple for
    schedule events that legalize into several collectives (s25's
    FusedMM reduce = reduce-scatter + value re-broadcast all-gather);
    the event's words split evenly across the expansion.  Returns None
    for support-pruned packs (``schedule_words`` contract).
    """
    words = prob.alg.schedule_words(prob, op, elision, session=session)
    if words is None:
        return None
    expansions = getattr(prob.alg._sched_mod, "WIRE_EXPANSIONS", {})
    out: List[ExpectedEvent] = []
    for point, phase, kind, w in words:
        if kind is None or w <= 0:
            continue
        kinds = expansions.get((op, point), (kind,))
        for k in kinds:
            out.append(ExpectedEvent(point, phase, k, w / len(kinds)))
    return out


# ---------------------------------------------------------------------------
# Sequence matching (maximal same-kind runs)
# ---------------------------------------------------------------------------

def _runs(seq: Iterable[Tuple[str, float]]) -> List[Tuple[str, int, float]]:
    """Collapse (kind, words) into maximal runs: (kind, count, words)."""
    out: List[Tuple[str, int, float]] = []
    for kind, words in seq:
        if out and out[-1][0] == kind:
            k, c, w = out[-1]
            out[-1] = (k, c + 1, w + words)
        else:
            out.append((kind, 1, words))
    return out


def match_sequence(expected: Sequence[ExpectedEvent],
                   instrs: Sequence,
                   word_bytes: int = WORD_BYTES) -> List[str]:
    """Errors from comparing the schedule to the ordered HLO collectives."""
    errors: List[str] = []
    exp = _runs((e.kind, e.words) for e in expected)
    got = _runs((i.kind, i.wire_bytes / word_bytes) for i in instrs)
    if [r[0] for r in exp] != [r[0] for r in got]:
        errors.append(
            f"collective kind sequence mismatch: schedule promises "
            f"{[f'{k}x{c}' for k, c, _ in exp]}, HLO emits "
            f"{[f'{k}x{c}' for k, c, _ in got]}")
        return errors
    for (kind, ecount, ewords), (_, gcount, gwords) in zip(exp, got):
        if kind in GATHERLIKE and ecount != gcount:
            errors.append(
                f"{kind} run: schedule has {ecount} event(s), HLO has "
                f"{gcount} instruction(s)")
        if kind == "collective-permute" and gcount < ecount:
            errors.append(
                f"collective-permute run: {ecount} live shift event(s) "
                f"but only {gcount} instruction(s)")
        if abs(ewords - gwords) > 1e-6 * max(1.0, abs(ewords)):
            errors.append(
                f"{kind} run words: modeled {ewords:.1f} != measured "
                f"{gwords:.1f}")
    return errors


# ---------------------------------------------------------------------------
# Replica-group soundness
# ---------------------------------------------------------------------------

def check_groups(instrs: Sequence, p: int) -> List[str]:
    """Mesh-partition errors of every collective's group structure."""
    errors: List[str] = []
    for ins in instrs:
        if ins.kind in GATHERLIKE:
            groups = ins.replica_groups
            if not groups:
                errors.append(f"{ins.name}: no replica_groups parsed")
                continue
            flat = [r for g in groups for r in g]
            sizes = {len(g) for g in groups}
            if len(sizes) != 1:
                errors.append(f"{ins.name}: unequal group sizes {sizes}")
            if len(flat) != len(set(flat)):
                errors.append(f"{ins.name}: overlapping replica groups")
            if set(flat) != set(range(p)):
                errors.append(
                    f"{ins.name}: groups cover {sorted(set(flat))}, "
                    f"not the full mesh 0..{p - 1}")
        elif ins.kind == "collective-permute":
            pairs = ins.source_target_pairs
            if not pairs:
                errors.append(f"{ins.name}: no source_target_pairs parsed")
                continue
            srcs = [s for s, _ in pairs]
            tgts = [t for _, t in pairs]
            if len(srcs) != len(set(srcs)) or len(tgts) != len(set(tgts)):
                errors.append(
                    f"{ins.name}: source_target_pairs not a partial "
                    f"permutation")
            bad = [x for x in srcs + tgts if not 0 <= x < p]
            if bad:
                errors.append(
                    f"{ins.name}: pair ranks {sorted(set(bad))} outside "
                    f"mesh 0..{p - 1}")
    return errors


# ---------------------------------------------------------------------------
# SPMD rendezvous simulation
# ---------------------------------------------------------------------------

def rank_programs(instrs: Sequence, p: int) -> Dict[int, List[tuple]]:
    """Per-rank collective queues, in backend issue (channel) order.

    Each queue entry is a collective id ``(index, group)`` shared by
    exactly the declared participants: one id per replica group of a
    gather-like collective (groups rendezvous independently), one id
    per collective-permute covering the union of its pair endpoints.
    """
    prog: Dict[int, List[tuple]] = {r: [] for r in range(p)}
    for idx, ins in enumerate(instrs):
        if ins.kind in GATHERLIKE and ins.replica_groups:
            parts = [tuple(sorted(g)) for g in ins.replica_groups]
        elif ins.kind == "collective-permute" and ins.source_target_pairs:
            members = sorted({x for pr in ins.source_target_pairs
                              for x in pr})
            parts = [tuple(members)]
        else:
            parts = [tuple(range(p))]     # conservative: global barrier
        for group in parts:
            cid = (idx, group)
            for r in group:
                if 0 <= r < p:
                    prog[r].append(cid)
    return prog


def simulate_rendezvous(prog: Dict[int, List[tuple]]) -> Dict[str, object]:
    """Drain per-rank queues under the SPMD rendezvous rule.

    A collective id fires only when every rank in its declared group
    (``cid[1]``) has that id at the head of its queue; firing pops it
    everywhere at once.  Returns ``{"ok", "fired", "stuck"}`` where
    ``stuck`` maps each undrained rank to its blocking head entry -
    non-empty exactly when the schedule can deadlock (a rank that never
    posts, posts twice, or posts out of order relative to a peer).
    """
    pos = {r: 0 for r in prog}
    fired: List[tuple] = []
    while True:
        progressed = False
        for r in sorted(prog):
            if pos[r] >= len(prog[r]):
                continue
            cid = prog[r][pos[r]]
            group = cid[1]
            ready = all(
                g in prog and pos[g] < len(prog[g])
                and prog[g][pos[g]] == cid
                for g in group)
            if ready:
                for g in group:
                    pos[g] += 1
                fired.append(cid)
                progressed = True
        if not progressed:
            break
    stuck = {r: repr(prog[r][pos[r]]) for r in sorted(prog)
             if pos[r] < len(prog[r])}
    return {"ok": not stuck, "fired": len(fired), "stuck": stuck}


# ---------------------------------------------------------------------------
# Per-cell verification
# ---------------------------------------------------------------------------

class CellVerdict(dict):
    """Report row for one verified cell (plain dict, JSON-ready)."""

    @property
    def ok(self) -> bool:
        return self["verdict"] == "pass"


def _lower(prob, op: str, elision: str, session):
    if op == "sddmm":
        return prob.alg.lower_sddmm(prob, session)
    if op == "spmm":
        return prob.alg.lower_spmm(prob, session)
    if op == "spmm_t":
        return prob.alg.lower_spmm_t(prob, session)
    if op == "fusedmm":
        return prob.alg.lower_fusedmm(prob, elision, session)
    raise ValueError(f"unknown op {op!r}")


def verify_cell(prob, op: str, elision: str = "none", session=None,
                expected_override: Optional[Sequence[ExpectedEvent]] = None,
                ) -> CellVerdict:
    """Statically verify one registry cell; never executes the program.

    ``expected_override`` substitutes the schedule-derived expectation
    (tests corrupt it to prove the checker notices).
    """
    from repro.roofline.hlo_parse import ordered_collectives

    p = int(prob.p)
    comm = getattr(prob, "comm", "dense")
    cell = (f"{prob.alg.name}.{op}"
            + (f"[{elision}]" if op == "fusedmm" else "")
            + f"[{comm}]" + ("+sess" if session is not None else ""))
    checks: Dict[str, str] = {}
    errors: List[str] = []

    lowered = _lower(prob, op, elision, session)
    hlo = lowered.compile().as_text()
    instrs = ordered_collectives(hlo)

    expected = expected_override
    if expected is None:
        expected = expected_collectives(prob, op, elision, session=session)
    mode = "structural" if expected is None else "full"

    if expected is not None:
        seq_errors = match_sequence(expected, instrs)
        checks["sequence"] = "fail" if seq_errors else "pass"
        errors.extend(seq_errors)

    group_errors = check_groups(instrs, p)
    checks["replica_groups"] = "fail" if group_errors else "pass"
    errors.extend(group_errors)

    sim = simulate_rendezvous(rank_programs(instrs, p))
    checks["rendezvous"] = "pass" if sim["ok"] else "fail"
    if not sim["ok"]:
        errors.append(f"rendezvous deadlock: stuck ranks {sim['stuck']}")

    return CellVerdict(
        cell=cell, family=prob.alg.name, op=op, elision=elision,
        comm=comm, session=session is not None, p=p, mode=mode,
        collectives=len(instrs),
        modeled_words=(None if expected is None
                       else round(sum(e.words for e in expected), 3)),
        measured_words=round(sum(i.wire_bytes for i in instrs)
                             / WORD_BYTES, 3),
        rendezvous_fired=sim["fired"],
        checks=checks, errors=errors,
        verdict="fail" if errors else "pass")


# ---------------------------------------------------------------------------
# Registry sweep
# ---------------------------------------------------------------------------

def _make_problem(family: str, comm: str, *, m: int, n: int, r: int,
                  c: int, nnz_row: int):
    import numpy as np

    from repro.core import api, sparse

    rows, cols, _ = sparse.erdos_renyi(m, n, nnz_row, seed=0)
    rng = np.random.default_rng(0)
    vals = rng.integers(1, 5, rows.shape[0]).astype(np.float32)
    return api.make_problem(rows, cols, vals, (m, n), r,
                            algorithm=family, c=c, comm=comm)


def conformance_cells(family_filter: Optional[str] = None,
                      comms: Tuple[str, ...] = ("dense", "sparse"),
                      ) -> List[dict]:
    """Enumerate the registry cell grid as kwargs for :func:`verify_cell`.

    The session axis is data-driven: a +session variant is emitted only
    when the family's ``schedule_words`` actually changes with a session
    (the pre-gathered program differs), so Session-inert cells (s25,
    d15/d25 spmm) are not compiled twice for an identical program.
    """
    from repro.core import api

    cells: List[dict] = []
    for family in sorted(api.ALGORITHMS):
        if family_filter and family != family_filter:
            continue
        alg = api.ALGORITHMS[family]
        ops = [("sddmm", ("none",)), ("spmm", ("none",)),
               ("spmm_t", ("none",)), ("fusedmm", alg.elisions)]
        for comm in comms:
            for op, elisions in ops:
                for el in elisions:
                    cells.append(dict(family=family, comm=comm, op=op,
                                      elision=el, session=False))
                    cells.append(dict(family=family, comm=comm, op=op,
                                      elision=el, session=True))
    return cells


def _session_sensitive(prob, op: str, elision: str) -> bool:
    from repro.core import api

    base = prob.alg.schedule_words(prob, op, elision, session=None)
    sess = prob.alg.schedule_words(prob, op, elision,
                                   session=api.Session())
    return base != sess


def run_conformance(family: Optional[str] = None,
                    comms: Tuple[str, ...] = ("dense", "sparse"),
                    *, m: int = 64, n: int = 64, r: int = 16, c: int = 2,
                    nnz_row: int = 4, progress=None) -> Dict[str, object]:
    """Verify the whole registry grid; returns the report dict.

    One problem per (family, comm) at the smoke shape (matching
    check_obs.py); session sensitivity is probed on the *dense* problem
    so the sparse grid keeps the same session axis.
    """
    import jax

    from repro.core import api

    p = len(jax.devices())
    probs: Dict[Tuple[str, str], object] = {}
    rows: List[CellVerdict] = []
    for spec in conformance_cells(family, comms):
        key = (spec["family"], spec["comm"])
        if key not in probs:
            probs[key] = _make_problem(*key, m=m, n=n, r=r, c=c,
                                       nnz_row=nnz_row)
        prob = probs[key]
        dense_key = (spec["family"], "dense")
        if dense_key not in probs:
            probs[dense_key] = _make_problem(*dense_key, m=m, n=n, r=r,
                                             c=c, nnz_row=nnz_row)
        if spec["session"] and not _session_sensitive(
                probs[dense_key], spec["op"], spec["elision"]):
            continue   # identical program; the plain cell covers it
        session = api.Session() if spec["session"] else None
        try:
            row = verify_cell(prob, spec["op"], spec["elision"], session)
        except Exception as exc:   # noqa: BLE001 - recorded per cell
            row = CellVerdict(
                cell=(f"{spec['family']}.{spec['op']}"
                      + (f"[{spec['elision']}]"
                         if spec["op"] == "fusedmm" else "")
                      + f"[{spec['comm']}]"
                      + ("+sess" if spec["session"] else "")),
                family=spec["family"], op=spec["op"],
                elision=spec["elision"], comm=spec["comm"],
                session=spec["session"], p=p, mode="error",
                collectives=0, modeled_words=None, measured_words=None,
                rendezvous_fired=0, checks={},
                errors=[f"verification raised: {exc!r}"], verdict="fail")
        rows.append(row)
        if progress is not None:
            progress(row)
    report = {
        "schema": 1,
        "p": p,
        "shape": {"m": m, "n": n, "r": r, "c": c, "nnz_row": nnz_row},
        "cells": [dict(r) for r in rows],
        "pass": sum(1 for r in rows if r.ok),
        "fail": sum(1 for r in rows if not r.ok),
        "structural": sum(1 for r in rows if r["mode"] == "structural"),
    }
    return report


def write_report(report: Dict[str, object], path: str) -> str:
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def load_report(path: str) -> Dict[str, object]:
    with open(path) as fh:
        return json.load(fh)
