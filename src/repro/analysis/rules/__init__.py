"""Repo-specific invariant rules R1-R5 (docs/static_analysis.md).

Each rule module exports a :class:`Rule`.  AST rules implement
``check(tree, path, source)`` over one file (``applies`` filters
paths); repo-level rules implement ``check_repo()`` instead.  Every
rule has a plain-text allowlist at ``rules/allow/<id>.txt`` whose
entries mark findings as accepted without deleting the evidence.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable, Dict, List, Optional

from repro.analysis.findings import AllowEntry, Finding, parse_allowlist

_ALLOW_DIR = os.path.join(os.path.dirname(__file__), "allow")


@dataclasses.dataclass
class Rule:
    """One invariant: per-file AST check or repo-level check."""

    id: str
    title: str
    applies: Callable[[str], bool]
    check: Optional[Callable[[ast.Module, str, str], List[Finding]]] = None
    check_repo: Optional[Callable[[], List[Finding]]] = None

    def allowlist(self, allow_dir: Optional[str] = None) -> List[AllowEntry]:
        path = os.path.join(allow_dir or _ALLOW_DIR,
                            f"{self.id.lower()}.txt")
        if not os.path.exists(path):
            return []
        with open(path) as fh:
            return parse_allowlist(fh.read())


def all_rules() -> Dict[str, Rule]:
    from repro.analysis.rules import (r1_layering, r2_round_guards,
                                      r3_dense_materialization,
                                      r4_callback_capture, r5_registry_cells)
    mods = (r1_layering, r2_round_guards, r3_dense_materialization,
            r4_callback_capture, r5_registry_cells)
    return {m.RULE.id: m.RULE for m in mods}


def dotted_name(node: ast.AST) -> str:
    """Best-effort dotted name of a Name/Attribute chain ('' otherwise)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    elif parts:
        parts.append("<expr>")
    return ".".join(reversed(parts))
