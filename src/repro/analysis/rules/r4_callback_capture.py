"""R4 - pure_callback closures must not capture mutable module state.

``jax.pure_callback`` promises XLA the bridged host function is pure:
the compiler is free to cache, reorder, elide, or re-execute it.  A
callback that reads a module-level mutable (a registry dict, a
rebindable ``_ACTIVE``-style global) breaks that promise - the traced
program bakes in whichever state existed at call time, and retraces vs
cache hits silently diverge.  Closing over locals of the enclosing
function (``prob``, ``ctx``) is fine: those are frozen per trace.

The rule finds calls to ``pure_callback`` (or the repo's ``_callback``
wrapper), resolves the callback argument when it is a lambda or a
locally-defined function, and flags reads of module-level names that
look mutable: assigned a list/dict/set literal or comprehension,
re-assigned more than once at module scope, or named in any ``global``
statement.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.findings import Finding
from repro.analysis.rules import Rule, dotted_name

CALLBACK_NAMES = ("pure_callback", "_callback")
_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)


def _applies(path: str) -> bool:
    return path.endswith(".py")


def _mutable_module_names(tree: ast.Module) -> Set[str]:
    assigned_count: dict = {}
    mutable: Set[str] = set()
    for stmt in tree.body:
        targets: List[ast.expr] = []
        value: Optional[ast.expr] = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        elif isinstance(stmt, ast.AugAssign):
            targets, value = [stmt.target], None
        for t in targets:
            if isinstance(t, ast.Name):
                assigned_count[t.id] = assigned_count.get(t.id, 0) + 1
                if value is not None and isinstance(value,
                                                    _MUTABLE_LITERALS):
                    mutable.add(t.id)
    mutable.update(n for n, c in assigned_count.items() if c > 1)
    for node in ast.walk(tree):
        if isinstance(node, ast.Global):
            mutable.update(node.names)
    return mutable


def _local_names(fn: ast.AST) -> Set[str]:
    """Names bound inside the callback (params + stores) - not captures."""
    out: Set[str] = set()
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        a = fn.args
        for arg in (a.posonlyargs + a.args + a.kwonlyargs
                    + ([a.vararg] if a.vararg else [])
                    + ([a.kwarg] if a.kwarg else [])):
            out.add(arg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node is not fn:
                out.add(node.name)
    return out


def _resolve_callback(tree: ast.Module,
                      arg: ast.expr) -> Optional[ast.AST]:
    if isinstance(arg, ast.Lambda):
        return arg
    if isinstance(arg, ast.Name):
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == arg.id:
                return node
    return None


def _check(tree: ast.Module, path: str, source: str) -> List[Finding]:
    del source
    mutable = _mutable_module_names(tree)
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        if dotted_name(node.func).split(".")[-1] not in CALLBACK_NAMES:
            continue
        fn = _resolve_callback(tree, node.args[0])
        if fn is None:
            continue   # parameter-forwarded callable; analyzed at its def
        locals_ = _local_names(fn)
        body = fn.body if isinstance(fn, ast.Lambda) else fn
        captured = set()
        for sub in ast.walk(body):
            if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
                if sub.id in mutable and sub.id not in locals_:
                    captured.add(sub.id)
        cb = getattr(fn, "name", "<lambda>")
        for name in sorted(captured):
            findings.append(Finding(
                rule="R4", path=path, line=node.lineno,
                symbol=cb,
                message=(f"pure_callback-bridged '{cb}' reads mutable "
                         f"module state '{name}'; XLA may cache or replay "
                         f"the callback with stale state")))
    return findings


RULE = Rule(
    id="R4",
    title="pure_callback closures must not capture mutable module state",
    applies=_applies,
    check=_check,
)
