"""R2 - round boundaries: DistProblem executors consult guard + tracer.

Every executor round boundary (``DistProblem.sddmm/spmm/spmm_t/
fusedmm``) is where fault injection fires and where the observability
tracer opens its round span; a method that skips either check silently
opts that op out of the fault-recovery contract (check_faults.py) and
the cost-model drift gate (check_obs.py).  The rule requires each
executor method body to contain both a ``faults.guard(...)`` call (any
call whose dotted name ends in ``guard``) and a tracer consult (any
call whose dotted name mentions ``tracer``, which covers both the
direct ``obs_tracer.active()`` form and the lazy ``_tracer_active()``
helper).
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.findings import Finding
from repro.analysis.rules import Rule, dotted_name

EXECUTOR_METHODS = ("sddmm", "spmm", "spmm_t", "fusedmm")


def _applies(path: str) -> bool:
    return path.endswith(".py")


def _calls(node: ast.AST) -> List[str]:
    return [dotted_name(c.func) for c in ast.walk(node)
            if isinstance(c, ast.Call)]


def _check(tree: ast.Module, path: str, source: str) -> List[Finding]:
    del source
    findings = []
    for cls in ast.walk(tree):
        if not (isinstance(cls, ast.ClassDef) and cls.name == "DistProblem"):
            continue
        for meth in cls.body:
            if not isinstance(meth, ast.FunctionDef):
                continue
            if meth.name not in EXECUTOR_METHODS:
                continue
            names = _calls(meth)
            sym = f"DistProblem.{meth.name}"
            if not any(n.split(".")[-1] == "guard" for n in names):
                findings.append(Finding(
                    rule="R2", path=path, line=meth.lineno, symbol=sym,
                    message=(f"executor round boundary '{meth.name}' never "
                             f"calls faults.guard; fault injection cannot "
                             f"fire for this op")))
            if not any("tracer" in n for n in names):
                findings.append(Finding(
                    rule="R2", path=path, line=meth.lineno, symbol=sym,
                    message=(f"executor round boundary '{meth.name}' never "
                             f"consults the obs tracer; rounds for this op "
                             f"are invisible to the drift gate")))
    return findings


RULE = Rule(
    id="R2",
    title="DistProblem executor rounds consult faults.guard and the tracer",
    applies=_applies,
    check=_check,
)
