"""R1 - layering: core/kernels must not eagerly import upper layers.

``repro.core`` and ``repro.kernels`` are the foundation every other
subsystem builds on; an eager (module-scope) import of
``repro.training``, ``repro.serving`` or ``repro.obs`` from them
inverts the dependency graph, makes the kernels unimportable without
the full stack, and reintroduces the import cycles the lazy-helper
pattern in ``core/api.py`` exists to prevent.  Function-scoped (lazy)
imports are fine - that is the sanctioned escape hatch.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.findings import Finding
from repro.analysis.rules import Rule

FOUNDATION = ("repro/core/", "repro/kernels/")
FORBIDDEN = ("repro.training", "repro.serving", "repro.obs")


def _applies(path: str) -> bool:
    return any(seg in path for seg in FOUNDATION)


def _forbidden(module: str) -> bool:
    return any(module == f or module.startswith(f + ".")
               for f in FORBIDDEN)


def _eager_imports(node: ast.AST) -> List[ast.stmt]:
    """Imports executed at module import time: module scope, class
    bodies, and top-level if/try arms - everything except function
    bodies."""
    out: List[ast.stmt] = []
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        if isinstance(child, (ast.Import, ast.ImportFrom)):
            out.append(child)
        else:
            out.extend(_eager_imports(child))
    return out


def _check(tree: ast.Module, path: str, source: str) -> List[Finding]:
    del source
    findings = []
    for node in _eager_imports(tree):
        if isinstance(node, ast.Import):
            targets = [a.name for a in node.names]
        else:
            assert isinstance(node, ast.ImportFrom)
            targets = [node.module] if node.module else []
        for mod in targets:
            if _forbidden(mod):
                findings.append(Finding(
                    rule="R1", path=path, line=node.lineno, symbol=mod,
                    message=(f"eager import of upper layer '{mod}' from "
                             f"foundation module; use a function-scoped "
                             f"(lazy) import instead")))
    return findings


RULE = Rule(
    id="R1",
    title="core/kernels must not eagerly import training/serving/obs",
    applies=_applies,
    check=_check,
)
