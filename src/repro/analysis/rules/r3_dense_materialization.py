"""R3 - no dense full-shape materialization in hot paths.

The paper's whole point is that the m x n sparse matrix never exists
densely on any rank; an ``np.zeros((m, n))`` / ``.todense()`` in an
executor or kernel hot path silently re-introduces the O(m*n) memory
the 1.5D/2.5D decompositions exist to avoid, and scales catastrophically
past toy sizes.  The rule flags, inside ``repro/core``,
``repro/kernels`` and ``repro/serving``:

* any ``.todense()`` / ``.toarray()`` call, and
* ``zeros/ones/empty/full``-style allocations whose shape argument is a
  2-tuple of one m-like and one n-like problem dimension (terminal
  attribute or bare name ``m``/``n``, in either order) - the
  ``np.zeros((prob.m, prob.n))`` idiom.

Documented debug-only host views (e.g. ``SparseResult.to_dense``) are
allowlisted with a reason rather than rewritten.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.findings import Finding
from repro.analysis.rules import Rule, dotted_name

HOT_DIRS = ("repro/core/", "repro/kernels/", "repro/serving/")
ALLOC_NAMES = ("zeros", "ones", "empty", "full")
DENSIFY_ATTRS = ("todense", "toarray")


def _applies(path: str) -> bool:
    return any(seg in path for seg in HOT_DIRS)


def _dim_letter(node: ast.expr) -> Optional[str]:
    """'m' or 'n' when the expression is an m/n problem dimension."""
    if isinstance(node, ast.Name) and node.id in ("m", "n"):
        return node.id
    if isinstance(node, ast.Attribute) and node.attr in ("m", "n"):
        return node.attr
    return None


def _enclosing(tree: ast.Module, target: ast.AST) -> str:
    """Dotted class/function context of a node (for the finding symbol)."""
    path: List[str] = []

    def visit(node: ast.AST, ctx: List[str]) -> bool:
        if node is target:
            path.extend(ctx)
            return True
        name = getattr(node, "name", None) if isinstance(
            node, (ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef)
        ) else None
        nxt = ctx + [name] if name else ctx
        return any(visit(c, nxt) for c in ast.iter_child_nodes(node))

    visit(tree, [])
    return ".".join(path)


def _check(tree: ast.Module, path: str, source: str) -> List[Finding]:
    del source
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fname = dotted_name(node.func)
        leaf = fname.split(".")[-1]
        if leaf in DENSIFY_ATTRS and isinstance(node.func, ast.Attribute):
            findings.append(Finding(
                rule="R3", path=path, line=node.lineno,
                symbol=_enclosing(tree, node),
                message=(f".{leaf}() densifies a sparse operand to the "
                         f"full problem shape in a hot path")))
            continue
        if leaf in ALLOC_NAMES and node.args:
            shape = node.args[0]
            if isinstance(shape, (ast.Tuple, ast.List)) \
                    and len(shape.elts) == 2:
                dims = {_dim_letter(e) for e in shape.elts}
                if dims == {"m", "n"}:
                    findings.append(Finding(
                        rule="R3", path=path, line=node.lineno,
                        symbol=_enclosing(tree, node),
                        message=(f"{fname}((m, n)) materializes the full "
                                 f"dense problem shape in a hot path")))
    return findings


RULE = Rule(
    id="R3",
    title="no dense full-shape materialization in executor/kernel hot paths",
    applies=_applies,
    check=_check,
)
