"""R5 - every registry cell has schedule_events/schedule_words.

The registry (``repro.core.api.ALGORITHMS``) declares the cell grid
(family x op x elision) the whole stack iterates over - fault
injection, the obs drift gate, the conformance verifier, serving.  All
of them assume each family's schedule module answers
``schedule_events(grid, op, elision)`` with a non-empty ordered
(point, phase) list and exposes a matching ``schedule_words``.  A cell
registered without its schedule silently falls out of every one of
those contracts, so the rule probes each declared cell through the
same entry points the runtime uses (with a stub grid - no devices, no
jax tracing).
"""
from __future__ import annotations

import inspect
import os
import types
from typing import Dict, List, Optional

from repro.analysis.findings import Finding
from repro.analysis.rules import Rule

OPS = ("sddmm", "spmm", "spmm_t", "fusedmm")
_STUB_GRID = types.SimpleNamespace(L=4, G=2, c=2, p=8)


def _mod_path(mod: object) -> str:
    try:
        f = inspect.getsourcefile(mod) or ""
    except TypeError:
        f = ""
    f = f.replace(os.sep, "/")
    if "/src/" in f:
        return f.split("/src/", 1)[1]
    return f or "<registry>"


def check_registry(algorithms: Optional[Dict[str, object]] = None
                   ) -> List[Finding]:
    """Probe every declared (family x op x elision) cell.

    ``algorithms`` defaults to the live registry; tests inject fake
    registries to exercise each failure mode without touching it.
    """
    if algorithms is None:
        from repro.core import api
        algorithms = api.ALGORITHMS
    findings: List[Finding] = []
    for name in sorted(algorithms):
        alg = algorithms[name]
        sched = getattr(alg, "_sched_mod", None)
        path = _mod_path(sched if sched is not None else type(alg))
        if sched is None:
            findings.append(Finding(
                rule="R5", path=path, line=1, symbol=name,
                message=f"registry family '{name}' has no schedule module"))
            continue
        events = getattr(sched, "schedule_events", None)
        words = getattr(sched, "schedule_words", None)
        if not callable(events):
            findings.append(Finding(
                rule="R5", path=path, line=1, symbol=name,
                message=(f"family '{name}' schedule module lacks a "
                         f"callable schedule_events")))
            continue
        if not callable(words):
            findings.append(Finding(
                rule="R5", path=path, line=1, symbol=name,
                message=(f"family '{name}' schedule module lacks a "
                         f"callable schedule_words")))
        else:
            params = set(inspect.signature(words).parameters)
            missing = {"grid", "plan", "op"} - params
            if missing:
                findings.append(Finding(
                    rule="R5", path=path, line=1, symbol=name,
                    message=(f"family '{name}' schedule_words signature "
                             f"missing {sorted(missing)}")))
        elisions = tuple(getattr(alg, "elisions", ()) or ("none",))
        for op in OPS:
            cell_elisions = elisions if op == "fusedmm" else ("none",)
            for el in cell_elisions:
                cell = f"{name}.{op}[{el}]"
                try:
                    ev = events(_STUB_GRID, op, el)
                except Exception as exc:   # noqa: BLE001 - reported
                    findings.append(Finding(
                        rule="R5", path=path, line=1, symbol=cell,
                        message=(f"schedule_events raised for declared "
                                 f"cell {cell}: {exc!r}")))
                    continue
                ok = (isinstance(ev, list) and ev
                      and all(isinstance(e, tuple) and len(e) == 2
                              for e in ev))
                if not ok:
                    findings.append(Finding(
                        rule="R5", path=path, line=1, symbol=cell,
                        message=(f"schedule_events({cell}) must return a "
                                 f"non-empty list of (point, phase) "
                                 f"tuples, got {type(ev).__name__}")))
    return findings


RULE = Rule(
    id="R5",
    title="every registry cell has schedule_events/schedule_words",
    applies=lambda path: False,        # repo-level, not per-file
    check_repo=check_registry,
)
