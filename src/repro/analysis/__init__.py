"""Static analysis: invariant linter + schedule-conformance verifier.

``python -m repro.analysis`` runs the linter (rules R1-R5) over
``src/repro`` and exits nonzero on violations;
``python -m repro.analysis conformance`` lowers every registry cell to
HLO and verifies its collective sequence against the published
schedule (docs/static_analysis.md).

This package root stays jax-free so pure-AST callers (editors, CI
lint-only steps) can import it without pulling the numeric stack:
``conformance`` is a submodule import away, and rule R5 imports the
registry only when it actually runs.
"""
from repro.analysis.findings import (AllowEntry, Finding, apply_allowlist,
                                     load_report, parse_allowlist,
                                     violations, write_report)
from repro.analysis.lint import (default_src_root, iter_sources, lint_file,
                                 render_findings, run_lint)

__all__ = [
    "AllowEntry", "Finding", "apply_allowlist", "parse_allowlist",
    "violations", "load_report", "write_report",
    "default_src_root", "iter_sources", "lint_file", "render_findings",
    "run_lint",
]
