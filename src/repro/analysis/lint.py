"""AST invariant linter over ``src/repro`` (rules R1-R5).

Per-file rules parse each source once and run every applicable rule's
AST check; repo-level rules (R5) probe the live registry.  Findings
matched by a rule's allowlist are *marked*, not dropped - they stay in
the report with the suppression reason, so the evidence and the excuse
travel together.  ``run_lint`` is pure (no process exit, no printing);
the CLI in ``__main__`` layers exit codes on top.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import (Finding, apply_allowlist, lint_report,
                                     violations)
from repro.analysis.rules import Rule, all_rules

__all__ = ["default_src_root", "iter_sources", "lint_file", "run_lint",
           "render_findings", "violations"]


def default_src_root() -> str:
    """The ``src`` directory containing the ``repro`` package."""
    here = os.path.dirname(os.path.abspath(__file__))   # .../src/repro/analysis
    return os.path.dirname(os.path.dirname(here))


def iter_sources(src_root: str) -> List[str]:
    """All ``repro/**/*.py`` paths, repo-relative (posix separators)."""
    out = []
    pkg_root = os.path.join(src_root, "repro")
    for dirpath, dirnames, filenames in os.walk(pkg_root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__",))
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                rel = os.path.relpath(os.path.join(dirpath, fn), src_root)
                out.append(rel.replace(os.sep, "/"))
    return out


def lint_file(path: str, source: str,
              rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Run every applicable per-file rule over one source blob.

    ``path`` is the repo-relative posix path the rules filter on; the
    file need not exist on disk (tests feed synthetic snippets).
    """
    active = list(rules) if rules is not None else \
        list(all_rules().values())
    tree = ast.parse(source, filename=path)
    findings: List[Finding] = []
    for rule in active:
        if rule.check is None or not rule.applies(path):
            continue
        findings.extend(rule.check(tree, path, source))
    return findings


def run_lint(src_root: Optional[str] = None,
             rules: Optional[Dict[str, Rule]] = None,
             allow_dir: Optional[str] = None,
             with_registry: bool = True,
             ) -> Tuple[List[Finding], int]:
    """Lint the whole tree; returns (findings, files_scanned).

    Findings are allowlist-marked and sorted (path, line, rule).
    ``with_registry=False`` skips repo-level rules (R5 imports the
    registry, which pulls in jax - pure-AST callers can opt out).
    """
    root = src_root or default_src_root()
    table = rules if rules is not None else all_rules()
    findings: List[Finding] = []
    paths = iter_sources(root)
    for rel in paths:
        with open(os.path.join(root, rel)) as fh:
            source = fh.read()
        findings.extend(lint_file(rel, source, rules=table.values()))
    if with_registry:
        for rule in table.values():
            if rule.check_repo is not None:
                findings.extend(rule.check_repo())
    for rule in table.values():
        entries = rule.allowlist(allow_dir)
        if entries:
            apply_allowlist([f for f in findings if f.rule == rule.id],
                            entries)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return findings, len(paths)


def render_findings(findings: Sequence[Finding]) -> str:
    if not findings:
        return "lint: clean"
    lines = [f.render() for f in findings]
    bad = violations(findings)
    lines.append(f"lint: {len(bad)} violation(s), "
                 f"{len(findings) - len(bad)} allowlisted")
    return "\n".join(lines)


def make_lint_report(findings: Sequence[Finding],
                     files_scanned: int) -> Dict[str, object]:
    return lint_report(findings, files_scanned)
