"""CLI: ``python -m repro.analysis [lint|conformance|all]``.

Exit status is nonzero when any lint violation (non-allowlisted
finding) or failing conformance cell exists — CI gates on it.  The
conformance sweep needs a multi-device mesh, so the device-count flag
is set *before* anything imports jax (XLA pins the host device count
at first backend init); an inherited XLA_FLAGS wins.
"""
import argparse
import os
import sys


def _parse(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo invariant linter + schedule-conformance verifier")
    ap.add_argument("command", nargs="?", default="lint",
                    choices=("lint", "conformance", "all"))
    ap.add_argument("--root", default=None,
                    help="src directory to lint (default: the installed "
                         "repro package's src root)")
    ap.add_argument("--report", default=None,
                    help="write ANALYSIS_report.json here (default: "
                         "ANALYSIS_report.json for conformance/all, "
                         "none for lint)")
    ap.add_argument("--family", default=None,
                    help="restrict conformance to one registry family")
    ap.add_argument("--comm", default=None, choices=("dense", "sparse"),
                    help="restrict conformance to one wire format")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count for conformance "
                         "(ignored when XLA_FLAGS is already set)")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse(sys.argv[1:] if argv is None else argv)
    report = {"schema": 1}
    failed = False

    if args.command in ("lint", "all"):
        from repro.analysis import lint
        findings, scanned = lint.run_lint(src_root=args.root)
        print(lint.render_findings(findings))
        report["lint"] = lint.make_lint_report(findings, scanned)
        failed |= bool(lint.violations(findings))

    if args.command in ("conformance", "all"):
        if "XLA_FLAGS" not in os.environ:
            os.environ["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={args.devices}")
        from repro.analysis import conformance
        comms = (args.comm,) if args.comm else ("dense", "sparse")

        def progress(row):
            words = ("" if row["modeled_words"] is None else
                     f" modeled={row['modeled_words']:.0f}"
                     f" measured={row['measured_words']:.0f}")
            print(f"{row['verdict']:4s} {row['cell']:32s} "
                  f"[{row['mode']}] collectives={row['collectives']}"
                  + words)
            for err in row["errors"]:
                print(f"     ! {err}")

        conf = conformance.run_conformance(family=args.family,
                                           comms=comms,
                                           progress=progress)
        report["conformance"] = conf
        print(f"conformance: {conf['pass']} pass, {conf['fail']} fail "
              f"({conf['structural']} structural) on p={conf['p']}")
        failed |= conf["fail"] > 0

    report_path = args.report
    if report_path is None and args.command != "lint":
        report_path = "ANALYSIS_report.json"
    if report_path:
        from repro.analysis.findings import write_report
        write_report(report, report_path)
        print(f"wrote {report_path}")

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
