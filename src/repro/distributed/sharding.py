"""Sharding hygiene: divisibility sanitizing and FSDP extension.

``sanitize`` drops any PartitionSpec entry whose mesh-axis product does not
divide the corresponding array dimension (odd vocab sizes like 50280 or
batch=1 decode simply fall back to replication on that dim — exactly what
a production launcher must do rather than crash).

``fsdp_extend`` implements ZeRO-3/FSDP via GSPMD: each parameter (and its
optimizer moments) additionally shards one free, divisible dimension over
the data axis; the partitioner inserts the per-layer all-gathers.  Without
this, f32 params + Adam moments of the 52B/72B architectures are 39+ GB
per chip — with it they drop to ~2.5 GB (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
from jax.sharding import PartitionSpec as P


def _axes_size(entry, axis_sizes: Dict[str, int]) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return math.prod(axis_sizes.get(a, 1) for a in entry if a)
    return axis_sizes.get(entry, 1)


def sanitize_spec(spec: P, shape, axis_sizes: Dict[str, int]) -> P:
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        size = _axes_size(entry, axis_sizes)
        out.append(entry if size > 0 and dim % size == 0 else None)
    return P(*out)


def sanitize_tree(spec_tree, shape_tree, axis_sizes: Dict[str, int]):
    return jax.tree.map(
        lambda s, x: sanitize_spec(s, x.shape, axis_sizes),
        spec_tree, shape_tree,
        is_leaf=lambda v: isinstance(v, P))


def fsdp_extend_spec(spec: P, shape, axis_sizes: Dict[str, int],
                     data_axis: str, min_size: int = 2 ** 16) -> P:
    """Shard one free dim over the data axis (largest divisible dim)."""
    if math.prod(shape) < min_size:      # skip small tensors (norms, biases)
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    dsize = axis_sizes.get(data_axis, 1)
    best, best_dim = None, 0
    for i, (dim, entry) in enumerate(zip(shape, entries)):
        if entry is None and dim % dsize == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best is not None:
        entries[best] = data_axis
    return P(*entries)


def fsdp_extend_tree(spec_tree, shape_tree, axis_sizes, data_axis):
    return jax.tree.map(
        lambda s, x: fsdp_extend_spec(s, x.shape, axis_sizes, data_axis),
        spec_tree, shape_tree,
        is_leaf=lambda v: isinstance(v, P))


_ACTIVE_MESH = None


def set_mesh(mesh) -> None:
    """Version-portable ambient-mesh install.

    jax >= 0.6 has ``jax.set_mesh``; older versions get the same effect by
    entering the Mesh context.  Re-installing (elastic remesh) exits the
    previously entered context first so the stack doesn't grow unboundedly.
    """
    global _ACTIVE_MESH
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
        return
    if _ACTIVE_MESH is not None:
        _ACTIVE_MESH.__exit__(None, None, None)
    mesh.__enter__()
    _ACTIVE_MESH = mesh
