"""Elastic scaling, straggler mitigation, and failure handling.

Design for 1000+ nodes (CPU-simulated here, same control flow on TPU):

* **Checkpoint/restart** — every step is restartable from the last
  committed checkpoint (atomic rename + _COMMITTED marker).  The launcher
  wraps each step in ``run_step_resilient``: a *retryable* failure
  triggers restore-and-retry with exponential backoff; repeated failures
  raise after ``max_retries``.  Only errors in :data:`RETRYABLE` are
  retried — a retry loop that swallows every ``Exception`` turns caller
  bugs (TypeError, shape mismatch) into silent infinite restores, so
  non-transient errors propagate on the first attempt.

* **Elastic re-mesh** — ``remesh``: given a new device count, recompute the
  mesh + shardings and device_put the restored pytrees.  Because all
  shardings derive from PartitionSpecs over named axes, a job can resume
  on a smaller/larger pod slice as long as divisibility holds (the
  standard slice-resize flow).  Whole-problem re-planning (degraded mesh,
  re-dispatched algorithm family) lives in ``repro.core.api.degrade``.

* **Straggler mitigation** — ``StepMonitor`` tracks a rolling median of
  step times; a step exceeding ``straggler_factor`` x median flags the
  step.  On real multi-host deployments the flagged host would be
  cordoned and the job re-meshed; here the hook fires a callback and the
  flagged step ids accumulate in ``monitor.flagged`` (tested
  deterministically with a fake clock).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.faults import TransientFault
from repro.obs import metrics as obs_metrics


def _runtime_error_types():
    """The runtime-side error types a production step can die with."""
    types = []
    try:
        from jax.errors import JaxRuntimeError
        types.append(JaxRuntimeError)
    except ImportError:
        pass
    try:
        from jaxlib.xla_extension import XlaRuntimeError
        types.append(XlaRuntimeError)
    except ImportError:
        pass
    return tuple(types)


#: Errors worth a restore-and-retry: injected faults from the harness and
#: runtime/collective failures from XLA.  Everything else is a caller bug.
RETRYABLE = (TransientFault,) + _runtime_error_types()


def backoff_delays(max_retries: int, *, base: float = 0.0,
                   factor: float = 2.0, max_delay: float = 2.0,
                   jitter: float = 0.25, seed: int = 0):
    """Deterministic exponential-backoff schedule with seeded jitter.

    Yields ``max_retries`` delays: ``min(base * factor**k, max_delay)``
    scaled by ``1 + jitter * U[0,1)`` from ``np.random.default_rng(seed)``
    — the same seed replays the same schedule, so retry timing is part of
    the reproducible record, not noise.
    """
    rng = np.random.default_rng(seed)
    d = base
    for _ in range(max_retries):
        yield min(d, max_delay) * (1.0 + jitter * float(rng.uniform()))
        d = d * factor if d > 0 else base


@dataclasses.dataclass
class StepMonitor:
    straggler_factor: float = 3.0
    window: int = 32
    clock: Callable[[], float] = time.monotonic
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _times: list = dataclasses.field(default_factory=list)
    #: step ids flagged as stragglers, in observation order
    flagged: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; returns True if flagged as straggler."""
        med = float(np.median(self._times)) if self._times else None
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        reg = obs_metrics.active()
        if reg is not None:
            reg.observe("train.step_seconds", seconds)
        if med is not None and seconds > self.straggler_factor * med:
            self.flagged.append(step)
            if reg is not None:
                reg.inc("train.stragglers")
            if self.on_straggler:
                self.on_straggler(step, seconds, med)
            return True
        return False

    def timed(self, step: int, fn, *a, **kw):
        t0 = self.clock()
        out = fn(*a, **kw)
        jax.block_until_ready(out)
        self.observe(step, self.clock() - t0)
        return out


def remesh(n_devices: int, model_parallel: int):
    """Build a (data, model) mesh over the first n_devices devices."""
    devs = np.array(jax.devices())[:n_devices]
    assert n_devices % model_parallel == 0
    return Mesh(devs.reshape(n_devices // model_parallel, model_parallel),
                ("data", "model"))


def run_step_resilient(step_fn, save_fn, restore_fn, *args,
                       max_retries: int = 2, on_failure=None,
                       retryable=RETRYABLE, backoff=None,
                       sleep=time.sleep):
    """Execute one training step with restore-and-retry semantics.

    step_fn dying with a *retryable* error (injected ``TransientFault``,
    runtime ``XlaRuntimeError`` from a preempted host or failed
    collective) triggers ``restore_fn() -> fresh args`` and a retry after
    an exponential-backoff delay.  Non-retryable errors — TypeErrors,
    shape mismatches, any caller bug — propagate immediately: retrying
    them can only loop forever on the same deterministic failure.

    ``backoff`` is an iterable of delays (default: ``backoff_delays``
    with zero base delay, i.e. no sleeping in tests); ``sleep`` is
    injectable for deterministic tests.  ``restore_fn`` may return None
    to retry with the original args.
    """
    delays = iter(backoff if backoff is not None
                  else backoff_delays(max_retries))
    attempt = 0
    while True:
        try:
            return step_fn(*args)
        except retryable as e:
            attempt += 1
            if on_failure:
                on_failure(attempt, e)
            if attempt > max_retries:
                raise
            d = next(delays, 0.0)
            if d > 0:
                sleep(d)
            fresh = restore_fn() if restore_fn is not None else None
            if fresh is not None:
                args = fresh
