"""Elastic scaling, straggler mitigation, and failure handling.

Design for 1000+ nodes (CPU-simulated here, same control flow on TPU):

* **Checkpoint/restart** — every step is restartable from the last
  committed checkpoint (atomic rename + _COMMITTED marker).  The launcher
  wraps each step in ``run_step_resilient``: a transient failure triggers
  restore-and-retry; repeated failures raise after ``max_retries``.

* **Elastic re-mesh** — ``remesh``: given a new device count, recompute the
  mesh + shardings and device_put the restored pytrees.  Because all
  shardings derive from PartitionSpecs over named axes, a job can resume
  on a smaller/larger pod slice as long as divisibility holds (the
  standard slice-resize flow).

* **Straggler mitigation** — ``StepMonitor`` tracks a rolling median of
  step times; a step exceeding ``straggler_factor`` x median flags the
  step.  On real multi-host deployments the flagged host would be
  cordoned and the job re-meshed; here the hook fires a callback (tested
  deterministically with a fake clock).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass
class StepMonitor:
    straggler_factor: float = 3.0
    window: int = 32
    clock: Callable[[], float] = time.monotonic
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _times: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; returns True if flagged as straggler."""
        med = float(np.median(self._times)) if self._times else None
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        if med is not None and seconds > self.straggler_factor * med:
            if self.on_straggler:
                self.on_straggler(step, seconds, med)
            return True
        return False

    def timed(self, step: int, fn, *a, **kw):
        t0 = self.clock()
        out = fn(*a, **kw)
        jax.block_until_ready(out)
        self.observe(step, self.clock() - t0)
        return out


def remesh(n_devices: int, model_parallel: int):
    """Build a (data, model) mesh over the first n_devices devices."""
    devs = np.array(jax.devices())[:n_devices]
    assert n_devices % model_parallel == 0
    return Mesh(devs.reshape(n_devices // model_parallel, model_parallel),
                ("data", "model"))


def run_step_resilient(step_fn, save_fn, restore_fn, *args,
                       max_retries: int = 2, on_failure=None):
    """Execute one training step with restore-and-retry semantics.

    step_fn raising (preempted host, failed collective) triggers
    restore_fn() -> fresh (params, opt_state) and a retry.  This is the
    per-step fault boundary the 1000-node deployment relies on; at that
    scale step_fn failures come from the runtime as XlaRuntimeError.
    """
    attempt = 0
    while True:
        try:
            return step_fn(*args)
        except Exception as e:   # noqa: BLE001 — any device failure
            attempt += 1
            if on_failure:
                on_failure(attempt, e)
            if attempt > max_retries:
                raise
            args = restore_fn()
