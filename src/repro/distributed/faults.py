"""Deterministic fault-injection harness for the distributed executors.

The paper's headline runs are 256-node jobs where device loss and
stragglers are routine; this module makes those failures *scriptable* so
the recovery machinery in ``repro.core.api`` can be driven
deterministically and replayed bit-for-bit.

Model
-----
Every executor round (one ``DistProblem.sddmm/spmm/spmm_t/fusedmm``
call) follows a statically known communication schedule: an optional
fiber **gather**, a sequence of **phase** computations interleaved with
cyclic **shift**s, and possibly a terminal **reduce**/scatter.  Each
family module exports its schedule (``d15.schedule_events`` etc.) as an
ordered list of ``(point, phase)`` events; a fault is addressed by the
coordinate

    (op, point, rank, phase, round)

— the ``round``-th guarded call of ``op`` since injection was armed, at
schedule event ``(point, phase)``, originating from device ``rank``.
A collective failure kills the whole round (exactly as a lost device
inside an all-gather or ppermute does on real hardware), so the guard
raises on the host at the round boundary, *before* launching the jitted
executor — the failure is observed at the same program point a runtime
``XlaRuntimeError`` would surface.

Faults are **typed**: :class:`TransientFault` models a recoverable hiccup
(link timeout, preemption — retry on the same mesh succeeds);
:class:`DeviceLost` additionally names the failed rank and requires the
caller to re-plan onto a degraded mesh (``repro.core.api.ElasticProblem``
does both).  A scripted spec fires exactly once; the retry that follows
runs fault-free unless another spec matches.

Determinism
-----------
:meth:`FaultPlan.random` derives every coordinate from a seeded
``numpy`` PRNG, so a failing injection run is replayable from its seed
alone; :meth:`FaultController.summary` returns a JSON-ready record of
every guarded round and every fired fault (the CI artifact).

Nothing here imports jax — the harness is pure host-side bookkeeping and
costs nothing when no plan is armed.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "TransientFault", "DeviceLost", "FaultSpec", "FaultPlan", "unwrap",
    "FaultController", "inject", "active", "guard", "OPS", "POINTS",
]

OPS = ("sddmm", "spmm", "spmm_t", "fusedmm")
POINTS = ("gather", "phase", "shift", "reduce")


class TransientFault(RuntimeError):
    """A retryable executor failure (simulated timeout / preemption).

    ``coord`` carries the (op, point, rank, phase, round) the fault was
    injected at, so recovery logs and test assertions can name it."""

    def __init__(self, msg: str, coord: Optional[dict] = None):
        super().__init__(msg)
        self.coord = coord or {}


class DeviceLost(TransientFault):
    """A device dropped out of the mesh: retrying on the same grid can
    never succeed — the caller must re-plan onto a degraded mesh
    (``repro.core.api.degrade``).  ``rank`` is the flat
    device index (schedule order) of the lost device."""

    def __init__(self, msg: str, rank: int, coord: Optional[dict] = None):
        super().__init__(msg, coord)
        self.rank = int(rank)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault at a (op, point, rank, phase, round) coordinate.

    ``-1`` / ``"*"`` wildcards match the first candidate in schedule
    order; ``round`` counts guarded calls of ``op`` since the plan was
    armed (0-based).  ``kind`` is ``"transient"`` or ``"device_lost"``.
    """
    op: str = "*"
    point: str = "*"
    rank: int = -1
    phase: int = -1
    round: int = 0
    kind: str = "transient"

    def __post_init__(self):
        if self.kind not in ("transient", "device_lost"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.op != "*" and self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r}; known: {OPS}")
        if self.point != "*" and self.point not in POINTS:
            raise ValueError(f"unknown point {self.point!r}; "
                             f"known: {POINTS}")


class FaultPlan:
    """An ordered script of :class:`FaultSpec`s; each fires at most once."""

    def __init__(self, specs: Sequence[FaultSpec]):
        self.specs: List[FaultSpec] = list(specs)

    @classmethod
    def scripted(cls, *specs: FaultSpec) -> "FaultPlan":
        return cls(specs)

    @classmethod
    def random(cls, seed: int, n_faults: int = 1, *, p: int = 8,
               ops: Sequence[str] = OPS,
               points: Sequence[str] = POINTS,
               max_phase: int = 2, max_round: int = 2,
               kinds: Sequence[str] = ("transient",)) -> "FaultPlan":
        """Seeded, replayable plan: identical seeds script identical
        coordinates (the harness's replay guarantee)."""
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(n_faults):
            specs.append(FaultSpec(
                op=str(rng.choice(list(ops))),
                point=str(rng.choice(list(points))),
                rank=int(rng.integers(p)),
                phase=int(rng.integers(max_phase)),
                round=int(rng.integers(max_round)),
                kind=str(rng.choice(list(kinds)))))
        return cls(specs)

    def __len__(self):
        return len(self.specs)


class FaultController:
    """Walks each guarded round's schedule against the armed plan.

    ``rounds`` counts guarded calls per op; ``log`` records every round
    (fired or not) and ``fired`` every injected fault — together the
    fault-injection summary the CI job uploads."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.pending: List[FaultSpec] = list(plan.specs)
        self.rounds: dict = {}
        self.log: List[dict] = []
        self.fired: List[dict] = []
        #: the typed fault most recently raised and not yet reclaimed by
        #: :func:`unwrap` — survives laundering through XLA boundaries
        self.last_raised: Optional[TransientFault] = None

    def guard(self, op: str, family: str, p: int,
              events: Sequence[Tuple[str, int]]):
        """Check one executor round against the plan; raises on a match.

        ``events`` is the family's ordered (point, phase) schedule for
        this op.  The first pending spec whose coordinate occurs in the
        schedule fires (and is consumed); specs naming coordinates the
        schedule never reaches stay pending — a no-op, not an error.
        """
        rnd = self.rounds.get(op, 0)
        self.rounds[op] = rnd + 1
        rec = dict(op=op, family=family, round=rnd, p=p,
                   events=len(events), fired=False)
        self.log.append(rec)
        for i, spec in enumerate(self.pending):
            if spec.op not in ("*", op) or spec.round not in (-1, rnd):
                continue
            for point, phase in events:
                if spec.point not in ("*", point):
                    continue
                if spec.phase not in (-1, phase):
                    continue
                rank = spec.rank if spec.rank >= 0 else 0
                if rank >= p:
                    continue        # names a rank this mesh doesn't have
                del self.pending[i]
                coord = dict(op=op, family=family, point=point,
                             rank=rank, phase=phase, round=rnd)
                rec["fired"] = True
                rec["coord"] = coord
                self.fired.append(coord)
                msg = (f"injected {spec.kind} fault at {point} "
                       f"(rank {rank}, phase {phase}) in {family}.{op} "
                       f"round {rnd}")
                if spec.kind == "device_lost":
                    err = DeviceLost(msg, rank, coord)
                else:
                    err = TransientFault(msg, coord)
                self.last_raised = err
                raise err

    def summary(self) -> dict:
        """JSON-ready injection record (the CI artifact payload)."""
        return dict(rounds=dict(self.rounds), guarded=len(self.log),
                    fired=self.fired,
                    pending=[dataclasses.asdict(s) for s in self.pending],
                    log=self.log)


_ACTIVE: Optional[FaultController] = None


def active() -> Optional[FaultController]:
    return _ACTIVE


@contextlib.contextmanager
def inject(plan):
    """Arm a fault plan for the dynamic extent of the context.

    Yields the :class:`FaultController` so callers can read the
    injection log/summary afterwards.  Nesting restores the previous
    controller on exit."""
    global _ACTIVE
    if not isinstance(plan, FaultPlan):
        plan = FaultPlan(plan)
    ctl = FaultController(plan)
    prev = _ACTIVE
    _ACTIVE = ctl
    try:
        yield ctl
    finally:
        _ACTIVE = prev


def unwrap(e: BaseException) -> BaseException:
    """Recover the typed fault behind an XLA-laundered exception.

    A guard firing inside a ``jax.pure_callback`` (the autodiff path
    wraps executors in callbacks) surfaces to the caller as an
    ``XlaRuntimeError`` — the Python exception type, and with it
    ``DeviceLost.rank``, is lost at the runtime boundary.  The
    controller keeps the typed original in ``last_raised``; this
    reclaims it (once) so recovery code can still dispatch on
    transient-vs-device-lost.  Already-typed exceptions and exceptions
    raised with no armed controller pass through unchanged.
    """
    if isinstance(e, TransientFault):
        return e
    if _ACTIVE is not None and _ACTIVE.last_raised is not None:
        typed, _ACTIVE.last_raised = _ACTIVE.last_raised, None
        return typed
    return e


def guard(op: str, problem, elision: str = "none") -> None:
    """Fault boundary of one executor round — called by the api layer.

    No-op (one attribute read) when no plan is armed.  ``problem`` is a
    ``repro.core.api.DistProblem``; its algorithm supplies the family's
    (point, phase) schedule for ``op`` (FusedMM schedules depend on the
    resolved ``elision``), so the scripted coordinates line up with what
    the executor actually does on the wire.
    """
    if _ACTIVE is None:
        return
    events = problem.alg.schedule_events(problem, op, elision)
    _ACTIVE.guard(op, problem.alg.name, problem.p, events)
