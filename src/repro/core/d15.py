"""1.5D dense-shifting, dense-replicating algorithms (paper Algorithm 1).

Grid: ("layer" = p/c, "fiber" = c).  The sparse matrix S is STATIONARY
(block (u, j) lives on device (u, j % c)), one dense matrix is REPLICATED
along the fiber (all-gather input / reduce-scatter output), the other dense
matrix PROPAGATES via cyclic shifts within each layer.

Block schedule: A row-block i lives on device (i // c, i % c).  B row-block
j starts on device (j // c, j % c); after t shifts device (u, v) holds
B block ((u - t) mod L) * c + v.  The planner materializes, for every
(device, phase), the row-tiled pack of the S block the local kernel needs,
so the jitted executor is a pure scan of {local kernel; ppermute}.

Modes (unified, per the paper's SpMM<->SDDMM conversion):
  sddmm_d15   : R = S * (A @ B.T)          A replicated-in, B shifts
  spmma_d15   : A = S @ B                  A replicated-out, B shifts
  spmmb_d15   : B = S.T @ A                A replicated-in, B shifts+accum
  fusedmm_d15 : FusedMM with elision in {"none", "reuse", "fused"}
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import common
from repro.core.grid import Grid15
from repro.kernels import ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlanD15:
    """Device-placed per-(device, phase) packs of S (and S^T)."""
    rows_local: jax.Array   # (L, c, T, nb, k) int32
    cols: jax.Array
    vals: jax.Array
    tile_base: jax.Array    # (L, c, T, nb)
    m: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    r: int = dataclasses.field(metadata=dict(static=True))
    row_tile: int = dataclasses.field(metadata=dict(static=True))
    transpose: bool = dataclasses.field(metadata=dict(static=True))
    # host-only metadata (not traced):
    meta: object = dataclasses.field(metadata=dict(static=True))

    @property
    def block_shape(self) -> Tuple[int, int]:
        # (rows of the replicated/gathered matrix, rows of one B block)
        if self.transpose:
            return (self.nB, self.cmA)
        return (self.cmA, self.nB)

    @property
    def cmA(self):
        return self.meta.cmA

    @property
    def nB(self):
        return self.meta.nB


@dataclasses.dataclass(frozen=True, eq=False)
class MetaD15:
    cmA: int
    nB: int
    block_meta: common.BlockMeta


def plan_d15(grid: Grid15, rows, cols, vals, m: int, n: int, r: int, *,
             transpose: bool = False, row_tile: int = 256,
             nz_block: int = 256) -> PlanD15:
    """Pack S for the 1.5D dense-shifting schedule (host, amortized).

    transpose=True packs S^T blocks (needed by replication-reuse FusedMM
    and by SpMMB — the paper stores both copies, §IV-B).
    """
    L, c, p = grid.L, grid.c, grid.p
    assert m % p == 0 and n % p == 0, (m, n, p)
    mA, nB = m // p, n // p
    cmA = c * mA
    blk_shape = (nB, cmA) if transpose else (cmA, nB)
    row_tile = common.choose_row_tile(blk_shape[0], row_tile)

    part = common.block_partition(np.asarray(rows), np.asarray(cols),
                                  np.asarray(vals), cmA, nB, p)
    empty = (np.zeros(0, np.int32), np.zeros(0, np.int32),
             np.zeros(0, np.float32))
    blocks, row_off, col_off = [], [], []
    for u in range(L):
        for v in range(c):
            for t in range(L):
                j = ((u - t) % L) * c + v
                br, bc, bv = part.get((u, j), empty)
                if transpose:
                    br, bc = bc, br
                    row_off.append(j * nB), col_off.append(u * cmA)
                else:
                    row_off.append(u * cmA), col_off.append(j * nB)
                blocks.append((br, bc, bv))
    rl, cl, vl, tb = common.pack_block_list(blocks, blk_shape, row_tile,
                                            nz_block)
    shp = (L, c, L) + rl.shape[1:]
    sh5 = grid.sharding("layer", "fiber")
    meta = MetaD15(cmA, nB, common.BlockMeta(
        np.array(row_off).reshape(L, c, L),
        np.array(col_off).reshape(L, c, L),
        (n, m) if transpose else (m, n)))
    return PlanD15(
        jax.device_put(rl.reshape(shp), sh5),
        jax.device_put(cl.reshape(shp), sh5),
        jax.device_put(vl.reshape(shp), sh5),
        jax.device_put(tb.reshape((L, c, L) + tb.shape[1:]), sh5),
        m, n, r, row_tile, transpose, meta)


def _coo(plan: PlanD15, s):
    rl, cl, vl, tb = s
    return common.coo_of(rl, cl, vl, tb, plan.block_shape, plan.row_tile)


def _shift(x, axis_name, size):
    return jax.lax.ppermute(x, axis_name,
                            [(i, (i + 1) % size) for i in range(size)])


def _exec(grid: Grid15, plan: PlanD15, body, A, B, out_specs):
    """Common shard_map/jit harness; S pack enters with (layer,fiber) dims."""
    mesh, lay, fib = grid.mesh, grid.layer, grid.fiber
    s_spec = P(lay, fib)
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=((s_spec,) * 4, P((lay, fib)), P((lay, fib))),
        out_specs=out_specs, check_vma=False)
    s_pack = (plan.rows_local, plan.cols, plan.vals, plan.tile_base)
    return fn(s_pack, A, B)


def _squeeze_s(s):
    return tuple(x[0, 0] for x in s)   # drop (layer, fiber) unit dims


# ---------------------------------------------------------------------------
# Unified Algorithm 1: SDDMM / SpMMA / SpMMB
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,))
def sddmm_d15(grid: Grid15, plan: PlanD15, A, B):
    """R = S * (A @ B.T); returns stacked vals (L, c, T, nb, k)."""
    lay, fib, L = grid.layer, grid.fiber, grid.L

    def body(s, A_loc, B_loc):
        s = _squeeze_s(s)
        T = jax.lax.all_gather(A_loc, fib, tiled=True)     # (c m/p, r)

        def phase(B_cur, s_t):
            vals = ops.sddmm(T, B_cur, _coo(plan, s_t)).vals
            return _shift(B_cur, lay, L), vals

        _, r_vals = jax.lax.scan(phase, B_loc, s)
        return r_vals[None, None]

    return _exec(grid, plan, body, A, B, P(lay, fib))


@functools.partial(jax.jit, static_argnums=(0,))
def spmma_d15(grid: Grid15, plan: PlanD15, B):
    """A = S @ B with A replicated as output, reduce-scattered at the end."""
    lay, fib, L, c = grid.layer, grid.fiber, grid.L, grid.c

    def body(s, _unused, B_loc):
        s = _squeeze_s(s)
        T0 = jnp.zeros((plan.cmA, plan.r), jnp.float32)

        def phase(carry, s_t):
            B_cur, T = carry
            T = T + ops.spmm(_coo(plan, s_t), B_cur, m=plan.cmA)
            return (_shift(B_cur, lay, L), T), None

        (_, T), _ = jax.lax.scan(phase, (B_loc, T0), s)
        return jax.lax.psum_scatter(T, fib, scatter_dimension=0, tiled=True)

    dummy = jnp.zeros((grid.p, 1), jnp.float32)  # placeholder A slot
    return _exec(grid, plan, body, dummy, B, P((lay, fib)))


@functools.partial(jax.jit, static_argnums=(0,))
def spmmb_d15(grid: Grid15, plan: PlanD15, A):
    """B = S.T @ A: A replicated-in; the shifting B buffer accumulates."""
    assert plan.transpose, "spmmb_d15 needs a transpose-packed plan"
    lay, fib, L = grid.layer, grid.fiber, grid.L

    def body(s, A_loc, B0):
        s = _squeeze_s(s)
        T = jax.lax.all_gather(A_loc, fib, tiled=True)

        def phase(B_cur, s_t):
            B_cur = B_cur + ops.spmm(_coo(plan, s_t), T, m=plan.nB)
            return _shift(B_cur, lay, L), None

        B_out, _ = jax.lax.scan(phase, B0, s)
        return B_out   # full cycle: home again

    zeros = jnp.zeros((plan.n, plan.r), jnp.float32)
    zeros = jax.device_put(zeros, grid.sharding((lay, fib)))
    return _exec(grid, plan, body, A, zeros, P((lay, fib)))


# ---------------------------------------------------------------------------
# FusedMM with the paper's three strategies
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("elision",))
def fusedmm_d15(grid: Grid15, plan: PlanD15, A, B, elision: str = "none"):
    """FusedMM on the 1.5D dense-shifting grid.

    elision="none"  : FusedMMA, SDDMM then SpMMA (2 rounds, AG + RS)
    elision="reuse" : FusedMMB on the S^T pack (2 rounds, single AG)
    elision="fused" : FusedMMA via the fused local kernel (1 round, AG + RS)

    Returns (out_dense, R_vals_stacked).
    """
    lay, fib, L = grid.layer, grid.fiber, grid.L

    if elision == "none":
        assert not plan.transpose

        def body(s, A_loc, B_loc):
            s = _squeeze_s(s)
            T = jax.lax.all_gather(A_loc, fib, tiled=True)

            def phase1(B_cur, s_t):
                vals = ops.sddmm(T, B_cur, _coo(plan, s_t)).vals
                return _shift(B_cur, lay, L), vals

            B_home, r_vals = jax.lax.scan(phase1, B_loc, s)
            T2 = jnp.zeros((plan.cmA, plan.r), jnp.float32)

            def phase2(carry, inp):
                s_t, rv = inp
                B_cur, T2 = carry
                R_t = _coo(plan, s_t).with_vals(rv)
                T2 = T2 + ops.spmm(R_t, B_cur, m=plan.cmA)
                return (_shift(B_cur, lay, L), T2), None

            (_, T2), _ = jax.lax.scan(phase2, (B_home, T2), (s, r_vals))
            out = jax.lax.psum_scatter(T2, fib, scatter_dimension=0,
                                       tiled=True)
            return out, r_vals[None, None]

        return _exec(grid, plan, body, A, B, (P((lay, fib)), P(lay, fib)))

    if elision == "reuse":
        # FusedMMB: replicate A once; it serves the SDDMM *and* the SpMMB.
        assert plan.transpose, "reuse needs a transpose-packed plan"

        def body(s, A_loc, B_loc):
            s = _squeeze_s(s)
            T = jax.lax.all_gather(A_loc, fib, tiled=True)   # single AG

            def phase1(B_cur, s_t):
                # sampled <B_j, A_i> on the S^T layout
                vals = ops.sddmm(B_cur, T, _coo(plan, s_t)).vals
                return _shift(B_cur, lay, L), vals

            _, r_vals = jax.lax.scan(phase1, B_loc, s)
            out0 = jnp.zeros((plan.nB, plan.r), jnp.float32)

            def phase2(out_cur, inp):
                s_t, rv = inp
                Rt = _coo(plan, s_t).with_vals(rv)
                out_cur = out_cur + ops.spmm(Rt, T, m=plan.nB)
                return _shift(out_cur, lay, L), None

            out, _ = jax.lax.scan(phase2, out0, (s, r_vals))
            return out, r_vals[None, None]   # out home after full cycle

        return _exec(grid, plan, body, A, B, (P((lay, fib)), P(lay, fib)))

    if elision == "fused":
        assert not plan.transpose

        def body(s, A_loc, B_loc):
            s = _squeeze_s(s)
            T = jax.lax.all_gather(A_loc, fib, tiled=True)
            T2 = jnp.zeros((plan.cmA, plan.r), jnp.float32)

            def phase(carry, s_t):
                B_cur, T2 = carry
                contrib, R_t = ops.fusedmm(T, B_cur, _coo(plan, s_t),
                                           m=plan.cmA)
                return (_shift(B_cur, lay, L), T2 + contrib), R_t.vals

            (_, T2), r_vals = jax.lax.scan(phase, (B_loc, T2), s)
            out = jax.lax.psum_scatter(T2, fib, scatter_dimension=0,
                                       tiled=True)
            return out, r_vals[None, None]

        return _exec(grid, plan, body, A, B, (P((lay, fib)), P(lay, fib)))

    raise ValueError(f"unknown elision {elision!r}")
