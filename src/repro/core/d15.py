"""1.5D dense-shifting, dense-replicating algorithms (paper Algorithm 1).

Grid: ("layer" = p/c, "fiber" = c).  The sparse matrix S is STATIONARY
(block (u, j) lives on device (u, j % c)), one dense matrix is REPLICATED
along the fiber (all-gather input / reduce-scatter output), the other dense
matrix PROPAGATES via cyclic shifts within each layer.

Block schedule: A row-block i lives on device (i // c, i % c).  B row-block
j starts on device (j // c, j % c); after t shifts device (u, v) holds
B block ((u - t) mod L) * c + v.  The planner materializes, for every
(device, phase), the row-tiled pack of the S block the local kernel needs
— padded per *phase*, so a sparse phase no longer pays the densest phase's
block count — plus a static kernel tiling chosen from the pack statistics.

Comm/compute overlap (see DESIGN.md): every phase loop is Python-unrolled
with a double-buffered carry — the cyclic ``ppermute`` of the *next* B
shard is issued before the local kernel consumes the current one, so shift
latency hides behind SDDMM/SpMM/FusedMM compute.  Where the traveling
buffer itself accumulates kernel output (SpMMB, FusedMMB), the *next*
phase's local contribution is instead precomputed from stationary data
while the current shift is in flight.  ``overlap=False`` reproduces the
serial compute-then-shift schedule (numerically identical; kept for A/B
benchmarking and the equivalence tests).

Modes (unified, per the paper's SpMM<->SDDMM conversion):
  sddmm_d15   : R = S * (A @ B.T)          A replicated-in, B shifts
  spmma_d15   : A = S @ B                  A replicated-out, B shifts
  spmmb_d15   : B = S.T @ A                A replicated-in, B shifts+accum
  fusedmm_d15 : FusedMM, elision in {"auto", "none", "reuse", "fused"}
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import common, costmodel
from repro.core.grid import Grid15
from repro.kernels import ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlanD15:
    """Device-placed per-(device, phase) packs of S (and S^T).

    Each field is a tuple with one stacked array per phase; block counts
    may differ across phases (per-phase padding).
    """
    rows_local: Tuple[jax.Array, ...]   # T x (L, c, nb_t, k) int32
    cols: Tuple[jax.Array, ...]
    vals: Tuple[jax.Array, ...]
    tile_base: Tuple[jax.Array, ...]    # T x (L, c, nb_t)
    m: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    r: int = dataclasses.field(metadata=dict(static=True))
    row_tile: int = dataclasses.field(metadata=dict(static=True))
    transpose: bool = dataclasses.field(metadata=dict(static=True))
    tiling: costmodel.Tiling = dataclasses.field(metadata=dict(static=True))
    # host-only metadata (not traced):
    meta: object = dataclasses.field(metadata=dict(static=True))
    # comm="sparse" support indices: (gather_send, gather_recv,
    # shift_send, shift_recv), each a tuple of (L, c, w) int32 arrays
    # (per fiber offset / per phase); empty for dense plans.
    sup: tuple = ()
    smeta: object = dataclasses.field(default=None,
                                      metadata=dict(static=True))

    @property
    def block_shape(self) -> Tuple[int, int]:
        # (rows of the replicated/gathered matrix, rows of one B block)
        if self.transpose:
            return (self.nB, self.cmA)
        return (self.cmA, self.nB)

    @property
    def cmA(self):
        return self.meta.cmA

    @property
    def nB(self):
        return self.meta.nB


@dataclasses.dataclass(frozen=True, eq=False)
class MetaD15:
    cmA: int
    nB: int
    block_meta: common.BlockMeta


def plan_d15(grid: Grid15, rows, cols, vals, m: int, n: int, r: int, *,
             transpose: bool = False, row_tile: int = 256,
             nz_block: int = 256, group: int = 1, comm: str = "dense",
             compress=None) -> PlanD15:
    """Pack S for the 1.5D dense-shifting schedule (host, amortized).

    transpose=True packs S^T blocks (needed by replication-reuse FusedMM
    and by SpMMB — the paper stores both copies, §IV-B).  ``group`` pads
    window runs so ``blocks_per_step`` up to ``group`` stays feasible.

    comm="sparse" additionally derives, from the same block structure,
    the per-device support index sets that let the executors prune the
    fiber all-gather (rows of the replicated operand any resident block
    reads) and the traveling B chunks (per-phase column support of the
    resident block) — see docs/algorithms.md "Sparse communication".
    """
    L, c, p = grid.L, grid.c, grid.p
    assert m % p == 0 and n % p == 0, (m, n, p)
    mA, nB = m // p, n // p
    cmA = c * mA
    blk_shape = (nB, cmA) if transpose else (cmA, nB)
    row_tile = common.choose_row_tile(blk_shape[0], row_tile)

    part = common.block_partition(np.asarray(rows), np.asarray(cols),
                                  np.asarray(vals), cmA, nB, p)
    empty = (np.zeros(0, np.int32), np.zeros(0, np.int32),
             np.zeros(0, np.float32))
    sh5 = grid.sharding("layer", "fiber")
    rls, cls, vls, tbs, tilings = [], [], [], [], []
    row_off = np.zeros((L, L, c), np.int64)   # (phase, layer, fiber)
    col_off = np.zeros((L, L, c), np.int64)
    n_dense = cmA if transpose else nB        # rows of the gathered/shifted
    sparse_comm = comm == "sparse"
    # comm="sparse" support sets, in pre-swap coordinates: the gathered
    # operand T is always indexed by S's row axis (block-local, [0,cmA))
    # and the traveling B chunk by S's col axis ([0,nB)), regardless of
    # pack orientation — transpose only relabels which pack field holds
    # which axis.
    a_sets = [[set() for _ in range(c)] for _ in range(L)]
    b_sets = [[[np.zeros(0, np.int64)] * c for _ in range(L)]
              for _ in range(L)]
    for t in range(L):                        # dense operand fed to kernels
        blocks = []
        for u in range(L):
            for v in range(c):
                j = ((u - t) % L) * c + v
                br, bc, bv = part.get((u, j), empty)
                if sparse_comm:
                    a_sets[u][v].update(np.unique(br).tolist())
                    b_sets[t][u][v] = np.unique(bc)
                if transpose:
                    br, bc = bc, br
                    row_off[t, u, v], col_off[t, u, v] = j * nB, u * cmA
                else:
                    row_off[t, u, v], col_off[t, u, v] = u * cmA, j * nB
                blocks.append((br, bc, bv))
        rl, cl, vl, tb = common.pack_block_list(blocks, blk_shape, row_tile,
                                                nz_block, group=group)
        tilings.append(common.plan_tiling(tb, n_b=n_dense, r=r,
                                          k=nz_block, row_tile=row_tile))
        shp = (L, c) + rl.shape[1:]
        rls.append(jax.device_put(rl.reshape(shp), sh5))
        cls.append(jax.device_put(cl.reshape(shp), sh5))
        vls.append(jax.device_put(vl.reshape(shp), sh5))
        tbs.append(jax.device_put(tb.reshape((L, c) + tb.shape[1:]), sh5))

    meta = MetaD15(cmA, nB, common.BlockMeta(
        row_off, col_off, (n, m) if transpose else (m, n)))
    sup, smeta = ((), None) if not sparse_comm else _sparse_sup(
        grid, a_sets, b_sets, mA, nB, sh5, compress)
    return PlanD15(tuple(rls), tuple(cls), tuple(vls), tuple(tbs),
                   m, n, r, row_tile, transpose,
                   common.merge_tilings(tilings), meta, sup, smeta)


def _sparse_sup(grid: Grid15, a_sets, b_sets, mA, nB, sh, compress):
    """Pad + align the comm="sparse" support sets into device arrays.

    Gather channel (offset d, device (u, v)): as a *sender* it ships the
    slab-local rows of its own A slab that receiver (u, (v+d)%c)'s
    support touches; as a *receiver* it scatters at the absolute rows of
    its support falling in sender (v-d)%c's slab.  Shift channel (phase
    t >= 1): the home layer of the chunk device (u, v) consumes at phase
    t is (u-t)%L, so sender i ships to (i+t)%L the column support of the
    receiver's phase-t resident block.  Per-channel crossover: if the
    padded support words are not under SPARSE_CROSSOVER x the dense
    words, the channel stays dense (flag off).
    """
    L, c = grid.L, grid.c
    cmA = c * mA
    cross = costmodel.SPARSE_CROSSOVER
    g_send, g_recv, wg, gather = (), (), 0, False
    if c > 1:
        a_sorted = [[np.array(sorted(a_sets[u][v]), np.int64)
                     for v in range(c)] for u in range(L)]
        send_sets = np.empty((c - 1, L, c), object)
        recv_sets = np.empty((c - 1, L, c), object)
        w = 1
        for d in range(1, c):
            for u in range(L):
                for v in range(c):
                    rcv = a_sorted[u][(v + d) % c]
                    send_sets[d - 1, u, v] = (
                        rcv[(rcv >= v * mA) & (rcv < (v + 1) * mA)] - v * mA)
                    own = a_sorted[u][v]
                    sv = (v - d) % c
                    recv_sets[d - 1, u, v] = \
                        own[(own >= sv * mA) & (own < (sv + 1) * mA)]
                    w = max(w, send_sets[d - 1, u, v].size)
        gather = w <= cross * mA
        if gather:
            wg = w
            g_send = tuple(jax.device_put(
                common.pad_sets(send_sets[d], wg, 0), sh)
                for d in range(c - 1))
            g_recv = tuple(jax.device_put(
                common.pad_sets(recv_sets[d], wg, cmA), sh)
                for d in range(c - 1))
    s_send, s_recv, ws, shift = (), (), (), False
    if L > 1:
        widths, sends, recvs = [], [], []
        for t in range(1, L):
            ssend = np.empty((L, c), object)
            srecv = np.empty((L, c), object)
            w = 1
            for i in range(L):
                for v in range(c):
                    ssend[i, v] = b_sets[t][(i + t) % L][v]
                    srecv[i, v] = b_sets[t][i][v]
                    w = max(w, srecv[i, v].size)
            widths.append(w)
            sends.append(ssend)
            recvs.append(srecv)
        shift = sum(widths) <= cross * (L - 1) * nB
        if shift:
            ws = tuple(widths)
            s_send = tuple(jax.device_put(
                common.pad_sets(sends[i], ws[i], 0), sh)
                for i in range(L - 1))
            s_recv = tuple(jax.device_put(
                common.pad_sets(recvs[i], ws[i], nB), sh)
                for i in range(L - 1))
    sup = (g_send, g_recv, s_send, s_recv)
    return sup, common.SparseMeta(gather=gather, shift=shift, wg=wg, ws=ws,
                                  compress=compress)


def _s(s, t):
    """Phase-t local pack (drop the (layer, fiber) unit dims)."""
    return tuple(x[t][0, 0] for x in s)


def _coo(plan: PlanD15, s_t):
    rl, cl, vl, tb = s_t
    return common.coo_of(rl, cl, vl, tb, plan.block_shape, plan.row_tile)


def _shift(x, axis_name, size):
    return jax.lax.ppermute(x, axis_name,
                            [(i, (i + 1) % size) for i in range(size)])


def _exec(grid: Grid15, plan: PlanD15, body, A, B, out_specs,
          a_spec=None):
    """Common shard_map/jit harness; S packs enter with (layer,fiber) dims.

    ``a_spec`` overrides the spec of the first dense operand — the
    pre-gathered (Session-cached) paths pass ``P(layer)``, i.e. rows split
    over the layer axis only and replicated along the fiber.  The plan's
    comm="sparse" support indices ride along as a fourth body argument
    (an empty pytree for dense plans).
    """
    mesh, lay, fib = grid.mesh, grid.layer, grid.fiber
    s_spec = P(lay, fib)
    s_pack = (plan.rows_local, plan.cols, plan.vals, plan.tile_base)
    s_specs = jax.tree_util.tree_map(lambda _: s_spec, s_pack)
    sup_specs = jax.tree_util.tree_map(lambda _: s_spec, plan.sup)
    fn = common.shard_map(
        body, mesh=mesh,
        in_specs=(s_specs, a_spec if a_spec is not None else P((lay, fib)),
                  P((lay, fib)), sup_specs),
        out_specs=out_specs)
    return fn(s_pack, A, B, plan.sup)


def _sq_sup(sup):
    """Drop the (layer, fiber) unit dims of the per-device support sets."""
    return jax.tree_util.tree_map(lambda x: x[0, 0], sup)


def _gather_T(plan: PlanD15, A_loc, sup, fib, c):
    """Fiber replication of the stationary operand, pruned when planned."""
    sm = plan.smeta
    if sm is None or not sm.gather:
        return jax.lax.all_gather(A_loc, fib, tiled=True)
    return common.pruned_gather_rows(A_loc, sup[0], sup[1], fib, c,
                                     compress=sm.compress)


def _shift_sparse(plan: PlanD15) -> bool:
    return plan.smeta is not None and plan.smeta.shift


def _b_chunks(plan: PlanD15, B_loc, sup, lay, L, barrier=False):
    """Per-phase B input chunks via support-pruned direct sends.

    Phase t's chunk ships straight from its home layer ((u-t) mod L for
    receiver u) instead of riding the dense ring: one ppermute of the
    receiver's per-phase column support, scattered into zeros.  Phase 0
    is the local chunk (free).  ``barrier=True`` re-sends from an
    optimization-barrier'd source — the "none" cell's honest second
    round, which XLA would otherwise CSE against round 1 (the payloads
    are syntactically identical; compare s15's re-gather idiom).
    """
    src = jax.lax.optimization_barrier(B_loc) if barrier else B_loc
    chunks = [B_loc]
    for t in range(1, L):
        perm = [(i, (i + t) % L) for i in range(L)]
        chunks.append(common.pruned_permute(
            src, sup[2][t - 1], sup[3][t - 1], perm, lay, plan.nB,
            compress=plan.smeta.compress))
    return chunks


def replicated_spec(grid: Grid15) -> P:
    """Sharding spec of a pre-gathered dense operand (see Session)."""
    return P(grid.layer)


def _phase_shift(n_phases: int, start: int = 0):
    out = []
    for t in range(start, start + n_phases):
        out += [("phase", t), ("shift", t)]
    return out


def schedule_events(grid: Grid15, op: str, elision: str = "none"):
    """Ordered (point, phase) fault boundaries of one executor round.

    Mirrors this family's wire schedule (repro.distributed.faults): an
    optional fiber all-gather, L phase/shift pairs per structure pass
    (two passes for the unfused/reuse FusedMM cells), and a terminal
    reduce-scatter where the output is replicated-out.
    """
    L = grid.L
    if op == "sddmm":
        return [("gather", 0)] + _phase_shift(L)
    if op == "spmm":
        return _phase_shift(L) + [("reduce", L - 1)]
    if op == "spmm_t":                       # spmmb: AG in, B accumulates
        return [("gather", 0)] + _phase_shift(L)
    if op == "fusedmm":
        if elision == "reuse":               # FusedMMB: single AG, 2 passes
            return [("gather", 0)] + _phase_shift(2 * L)
        if elision == "fused":               # one structure pass
            return [("gather", 0)] + _phase_shift(L) + [("reduce", L - 1)]
        return ([("gather", 0)] + _phase_shift(2 * L)
                + [("reduce", 2 * L - 1)])
    raise ValueError(f"unknown op {op!r}")


# Every d15 schedule event legalizes to at most one collective kind —
# no multi-collective expansions (contract read by the static
# conformance verifier; s25 declares the one real entry).
WIRE_EXPANSIONS: dict = {}


def schedule_words(grid: Grid15, plan: PlanD15, op: str,
                   elision: str = "none", pre_gathered: bool = False):
    """Impl-exact per-device wire words for each schedule event.

    Returns ``(point, phase, kind, words)`` tuples aligned 1:1 with
    :func:`schedule_events` — ``kind`` names the HLO collective the
    event compiles to (None for compute phases).  The formulas mirror
    the executors exactly, including XLA's dead-code elimination: a
    cycle-closing shift whose result no consumer reads costs 0 words.
    Dense-wire plans only (the obs layer defines cost-model drift for
    comm="dense"); per-event sums are asserted at 1.00x against the
    compiled HLO by tests/dist_scripts/check_obs.py.
    """
    L, c, p = grid.L, grid.c, grid.p
    ag = 0.0 if pre_gathered else float((c - 1) * (plan.m // p) * plan.r)
    rs = float((c - 1) * (plan.m // p) * plan.r)
    sh = float((plan.n // p) * plan.r)
    if op in ("sddmm", "spmm"):
        dead = {L - 1}              # result of the cycle-closing shift
    elif op == "spmm_t":
        dead = set()                # the traveling buffer IS the output
    elif op == "fusedmm":
        el = resolve_elision(elision, plan.transpose)
        # "none": round-1's last shift feeds round 2; only the very last
        # dies.  "reuse"/"fused": round 1 (or the single round) discards
        # its final B position; reuse's round-2 output travels home live.
        dead = {2 * L - 1} if el == "none" else {L - 1}
    else:
        raise ValueError(f"unknown op {op!r}")
    out = []
    for point, t in schedule_events(grid, op, elision):
        if point == "gather":
            out.append((point, t, "all-gather", ag))
        elif point == "reduce":
            out.append((point, t, "reduce-scatter", rs))
        elif point == "shift":
            out.append((point, t, "collective-permute",
                        0.0 if t in dead else sh))
        else:
            out.append((point, t, None, 0.0))
    return out


def resolve_elision(elision: str, transpose: bool) -> str:
    """Resolve the uniform ``"auto"`` default *for the pack in hand*.

    A plan is already committed to an orientation, so only the elisions
    that orientation supports are candidates: a transpose pack admits
    replication reuse (FusedMMB) alone, and for a normal pack local
    fusion beats the unoptimized sequence at every c (Table III: n*r/c
    vs 2*n*r/c replication words, identical shift words), so "auto"
    never resolves to "none".  The cross-orientation, phi-aware ranking
    — which may *choose* to build the transpose pack — lives one level
    up in ``repro.core.api.DistProblem.resolve_elision``.
    """
    if elision != "auto":
        return elision
    return "reuse" if transpose else "fused"


def _sddmm_phases(plan, T, B0, s, L, lay, overlap, swap=False, chunks=None):
    """L SDDMM phases against a shifting B; returns (vals list, B home).

    Overlapped: the shift of B for phase t+1 is issued before the phase-t
    kernel, so it has no consumer inside the phase and hides behind it.
    ``chunks`` (comm="sparse") supplies the per-phase B chunks from
    support-pruned direct sends instead of the dense ring — the kernels
    read identical values (supported rows) so results are bitwise equal.
    """
    tk = plan.tiling.kernel_kwargs()
    vals_out = []
    if chunks is not None:
        for t in range(L):
            coo = _coo(plan, _s(s, t))
            args = (chunks[t], T) if swap else (T, chunks[t])
            vals_out.append(ops.sddmm(*args, coo, **tk).vals)
        return vals_out, B0
    B_cur = B0
    B_nxt = _shift(B0, lay, L) if overlap else None
    for t in range(L):
        coo = _coo(plan, _s(s, t))
        args = (B_cur, T) if swap else (T, B_cur)
        vals_out.append(ops.sddmm(*args, coo, **tk).vals)
        if overlap:
            B_cur = B_nxt
            if t + 1 < L:
                B_nxt = _shift(B_nxt, lay, L)
        else:
            B_cur = _shift(B_cur, lay, L)
    return vals_out, B_cur


# ---------------------------------------------------------------------------
# Unified Algorithm 1: SDDMM / SpMMA / SpMMB
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("overlap", "pre_gathered"))
def sddmm_d15(grid: Grid15, plan: PlanD15, A, B, overlap: bool = True,
              pre_gathered: bool = False):
    """R = S * (A @ B.T); returns per-phase vals, T x (L, c, nb_t, k).

    pre_gathered=True: A arrives already fiber-replicated (sharding
    ``replicated_spec(grid)``) and the all-gather is skipped — the
    across-call replication reuse of ``repro.core.api.Session``."""
    lay, fib, L = grid.layer, grid.fiber, grid.L

    def body(s, A_loc, B_loc, sup):
        sup = _sq_sup(sup)
        T = A_loc if pre_gathered \
            else _gather_T(plan, A_loc, sup, fib, grid.c)    # (c m/p, r)
        chunks = _b_chunks(plan, B_loc, sup, lay, L) \
            if _shift_sparse(plan) else None
        r_vals, _ = _sddmm_phases(plan, T, B_loc, s, L, lay, overlap,
                                  chunks=chunks)
        return tuple(v[None, None] for v in r_vals)

    return _exec(grid, plan, body, A, B,
                 tuple(P(lay, fib) for _ in range(L)),
                 a_spec=replicated_spec(grid) if pre_gathered else None)


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("overlap",))
def spmma_d15(grid: Grid15, plan: PlanD15, B, overlap: bool = True):
    """A = S @ B with A replicated as output, reduce-scattered at the end."""
    lay, fib, L, c = grid.layer, grid.fiber, grid.L, grid.c
    tk = plan.tiling.kernel_kwargs()

    def body(s, _unused, B_loc, sup):
        sup = _sq_sup(sup)
        T = jnp.zeros((plan.cmA, plan.r), jnp.float32)
        if _shift_sparse(plan):
            chunks = _b_chunks(plan, B_loc, sup, lay, L)
            for t in range(L):
                T = T + ops.spmm(_coo(plan, _s(s, t)), chunks[t],
                                 m=plan.cmA, **tk)
            return jax.lax.psum_scatter(T, fib, scatter_dimension=0,
                                        tiled=True)
        B_cur = B_loc
        B_nxt = _shift(B_loc, lay, L) if overlap else None
        for t in range(L):
            T = T + ops.spmm(_coo(plan, _s(s, t)), B_cur, m=plan.cmA, **tk)
            if overlap:
                B_cur = B_nxt
                if t + 1 < L:
                    B_nxt = _shift(B_nxt, lay, L)
            else:
                B_cur = _shift(B_cur, lay, L)
        return jax.lax.psum_scatter(T, fib, scatter_dimension=0, tiled=True)

    dummy = jnp.zeros((grid.p, 1), jnp.float32)  # placeholder A slot
    return _exec(grid, plan, body, dummy, B, P((lay, fib)))


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("overlap", "pre_gathered"))
def spmmb_d15(grid: Grid15, plan: PlanD15, A, overlap: bool = True,
              pre_gathered: bool = False):
    """B = S.T @ A: A replicated-in; the shifting B buffer accumulates.

    The traveling buffer is an accumulator, so its shift depends on the
    local kernel; overlap instead precomputes the *next* phase's local
    contribution (stationary S^T against the gathered T) while the shift
    is in flight — only the cheap add serializes with communication.

    pre_gathered=True: A arrives already fiber-replicated (sharding
    ``replicated_spec(grid)``) and the all-gather is skipped — this is
    how a training step's backward transpose-SpMM replays the forward's
    replication of A through an ``api.Session`` (repro.core.grads).
    """
    assert plan.transpose, "spmmb_d15 needs a transpose-packed plan"
    lay, fib, L = grid.layer, grid.fiber, grid.L
    tk = plan.tiling.kernel_kwargs()

    def body(s, A_loc, B0, sup):
        # only the gather is prunable here: the traveling B buffer IS
        # the output accumulator — its FP addition order must be exact
        T = A_loc if pre_gathered \
            else _gather_T(plan, A_loc, _sq_sup(sup), fib, grid.c)
        B_cur = B0
        if overlap:
            contrib = ops.spmm(_coo(plan, _s(s, 0)), T, m=plan.nB, **tk)
            for t in range(L):
                B_cur = _shift(B_cur + contrib, lay, L)
                if t + 1 < L:
                    contrib = ops.spmm(_coo(plan, _s(s, t + 1)), T,
                                       m=plan.nB, **tk)
        else:
            for t in range(L):
                B_cur = B_cur + ops.spmm(_coo(plan, _s(s, t)), T,
                                         m=plan.nB, **tk)
                B_cur = _shift(B_cur, lay, L)
        return B_cur   # full cycle: home again

    zeros = jnp.zeros((plan.n, plan.r), jnp.float32)
    zeros = jax.device_put(zeros, grid.sharding((lay, fib)))
    return _exec(grid, plan, body, A, zeros, P((lay, fib)),
                 a_spec=replicated_spec(grid) if pre_gathered else None)


# ---------------------------------------------------------------------------
# FusedMM with the paper's three strategies
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("elision", "overlap", "pre_gathered"))
def fusedmm_d15(grid: Grid15, plan: PlanD15, A, B, elision: str = "auto",
                overlap: bool = True, pre_gathered: bool = False):
    """FusedMM on the 1.5D dense-shifting grid.

    elision="auto"  : resolve via the cost model (see resolve_elision)
    elision="none"  : FusedMMA, SDDMM then SpMMA (2 rounds, AG + RS)
    elision="reuse" : FusedMMB on the S^T pack (2 rounds, single AG)
    elision="fused" : FusedMMA via the fused local kernel (1 round, AG + RS)

    pre_gathered=True: the first dense operand arrives already replicated
    along the fiber (sharding ``replicated_spec(grid)``) and the all-gather
    is skipped — the across-call replication reuse exploited by
    ``repro.core.api.Session``.  Numerically identical to the gathered
    path: the local kernels consume the same T values either way.

    Returns (out_dense, per-phase R_vals tuple).
    """
    elision = resolve_elision(elision, plan.transpose)
    lay, fib, L = grid.layer, grid.fiber, grid.L
    tk = plan.tiling.kernel_kwargs()
    r_specs = tuple(P(lay, fib) for _ in range(L))
    a_spec = replicated_spec(grid) if pre_gathered else None

    def gather(A_loc, sup):
        if pre_gathered:
            return A_loc
        return _gather_T(plan, A_loc, sup, fib, grid.c)

    if elision == "none":
        assert not plan.transpose

        def body(s, A_loc, B_loc, sup):
            sup = _sq_sup(sup)
            T = gather(A_loc, sup)
            if _shift_sparse(plan):
                chunks = _b_chunks(plan, B_loc, sup, lay, L)
                r_vals, _ = _sddmm_phases(plan, T, B_loc, s, L, lay,
                                          overlap, chunks=chunks)
                # honest two-launch baseline: B ships again for round 2
                chunks = _b_chunks(plan, B_loc, sup, lay, L, barrier=True)
                T2 = jnp.zeros((plan.cmA, plan.r), jnp.float32)
                for t in range(L):
                    R_t = _coo(plan, _s(s, t)).with_vals(r_vals[t])
                    T2 = T2 + ops.spmm(R_t, chunks[t], m=plan.cmA, **tk)
                out = jax.lax.psum_scatter(T2, fib, scatter_dimension=0,
                                           tiled=True)
                return out, tuple(v[None, None] for v in r_vals)
            r_vals, B_cur = _sddmm_phases(plan, T, B_loc, s, L, lay, overlap)
            T2 = jnp.zeros((plan.cmA, plan.r), jnp.float32)
            B_nxt = _shift(B_cur, lay, L) if overlap else None
            for t in range(L):
                R_t = _coo(plan, _s(s, t)).with_vals(r_vals[t])
                T2 = T2 + ops.spmm(R_t, B_cur, m=plan.cmA, **tk)
                if overlap:
                    B_cur = B_nxt
                    if t + 1 < L:
                        B_nxt = _shift(B_nxt, lay, L)
                else:
                    B_cur = _shift(B_cur, lay, L)
            out = jax.lax.psum_scatter(T2, fib, scatter_dimension=0,
                                       tiled=True)
            return out, tuple(v[None, None] for v in r_vals)

        return _exec(grid, plan, body, A, B, (P((lay, fib)), r_specs),
                     a_spec=a_spec)

    if elision == "reuse":
        # FusedMMB: replicate A once; it serves the SDDMM *and* the SpMMB.
        assert plan.transpose, "reuse needs a transpose-packed plan"

        def body(s, A_loc, B_loc, sup):
            sup = _sq_sup(sup)
            T = gather(A_loc, sup)                           # single AG
            chunks = _b_chunks(plan, B_loc, sup, lay, L) \
                if _shift_sparse(plan) else None
            # sampled <B_j, A_i> on the S^T layout
            r_vals, _ = _sddmm_phases(plan, T, B_loc, s, L, lay, overlap,
                                      swap=True, chunks=chunks)
            out_cur = jnp.zeros((plan.nB, plan.r), jnp.float32)
            if overlap:
                contrib = ops.spmm(
                    _coo(plan, _s(s, 0)).with_vals(r_vals[0]), T,
                    m=plan.nB, **tk)
                for t in range(L):
                    out_cur = _shift(out_cur + contrib, lay, L)
                    if t + 1 < L:
                        contrib = ops.spmm(
                            _coo(plan, _s(s, t + 1)).with_vals(r_vals[t + 1]),
                            T, m=plan.nB, **tk)
            else:
                for t in range(L):
                    Rt = _coo(plan, _s(s, t)).with_vals(r_vals[t])
                    out_cur = out_cur + ops.spmm(Rt, T, m=plan.nB, **tk)
                    out_cur = _shift(out_cur, lay, L)
            # out home after full cycle
            return out_cur, tuple(v[None, None] for v in r_vals)

        return _exec(grid, plan, body, A, B, (P((lay, fib)), r_specs),
                     a_spec=a_spec)

    if elision == "fused":
        assert not plan.transpose

        def body(s, A_loc, B_loc, sup):
            sup = _sq_sup(sup)
            T = gather(A_loc, sup)
            T2 = jnp.zeros((plan.cmA, plan.r), jnp.float32)
            r_vals = []
            chunks = _b_chunks(plan, B_loc, sup, lay, L) \
                if _shift_sparse(plan) else None
            B_cur = B_loc
            B_nxt = _shift(B_loc, lay, L) \
                if overlap and chunks is None else None
            for t in range(L):
                B_t = chunks[t] if chunks is not None else B_cur
                contrib, R_t = ops.fusedmm(T, B_t, _coo(plan, _s(s, t)),
                                           m=plan.cmA, **tk)
                T2 = T2 + contrib
                r_vals.append(R_t.vals)
                if chunks is not None:
                    pass
                elif overlap:
                    B_cur = B_nxt
                    if t + 1 < L:
                        B_nxt = _shift(B_nxt, lay, L)
                else:
                    B_cur = _shift(B_cur, lay, L)
            out = jax.lax.psum_scatter(T2, fib, scatter_dimension=0,
                                       tiled=True)
            return out, tuple(v[None, None] for v in r_vals)

        return _exec(grid, plan, body, A, B, (P((lay, fib)), r_specs),
                     a_spec=a_spec)

    raise ValueError(f"unknown elision {elision!r}")
