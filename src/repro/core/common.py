"""Shared planner utilities for the distributed sparse algorithms.

Planners run once on the host (numpy) — the analogue of the paper's
amortized preprocessing — and produce static-shape, device-placed pytrees
that the jitted shard_map executors consume repeatedly.

Two planner-level decisions feed the VMEM-tiled kernels (see DESIGN.md):

* packs are padded per *phase* (1.5D dense-shifting) or per *device*
  (traveling packs) rather than to one global ``nbmax``, so a phase with
  few nonzero blocks no longer pays for the densest phase;
* each pack carries a static :class:`repro.core.costmodel.Tiling`
  (``r_tile``/``blocks_per_step``) chosen at plan time from the concrete
  block structure, which the executors thread into every local kernel call.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel
from repro.core.sparse import RowTiledCOO, pack_row_tiled

try:  # jax >= 0.5 exposes shard_map at the top level with check_vma
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(body, *, mesh, in_specs, out_specs):
    """Version-portable jax.shard_map with replication checking off."""
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: False})


def extract_block(rows, cols, vals, r0, r1, c0, c1):
    """Nonzeros of S falling in the [r0,r1) x [c0,c1) block, rebased."""
    msk = (rows >= r0) & (rows < r1) & (cols >= c0) & (cols < c1)
    return rows[msk] - r0, cols[msk] - c0, vals[msk]


def block_partition(rows, cols, vals, row_size, col_size, n_col_blocks):
    """Group nonzeros by (row-block, col-block) in one O(nnz log nnz) pass.

    Returns {(bu, bj): (rows_rebased, cols_rebased, vals)}.  Replaces
    per-block full-array masking, which is O(nnz * blocks) — prohibitive
    for production-scale planning (millions of nnz x thousands of blocks).
    """
    bid = (rows // row_size).astype(np.int64) * n_col_blocks \
        + (cols // col_size)
    order = np.argsort(bid, kind="stable")
    rows, cols, vals, bid = (rows[order], cols[order], vals[order],
                             bid[order])
    uniq, starts = np.unique(bid, return_index=True)
    ends = np.append(starts[1:], len(bid))
    out = {}
    for u, s, e in zip(uniq, starts, ends):
        bu, bj = int(u) // n_col_blocks, int(u) % n_col_blocks
        out[(bu, bj)] = (rows[s:e] - bu * row_size,
                         cols[s:e] - bj * col_size, vals[s:e])
    return out


def pack_block_list(blocks, shape, row_tile, nz_block, group: int = 1):
    """Pack a list of COO blocks to RowTiled arrays with a common nblocks.

    blocks: list of (rows, cols, vals) numpy triples, all logical `shape`.
    The common block count is the max over *this list only* — callers that
    used to stack every phase into one array now call this once per phase,
    so each phase is padded to its own densest device, not the global max.
    Returns stacked numpy arrays (N, nb, k), (N, nb, k), (N, nb, k), (N, nb).
    """
    packs = [pack_row_tiled(r, c, v, shape, row_tile=row_tile,
                            nz_block=nz_block, group=group)
             for (r, c, v) in blocks]
    nbmax = max(p.nblocks for p in packs)
    nbmax = ((nbmax + group - 1) // group) * group
    rl = np.zeros((len(packs), nbmax, nz_block), np.int32)
    cl = np.zeros((len(packs), nbmax, nz_block), np.int32)
    vl = np.zeros((len(packs), nbmax, nz_block), np.float32)
    tb = np.zeros((len(packs), nbmax), np.int32)
    for i, p in enumerate(packs):
        nb = p.nblocks
        rl[i, :nb] = np.asarray(p.rows_local)
        cl[i, :nb] = np.asarray(p.cols)
        vl[i, :nb] = np.asarray(p.vals)
        tb[i, :nb] = np.asarray(p.tile_base)
        tb[i, nb:] = tb[i, nb - 1] if nb else 0   # keep bases monotone
    return rl, cl, vl, tb


def plan_tiling(tile_base: np.ndarray, *, n_b: int, r: int, k: int,
                row_tile: int) -> costmodel.Tiling:
    """Choose the kernel tiling for a stacked pack at plan time (host)."""
    nb = tile_base.shape[-1]
    return costmodel.choose_tiling(n_b=n_b, r=r, nb=nb, k=k,
                                   row_tile=row_tile, tile_base=tile_base)


def merge_tilings(tilings) -> costmodel.Tiling:
    """Conservative merge across phases: knobs every phase supports."""
    tilings = list(tilings)
    r_tile = tilings[0].r_tile
    bps = tilings[0].blocks_per_step
    for t in tilings[1:]:
        r_tile = math.gcd(r_tile, t.r_tile)
        bps = math.gcd(bps, t.blocks_per_step)
    return costmodel.Tiling(r_tile=r_tile, blocks_per_step=bps)


def coo_of(rows_local, cols, vals, tile_base, shape, row_tile) -> RowTiledCOO:
    """Assemble a RowTiledCOO inside traced code from raw arrays."""
    return RowTiledCOO(rows_local, cols, vals, tile_base, shape, row_tile)


def choose_row_tile(height: int, want: int = 256) -> int:
    """Largest divisor of `height` that is <= want (prefers multiples of 8)."""
    t = min(want, height)
    while height % t:
        t -= 1
    return t


# ---------------------------------------------------------------------------
# Support-pruned communication (comm="sparse")
# ---------------------------------------------------------------------------
#
# A dense *input* operand movement (fiber all-gather, traveling A/B chunk)
# only needs to deliver the rows the receiver's nonzeros actually read —
# the pack's row/col support.  The planners precompute, per channel, the
# per-(device, offset/phase) send and receive index sets, padded to a
# static width; the executors replace the dense collective with one
# ``ppermute`` of the packed rows per offset, scattered into a zero
# buffer at the receiver.  Rows outside the support stay zero but are
# never read by the local kernels, so results are bitwise-identical to
# the dense schedule.  Traveling *accumulators* (SpMMB/FusedMMB outputs,
# partial-dot buffers) and reduce-scatters are never pruned: they carry
# partial sums whose exact FP addition order must be preserved.

@dataclasses.dataclass(frozen=True)
class SparseMeta:
    """Static per-plan record of which channels ship pruned (and how wide).

    ``gather``/``gather_b`` — the fiber all-gather(s) of a dense operand;
    ``shift``/``shift_b`` — the traveling dense input chunks.  A flag is
    False when the channel does not exist on this grid (c == 1, L == 1)
    or when the crossover heuristic found the support too dense to win
    (``costmodel.SPARSE_CROSSOVER``); the executor then keeps the dense
    schedule for that channel.  ``wg``/``wg_b`` are the padded per-offset
    gather widths, ``ws``/``ws_b`` the per-phase padded shift widths —
    the exact payload heights shipped, which the nnz-dependent cost
    model is asserted against at 1.00x.
    """
    gather: bool = False
    gather_b: bool = False
    shift: bool = False
    shift_b: bool = False
    wg: int = 0
    wg_b: int = 0
    ws: Tuple[int, ...] = ()
    ws_b: Tuple[int, ...] = ()
    compress: object = None     # None | "bf16" — wire format of pruned sends


def pad_sets(sets: np.ndarray, width: int, fill: int) -> np.ndarray:
    """Stack an object-array of sorted index sets into (..., width) int32.

    Senders pad with 0 (a junk row that the receiver drops); receivers
    pad with an out-of-bounds index (scatter ``mode="drop"``).
    """
    sets = np.asarray(sets, dtype=object)
    out = np.full(sets.shape + (width,), fill, np.int32)
    for idx in np.ndindex(sets.shape):
        s = np.asarray(sets[idx], np.int32)
        out[idx][:s.shape[0]] = s
    return out


def _wire(x, compress):
    if compress == "bf16":
        from repro.training import compression
        return compression.to_bf16(x)
    return x


def _unwire(x, dtype, compress):
    # NB: on the CPU test backend XLA's float-normalization legalizes
    # bf16 collectives to f32 (converts fused at the sender), so host
    # meshes see the bf16 *rounding* but not the byte saving; backends
    # with native bf16 collectives ship the half-width payload.
    if compress == "bf16":
        from repro.training import compression
        return compression.from_bf16(x, dtype)
    return x


def pruned_permute(x, send_idx, recv_idx, perm, axis_name, out_rows, *,
                   out=None, compress=None):
    """One support-pruned send: ship ``x[send_idx]``, scatter at ``recv_idx``.

    ``send_idx``/``recv_idx`` are equal-width per-device index vectors
    (aligned element-wise by the planner); receiver padding points at
    ``out_rows`` (out of bounds) and is dropped.  Returns a dense
    ``(out_rows, x.shape[1])`` buffer — zeros (or ``out``) outside the
    support.
    """
    payload = _wire(x[send_idx, :], compress)
    arrived = _unwire(jax.lax.ppermute(payload, axis_name, perm),
                      x.dtype, compress)
    if out is None:
        out = jnp.zeros((out_rows, x.shape[1]), x.dtype)
    return out.at[recv_idx, :].set(arrived, mode="drop")


def pruned_gather_rows(x, send_tuple, recv_tuple, axis_name, size, *,
                       compress=None):
    """Support-pruned row-tiled fiber all-gather: (slot, r) -> (slot*size, r).

    The own slab lands whole (free); every other slab arrives as one
    pruned ppermute per offset d, placed at absolute row indices.
    """
    slot = x.shape[0]
    idx = jax.lax.axis_index(axis_name)
    out = jnp.zeros((slot * size, x.shape[1]), x.dtype)
    out = jax.lax.dynamic_update_slice(out, x, (idx * slot, 0))
    for d in range(1, size):
        perm = [(i, (i + d) % size) for i in range(size)]
        out = pruned_permute(x, send_tuple[d - 1], recv_tuple[d - 1], perm,
                             axis_name, slot * size, out=out,
                             compress=compress)
    return out


def pruned_gather_cols(x, send_tuple, recv_idx, axis_name, size, *,
                       compress=None):
    """Support-pruned column-slab fiber all-gather: (m, w) -> (m, w*size).

    Slabs are full-height, so the receiver's row support ``recv_idx`` is
    one set per device (the union over its resident blocks), independent
    of the source — senders ship ``x[recv's rows]`` per offset.
    """
    m, w = x.shape
    idx = jax.lax.axis_index(axis_name)
    out = jnp.zeros((m, w * size), x.dtype)
    out = jax.lax.dynamic_update_slice(out, x, (0, idx * w))
    for d in range(1, size):
        perm = [(i, (i + d) % size) for i in range(size)]
        payload = _wire(x[send_tuple[d - 1], :], compress)
        arrived = _unwire(jax.lax.ppermute(payload, axis_name, perm),
                          x.dtype, compress)
        slab = jnp.zeros((m, w), x.dtype).at[recv_idx, :].set(
            arrived, mode="drop")
        out = jax.lax.dynamic_update_slice(out, slab,
                                           (0, ((idx - d) % size) * w))
    return out


@dataclasses.dataclass(frozen=True, eq=False)   # identity semantics:
# numpy arrays inside static pytree metadata must not be __eq__-compared
class BlockMeta:
    """Host-side metadata to reassemble stacked sparse outputs densely.

    ``row_offsets``/``col_offsets`` carry one entry per stacked block; for
    per-phase packs (1.5D dense shifting) the *leading* axis is the phase
    and the block arrays arrive as a tuple with one stacked array per
    phase (ragged block counts across phases are fine).
    """
    row_offsets: np.ndarray  # (...,) global row offset per block
    col_offsets: np.ndarray  # (...,) global col offset per block
    shape: Tuple[int, int]

    def to_triples(self, rows_local, cols, vals, tile_base,
                   row_tile=None):
        """Flat global COO (rows, cols, vals) of the stacked blocks.

        Padding entries (vals == 0) are filtered out.  This is the
        layout-independent view the api layer assembles results through;
        unlike a dense scatter it is O(nnz), so it scales to the sparse
        sizes the library targets.
        """
        parts = []
        if isinstance(rows_local, (tuple, list)):   # per-phase ragged packs
            for t in range(len(rows_local)):
                parts.append(self._triples_of(
                    rows_local[t], cols[t], vals[t], tile_base[t],
                    self.row_offsets[t], self.col_offsets[t]))
        else:
            parts.append(self._triples_of(rows_local, cols, vals,
                                          tile_base, self.row_offsets,
                                          self.col_offsets))
        return (np.concatenate([p[0] for p in parts]),
                np.concatenate([p[1] for p in parts]),
                np.concatenate([p[2] for p in parts]))

    def to_dense(self, rows_local, cols, vals, tile_base, row_tile=None):
        """Scatter stacked (..., nb, k) block arrays into a dense matrix."""
        r, c, v = self.to_triples(rows_local, cols, vals, tile_base)
        out = np.zeros(self.shape, np.float64)
        np.add.at(out, (r, c), v)
        return out.astype(np.float32)

    @staticmethod
    def _triples_of(rows_local, cols, vals, tile_base, row_off, col_off):
        rl = np.asarray(rows_local)
        cl = np.asarray(cols)
        vl = np.asarray(vals)
        tb = np.asarray(tile_base)
        flat_ro = np.asarray(row_off).reshape(-1).astype(np.int64)
        flat_co = np.asarray(col_off).reshape(-1).astype(np.int64)
        rl = rl.reshape(-1, *rl.shape[-2:])
        cl = cl.reshape(-1, *cl.shape[-2:])
        vl = vl.reshape(-1, *vl.shape[-2:])
        tb = tb.reshape(-1, tb.shape[-1])
        r = (rl.astype(np.int64) + tb[:, :, None]
             + flat_ro[:, None, None]).reshape(-1)
        c = (cl.astype(np.int64) + flat_co[:, None, None]).reshape(-1)
        v = vl.reshape(-1)
        keep = v != 0
        return r[keep], c[keep], v[keep]
