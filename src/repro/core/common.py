"""Shared planner utilities for the distributed sparse algorithms.

Planners run once on the host (numpy) — the analogue of the paper's
amortized preprocessing — and produce static-shape, device-placed pytrees
that the jitted shard_map executors consume repeatedly.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse import RowTiledCOO, pack_row_tiled


def extract_block(rows, cols, vals, r0, r1, c0, c1):
    """Nonzeros of S falling in the [r0,r1) x [c0,c1) block, rebased."""
    msk = (rows >= r0) & (rows < r1) & (cols >= c0) & (cols < c1)
    return rows[msk] - r0, cols[msk] - c0, vals[msk]


def block_partition(rows, cols, vals, row_size, col_size, n_col_blocks):
    """Group nonzeros by (row-block, col-block) in one O(nnz log nnz) pass.

    Returns {(bu, bj): (rows_rebased, cols_rebased, vals)}.  Replaces
    per-block full-array masking, which is O(nnz * blocks) — prohibitive
    for production-scale planning (millions of nnz x thousands of blocks).
    """
    bid = (rows // row_size).astype(np.int64) * n_col_blocks \
        + (cols // col_size)
    order = np.argsort(bid, kind="stable")
    rows, cols, vals, bid = (rows[order], cols[order], vals[order],
                             bid[order])
    uniq, starts = np.unique(bid, return_index=True)
    ends = np.append(starts[1:], len(bid))
    out = {}
    for u, s, e in zip(uniq, starts, ends):
        bu, bj = int(u) // n_col_blocks, int(u) % n_col_blocks
        out[(bu, bj)] = (rows[s:e] - bu * row_size,
                         cols[s:e] - bj * col_size, vals[s:e])
    return out


def pack_block_list(blocks, shape, row_tile, nz_block):
    """Pack a list of COO blocks to RowTiled arrays with a common nblocks.

    blocks: list of (rows, cols, vals) numpy triples, all logical `shape`.
    Returns stacked numpy arrays (N, nb, k), (N, nb, k), (N, nb, k), (N, nb).
    """
    packs = [pack_row_tiled(r, c, v, shape, row_tile=row_tile,
                            nz_block=nz_block) for (r, c, v) in blocks]
    nbmax = max(p.nblocks for p in packs)
    rl = np.zeros((len(packs), nbmax, nz_block), np.int32)
    cl = np.zeros((len(packs), nbmax, nz_block), np.int32)
    vl = np.zeros((len(packs), nbmax, nz_block), np.float32)
    tb = np.zeros((len(packs), nbmax), np.int32)
    for i, p in enumerate(packs):
        nb = p.nblocks
        rl[i, :nb] = np.asarray(p.rows_local)
        cl[i, :nb] = np.asarray(p.cols)
        vl[i, :nb] = np.asarray(p.vals)
        tb[i, :nb] = np.asarray(p.tile_base)
        tb[i, nb:] = tb[i, nb - 1] if nb else 0   # keep bases monotone
    return rl, cl, vl, tb


def coo_of(rows_local, cols, vals, tile_base, shape, row_tile) -> RowTiledCOO:
    """Assemble a RowTiledCOO inside traced code from raw arrays."""
    return RowTiledCOO(rows_local, cols, vals, tile_base, shape, row_tile)


def choose_row_tile(height: int, want: int = 256) -> int:
    """Largest divisor of `height` that is <= want (prefers multiples of 8)."""
    t = min(want, height)
    while height % t:
        t -= 1
    return t


@dataclasses.dataclass(frozen=True, eq=False)   # identity semantics:
# numpy arrays inside static pytree metadata must not be __eq__-compared
class BlockMeta:
    """Host-side metadata to reassemble stacked sparse outputs densely."""
    row_offsets: np.ndarray  # (...,) global row offset per block
    col_offsets: np.ndarray  # (...,) global col offset per block
    shape: Tuple[int, int]

    def to_dense(self, rows_local, cols, vals, tile_base, row_tile=None):
        """Scatter stacked (..., nb, k) block arrays into a dense matrix."""
        rows_local = np.asarray(rows_local)
        cols = np.asarray(cols)
        vals = np.asarray(vals)
        tile_base = np.asarray(tile_base)
        out = np.zeros(self.shape, np.float64)
        flat_ro = self.row_offsets.reshape(-1)
        flat_co = self.col_offsets.reshape(-1)
        nblk = rows_local.shape[:-2]
        rl = rows_local.reshape(-1, *rows_local.shape[-2:])
        cl = cols.reshape(-1, *cols.shape[-2:])
        vl = vals.reshape(-1, *vals.shape[-2:])
        tb = tile_base.reshape(-1, tile_base.shape[-1])
        for b in range(rl.shape[0]):
            r = (rl[b] + tb[b][:, None]).reshape(-1) + flat_ro[b]
            c = cl[b].reshape(-1) + flat_co[b]
            v = vl[b].reshape(-1)
            np.add.at(out, (r[v != 0], c[v != 0]), v[v != 0])
        return out.astype(np.float32)
