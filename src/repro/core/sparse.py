"""Static-shape sparse formats for TPU-friendly SDDMM / SpMM / FusedMM.

XLA requires static shapes, so every distributed block of the sparse matrix
``S`` is packed to a fixed nonzero capacity.  Padding entries carry
``val = 0`` and point at row/col 0, so:

  * SpMM contributions from padding vanish (0 * B[0] adds nothing),
  * SDDMM outputs at padding are 0 (sample value multiplies the dot).

Two layouts:

``PaddedCOO``      -- flat (rows, cols, vals) triple, 3 words per nonzero,
                      exactly the paper's COO cyclic-shift payload.
``RowTiledCOO``    -- PaddedCOO additionally sorted by row and chunked into
                      nonzero blocks of ``nz_block`` entries whose rows all
                      fall inside one ``row_tile``-row window.  This is the
                      TPU adaptation: it lets the local SpMM kernel turn
                      scatter-add into a one-hot matmul on the MXU.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedCOO:
    """A fixed-capacity COO block of an (m x n) sparse matrix."""

    rows: jax.Array  # int32[cap]
    cols: jax.Array  # int32[cap]
    vals: jax.Array  # float[cap]  (0.0 at padding)
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def capacity(self) -> int:
        return self.rows.shape[0]

    def to_dense(self) -> jax.Array:
        out = jnp.zeros(self.shape, self.vals.dtype)
        return out.at[self.rows, self.cols].add(self.vals)

    def with_vals(self, vals: jax.Array) -> "PaddedCOO":
        return PaddedCOO(self.rows, self.cols, vals, self.shape)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RowTiledCOO:
    """Row-sorted, tile-aligned COO for the one-hot-matmul local kernels.

    Nonzeros are sorted by row and split into blocks of ``nz_block``
    entries.  Block ``b`` only touches rows in
    ``[tile_base[b], tile_base[b] + row_tile)``; ``rows_local`` stores the
    offset within that window.  Padding entries have ``vals == 0`` and
    ``rows_local == 0``.
    """

    rows_local: jax.Array  # int32[nblocks, nz_block] in [0, row_tile)
    cols: jax.Array        # int32[nblocks, nz_block]
    vals: jax.Array        # float[nblocks, nz_block]
    tile_base: jax.Array   # int32[nblocks] multiples of row_tile
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    row_tile: int = dataclasses.field(metadata=dict(static=True))

    @property
    def nblocks(self) -> int:
        return self.rows_local.shape[0]

    @property
    def nz_block(self) -> int:
        return self.rows_local.shape[1]

    def rows_global(self) -> jax.Array:
        return self.rows_local + self.tile_base[:, None]

    def to_dense(self) -> jax.Array:
        out = jnp.zeros(self.shape, self.vals.dtype)
        return out.at[self.rows_global().reshape(-1),
                      self.cols.reshape(-1)].add(self.vals.reshape(-1))

    def with_vals(self, vals: jax.Array) -> "RowTiledCOO":
        return RowTiledCOO(self.rows_local, self.cols, vals,
                           self.tile_base, self.shape, self.row_tile)

    def to_padded_coo(self) -> PaddedCOO:
        return PaddedCOO(self.rows_global().reshape(-1),
                         self.cols.reshape(-1),
                         self.vals.reshape(-1), self.shape)


# ---------------------------------------------------------------------------
# Packing (numpy, amortized preprocessing -- mirrors the paper's reorder step)
# ---------------------------------------------------------------------------

def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def pack_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
             shape: Tuple[int, int], capacity: int | None = None,
             pad_multiple: int = 8) -> PaddedCOO:
    """Pack raw COO triplets into a PaddedCOO with static capacity."""
    nnz = int(rows.shape[0])
    cap = capacity if capacity is not None else _round_up(max(nnz, 1), pad_multiple)
    if nnz > cap:
        raise ValueError(f"nnz={nnz} exceeds capacity={cap}")
    r = np.zeros(cap, np.int32)
    c = np.zeros(cap, np.int32)
    v = np.zeros(cap, np.float32)
    r[:nnz], c[:nnz], v[:nnz] = rows, cols, vals
    return PaddedCOO(jnp.asarray(r), jnp.asarray(c), jnp.asarray(v), shape)


def pack_row_tiled(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                   shape: Tuple[int, int], *, row_tile: int = 256,
                   nz_block: int = 256, nblocks: int | None = None,
                   group: int = 1) -> RowTiledCOO:
    """Sort by row, then emit nz blocks confined to row_tile windows.

    A block is flushed (padded) whenever it fills up or the next nonzero
    falls outside the current row window.  Window boundaries are aligned to
    multiples of ``row_tile`` so ``tile_base`` can double as a BlockSpec
    index.

    ``group > 1`` pads every window's run of blocks (and the total block
    count) to a multiple of ``group``, so the kernels may merge any
    ``blocks_per_step`` dividing ``group`` — each aligned group then shares
    one ``tile_base`` window (the precondition checked by
    ``costmodel.groupable_blocks_per_step``).
    """
    # clamp to the largest divisor of the row count (kernel window blocking
    # requires row_tile | m)
    row_tile = min(row_tile, shape[0])
    while shape[0] % row_tile:
        row_tile -= 1
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    nnz = rows.shape[0]

    def zero_block():
        return (np.zeros(nz_block, np.int32), np.zeros(nz_block, np.int32),
                np.zeros(nz_block, np.float32))

    blk_rows, blk_cols, blk_vals, bases = [], [], [], []
    i = 0
    while i < nnz:
        base = (int(rows[i]) // row_tile) * row_tile
        # all nonzeros in [base, base+row_tile) starting at i
        hi = int(np.searchsorted(rows, base + row_tile, side="left"))
        run = 0
        while i < hi:
            j = min(i + nz_block, hi)
            n = j - i
            lr, lc, lv = zero_block()
            lr[:n] = rows[i:j] - base
            lc[:n] = cols[i:j]
            lv[:n] = vals[i:j]
            blk_rows.append(lr); blk_cols.append(lc); blk_vals.append(lv)
            bases.append(base)
            run += 1
            i = j
        while run % group:           # pad the window run to a group boundary
            lr, lc, lv = zero_block()
            blk_rows.append(lr); blk_cols.append(lc); blk_vals.append(lv)
            bases.append(base)
            run += 1

    nb = len(bases)
    target = nblocks if nblocks is not None else max(nb, 1)
    target = _round_up(target, group)
    if nb > target:
        raise ValueError(f"needs {nb} blocks > target {target}")
    # Padding blocks inherit the last real base so the sequence of output
    # tiles stays non-decreasing (Pallas requires consecutive revisits).
    pad_base = bases[-1] if bases else 0
    for _ in range(target - nb):
        blk_rows.append(np.zeros(nz_block, np.int32))
        blk_cols.append(np.zeros(nz_block, np.int32))
        blk_vals.append(np.zeros(nz_block, np.float32))
        bases.append(pad_base)

    return RowTiledCOO(
        jnp.asarray(np.stack(blk_rows)), jnp.asarray(np.stack(blk_cols)),
        jnp.asarray(np.stack(blk_vals)), jnp.asarray(np.array(bases, np.int32)),
        shape, row_tile)


# ---------------------------------------------------------------------------
# Random sparse matrix generators (paper's workloads)
# ---------------------------------------------------------------------------

def erdos_renyi(m: int, n: int, nnz_per_row: int, seed: int = 0,
                dtype=np.float32):
    """Erdos-Renyi random sparse matrix, ~nnz_per_row nonzeros per row.

    Matches the paper's weak-scaling generator (CombBLAS ER): each row draws
    ``nnz_per_row`` columns uniformly (with possible duplicates removed).
    Returns (rows, cols, vals) numpy COO, deduplicated & sorted.
    """
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(m, dtype=np.int64), nnz_per_row)
    cols = rng.integers(0, n, size=rows.shape[0], dtype=np.int64)
    key = rows * n + cols
    key = np.unique(key)
    rows = (key // n).astype(np.int32)
    cols = (key % n).astype(np.int32)
    vals = rng.standard_normal(rows.shape[0]).astype(dtype)
    return rows, cols, vals


def random_problem(m: int, n: int, r: int, nnz_per_row: int, *,
                   seed: int = 0, scale: float = 1.0):
    """One seeded (rows, cols, vals, X, Y) problem bundle.

    The Erdos-Renyi sparse matrix plus matching dense operands
    ``X (m, r)`` / ``Y (n, r)`` in float32 — the setup every benchmark,
    test and dist_script needs.  Deterministic in ``seed`` alone (the
    dense operands draw from ``seed + 1``, preserving the historical
    streams of ``benchmarks/common.er_problem``), so two call sites with
    the same arguments see the same problem.  ``scale`` shrinks the
    dense entries for iterative-solver initializations.
    """
    rows, cols, vals = erdos_renyi(m, n, nnz_per_row, seed=seed)
    rng = np.random.default_rng(seed + 1)
    X = (rng.standard_normal((m, r)) * scale).astype(np.float32)
    Y = (rng.standard_normal((n, r)) * scale).astype(np.float32)
    return rows, cols, vals, X, Y


def rmat(scale: int, edge_factor: int = 16, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         dtype=np.float32):
    """RMAT power-law generator — surrogate for SuiteSparse web/social graphs."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    ne = n * edge_factor
    rows = np.zeros(ne, np.int64)
    cols = np.zeros(ne, np.int64)
    for lvl in range(scale):
        r = rng.random(ne)
        # quadrant probabilities a, b, c, d
        right = r >= a + b  # col high bit
        down = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        rows |= down.astype(np.int64) << lvl
        cols |= right.astype(np.int64) << lvl
    key = np.unique(rows * n + cols)
    rows = (key // n).astype(np.int32)
    cols = (key % n).astype(np.int32)
    vals = rng.standard_normal(rows.shape[0]).astype(dtype)
    return rows, cols, vals


def powerlaw_problem(scale: int, r: int, *, edge_factor: int = 16,
                     seed: int = 0, a: float = 0.57, b: float = 0.19,
                     c: float = 0.19):
    """One seeded power-law (rows, cols, vals, X, Y) problem bundle.

    The RMAT surrogate for the paper's headline web/social matrices
    (m = n = 2**scale), unpermuted so the degree skew — many empty or
    near-empty rows and columns, a few dense hubs — survives into the
    per-device packs.  This is the regime where ``comm="sparse"``
    support pruning beats the dense Table-III optimum outright: the
    row/col supports cover only a fraction of each fiber slab.  Same
    bundle contract as :func:`random_problem` (dense operands draw from
    ``seed + 1``), so benchmarks and dist_scripts can swap generators.
    """
    rows, cols, vals = rmat(scale, edge_factor, seed=seed, a=a, b=b, c=c)
    m = n = 1 << scale
    rng = np.random.default_rng(seed + 1)
    X = rng.standard_normal((m, r)).astype(np.float32)
    Y = rng.standard_normal((n, r)).astype(np.float32)
    return rows, cols, vals, X, Y


def random_permute(rows: np.ndarray, cols: np.ndarray, m: int, n: int,
                   seed: int = 0):
    """Random row+col permutation for load balance (paper §VI)."""
    rng = np.random.default_rng(seed)
    pr = rng.permutation(m).astype(np.int32)
    pc = rng.permutation(n).astype(np.int32)
    return pr[rows], pc[cols]


def block_sparse_mask(seq: int, block: int, window_blocks: int,
                      global_blocks: int = 1):
    """Block-sparse attention mask (sliding window + global) as COO blocks.

    Returns (rows, cols) of *block* indices for a lower-triangular
    sliding-window + global-token pattern over seq/block block rows.
    Used by the block-sparse FusedMM attention path.
    """
    nb = seq // block
    rows, cols = [], []
    for i in range(nb):
        lo = max(0, i - window_blocks + 1)
        for j in range(lo, i + 1):
            rows.append(i); cols.append(j)
        for j in range(min(global_blocks, lo)):
            rows.append(i); cols.append(j)
    return np.asarray(rows, np.int32), np.asarray(cols, np.int32)
