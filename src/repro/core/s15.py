"""1.5D sparse-shifting, dense-replicating algorithms (paper §V-B).

Grid: ("layer" = p/c, "fiber" = c).  The DENSE matrices are stationary,
column-split across layer positions and replicated (all-gathered) along the
fiber; the SPARSE matrix propagates: row-blocks of S cyclically shift
within each layer, carrying partially-accumulated sample values (3 words
per nonzero — rows, cols, value — exactly the paper's COO payload).

Layout: device (u, v) at rest holds
  A[:, W_u,v], B[:, W_u,v]   column slices of width r/p
  S row-block b = u*c + v    (height m/p), row-tiled pack

After the fiber all-gather each device holds the full-height slices
A[:, W_u], B[:, W_u] of width r*c/p.  A nonzero's dot product accumulates
as its block visits every layer position u (covering all r columns); the
block returns home after a full cycle, where the partial dots are scaled
by the original sample values.  The SpMM round shifts the (now final)
values again, emitting per-phase output slabs out[rows(b_t), W_u].

Because phi = nnz/(nr) is low exactly when this layout wins (paper Fig. 6),
the shifted payload (3*nnz/p words/phase) is tiny compared to the dense
blocks the d15 algorithm would shift.

Comm/compute overlap (see DESIGN.md): the propagation loops are
Python-unrolled with double-buffered carries — the coordinate shift for
the next phase is issued before the local kernel consumes the current
pack, so the (already tiny) payload transfer hides entirely behind the
SDDMM/SpMM compute.  The partial-dot buffer lags one kernel behind, as it
must include the current phase's dots before traveling.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import common, costmodel
from repro.core.grid import Grid15
from repro.kernels import ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlanS15:
    rows_local: jax.Array   # (L, c, nb, k) int32 — one home block per device
    cols: jax.Array
    vals: jax.Array         # original sample values (stay home)
    tile_base: jax.Array    # (L, c, nb)
    m: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    r: int = dataclasses.field(metadata=dict(static=True))
    row_tile: int = dataclasses.field(metadata=dict(static=True))
    tiling: costmodel.Tiling = dataclasses.field(metadata=dict(static=True))
    meta: object = dataclasses.field(metadata=dict(static=True))
    sup: tuple = ()             # comm="sparse" support index arrays
    smeta: object = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def mS(self):
        return self.meta.mS

    @property
    def rc(self):
        return self.meta.rc  # r*c/p: gathered dense slice width


@dataclasses.dataclass(frozen=True, eq=False)
class MetaS15:
    mS: int
    rc: int
    block_meta: common.BlockMeta


def plan_s15(grid: Grid15, rows, cols, vals, m: int, n: int, r: int, *,
             row_tile: int = 256, nz_block: int = 256, group: int = 1,
             comm: str = "dense", compress=None) -> PlanS15:
    """Pack one home row-block per device (host, amortized).

    comm="sparse": the dense column slabs are full-height, and device
    (u, v) only ever reads the rows/cols its resident blocks touch —
    blocks b = v (mod c), the same set for every layer position u.  The
    planner records those two unions (A rows, B cols) so the fiber
    all-gathers ship only supported rows.  The COO propagation is the
    sparse payload itself and always stays as-is.
    """
    L, c, p = grid.L, grid.c, grid.p
    assert m % p == 0 and r % p == 0, (m, r, p)
    mS = m // p
    row_tile = common.choose_row_tile(mS, row_tile)
    sparse_comm = comm == "sparse"
    a_sets = [set() for _ in range(c)]   # absolute A rows read at fiber v
    b_sets = [set() for _ in range(c)]   # B cols read at fiber v
    blocks, row_off = [], []
    for u in range(L):
        for v in range(c):
            b = u * c + v
            br, bc, bv = common.extract_block(rows, cols, vals,
                                              b * mS, (b + 1) * mS, 0, n)
            if sparse_comm:
                a_sets[v].update((np.unique(br) + b * mS).tolist())
                b_sets[v].update(np.unique(bc).tolist())
            blocks.append((br, bc, bv))
            row_off.append(b * mS)
    rl, cl, vl, tb = common.pack_block_list(blocks, (mS, n), row_tile,
                                            nz_block, group=group)
    tiling = common.plan_tiling(tb, n_b=n, r=r * c // p, k=nz_block,
                                row_tile=row_tile)
    sh = grid.sharding("layer", "fiber")
    shp = (L, c) + rl.shape[1:]
    meta = MetaS15(mS, r * c // p, common.BlockMeta(
        np.array(row_off).reshape(L, c), np.zeros((L, c), np.int64), (m, n)))
    sup, smeta = ((), None) if not sparse_comm else _sparse_sup(
        grid, a_sets, b_sets, m, n, sh, compress)
    return PlanS15(
        jax.device_put(rl.reshape(shp), sh),
        jax.device_put(cl.reshape(shp), sh),
        jax.device_put(vl.reshape(shp), sh),
        jax.device_put(tb.reshape((L, c) + tb.shape[1:]), sh),
        m, n, r, row_tile, tiling, meta, sup, smeta)


def _sparse_sup(grid: Grid15, a_sets, b_sets, m, n, sh, compress):
    """Pad + align the comm="sparse" support sets into device arrays.

    Slabs are full-height, so the support is receiver-determined: per
    offset d the sender at fiber v ships rows R[(v+d) % c] of its own
    column slab and scatters arrivals at its constant R[v].  One channel
    per dense operand (A rows / B cols); per-channel crossover against
    the dense slab height.
    """
    L, c = grid.L, grid.c
    cross = costmodel.SPARSE_CROSSOVER

    def grid_sets(pick):
        out = np.empty((L, c), object)
        for u in range(L):
            for v in range(c):
                out[u, v] = pick(v)
        return out

    def channel(sets, height):
        sorted_ = [np.array(sorted(sets[v]), np.int64) for v in range(c)]
        w = max(1, max(s.size for s in sorted_))
        if c == 1 or w > cross * height:
            return (), (), 0, False
        send = tuple(
            jax.device_put(common.pad_sets(
                grid_sets(lambda v: sorted_[(v + d) % c]), w, 0), sh)
            for d in range(1, c))
        recv = jax.device_put(common.pad_sets(
            grid_sets(lambda v: sorted_[v]), w, height), sh)
        return send, (recv,), w, True

    a_send, a_recv, wa, ga = channel(a_sets, m)
    b_send, b_recv, wb, gb = channel(b_sets, n)
    sup = (a_send, a_recv, b_send, b_recv)
    return sup, common.SparseMeta(gather=ga, gather_b=gb, wg=wa, wg_b=wb,
                                  compress=compress)


def _coo(plan, rl, cl, vl, tb):
    return common.coo_of(rl, cl, vl, tb, (plan.mS, plan.n), plan.row_tile)


def _shift(x, axis_name, size):
    return jax.lax.ppermute(x, axis_name,
                            [(i, (i + 1) % size) for i in range(size)])


def _shift_tuple(xs, axis_name, size):
    return tuple(_shift(x, axis_name, size) for x in xs)


def _exec(grid: Grid15, plan: PlanS15, body, A, B, out_specs,
          a_spec=None, b_spec=None):
    """``a_spec``/``b_spec`` override the dense-operand specs — the
    pre-gathered (Session-cached) paths pass ``P(None, layer)``: column
    slabs split over the layer axis, replicated along the fiber."""
    mesh, lay, fib = grid.mesh, grid.layer, grid.fiber
    s_spec = P(lay, fib)
    sup_specs = jax.tree_util.tree_map(lambda _: s_spec, plan.sup)
    fn = common.shard_map(
        body, mesh=mesh,
        in_specs=((s_spec,) * 4,
                  a_spec if a_spec is not None else P(None, (lay, fib)),
                  b_spec if b_spec is not None else P(None, (lay, fib)),
                  sup_specs),
        out_specs=out_specs)
    s_pack = (plan.rows_local, plan.cols, plan.vals, plan.tile_base)
    return fn(s_pack, A, B, plan.sup)


def replicated_spec(grid: Grid15) -> P:
    """Sharding spec of a pre-gathered dense operand (see Session)."""
    return P(None, grid.layer)


def schedule_events(grid: Grid15, op: str, elision: str = "none"):
    """Ordered (point, phase) fault boundaries of one executor round.

    s15 fiber-gathers dense *column slabs* (one gather event per dense
    operand) and shifts the sparse structure through L phases; the
    "fused" cell ships the structure once (one propagation round), the
    other cells twice.  There is no terminal reduce — the output comes
    home as phase-stacked slabs (repro.distributed.faults).
    """
    L = grid.L

    def passes(n, start=0):
        out = []
        for t in range(start, start + n * L):
            out += [("phase", t), ("shift", t)]
        return out

    if op == "sddmm":
        return [("gather", 0), ("gather", 1)] + passes(1)
    if op in ("spmm", "spmm_t"):     # spmm_t = spmm on the S^T problem
        return [("gather", 0)] + passes(1)
    if op == "fusedmm":
        head = [("gather", 0), ("gather", 1)]
        if elision == "fused":
            return head + passes(1)
        if elision == "none":
            # B's honest re-gather happens BETWEEN the propagation
            # rounds (the SpMM half gathers afresh), so its event sits
            # there — the emitted HLO order, which the static
            # conformance verifier pins (repro.analysis.conformance)
            return (head + passes(1) + [("gather", 2)]
                    + passes(1, start=L))
        return head + passes(2)      # reuse: replayed, no re-gather
    raise ValueError(f"unknown op {op!r}")


# No s15 schedule event legalizes to more than one collective kind —
# a shift's three payloads are all collective-permutes (contract read
# by the static conformance verifier; s25 declares the one real entry).
WIRE_EXPANSIONS: dict = {}


def schedule_words(grid: Grid15, plan: PlanS15, op: str,
                   elision: str = "none",
                   pre_gathered=(False, False)):
    """Impl-exact per-device wire words for each schedule event.

    Aligned 1:1 with :func:`schedule_events`; see d15.schedule_words for
    the contract.  The COO propagation decomposes per shift event into a
    partial/value payload (nb*k words) and a structure payload
    (2*nb*k + tile-map words); ``tile_base`` only travels when the pack
    has more than one row tile per block (row_tile < mS) — with a single
    tile the kernels never read it and XLA prunes its shift chain.
    """
    L, c, p = grid.L, grid.c, grid.p
    nb, k = plan.rows_local.shape[-2:]
    e = float(nb * k)
    b = float(nb) if plan.row_tile < plan.mS else 0.0
    ga = float((c - 1) * plan.m * (plan.r // p))
    gb = float((c - 1) * plan.n * (plan.r // p))
    pre_a, pre_b = pre_gathered
    if op == "sddmm":
        gathers = [0.0 if pre_a else ga, 0.0 if pre_b else gb]

        def shift_w(t):
            return e + ((2 * e + b) if t < L - 1 else 0.0)
    elif op in ("spmm", "spmm_t"):
        gathers = [0.0 if pre_b else gb]

        def shift_w(t):
            return (3 * e + b) if t < L - 1 else 0.0
    elif op == "fusedmm":
        el = "fused" if elision == "auto" else elision
        gathers = [0.0 if pre_a else ga, 0.0 if pre_b else gb]
        if el == "none":
            gathers.append(gb)   # honest re-gather, never session-elided
        if el == "fused":
            # single structure pass: the partial, the ORIGINAL values
            # (the SpMM half samples R = vals * partial in-flight) and
            # the structure all travel together; the final shift brings
            # the partial home alone
            def shift_w(t):
                return e + ((3 * e + b) if t < L - 1 else 0.0)
        else:
            # none/reuse: round-1's final struct shift feeds round 2's
            # full-pack propagation, so only the very last shift dies
            def shift_w(t):
                return (3 * e + b) if t < 2 * L - 1 else 0.0
    else:
        raise ValueError(f"unknown op {op!r}")
    out, gi = [], iter(gathers)
    for point, t in schedule_events(grid, op, elision):
        if point == "gather":
            out.append((point, t, "all-gather", next(gi)))
        elif point == "shift":
            out.append((point, t, "collective-permute", float(shift_w(t))))
        else:
            out.append((point, t, None, 0.0))
    return out


def _sddmm_round(grid, plan, T_A, T_B, s, L, lay):
    """One propagation round accumulating partial sampled dots.

    s = (rl, cl, vals, tb) local pack; returns the pack home again with
    partial dot products in the values slot (UNSCALED by original vals),
    plus the per-phase resident structures ``structs`` (local references,
    no extra communication — dead code unless a caller consumes them, as
    the "fused" one-structure-pass schedule does).  The coordinate shifts
    are double-buffered ahead of the kernel; the partial buffer trails
    one kernel behind.
    """
    u = jax.lax.axis_index(lay)
    tk = plan.tiling.kernel_kwargs()
    rl, cl, _, tb = s
    partial = jnp.zeros_like(s[2])
    ones = jnp.ones_like(partial)

    struct = (rl, cl, tb)
    structs = []
    nxt = _shift_tuple(struct, lay, L) if L > 1 else None
    for t in range(L):
        blk = (u - t) % L                       # layer-row of resident block
        off = (blk * grid.c + jax.lax.axis_index(grid.fiber)) * plan.mS
        a_slice = jax.lax.dynamic_slice(
            T_A, (off, 0), (plan.mS, plan.rc))
        rl_c, cl_c, tb_c = struct
        structs.append(struct)
        dots = ops.sddmm(a_slice, T_B,
                         _coo(plan, rl_c, cl_c, ones, tb_c), **tk).vals
        partial = _shift(partial + dots, lay, L)
        if L > 1:
            struct = nxt
            if t + 1 < L:
                nxt = _shift_tuple(nxt, lay, L)
        else:
            struct = _shift_tuple(struct, lay, L)
    rl, cl, tb = struct
    return (rl, cl, partial, tb), structs


def _spmm_round(grid, plan, T_B, s, L, lay):
    """Propagation round for SpMMA: emits per-phase output slabs."""
    tk = plan.tiling.kernel_kwargs()
    cur = s
    nxt = _shift_tuple(cur, lay, L) if L > 1 else None
    slabs = []
    for t in range(L):
        rl, cl, vals, tb = cur
        slabs.append(ops.spmm(_coo(plan, rl, cl, vals, tb), T_B,
                              m=plan.mS, **tk))
        if L > 1:
            cur = nxt
            if t + 1 < L:
                nxt = _shift_tuple(nxt, lay, L)
        else:
            cur = _shift_tuple(cur, lay, L)
    return jnp.stack(slabs)  # (L, mS, rc) — slab t covers rows of block b_t


def _spmm_round_cached(grid, plan, T_B, vals0, structs, L, lay):
    """SpMM propagation round replaying locally cached structure.

    The "fused" one-structure-pass elision: the SDDMM round already
    marched every block's coordinates through this device (``structs``,
    period-L schedule — round-2 phase t re-encounters round-1 phase t's
    block), so only the final sample values travel: 1 word/nnz/phase
    instead of the 3-word COO pack.  Kernel operands are value-identical
    to :func:`_spmm_round`, hence bitwise-identical slabs.
    """
    tk = plan.tiling.kernel_kwargs()
    vals_cur = vals0
    vals_nxt = _shift(vals_cur, lay, L) if L > 1 else None
    slabs = []
    for t in range(L):
        rl, cl, tb = structs[t]
        slabs.append(ops.spmm(_coo(plan, rl, cl, vals_cur, tb), T_B,
                              m=plan.mS, **tk))
        if L > 1:
            vals_cur = vals_nxt
            if t + 1 < L:
                vals_nxt = _shift(vals_nxt, lay, L)
        else:
            vals_cur = _shift(vals_cur, lay, L)
    return jnp.stack(slabs)


def _gather_cols(x, fib):
    """All-gather column slices along the fiber: (n, r/p) -> (n, rc/p)."""
    return jax.lax.all_gather(x, fib, axis=1, tiled=True)


def _sq_sup(sup):
    """Per-device view of the support arrays (drop (layer, fiber) dims)."""
    return jax.tree_util.tree_map(lambda x: x[0, 0], sup)


def _gather_side(plan, x, sup, fib, c, side):
    """Fiber all-gather of one dense operand, support-pruned when won."""
    sm = plan.smeta
    on = sm is not None and (sm.gather if side == 0 else sm.gather_b)
    if not on:
        return _gather_cols(x, fib)
    send, recv = sup[2 * side], sup[2 * side + 1][0]
    return common.pruned_gather_cols(x, send, recv, fib, c,
                                     compress=sm.compress)


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("pre_gathered",))
def sddmm_s15(grid: Grid15, plan: PlanS15, A, B,
              pre_gathered: tuple = (False, False)):
    """R = S * (A @ B.T); R values return to home-block layout.

    pre_gathered=(a, b): the corresponding dense operand arrives already
    fiber-replicated (sharding ``replicated_spec(grid)``) and its
    all-gather is skipped — the ``repro.core.api.Session`` reuse path."""
    lay, fib, L = grid.layer, grid.fiber, grid.L
    pre_a, pre_b = pre_gathered

    def body(s, A_loc, B_loc, sup):
        s = tuple(x[0, 0] for x in s)
        sup = _sq_sup(sup)
        T_A = A_loc if pre_a else _gather_side(plan, A_loc, sup, fib,
                                               grid.c, 0)
        T_B = B_loc if pre_b else _gather_side(plan, B_loc, sup, fib,
                                               grid.c, 1)
        (rl, cl, partial, tb), _ = _sddmm_round(grid, plan, T_A, T_B, s,
                                                L, lay)
        vals = s[2] * partial            # scale by original samples (home)
        return vals[None, None]

    rspec = replicated_spec(grid)
    return _exec(grid, plan, body, A, B, P(lay, fib),
                 a_spec=rspec if pre_a else None,
                 b_spec=rspec if pre_b else None)


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("pre_gathered",))
def spmma_s15(grid: Grid15, plan: PlanS15, B, pre_gathered: bool = False):
    """A = S @ B; output slabs stacked by phase: (L, c, T, mS, rc/p).

    pre_gathered=True: B's column slices arrive already fiber-replicated
    (sharding ``replicated_spec(grid)``) and the all-gather is skipped —
    the backward transpose-SpMM of a training step replays the forward's
    gather through an ``api.Session`` this way (repro.core.grads).
    """
    lay, fib, L = grid.layer, grid.fiber, grid.L

    def body(s, _A, B_loc, sup):
        s = tuple(x[0, 0] for x in s)
        T_B = B_loc if pre_gathered else _gather_side(
            plan, B_loc, _sq_sup(sup), fib, grid.c, 1)
        slabs = _spmm_round(grid, plan, T_B, s, L, lay)
        return slabs[None, None]

    dummy = jnp.zeros((1, grid.p), jnp.float32)
    return _exec(grid, plan, body, dummy, B, P(lay, fib),
                 b_spec=replicated_spec(grid) if pre_gathered else None)


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("elision", "pre_gathered"))
def fusedmm_s15(grid: Grid15, plan: PlanS15, A, B, elision: str = "auto",
                pre_gathered: tuple = (False, False)):
    """FusedMMA = SpMMA(SDDMM(A,B,S), B) with sparse shifting.

    elision="auto" : resolves to "fused" (always cheapest here; see
    docs/choosing.md)
    elision="fused": one-structure-pass — the SpMM round replays the
    per-phase coordinate structure cached locally during the SDDMM round
    (the schedules coincide, period L), so only the final sample values
    travel in round 2: the 6*phi/c shift term drops to 4*phi/c.  The
    single fiber all-gather of "reuse" is retained.  True local-kernel
    fusion is impossible here — each phase's gathered slices span only
    r*c/p of the r columns, so per-phase dots are partial (docs/
    algorithms.md) — but the *communication* signature of local fusion
    (structure shipped once, not twice) is achieved.
    elision="reuse": the fiber all-gathers of the dense column slices are
    performed ONCE and serve both rounds (paper's replication reuse).
    elision="none": B is re-gathered between the rounds, emulating two
    independent kernel launches (the unoptimized baseline).

    pre_gathered=(a, b): the corresponding dense operand arrives already
    fiber-replicated (sharding ``replicated_spec(grid)``) and its
    all-gather is skipped — the across-call replication reuse exploited by
    ``repro.core.api.Session``.

    Returns (slabs (L,c,T,mS,rc/p), R_vals (L,c,nb,k)).
    """
    if elision == "auto":
        elision = "fused"
    lay, fib, L = grid.layer, grid.fiber, grid.L
    pre_a, pre_b = pre_gathered

    def body(s, A_loc, B_loc, sup):
        s = tuple(x[0, 0] for x in s)
        sup = _sq_sup(sup)
        T_A = A_loc if pre_a else _gather_side(plan, A_loc, sup, fib,
                                               grid.c, 0)
        T_B = B_loc if pre_b else _gather_side(plan, B_loc, sup, fib,
                                               grid.c, 1)
        (rl, cl, partial, tb), structs = _sddmm_round(grid, plan, T_A, T_B,
                                                      s, L, lay)
        r_vals = s[2] * partial
        if elision == "fused":
            slabs = _spmm_round_cached(grid, plan, T_B, r_vals, structs,
                                       L, lay)
            return slabs[None, None], r_vals[None, None]
        if elision == "none":
            # Unoptimized baseline: replicate B again for the SpMM, as two
            # independent kernel launches would.  NOTE: a naive duplicate
            # all-gather gets CSE'd by XLA — the compiler applies the
            # paper's replication reuse automatically within one program
            # (an observation we report in EXPERIMENTS.md).  To price the
            # two-launch baseline honestly we re-derive the local slice
            # from the gathered buffer and re-gather it, which XLA cannot
            # structurally merge.
            v_idx = jax.lax.axis_index(fib)
            w = T_B.shape[1] // grid.c
            B_back = jax.lax.dynamic_slice_in_dim(T_B, v_idx * w, w, axis=1)
            T_B = _gather_side(plan, B_back, sup, fib, grid.c, 1)
        slabs = _spmm_round(grid, plan, T_B, (rl, cl, r_vals, tb), L, lay)
        return slabs[None, None], r_vals[None, None]

    rspec = replicated_spec(grid)
    return _exec(grid, plan, body, A, B, (P(lay, fib), P(lay, fib)),
                 a_spec=rspec if pre_a else None,
                 b_spec=rspec if pre_b else None)


def assemble_spmm_out(grid: Grid15, plan: PlanS15, slabs) -> np.ndarray:
    """Host-side reassembly of phase-stacked SpMM slabs into (m, r)."""
    L, c = grid.L, grid.c
    slabs = np.asarray(slabs)
    out = np.zeros((plan.m, plan.r), np.float32)
    w = plan.r * c // grid.p
    for u in range(L):
        for v in range(c):
            for t in range(L):
                b = ((u - t) % L) * c + v
                out[b * plan.mS:(b + 1) * plan.mS,
                    u * w:(u + 1) * w] = slabs[u, v, t]
    return out
