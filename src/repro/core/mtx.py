"""Minimal Matrix Market (``.mtx``) I/O for real sparse matrices.

The paper's strong-scaling experiments run on SuiteSparse matrices
distributed in Matrix Market coordinate format; this loader lets the
examples, benchmarks and dryruns consume those files directly instead of
only the synthetic Erdos-Renyi/RMAT generators.  Kept dependency-free
(no scipy.io): the subset implemented — ``coordinate`` storage with
``real``/``integer``/``pattern`` fields and ``general``/``symmetric``/
``skew-symmetric`` symmetry — covers the SuiteSparse collection's sparse
matrices.  ``array`` (dense) storage is intentionally rejected: this
library is about sparse kernels.

A tiny bundled fixture lives at ``tests/fixtures/tiny.mtx`` so the
``--mtx`` paths of the examples/benchmarks are exercised in CI without
shipping a real dataset.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["load_mtx", "save_mtx"]


def load_mtx(path: str, dtype=np.float32):
    """Read a Matrix Market coordinate file.

    Returns ``(rows, cols, vals, (m, n))`` with int32 zero-based
    coordinates, ``dtype`` values (``pattern`` entries become 1.0), and
    symmetric/skew-symmetric storage expanded to the full pattern
    (off-diagonal entries mirrored, negated for skew).  Duplicate
    entries are summed, matching common sparse-assembly convention.
    """
    with open(path, "r") as f:
        header = f.readline()
        parts = header.strip().split()
        if len(parts) < 5 or parts[0] != "%%MatrixMarket":
            raise ValueError(f"{path}: not a MatrixMarket file: {header!r}")
        _, obj, fmt, field, symmetry = (p.lower() for p in parts[:5])
        if obj != "matrix" or fmt != "coordinate":
            raise ValueError(f"{path}: only 'matrix coordinate' supported, "
                             f"got '{obj} {fmt}'")
        if field not in ("real", "integer", "pattern"):
            raise ValueError(f"{path}: unsupported field {field!r} "
                             "(real/integer/pattern)")
        if symmetry not in ("general", "symmetric", "skew-symmetric"):
            raise ValueError(f"{path}: unsupported symmetry {symmetry!r}")
        line = f.readline()
        while line and line.lstrip().startswith("%"):
            line = f.readline()
        dims = line.split()
        if len(dims) != 3:
            raise ValueError(f"{path}: bad size line {line!r}")
        m, n, nnz = (int(x) for x in dims)
        body = np.loadtxt(f, ndmin=2) if nnz else np.zeros((0, 3))
    if body.shape[0] != nnz:
        raise ValueError(f"{path}: size line promises {nnz} entries, "
                         f"found {body.shape[0]}")
    rows = body[:, 0].astype(np.int64) - 1
    cols = body[:, 1].astype(np.int64) - 1
    bad = (rows < 0) | (rows >= m) | (cols < 0) | (cols >= n)
    if bool(bad.any()):
        i = int(np.argmax(bad))
        raise ValueError(
            f"{path}: entry {i} at 1-based ({rows[i] + 1}, {cols[i] + 1}) "
            f"outside the declared {m} x {n} shape")
    if field == "pattern":
        vals = np.ones(nnz, np.float64)
    else:
        if body.shape[1] < 3:
            raise ValueError(f"{path}: {field} matrix without value column")
        vals = body[:, 2].astype(np.float64)
    if symmetry != "general":
        off = rows != cols
        sign = -1.0 if symmetry == "skew-symmetric" else 1.0
        rows, cols = (np.concatenate([rows, cols[off]]),
                      np.concatenate([cols, rows[off]]))
        vals = np.concatenate([vals, sign * vals[off]])
    # sum duplicates + canonical row-major order (matches erdos_renyi)
    key = rows * n + cols
    order = np.argsort(key, kind="stable")
    key, vals = key[order], vals[order]
    uniq, starts = np.unique(key, return_index=True)
    summed = np.add.reduceat(vals, starts) if len(vals) else vals
    rows = (uniq // n).astype(np.int32)
    cols = (uniq % n).astype(np.int32)
    return rows, cols, summed.astype(dtype), (m, n)


def save_mtx(path: str, rows, cols, vals, shape: Tuple[int, int]):
    """Write a general real coordinate Matrix Market file (1-based)."""
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    m, n = shape
    with open(path, "w") as f:
        f.write("%%MatrixMarket matrix coordinate real general\n")
        f.write(f"% written by repro.core.mtx\n{m} {n} {len(vals)}\n")
        for i, j, v in zip(rows, cols, vals):
            f.write(f"{int(i) + 1} {int(j) + 1} {float(v):.9g}\n")
