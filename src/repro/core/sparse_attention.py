"""Block-sparse attention as SDDMM -> row-softmax -> SpMM (beyond-paper).

The paper's GAT workload already shows attention IS the FusedMM pattern;
this module closes the loop for LM attention: a block-sparse causal mask
(sliding window + global tokens) makes long-context attention a sparse
kernel problem, so the paper's distributed algorithms (and their
communication analysis in phi = nnz/(S*hd)) apply directly to the
attention layer.  Used by examples/sparse_attention_lm.py and available
as an opt-in attention for long-context experiments.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import sparse
from repro.kernels import ops


def build_causal_block_mask(seq: int, block: int, window_blocks: int,
                            global_blocks: int = 1, row_tile: int = 128,
                            nz_block: int = 256) -> sparse.RowTiledCOO:
    """Element-level RowTiledCOO for a causal sliding-window+global mask."""
    brows, bcols = sparse.block_sparse_mask(seq, block, window_blocks,
                                            global_blocks)
    rows_l, cols_l = [], []
    for br, bc in zip(brows, bcols):
        r0, c0 = br * block, bc * block
        r = np.repeat(np.arange(block), block) + r0
        c = np.tile(np.arange(block), block) + c0
        keep = r >= c              # causal inside diagonal blocks
        rows_l.append(r[keep].astype(np.int32))
        cols_l.append(c[keep].astype(np.int32))
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    key = np.unique(rows.astype(np.int64) * seq + cols)
    rows = (key // seq).astype(np.int32)
    cols = (key % seq).astype(np.int32)
    vals = np.ones(len(rows), np.float32)
    return sparse.pack_row_tiled(rows, cols, vals, (seq, seq),
                                 row_tile=row_tile, nz_block=nz_block)


def row_softmax(S: sparse.RowTiledCOO) -> sparse.RowTiledCOO:
    rows = S.rows_global().reshape(-1)
    vals = S.vals.reshape(-1)
    mask = vals != 0
    neg = jnp.full((S.shape[0],), -1e30, jnp.float32)
    rmax = neg.at[rows].max(jnp.where(mask, vals, -1e30))
    ex = jnp.where(mask, jnp.exp(vals - rmax[rows]), 0.0)
    rsum = jnp.zeros((S.shape[0],), jnp.float32).at[rows].add(ex)
    out = ex / jnp.maximum(rsum[rows], 1e-30)
    return S.with_vals(out.reshape(S.vals.shape))


def sparse_attention_head(q, k, v, mask: sparse.RowTiledCOO):
    """One attention head over a block-sparse mask.

    q (S, hd), k (S, hd), v (S, hd) -> (S, hd).
    scores = SDDMM(q, k, mask)/sqrt(hd); probs = row_softmax;
    out = SpMM(probs, v).
    """
    hd = q.shape[-1]
    scores = ops.sddmm(q * (hd ** -0.5), k, mask)
    # mask vals are 1.0 -> scores are the raw sampled dots
    probs = row_softmax(scores)
    return ops.spmm(probs, v, m=q.shape[0])


def dense_reference(q, k, v, mask_dense):
    hd = q.shape[-1]
    s = (q @ k.T) * (hd ** -0.5)
    s = jnp.where(mask_dense != 0, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.nan_to_num(p)
    return p @ v


def sparsity_stats(mask: sparse.RowTiledCOO, seq: int, hd: int):
    nnz = int((np.asarray(mask.vals) != 0).sum())
    return dict(nnz=nnz, dense=seq * seq,
                fraction=nnz / (seq * seq),
                phi=nnz / (seq * hd))
