"""Unified distributed-algorithm API: Algorithm registry, DistProblem,
Session (paper §V + §VI-E applications).

The four executor families (``d15``, ``s15``, ``d25``, ``s25``) implement
the same mathematical procedures — SDDMM, SpMM and FusedMM — with four
different communication schedules.  This module puts them behind ONE
abstraction so applications, launch tooling and benchmarks never branch
per family:

* **Algorithm** — registry entry binding a family's planner and its
  sddmm/spmm/fusedmm executors to a shared signature.  All algorithms
  expose *FusedMMA semantics*: ``fusedmm(S, X, Y) = (S * (X @ Y.T)) @ Y``
  with output ``(m, r)``; where a family's replication-reuse executor is
  the FusedMMB form (d15/d25), the registry runs it on the transpose pack
  with swapped operands — ``FusedMMA(S, X, Y) = FusedMMB(S^T, Y, X)`` —
  so the caller-visible contract never changes.  The elision matrix is
  full rank: every entry declares ``reuse`` and (except s25, where it is
  structurally impossible) ``fused``, each cell backed by a Table-III
  word-count row in ``costmodel`` — docs/algorithms.md tabulates the
  grid with per-cell formulas.
* **DistProblem** — owns the host COO of S, the processor grid, and the
  device-placed packs in every orientation the chosen strategies need
  (built lazily, amortized across calls like the paper's preprocessing).
* **Session** — caches *replication state*: the fiber-all-gathered copy of
  a dense operand.  Within one FusedMM call the paper's replication-reuse
  elision shares a single all-gather between the SDDMM and SpMM rounds;
  the Session extends the same elision **across calls** — ALS's CG loop
  calls FusedMM every iteration with the same stationary factor matrix,
  so its gather is paid once per solve instead of once per iteration.
  Cached calls are bitwise-identical to uncached ones: the executors'
  ``pre_gathered`` paths feed the local kernels the very same operand
  values the in-call all-gather would have produced.

Dispatch: ``make_problem(..., algorithm="auto")`` ranks every feasible
(family, elision, c) by the paper's Table-III bandwidth formulas
(:func:`repro.core.costmodel.choose_algorithm`) — low phi = nnz/(n*r)
selects the sparse-shifting/replicating families, high phi the dense ones.

Results come back host-assembled (numpy) so the contract is uniform
across the four families' on-device layouts; the family modules remain
the layout-aware fast path for callers that keep data device-resident.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, d15, d25, s15, s25
from repro.core.grid import make_grid15, make_grid25

__all__ = [
    "ALGORITHMS", "Algorithm", "DistProblem", "Session", "SparseResult",
    "make_problem", "sddmm", "spmm", "fusedmm", "activate",
]


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

def _match_coo(sorted_keys, order, keys):
    """Locate query coordinate keys (r*n + c) in a problem's COO.

    ``(sorted_keys, order)`` come from :meth:`DistProblem.coo_sort`
    (computed once per problem — the coordinates never change).  Returns
    (positions, ok): for each query key, a position into the problem's
    COO order and a mask of keys that actually occur there.  O(q log nnz)
    per call; never materializes a dense matrix.
    """
    if len(order) == 0:
        return (np.zeros(len(keys), np.int64),
                np.zeros(len(keys), bool))
    pos = np.minimum(np.searchsorted(sorted_keys, keys), len(order) - 1)
    idx = order[pos]
    return idx, sorted_keys[pos] == keys

@dataclasses.dataclass
class SparseResult:
    """Sampled (SDDMM-shaped) output in its family's home layout.

    ``raw`` keeps the device-side values exactly as the executor returned
    them (per-phase tuples for d15, fiber-sharded shards for s25, ...);
    ``_triples`` assembles the flat global COO view — O(nnz), never a
    dense matrix — from which ``values``/``to_dense`` derive.
    """
    problem: "DistProblem"
    raw: object
    _triples: Callable[[], tuple]
    _coo: Optional[tuple] = None
    _vals: Optional[np.ndarray] = None

    def to_coo(self):
        """Flat global (rows, cols, vals), padding filtered."""
        if self._coo is None:
            self._coo = self._triples()
        return self._coo

    def to_dense(self) -> np.ndarray:
        """Dense (m, n) matrix with the sampled values scattered in.

        Quadratic in the matrix dimensions — small/debug problems only;
        prefer ``values``/``to_coo`` on production shapes.
        """
        r, c, v = self.to_coo()
        out = np.zeros((self.problem.m, self.problem.n), np.float64)
        np.add.at(out, (r, c), v)
        return out.astype(np.float32)

    def values(self) -> np.ndarray:
        """Values aligned with the problem's host COO (rows, cols) order.

        O(nnz log nnz): the assembled triples are matched to the
        problem's coordinate keys — no dense materialization.
        """
        if self._vals is None:
            prob = self.problem
            r, c, v = self.to_coo()
            sk, order = prob.coo_sort()
            idx, ok = _match_coo(sk, order, r * prob.n + c)
            out = np.zeros(prob.nnz, np.float64)
            np.add.at(out, idx[ok], v[ok])
            self._vals = out.astype(np.float32)
        return self._vals


# ---------------------------------------------------------------------------
# Algorithm registry
# ---------------------------------------------------------------------------

ALGORITHMS: Dict[str, "Algorithm"] = {}


class Algorithm:
    """Registry entry: one distributed algorithm family behind the shared
    plan/sddmm/spmm/fusedmm signature.  Subclasses adapt layouts only —
    the executors live in their family modules."""

    name: str = ""
    elisions: Tuple[str, ...] = ()       # strategies fusedmm accepts
    auto_elisions: Tuple[str, ...] = ()  # candidates for elision="auto"

    # -- grid / feasibility --------------------------------------------------
    def make_grid(self, c: int, devices):
        raise NotImplementedError

    def make_plan(self, prob, orient: str):
        """Build this family's pack for one orientation (host, amortized)."""
        raise NotImplementedError

    def feasible(self, *, m: int, n: int, r: int, p: int, c: int) -> bool:
        return costmodel.family_feasible(self.name, m=m, n=n, r=r, p=p, c=c)

    def min_r_multiple(self, grid) -> int:
        """Smallest multiple the dense operand width r must obey."""
        return 1

    # -- layouts -------------------------------------------------------------
    def shard_x(self, prob, X):
        """Place an (m, r) operand in this family's X input layout."""
        raise NotImplementedError

    def shard_y(self, prob, Y):
        """Place an (n, r) operand in this family's Y input layout."""
        raise NotImplementedError

    def replicate(self, prob, arr, slot: str):
        """Place an operand in the fiber-replicated (gathered) layout —
        the across-call replication state a Session caches."""
        raise NotImplementedError

    # -- execution (device in, host out) ------------------------------------
    def sddmm(self, prob, X, Y) -> SparseResult:
        raise NotImplementedError

    def spmm(self, prob, Y) -> np.ndarray:
        raise NotImplementedError

    def fusedmm(self, prob, X, Y, elision: str,
                session: Optional["Session"]):
        fn, args, kwargs, post = self._fusedmm_call(prob, X, Y, elision,
                                                    session)
        return post(fn(*args, **kwargs))

    def lower_fusedmm(self, prob, elision: str):
        """Lower the family's jitted FusedMM for HLO/roofline analysis."""
        X = np.zeros((prob.m, prob.r), np.float32)
        Y = np.zeros((prob.n, prob.r), np.float32)
        fn, args, kwargs, _ = self._fusedmm_call(prob, X, Y, elision, None)
        return fn.lower(*args, **kwargs)

    def _fusedmm_call(self, prob, X, Y, elision, session):
        raise NotImplementedError


def register(cls):
    alg = cls()
    ALGORITHMS[alg.name] = alg
    return cls


def _put(arr, sharding):
    return jax.device_put(jnp.asarray(np.asarray(arr, np.float32)),
                          sharding)


# ---------------------------------------------------------------------------
# 1.5D dense shifting
# ---------------------------------------------------------------------------

@register
class _D15(Algorithm):
    name = "d15"
    elisions = ("none", "reuse", "fused")
    auto_elisions = ("none", "reuse", "fused")

    def make_grid(self, c, devices):
        return make_grid15(c, devices=devices)

    def make_plan(self, prob, orient):
        kw = dict(row_tile=prob.row_tile, nz_block=prob.nz_block)
        if orient == "normal":
            return d15.plan_d15(prob.grid, prob.rows, prob.cols, prob.vals,
                                prob.m, prob.n, prob.r, **kw)
        return d15.plan_d15(prob.grid, prob.cols, prob.rows, prob.vals,
                            prob.n, prob.m, prob.r, transpose=True, **kw)

    def shard_x(self, prob, X):
        g = prob.grid
        return _put(X, g.sharding((g.layer, g.fiber)))

    shard_y = shard_x   # same layout, different row count

    def replicate(self, prob, arr, slot):
        g = prob.grid
        return _put(arr, g.sharding(g.layer))

    def sddmm(self, prob, X, Y):
        plan = prob.plan("normal")
        rv = d15.sddmm_d15(prob.grid, plan, self.shard_x(prob, X),
                           self.shard_y(prob, Y))
        return SparseResult(prob, rv,
                            lambda: plan.meta.block_meta.to_triples(
                                plan.rows_local, plan.cols, rv,
                                plan.tile_base))

    def spmm(self, prob, Y):
        plan = prob.plan("normal")
        return np.asarray(d15.spmma_d15(prob.grid, plan,
                                        self.shard_y(prob, Y)))

    def _fusedmm_call(self, prob, X, Y, elision, session):
        grid = prob.grid
        if elision == "reuse":
            # FusedMMA(S, X, Y) = FusedMMB(S^T, Y, X): Y takes the
            # replicated slot, X the shifting slot, on the S^T pack.
            plan = prob.plan("transpose")
            a_host, slot = Y, "y"
            b = self.shard_x(prob, X)
        else:
            plan = prob.plan("normal")
            a_host, slot = X, "x"
            b = self.shard_y(prob, Y)
        if session is not None:
            a, pre = session.replicate(prob, a_host, slot), True
        else:
            a, pre = (self.shard_x if slot == "x" else self.shard_y)(
                prob, a_host), False

        def post(res):
            out, rvals = res
            return np.asarray(out), SparseResult(
                prob, rvals, lambda: plan.meta.block_meta.to_triples(
                    plan.rows_local, plan.cols, rvals, plan.tile_base))

        return (d15.fusedmm_d15, (grid, plan, a, b),
                dict(elision=elision, pre_gathered=pre), post)


# ---------------------------------------------------------------------------
# 1.5D sparse shifting
# ---------------------------------------------------------------------------

@register
class _S15(Algorithm):
    name = "s15"
    elisions = ("none", "reuse", "fused")
    auto_elisions = ("fused", "reuse", "none")

    def make_grid(self, c, devices):
        return make_grid15(c, devices=devices)

    def make_plan(self, prob, orient):
        assert orient == "normal", "s15 keeps S stationary-by-row"
        return s15.plan_s15(prob.grid, prob.rows, prob.cols, prob.vals,
                            prob.m, prob.n, prob.r,
                            row_tile=prob.row_tile, nz_block=prob.nz_block)

    def min_r_multiple(self, grid):
        return grid.p

    def shard_x(self, prob, X):
        g = prob.grid
        return _put(X, g.sharding(None, (g.layer, g.fiber)))

    shard_y = shard_x

    def replicate(self, prob, arr, slot):
        g = prob.grid
        return _put(arr, g.sharding(None, g.layer))

    def _rvals_triples(self, prob, plan, rv):
        return lambda: plan.meta.block_meta.to_triples(
            plan.rows_local, plan.cols, np.asarray(rv), plan.tile_base)

    def sddmm(self, prob, X, Y):
        plan = prob.plan("normal")
        rv = s15.sddmm_s15(prob.grid, plan, self.shard_x(prob, X),
                           self.shard_y(prob, Y))
        return SparseResult(prob, rv, self._rvals_triples(prob, plan, rv))

    def spmm(self, prob, Y):
        plan = prob.plan("normal")
        slabs = s15.spmma_s15(prob.grid, plan, self.shard_y(prob, Y))
        return s15.assemble_spmm_out(prob.grid, plan, slabs)

    def _fusedmm_call(self, prob, X, Y, elision, session):
        grid = prob.grid
        plan = prob.plan("normal")
        if session is not None:
            a = session.replicate(prob, X, "x")
            b = session.replicate(prob, Y, "y")
            pre = (True, True)
        else:
            a, b = self.shard_x(prob, X), self.shard_y(prob, Y)
            pre = (False, False)

        def post(res):
            slabs, rvals = res
            return (s15.assemble_spmm_out(grid, plan, slabs),
                    SparseResult(prob, rvals,
                                 self._rvals_triples(prob, plan, rvals)))

        return (s15.fusedmm_s15, (grid, plan, a, b),
                dict(elision=elision, pre_gathered=pre), post)


# ---------------------------------------------------------------------------
# 2.5D dense replicating
# ---------------------------------------------------------------------------

@register
class _D25(Algorithm):
    name = "d25"
    elisions = ("none", "reuse", "fused")
    auto_elisions = ("fused", "reuse", "none")

    def make_grid(self, c, devices):
        return make_grid25(c, devices=devices)

    def make_plan(self, prob, orient):
        kw = dict(row_tile=prob.row_tile, nz_block=prob.nz_block)
        if orient == "normal":
            return d25.plan_d25(prob.grid, prob.rows, prob.cols, prob.vals,
                                prob.m, prob.n, prob.r, **kw)
        return d25.plan_d25(prob.grid, prob.cols, prob.rows, prob.vals,
                            prob.n, prob.m, prob.r, transpose=True, **kw)

    def min_r_multiple(self, grid):
        return grid.G

    def shard_x(self, prob, X):
        # the replicated-slot layout; the shifting operand is skewed via
        # d25.skew_b at the call sites below
        g = prob.grid
        return _put(X, g.sharding((g.row, g.fiber), g.col))

    def replicate(self, prob, arr, slot):
        g = prob.grid
        return _put(arr, g.sharding(g.row, g.col))

    def sddmm(self, prob, X, Y):
        plan = prob.plan("normal")
        rv = d25.sddmm_d25(prob.grid, plan, self.shard_x(prob, X),
                           d25.skew_b(prob.grid, np.asarray(Y, np.float32)))
        return SparseResult(prob, rv,
                            lambda: plan.meta.block_meta.to_triples(
                                plan.rows_local, plan.cols,
                                np.asarray(rv), plan.tile_base))

    def spmm(self, prob, Y):
        plan = prob.plan("normal")
        out = d25.spmma_d25(prob.grid, plan,
                            d25.skew_b(prob.grid, np.asarray(Y, np.float32)))
        return np.asarray(out)

    def _fusedmm_call(self, prob, X, Y, elision, session):
        grid = prob.grid
        if elision == "reuse":
            plan = prob.plan("transpose")
            a_host, slot = Y, "y"
            b = d25.skew_b(grid, np.asarray(X, np.float32))
        else:
            plan = prob.plan("normal")
            a_host, slot = X, "x"
            b = d25.skew_b(grid, np.asarray(Y, np.float32))
        if session is not None:
            a, pre = session.replicate(prob, a_host, slot), True
        else:
            a, pre = self.shard_x(prob, a_host), False

        def post(res):
            out, rvals = res
            triples = lambda: plan.meta.block_meta.to_triples(  # noqa: E731
                plan.rows_local, plan.cols, np.asarray(rvals),
                plan.tile_base)
            if elision == "reuse":
                return (d25.unskew_out(grid, plan, out),
                        SparseResult(prob, rvals, triples))
            return np.asarray(out), SparseResult(prob, rvals, triples)

        return (d25.fusedmm_d25, (grid, plan, a, b),
                dict(elision=elision, pre_gathered=pre), post)


# ---------------------------------------------------------------------------
# 2.5D sparse replicating
# ---------------------------------------------------------------------------

@register
class _S25(Algorithm):
    name = "s25"
    # "fused" is structurally impossible here (docs/algorithms.md): the
    # cross-fiber partial-sum reduction separates the SDDMM and SpMM
    # halves, and the stationary S ships no structure to elide.
    elisions = ("none", "reuse")
    auto_elisions = ("reuse", "none")

    def make_grid(self, c, devices):
        return make_grid25(c, devices=devices)

    def make_plan(self, prob, orient):
        assert orient == "normal", "s25 replicates the structure"
        return s25.plan_s25(prob.grid, prob.rows, prob.cols, prob.vals,
                            prob.m, prob.n, prob.r,
                            row_tile=prob.row_tile, nz_block=prob.nz_block)

    def min_r_multiple(self, grid):
        return grid.G * grid.c

    def shard_x(self, prob, X):
        return s25.skew_dense(prob.grid, np.asarray(X, np.float32),
                              along="row")

    def shard_y(self, prob, Y):
        return s25.skew_dense(prob.grid, np.asarray(Y, np.float32),
                              along="col")

    # nothing dense is replicated: Session caching is a no-op here
    def replicate(self, prob, arr, slot):
        return self.shard_x(prob, arr) if slot == "x" \
            else self.shard_y(prob, arr)

    def _rvals_triples(self, prob, plan, rv):
        def triples():
            g = prob.grid
            G, nb = g.G, plan.rows_local.shape[3]
            full = np.asarray(rv).reshape(G, G, nb, np.asarray(rv).shape[-1])
            return plan.meta.block_meta.to_triples(
                np.asarray(plan.rows_local)[:, :, 0],
                np.asarray(plan.cols)[:, :, 0], full,
                np.asarray(plan.tile_base)[:, :, 0])
        return triples

    def sddmm(self, prob, X, Y):
        plan = prob.plan("normal")
        rv = s25.sddmm_s25(prob.grid, plan, self.shard_x(prob, X),
                           self.shard_y(prob, Y))
        return SparseResult(prob, rv, self._rvals_triples(prob, plan, rv))

    def spmm(self, prob, Y):
        plan = prob.plan("normal")
        out = s25.spmma_s25(prob.grid, plan, self.shard_y(prob, Y))
        return s25.unskew_out(prob.grid, plan, out)

    def _fusedmm_call(self, prob, X, Y, elision, session):
        grid = prob.grid
        plan = prob.plan("normal")
        a, b = self.shard_x(prob, X), self.shard_y(prob, Y)

        def post(res):
            out, rvals = res
            return (s25.unskew_out(grid, plan, out),
                    SparseResult(prob, rvals,
                                 self._rvals_triples(prob, plan, rvals)))

        return (s25.fusedmm_s25, (grid, plan, a, b),
                dict(elision=elision), post)


# ---------------------------------------------------------------------------
# DistProblem
# ---------------------------------------------------------------------------

_COST_NAME = costmodel.ELISION_COST_NAME


@dataclasses.dataclass
class DistProblem:
    """A packed sparse matrix + dense layouts bound to one algorithm/grid.

    Plans (the amortized host-side packing of S, and of S^T where a
    strategy needs it) are built lazily per orientation and cached, so
    repeated kernel calls — ALS's CG loop, GAT's per-layer sweeps — pay
    planning once, exactly like the paper's preprocessing."""
    alg: Algorithm
    grid: object
    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    m: int
    n: int
    r: int
    row_tile: int = 32
    nz_block: int = 32
    _plans: dict = dataclasses.field(default_factory=dict)
    _derived_r: dict = dataclasses.field(default_factory=dict)
    _coo_sort: Optional[tuple] = None

    # -- metadata ------------------------------------------------------------
    @property
    def nnz(self) -> int:
        return int(len(self.vals))

    @property
    def phi(self) -> float:
        return self.nnz / (self.n * self.r)

    @property
    def p(self) -> int:
        return self.grid.p

    @property
    def c(self) -> int:
        return self.grid.c

    # -- planning ------------------------------------------------------------
    def plan(self, orient: str = "normal"):
        if orient not in self._plans:
            self._plans[orient] = self.alg.make_plan(self, orient)
        return self._plans[orient]

    def coo_sort(self):
        """(sorted coordinate keys, argsort order) — cached; coordinates
        are immutable for a problem's lifetime."""
        if self._coo_sort is None:
            key = self.rows.astype(np.int64) * self.n + self.cols
            order = np.argsort(key, kind="stable")
            self._coo_sort = (key[order], order)
        return self._coo_sort

    # -- derived problems ----------------------------------------------------
    def with_values(self, vals: np.ndarray) -> "DistProblem":
        """Same structure, new sample values (e.g. softmaxed attention).

        Packing is deterministic in the coordinates, so the derived
        problem's blocks line up with this one's.  The derived problem
        re-packs on first use (values are baked into the device packs);
        injecting new values into the cached structural plan — the s25
        family's "attractive property" generalized — is a known future
        optimization for value-churn-heavy callers like GAT."""
        vals = np.asarray(vals, np.float32)
        assert vals.shape == self.rows.shape
        return dataclasses.replace(self, vals=vals, _plans={},
                                   _derived_r={})

    def with_r(self, r: int) -> "DistProblem":
        """Same sparse matrix, different dense-operand width.

        Derived problems are cached by width, so repeated callers (e.g.
        GAT deriving score/aggregation widths once per layer) reuse one
        set of packs instead of re-planning every call."""
        if r == self.r:
            return self
        if r not in self._derived_r:
            mult = self.alg.min_r_multiple(self.grid)
            if r % mult:
                raise ValueError(f"r={r} must be a multiple of {mult} "
                                 f"for {self.alg.name} on this grid")
            self._derived_r[r] = dataclasses.replace(
                self, r=r, _plans={}, _derived_r={})
        return self._derived_r[r]

    def transposed(self) -> "DistProblem":
        """The S^T problem on the same grid (for SpMMB-style updates)."""
        if not self.alg.feasible(m=self.n, n=self.m, r=self.r,
                                 p=self.p, c=self.c):
            raise ValueError(f"{self.alg.name} infeasible for the "
                             f"transposed shape ({self.n}, {self.m})")
        return dataclasses.replace(self, rows=self.cols, cols=self.rows,
                                   m=self.n, n=self.m, _plans={},
                                   _derived_r={}, _coo_sort=None)

    # -- elision resolution --------------------------------------------------
    def resolve_elision(self, elision: str = "auto",
                        session: Optional["Session"] = None) -> str:
        """Resolve ``elision="auto"``: rank this family's candidate
        strategies by their Table-III words at the problem's (p, c, phi).

        Without a Session the per-call :func:`costmodel.words_fusedmm`
        ranks the cells; with one, the *steady-state*
        :func:`costmodel.words_fusedmm_cached` does — it credits each
        cell the share of its replication term the Session elides (the
        stationary operand's all-gather, paid once per cache fill
        instead of once per call).  This is why a Session can flip the
        choice: d15's "reuse" drops to its shift words alone and
        overtakes "fused" at large c, while on s15 "fused" keeps its
        4*phi/c-vs-6*phi/c shift advantage and wins either way.  An
        explicit elision is validated against the registry entry and
        returned unchanged.
        """
        if elision != "auto":
            if elision not in self.alg.elisions:
                raise ValueError(f"{self.alg.name} supports "
                                 f"{self.alg.elisions}, got {elision!r}")
            return elision
        cost_fn = (costmodel.words_fusedmm_cached if session is not None
                   else costmodel.words_fusedmm)

        def words(el):
            cost = cost_fn(
                _COST_NAME[(self.alg.name, el)], p=self.p, c=self.c,
                n=self.n, r=self.r, nnz=self.nnz)
            return cost.words

        return min(self.alg.auto_elisions, key=words)

    # -- the shared-signature executors --------------------------------------
    def sddmm(self, X, Y) -> SparseResult:
        """R = S * (X @ Y.T) sampled at nnz(S); X (m, r), Y (n, r)."""
        return self.alg.sddmm(self, X, Y)

    def spmm(self, Y) -> np.ndarray:
        """out = S @ Y, host-assembled (m, r); Y is (n, r)."""
        return self.alg.spmm(self, Y)

    def fusedmm(self, X, Y, elision: str = "auto",
                session: Optional["Session"] = None):
        """out = (S * (X @ Y.T)) @ Y, host-assembled (m, r).

        Returns (out, SparseResult of the intermediate R).  ``elision``
        must be one of this family's registry-declared cells (or
        "auto"); see the module-level :func:`fusedmm` for the full
        matrix and docs/algorithms.md for the per-cell word counts."""
        el = self.resolve_elision(elision, session)
        return self.alg.fusedmm(self, X, Y, el, session)

    def lower_fusedmm(self, elision: str = "auto"):
        return self.alg.lower_fusedmm(self, self.resolve_elision(elision))


# ---------------------------------------------------------------------------
# Session: across-call replication reuse
# ---------------------------------------------------------------------------

class Session:
    """Caches fiber-replicated dense operands across executor calls.

    Keyed by operand identity (a strong reference pins the id), so the
    stationary factor of an iterative solver hits the cache on every
    iteration while the iterate itself simply misses and is replicated
    fresh — never stale.  Cached and uncached calls are bitwise-identical
    (the kernels consume the same values either way).

    The cache is LRU-bounded: families that gather *both* operands (s15)
    replicate the changing iterate through the session too, and without
    eviction every iterate — host array plus device copy — would stay
    pinned for the session's lifetime.  The stationary operand is hit on
    every call and therefore never ages out.

    In-place mutation of a cached numpy operand (``B *= 0.9``) is
    detected by a content fingerprint (shape/dtype/sum) checked on every
    hit — a mismatch transparently re-replicates.  jax arrays are
    immutable, so identity alone is sound for them."""

    def __init__(self, max_entries: int = 16):
        self._cache = collections.OrderedDict()
        self._max_entries = max_entries

    @staticmethod
    def _fingerprint(arr):
        if isinstance(arr, np.ndarray):
            return (arr.shape, str(arr.dtype),
                    float(arr.sum(dtype=np.float64)))
        return None          # jax arrays are immutable

    def replicate(self, problem: DistProblem, arr, slot: str):
        key = (id(problem.grid), problem.alg.name, slot, id(arr))
        fp = self._fingerprint(arr)
        hit = self._cache.get(key)
        if hit is not None and hit[0] is arr and hit[2] == fp:
            self._cache.move_to_end(key)
            return hit[1]
        rep = problem.alg.replicate(problem, arr, slot)
        self._cache[key] = (arr, rep, fp)
        while len(self._cache) > self._max_entries:
            self._cache.popitem(last=False)
        return rep

    def clear(self):
        self._cache.clear()

    def __len__(self):
        return len(self._cache)


# ---------------------------------------------------------------------------
# Construction + module-level conveniences
# ---------------------------------------------------------------------------

def make_problem(rows, cols, vals, shape: Tuple[int, int], r: int, *,
                 algorithm: str = "auto", c: int | None = None,
                 devices=None, row_tile: int = 32,
                 nz_block: int = 32) -> DistProblem:
    """Build a DistProblem, dispatching the algorithm by the cost model.

    algorithm="auto" ranks every feasible (family, elision, c) by the
    paper's Table-III bandwidth formulas; a family name pins the family
    and picks its best feasible c (or the caller's explicit ``c``).
    """
    m, n = shape
    devices = list(devices) if devices is not None else list(jax.devices())
    p = len(devices)
    families = costmodel.FAMILIES if algorithm == "auto" else (algorithm,)
    if algorithm != "auto" and algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}; registered: "
                         f"{sorted(ALGORITHMS)}")
    choice = costmodel.choose_algorithm(m=m, n=n, nnz=len(vals), r=r, p=p,
                                        c=c, families=families)
    alg = ALGORITHMS[choice.family]
    grid = alg.make_grid(choice.c, devices)
    return DistProblem(alg, grid, np.asarray(rows), np.asarray(cols),
                       np.asarray(vals, np.float32), m, n, r,
                       row_tile=row_tile, nz_block=nz_block)


def sddmm(problem: DistProblem, X, Y) -> SparseResult:
    """Distributed SDDMM: ``R = S * (X @ Y.T)`` sampled at nnz(S).

    Shapes: ``X (m, r)``, ``Y (n, r)`` host arrays (any dtype castable
    to float32); returns a :class:`SparseResult` holding the sampled
    values in the family's home device layout, with ``values()`` /
    ``to_coo()`` / ``to_dense()`` host views.  Every family honors the
    same signature; no family-specific kwargs exist at this level (the
    per-family knobs — ``overlap``, ``pre_gathered`` — live on the
    ``repro.core.<family>`` executors).
    """
    return problem.sddmm(X, Y)


def spmm(problem: DistProblem, Y) -> np.ndarray:
    """Distributed SpMM: ``out = S @ Y``, host-assembled ``(m, r)``.

    ``Y`` is ``(n, r)``; the result is a numpy float32 array regardless
    of the family's on-device layout (slab-stacked for s15, skewed
    chunks for s25, ... — assembly is the registry entry's job).
    """
    return problem.spmm(Y)


def fusedmm(problem: DistProblem, X, Y, elision: str = "auto",
            session: Optional[Session] = None):
    """Distributed FusedMM with *FusedMMA semantics* on every family:

        ``out = (S * (X @ Y.T)) @ Y``

    ``X (m, r)``, ``Y (n, r)`` -> ``(out (m, r) numpy, SparseResult R)``
    where ``R`` is the sampled intermediate.  Families whose
    replication-reuse executor is the FusedMMB form (d15/d25) run it on
    the transpose pack with swapped operands transparently.

    ``elision`` selects the communication-eliding strategy; each family
    honors exactly the cells its registry entry declares
    (docs/algorithms.md matrix):

    =======  ==============================  =========================
    family   elisions                        notes
    =======  ==============================  =========================
    d15      none, reuse, fused              fused = true local fusion
    s15      none, reuse, fused              fused = one-structure-pass
    d25      none, reuse, fused              fused = one-structure-pass
    s25      none, reuse                     fused structurally
                                             impossible
    =======  ==============================  =========================

    ``elision="auto"`` ranks the declared cells by the Table-III word
    counts at the problem's (p, c, phi) — steady-state (cached) counts
    when a ``session`` is passed (docs/choosing.md).  An undeclared
    elision raises ``ValueError``.  ``session`` caches the stationary
    operand's fiber replication across calls, bitwise-identically.
    """
    return problem.fusedmm(X, Y, elision=elision, session=session)


# ---------------------------------------------------------------------------
# Local-kernel routing (repro.kernels.ops)
# ---------------------------------------------------------------------------

class _Router:
    """Routes ops.sddmm/spmm/fusedmm calls on a bound RowTiledCOO pack to
    the active DistProblem.  Only exact pack identity routes; traced
    arguments and mismatched shapes fall through to the local kernels."""

    def __init__(self, problem: DistProblem, pack):
        self.problem, self.pack = problem, pack

    def _traced(self, *arrs) -> bool:
        return any(isinstance(a, jax.core.Tracer) for a in arrs)

    def _sample(self, result: SparseResult):
        """Re-inject a distributed result into the bound pack's slots —
        O(nnz log nnz) coordinate matching, no dense materialization."""
        S = self.pack
        prob = self.problem
        vals_prob = result.values()            # problem COO order
        key = (np.asarray(S.rows_global()).reshape(-1).astype(np.int64)
               * prob.n + np.asarray(S.cols).reshape(-1))
        sk, order = prob.coo_sort()
        idx, ok = _match_coo(sk, order, key)
        out = np.zeros(key.shape[0], np.float32)
        out[ok] = vals_prob[idx[ok]]
        # padding entries point at (tile_base, 0), which may collide with
        # a real nonzero — mask them back to zero
        vals_pack = np.asarray(S.vals)
        out = np.where(vals_pack.reshape(-1) != 0, out, 0.0)
        return S.with_vals(jnp.asarray(out.reshape(vals_pack.shape)))

    def sddmm(self, A, B, S):
        if S is not self.pack or self._traced(A, B, S.vals):
            return NotImplemented
        return self._sample(self.problem.sddmm(np.asarray(A),
                                               np.asarray(B)))

    def spmm(self, S, B, m):
        if S is not self.pack or self._traced(B, S.vals) \
                or m != self.problem.m:
            return NotImplemented
        return jnp.asarray(self.problem.spmm(np.asarray(B)))

    def fusedmm(self, A, B, S, m):
        if S is not self.pack or self._traced(A, B, S.vals) \
                or m != self.problem.m:
            return NotImplemented
        out, r = self.problem.fusedmm(np.asarray(A), np.asarray(B))
        return jnp.asarray(out), self._sample(r)


@contextlib.contextmanager
def activate(problem: DistProblem, local_pack):
    """Route ``repro.kernels.ops`` calls on ``local_pack`` through the
    distributed problem while the context is live (mesh-active mode).

    Calls must be eager (outside jit) to route; traced calls fall through
    to the local Pallas/ref kernels unchanged."""
    from repro.kernels import ops
    prev = ops._DIST_ROUTER
    ops._DIST_ROUTER = _Router(problem, local_pack)
    try:
        yield
    finally:
        ops._DIST_ROUTER = prev
