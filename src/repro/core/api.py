"""Unified distributed-algorithm API: Algorithm registry, DistProblem,
Session (paper §V + §VI-E applications).

The four executor families (``d15``, ``s15``, ``d25``, ``s25``) implement
the same mathematical procedures — SDDMM, SpMM and FusedMM — with four
different communication schedules.  This module puts them behind ONE
abstraction so applications, launch tooling and benchmarks never branch
per family:

* **Algorithm** — registry entry binding a family's planner and its
  sddmm/spmm/fusedmm executors to a shared signature.  All algorithms
  expose *FusedMMA semantics*: ``fusedmm(S, X, Y) = (S * (X @ Y.T)) @ Y``
  with output ``(m, r)``; where a family's replication-reuse executor is
  the FusedMMB form (d15/d25), the registry runs it on the transpose pack
  with swapped operands — ``FusedMMA(S, X, Y) = FusedMMB(S^T, Y, X)`` —
  so the caller-visible contract never changes.  The elision matrix is
  full rank: every entry declares ``reuse`` and (except s25, where it is
  structurally impossible) ``fused``, each cell backed by a Table-III
  word-count row in ``costmodel`` — docs/algorithms.md tabulates the
  grid with per-cell formulas.
* **DistProblem** — owns the host COO of S, the processor grid, and the
  device-placed packs in every orientation the chosen strategies need
  (built lazily, amortized across calls like the paper's preprocessing).
* **Session** — caches *replication state*: the fiber-all-gathered copy of
  a dense operand.  Within one FusedMM call the paper's replication-reuse
  elision shares a single all-gather between the SDDMM and SpMM rounds;
  the Session extends the same elision **across calls** — ALS's CG loop
  calls FusedMM every iteration with the same stationary factor matrix,
  so its gather is paid once per solve instead of once per iteration.
  Cached calls are bitwise-identical to uncached ones: the executors'
  ``pre_gathered`` paths feed the local kernels the very same operand
  values the in-call all-gather would have produced.

Dispatch: ``make_problem(..., algorithm="auto")`` ranks every feasible
(family, elision, c) by the paper's Table-III bandwidth formulas
(:func:`repro.core.costmodel.choose_algorithm`) — low phi = nnz/(n*r)
selects the sparse-shifting/replicating families, high phi the dense ones.

Results come back host-assembled (numpy) so the contract is uniform
across the four families' on-device layouts; the family modules remain
the layout-aware fast path for callers that keep data device-resident.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import time
import weakref
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, d15, d25, s15, s25
from repro.core.grid import make_grid15, make_grid25
from repro.distributed import faults

__all__ = [
    "ALGORITHMS", "Algorithm", "DistProblem", "Session", "SparseResult",
    "make_problem", "sddmm", "spmm", "spmm_t", "fusedmm", "activate",
    "ElasticProblem", "RetryPolicy", "FaultRecoveryError",
    "RETRYABLE_ERRORS", "problem_from_meta", "degrade", "spmm_batched",
]


def _tracer_active():
    """The active obs tracer, or None.

    Function-scoped import by design (lint rule R1): ``repro.core`` is
    the foundation layer and must stay importable without the obs
    stack; resolving through ``sys.modules`` per call also keeps the
    tests' module-level monkeypatching visible."""
    from repro.obs import tracer as obs_tracer
    return obs_tracer.active()


def _metrics_active():
    """The active obs metrics registry, or None (lazy — see above)."""
    from repro.obs import metrics as obs_metrics
    return obs_metrics.active()


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

def _match_coo(sorted_keys, order, keys):
    """Locate query coordinate keys (r*n + c) in a problem's COO.

    ``(sorted_keys, order)`` come from :meth:`DistProblem.coo_sort`
    (computed once per problem — the coordinates never change).  Returns
    (positions, ok): for each query key, a position into the problem's
    COO order and a mask of keys that actually occur there.  O(q log nnz)
    per call; never materializes a dense matrix.
    """
    if len(order) == 0:
        return (np.zeros(len(keys), np.int64),
                np.zeros(len(keys), bool))
    pos = np.minimum(np.searchsorted(sorted_keys, keys), len(order) - 1)
    idx = order[pos]
    return idx, sorted_keys[pos] == keys

@dataclasses.dataclass
class SparseResult:
    """Sampled (SDDMM-shaped) output in its family's home layout.

    ``raw`` keeps the device-side values exactly as the executor returned
    them (per-phase tuples for d15, fiber-sharded shards for s25, ...);
    ``_triples`` assembles the flat global COO view — O(nnz), never a
    dense matrix — from which ``values``/``to_dense`` derive.
    """
    problem: "DistProblem"
    raw: object
    _triples: Callable[[], tuple]
    _coo: Optional[tuple] = None
    _vals: Optional[np.ndarray] = None

    def to_coo(self):
        """Flat global (rows, cols, vals), padding filtered."""
        if self._coo is None:
            self._coo = self._triples()
        return self._coo

    def to_dense(self) -> np.ndarray:
        """Dense (m, n) matrix with the sampled values scattered in.

        Quadratic in the matrix dimensions — small/debug problems only;
        prefer ``values``/``to_coo`` on production shapes.
        """
        r, c, v = self.to_coo()
        out = np.zeros((self.problem.m, self.problem.n), np.float64)
        np.add.at(out, (r, c), v)
        return out.astype(np.float32)

    def values(self) -> np.ndarray:
        """Values aligned with the problem's host COO (rows, cols) order.

        O(nnz log nnz): the assembled triples are matched to the
        problem's coordinate keys — no dense materialization.
        """
        if self._vals is None:
            prob = self.problem
            r, c, v = self.to_coo()
            sk, order = prob.coo_sort()
            idx, ok = _match_coo(sk, order, r * prob.n + c)
            out = np.zeros(prob.nnz, np.float64)
            np.add.at(out, idx[ok], v[ok])
            self._vals = out.astype(np.float32)
        return self._vals


# ---------------------------------------------------------------------------
# Algorithm registry
# ---------------------------------------------------------------------------

ALGORITHMS: Dict[str, "Algorithm"] = {}


class Algorithm:
    """Registry entry: one distributed algorithm family behind the shared
    plan/sddmm/spmm/fusedmm signature.  Subclasses adapt layouts only —
    the executors live in their family modules."""

    name: str = ""
    elisions: Tuple[str, ...] = ()       # strategies fusedmm accepts
    auto_elisions: Tuple[str, ...] = ()  # candidates for elision="auto"
    #: the family schedule module (d15/s15/d25/s25) — set per subclass;
    #: typed Any because each module exposes the schedule_* contract
    #: structurally, not through a shared base.
    _sched_mod: Any = None

    # -- grid / feasibility --------------------------------------------------
    def make_grid(self, c: int, devices):
        raise NotImplementedError

    def make_plan(self, prob, orient: str):
        """Build this family's pack for one orientation (host, amortized)."""
        raise NotImplementedError

    def feasible(self, *, m: int, n: int, r: int, p: int, c: int) -> bool:
        return costmodel.family_feasible(self.name, m=m, n=n, r=r, p=p, c=c)

    def min_r_multiple(self, grid) -> int:
        """Smallest multiple the dense operand width r must obey."""
        return 1

    def schedule_events(self, prob, op: str, elision: str = "none"):
        """This family's ordered (point, phase) fault boundaries for one
        ``op`` round — the coordinates ``repro.distributed.faults``
        scripts failures at (each family module exports its own)."""
        return self._sched_mod.schedule_events(prob.grid, op, elision)

    def schedule_words(self, prob, op: str, elision: str = "none",
                       session: Optional["Session"] = None):
        """Modeled per-device wire words for each schedule event.

        Returns ``(point, phase, kind, words)`` tuples aligned 1:1 with
        :meth:`schedule_events` — the live cost-model side of the obs
        tracer (``repro.obs``).  The formulas are impl-exact for dense
        wire formats (including XLA's dead-code elimination of unread
        cycle-closing shifts); ``session`` models the pre-gathered
        (replay) program the executors compile when one is passed.
        Returns None for support-pruned (``comm="sparse"``) packs, whose
        volume is data-dependent — drift is undefined there."""
        plan, pre = self._words_plan(prob, op, elision, session)
        if plan.smeta is not None:
            return None
        return self._sched_mod.schedule_words(prob.grid, plan, op,
                                              elision=elision,
                                              pre_gathered=pre)

    def _words_plan(self, prob, op, elision, session):
        """(plan, pre_gathered) mirroring this family's ``_*_call``
        orientation and Session behavior for one op."""
        raise NotImplementedError

    # -- layouts -------------------------------------------------------------
    def shard_x(self, prob, X):
        """Place an (m, r) operand in this family's X input layout."""
        raise NotImplementedError

    def shard_y(self, prob, Y):
        """Place an (n, r) operand in this family's Y input layout."""
        raise NotImplementedError

    def replicate(self, prob, arr, slot: str):
        """Place an operand in the fiber-replicated (gathered) layout —
        the across-call replication state a Session caches."""
        raise NotImplementedError

    # -- execution (device in, host out) ------------------------------------
    def sddmm(self, prob, X, Y, session=None) -> SparseResult:
        """R = S * (X Y^T) sampled at nnz(S).  ``session`` serves the
        family's fiber replication of the dense operand(s) from the
        across-call cache (d15/s15/d25; s25 replicates nothing)."""
        fn, args, kwargs, post = self._sddmm_call(prob, X, Y, session)
        return post(fn(*args, **kwargs))

    def _sddmm_call(self, prob, X, Y, session):
        raise NotImplementedError

    def spmm(self, prob, Y, vals=None, session=None) -> np.ndarray:
        """out = S(vals) @ Y.  ``vals`` (host COO order) substitutes the
        sample values via the cached structure pack
        (:meth:`DistProblem.injected_plan`); ``session`` serves the
        dense gather where the family has one (s15 only — the other
        families' SpMM replicates nothing inbound)."""
        fn, args, kwargs, post = self._spmm_call(prob, Y, vals, session)
        return post(fn(*args, **kwargs))

    def _spmm_call(self, prob, Y, vals, session):
        raise NotImplementedError

    def lower_sddmm(self, prob, session: Optional["Session"] = None):
        """Lower the family's jitted SDDMM for HLO/wire-word analysis;
        with a ``session``, the pre-gathered (replay) variant."""
        X = np.zeros((prob.m, prob.r), np.float32)
        Y = np.zeros((prob.n, prob.r), np.float32)
        fn, args, kwargs, _ = self._sddmm_call(prob, X, Y, session)
        return fn.lower(*args, **kwargs)

    def lower_spmm(self, prob, session: Optional["Session"] = None):
        """Lower the family's jitted SpMM for HLO/wire-word analysis."""
        Y = np.zeros((prob.n, prob.r), np.float32)
        fn, args, kwargs, _ = self._spmm_call(prob, Y, None, session)
        return fn.lower(*args, **kwargs)

    def spmm_t(self, prob, A, vals=None, session=None) -> np.ndarray:
        """out = S(vals)^T @ A on the SAME grid — the dual of spmm.

        d15/d25 run their native FusedMMB-style executor on S's
        transpose pack; s15/s25 run spmm on the transposed problem.
        Where the executor all-gathers A, the gather is Session-
        replayable — the backward of a training step reuses the
        forward's replication of A this way (repro.core.grads).
        ``vals`` (problem host-COO order) overrides the pack's sample
        values.
        """
        fn, args, kwargs, post = self._spmm_t_call(prob, A, vals, session)
        return post(fn(*args, **kwargs))

    def _spmm_t_call(self, prob, A, vals, session):
        raise NotImplementedError

    def fusedmm(self, prob, X, Y, elision: str,
                session: Optional["Session"]):
        fn, args, kwargs, post = self._fusedmm_call(prob, X, Y, elision,
                                                    session)
        return post(fn(*args, **kwargs))

    def lower_fusedmm(self, prob, elision: str,
                      session: Optional["Session"] = None):
        """Lower the family's jitted FusedMM for HLO/roofline analysis.

        Passing a ``session`` lowers the Session-replayed variant (the
        pre-gathered program, no in-call fiber all-gather) — what a
        training step's backward dual-FusedMM actually compiles to."""
        X = np.zeros((prob.m, prob.r), np.float32)
        Y = np.zeros((prob.n, prob.r), np.float32)
        fn, args, kwargs, _ = self._fusedmm_call(prob, X, Y, elision,
                                                 session)
        return fn.lower(*args, **kwargs)

    def lower_spmm_t(self, prob, session: Optional["Session"] = None):
        """Lower the jitted SpMM-transpose (the VJP's dual kernel)."""
        A = np.zeros((prob.m, prob.r), np.float32)
        fn, args, kwargs, _ = self._spmm_t_call(prob, A, None, session)
        return fn.lower(*args, **kwargs)

    def _fusedmm_call(self, prob, X, Y, elision, session):
        raise NotImplementedError


def register(cls):
    alg = cls()
    ALGORITHMS[alg.name] = alg
    return cls


def _put(arr, sharding):
    return jax.device_put(jnp.asarray(np.asarray(arr, np.float32)),
                          sharding)


# ---------------------------------------------------------------------------
# 1.5D dense shifting
# ---------------------------------------------------------------------------

@register
class _D15(Algorithm):
    name = "d15"
    elisions = ("none", "reuse", "fused")
    auto_elisions = ("none", "reuse", "fused")
    _sched_mod = d15

    def make_grid(self, c, devices):
        return make_grid15(c, devices=devices)

    def make_plan(self, prob, orient):
        kw = dict(row_tile=prob.row_tile, nz_block=prob.nz_block,
                  comm=prob.comm, compress=prob.compress)
        if orient == "normal":
            return d15.plan_d15(prob.grid, prob.rows, prob.cols, prob.vals,
                                prob.m, prob.n, prob.r, **kw)
        return d15.plan_d15(prob.grid, prob.cols, prob.rows, prob.vals,
                            prob.n, prob.m, prob.r, transpose=True, **kw)

    def shard_x(self, prob, X):
        g = prob.grid
        return _put(X, g.sharding((g.layer, g.fiber)))

    shard_y = shard_x   # same layout, different row count

    def replicate(self, prob, arr, slot):
        g = prob.grid
        return _put(arr, g.sharding(g.layer))

    def _words_plan(self, prob, op, elision, session):
        pre = session is not None
        if op == "spmm":
            return prob.plan("normal"), False   # nothing inbound replicated
        if op == "spmm_t":
            return prob.transposed().plan("transpose"), pre
        if op == "fusedmm" and elision == "reuse":
            return prob.plan("transpose"), pre
        return prob.plan("normal"), pre

    def _sddmm_call(self, prob, X, Y, session):
        plan = prob.plan("normal")
        if session is not None:
            a, pre = session.replicate(prob, X, "x"), True
        else:
            a, pre = self.shard_x(prob, X), False

        def post(rv):
            return SparseResult(prob, rv,
                                lambda: plan.meta.block_meta.to_triples(
                                    plan.rows_local, plan.cols, rv,
                                    plan.tile_base))

        return (d15.sddmm_d15, (prob.grid, plan, a, self.shard_y(prob, Y)),
                dict(pre_gathered=pre), post)

    def _spmm_call(self, prob, Y, vals, session):
        # B shifts and the output reduce-scatters: nothing inbound is
        # replicated, so there is no gather for a session to serve
        plan = prob.injected_plan("normal", vals)
        return (d15.spmma_d15, (prob.grid, plan, self.shard_y(prob, Y)),
                {}, np.asarray)

    def _spmm_t_call(self, prob, A, vals, session):
        # native FusedMMB-half: spmmb on S's transpose pack — which is
        # the TRANSPOSED problem's "transpose" orientation (this
        # problem's own "transpose" plan packs (S^T)^T for the reuse
        # cell).  The AG of A is Session-replayable (pre_gathered),
        # unlike a transposed spmma whose output reduce-scatter could
        # never be elided.
        plan = prob.transposed().injected_plan("transpose", vals)
        if session is not None:
            a, pre = session.replicate(prob, A, "x"), True
        else:
            a, pre = self.shard_x(prob, A), False
        return (d15.spmmb_d15, (prob.grid, plan, a),
                dict(pre_gathered=pre), np.asarray)

    def _fusedmm_call(self, prob, X, Y, elision, session):
        grid = prob.grid
        if elision == "reuse":
            # FusedMMA(S, X, Y) = FusedMMB(S^T, Y, X): Y takes the
            # replicated slot, X the shifting slot, on the S^T pack.
            plan = prob.plan("transpose")
            a_host, slot = Y, "y"
            b = self.shard_x(prob, X)
        else:
            plan = prob.plan("normal")
            a_host, slot = X, "x"
            b = self.shard_y(prob, Y)
        if session is not None:
            a, pre = session.replicate(prob, a_host, slot), True
        else:
            a, pre = (self.shard_x if slot == "x" else self.shard_y)(
                prob, a_host), False

        def post(res):
            out, rvals = res
            return np.asarray(out), SparseResult(
                prob, rvals, lambda: plan.meta.block_meta.to_triples(
                    plan.rows_local, plan.cols, rvals, plan.tile_base))

        return (d15.fusedmm_d15, (grid, plan, a, b),
                dict(elision=elision, pre_gathered=pre), post)


# ---------------------------------------------------------------------------
# 1.5D sparse shifting
# ---------------------------------------------------------------------------

@register
class _S15(Algorithm):
    name = "s15"
    elisions = ("none", "reuse", "fused")
    auto_elisions = ("fused", "reuse", "none")
    _sched_mod = s15

    def make_grid(self, c, devices):
        return make_grid15(c, devices=devices)

    def make_plan(self, prob, orient):
        assert orient == "normal", "s15 keeps S stationary-by-row"
        return s15.plan_s15(prob.grid, prob.rows, prob.cols, prob.vals,
                            prob.m, prob.n, prob.r,
                            row_tile=prob.row_tile, nz_block=prob.nz_block,
                            comm=prob.comm, compress=prob.compress)

    def min_r_multiple(self, grid):
        return grid.p

    def shard_x(self, prob, X):
        g = prob.grid
        return _put(X, g.sharding(None, (g.layer, g.fiber)))

    shard_y = shard_x

    def replicate(self, prob, arr, slot):
        g = prob.grid
        return _put(arr, g.sharding(None, g.layer))

    def _rvals_triples(self, prob, plan, rv):
        return lambda: plan.meta.block_meta.to_triples(
            plan.rows_local, plan.cols, np.asarray(rv), plan.tile_base)

    def _words_plan(self, prob, op, elision, session):
        pre = session is not None
        if op == "spmm_t":
            # the A gather lands in the single-gather (B) slot of the
            # transposed problem's plan, same as _spmm_t_call
            return prob.transposed().plan("normal"), (False, pre)
        if op == "spmm":
            return prob.plan("normal"), (False, pre)
        return prob.plan("normal"), (pre, pre)

    def _sddmm_call(self, prob, X, Y, session):
        plan = prob.plan("normal")
        if session is not None:
            a = session.replicate(prob, X, "x")
            b = session.replicate(prob, Y, "y")
            pre = (True, True)
        else:
            a, b = self.shard_x(prob, X), self.shard_y(prob, Y)
            pre = (False, False)

        def post(rv):
            return SparseResult(prob, rv,
                                self._rvals_triples(prob, plan, rv))

        return (s15.sddmm_s15, (prob.grid, plan, a, b),
                dict(pre_gathered=pre), post)

    def _spmm_call(self, prob, Y, vals, session):
        plan = prob.injected_plan("normal", vals)
        if session is not None:
            b, pre = session.replicate(prob, Y, "y"), True
        else:
            b, pre = self.shard_y(prob, Y), False
        return (s15.spmma_s15, (prob.grid, plan, b),
                dict(pre_gathered=pre),
                lambda slabs: s15.assemble_spmm_out(prob.grid, plan, slabs))

    def _spmm_t_call(self, prob, A, vals, session):
        # S stays stationary-by-row, so the transpose runs on the S^T
        # problem (same grid); the column-slab gather of A is Session-
        # replayable — same layout the forward replicated A in.
        tp = prob.transposed()
        plan = tp.injected_plan("normal", vals)
        if session is not None:
            a, pre = session.replicate(tp, A, "x"), True
        else:
            a, pre = self.shard_x(tp, A), False
        return (s15.spmma_s15, (tp.grid, plan, a), dict(pre_gathered=pre),
                lambda slabs: s15.assemble_spmm_out(tp.grid, plan, slabs))

    def _fusedmm_call(self, prob, X, Y, elision, session):
        grid = prob.grid
        plan = prob.plan("normal")
        if session is not None:
            a = session.replicate(prob, X, "x")
            b = session.replicate(prob, Y, "y")
            pre = (True, True)
        else:
            a, b = self.shard_x(prob, X), self.shard_y(prob, Y)
            pre = (False, False)

        def post(res):
            slabs, rvals = res
            return (s15.assemble_spmm_out(grid, plan, slabs),
                    SparseResult(prob, rvals,
                                 self._rvals_triples(prob, plan, rvals)))

        return (s15.fusedmm_s15, (grid, plan, a, b),
                dict(elision=elision, pre_gathered=pre), post)


# ---------------------------------------------------------------------------
# 2.5D dense replicating
# ---------------------------------------------------------------------------

@register
class _D25(Algorithm):
    name = "d25"
    elisions = ("none", "reuse", "fused")
    auto_elisions = ("fused", "reuse", "none")
    _sched_mod = d25

    def make_grid(self, c, devices):
        return make_grid25(c, devices=devices)

    def make_plan(self, prob, orient):
        kw = dict(row_tile=prob.row_tile, nz_block=prob.nz_block,
                  comm=prob.comm, compress=prob.compress)
        if orient == "normal":
            return d25.plan_d25(prob.grid, prob.rows, prob.cols, prob.vals,
                                prob.m, prob.n, prob.r, **kw)
        return d25.plan_d25(prob.grid, prob.cols, prob.rows, prob.vals,
                            prob.n, prob.m, prob.r, transpose=True, **kw)

    def min_r_multiple(self, grid):
        return grid.G

    def shard_x(self, prob, X):
        # the replicated-slot layout; the shifting operand is skewed via
        # d25.skew_b at the call sites below
        g = prob.grid
        return _put(X, g.sharding((g.row, g.fiber), g.col))

    def replicate(self, prob, arr, slot):
        g = prob.grid
        return _put(arr, g.sharding(g.row, g.col))

    def _words_plan(self, prob, op, elision, session):
        pre = session is not None
        if op == "spmm":
            return prob.plan("normal"), False   # Cannon-shifts, no gather
        if op == "spmm_t":
            return prob.transposed().plan("transpose"), pre
        if op == "fusedmm" and elision == "reuse":
            return prob.plan("transpose"), pre
        return prob.plan("normal"), pre

    def _sddmm_call(self, prob, X, Y, session):
        plan = prob.plan("normal")
        if session is not None:
            a, pre = session.replicate(prob, X, "x"), True
        else:
            a, pre = self.shard_x(prob, X), False

        def post(rv):
            return SparseResult(prob, rv,
                                lambda: plan.meta.block_meta.to_triples(
                                    plan.rows_local, plan.cols,
                                    np.asarray(rv), plan.tile_base))

        return (d25.sddmm_d25,
                (prob.grid, plan, a,
                 d25.skew_b(prob.grid, np.asarray(Y, np.float32))),
                dict(pre_gathered=pre), post)

    def _spmm_call(self, prob, Y, vals, session):
        # B Cannon-shifts and the output reduce-scatters: no inbound
        # replication for a session to serve
        plan = prob.injected_plan("normal", vals)
        return (d25.spmma_d25,
                (prob.grid, plan,
                 d25.skew_b(prob.grid, np.asarray(Y, np.float32))),
                {}, np.asarray)

    def _spmm_t_call(self, prob, A, vals, session):
        # native FusedMMB-half on the Cannon grid (see _D15._spmm_t_call
        # for why the transposed problem's "transpose" orientation is
        # S's own transpose pack)
        plan = prob.transposed().injected_plan("transpose", vals)
        if session is not None:
            a, pre = session.replicate(prob, A, "x"), True
        else:
            a, pre = self.shard_x(prob, A), False
        return (d25.spmmb_d25, (prob.grid, plan, a),
                dict(pre_gathered=pre),
                lambda out: d25.unskew_out(prob.grid, plan, out))

    def _fusedmm_call(self, prob, X, Y, elision, session):
        grid = prob.grid
        if elision == "reuse":
            plan = prob.plan("transpose")
            a_host, slot = Y, "y"
            b = d25.skew_b(grid, np.asarray(X, np.float32))
        else:
            plan = prob.plan("normal")
            a_host, slot = X, "x"
            b = d25.skew_b(grid, np.asarray(Y, np.float32))
        if session is not None:
            a, pre = session.replicate(prob, a_host, slot), True
        else:
            a, pre = self.shard_x(prob, a_host), False

        def post(res):
            out, rvals = res
            triples = lambda: plan.meta.block_meta.to_triples(  # noqa: E731
                plan.rows_local, plan.cols, np.asarray(rvals),
                plan.tile_base)
            if elision == "reuse":
                return (d25.unskew_out(grid, plan, out),
                        SparseResult(prob, rvals, triples))
            return np.asarray(out), SparseResult(prob, rvals, triples)

        return (d25.fusedmm_d25, (grid, plan, a, b),
                dict(elision=elision, pre_gathered=pre), post)


# ---------------------------------------------------------------------------
# 2.5D sparse replicating
# ---------------------------------------------------------------------------

@register
class _S25(Algorithm):
    name = "s25"
    # "fused" is structurally impossible here (docs/algorithms.md): the
    # cross-fiber partial-sum reduction separates the SDDMM and SpMM
    # halves, and the stationary S ships no structure to elide.
    elisions = ("none", "reuse")
    auto_elisions = ("reuse", "none")
    _sched_mod = s25

    def make_grid(self, c, devices):
        return make_grid25(c, devices=devices)

    def make_plan(self, prob, orient):
        assert orient == "normal", "s25 replicates the structure"
        return s25.plan_s25(prob.grid, prob.rows, prob.cols, prob.vals,
                            prob.m, prob.n, prob.r,
                            row_tile=prob.row_tile, nz_block=prob.nz_block,
                            comm=prob.comm, compress=prob.compress)

    def min_r_multiple(self, grid):
        return grid.G * grid.c

    def shard_x(self, prob, X):
        return s25.skew_dense(prob.grid, np.asarray(X, np.float32),
                              along="row")

    def shard_y(self, prob, Y):
        return s25.skew_dense(prob.grid, np.asarray(Y, np.float32),
                              along="col")

    # nothing dense is replicated: Session caching is a no-op here
    def replicate(self, prob, arr, slot):
        return self.shard_x(prob, arr) if slot == "x" \
            else self.shard_y(prob, arr)

    def _rvals_triples(self, prob, plan, rv):
        def triples():
            g = prob.grid
            G, nb = g.G, plan.rows_local.shape[3]
            full = np.asarray(rv).reshape(G, G, nb, np.asarray(rv).shape[-1])
            return plan.meta.block_meta.to_triples(
                np.asarray(plan.rows_local)[:, :, 0],
                np.asarray(plan.cols)[:, :, 0], full,
                np.asarray(plan.tile_base)[:, :, 0])
        return triples

    def _words_plan(self, prob, op, elision, session):
        del elision, session            # Session-inert, values-only fiber
        if op == "spmm_t":
            return prob.transposed().plan("normal"), False
        return prob.plan("normal"), False

    def _sddmm_call(self, prob, X, Y, session):
        # nothing dense is replicated: session accepted and ignored
        plan = prob.plan("normal")

        def post(rv):
            return SparseResult(prob, rv,
                                self._rvals_triples(prob, plan, rv))

        return (s25.sddmm_s25,
                (prob.grid, plan, self.shard_x(prob, X),
                 self.shard_y(prob, Y)), {}, post)

    def _spmm_call(self, prob, Y, vals, session):
        plan = prob.injected_plan("normal", vals)
        return (s25.spmma_s25, (prob.grid, plan, self.shard_y(prob, Y)),
                {}, lambda out: s25.unskew_out(prob.grid, plan, out))

    def _spmm_t_call(self, prob, A, vals, session):
        # spmm on the transposed problem (structure re-replicated on the
        # same grid); nothing dense is replicated, so there is no gather
        # for a Session to replay — session is accepted and ignored.
        tp = prob.transposed()
        plan = tp.injected_plan("normal", vals)
        return (s25.spmma_s25, (tp.grid, plan, self.shard_y(tp, A)), {},
                lambda out: s25.unskew_out(tp.grid, plan, out))

    def _fusedmm_call(self, prob, X, Y, elision, session):
        grid = prob.grid
        plan = prob.plan("normal")
        a, b = self.shard_x(prob, X), self.shard_y(prob, Y)

        def post(res):
            out, rvals = res
            return (s25.unskew_out(grid, plan, out),
                    SparseResult(prob, rvals,
                                 self._rvals_triples(prob, plan, rvals)))

        return (s25.fusedmm_s25, (grid, plan, a, b),
                dict(elision=elision), post)


# ---------------------------------------------------------------------------
# DistProblem
# ---------------------------------------------------------------------------

_COST_NAME = costmodel.ELISION_COST_NAME


@dataclasses.dataclass
class DistProblem:
    """A packed sparse matrix + dense layouts bound to one algorithm/grid.

    Plans (the amortized host-side packing of S, and of S^T where a
    strategy needs it) are built lazily per orientation and cached, so
    repeated kernel calls — ALS's CG loop, GAT's per-layer sweeps — pay
    planning once, exactly like the paper's preprocessing."""
    alg: Algorithm
    #: the family grid (Grid15/Grid25) — structural (``.p``/``.L``/
    #: ``.G`` reads), no shared base class
    grid: Any
    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    m: int
    n: int
    r: int
    row_tile: int = 32
    nz_block: int = 32
    #: wire format for the dense-operand movements: "dense" ships full
    #: fibers/chunks, "sparse" support-prunes each channel at plan time
    #: (crossover-guarded per channel; bitwise-identical results either
    #: way).  Resolved from "auto" in :func:`make_problem`.
    comm: str = "dense"
    #: optional payload compression for the PRUNED sends ("bf16" or
    #: None); dense-mode channels ignore it.
    compress: Optional[str] = None
    _plans: dict = dataclasses.field(default_factory=dict)
    _derived_r: dict = dataclasses.field(default_factory=dict)
    _posmaps: dict = dataclasses.field(default_factory=dict)
    _coo_sort: Optional[tuple] = None
    _ones: Optional["DistProblem"] = None
    _transposed: Optional["DistProblem"] = None

    # -- metadata ------------------------------------------------------------
    @property
    def nnz(self) -> int:
        return int(len(self.vals))

    @property
    def phi(self) -> float:
        return self.nnz / (self.n * self.r)

    @property
    def p(self) -> int:
        return self.grid.p

    @property
    def c(self) -> int:
        return self.grid.c

    # -- planning ------------------------------------------------------------
    def plan(self, orient: str = "normal"):
        if orient not in self._plans:
            self._plans[orient] = self.alg.make_plan(self, orient)
        return self._plans[orient]

    def _posmap(self, orient: str):
        """Pack-slot -> host-COO-position map for one orientation.

        Built once per orientation by planning a position-coded copy of
        the problem (entry i carries value i+1; padding slots stay 0) —
        packing is deterministic in the coordinates, so the map is valid
        for ANY value vector on this structure."""
        if orient not in self._posmaps:
            posvals = np.arange(1, self.nnz + 1, dtype=np.float32)
            # packing is deterministic in the coordinates and identical
            # across comm modes, so the position plan skips the (pure
            # overhead here) support-set construction
            tmp = dataclasses.replace(
                self, vals=posvals, comm="dense", compress=None,
                _plans={}, _posmaps={},
                _derived_r={}, _ones=None, _transposed=None)
            pv = self.alg.make_plan(tmp, orient).vals

            def to_idx(a):
                return np.asarray(a).astype(np.int64)

            self._posmaps[orient] = (tuple(to_idx(a) for a in pv)
                                     if isinstance(pv, tuple) else
                                     to_idx(pv))
        return self._posmaps[orient]

    def injected_plan(self, orient: str, vals=None):
        """This orientation's plan with ``vals`` (host COO order)
        substituted into the value slots — the s25 family's "attractive
        property" (only values move between calls, the structure is
        packed once) generalized to every family.  The hot path of the
        backward pass: cotangent-valued sparse operands reuse the cached
        structure pack instead of re-planning per training step.

        Falls back to a full re-pack above 2^24 nonzeros, where float32
        position coding would alias."""
        if vals is None:
            return self.plan(orient)
        vals = np.asarray(vals, np.float32)
        if self.nnz >= (1 << 24):
            return self.with_values(vals).plan(orient)
        base = self.plan(orient)
        pos = self._posmap(orient)
        lookup = np.concatenate([np.zeros(1, np.float32), vals])

        def inject(pos_arr, old_dev):
            return jax.device_put(jnp.asarray(lookup[pos_arr]),
                                  old_dev.sharding)

        if isinstance(base.vals, tuple):
            new_vals = tuple(inject(p, o)
                             for p, o in zip(pos, base.vals))
        else:
            new_vals = inject(pos, base.vals)
        return dataclasses.replace(base, vals=new_vals)

    def coo_sort(self):
        """(sorted coordinate keys, argsort order) — cached; coordinates
        are immutable for a problem's lifetime."""
        if self._coo_sort is None:
            key = self.rows.astype(np.int64) * self.n + self.cols
            order = np.argsort(key, kind="stable")
            self._coo_sort = (key[order], order)
        return self._coo_sort

    # -- derived problems ----------------------------------------------------
    def with_values(self, vals: np.ndarray) -> "DistProblem":
        """Same structure, new sample values (e.g. softmaxed attention).

        Packing is deterministic in the coordinates, so the derived
        problem's blocks line up with this one's.  The derived problem
        re-packs on first use (values are baked into the device packs);
        value-churn-heavy callers that keep ONE problem and vary values
        per call (the backward passes, spmm with ``vals=``) should go
        through :meth:`injected_plan` instead, which reuses this
        problem's cached structure pack."""
        vals = np.asarray(vals, np.float32)
        assert vals.shape == self.rows.shape
        return dataclasses.replace(self, vals=vals, _plans={},
                                   _derived_r={}, _posmaps=self._posmaps,
                                   _ones=None, _transposed=None)

    def ones(self) -> "DistProblem":
        """The unit-valued problem on S's pattern (cached).

        The sampling mask: ``ones().sddmm(X, Y)`` yields the raw dots
        ``<x_i, y_j>`` at nnz(S) — what the backward of a values-
        differentiable SpMM needs (repro.core.grads)."""
        if self._ones is None:
            if bool(np.all(self.vals == 1.0)):
                self._ones = self
            else:
                self._ones = self.with_values(np.ones_like(self.vals))
        return self._ones

    def with_r(self, r: int) -> "DistProblem":
        """Same sparse matrix, different dense-operand width.

        Derived problems are cached by width, so repeated callers (e.g.
        GAT deriving score/aggregation widths once per layer) reuse one
        set of packs instead of re-planning every call."""
        if r == self.r:
            return self
        if r not in self._derived_r:
            mult = self.alg.min_r_multiple(self.grid)
            if r % mult:
                raise ValueError(f"r={r} must be a multiple of {mult} "
                                 f"for {self.alg.name} on this grid")
            self._derived_r[r] = dataclasses.replace(
                self, r=r, _plans={}, _derived_r={}, _posmaps={},
                _ones=None, _transposed=None)
        return self._derived_r[r]

    def with_pattern(self, rows, cols, vals=None, *, m: int | None = None,
                     n: int | None = None) -> "DistProblem":
        """A *different* sparse pattern on the SAME grid and algorithm —
        the serving tick's union-of-patterns entry point (docs/serving.md).

        The derived problem shares this problem's grid **object**, family,
        wire format and tiling knobs, so Session replication state — which
        is keyed by the grid identity plus operand content — carries over:
        the deployed factor matrices' fiber gathers, paid once per
        deployed graph, serve every per-tick query pattern's SDDMM
        directly.  Packs and posmaps are rebuilt lazily for the new
        structure (host-side packing, O(nnz) of the query pattern).
        ``vals=None`` installs unit samples (the SDDMM mask).  The shape
        defaults to this problem's ``(m, n)``; a different shape is
        validated against the family's feasibility rules."""
        m = self.m if m is None else int(m)
        n = self.n if n is None else int(n)
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        if rows.ndim != 1 or rows.shape != cols.shape:
            raise ValueError("pattern rows/cols must be matching 1-D "
                             f"arrays, got {rows.shape} / {cols.shape}")
        if len(rows) == 0:
            raise ValueError("empty query pattern")
        vals = (np.ones(len(rows), np.float32) if vals is None
                else np.asarray(vals, np.float32))
        if vals.shape != rows.shape:
            raise ValueError(f"vals length {vals.shape} != pattern "
                             f"length {rows.shape}")
        if (int(rows.min()) < 0 or int(rows.max()) >= m
                or int(cols.min()) < 0 or int(cols.max()) >= n):
            raise ValueError(f"pattern coordinates outside ({m}, {n})")
        if (m, n) != (self.m, self.n) and not self.alg.feasible(
                m=m, n=n, r=self.r, p=self.p, c=self.c):
            raise ValueError(f"{self.alg.name} infeasible for pattern "
                             f"shape ({m}, {n}) on this grid")
        return dataclasses.replace(
            self, rows=rows, cols=cols, vals=vals, m=m, n=n,
            _plans={}, _derived_r={}, _posmaps={}, _coo_sort=None,
            _ones=None, _transposed=None)

    def spmm_batched(self, Ys, vals=None,
                     session: Optional["Session"] = None,
                     pad_to: int | None = None) -> List[np.ndarray]:
        """One SpMM round over column-concatenated right-hand sides.

        ``Ys`` is a sequence of ``(n, r_i)`` host arrays.  They are
        concatenated along columns, zero-padded up to the smallest
        feasible width (the summed widths rounded up to the family's
        r-multiple — or ``pad_to``, a caller-supplied bucket that bounds
        the set of compiled widths a long-running server accumulates),
        executed as ONE :meth:`spmm` at that width on the width-derived
        problem, and split back per request.  An SpMM's output columns
        are independent — ``out[:, j]`` consumes only ``Y[:, j]``, the
        nonzero accumulation order never depends on the dense width, and
        padding columns are zero and dropped — so the batched round is
        **bitwise-identical** to running each RHS alone (the serving
        batcher's parity contract, docs/serving.md).  ``vals`` /
        ``session`` exactly as for :meth:`spmm`."""
        Ys = [np.asarray(Y, np.float32) for Y in Ys]
        if not Ys:
            return []
        for Y in Ys:
            if Y.ndim != 2 or Y.shape[0] != self.n:
                raise ValueError(f"every RHS must be (n={self.n}, r_i), "
                                 f"got {Y.shape}")
        widths = [Y.shape[1] for Y in Ys]
        mult = self.alg.min_r_multiple(self.grid)
        r_tot = -(-max(sum(widths), 1) // mult) * mult
        if pad_to is not None:
            if pad_to < r_tot or pad_to % mult:
                raise ValueError(f"pad_to={pad_to} must be a multiple of "
                                 f"{mult} and >= {r_tot}")
            r_tot = pad_to
        cat = np.zeros((self.n, r_tot), np.float32)
        off = 0
        for Y, w in zip(Ys, widths):
            cat[:, off:off + w] = Y
            off += w
        prob = self if r_tot == self.r else self.with_r(r_tot)
        out = prob.spmm(cat, vals=vals, session=session)
        outs, off = [], 0
        for w in widths:
            outs.append(out[:, off:off + w])
            off += w
        return outs

    def transposed(self) -> "DistProblem":
        """The S^T problem on the same grid (for SpMMB-style updates).

        Cached: the backward pass hits this every training step, and the
        structure never changes — combined with :meth:`injected_plan`,
        the transpose pack is planned exactly once per problem."""
        if self._transposed is None:
            if not self.alg.feasible(m=self.n, n=self.m, r=self.r,
                                     p=self.p, c=self.c):
                raise ValueError(f"{self.alg.name} infeasible for the "
                                 f"transposed shape ({self.n}, {self.m})")
            tp = dataclasses.replace(self, rows=self.cols,
                                     cols=self.rows, m=self.n, n=self.m,
                                     _plans={}, _derived_r={},
                                     _posmaps={}, _coo_sort=None,
                                     _ones=None, _transposed=None)
            tp._transposed = self
            self._transposed = tp
        return self._transposed

    # -- elastic recovery ----------------------------------------------------
    def replan(self, *, devices=None, algorithm: str = "auto",
               c: int | None = None) -> "DistProblem":
        """Re-plan this problem from its host COO onto a (possibly
        different) device set — the elastic-recovery path after device
        loss.  ``algorithm="auto"`` re-runs the Table-III cost-model
        dispatch on the new mesh (family, elision candidates and
        ``optimal_c`` may all change with p); a family name pins it.
        ``devices=None`` re-plans on this problem's own mesh (not the
        process's full device set).  Packs, posmaps and derived problems
        are rebuilt lazily on first use, exactly as for a fresh
        problem."""
        if devices is None:
            devices = list(np.asarray(self.grid.mesh.devices).reshape(-1))
        return make_problem(self.rows, self.cols, self.vals,
                            (self.m, self.n), self.r, algorithm=algorithm,
                            c=c, devices=devices, row_tile=self.row_tile,
                            nz_block=self.nz_block, comm=self.comm,
                            compress=self.compress)

    def coo_digest(self) -> str:
        """Content digest of the host COO (structure + values) — ties a
        checkpoint's pack metadata to the matrix it was planned for."""
        h = hashlib.blake2b(digest_size=16)
        h.update(np.ascontiguousarray(self.rows.astype(np.int64)))
        h.update(np.ascontiguousarray(self.cols.astype(np.int64)))
        h.update(np.ascontiguousarray(self.vals.astype(np.float32)))
        h.update(np.int64([self.m, self.n, self.r]).tobytes())
        return h.hexdigest()

    def meta_dict(self) -> dict:
        """JSON-able Session/pack metadata for distributed checkpoints:
        enough to rebuild an equivalent problem (same mesh -> identical
        family/c/packs; degraded mesh -> cost-model re-dispatch) via
        :func:`problem_from_meta`."""
        return dict(family=self.alg.name, p=self.p, c=self.c, m=self.m,
                    n=self.n, r=self.r, nnz=self.nnz,
                    row_tile=self.row_tile, nz_block=self.nz_block,
                    comm=self.comm, compress=self.compress,
                    coo_digest=self.coo_digest())

    # -- elision resolution --------------------------------------------------
    def resolve_elision(self, elision: str = "auto",
                        session: Optional["Session"] = None) -> str:
        """Resolve ``elision="auto"``: rank this family's candidate
        strategies by their Table-III words at the problem's (p, c, phi).

        Without a Session the per-call :func:`costmodel.words_fusedmm`
        ranks the cells; with one, the *steady-state*
        :func:`costmodel.words_fusedmm_cached` does — it credits each
        cell the share of its replication term the Session elides (the
        stationary operand's all-gather, paid once per cache fill
        instead of once per call).  This is why a Session can flip the
        choice: d15's "reuse" drops to its shift words alone and
        overtakes "fused" at large c, while on s15 "fused" keeps its
        4*phi/c-vs-6*phi/c shift advantage and wins either way.  An
        explicit elision is validated against the registry entry and
        returned unchanged.
        """
        if elision != "auto":
            if elision not in self.alg.elisions:
                raise ValueError(f"{self.alg.name} supports "
                                 f"{self.alg.elisions}, got {elision!r}")
            return elision
        cost_fn = (costmodel.words_fusedmm_cached if session is not None
                   else costmodel.words_fusedmm)

        def words(el):
            cost = cost_fn(
                _COST_NAME[(self.alg.name, el)], p=self.p, c=self.c,
                n=self.n, r=self.r, nnz=self.nnz)
            return cost.words

        return min(self.alg.auto_elisions, key=words)

    # -- the shared-signature executors --------------------------------------
    def sddmm(self, X, Y, session: Optional["Session"] = None
              ) -> SparseResult:
        """R = S * (X @ Y.T) sampled at nnz(S); X (m, r), Y (n, r).

        ``session`` serves the dense operands' fiber replication from
        the across-call cache (bitwise-identical; d15/d25 gather X,
        s15 gathers both, s25 nothing)."""
        faults.guard("sddmm", self)
        tr = _tracer_active()
        if tr is None:
            return self.alg.sddmm(self, X, Y, session=session)
        with tr.round(self, "sddmm", session=session):
            return self.alg.sddmm(self, X, Y, session=session)

    def spmm(self, Y, vals=None,
             session: Optional["Session"] = None) -> np.ndarray:
        """out = S(vals) @ Y, host-assembled (m, r); Y is (n, r).

        ``vals`` (host COO order, None -> own values) substitutes the
        sample values through the cached structure pack — O(nnz) value
        injection, no re-planning (:meth:`injected_plan`).  ``session``
        serves s15's column-slab gather of Y; the other families' SpMM
        replicates nothing inbound."""
        faults.guard("spmm", self)
        tr = _tracer_active()
        if tr is None:
            return self.alg.spmm(self, Y, vals=vals, session=session)
        with tr.round(self, "spmm", session=session):
            return self.alg.spmm(self, Y, vals=vals, session=session)

    def spmm_t(self, A, vals=None, session: Optional["Session"] = None
               ) -> np.ndarray:
        """out = S(vals)^T @ A, host-assembled (n, r); A is (m, r).

        ``vals`` (this problem's host-COO order, None -> own values)
        overrides the sample values — the backward of a training step
        runs this with the forward's sampled intermediate as the sparse
        operand (repro.core.grads).  ``session`` replays a cached fiber
        replication of A where the family gathers one (d15/d25/s15)."""
        faults.guard("spmm_t", self)
        if vals is not None:
            vals = np.asarray(vals, np.float32)
        A = np.asarray(A, np.float32)
        tr = _tracer_active()
        if tr is None:
            return self.alg.spmm_t(self, A, vals=vals, session=session)
        with tr.round(self, "spmm_t", session=session):
            return self.alg.spmm_t(self, A, vals=vals, session=session)

    def fusedmm(self, X, Y, elision: str = "auto",
                session: Optional["Session"] = None):
        """out = (S * (X @ Y.T)) @ Y, host-assembled (m, r).

        Returns (out, SparseResult of the intermediate R).  ``elision``
        must be one of this family's registry-declared cells (or
        "auto"); see the module-level :func:`fusedmm` for the full
        matrix and docs/algorithms.md for the per-cell word counts."""
        el = self.resolve_elision(elision, session)
        faults.guard("fusedmm", self, elision=el)
        tr = _tracer_active()
        if tr is None:
            return self.alg.fusedmm(self, X, Y, el, session)
        with tr.round(self, "fusedmm", elision=el, session=session):
            return self.alg.fusedmm(self, X, Y, el, session)

    def lower_fusedmm(self, elision: str = "auto",
                      session: Optional["Session"] = None):
        return self.alg.lower_fusedmm(self, self.resolve_elision(elision),
                                      session=session)

    def lower_spmm_t(self, session: Optional["Session"] = None):
        """Lower the dual SpMM-transpose program (the VJP's Ybar kernel);
        with a ``session``, the pre-gathered (replay) variant."""
        return self.alg.lower_spmm_t(self, session=session)

    def lower_sddmm(self, session: Optional["Session"] = None):
        """Lower the jitted SDDMM program (wire-word measurement)."""
        return self.alg.lower_sddmm(self, session=session)

    def lower_spmm(self, session: Optional["Session"] = None):
        """Lower the jitted SpMM program (wire-word measurement)."""
        return self.alg.lower_spmm(self, session=session)

    def schedule_words(self, op: str, elision: str = "auto",
                       session: Optional["Session"] = None):
        """Modeled per-device wire words for each of :func:`schedule_events`'
        (point, phase) boundaries of one ``op`` round — the live
        cost-model side of ``repro.obs`` span drift.  None for
        support-pruned wire formats (data-dependent volume)."""
        el = (self.resolve_elision(elision, session)
              if op == "fusedmm" else "none")
        return self.alg.schedule_words(self, op, el, session=session)


# ---------------------------------------------------------------------------
# Session: across-call replication reuse
# ---------------------------------------------------------------------------

class Session:
    """Caches fiber-replicated dense operands across executor calls.

    Keyed by operand CONTENT (grid, family, slot, shape, dtype, byte
    digest), so the stationary factor of an iterative solver hits the
    cache on every iteration while the iterate itself misses and is
    replicated fresh — never stale, and in-place mutation of a cached
    numpy operand (``B *= 0.9``) re-replicates automatically.  Content
    keying is what lets a training step's BACKWARD replay the gathers its
    forward performed: the cotangent path hands the executors *new array
    objects* carrying the same stationary operand values (they round-trip
    through jax tracing in ``repro.core.grads``), and identity-based
    keying would miss every one of them.  Cached and uncached calls are
    bitwise-identical (the kernels consume the same values either way).

    The cache is LRU-bounded: families that gather *both* operands (s15)
    replicate the changing iterate through the session too, and without
    eviction every iterate's device copy would stay pinned for the
    session's lifetime.  The stationary operand is hit on every call and
    therefore never ages out."""

    def __init__(self, max_entries: int = 16):
        self._cache = collections.OrderedDict()
        self._id_memo = collections.OrderedDict()
        self._max_entries = max_entries
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _key(problem: "DistProblem", arr, slot: str):
        # comm mode is part of the key: replication state cached for a
        # dense-wire problem is never served to a sparse-wire one (the
        # pre-gathered layouts coincide today, but the key must not bake
        # that implementation detail in)
        a = np.asarray(arr)
        digest = hashlib.blake2b(a.tobytes(), digest_size=16).hexdigest()
        return (id(problem.grid), problem.alg.name, problem.comm, slot,
                a.shape, str(a.dtype), digest)

    @staticmethod
    def _cheap_fp(arr):
        # mutation check for numpy operands on the id fast path; jax
        # arrays are immutable, so identity alone is sound for them
        if isinstance(arr, np.ndarray):
            return (arr.shape, str(arr.dtype),
                    float(arr.sum(dtype=np.float64)))
        return None

    def _content_key(self, problem: "DistProblem", arr, slot: str):
        """Content key with an identity fast path: the iterating caller
        (ALS's CG loop) passes the SAME host array object every call,
        so the full tobytes+digest — a device sync for jax operands —
        is paid once, not per hit; the memo verifies numpy operands by
        a cheap sum fingerprint so in-place mutation still re-keys.
        The memo holds only WEAK references (no operand pinning) and
        evicts LRU per entry; an id is validated by dereferencing the
        weakref, so id recycling after gc cannot alias a dead entry."""
        memo_k = (id(problem.grid), problem.alg.name, problem.comm, slot,
                  id(arr))
        memo = self._id_memo.get(memo_k)
        fp = self._cheap_fp(arr)
        if memo is not None and memo[0]() is arr and memo[2] == fp:
            self._id_memo.move_to_end(memo_k)
            return memo[1]
        key = self._key(problem, arr, slot)
        try:
            ref = weakref.ref(arr)
        except TypeError:
            return key                     # un-weakref-able: no memo
        self._id_memo[memo_k] = (ref, key, fp)
        while len(self._id_memo) > 4 * self._max_entries:
            self._id_memo.popitem(last=False)
        return key

    def replicate(self, problem: "DistProblem", arr, slot: str):
        key = self._content_key(problem, arr, slot)
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self.hits += 1
            return hit
        rep = problem.alg.replicate(problem, arr, slot)
        self._cache[key] = rep
        self.misses += 1
        while len(self._cache) > self._max_entries:
            self._cache.popitem(last=False)
        return rep

    def invalidate(self, problem: "DistProblem") -> int:
        """Drop every cached replication bound to ``problem``'s grid.

        The recovery path after an executor fault: a failed collective
        leaves no trustworthy device state, and after a re-mesh the old
        grid's entries could never be consumed again anyway (keys lead
        with the grid identity).  Returns the number of evicted entries.
        """
        gid = id(problem.grid)
        doomed = [k for k in self._cache if k[0] == gid]
        for k in doomed:
            del self._cache[k]
        for k in [k for k in self._id_memo if k[0] == gid]:
            del self._id_memo[k]
        return len(doomed)

    def stats(self) -> dict:
        """Cache-health counters: ``hits``/``misses`` since construction
        plus current LRU ``entries`` and the ``capacity`` bound — what
        ``bench_dist`` surfaces per training-step row so a mis-keyed
        session (0 hits) is visible in the benchmark artifact."""
        return dict(hits=self.hits, misses=self.misses,
                    entries=len(self._cache),
                    capacity=self._max_entries)

    def clear(self):
        self._cache.clear()
        self._id_memo.clear()

    def __len__(self):
        return len(self._cache)


# ---------------------------------------------------------------------------
# Construction + module-level conveniences
# ---------------------------------------------------------------------------

def make_problem(rows, cols, vals, shape: Tuple[int, int], r: int, *,
                 algorithm: str = "auto", c: int | None = None,
                 devices=None, row_tile: int = 32,
                 nz_block: int = 32, comm: str = "dense",
                 compress: Optional[str] = None) -> DistProblem:
    """Build a DistProblem, dispatching the algorithm by the cost model.

    algorithm="auto" ranks every feasible (family, elision, c) by the
    paper's Table-III bandwidth formulas; a family name pins the family
    and picks its best feasible c (or the caller's explicit ``c``).

    ``comm`` selects the wire format for the dense-operand movements:
    "dense" (the Table-III baseline), "sparse" (support-pruned sends,
    bitwise-identical results), or "auto" — prune when S's row/column
    support density clears :data:`costmodel.SPARSE_CROSSOVER`
    (:func:`costmodel.choose_comm`; docs/choosing.md).  ``compress``
    ("bf16" or None) additionally halves the pruned payloads with
    error-feedback handled by the training loop (lossy — NOT
    bitwise-identical; comm="sparse" alone is exact).
    """
    m, n = shape
    if comm not in ("auto", "dense", "sparse"):
        raise ValueError(f"comm must be 'auto'|'dense'|'sparse', "
                         f"got {comm!r}")
    if compress not in (None, "bf16"):
        raise ValueError(f"compress must be None or 'bf16', "
                         f"got {compress!r}")
    if comm == "auto":
        comm = costmodel.choose_comm(rows, cols, m, n)
    devices = list(devices) if devices is not None else list(jax.devices())
    p = len(devices)
    families = costmodel.FAMILIES if algorithm == "auto" else (algorithm,)
    if algorithm != "auto" and algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}; registered: "
                         f"{sorted(ALGORITHMS)}")
    choice = costmodel.choose_algorithm(m=m, n=n, nnz=len(vals), r=r, p=p,
                                        c=c, families=families)
    alg = ALGORITHMS[choice.family]
    grid = alg.make_grid(choice.c, devices)
    return DistProblem(alg, grid, np.asarray(rows), np.asarray(cols),
                       np.asarray(vals, np.float32), m, n, r,
                       row_tile=row_tile, nz_block=nz_block,
                       comm=comm, compress=compress)


def sddmm(problem: DistProblem, X, Y,
          session: Optional[Session] = None) -> SparseResult:
    """Distributed SDDMM: ``R = S * (X @ Y.T)`` sampled at nnz(S).

    Shapes: ``X (m, r)``, ``Y (n, r)`` host arrays (any dtype castable
    to float32); returns a :class:`SparseResult` holding the sampled
    values in the family's home device layout, with ``values()`` /
    ``to_coo()`` / ``to_dense()`` host views.  Every family honors the
    same signature.  ``session`` serves the operands' fiber replication
    from the across-call cache, bitwise-identically — a training step's
    backward then replays the forward's gathers (repro.core.grads).
    """
    return problem.sddmm(X, Y, session=session)


def spmm(problem: DistProblem, Y, vals=None,
         session: Optional[Session] = None) -> np.ndarray:
    """Distributed SpMM: ``out = S(vals) @ Y``, host-assembled ``(m, r)``.

    ``Y`` is ``(n, r)``; the result is a numpy float32 array regardless
    of the family's on-device layout (slab-stacked for s15, skewed
    chunks for s25, ... — assembly is the registry entry's job).
    ``vals`` (host COO order) substitutes the sample values via O(nnz)
    injection into the cached structure pack; ``session`` serves s15's
    gather of Y (the other families' SpMM replicates nothing inbound).
    """
    return problem.spmm(Y, vals=vals, session=session)


def spmm_t(problem: DistProblem, A, vals=None,
           session: Optional[Session] = None) -> np.ndarray:
    """Distributed SpMM-transpose: ``out = S(vals)^T @ A``, ``(n, r)``.

    The dual of :func:`spmm` on the same grid — d15/d25 run their native
    FusedMMB-style executor on the transpose pack (AG of ``A``
    Session-replayable), s15/s25 run spmm on the transposed problem.
    ``vals`` overrides the sample values in the problem's host COO
    order; this is how every backward pass applies a cotangent-valued
    sparse matrix without re-building a DistProblem by hand
    (:mod:`repro.core.grads`).
    """
    return problem.spmm_t(A, vals=vals, session=session)


def spmm_batched(problem: DistProblem, Ys, vals=None,
                 session: Optional[Session] = None,
                 pad_to: int | None = None) -> List[np.ndarray]:
    """One SpMM round over many right-hand sides — the serving batcher's
    aggregation primitive.  See :meth:`DistProblem.spmm_batched`."""
    return problem.spmm_batched(Ys, vals=vals, session=session,
                                pad_to=pad_to)


def fusedmm(problem: DistProblem, X, Y, elision: str = "auto",
            session: Optional[Session] = None):
    """Distributed FusedMM with *FusedMMA semantics* on every family:

        ``out = (S * (X @ Y.T)) @ Y``

    ``X (m, r)``, ``Y (n, r)`` -> ``(out (m, r) numpy, SparseResult R)``
    where ``R`` is the sampled intermediate.  Families whose
    replication-reuse executor is the FusedMMB form (d15/d25) run it on
    the transpose pack with swapped operands transparently.

    ``elision`` selects the communication-eliding strategy; each family
    honors exactly the cells its registry entry declares
    (docs/algorithms.md matrix):

    =======  ==============================  =========================
    family   elisions                        notes
    =======  ==============================  =========================
    d15      none, reuse, fused              fused = true local fusion
    s15      none, reuse, fused              fused = one-structure-pass
    d25      none, reuse, fused              fused = one-structure-pass
    s25      none, reuse                     fused structurally
                                             impossible
    =======  ==============================  =========================

    ``elision="auto"`` ranks the declared cells by the Table-III word
    counts at the problem's (p, c, phi) — steady-state (cached) counts
    when a ``session`` is passed (docs/choosing.md).  An undeclared
    elision raises ``ValueError``.  ``session`` caches the stationary
    operand's fiber replication across calls, bitwise-identically.
    """
    return problem.fusedmm(X, Y, elision=elision, session=session)


# ---------------------------------------------------------------------------
# Elastic recovery: typed retry, backoff, degrade-and-re-plan
# ---------------------------------------------------------------------------

def _runtime_error_types():
    # the classes a real multi-host jax job raises on device failure;
    # import-guarded so the api layer never hard-depends on jaxlib layout
    out = []
    try:
        from jax.errors import JaxRuntimeError
        out.append(JaxRuntimeError)
    except ImportError:
        pass
    try:
        from jaxlib.xla_extension import XlaRuntimeError
        if XlaRuntimeError not in out:
            out.append(XlaRuntimeError)
    except ImportError:
        pass
    return tuple(out)


#: Errors worth retrying: scripted faults from the injection harness and
#: the runtime's own device-failure surface.  Caller bugs (TypeError,
#: ValueError, ...) are NOT in this set and propagate immediately.
RETRYABLE_ERRORS: Tuple[type, ...] = (
    (faults.TransientFault,) + _runtime_error_types())


class FaultRecoveryError(RuntimeError):
    """Recovery budget exhausted: carries the per-attempt fault history
    so post-mortems see every coordinate that fired."""

    def __init__(self, msg: str, history: Optional[list] = None):
        super().__init__(msg)
        self.history = history or []


@dataclasses.dataclass
class RetryPolicy:
    """Typed retry/backoff policy for the elastic executors.

    Exponential backoff with *deterministic, seedable* jitter: the delay
    sequence is a pure function of ``seed``, so a recovery trace replays
    exactly (and tests inject ``sleep`` to run instantly).  The first
    retry fires after ~``base_delay``; each subsequent delay multiplies
    by ``factor`` and is capped at ``max_delay``; jitter stretches each
    delay by up to ``jitter`` fractionally (decorrelates retry storms
    across ranks without sacrificing replayability — seed by rank)."""
    max_retries: int = 3
    base_delay: float = 0.0          # seconds; 0 disables sleeping
    factor: float = 2.0
    max_delay: float = 2.0
    jitter: float = 0.25
    seed: int = 0
    sleep: Callable[[float], None] = time.sleep

    def delays(self):
        """The policy's full backoff schedule (len == max_retries)."""
        rng = np.random.default_rng(self.seed)
        d = self.base_delay
        for _ in range(self.max_retries):
            yield min(d, self.max_delay) * (1.0 + self.jitter
                                            * float(rng.uniform()))
            d = d * self.factor if d else 0.0


def problem_from_meta(meta: dict, rows, cols, vals, *,
                      devices=None) -> DistProblem:
    """Rebuild a checkpointed problem from its :meth:`DistProblem.meta_dict`.

    The host COO is supplied by the caller (checkpoints store metadata,
    not the matrix) and verified against the saved content digest — a
    mismatched matrix raises ``ValueError`` rather than silently
    producing wrong packs.  On a mesh with the checkpoint's device count
    the saved (family, c) is pinned, so the rebuilt packs are identical;
    on a different (degraded) mesh the cost model re-dispatches
    ``algorithm="auto"``."""
    devices = list(devices) if devices is not None else list(jax.devices())
    prob = make_problem(rows, cols, vals, (meta["m"], meta["n"]),
                        meta["r"],
                        algorithm=(meta["family"]
                                   if len(devices) == meta["p"] else "auto"),
                        c=meta["c"] if len(devices) == meta["p"] else None,
                        devices=devices, row_tile=meta["row_tile"],
                        nz_block=meta["nz_block"],
                        comm=meta.get("comm", "dense"),
                        compress=meta.get("compress"))
    digest = prob.coo_digest()
    if digest != meta["coo_digest"]:
        raise ValueError(
            f"checkpointed problem metadata does not match the supplied "
            f"COO (digest {digest} != saved {meta['coo_digest']}) — "
            f"wrong matrix for this checkpoint")
    return prob


def degrade(problem: DistProblem, lost_rank: Optional[int] = None, *,
            devices=None, algorithm: str = "auto") -> DistProblem:
    """Re-plan ``problem`` onto a degraded mesh after device loss.

    Drops ``lost_rank`` (flat schedule-order index) from the problem's
    device list — or takes an explicit surviving ``devices`` — then
    picks the **largest device count the cost model can dispatch**: the
    planners' divisibility constraints rarely admit p-1 (64x64 blocks
    don't split 7 ways), so the mesh shrinks to the nearest feasible
    size, exactly like a pod losing a slice.  Raises ``ValueError`` with
    the constraint trail if no device count <= the survivors works."""
    if devices is None:
        devs = list(np.asarray(problem.grid.mesh.devices).reshape(-1))
        if lost_rank is not None:
            if not 0 <= lost_rank < len(devs):
                raise ValueError(f"lost_rank {lost_rank} outside the "
                                 f"mesh's {len(devs)} devices")
            devs = devs[:lost_rank] + devs[lost_rank + 1:]
    else:
        devs = list(devices)
    errors = []
    for p_new in range(len(devs), 0, -1):
        try:
            return problem.replan(devices=devs[:p_new],
                                  algorithm=algorithm)
        except ValueError as e:
            errors.append(f"p={p_new}: {e}")
    raise ValueError("no feasible degraded mesh for "
                     f"({problem.m}x{problem.n}, r={problem.r}) on "
                     f"{len(devs)} surviving devices:\n  "
                     + "\n  ".join(errors))


class ElasticProblem:
    """Fault-tolerant facade over a :class:`DistProblem`.

    Mirrors the four executor entrypoints; every call runs under the
    typed retry loop:

    * :class:`repro.distributed.faults.TransientFault` / runtime
      ``XlaRuntimeError`` -> invalidate the Session entries bound to the
      problem's grid (a failed collective leaves no trustworthy
      replication state), back off per :class:`RetryPolicy`, retry the
      round on the same mesh;
    * :class:`repro.distributed.faults.DeviceLost` -> additionally drop
      the lost rank and re-plan the problem from host COO onto the
      largest feasible degraded mesh (:func:`degrade` — cost-model
      re-dispatched), then retry there;
    * anything else (caller bugs) propagates immediately — retrying a
      ``TypeError`` can never succeed.

    Results are host-assembled in problem COO order, so a recovered call
    is **bitwise-identical** to a fault-free one on the same mesh, and
    value-identical after a re-mesh wherever the accumulations are exact
    (docs/robustness.md spells out the guarantee).  ``recoveries``
    records every handled fault; :class:`FaultRecoveryError` (with that
    history) is raised when ``policy.max_retries`` is exhausted.
    """

    def __init__(self, problem: DistProblem,
                 session: Optional[Session] = None,
                 policy: Optional[RetryPolicy] = None):
        self.problem = problem
        self.session = session
        self.policy = policy or RetryPolicy()
        self.recoveries: List[dict] = []

    def _run(self, label: str, fn):
        attempt = 0
        delays = self.policy.delays()
        while True:
            try:
                return fn(self.problem)
            except RETRYABLE_ERRORS as e:
                e = faults.unwrap(e)   # typed fault may be XLA-laundered
                attempt += 1
                rec = dict(op=label, attempt=attempt, error=repr(e),
                           family=self.problem.alg.name,
                           p=self.problem.p,
                           coord=getattr(e, "coord", None))
                self.recoveries.append(rec)
                reg = _metrics_active()
                if reg is not None:
                    reg.inc("elastic.faults", 1, op=label,
                            kind=type(e).__name__)
                    reg.inc("elastic.retries", 1, op=label)
                if self.session is not None:
                    rec["evicted"] = self.session.invalidate(self.problem)
                if attempt > self.policy.max_retries:
                    if reg is not None:
                        reg.inc("elastic.exhausted", 1, op=label)
                    raise FaultRecoveryError(
                        f"{label} failed after {attempt} attempts "
                        f"(budget {self.policy.max_retries}): {e}",
                        history=list(self.recoveries)) from e
                if isinstance(e, faults.DeviceLost):
                    self.problem = degrade(self.problem, e.rank)
                    rec["remeshed_to_p"] = self.problem.p
                    rec["family_after"] = self.problem.alg.name
                    if reg is not None:
                        reg.inc("elastic.degrades", 1, op=label)
                        reg.gauge("elastic.p", self.problem.p)
                delay = next(delays, self.policy.max_delay)
                if delay:
                    self.policy.sleep(delay)

    # -- the shared-signature executors, resiliently -------------------------
    def sddmm(self, X, Y) -> SparseResult:
        return self._run("sddmm",
                         lambda p: p.sddmm(X, Y, session=self.session))

    def spmm(self, Y, vals=None) -> np.ndarray:
        return self._run("spmm", lambda p: p.spmm(Y, vals=vals,
                                                  session=self.session))

    def spmm_t(self, A, vals=None) -> np.ndarray:
        return self._run("spmm_t",
                         lambda p: p.spmm_t(A, vals=vals,
                                            session=self.session))

    def fusedmm(self, X, Y, elision: str = "auto"):
        return self._run("fusedmm",
                         lambda p: p.fusedmm(X, Y, elision=elision,
                                             session=self.session))

    def spmm_batched(self, Ys, vals=None, pad_to: int | None = None):
        return self._run(
            "spmm_batched",
            lambda p: p.spmm_batched(Ys, vals=vals, session=self.session,
                                     pad_to=pad_to))

    # -- derived-problem rounds, resiliently ---------------------------------
    def run_round(self, label: str, fn):
        """Run one serving round under the typed retry loop.

        ``fn(problem)`` receives the CURRENT deployment problem — after a
        ``DeviceLost`` the facade degrades ``self.problem`` onto the
        surviving mesh and calls ``fn`` again with the re-planned
        problem, so ``fn`` must derive any per-round state (a
        :meth:`DistProblem.with_pattern` union problem, a width-derived
        batch problem) from its argument rather than close over a
        pre-fault derivation.  This is the hook the serving engine's
        score ticks use: the union-of-patterns problem is rebuilt on the
        degraded grid each retry, keeping answers bitwise-correct across
        the re-mesh (tests/dist_scripts/check_serving.py)."""
        return self._run(label, fn)


# ---------------------------------------------------------------------------
# Local-kernel routing (repro.kernels.ops)
# ---------------------------------------------------------------------------

class _Router:
    """Routes ops.sddmm/spmm/fusedmm calls on a bound RowTiledCOO pack to
    the active DistProblem.  Only exact pack identity routes; traced
    arguments and mismatched shapes fall through to the local kernels."""

    def __init__(self, problem: DistProblem, pack):
        self.problem, self.pack = problem, pack

    def _traced(self, *arrs) -> bool:
        return any(isinstance(a, jax.core.Tracer) for a in arrs)

    def _sample(self, result: SparseResult):
        """Re-inject a distributed result into the bound pack's slots —
        O(nnz log nnz) coordinate matching, no dense materialization."""
        S = self.pack
        prob = self.problem
        vals_prob = result.values()            # problem COO order
        key = (np.asarray(S.rows_global()).reshape(-1).astype(np.int64)
               * prob.n + np.asarray(S.cols).reshape(-1))
        sk, order = prob.coo_sort()
        idx, ok = _match_coo(sk, order, key)
        out = np.zeros(key.shape[0], np.float32)
        out[ok] = vals_prob[idx[ok]]
        # padding entries point at (tile_base, 0), which may collide with
        # a real nonzero — mask them back to zero
        vals_pack = np.asarray(S.vals)
        out = np.where(vals_pack.reshape(-1) != 0, out, 0.0)
        return S.with_vals(jnp.asarray(out.reshape(vals_pack.shape)))

    def sddmm(self, A, B, S):
        if S is not self.pack or self._traced(A, B, S.vals):
            return NotImplemented
        return self._sample(self.problem.sddmm(np.asarray(A),
                                               np.asarray(B)))

    def spmm(self, S, B, m):
        if S is not self.pack or self._traced(B, S.vals) \
                or m != self.problem.m:
            return NotImplemented
        return jnp.asarray(self.problem.spmm(np.asarray(B)))

    def fusedmm(self, A, B, S, m):
        if S is not self.pack or self._traced(A, B, S.vals) \
                or m != self.problem.m:
            return NotImplemented
        out, r = self.problem.fusedmm(np.asarray(A), np.asarray(B))
        return jnp.asarray(out), self._sample(r)


@contextlib.contextmanager
def activate(problem: DistProblem, local_pack):
    """Route ``repro.kernels.ops`` calls on ``local_pack`` through the
    distributed problem while the context is live (mesh-active mode).

    Calls must be eager (outside jit) to route; traced calls fall through
    to the local Pallas/ref kernels unchanged."""
    from repro.kernels import ops
    prev = ops._DIST_ROUTER
    ops._DIST_ROUTER = _Router(problem, local_pack)
    try:
        yield
    finally:
        ops._DIST_ROUTER = prev
