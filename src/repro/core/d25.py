"""2.5D dense-replicating algorithms (paper Algorithm 2).

Grid: ("row" = G, "col" = G, "fiber" = c) with p = G^2 c.  Each fiber layer
runs a concurrent Cannon pass on its G x G grid: the sparse matrix S shifts
along grid rows, dense matrix B shifts along grid columns, and dense matrix
A is replicated along the fiber (all-gather input / reduce-scatter output).

Blocking (device (x, y, z)):
  A block (i = x*c + z, y):  (m/(Gc), r/G)   -> fiber AG gives T = A[X_x, W_y]
  S block (x, j_t):          (m/G,  n/(Gc))  travels along the row axis
  B block (j_t, y):          (n/(Gc), r/G)   travels along the column axis
with the Cannon alignment j_t = ((x + y + t) mod G)*c + z.  The planner
pre-skews S and B (the paper's "initial shift", done for free at fill time).

SDDMM sample values accumulate inside the traveling S pack (partial dots
over each visited column slice W_y) and are scaled by the original values
once the pack returns home — so only 3 words per nonzero ever move.

Comm/compute overlap (see DESIGN.md): the Cannon loops are Python-unrolled
with a double-buffered carry — the ``ppermute`` of the next phase's S pack
and B block is issued before the local kernel runs on the current ones.
The accumulating buffers (traveling partial dots / FusedMMB output) still
serialize their own small shift behind the kernel that feeds them, but the
dense-block and coordinate shifts all hide behind compute.
``overlap=False`` reproduces the serial schedule (numerically identical).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import common, costmodel
from repro.core.grid import Grid25
from repro.kernels import ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlanD25:
    rows_local: jax.Array   # (G, G, c, nb, k)
    cols: jax.Array
    vals: jax.Array
    tile_base: jax.Array    # (G, G, c, nb)
    m: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    r: int = dataclasses.field(metadata=dict(static=True))
    row_tile: int = dataclasses.field(metadata=dict(static=True))
    transpose: bool = dataclasses.field(metadata=dict(static=True))
    tiling: costmodel.Tiling = dataclasses.field(metadata=dict(static=True))
    meta: object = dataclasses.field(metadata=dict(static=True))
    sup: tuple = ()             # comm="sparse" support index arrays
    smeta: object = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def block_shape(self):
        if self.transpose:
            return (self.meta.nS, self.meta.mS)
        return (self.meta.mS, self.meta.nS)


@dataclasses.dataclass(frozen=True, eq=False)
class MetaD25:
    mS: int    # m/G   (S block rows, T rows)
    nS: int    # n/(Gc) (S block cols, B block rows)
    mA: int    # m/(Gc) (A block rows at rest)
    rW: int    # r/G   (dense column-slice width)
    block_meta: common.BlockMeta


def plan_d25(grid: Grid25, rows, cols, vals, m: int, n: int, r: int, *,
             transpose: bool = False, row_tile: int = 256,
             nz_block: int = 256, group: int = 1, comm: str = "dense",
             compress=None) -> PlanD25:
    """Pack S pre-skewed for the Cannon schedule (host, amortized).

    comm="sparse": device (x, y, z) only ever touches S blocks
    (x, g*c + z) — the fiber all-gather of A needs just the union of
    their (pre-swap) row supports, and the B chunk consumed at phase t
    just the column support of the block resident that phase, so both
    channels ship pruned (docs/algorithms.md "Sparse communication").
    The traveling COO pack, the partial-dot buffer, the traveling
    output chunks and the reduce-scatter stay dense — they carry the
    accumulation order.
    """
    G, c, p = grid.G, grid.c, grid.p
    assert m % (G * c) == 0 and n % (G * c) == 0 and r % G == 0
    mS, nS, mA, rW = m // G, n // (G * c), m // (G * c), r // G
    blk_shape = (nS, mS) if transpose else (mS, nS)
    row_tile = common.choose_row_tile(blk_shape[0], row_tile)

    blocks, row_off, col_off = [], [], []
    for x in range(G):
        for y in range(G):
            for z in range(c):
                j = ((x + y) % G) * c + z          # Cannon pre-skew
                r0, r1 = x * mS, (x + 1) * mS
                c0, c1 = j * nS, (j + 1) * nS
                br, bc, bv = common.extract_block(rows, cols, vals,
                                                  r0, r1, c0, c1)
                if transpose:
                    br, bc = bc, br
                    row_off.append(c0), col_off.append(r0)
                else:
                    row_off.append(r0), col_off.append(c0)
                blocks.append((br, bc, bv))
    rl, cl, vl, tb = common.pack_block_list(blocks, blk_shape, row_tile,
                                            nz_block, group=group)
    tiling = common.plan_tiling(tb, n_b=mS if transpose else nS, r=rW,
                                k=nz_block, row_tile=row_tile)
    sh = grid.sharding("row", "col", "fiber")
    shp = (G, G, c) + rl.shape[1:]
    meta = MetaD25(mS, nS, mA, rW, common.BlockMeta(
        np.array(row_off).reshape(G, G, c),
        np.array(col_off).reshape(G, G, c),
        (n, m) if transpose else (m, n)))
    sup, smeta = ((), None) if comm != "sparse" else _sparse_sup(
        grid, rows, cols, vals, meta, sh, compress)
    return PlanD25(
        jax.device_put(rl.reshape(shp), sh),
        jax.device_put(cl.reshape(shp), sh),
        jax.device_put(vl.reshape(shp), sh),
        jax.device_put(tb.reshape((G, G, c) + tb.shape[1:]), sh),
        m, n, r, row_tile, transpose, tiling, meta, sup, smeta)


def _sparse_sup(grid: Grid25, rows, cols, vals, meta, sh, compress):
    """Pad + align the comm="sparse" support sets into device arrays.

    Supports are in *pre-swap* coordinates — the gathered operand T is
    always indexed by S's row axis ([0, mS)) and the traveling B chunk
    by S's col axis ([0, nS)) — so one support set serves both pack
    orientations.  Gather: per offset d along the fiber, sender z ships
    the slab-local rows of receiver (z+d)%c's union support (which
    depends on (x, z) only).  Shift: phase t's B chunk is shipped
    directly from its home grid-row (x+t)%G, pruned to the column
    support of the block the receiver holds that phase.
    """
    G, c = grid.G, grid.c
    mS, nS, mA = meta.mS, meta.nS, meta.mA
    cross = costmodel.SPARSE_CROSSOVER
    part = common.block_partition(np.asarray(rows), np.asarray(cols),
                                  np.asarray(vals), mS, nS, G * c)
    empty = np.zeros(0, np.int64)
    ub_rows = {k: np.unique(v[0]) for k, v in part.items()}
    ub_cols = {k: np.unique(v[1]) for k, v in part.items()}

    g_send, g_recv, wg, gather = (), (), 0, False
    if c > 1:
        ra = [[np.unique(np.concatenate(
            [ub_rows.get((x, g * c + z), empty) for g in range(G)]))
            for z in range(c)] for x in range(G)]
        send_sets = np.empty((c - 1, G, G, c), object)
        recv_sets = np.empty((c - 1, G, G, c), object)
        w = 1
        for d in range(1, c):
            for x in range(G):
                for y in range(G):
                    for z in range(c):
                        rcv = ra[x][(z + d) % c]
                        send_sets[d - 1, x, y, z] = (
                            rcv[(rcv >= z * mA) & (rcv < (z + 1) * mA)]
                            - z * mA)
                        own = ra[x][z]
                        zs = (z - d) % c
                        recv_sets[d - 1, x, y, z] = \
                            own[(own >= zs * mA) & (own < (zs + 1) * mA)]
                        w = max(w, send_sets[d - 1, x, y, z].size)
        gather = w <= cross * mA
        if gather:
            wg = w
            g_send = tuple(jax.device_put(
                common.pad_sets(send_sets[d], wg, 0), sh)
                for d in range(c - 1))
            g_recv = tuple(jax.device_put(
                common.pad_sets(recv_sets[d], wg, mS), sh)
                for d in range(c - 1))

    s_send, s_recv, ws, shift = (), (), (), False
    if G > 1:
        widths, sends, recvs = [], [], []
        for t in range(1, G):
            ssend = np.empty((G, G, c), object)
            srecv = np.empty((G, G, c), object)
            w = 1
            for x in range(G):
                for y in range(G):
                    for z in range(c):
                        ssend[x, y, z] = ub_cols.get(
                            ((x - t) % G, ((x + y) % G) * c + z), empty)
                        srecv[x, y, z] = ub_cols.get(
                            (x, ((x + y + t) % G) * c + z), empty)
                        w = max(w, srecv[x, y, z].size)
            widths.append(w)
            sends.append(ssend)
            recvs.append(srecv)
        shift = sum(widths) <= cross * (G - 1) * nS
        if shift:
            ws = tuple(widths)
            s_send = tuple(jax.device_put(
                common.pad_sets(sends[i], ws[i], 0), sh)
                for i in range(G - 1))
            s_recv = tuple(jax.device_put(
                common.pad_sets(recvs[i], ws[i], nS), sh)
                for i in range(G - 1))
    sup = (g_send, g_recv, s_send, s_recv)
    return sup, common.SparseMeta(gather=gather, shift=shift, wg=wg, ws=ws,
                                  compress=compress)


def skew_b(grid: Grid25, B: np.ndarray) -> jax.Array:
    """Pre-skew B into its Cannon start position: (G, G, c, n/(Gc), r/G)."""
    G, c = grid.G, grid.c
    n, r = B.shape
    nS, rW = n // (G * c), r // G
    out = np.zeros((G, G, c, nS, rW), B.dtype)
    for x in range(G):
        for y in range(G):
            for z in range(c):
                j = ((x + y) % G) * c + z
                out[x, y, z] = B[j * nS:(j + 1) * nS, y * rW:(y + 1) * rW]
    return jax.device_put(out, grid.sharding("row", "col", "fiber"))


def unskew_out(grid: Grid25, plan: PlanD25, stacked) -> np.ndarray:
    """Invert the skew for B-shaped outputs (FusedMMB): -> (n, r)."""
    G, c = grid.G, grid.c
    nS, rW = plan.meta.nS, plan.meta.rW
    stacked = np.asarray(stacked)
    out = np.zeros((plan.n, plan.r), np.float32)
    for x in range(G):
        for y in range(G):
            for z in range(c):
                j = ((x + y) % G) * c + z
                out[j * nS:(j + 1) * nS, y * rW:(y + 1) * rW] = \
                    stacked[x, y, z]
    return out


def _coo(plan, rl, cl, vl, tb):
    return common.coo_of(rl, cl, vl, tb, plan.block_shape, plan.row_tile)


def _shift_back(x, axis_name, size):
    """Move the buffer at position i to position i-1 (Cannon advance)."""
    return jax.lax.ppermute(x, axis_name,
                            [(i, (i - 1) % size) for i in range(size)])


def _exec(grid: Grid25, plan: PlanD25, body, A, B_sk, out_specs,
          a_spec=None):
    """``a_spec`` overrides the replicated-operand spec — the pre-gathered
    (Session-cached) paths pass ``P(row, col)``: rows split over the grid
    row axis only, replicated along the fiber."""
    mesh = grid.mesh
    rw, cl_ax, fib = grid.row, grid.col, grid.fiber
    s_spec = P(rw, cl_ax, fib)
    sup_specs = jax.tree_util.tree_map(lambda _: s_spec, plan.sup)
    fn = common.shard_map(
        body, mesh=mesh,
        in_specs=((s_spec,) * 4,
                  a_spec if a_spec is not None else P((rw, fib), cl_ax),
                  s_spec, sup_specs),
        out_specs=out_specs)
    s_pack = (plan.rows_local, plan.cols, plan.vals, plan.tile_base)
    return fn(s_pack, A, B_sk, plan.sup)


def replicated_spec(grid: Grid25) -> P:
    """Sharding spec of a pre-gathered dense operand (see Session)."""
    return P(grid.row, grid.col)


def schedule_events(grid: Grid25, op: str, elision: str = "none"):
    """Ordered (point, phase) fault boundaries of one executor round.

    Cannon schedule: an optional fiber all-gather of the replicated
    operand, G phase/shift pairs per structure pass (two passes for the
    unfused/reuse FusedMM cells), and a terminal fiber reduce-scatter
    where the output is replicated-out (repro.distributed.faults).
    """
    G = grid.G

    def passes(n, start=0):
        out = []
        for t in range(start, start + n * G):
            out += [("phase", t), ("shift", t)]
        return out

    if op == "sddmm":
        return [("gather", 0)] + passes(1)
    if op == "spmm":
        return passes(1) + [("reduce", G - 1)]
    if op == "spmm_t":                       # spmmb on the S^T pack
        return [("gather", 0)] + passes(1)
    if op == "fusedmm":
        if elision == "reuse":
            return [("gather", 0)] + passes(2)
        if elision == "fused":               # one structure pass
            return [("gather", 0)] + passes(1) + [("reduce", G - 1)]
        return [("gather", 0)] + passes(2) + [("reduce", 2 * G - 1)]
    raise ValueError(f"unknown op {op!r}")


# A d25 Cannon shift multiplexes several channels but they are all
# collective-permutes — no schedule event legalizes to more than one
# collective kind (contract read by the static conformance verifier;
# s25 declares the one real entry).
WIRE_EXPANSIONS: dict = {}


def schedule_words(grid: Grid25, plan: PlanD25, op: str,
                   elision: str = "none", pre_gathered: bool = False):
    """Impl-exact per-device wire words for each schedule event.

    Aligned 1:1 with :func:`schedule_events`; see d15.schedule_words for
    the contract.  A Cannon shift event multiplexes up to three channels
    — the partial/value payload (nb*k), the coordinate structure
    (2*nb*k + tile map), and the dense B chunk (nS*rW) — whose liveness
    differs per cell (an accumulating buffer always travels; a carry
    whose final position nothing reads is DCE'd).
    """
    G, c = grid.G, grid.c
    meta = plan.meta
    nb, k = plan.rows_local.shape[-2:]
    e = float(nb * k)
    b = float(nb) if plan.row_tile < plan.block_shape[0] else 0.0
    chunk = float(meta.nS * meta.rW)
    ag = 0.0 if pre_gathered else float((c - 1) * meta.mA * meta.rW)
    rs = float((c - 1) * meta.mS * meta.rW / c)
    if op == "sddmm":
        # traveling partial always moves; struct + B die on the last hop
        def shift_w(t):
            return e + ((2 * e + b + chunk) if t < G - 1 else 0.0)
    elif op == "spmm":
        def shift_w(t):
            return (3 * e + b + chunk) if t < G - 1 else 0.0
    elif op == "spmm_t":
        # spmmb: the output chunk travels every hop; the structure carry
        # dies after feeding the last contribution
        def shift_w(t):
            return chunk + ((3 * e + b) if t < G - 1 else 0.0)
    elif op == "fusedmm":
        el = resolve_elision(elision, plan.transpose)
        if el == "none":
            # round 1 hands struct AND B to round 2 (all hops live)
            def shift_w(t):
                if t < G:
                    return 3 * e + b + chunk
                return (3 * e + b + chunk) if t < 2 * G - 1 else 0.0
        elif el == "fused":
            # single structure pass: partial, ORIGINAL values, structure
            # and the B chunk all travel; the final hop brings the
            # partial home alone
            def shift_w(t):
                return e + ((3 * e + b + chunk) if t < G - 1 else 0.0)
        else:   # reuse: struct feeds round 2; output travels home live
            def shift_w(t):
                if t < G:
                    return 3 * e + b + (chunk if t < G - 1 else 0.0)
                return chunk + ((3 * e + b) if t - G < G - 1 else 0.0)
    else:
        raise ValueError(f"unknown op {op!r}")
    out = []
    for point, t in schedule_events(grid, op, elision):
        if point == "gather":
            out.append((point, t, "all-gather", ag))
        elif point == "reduce":
            out.append((point, t, "reduce-scatter", rs))
        elif point == "shift":
            out.append((point, t, "collective-permute", float(shift_w(t))))
        else:
            out.append((point, t, None, 0.0))
    return out


def resolve_elision(elision: str, transpose: bool) -> str:
    """Resolve the uniform ``"auto"`` default *for the pack in hand*:
    reuse iff transpose-packed (FusedMMB), the one-structure-pass
    "fused" schedule otherwise — it beats the plain Cannon FusedMMA at
    every (p, c, phi): same AG/RS, strictly fewer shift words
    (Table III extension: 4*phi+1 vs 6*phi+2).  The cross-orientation
    ranking lives in ``repro.core.api.DistProblem.resolve_elision``."""
    if elision != "auto":
        return elision
    return "reuse" if transpose else "fused"


def _sq(args):
    return tuple(x[0, 0, 0] for x in args)


def _sq_sup(sup):
    """Per-device view of the support arrays (drop grid dims)."""
    return jax.tree_util.tree_map(lambda x: x[0, 0, 0], sup)


def _gather_T(plan, A_loc, sup, fib, c):
    """Fiber all-gather of the replicated operand, pruned when won."""
    sm = plan.smeta
    if sm is None or not sm.gather:
        return jax.lax.all_gather(A_loc, fib, tiled=True)
    return common.pruned_gather_rows(A_loc, sup[0], sup[1], fib, c,
                                     compress=sm.compress)


def _shift_sparse(plan) -> bool:
    return plan.smeta is not None and plan.smeta.shift


def _b_chunks(grid, plan, B0, sup, G, barrier=False):
    """Per-phase B chunks via direct pruned sends from each chunk's home.

    Phase t's chunk lives at grid-row (x+t) % G, so one ppermute with
    perm i -> (i-t) % G replaces the dense ring hop, shipping only the
    column support of the receiver's phase-t resident block.  barrier=
    True keeps a replay round (FusedMM "none") out of XLA's CSE — the
    re-sends are syntactically identical to round 1's otherwise.
    """
    src = jax.lax.optimization_barrier(B0) if barrier else B0
    chunks = [B0]
    for t in range(1, G):
        perm = [(i, (i - t) % G) for i in range(G)]
        chunks.append(common.pruned_permute(
            src, sup[2][t - 1], sup[3][t - 1], perm, grid.row,
            plan.meta.nS, compress=plan.smeta.compress))
    return chunks


def _sddmm_round(grid, plan, T, s, B0, overlap=True, chunks=None):
    """Cannon round accumulating partial dots in the traveling S pack.

    For a normal pack the kernel samples <T_i, B_j>; for a transpose pack
    the roles of the dense args swap.  The coordinate and B shifts are
    issued double-buffered ahead of the kernel; the partial-dot buffer
    lags one kernel behind (it needs the dots before it can travel).
    Returns (pack home w/ partial dots, B home, structs, bchunks) where
    ``structs``/``bchunks`` are the per-phase resident structure tuples
    and B chunks — local references, free unless a caller consumes them
    (the "fused" one-structure-pass schedule replays both in round 2).
    """
    G = grid.G
    tk = plan.tiling.kernel_kwargs()
    rl, cl, vl, tb = s
    partial = jnp.zeros_like(vl)
    ones = jnp.ones_like(vl)
    struct = (rl, cl, tb)
    structs, bchunks = [], []
    B_cur = B0 if chunks is None else chunks[0]
    if overlap and G > 1:
        nxt = tuple(_shift_back(x, grid.col, G) for x in struct)
        if chunks is None:
            B_nxt = _shift_back(B_cur, grid.row, G)
    for t in range(G):
        rl_c, cl_c, tb_c = struct
        structs.append(struct)
        bchunks.append(B_cur)
        coo = _coo(plan, rl_c, cl_c, ones, tb_c)
        if plan.transpose:
            dots = ops.sddmm(B_cur, T, coo, **tk).vals
        else:
            dots = ops.sddmm(T, B_cur, coo, **tk).vals
        partial = _shift_back(partial + dots, grid.col, G)
        if overlap and G > 1:
            struct = nxt
            if t + 1 < G:
                nxt = tuple(_shift_back(x, grid.col, G) for x in nxt)
        else:
            struct = tuple(_shift_back(x, grid.col, G) for x in struct)
        if chunks is not None:            # comm="sparse": direct sends
            B_cur = chunks[t + 1] if t + 1 < G else chunks[0]
        elif overlap and G > 1:
            B_cur = B_nxt
            if t + 1 < G:
                B_nxt = _shift_back(B_nxt, grid.row, G)
        else:
            B_cur = _shift_back(B_cur, grid.row, G)
    rl, cl, tb = struct
    return (rl, cl, partial, tb), B_cur, structs, bchunks


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("overlap", "pre_gathered"))
def sddmm_d25(grid: Grid25, plan: PlanD25, A, B_sk, overlap: bool = True,
              pre_gathered: bool = False):
    """R = S * (A @ B.T); values return to skewed-home layout.

    pre_gathered=True: A arrives already fiber-replicated (sharding
    ``replicated_spec(grid)``) and the all-gather is skipped — the
    across-call replication reuse of ``repro.core.api.Session``."""
    fib = grid.fiber

    def body(s, A_loc, B_loc, sup):
        s = _sq(s)
        sup = _sq_sup(sup)
        B0 = B_loc[0, 0, 0]
        T = A_loc if pre_gathered \
            else _gather_T(plan, A_loc, sup, fib, grid.c)
        chunks = _b_chunks(grid, plan, B0, sup, grid.G) \
            if _shift_sparse(plan) else None
        (rl, cl, partial, tb), _, _, _ = _sddmm_round(grid, plan, T, s, B0,
                                                      overlap, chunks)
        return (s[2] * partial)[None, None, None]

    return _exec(grid, plan, body, A, B_sk,
                 P(grid.row, grid.col, grid.fiber),
                 a_spec=replicated_spec(grid) if pre_gathered else None)


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("overlap",))
def spmma_d25(grid: Grid25, plan: PlanD25, B_sk, overlap: bool = True):
    """A = S @ B, output replicated along fiber then reduce-scattered."""
    G, fib = grid.G, grid.fiber
    tk = plan.tiling.kernel_kwargs()

    def body(s, _A, B_loc, sup):
        sparse_b = _shift_sparse(plan)
        chunks = _b_chunks(grid, plan, B_loc[0, 0, 0], _sq_sup(sup), G) \
            if sparse_b else None
        cur = _sq(s) + (() if sparse_b else (B_loc[0, 0, 0],))
        if overlap and G > 1:
            nxt = _advance(grid, cur, G) if not sparse_b else \
                tuple(_shift_back(x, grid.col, G) for x in cur)
        T2 = jnp.zeros((plan.meta.mS, plan.meta.rW), jnp.float32)
        for t in range(G):
            rl, cl, vl, tb = cur[:4]
            B_cur = chunks[t] if sparse_b else cur[4]
            T2 = T2 + ops.spmm(_coo(plan, rl, cl, vl, tb), B_cur,
                               m=plan.meta.mS, **tk)
            if overlap and G > 1:
                cur = nxt
                if t + 1 < G:
                    nxt = _advance(grid, nxt, G) if not sparse_b else \
                        tuple(_shift_back(x, grid.col, G) for x in nxt)
            elif sparse_b:
                cur = tuple(_shift_back(x, grid.col, G) for x in cur)
            else:
                cur = _advance(grid, cur, G)
        out = jax.lax.psum_scatter(T2, fib, scatter_dimension=0, tiled=True)
        return out

    dummy = jnp.zeros((grid.G * grid.c, grid.G), jnp.float32)
    return _exec(grid, plan, body, dummy, B_sk,
                 P((grid.row, grid.fiber), grid.col))


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("overlap", "pre_gathered"))
def spmmb_d25(grid: Grid25, plan: PlanD25, A, overlap: bool = True,
              pre_gathered: bool = False):
    """B = S.T @ A on the Cannon grid (transpose pack): AG(A) in, the
    output travels home with the propagated buffer — the FusedMMB second
    round standalone, needed by the backward transpose-SpMMs of a
    training step (repro.core.grads).

    The traveling output accumulates, so its shift trails the kernel;
    overlap precomputes the next contribution from the double-buffered
    traveling structure while the output chunk is in flight (the same
    schedule as fusedmm_d25's "reuse" SpMM round).  pre_gathered=True:
    A arrives already fiber-replicated (``replicated_spec(grid)``) and
    the all-gather is skipped — the Session replay path.

    Returns output chunks stacked (G, G, c, nS, rW) in skewed-home
    layout; reassemble with :func:`unskew_out`.
    """
    assert plan.transpose, "spmmb_d25 needs a transpose-packed plan"
    G, fib = grid.G, grid.fiber
    tk = plan.tiling.kernel_kwargs()

    def body(s, A_loc, _B, sup):
        s = _sq(s)
        T = A_loc if pre_gathered \
            else _gather_T(plan, A_loc, _sq_sup(sup), fib, grid.c)
        out_cur = jnp.zeros((plan.meta.nS, plan.meta.rW), jnp.float32)
        struct = s
        contrib = ops.spmm(_coo(plan, *struct), T, m=plan.meta.nS, **tk)
        if overlap and G > 1:
            nxt = tuple(_shift_back(x, grid.col, G) for x in struct)
        for t in range(G):
            out_cur = _shift_back(out_cur + contrib, grid.row, G)
            if t + 1 < G:
                if overlap:
                    contrib = ops.spmm(_coo(plan, *nxt), T,
                                       m=plan.meta.nS, **tk)
                    if t + 2 < G:
                        nxt = tuple(_shift_back(x, grid.col, G)
                                    for x in nxt)
                else:
                    struct = tuple(_shift_back(x, grid.col, G)
                                   for x in struct)
                    contrib = ops.spmm(_coo(plan, *struct), T,
                                       m=plan.meta.nS, **tk)
        return out_cur[None, None, None]

    dummy = jnp.zeros((grid.G, grid.G, grid.c, 1, 1), jnp.float32)
    return _exec(grid, plan, body, A, dummy,
                 P(grid.row, grid.col, grid.fiber),
                 a_spec=replicated_spec(grid) if pre_gathered else None)


def _advance(grid, cur, G):
    """Cannon advance of a (struct..., B) carry: pack along col, B along row."""
    *struct, B = cur
    return tuple(_shift_back(x, grid.col, G) for x in struct) \
        + (_shift_back(B, grid.row, G),)


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("elision", "overlap", "pre_gathered"))
def fusedmm_d25(grid: Grid25, plan: PlanD25, A, B_sk, elision: str = "auto",
                overlap: bool = True, pre_gathered: bool = False):
    """FusedMM on the 2.5D dense-replicating grid.

    elision="auto" : resolve via the cost model (see resolve_elision)
    elision="none" : FusedMMA — AG(A) + 2 Cannon rounds + RS(out).
                     Requires a normal pack.  Returns (out (m,r), R_vals).
    elision="reuse": FusedMMB — single AG(A), output travels home with the
                     propagated buffer (no reduce-scatter).  Requires a
                     transpose pack.  Returns (out stacked skewed, R_vals).
    elision="fused": one-structure-pass FusedMMA — round 2 replays the
                     per-phase structure AND B chunks cached locally
                     during the SDDMM round (both schedules have period
                     G), so only the final sample values travel: the
                     shift term drops from 6*phi+2 to 4*phi+1 Table-III
                     units.  True local-kernel fusion is impossible on
                     this grid (per-phase dots cover only the resident
                     r/G column slice — docs/algorithms.md), but the
                     communication signature of local fusion is
                     achieved.  Requires a normal pack; same returns and
                     bitwise-identical outputs to "none".

    pre_gathered=True: A arrives already fiber-replicated (sharding
    ``replicated_spec(grid)``) and the all-gather is skipped — the
    across-call replication reuse exploited by ``repro.core.api.Session``.
    """
    elision = resolve_elision(elision, plan.transpose)
    G, fib = grid.G, grid.fiber
    tk = plan.tiling.kernel_kwargs()
    a_spec = replicated_spec(grid) if pre_gathered else None

    def gather(A_loc, sup):
        if pre_gathered:
            return A_loc
        return _gather_T(plan, A_loc, sup, fib, grid.c)

    if elision == "none":
        assert not plan.transpose

        def body(s, A_loc, B_loc, sup):
            s = _sq(s)
            sup = _sq_sup(sup)
            B0 = B_loc[0, 0, 0]
            T = gather(A_loc, sup)
            sparse_b = _shift_sparse(plan)
            chunks = _b_chunks(grid, plan, B0, sup, G) if sparse_b else None
            (rl, cl, partial, tb), B_home, _, _ = _sddmm_round(
                grid, plan, T, s, B0, overlap, chunks)
            r_vals = s[2] * partial
            # Round 2 re-ships the chunks; the barrier keeps the replay's
            # (syntactically identical) sends out of XLA's CSE so the
            # two-launch baseline is priced honestly.
            chunks2 = _b_chunks(grid, plan, B0, sup, G, barrier=True) \
                if sparse_b else None
            T2 = jnp.zeros((plan.meta.mS, plan.meta.rW), jnp.float32)
            cur = (rl, cl, r_vals, tb) + (() if sparse_b else (B_home,))
            if overlap and G > 1:
                nxt = _advance(grid, cur, G) if not sparse_b else \
                    tuple(_shift_back(x, grid.col, G) for x in cur)
            for t in range(G):
                rl_c, cl_c, vl_c, tb_c = cur[:4]
                B_cur = chunks2[t] if sparse_b else cur[4]
                T2 = T2 + ops.spmm(_coo(plan, rl_c, cl_c, vl_c, tb_c),
                                   B_cur, m=plan.meta.mS, **tk)
                if overlap and G > 1:
                    cur = nxt
                    if t + 1 < G:
                        nxt = _advance(grid, nxt, G) if not sparse_b else \
                            tuple(_shift_back(x, grid.col, G) for x in nxt)
                elif sparse_b:
                    cur = tuple(_shift_back(x, grid.col, G) for x in cur)
                else:
                    cur = _advance(grid, cur, G)
            out = jax.lax.psum_scatter(T2, fib, scatter_dimension=0,
                                       tiled=True)
            return out, r_vals[None, None, None]

        return _exec(grid, plan, body, A, B_sk,
                     (P((grid.row, grid.fiber), grid.col),
                      P(grid.row, grid.col, grid.fiber)),
                     a_spec=a_spec)

    if elision == "fused":
        assert not plan.transpose

        def body(s, A_loc, B_loc, sup):
            s = _sq(s)
            sup = _sq_sup(sup)
            B0 = B_loc[0, 0, 0]
            T = gather(A_loc, sup)
            chunks = _b_chunks(grid, plan, B0, sup, G) \
                if _shift_sparse(plan) else None
            (rl, cl, partial, tb), _, structs, bchunks = _sddmm_round(
                grid, plan, T, s, B0, overlap, chunks)
            r_vals = s[2] * partial
            # Round 2 replays the cached structure and B chunks; only the
            # final values travel (same col-axis schedule as the pack
            # advance in "none", so kernel operands are value-identical).
            T2 = jnp.zeros((plan.meta.mS, plan.meta.rW), jnp.float32)
            vals_cur = r_vals
            if overlap and G > 1:
                vals_nxt = _shift_back(vals_cur, grid.col, G)
            for t in range(G):
                rl_c, cl_c, tb_c = structs[t]
                T2 = T2 + ops.spmm(_coo(plan, rl_c, cl_c, vals_cur, tb_c),
                                   bchunks[t], m=plan.meta.mS, **tk)
                if overlap and G > 1:
                    vals_cur = vals_nxt
                    if t + 1 < G:
                        vals_nxt = _shift_back(vals_nxt, grid.col, G)
                else:
                    vals_cur = _shift_back(vals_cur, grid.col, G)
            out = jax.lax.psum_scatter(T2, fib, scatter_dimension=0,
                                       tiled=True)
            return out, r_vals[None, None, None]

        return _exec(grid, plan, body, A, B_sk,
                     (P((grid.row, grid.fiber), grid.col),
                      P(grid.row, grid.col, grid.fiber)),
                     a_spec=a_spec)

    if elision == "reuse":
        assert plan.transpose

        def body(s, A_loc, B_loc, sup):
            s = _sq(s)
            sup = _sq_sup(sup)
            B0 = B_loc[0, 0, 0]
            T = gather(A_loc, sup)                           # single AG
            chunks = _b_chunks(grid, plan, B0, sup, G) \
                if _shift_sparse(plan) else None
            (rl, cl, partial, tb), _, _, _ = _sddmm_round(grid, plan, T, s,
                                                          B0, overlap,
                                                          chunks)
            r_vals = s[2] * partial
            out_cur = jnp.zeros((plan.meta.nS, plan.meta.rW), jnp.float32)
            # the output travels and accumulates, so its shift trails the
            # kernel; the *next* contribution is precomputed from the
            # double-buffered traveling structure while it is in flight
            struct = (rl, cl, r_vals, tb)
            contrib = ops.spmm(_coo(plan, *struct), T, m=plan.meta.nS, **tk)
            if overlap and G > 1:
                nxt = tuple(_shift_back(x, grid.col, G) for x in struct)
            for t in range(G):
                out_cur = _shift_back(out_cur + contrib, grid.row, G)
                if t + 1 < G:
                    if overlap:
                        contrib = ops.spmm(_coo(plan, *nxt), T,
                                           m=plan.meta.nS, **tk)
                        if t + 2 < G:
                            nxt = tuple(_shift_back(x, grid.col, G)
                                        for x in nxt)
                    else:
                        struct = tuple(_shift_back(x, grid.col, G)
                                       for x in struct)
                        contrib = ops.spmm(_coo(plan, *struct), T,
                                           m=plan.meta.nS, **tk)
            return out_cur[None, None, None], r_vals[None, None, None]

        return _exec(grid, plan, body, A, B_sk,
                     (P(grid.row, grid.col, grid.fiber),
                      P(grid.row, grid.col, grid.fiber)),
                     a_spec=a_spec)

    raise ValueError(f"unknown elision {elision!r}")
