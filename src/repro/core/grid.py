"""Processor-grid abstraction mapping the paper's grids onto JAX meshes.

The paper's ``p`` processors with replication factor ``c`` become named mesh
axes:

  1.5D: ("layer", "fiber") of shape (p/c, c)
        cyclic shifts run over "layer" (lax.ppermute),
        replication collectives over "fiber" (all_gather / psum_scatter).
  2.5D: ("row", "col", "fiber") of shape (sqrt(p/c), sqrt(p/c), c)
        Cannon shifts over "row"/"col", replication over "fiber".

``from_mesh`` reinterprets existing production-mesh axes (e.g. the LM mesh's
("data", "model")) as sparse-kernel axes without re-creating devices.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Grid15:
    mesh: Mesh
    layer: str = "layer"
    fiber: str = "fiber"

    @property
    def L(self) -> int:
        return self.mesh.shape[self.layer]

    @property
    def c(self) -> int:
        return self.mesh.shape[self.fiber]

    @property
    def p(self) -> int:
        return self.L * self.c

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


@dataclasses.dataclass(frozen=True)
class Grid25:
    mesh: Mesh
    row: str = "row"
    col: str = "col"
    fiber: str = "fiber"

    @property
    def G(self) -> int:
        g = self.mesh.shape[self.row]
        assert g == self.mesh.shape[self.col]
        return g

    @property
    def c(self) -> int:
        return self.mesh.shape[self.fiber]

    @property
    def p(self) -> int:
        return self.G * self.G * self.c

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


def make_grid15(c: int, devices=None) -> Grid15:
    devices = np.asarray(devices if devices is not None else jax.devices())
    p = devices.size
    assert p % c == 0, (p, c)
    mesh = Mesh(devices.reshape(p // c, c), ("layer", "fiber"))
    return Grid15(mesh)


def make_grid25(c: int, devices=None) -> Grid25:
    devices = np.asarray(devices if devices is not None else jax.devices())
    p = devices.size
    assert p % c == 0, (p, c)
    g = math.isqrt(p // c)
    assert g * g * c == p, f"p/c={p//c} must be a perfect square"
    mesh = Mesh(devices.reshape(g, g, c), ("row", "col", "fiber"))
    return Grid25(mesh)


def grid15_from_mesh(mesh: Mesh, layer_axis: str, fiber_axis: str) -> Grid15:
    """Reinterpret two axes of an existing mesh as (layer, fiber)."""
    return Grid15(mesh, layer=layer_axis, fiber=fiber_axis)
