"""Distributed autodiff: dual-primitive VJPs for the api entrypoints.

The paper's central structural result — every 1.5D/2.5D SpMM algorithm
converts to an SDDMM algorithm with identical communication cost and
identical input/output layouts (Table III) — is exactly the statement
that the BACKWARD of each distributed primitive is the other primitive
on the same ``DistProblem`` pack.  This module turns that into
``jax.custom_vjp`` rules for the public ``api.sddmm`` / ``api.spmm`` /
``api.fusedmm`` entrypoints, so ``jax.grad`` flows end-to-end through
the distributed kernels and every future training workload (GAT layers,
sampled-loss embeddings, ALS) sits on one differentiable layer.

The duals, for ``R = S * (X Y^T)`` and ``out = R Y`` (FusedMMA):

====================  =====================================================
primal                backward (cotangent g on the output)
====================  =====================================================
``sddmm(X, Y)``       ``Xbar = SpMM(S(g*s), Y)``, ``Ybar = SpMM^T(S(g*s), X)``
``spmm(v, Y)``        ``vbar = SDDMM_ones(g, Y)``, ``Ybar = SpMM^T(S(v), g)``
``fusedmm(X, Y)``     ``Xbar, Ghat = FusedMM(S, g, Y)`` — the SAME cell —
                      ``Ybar = SpMM^T(S(r), g) + SpMM^T(S(ghat), X)``
====================  =====================================================

where ``s`` are S's sample values, ``r`` the forward's sampled
intermediate and ``Ghat = S * (g Y^T)``.  Every backward call runs on
the SAME grid, family and elision cell as its forward, so forward and
backward provably ship the same words per primitive
(``costmodel.words_fusedmm_bwd``; measured against the compiled HLO in
``tests/dist_scripts/check_grad_costs.py``).

**Session replay.**  Threading the forward's ``api.Session`` through the
VJP replays the fiber replication the forward already gathered: the
Session is content-keyed, so the stationary operand ``Y`` arriving in
the backward as a *new array object* (it round-trips through jax
tracing) still hits the cache, and the transpose-SpMM that needs the
forward's replicated ``X`` replays that gather too.  No dense factor is
all-gathered twice in one training step — the training-step analogue of
the paper's replication-reuse elision
(``costmodel.SESSION_BWD_ELIDED``, docs/choosing.md).

**Mechanics.**  The distributed executors are host-orchestrated (numpy
packs in, host-assembled numpy out), so the primals and the VJP rules
run them through ``jax.pure_callback`` — traceable from ``jax.grad`` /
``jit`` while the actual communication schedules execute exactly as in
the eager api.  Gradients are only defined with respect to the dense
operands (and ``spmm``'s sample values); the sparsity STRUCTURE is not
differentiable.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api

__all__ = ["sddmm", "spmm", "fusedmm"]


@dataclasses.dataclass(frozen=True, eq=False)
class _Ctx:
    """Non-differentiable closure of a VJP: the problem, the resolved
    elision cell (forward and backward must run the SAME cell), and the
    Session whose forward-gathered replication the backward replays."""
    problem: api.DistProblem
    elision: str = "none"
    session: Optional[api.Session] = None


def _callback(fn, shapes, *args):
    out_types = tuple(jax.ShapeDtypeStruct(s, np.float32) for s in shapes)
    return jax.pure_callback(fn, out_types, *args)


def _f32(*arrs):
    return tuple(np.asarray(a, np.float32) for a in arrs)


# ---------------------------------------------------------------------------
# SDDMM: R_vals = S * (X Y^T) sampled at nnz(S)  ->  (nnz,)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sddmm(ctx: _Ctx, X, Y):
    def host(X, Y):
        X, Y = _f32(X, Y)
        return (ctx.problem.sddmm(X, Y, session=ctx.session).values(),)
    (vals,) = _callback(host, ((ctx.problem.nnz,),), X, Y)
    return vals


def _sddmm_fwd(ctx, X, Y):
    return _sddmm(ctx, X, Y), (X, Y)


def _sddmm_bwd(ctx, res, g):
    X, Y = res
    prob = ctx.problem
    m, n, r, nnz = prob.m, prob.n, prob.r, prob.nnz

    def host(X, Y, g):
        X, Y, g = _f32(X, Y, g)
        gs = g * prob.vals                  # cotangent through the sampling
        # the duals: grad-wrt-X is SpMM, grad-wrt-Y is SpMM-transpose,
        # both with the cotangent-valued sparse matrix on S's pattern
        # (value injection into the cached structure pack, no re-plan).
        # With a session, Y's and X's forward gathers are replayed.
        xbar = prob.spmm(Y, vals=gs, session=ctx.session)
        ybar = prob.spmm_t(X, vals=gs, session=ctx.session)
        return xbar, ybar

    return _callback(host, ((m, r), (n, r)), X, Y, g)


_sddmm.defvjp(_sddmm_fwd, _sddmm_bwd)


def sddmm(problem: api.DistProblem, X, Y, *,
          session: Optional[api.Session] = None):
    """Differentiable distributed SDDMM: values of ``S * (X @ Y.T)`` at
    nnz(S), in the problem's host COO order — the ``jax.custom_vjp``
    form of :func:`repro.core.api.sddmm`.

    ``X (m, r)``, ``Y (n, r)`` -> ``(nnz,)`` jnp array, differentiable
    in both operands; each backward is the dual distributed primitive
    (SpMM / SpMM-transpose) on the same pack, with the cotangent values
    injected into the cached structure plan (no re-packing per step).
    A ``session`` is threaded through BOTH passes: the forward fills it
    with the operands' fiber replication and the backward's dual
    SpMM/SpMM^T replay those gathers within the same step.
    """
    ctx = _Ctx(problem, session=session)
    return _sddmm(ctx, jnp.asarray(X), jnp.asarray(Y))


# ---------------------------------------------------------------------------
# SpMM: out = S(vals) @ Y  ->  (m, r); differentiable in vals AND Y
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _spmm(ctx: _Ctx, vals, Y):
    def host(vals, Y):
        vals, Y = _f32(vals, Y)
        return (ctx.problem.spmm(Y, vals=vals, session=ctx.session),)
    (out,) = _callback(host, ((ctx.problem.m, ctx.problem.r),), vals, Y)
    return out


def _spmm_fwd(ctx, vals, Y):
    return _spmm(ctx, vals, Y), (vals, Y)


def _spmm_bwd(ctx, res, g):
    vals, Y = res
    prob = ctx.problem
    n, r, nnz = prob.n, prob.r, prob.nnz

    def host(vals, Y, g):
        vals, Y, g = _f32(vals, Y, g)
        # grad-wrt-vals is the dual SDDMM: g_i . y_j sampled on S's
        # pattern (unit sample values so the dots arrive unscaled);
        # with a session, Y's forward gather is replayed here
        vbar = prob.ones().sddmm(g, Y, session=ctx.session).values()
        # grad-wrt-Y is the dual SpMM-transpose with the primal values
        ybar = prob.spmm_t(g, vals=vals)
        return vbar, ybar

    return _callback(host, ((nnz,), (n, r)), vals, Y, g)


_spmm.defvjp(_spmm_fwd, _spmm_bwd)


def spmm(problem: api.DistProblem, vals, Y, *,
         session: Optional[api.Session] = None):
    """Differentiable distributed SpMM: ``out = S(vals) @ Y`` with the
    sample values as a first-class differentiable input — the
    ``jax.custom_vjp`` form of :func:`repro.core.api.spmm`.

    ``vals (nnz,)`` in the problem's host COO order (pass
    ``problem.vals`` for the baked values), ``Y (n, r)`` ->
    ``(m, r)`` jnp array.  Differentiable in both: grad-wrt-vals is the
    dual SDDMM on S's pattern, grad-wrt-Y the dual SpMM-transpose; the
    changing values are injected into the cached structure plan (no
    re-packing per step).  Making ``vals`` differentiable is what lets
    a GAT layer train through its softmaxed attention values
    (repro.apps.gat).  A ``session`` is threaded through both passes
    (the forward's gather of Y replays in the backward's dual SDDMM on
    the families that replicate it).
    """
    ctx = _Ctx(problem, session=session)
    return _spmm(ctx, jnp.asarray(vals), jnp.asarray(Y))


# ---------------------------------------------------------------------------
# FusedMM: out = (S * (X Y^T)) @ Y  ->  (m, r)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fusedmm(ctx: _Ctx, X, Y):
    def host(X, Y):
        X, Y = _f32(X, Y)
        out, _ = ctx.problem.fusedmm(X, Y, elision=ctx.elision,
                                     session=ctx.session)
        return (out,)
    (out,) = _callback(host, ((ctx.problem.m, ctx.problem.r),), X, Y)
    return out


def _fusedmm_fwd(ctx, X, Y):
    prob = ctx.problem

    def host(X, Y):
        X, Y = _f32(X, Y)
        out, R = prob.fusedmm(X, Y, elision=ctx.elision,
                              session=ctx.session)
        return out, R.values()

    out, r_vals = _callback(host, ((prob.m, prob.r), (prob.nnz,)), X, Y)
    return out, (X, Y, r_vals)


def _fusedmm_bwd(ctx, res, g):
    X, Y, r_vals = res
    prob = ctx.problem
    m, n, r = prob.m, prob.n, prob.r

    def host(X, Y, r_vals, g):
        X, Y, r_vals, g = _f32(X, Y, r_vals, g)
        # grad-wrt-X IS FusedMM on the same cell with g in X's slot:
        #   Ghat = S * (g Y^T)   (the dual's sampled intermediate)
        #   Xbar = Ghat @ Y      (the dual's output)
        # With a Session the stationary Y's fiber gather is replayed
        # from the forward (content-keyed hit) instead of re-shipped.
        xbar, Ghat = prob.fusedmm(g, Y, elision=ctx.elision,
                                  session=ctx.session)
        ghat_vals = Ghat.values()
        # grad-wrt-Y: two transpose-SpMMs on the same grid — R^T g
        # (cotangent through the SpMM half) + Ghat^T X (through the
        # SDDMM half); the second replays the forward's gather of X.
        ybar = prob.spmm_t(g, vals=r_vals) \
            + prob.spmm_t(X, vals=ghat_vals, session=ctx.session)
        return xbar, ybar

    return _callback(host, ((m, r), (n, r)), X, Y, r_vals, g)


_fusedmm.defvjp(_fusedmm_fwd, _fusedmm_bwd)


def fusedmm(problem: api.DistProblem, X, Y, *, elision: str = "auto",
            session: Optional[api.Session] = None):
    """Differentiable distributed FusedMM:
    ``out = (S * (X @ Y.T)) @ Y`` — the ``jax.custom_vjp`` form of
    :func:`repro.core.api.fusedmm` (output only; the sampled
    intermediate is kept as a backward residual).

    ``X (m, r)``, ``Y (n, r)`` -> ``(m, r)`` jnp array.  The backward
    is built from dual primitives on the SAME pack and elision cell:
    grad-wrt-X is this very FusedMM cell with the cotangent in X's
    slot, grad-wrt-Y two transpose-SpMMs, so forward and backward ship
    the same words per Table III (``costmodel.words_fusedmm_bwd``).
    ``elision`` is resolved once here and pinned for both passes.
    Thread the forward's ``session`` to replay its fiber replication in
    the backward (no dense factor gathered twice per training step).
    """
    el = problem.resolve_elision(elision, session)
    ctx = _Ctx(problem, elision=el, session=session)
    return _fusedmm(ctx, jnp.asarray(X), jnp.asarray(Y))
