"""2.5D sparse-replicating algorithms (paper §V-D).

Grid: ("row" = G, "col" = G, "fiber" = c), p = G^2 c.  The sparse matrix is
STATIONARY and structure-replicated along the fiber; only its VALUES move
along the fiber (all-gather / reduce-scatter), since the coordinates never
change between calls — the paper's "attractive property".  Both dense
matrices propagate within each layer, split into r-chunks of width r/(Gc):

  device (x, y, z) holds, at phase t,
    S block (x, y):            (m/G, n/G)  structure replicated over z,
                               values fiber-sharded by nonzero-block
    A chunk A[X_x, w_{k_t,z}]: (m/G, r/(Gc))  travels along the col axis
    B chunk B[Y_y, w_{k_t,z}]: (n/G, r/(Gc))  travels along the row axis
  with Cannon alignment k_t = (x + y + t) mod G.

SDDMM: each phase adds the partial dots over the resident r-chunk into a
layer-local accumulator; after the round the partials are summed across the
fiber (reduce-scatter to the home value shards) and scaled by the original
sample values.  SpMM: output chunks travel along the col axis (taking A's
schedule) and accumulate R @ B contributions from every column block.
FusedMM admits no dense-*replication* elision here (nothing dense is
replicated) — the fiber traffic is values-only: AG + RS + AG, i.e. the
paper's 3*phi*nr*(c-1)/p term.  It does admit B-chunk *reuse*
(elision="reuse"): the SpMM round replays the B r-chunks cached during
the SDDMM round instead of shifting them a second time, cutting the
dense-chunk trips from 4 to 3.  Local kernel fusion is structurally
impossible (the cross-fiber partial-sum reduction separates the two
halves); docs/algorithms.md carries the full argument.

Comm/compute overlap (see DESIGN.md): the Cannon loops are Python-unrolled
with double-buffered carries — the r-chunk shifts for the next phase are
issued before the local kernel consumes the current chunks.  In the SpMM
round the traveling output accumulates kernel results, so its own shift
trails the kernel; the next contribution is instead precomputed from the
double-buffered incoming B chunk while the output chunk is in flight.

Transpose / backward plumbing: s25 needs no FusedMMB-style executor —
SpMM^T runs spmma_s25 on the TRANSPOSED problem (S^T structure
replicated on the same grid; registry `_S25._spmm_t_call`), and because
nothing dense is replicated here, a training step's Session replay
elides nothing: the backward ships identical words with or without one
(costmodel.SESSION_BWD_ELIDED["s25"] == 0, asserted bitwise by
tests/dist_scripts/check_grad_costs.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import common, costmodel
from repro.core.grid import Grid25
from repro.kernels import ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlanS25:
    rows_local: jax.Array   # (G, G, c, nb, k) — identical across z
    cols: jax.Array         # (G, G, c, nb, k)
    vals: jax.Array         # (G, G, c, nb/c, k) — fiber-sharded by block
    tile_base: jax.Array    # (G, G, c, nb)
    m: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    r: int = dataclasses.field(metadata=dict(static=True))
    row_tile: int = dataclasses.field(metadata=dict(static=True))
    tiling: costmodel.Tiling = dataclasses.field(metadata=dict(static=True))
    meta: object = dataclasses.field(metadata=dict(static=True))
    sup: tuple = ()             # comm="sparse" support index arrays
    smeta: object = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def mS(self):
        return self.meta.mS

    @property
    def nS(self):
        return self.meta.nS

    @property
    def rc(self):
        return self.meta.rc


@dataclasses.dataclass(frozen=True, eq=False)
class MetaS25:
    mS: int   # m/G
    nS: int   # n/G
    rc: int   # r/(Gc)
    block_meta: common.BlockMeta


def plan_s25(grid: Grid25, rows, cols, vals, m: int, n: int, r: int, *,
             row_tile: int = 256, nz_block: int = 256, group: int = 1,
             comm: str = "dense", compress=None) -> PlanS25:
    """Pack the stationary S block per layer position (host, amortized).

    comm="sparse": the stationary block (x, y) reads its A r-chunks only
    at its row support and its B r-chunks only at its column support —
    both constant across phases, since only the chunk's column window
    changes.  Each phase's chunk ships directly from its home position,
    pruned to the receiver's support.  The fiber value traffic (the
    3*phi term) and the traveling output chunks stay dense.
    """
    G, c, p = grid.G, grid.c, grid.p
    assert m % G == 0 and n % G == 0 and r % (G * c) == 0
    mS, nS, rc = m // G, n // G, r // (G * c)
    row_tile = common.choose_row_tile(mS, row_tile)

    blocks, row_off, col_off = [], [], []
    rsup = np.empty((G, G), object)
    csup = np.empty((G, G), object)
    for x in range(G):
        for y in range(G):
            br, bc, bv = common.extract_block(
                rows, cols, vals, x * mS, (x + 1) * mS, y * nS, (y + 1) * nS)
            rsup[x, y], csup[x, y] = np.unique(br), np.unique(bc)
            blocks.append((br, bc, bv))
            row_off.append(x * mS), col_off.append(y * nS)
    rl, cl, vl, tb = common.pack_block_list(blocks, (mS, nS), row_tile,
                                            nz_block, group=group)
    nb = rl.shape[1]
    if nb % c:                       # pad so the value shards split evenly
        pad = c - nb % c
        rl = np.pad(rl, ((0, 0), (0, pad), (0, 0)))
        cl = np.pad(cl, ((0, 0), (0, pad), (0, 0)))
        vl = np.pad(vl, ((0, 0), (0, pad), (0, 0)))
        tb = np.pad(tb, ((0, 0), (0, pad)), mode="edge")
        nb += pad
    k = rl.shape[-1]
    tiling = common.plan_tiling(tb, n_b=nS, r=rc, k=nz_block,
                                row_tile=row_tile)
    # replicate structure across z; shard values by nonzero-block across z
    rl_g = np.broadcast_to(rl[:, None], (G * G, c, nb, k)).reshape(
        G, G, c, nb, k)
    cl_g = np.broadcast_to(cl[:, None], (G * G, c, nb, k)).reshape(
        G, G, c, nb, k)
    tb_g = np.broadcast_to(tb[:, None], (G * G, c, nb)).reshape(G, G, c, nb)
    vl_g = vl.reshape(G, G, c, nb // c, k)
    sh = grid.sharding("row", "col", "fiber")
    meta = MetaS25(mS, nS, rc, common.BlockMeta(
        np.array(row_off).reshape(G, G), np.array(col_off).reshape(G, G),
        (m, n)))
    sup, smeta = ((), None) if comm != "sparse" else _sparse_sup(
        grid, rsup, csup, mS, nS, sh, compress)
    return PlanS25(
        jax.device_put(rl_g, sh), jax.device_put(cl_g, sh),
        jax.device_put(vl_g, sh), jax.device_put(tb_g, sh),
        m, n, r, row_tile, tiling, meta, sup, smeta)


def _sparse_sup(grid: Grid25, rsup, csup, mS, nS, sh, compress):
    """Pad + align the comm="sparse" support sets into device arrays.

    Chunks are full-height within their layer block, so the support is
    receiver-determined and phase-constant: at phase t device (x, y, z)
    receives its A chunk from grid-col (y+t) % G pruned to rsup[x, y],
    and its B chunk from grid-row (x+t) % G pruned to csup[x, y].  One
    channel per traveling operand, each with its own crossover.
    """
    G, c = grid.G, grid.c
    cross = costmodel.SPARSE_CROSSOVER

    def channel(sup2, height, sender):
        w = max(1, max(sup2[x, y].size for x in range(G) for y in range(G)))
        if G == 1 or w > cross * height:
            return (), (), 0, False
        send = []
        for t in range(1, G):
            s_t = np.empty((G, G, c), object)
            for x in range(G):
                for y in range(G):
                    for z in range(c):
                        s_t[x, y, z] = sup2[sender(x, y, t)]
            send.append(jax.device_put(common.pad_sets(s_t, w, 0), sh))
        recv = np.empty((G, G, c), object)
        for x in range(G):
            for y in range(G):
                for z in range(c):
                    recv[x, y, z] = sup2[x, y]
        recv = jax.device_put(common.pad_sets(recv, w, height), sh)
        return tuple(send), (recv,), w, True

    a_send, a_recv, wa, sa = channel(
        rsup, mS, lambda x, y, t: (x, (y - t) % G))
    b_send, b_recv, wb, sb = channel(
        csup, nS, lambda x, y, t: ((x - t) % G, y))
    sup = (a_send, a_recv, b_send, b_recv)
    return sup, common.SparseMeta(shift=sa, shift_b=sb,
                                  ws=(wa,) if sa else (),
                                  ws_b=(wb,) if sb else (),
                                  compress=compress)


def skew_dense(grid: Grid25, X: np.ndarray, along: str) -> jax.Array:
    """Pre-skew a dense matrix into Cannon start chunks.

    along="row": X = A (rows follow the grid-row coordinate x)
    along="col": X = B (rows follow the grid-col coordinate y)
    Returns stacked (G, G, c, rows/G, r/(Gc)) device-placed array.
    """
    G, c = grid.G, grid.c
    nrows, r = X.shape
    blk, rc = nrows // G, r // (G * c)
    out = np.zeros((G, G, c, blk, rc), X.dtype)
    for x in range(G):
        for y in range(G):
            for z in range(c):
                k = (x + y) % G
                w0 = (k * c + z) * rc
                row0 = (x if along == "row" else y) * blk
                out[x, y, z] = X[row0:row0 + blk, w0:w0 + rc]
    return jax.device_put(out, grid.sharding("row", "col", "fiber"))


def unskew_out(grid: Grid25, plan: PlanS25, stacked) -> np.ndarray:
    """Reassemble A-shaped outputs whose chunks ended in skewed-home spots."""
    G, c = grid.G, grid.c
    mS, rc = plan.mS, plan.rc
    stacked = np.asarray(stacked)
    out = np.zeros((plan.m, plan.r), np.float32)
    for x in range(G):
        for y in range(G):
            for z in range(c):
                k = (x + y) % G
                w0 = (k * c + z) * rc
                out[x * mS:(x + 1) * mS, w0:w0 + rc] += stacked[x, y, z]
    return out


def _coo(plan, rl, cl, vl, tb):
    return common.coo_of(rl, cl, vl, tb, (plan.mS, plan.nS), plan.row_tile)


def _shift_back(x, axis_name, size):
    return jax.lax.ppermute(x, axis_name,
                            [(i, (i - 1) % size) for i in range(size)])


def _exec(grid: Grid25, plan: PlanS25, body, A_sk, B_sk, out_specs):
    s_spec = P(grid.row, grid.col, grid.fiber)
    sup_specs = jax.tree_util.tree_map(lambda _: s_spec, plan.sup)
    fn = common.shard_map(
        body, mesh=grid.mesh,
        in_specs=((s_spec,) * 4, s_spec, s_spec, sup_specs),
        out_specs=out_specs)
    s_pack = (plan.rows_local, plan.cols, plan.vals, plan.tile_base)
    return fn(s_pack, A_sk, B_sk, plan.sup)


def _sq_sup(sup):
    """Per-device view of the support arrays (drop grid dims)."""
    return jax.tree_util.tree_map(lambda x: x[0, 0, 0], sup)


def _a_sparse(plan) -> bool:
    return plan.smeta is not None and plan.smeta.shift


def _b_sparse(plan) -> bool:
    return plan.smeta is not None and plan.smeta.shift_b


def _r_chunks(grid, plan, X0, send, recv, axis_name, out_rows,
              barrier=False):
    """Per-phase r-chunks via direct pruned sends from each chunk's home.

    Phase t's chunk sits t positions up the travel axis, so one ppermute
    with perm i -> (i-t) % G replaces the dense ring hop; the payload is
    the receiver's (phase-constant) support.  barrier=True keeps a
    replay round (FusedMM "none") out of XLA's CSE.
    """
    G = grid.G
    src = jax.lax.optimization_barrier(X0) if barrier else X0
    chunks = [X0]
    for t in range(1, G):
        perm = [(i, (i - t) % G) for i in range(G)]
        chunks.append(common.pruned_permute(
            src, send[t - 1], recv[0], perm, axis_name, out_rows,
            compress=plan.smeta.compress))
    return chunks


def _sddmm_round(grid, plan, s, A0, B0, sup=()):
    """Cannon round over r-chunks; returns layer-partial dots (nb, k).

    The A/B chunk shifts for phase t+1 are issued before the phase-t
    kernel; the partial accumulator stays local (fiber-reduced later).
    Also returns ``bchunks``, the per-phase resident B chunks — local
    references, free unless a caller consumes them (the "reuse"
    B-chunk-replay schedule feeds them to the SpMM round, eliding B's
    second trip around the grid).  comm="sparse" replaces either ring
    with per-phase direct pruned sends (see _r_chunks).
    """
    G = grid.G
    tk = plan.tiling.kernel_kwargs()
    rl, cl, _, tb = s
    partial = jnp.zeros(rl.shape, jnp.float32)
    ones = jnp.ones(rl.shape, jnp.float32)
    achunks = bchunks_in = None
    if _a_sparse(plan):
        achunks = _r_chunks(grid, plan, A0, sup[0], sup[1], grid.col,
                            plan.mS)
    if _b_sparse(plan):
        bchunks_in = _r_chunks(grid, plan, B0, sup[2], sup[3], grid.row,
                               plan.nS)
    A_cur, B_cur = A0, B0
    bchunks = []
    if G > 1:
        if achunks is None:
            A_nxt = _shift_back(A_cur, grid.col, G)
        if bchunks_in is None:
            B_nxt = _shift_back(B_cur, grid.row, G)
    for t in range(G):
        bchunks.append(B_cur)
        dots = ops.sddmm(A_cur, B_cur, _coo(plan, rl, cl, ones, tb),
                         **tk).vals
        partial = partial + dots
        nt = t + 1 if t + 1 < G else 0
        if achunks is not None:
            A_cur = achunks[nt]
        elif G > 1:
            A_cur = A_nxt
            if t + 1 < G:
                A_nxt = _shift_back(A_nxt, grid.col, G)
        else:
            A_cur = _shift_back(A_cur, grid.col, G)
        if bchunks_in is not None:
            B_cur = bchunks_in[nt]
        elif G > 1:
            B_cur = B_nxt
            if t + 1 < G:
                B_nxt = _shift_back(B_nxt, grid.row, G)
        else:
            B_cur = _shift_back(B_cur, grid.row, G)
    return partial, A_cur, B_cur, bchunks


@functools.partial(jax.jit, static_argnums=(0,))
def sddmm_s25(grid: Grid25, plan: PlanS25, A_sk, B_sk):
    """R = S * (A @ B.T); values end fiber-sharded at home (nb/c, k)."""
    fib = grid.fiber

    def body(s, A_loc, B_loc, sup):
        s = tuple(x[0, 0, 0] for x in s)
        partial, _, _, _ = _sddmm_round(grid, plan, s,
                                        A_loc[0, 0, 0], B_loc[0, 0, 0],
                                        _sq_sup(sup))
        # sum partials over the fiber, back to home value shards
        mine = jax.lax.psum_scatter(partial, fib, scatter_dimension=0,
                                    tiled=True)
        return (s[2] * mine)[None, None, None]

    return _exec(grid, plan, body, A_sk, B_sk,
                 P(grid.row, grid.col, grid.fiber))


def _spmm_round(grid, plan, s, B0, sup=(), barrier=False):
    """Cannon round for SpMM: the traveling output accumulates, so its
    shift trails the kernel; the next contribution is precomputed from the
    double-buffered incoming B chunk while the output is in flight.
    comm="sparse" replaces the B ring with direct pruned sends (the
    traveling output keeps its dense, order-preserving shifts)."""
    G = grid.G
    tk = plan.tiling.kernel_kwargs()
    rl, cl, vals, tb = s
    coo = _coo(plan, rl, cl, vals, tb)
    out_cur = jnp.zeros((plan.mS, plan.rc), jnp.float32)
    chunks = _r_chunks(grid, plan, B0, sup[2], sup[3], grid.row, plan.nS,
                       barrier=barrier) if _b_sparse(plan) else None
    contrib = ops.spmm(coo, B0, m=plan.mS, **tk)
    if chunks is None:
        B_nxt = _shift_back(B0, grid.row, G) if G > 1 else None
    for t in range(G):
        out_cur = _shift_back(out_cur + contrib, grid.col, G)
        if t + 1 < G:
            B_in = chunks[t + 1] if chunks is not None else B_nxt
            contrib = ops.spmm(coo, B_in, m=plan.mS, **tk)
            if chunks is None and t + 2 < G:
                B_nxt = _shift_back(B_nxt, grid.row, G)
    return out_cur


def _spmm_round_cached(grid, plan, s, bchunks):
    """SpMM round replaying the B r-chunks cached during the SDDMM round
    (the "reuse" elision): B's second trip around the grid is elided and
    only the traveling output shifts.  B's round-2 schedule coincides
    with its round-1 schedule (period G), so the kernel operands are
    value-identical to :func:`_spmm_round` — bitwise-identical output."""
    G = grid.G
    tk = plan.tiling.kernel_kwargs()
    coo = _coo(plan, *s)
    out_cur = jnp.zeros((plan.mS, plan.rc), jnp.float32)
    contrib = ops.spmm(coo, bchunks[0], m=plan.mS, **tk)
    for t in range(G):
        out_cur = _shift_back(out_cur + contrib, grid.col, G)
        if t + 1 < G:
            contrib = ops.spmm(coo, bchunks[t + 1], m=plan.mS, **tk)
    return out_cur


@functools.partial(jax.jit, static_argnums=(0,))
def spmma_s25(grid: Grid25, plan: PlanS25, B_sk):
    """A = S @ B; output chunks end in skewed-home layout."""
    G, fib = grid.G, grid.fiber

    def body(s, _A, B_loc, sup):
        rl, cl, vshard, tb = tuple(x[0, 0, 0] for x in s)
        vals = jax.lax.all_gather(vshard, fib, tiled=True)   # (nb, k)
        out = _spmm_round(grid, plan, (rl, cl, vals, tb), B_loc[0, 0, 0],
                          _sq_sup(sup))
        return out[None, None, None]

    dummy = jnp.zeros((grid.G, grid.G, grid.c, 1, 1), jnp.float32)
    return _exec(grid, plan, body, dummy, B_sk,
                 P(grid.row, grid.col, grid.fiber))


def resolve_elision(elision: str) -> str:
    """Resolve the uniform ``"auto"`` default: B-chunk "reuse" beats the
    unoptimized round at every (p, c, phi) — same fiber value traffic,
    one fewer dense-chunk trip (3 vs 4 Table-III units)."""
    if elision != "auto":
        return elision
    return "reuse"


def schedule_events(grid: Grid25, op: str, elision: str = "none"):
    """Ordered (point, phase) fault boundaries of one executor round.

    s25 replicates the *structure*, never a dense operand — no gather
    events.  Each round is G phase/shift pairs of traveling dense
    chunks; the SDDMM half ends in the cross-fiber partial-sum
    reduce-scatter (the very barrier that makes "fused" impossible
    here), and FusedMM chains both halves (repro.distributed.faults).
    """
    G = grid.G

    def passes(n, start=0):
        out = []
        for t in range(start, start + n * G):
            out += [("phase", t), ("shift", t)]
        return out

    if op == "sddmm":
        return passes(1) + [("reduce", G - 1)]
    if op in ("spmm", "spmm_t"):     # spmm_t = spmm on the S^T problem
        return passes(1)
    if op == "fusedmm":              # SDDMM pass, RS barrier, SpMM pass
        return passes(1) + [("reduce", G - 1)] + passes(1, start=G)
    raise ValueError(f"unknown op {op!r}")


# FusedMM's reduce event carries the partial-sum reduce-scatter AND the
# value re-broadcast: it legalizes to two HLO collectives (RS + AG),
# splitting the event's 2*fiber words evenly.  The static conformance
# verifier (repro.analysis.conformance) reads this to expand the event
# before matching the compiled collective sequence.
WIRE_EXPANSIONS: dict = {
    ("fusedmm", "reduce"): ("reduce-scatter", "all-gather"),
}


def schedule_words(grid: Grid25, plan: PlanS25, op: str,
                   elision: str = "none", pre_gathered: bool = False):
    """Impl-exact per-device wire words for each schedule event.

    Aligned 1:1 with :func:`schedule_events`; see d15.schedule_words for
    the contract.  s25 replicates no dense operand, so ``pre_gathered``
    changes nothing; the fiber traffic is values-only.  SpMM's opening
    value all-gather has no event of its own in the fault schedule — its
    words ride the first phase span; FusedMM's reduce event carries both
    the partial-sum reduce-scatter AND the value re-broadcast (RS + AG).
    """
    del pre_gathered   # nothing dense is replicated here (Session-inert)
    G, c = grid.G, grid.c
    nb, k = plan.rows_local.shape[-2:]
    fiber = float((c - 1) * (nb // c) * k)
    a_ch = float(plan.mS * plan.rc)    # A chunk / traveling output chunk
    b_ch = float(plan.nS * plan.rc)
    if op == "sddmm":
        # both dense chunks die on the cycle-closing hop
        def shift_w(t):
            return (a_ch + b_ch) if t < G - 1 else 0.0
    elif op in ("spmm", "spmm_t"):
        # the output chunk accumulates (always travels); B's last hop dies
        def shift_w(t):
            return a_ch + (b_ch if t < G - 1 else 0.0)
    elif op == "fusedmm":
        el = resolve_elision(elision)
        if el == "none":
            # round 1: B home feeds round 2 (all hops live), A's last dies;
            # round 2: output always travels, B's last hop dies
            def shift_w(t):
                if t < G:
                    return b_ch + (a_ch if t < G - 1 else 0.0)
                return a_ch + (b_ch if t - G < G - 1 else 0.0)
        else:   # reuse: round 2 replays cached B chunks — output only
            def shift_w(t):
                if t < G:
                    return (a_ch + b_ch) if t < G - 1 else 0.0
                return a_ch
    else:
        raise ValueError(f"unknown op {op!r}")
    out = []
    for point, t in schedule_events(grid, op, elision):
        if point == "reduce":
            out.append((point, t, "reduce-scatter",
                        2 * fiber if op == "fusedmm" else fiber))
        elif point == "phase" and t == 0 and op in ("spmm", "spmm_t"):
            out.append((point, t, "all-gather", fiber))
        elif point == "shift":
            out.append((point, t, "collective-permute", float(shift_w(t))))
        else:
            out.append((point, t, None, 0.0))
    return out


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("elision",))
def fusedmm_s25(grid: Grid25, plan: PlanS25, A_sk, B_sk,
                elision: str = "auto"):
    """FusedMMA on the 2.5D sparse-replicating grid.

    elision="auto" : resolves to "reuse" (see resolve_elision)
    elision="none" : A and B travel in the SDDMM round, out and B in the
                     SpMM round — 4 dense-chunk trips.
    elision="reuse": the SpMM round replays the B r-chunks cached
                     locally during the SDDMM round (B's two schedules
                     coincide, period G), eliding B's second trip: 3
                     dense-chunk trips, bitwise-identical output.
    elision="fused": structurally impossible — rejected.  Per-phase dots
                     cover only the resident r/(Gc) chunk, and the
                     partial sums must cross the fiber (RS + AG) before
                     any SpMM can consume them; with S stationary there
                     is no structure communication to elide either (the
                     paper's "no elision possible", docs/algorithms.md).

    Fiber traffic in every cell is values-only: AG(vals) happens
    implicitly by computing partials, RS reduces them home, AG
    re-broadcasts the final values for the SpMM round — the
    3*phi*nr*(c-1)/p term of Table III.
    Returns (out chunks (G,G,c,mS,rc) skewed-home, R values fiber-sharded).
    """
    elision = resolve_elision(elision)
    if elision not in ("none", "reuse"):
        raise ValueError(f"s25 supports ('none', 'reuse'), got "
                         f"{elision!r} (local fusion is structurally "
                         f"impossible here — see docs/algorithms.md)")
    G, fib = grid.G, grid.fiber

    def body(s, A_loc, B_loc, sup):
        s = tuple(x[0, 0, 0] for x in s)
        sup = _sq_sup(sup)
        rl, cl, vshard, tb = s
        partial, A_home, B_home, bchunks = _sddmm_round(grid, plan, s,
                                                        A_loc[0, 0, 0],
                                                        B_loc[0, 0, 0],
                                                        sup)
        mine = jax.lax.psum_scatter(partial, fib, scatter_dimension=0,
                                    tiled=True)                  # RS
        r_mine = vshard * mine
        r_vals = jax.lax.all_gather(r_mine, fib, tiled=True)     # AG
        if elision == "reuse":
            out = _spmm_round_cached(grid, plan, (rl, cl, r_vals, tb),
                                     bchunks)
        else:
            # barrier: the replay's pruned sends are syntactically
            # identical to round 1's — keep them out of XLA's CSE so the
            # unoptimized baseline is priced honestly.
            out = _spmm_round(grid, plan, (rl, cl, r_vals, tb), B_home,
                              sup, barrier=True)
        return out[None, None, None], r_mine[None, None, None]

    return _exec(grid, plan, body, A_sk, B_sk,
                 (P(grid.row, grid.col, grid.fiber),
                  P(grid.row, grid.col, grid.fiber)))
