"""2.5D sparse-replicating algorithms (paper §V-D).

Grid: ("row" = G, "col" = G, "fiber" = c), p = G^2 c.  The sparse matrix is
STATIONARY and structure-replicated along the fiber; only its VALUES move
along the fiber (all-gather / reduce-scatter), since the coordinates never
change between calls — the paper's "attractive property".  Both dense
matrices propagate within each layer, split into r-chunks of width r/(Gc):

  device (x, y, z) holds, at phase t,
    S block (x, y):            (m/G, n/G)  structure replicated over z,
                               values fiber-sharded by nonzero-block
    A chunk A[X_x, w_{k_t,z}]: (m/G, r/(Gc))  travels along the col axis
    B chunk B[Y_y, w_{k_t,z}]: (n/G, r/(Gc))  travels along the row axis
  with Cannon alignment k_t = (x + y + t) mod G.

SDDMM: each phase adds the partial dots over the resident r-chunk into a
layer-local accumulator; after the round the partials are summed across the
fiber (reduce-scatter to the home value shards) and scaled by the original
sample values.  SpMM: output chunks travel along the col axis (taking A's
schedule) and accumulate R @ B contributions from every column block.
FusedMM admits no dense-*replication* elision here (nothing dense is
replicated) — the fiber traffic is values-only: AG + RS + AG, i.e. the
paper's 3*phi*nr*(c-1)/p term.  It does admit B-chunk *reuse*
(elision="reuse"): the SpMM round replays the B r-chunks cached during
the SDDMM round instead of shifting them a second time, cutting the
dense-chunk trips from 4 to 3.  Local kernel fusion is structurally
impossible (the cross-fiber partial-sum reduction separates the two
halves); docs/algorithms.md carries the full argument.

Comm/compute overlap (see DESIGN.md): the Cannon loops are Python-unrolled
with double-buffered carries — the r-chunk shifts for the next phase are
issued before the local kernel consumes the current chunks.  In the SpMM
round the traveling output accumulates kernel results, so its own shift
trails the kernel; the next contribution is instead precomputed from the
double-buffered incoming B chunk while the output chunk is in flight.

Transpose / backward plumbing: s25 needs no FusedMMB-style executor —
SpMM^T runs spmma_s25 on the TRANSPOSED problem (S^T structure
replicated on the same grid; registry `_S25._spmm_t_call`), and because
nothing dense is replicated here, a training step's Session replay
elides nothing: the backward ships identical words with or without one
(costmodel.SESSION_BWD_ELIDED["s25"] == 0, asserted bitwise by
tests/dist_scripts/check_grad_costs.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import common, costmodel
from repro.core.grid import Grid25
from repro.kernels import ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlanS25:
    rows_local: jax.Array   # (G, G, c, nb, k) — identical across z
    cols: jax.Array         # (G, G, c, nb, k)
    vals: jax.Array         # (G, G, c, nb/c, k) — fiber-sharded by block
    tile_base: jax.Array    # (G, G, c, nb)
    m: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    r: int = dataclasses.field(metadata=dict(static=True))
    row_tile: int = dataclasses.field(metadata=dict(static=True))
    tiling: costmodel.Tiling = dataclasses.field(metadata=dict(static=True))
    meta: object = dataclasses.field(metadata=dict(static=True))

    @property
    def mS(self):
        return self.meta.mS

    @property
    def nS(self):
        return self.meta.nS

    @property
    def rc(self):
        return self.meta.rc


@dataclasses.dataclass(frozen=True, eq=False)
class MetaS25:
    mS: int   # m/G
    nS: int   # n/G
    rc: int   # r/(Gc)
    block_meta: common.BlockMeta


def plan_s25(grid: Grid25, rows, cols, vals, m: int, n: int, r: int, *,
             row_tile: int = 256, nz_block: int = 256,
             group: int = 1) -> PlanS25:
    G, c, p = grid.G, grid.c, grid.p
    assert m % G == 0 and n % G == 0 and r % (G * c) == 0
    mS, nS, rc = m // G, n // G, r // (G * c)
    row_tile = common.choose_row_tile(mS, row_tile)

    blocks, row_off, col_off = [], [], []
    for x in range(G):
        for y in range(G):
            br, bc, bv = common.extract_block(
                rows, cols, vals, x * mS, (x + 1) * mS, y * nS, (y + 1) * nS)
            blocks.append((br, bc, bv))
            row_off.append(x * mS), col_off.append(y * nS)
    rl, cl, vl, tb = common.pack_block_list(blocks, (mS, nS), row_tile,
                                            nz_block, group=group)
    nb = rl.shape[1]
    if nb % c:                       # pad so the value shards split evenly
        pad = c - nb % c
        rl = np.pad(rl, ((0, 0), (0, pad), (0, 0)))
        cl = np.pad(cl, ((0, 0), (0, pad), (0, 0)))
        vl = np.pad(vl, ((0, 0), (0, pad), (0, 0)))
        tb = np.pad(tb, ((0, 0), (0, pad)), mode="edge")
        nb += pad
    k = rl.shape[-1]
    tiling = common.plan_tiling(tb, n_b=nS, r=rc, k=nz_block,
                                row_tile=row_tile)
    # replicate structure across z; shard values by nonzero-block across z
    rl_g = np.broadcast_to(rl[:, None], (G * G, c, nb, k)).reshape(
        G, G, c, nb, k)
    cl_g = np.broadcast_to(cl[:, None], (G * G, c, nb, k)).reshape(
        G, G, c, nb, k)
    tb_g = np.broadcast_to(tb[:, None], (G * G, c, nb)).reshape(G, G, c, nb)
    vl_g = vl.reshape(G, G, c, nb // c, k)
    sh = grid.sharding("row", "col", "fiber")
    meta = MetaS25(mS, nS, rc, common.BlockMeta(
        np.array(row_off).reshape(G, G), np.array(col_off).reshape(G, G),
        (m, n)))
    return PlanS25(
        jax.device_put(rl_g, sh), jax.device_put(cl_g, sh),
        jax.device_put(vl_g, sh), jax.device_put(tb_g, sh),
        m, n, r, row_tile, tiling, meta)


def skew_dense(grid: Grid25, X: np.ndarray, along: str) -> jax.Array:
    """Pre-skew a dense matrix into Cannon start chunks.

    along="row": X = A (rows follow the grid-row coordinate x)
    along="col": X = B (rows follow the grid-col coordinate y)
    Returns stacked (G, G, c, rows/G, r/(Gc)) device-placed array.
    """
    G, c = grid.G, grid.c
    nrows, r = X.shape
    blk, rc = nrows // G, r // (G * c)
    out = np.zeros((G, G, c, blk, rc), X.dtype)
    for x in range(G):
        for y in range(G):
            for z in range(c):
                k = (x + y) % G
                w0 = (k * c + z) * rc
                row0 = (x if along == "row" else y) * blk
                out[x, y, z] = X[row0:row0 + blk, w0:w0 + rc]
    return jax.device_put(out, grid.sharding("row", "col", "fiber"))


def unskew_out(grid: Grid25, plan: PlanS25, stacked) -> np.ndarray:
    """Reassemble A-shaped outputs whose chunks ended in skewed-home spots."""
    G, c = grid.G, grid.c
    mS, rc = plan.mS, plan.rc
    stacked = np.asarray(stacked)
    out = np.zeros((plan.m, plan.r), np.float32)
    for x in range(G):
        for y in range(G):
            for z in range(c):
                k = (x + y) % G
                w0 = (k * c + z) * rc
                out[x * mS:(x + 1) * mS, w0:w0 + rc] += stacked[x, y, z]
    return out


def _coo(plan, rl, cl, vl, tb):
    return common.coo_of(rl, cl, vl, tb, (plan.mS, plan.nS), plan.row_tile)


def _shift_back(x, axis_name, size):
    return jax.lax.ppermute(x, axis_name,
                            [(i, (i - 1) % size) for i in range(size)])


def _exec(grid: Grid25, plan: PlanS25, body, A_sk, B_sk, out_specs):
    s_spec = P(grid.row, grid.col, grid.fiber)
    fn = common.shard_map(
        body, mesh=grid.mesh,
        in_specs=((s_spec,) * 4, s_spec, s_spec),
        out_specs=out_specs)
    s_pack = (plan.rows_local, plan.cols, plan.vals, plan.tile_base)
    return fn(s_pack, A_sk, B_sk)


def _sddmm_round(grid, plan, s, A0, B0):
    """Cannon round over r-chunks; returns layer-partial dots (nb, k).

    The A/B chunk shifts for phase t+1 are issued before the phase-t
    kernel; the partial accumulator stays local (fiber-reduced later).
    Also returns ``bchunks``, the per-phase resident B chunks — local
    references, free unless a caller consumes them (the "reuse"
    B-chunk-replay schedule feeds them to the SpMM round, eliding B's
    second trip around the grid).
    """
    G = grid.G
    tk = plan.tiling.kernel_kwargs()
    rl, cl, _, tb = s
    partial = jnp.zeros(rl.shape, jnp.float32)
    ones = jnp.ones(rl.shape, jnp.float32)
    A_cur, B_cur = A0, B0
    bchunks = []
    if G > 1:
        A_nxt = _shift_back(A_cur, grid.col, G)
        B_nxt = _shift_back(B_cur, grid.row, G)
    for t in range(G):
        bchunks.append(B_cur)
        dots = ops.sddmm(A_cur, B_cur, _coo(plan, rl, cl, ones, tb),
                         **tk).vals
        partial = partial + dots
        if G > 1:
            A_cur, B_cur = A_nxt, B_nxt
            if t + 1 < G:
                A_nxt = _shift_back(A_nxt, grid.col, G)
                B_nxt = _shift_back(B_nxt, grid.row, G)
        else:
            A_cur = _shift_back(A_cur, grid.col, G)
            B_cur = _shift_back(B_cur, grid.row, G)
    return partial, A_cur, B_cur, bchunks


@functools.partial(jax.jit, static_argnums=(0,))
def sddmm_s25(grid: Grid25, plan: PlanS25, A_sk, B_sk):
    """R = S * (A @ B.T); values end fiber-sharded at home (nb/c, k)."""
    fib = grid.fiber

    def body(s, A_loc, B_loc):
        s = tuple(x[0, 0, 0] for x in s)
        partial, _, _, _ = _sddmm_round(grid, plan, s,
                                        A_loc[0, 0, 0], B_loc[0, 0, 0])
        # sum partials over the fiber, back to home value shards
        mine = jax.lax.psum_scatter(partial, fib, scatter_dimension=0,
                                    tiled=True)
        return (s[2] * mine)[None, None, None]

    return _exec(grid, plan, body, A_sk, B_sk,
                 P(grid.row, grid.col, grid.fiber))


def _spmm_round(grid, plan, s, B0):
    """Cannon round for SpMM: the traveling output accumulates, so its
    shift trails the kernel; the next contribution is precomputed from the
    double-buffered incoming B chunk while the output is in flight."""
    G = grid.G
    tk = plan.tiling.kernel_kwargs()
    rl, cl, vals, tb = s
    coo = _coo(plan, rl, cl, vals, tb)
    out_cur = jnp.zeros((plan.mS, plan.rc), jnp.float32)
    contrib = ops.spmm(coo, B0, m=plan.mS, **tk)
    B_nxt = _shift_back(B0, grid.row, G) if G > 1 else None
    for t in range(G):
        out_cur = _shift_back(out_cur + contrib, grid.col, G)
        if t + 1 < G:
            contrib = ops.spmm(coo, B_nxt, m=plan.mS, **tk)
            if t + 2 < G:
                B_nxt = _shift_back(B_nxt, grid.row, G)
    return out_cur


def _spmm_round_cached(grid, plan, s, bchunks):
    """SpMM round replaying the B r-chunks cached during the SDDMM round
    (the "reuse" elision): B's second trip around the grid is elided and
    only the traveling output shifts.  B's round-2 schedule coincides
    with its round-1 schedule (period G), so the kernel operands are
    value-identical to :func:`_spmm_round` — bitwise-identical output."""
    G = grid.G
    tk = plan.tiling.kernel_kwargs()
    coo = _coo(plan, *s)
    out_cur = jnp.zeros((plan.mS, plan.rc), jnp.float32)
    contrib = ops.spmm(coo, bchunks[0], m=plan.mS, **tk)
    for t in range(G):
        out_cur = _shift_back(out_cur + contrib, grid.col, G)
        if t + 1 < G:
            contrib = ops.spmm(coo, bchunks[t + 1], m=plan.mS, **tk)
    return out_cur


@functools.partial(jax.jit, static_argnums=(0,))
def spmma_s25(grid: Grid25, plan: PlanS25, B_sk):
    """A = S @ B; output chunks end in skewed-home layout."""
    G, fib = grid.G, grid.fiber

    def body(s, _A, B_loc):
        rl, cl, vshard, tb = tuple(x[0, 0, 0] for x in s)
        vals = jax.lax.all_gather(vshard, fib, tiled=True)   # (nb, k)
        out = _spmm_round(grid, plan, (rl, cl, vals, tb), B_loc[0, 0, 0])
        return out[None, None, None]

    dummy = jnp.zeros((grid.G, grid.G, grid.c, 1, 1), jnp.float32)
    return _exec(grid, plan, body, dummy, B_sk,
                 P(grid.row, grid.col, grid.fiber))


def resolve_elision(elision: str) -> str:
    """Resolve the uniform ``"auto"`` default: B-chunk "reuse" beats the
    unoptimized round at every (p, c, phi) — same fiber value traffic,
    one fewer dense-chunk trip (3 vs 4 Table-III units)."""
    if elision != "auto":
        return elision
    return "reuse"


def schedule_events(grid: Grid25, op: str, elision: str = "none"):
    """Ordered (point, phase) fault boundaries of one executor round.

    s25 replicates the *structure*, never a dense operand — no gather
    events.  Each round is G phase/shift pairs of traveling dense
    chunks; the SDDMM half ends in the cross-fiber partial-sum
    reduce-scatter (the very barrier that makes "fused" impossible
    here), and FusedMM chains both halves (repro.distributed.faults).
    """
    G = grid.G

    def passes(n, start=0):
        out = []
        for t in range(start, start + n * G):
            out += [("phase", t), ("shift", t)]
        return out

    if op == "sddmm":
        return passes(1) + [("reduce", G - 1)]
    if op in ("spmm", "spmm_t"):     # spmm_t = spmm on the S^T problem
        return passes(1)
    if op == "fusedmm":              # SDDMM pass, RS barrier, SpMM pass
        return passes(1) + [("reduce", G - 1)] + passes(1, start=G)
    raise ValueError(f"unknown op {op!r}")


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("elision",))
def fusedmm_s25(grid: Grid25, plan: PlanS25, A_sk, B_sk,
                elision: str = "auto"):
    """FusedMMA on the 2.5D sparse-replicating grid.

    elision="auto" : resolves to "reuse" (see resolve_elision)
    elision="none" : A and B travel in the SDDMM round, out and B in the
                     SpMM round — 4 dense-chunk trips.
    elision="reuse": the SpMM round replays the B r-chunks cached
                     locally during the SDDMM round (B's two schedules
                     coincide, period G), eliding B's second trip: 3
                     dense-chunk trips, bitwise-identical output.
    elision="fused": structurally impossible — rejected.  Per-phase dots
                     cover only the resident r/(Gc) chunk, and the
                     partial sums must cross the fiber (RS + AG) before
                     any SpMM can consume them; with S stationary there
                     is no structure communication to elide either (the
                     paper's "no elision possible", docs/algorithms.md).

    Fiber traffic in every cell is values-only: AG(vals) happens
    implicitly by computing partials, RS reduces them home, AG
    re-broadcasts the final values for the SpMM round — the
    3*phi*nr*(c-1)/p term of Table III.
    Returns (out chunks (G,G,c,mS,rc) skewed-home, R values fiber-sharded).
    """
    elision = resolve_elision(elision)
    if elision not in ("none", "reuse"):
        raise ValueError(f"s25 supports ('none', 'reuse'), got "
                         f"{elision!r} (local fusion is structurally "
                         f"impossible here — see docs/algorithms.md)")
    G, fib = grid.G, grid.fiber

    def body(s, A_loc, B_loc):
        s = tuple(x[0, 0, 0] for x in s)
        rl, cl, vshard, tb = s
        partial, A_home, B_home, bchunks = _sddmm_round(grid, plan, s,
                                                        A_loc[0, 0, 0],
                                                        B_loc[0, 0, 0])
        mine = jax.lax.psum_scatter(partial, fib, scatter_dimension=0,
                                    tiled=True)                  # RS
        r_mine = vshard * mine
        r_vals = jax.lax.all_gather(r_mine, fib, tiled=True)     # AG
        if elision == "reuse":
            out = _spmm_round_cached(grid, plan, (rl, cl, r_vals, tb),
                                     bchunks)
        else:
            out = _spmm_round(grid, plan, (rl, cl, r_vals, tb), B_home)
        return out[None, None, None], r_mine[None, None, None]

    return _exec(grid, plan, body, A_sk, B_sk,
                 (P(grid.row, grid.col, grid.fiber),
                  P(grid.row, grid.col, grid.fiber)))
