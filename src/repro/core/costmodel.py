"""Alpha-beta-gamma communication model + local-kernel tiling model.

Implements the paper's Table III (latency/bandwidth costs per algorithm,
embedded in the FusedMM procedure) and Table IV (optimal replication
factors), plus the regime-selection rule of §V-E: sparse-shifting /
sparse-replicating algorithms win for low phi = nnz(S)/(n*r); dense-shifting
/ dense-replicating win for high phi.

All word counts are *per processor* (the max over processors, assuming the
random-permutation load balancing of §VI), matching the paper's "maximum
amount of time any processor spends sending and receiving".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import numpy as np

ALGORITHMS = (
    "d15_no_elision",        # 1.5D dense shift, unoptimized SDDMM;SpMM
    "d15_replication_reuse", # 1.5D dense shift + replication reuse
    "d15_local_fusion",      # 1.5D dense shift + local kernel fusion
    "s15_no_elision",        # 1.5D sparse shift, unoptimized baseline
    "s15_replication_reuse", # 1.5D sparse shift + replication reuse
    "s15_local_fusion",      # 1.5D sparse shift + one-structure-pass
    "d25_no_elision",        # 2.5D dense replicating, unoptimized
    "d25_replication_reuse", # 2.5D dense replicating + replication reuse
    "d25_local_fusion",      # 2.5D dense replicating + one-structure-pass
    "s25_no_elision",        # 2.5D sparse replicating, unoptimized
    "s25_replication_reuse", # 2.5D sparse replicating + B-chunk reuse
)

# Table-III algorithm name -> (executor family, elision strategy).  The
# families are the four implementations behind repro.core.api; elision is
# the FusedMM strategy the family executor takes as its static argument.
# The grid is full rank: every (family, elision) cell a registry entry
# declares has exactly one word-count row here (docs/algorithms.md
# derives the formulas; rows beyond the paper's Table III price the
# one-structure-pass "fused" cells and s25's B-chunk "reuse").  The one
# structurally impossible cell — s25 "fused" — has no row because no
# executor can exist for it (see docs/algorithms.md).
FAMILY_ELISION = {
    "d15_no_elision": ("d15", "none"),
    "d15_replication_reuse": ("d15", "reuse"),
    "d15_local_fusion": ("d15", "fused"),
    "s15_no_elision": ("s15", "none"),
    "s15_replication_reuse": ("s15", "reuse"),
    "s15_local_fusion": ("s15", "fused"),
    "d25_no_elision": ("d25", "none"),
    "d25_replication_reuse": ("d25", "reuse"),
    "d25_local_fusion": ("d25", "fused"),
    "s25_no_elision": ("s25", "none"),
    "s25_replication_reuse": ("s25", "reuse"),
}

# inverse of FAMILY_ELISION: (family, elision) -> Table-III row name.
# Sound because the grid is full rank with exactly one row per cell.
ELISION_COST_NAME = {fe: name for name, fe in FAMILY_ELISION.items()}

FAMILIES = ("d15", "s15", "d25", "s25")


@dataclasses.dataclass(frozen=True)
class CommCost:
    algorithm: str
    p: int
    c: int
    words: float      # words sent+received per processor (beta term)
    messages: float   # message count (alpha term)
    phi: float

    def time(self, alpha: float, beta: float) -> float:
        return self.alpha_time(alpha) + self.beta_time(beta)

    def alpha_time(self, alpha: float) -> float:
        return alpha * self.messages

    def beta_time(self, beta: float) -> float:
        return beta * self.words


def _check(p: int, c: int):
    if c < 1 or p % c:
        raise ValueError(f"replication factor c={c} must divide p={p}")


def words_fusedmm(algorithm: str, *, p: int, c: int, n: int, r: int,
                  nnz: int) -> CommCost:
    """Words communicated per processor for a FusedMM call (Table III)."""
    _check(p, c)
    phi = nnz / (n * r)
    if algorithm == "d15_no_elision":
        words = n * r * (2.0 / c + 2.0 * (c - 1) / p)
        msgs = 2 * p / c + 2 * (c - 1)
    elif algorithm == "d15_replication_reuse":
        words = n * r * (2.0 / c + (c - 1) / p)
        msgs = 2 * p / c + (c - 1)
    elif algorithm == "d15_local_fusion":
        words = n * r * (1.0 / c + 2.0 * (c - 1) / p)
        msgs = p / c + 2 * (c - 1)
    elif algorithm == "s15_no_elision":
        # two full COO propagation rounds (3 words/nnz each) and the
        # dense column slices re-gathered between the kernel launches
        words = n * r * (6.0 * phi / c + 2.0 * (c - 1) / p)
        msgs = 2 * p / c + 2 * (c - 1)
    elif algorithm == "s15_replication_reuse":
        words = n * r * (6.0 * phi / c + (c - 1) / p)
        msgs = 2 * p / c + (c - 1)
    elif algorithm == "s15_local_fusion":
        # one-structure-pass: the SpMM round replays the locally cached
        # per-phase coordinate structure, so only the final values travel
        # (1 word/nnz/phase instead of 3): 6*phi -> 4*phi
        words = n * r * (4.0 * phi / c + (c - 1) / p)
        msgs = 2 * p / c + (c - 1)
    elif algorithm == "d25_no_elision":
        sq = math.sqrt(p / c)
        words = n * r / math.sqrt(p * c) * (6 * phi + 2) \
            + 2 * n * r * (c - 1) / p
        msgs = 4 * sq + 2 * (c - 1)
    elif algorithm == "d25_replication_reuse":
        sq = math.sqrt(p / c)
        words = n * r / math.sqrt(p * c) * (6 * phi + 2) \
            + n * r * (c - 1) / p
        msgs = 4 * sq + (c - 1)
    elif algorithm == "d25_local_fusion":
        # one-structure-pass on the Cannon grid: round 2 replays cached
        # structure AND cached B chunks, shifting only the final values —
        # 6*phi+2 -> 4*phi+1 on the shift term; AG in + RS out retained
        sq = math.sqrt(p / c)
        words = n * r / math.sqrt(p * c) * (4 * phi + 1) \
            + 2 * n * r * (c - 1) / p
        msgs = 4 * sq + 2 * (c - 1)
    elif algorithm == "s25_no_elision":
        sq = math.sqrt(p / c)
        words = n * r / math.sqrt(p) * 4.0 / math.sqrt(c) \
            + 3.0 * phi * n * r * (c - 1) / p
        msgs = 4 * sq + 3 * (c - 1)
    elif algorithm == "s25_replication_reuse":
        # the SpMM round replays the B r-chunks cached during the SDDMM
        # round instead of re-shifting them: 4 -> 3 dense-chunk units
        sq = math.sqrt(p / c)
        words = n * r / math.sqrt(p * c) * 3.0 \
            + 3.0 * phi * n * r * (c - 1) / p
        msgs = 3 * sq + 3 * (c - 1)
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return CommCost(algorithm, p, c, words, msgs, phi)


# Fraction of a cell's replication term an api.Session elides in steady
# state.  The Session caches the fiber all-gather of the *stationary*
# (second, by convention) dense operand across calls.  Cells whose
# gathered operand is the changing first one (d15/d25 "none"/"fused")
# save nothing; the FusedMMB "reuse" cells gather exactly the stationary
# operand (full saving); s15 gathers both operands through the Session,
# so only the stationary half of its replication term is cacheable; s25
# replicates nothing dense.  See docs/choosing.md for the derivation.
SESSION_CACHEABLE = {
    "d15_replication_reuse": 1.0,
    "d25_replication_reuse": 1.0,
    "s15_no_elision": 0.5,
    "s15_replication_reuse": 0.5,
    "s15_local_fusion": 0.5,
}


def words_fusedmm_cached(algorithm: str, *, p: int, c: int, n: int, r: int,
                         nnz: int) -> CommCost:
    """Steady-state per-call words with an :class:`repro.core.api.Session`
    holding the stationary operand's replication (docs/choosing.md).

    Subtracts the cacheable share of the cell's ``n*r*(c-1)/p``
    replication term from :func:`words_fusedmm`; the shift words are
    never cacheable (the traveling operand changes every call).
    """
    cost = words_fusedmm(algorithm, p=p, c=c, n=n, r=r, nnz=nnz)
    frac = SESSION_CACHEABLE.get(algorithm, 0.0)
    saved = frac * n * r * (c - 1) / p
    return dataclasses.replace(cost, words=max(cost.words - saved, 0.0),
                               messages=max(cost.messages - frac * (c - 1),
                                            0.0))


def words_spmm(family: str, *, p: int, c: int, n: int, r: int,
               nnz: int) -> CommCost:
    """Words per processor for ONE distributed SpMM (or SpMM^T) round.

    Table III embeds two kernel rounds in every FusedMM row; these are
    the single-round costs, needed to price the backward pass — each
    transpose-SpMM of a VJP is one such round on the same grid.  By the
    paper's SpMM<->SDDMM duality the transpose orientation ships the
    same words (the traveling/replicated roles are symmetric).
    """
    _check(p, c)
    phi = nnz / (n * r)
    if family == "d15":
        words = n * r * (1.0 / c + (c - 1) / p)
        msgs = p / c + (c - 1)
    elif family == "s15":
        words = n * r * (3.0 * phi / c + (c - 1) / p)
        msgs = p / c + (c - 1)
    elif family == "d25":
        sq = math.sqrt(p / c)
        words = n * r * (3 * phi + 1) / math.sqrt(p * c) \
            + n * r * (c - 1) / p
        msgs = 2 * sq + (c - 1)
    elif family == "s25":
        sq = math.sqrt(p / c)
        words = n * r * 2.0 / math.sqrt(p * c) \
            + phi * n * r * (c - 1) / p
        msgs = 2 * sq + (c - 1)
    else:
        raise ValueError(f"unknown family {family!r}")
    return CommCost(f"{family}_spmm", p, c, words, msgs, phi)


# ---------------------------------------------------------------------------
# Sparsity-aware communication (comm="sparse") — nnz-dependent words
# ---------------------------------------------------------------------------
#
# Support pruning ships only the rows of a dense input operand that the
# receiver's nonzeros read (SpComm3D's observation, PAPERS.md).  The
# pruned channels per family are exactly the implementation's
# (docs/algorithms.md "Sparse communication"):
#
#   d15: fiber AG of the replicated operand; traveling B input chunks
#        (both FusedMM rounds where B travels — never the traveling
#        FusedMMB/SpMMB *output* accumulator, whose FP order is exact)
#   s15: both fiber all-gathers of the dense column slabs (the COO pack
#        shifts are already 3 words/nnz — nothing dense travels)
#   d25: fiber AG of A; traveling B input chunks on the Cannon rows
#   s25: traveling A and B input r-chunks (nothing dense is replicated;
#        fiber traffic is values-only and stays exact)
#
# Reduce-scatters and traveling accumulators always stay dense.  The
# formulas below take the measured support densities rho_row/rho_col
# (fraction of rows/cols of S with at least one nonzero) and price each
# pruned channel at rho x its dense words; they are per-processor and
# channel-exact against the implementation up to padding (per-offset
# supports pad to the max over devices) and locality (per-device block
# supports are smaller than the global rho), so measured wire words land
# slightly *below* these estimates on skewed matrices.

SPARSE_CROSSOVER = 0.9
"""Per-channel fallback threshold: a channel ships pruned only when its
padded support words are below this fraction of its dense words —
otherwise index+pad overhead makes pruning a loss and the planner keeps
the dense schedule for that channel (recorded in the plan's SparseMeta)."""


def support_density(rows, cols, m: int, n: int):
    """(rho_row, rho_col): fraction of rows/cols of S that are nonempty.

    The cheap host-side statistic ``comm="auto"`` decides from — an upper
    bound on every per-device support density (a device's support is the
    union over only *its* blocks' nonzeros).
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    rho_r = (np.unique(rows).size / m) if m else 1.0
    rho_c = (np.unique(cols).size / n) if n else 1.0
    return float(rho_r), float(rho_c)


def choose_comm(rows, cols, m: int, n: int) -> str:
    """The ``comm="auto"`` rule: prune when *either* support is sparse.

    One sparse side is enough — each channel falls back to dense
    independently (SPARSE_CROSSOVER), so a matrix with full column
    support but skewed row support still wins on its gather channels.
    See docs/choosing.md.
    """
    rho_r, rho_c = support_density(rows, cols, m, n)
    return "sparse" if min(rho_r, rho_c) <= SPARSE_CROSSOVER else "dense"


def words_fusedmm_sparse(algorithm: str, *, p: int, c: int, m: int, n: int,
                         r: int, nnz: int, rho_row: float,
                         rho_col: float) -> CommCost:
    """Per-processor FusedMM words under comm="sparse" (channel-exact).

    Mirrors the implementation's channel inventory (module comment):
    dense-channel terms match :func:`words_fusedmm`'s Table-III rows at
    rho = 1; pruned channels scale by the support density of the axis
    that indexes them (the gathered operand by ``rho_row`` of S — its
    rows index the replicated matrix — and the traveling B chunks by
    ``rho_col``).  ``m``/``n`` are S's dims (the existing dense model
    assumes square; this one does not need to).
    """
    _check(p, c)
    phi = nnz / (n * r)
    L = p // c
    G = int(math.isqrt(p // c)) if p // c else 1
    ra, rb = rho_row, rho_col
    if algorithm.startswith("d15"):
        ag = (c - 1) * (m // p) * r          # one dense AG/RS unit
        rnd = max(L - 1, 0) * (n // p) * r   # one dense-B trip round
        out = L * (n // p) * r               # FusedMMB output trips
        words = {"d15_no_elision": ag * (1 + ra) + 2 * rnd * rb,
                 "d15_replication_reuse": ag * ra + rnd * rb + out,
                 "d15_local_fusion": ag * (1 + ra) + rnd * rb,
                 }[algorithm]
        msgs = 2 * (c - 1) + {"d15_no_elision": 2 * max(L - 1, 0),
                              "d15_replication_reuse": max(L - 1, 0) + L,
                              "d15_local_fusion": max(L - 1, 0)}[algorithm]
    elif algorithm.startswith("s15"):
        gth_a = (c - 1) * m * (r // p)       # one dense column-slab AG
        gth_b = (c - 1) * n * (r // p)
        shift = words_fusedmm(algorithm, p=p, c=c, n=n, r=r, nnz=nnz).words \
            - n * r * (2 if algorithm == "s15_no_elision" else 1) * (c - 1) / p
        n_gb = 2 if algorithm == "s15_no_elision" else 1
        words = shift + gth_a * ra + n_gb * gth_b * rb
        msgs = (1 + n_gb) * (c - 1) + 2 * p / c
    elif algorithm.startswith("d25"):
        mA, nS, rW = m // (G * c), n // (G * c), r // G
        ag = (c - 1) * mA * rW               # AG unit (RS same, dense)
        rnd = max(G - 1, 0) * nS * rW        # one dense-B trip round
        out = G * nS * rW
        coo = words_fusedmm(algorithm, p=p, c=c, n=n, r=r, nnz=nnz).words
        # strip the dense model's AG/RS and dense-chunk terms, keep COO
        dense_units = {"d25_no_elision": (2, 2), "d25_local_fusion": (2, 1),
                       "d25_replication_reuse": (1, 1)}[algorithm]
        coo -= dense_units[0] * n * r * (c - 1) / p
        coo -= (dense_units[1] * G * nS * rW
                if algorithm != "d25_replication_reuse" else G * nS * rW)
        coo = max(coo, 0.0)
        words = {"d25_no_elision": ag * (1 + ra) + 2 * rnd * rb,
                 "d25_replication_reuse": ag * ra + rnd * rb + out,
                 "d25_local_fusion": ag * (1 + ra) + rnd * rb,
                 }[algorithm] + coo
        msgs = words_fusedmm(algorithm, p=p, c=c, n=n, r=r, nnz=nnz).messages
    elif algorithm.startswith("s25"):
        mS, nS, rc = m // G, n // G, r // (G * c)
        a_rnd = max(G - 1, 0) * mS * rc      # one A-chunk trip round
        b_rnd = max(G - 1, 0) * nS * rc
        out = G * mS * rc                    # output trips (dense)
        vals = 3.0 * phi * n * r * (c - 1) / p   # fiber values (dense)
        n_b = 2 if algorithm == "s25_no_elision" else 1
        words = a_rnd * ra + n_b * b_rnd * rb + out + vals
        msgs = words_fusedmm(algorithm, p=p, c=c, n=n, r=r, nnz=nnz).messages
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return CommCost(f"{algorithm}_sparse", p, c, float(words), float(msgs),
                    phi)


def words_spmm_sparse(family: str, *, p: int, c: int, m: int, n: int,
                      r: int, nnz: int, rho_row: float,
                      rho_col: float) -> CommCost:
    """Per-processor words of ONE SpMM round under comm="sparse"."""
    _check(p, c)
    phi = nnz / (n * r)
    L = p // c
    G = int(math.isqrt(p // c)) if p // c else 1
    dense = words_spmm(family, p=p, c=c, n=n, r=r, nnz=nnz)
    if family == "d15":      # B trip pruned; RS stays dense
        words = (c - 1) * (m // p) * r + max(L - 1, 0) * (n // p) * r \
            * rho_col
    elif family == "s15":    # one gather pruned; COO trip already sparse
        words = dense.words - n * r * (c - 1) / p \
            + rho_col * (c - 1) * n * (r // p)
    elif family == "d25":    # B trips pruned; RS dense; COO kept
        nS, rW = n // (G * c), r // G
        words = dense.words - G * nS * rW + max(G - 1, 0) * nS * rW * rho_col
    elif family == "s25":    # B trips pruned; output + values dense
        mS, nS, rc = m // G, n // G, r // (G * c)
        words = G * mS * rc + max(G - 1, 0) * nS * rc * rho_col \
            + phi * n * r * (c - 1) / p
    else:
        raise ValueError(f"unknown family {family!r}")
    return CommCost(f"{family}_spmm_sparse", p, c, float(words),
                    float(dense.messages), phi)


# Replication units (of n*r*(c-1)/p words) a Session elides from the
# BACKWARD pass when the same Session that served the forward is threaded
# through the VJP (repro.core.grads): the backward's dual FusedMM finds
# the stationary operand's fiber replication already resident (gathered
# by the forward), and the SpMM^T that gathers the forward's replicated
# operand X replays it too.  d15/d25/s15 each elide two gathers (one in
# the dual FusedMM, one in a transpose-SpMM); s25 replicates nothing
# dense, so a Session elides nothing there.  Distinct from
# SESSION_CACHEABLE, which models the *across-call* steady state used by
# elision="auto" ranking — this is the *within-step* fwd->bwd replay.
SESSION_BWD_ELIDED = {"d15": 2.0, "s15": 2.0, "d25": 2.0, "s25": 0.0}


def words_fusedmm_bwd(algorithm: str, *, p: int, c: int, n: int, r: int,
                      nnz: int, session: bool = False) -> CommCost:
    """Words per processor for the BACKWARD of one FusedMM call.

    The VJP (repro.core.grads) is built from dual primitives on the same
    pack and cell: grad-wrt-X is the SAME FusedMM cell with the output
    cotangent in X's slot (one Table-III row), and grad-wrt-Y is two
    transpose-SpMMs (R^T g and Ghat^T X) — so

        bwd = words_fusedmm(cell) + 2 * words_spmm(family)

    and forward and backward provably ship the same words per primitive.
    ``session=True`` credits the within-step replication replay
    (SESSION_BWD_ELIDED): the forward's fiber gathers are reused by the
    backward instead of re-communicated.
    """
    family, _ = FAMILY_ELISION[algorithm]
    fm = words_fusedmm(algorithm, p=p, c=c, n=n, r=r, nnz=nnz)
    sp = words_spmm(family, p=p, c=c, n=n, r=r, nnz=nnz)
    words = fm.words + 2 * sp.words
    msgs = fm.messages + 2 * sp.messages
    if session:
        units = SESSION_BWD_ELIDED[family]
        words = max(words - units * n * r * (c - 1) / p, 0.0)
        msgs = max(msgs - units * (c - 1), 0.0)
    return CommCost(f"{algorithm}_bwd", p, c, words, msgs, fm.phi)


def words_trainstep(algorithm: str, *, p: int, c: int, n: int, r: int,
                    nnz: int, session: bool = False) -> CommCost:
    """Words per processor for one training step: forward FusedMM plus
    its dual-primitive backward (words_fusedmm_bwd).  The forward always
    pays its full replication (it fills the Session); only the backward
    is credited the replay."""
    fwd = words_fusedmm(algorithm, p=p, c=c, n=n, r=r, nnz=nnz)
    bwd = words_fusedmm_bwd(algorithm, p=p, c=c, n=n, r=r, nnz=nnz,
                            session=session)
    return CommCost(f"{algorithm}_trainstep", p, c, fwd.words + bwd.words,
                    fwd.messages + bwd.messages, fwd.phi)


def optimal_c(algorithm: str, *, p: int, phi: float = 0.0) -> float:
    """Closed-form optimal replication factor (Table IV, continuous)."""
    if algorithm == "d15_no_elision":
        return math.sqrt(p)
    if algorithm == "d15_replication_reuse":
        return math.sqrt(2 * p)
    if algorithm == "d15_local_fusion":
        return math.sqrt(p / 2)
    if algorithm == "s15_no_elision":
        return math.sqrt(3 * p * phi)
    if algorithm == "s15_replication_reuse":
        return math.sqrt(6 * p * phi)
    if algorithm == "s15_local_fusion":
        return 2 * math.sqrt(p * phi)
    if algorithm == "d25_no_elision":
        return (p * (1 + 3 * phi) ** 2 / 4) ** (1 / 3)
    if algorithm == "d25_replication_reuse":
        return (p * (1 + 3 * phi) ** 2) ** (1 / 3)
    if algorithm == "d25_local_fusion":
        return (p * (1 + 4 * phi) ** 2 / 16) ** (1 / 3)
    if algorithm == "s25_no_elision":
        # argmin_c of 4/sqrt(pc) + 3*phi*c/p: c* = (4p/(9 phi^2))^(1/3)
        return (p / (3 * phi / 2) ** 2) ** (1 / 3) if phi > 0 else float(p)
    if algorithm == "s25_replication_reuse":
        return (p / (2 * phi) ** 2) ** (1 / 3) if phi > 0 else float(p)
    raise ValueError(f"unknown algorithm {algorithm!r}")


# Training-step coefficient table: per-processor trainstep words / (n r)
#   1.5D cells:  A/c          + B (c-1)/p
#   2.5D cells:  A/sqrt(p c)  + B (c-1)/p
# with A = a0 + a_phi * phi and B = b0 + b_phi * phi.  Derived by summing
# words_fusedmm + words_fusedmm_bwd (= 2x fusedmm + 2x spmm) per cell;
# kept closed-form so optimal_c_trainstep stays analytic like Table IV.
_TRAINSTEP_COEFS = {
    "d15_no_elision":        (6.0, 0.0, 6.0, 0.0),
    "d15_replication_reuse": (6.0, 0.0, 4.0, 0.0),
    "d15_local_fusion":      (4.0, 0.0, 6.0, 0.0),
    "s15_no_elision":        (0.0, 18.0, 6.0, 0.0),
    "s15_replication_reuse": (0.0, 18.0, 4.0, 0.0),
    "s15_local_fusion":      (0.0, 14.0, 4.0, 0.0),
    "d25_no_elision":        (6.0, 18.0, 6.0, 0.0),
    "d25_replication_reuse": (6.0, 18.0, 4.0, 0.0),
    "d25_local_fusion":      (4.0, 14.0, 6.0, 0.0),
    "s25_no_elision":        (12.0, 0.0, 0.0, 8.0),
    "s25_replication_reuse": (10.0, 0.0, 0.0, 8.0),
}


def optimal_c_trainstep(algorithm: str, *, p: int, phi: float = 0.0,
                        session: bool = False) -> float:
    """Closed-form optimal replication factor for a TRAINING STEP.

    The backward pass doubles the dense traffic (the dual FusedMM plus
    two transpose-SpMMs re-ship the dense operands), which shifts the
    optimum away from Table IV's forward-only c*: e.g. d15 "reuse" drops
    from sqrt(2p) to sqrt(1.5p) — the extra backward shift words punish
    large c harder than the (session-elidable) replication does.
    ``session=True`` removes the backward's replayed gathers
    (SESSION_BWD_ELIDED), pushing c* back up.
    """
    if algorithm not in _TRAINSTEP_COEFS:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    a0, a_phi, b0, b_phi = _TRAINSTEP_COEFS[algorithm]
    family, _ = FAMILY_ELISION[algorithm]
    a = a0 + a_phi * phi
    b = b0 + b_phi * phi
    if session:
        b = b - SESSION_BWD_ELIDED[family]
    if b <= 0 or a <= 0:
        return float(p)
    if family in ("d15", "s15"):
        return math.sqrt(a * p / b)
    return (a * a * p / (4 * b * b)) ** (1 / 3)


def feasible_cs(algorithm: str, p: int, r: int = 0):
    """Integer replication factors the algorithm supports on p processors."""
    out = []
    for c in range(1, p + 1):
        if p % c:
            continue
        if algorithm.startswith(("d25", "s25")):
            q = p // c
            s = math.isqrt(q)
            if s * s != q:
                continue
        out.append(c)
    return out


def best_c(algorithm: str, *, p: int, n: int, r: int, nnz: int) -> CommCost:
    """Best feasible integer c by exhaustive evaluation of Table III."""
    best = None
    for c in feasible_cs(algorithm, p):
        cost = words_fusedmm(algorithm, p=p, c=c, n=n, r=r, nnz=nnz)
        if best is None or cost.words < best.words:
            best = cost
    if best is None:
        raise ValueError(f"no feasible c for {algorithm} at p={p}")
    return best


def select_algorithm(*, p: int, n: int, r: int, nnz: int,
                     candidates=ALGORITHMS) -> Dict[str, CommCost]:
    """Rank candidate algorithms at their best c (the paper's Fig. 6 rule)."""
    costs = {}
    for alg in candidates:
        try:
            costs[alg] = best_c(alg, p=p, n=n, r=r, nnz=nnz)
        except ValueError:
            continue
    return dict(sorted(costs.items(), key=lambda kv: kv[1].words))


def family_feasible(family: str, *, m: int, n: int, r: int, p: int,
                    c: int) -> bool:
    """Can `family` run (m x n, width r) on p processors at replication c?

    Mirrors the divisibility asserted by the planners in repro.core:
      d15: m % p == 0 and n % p == 0          (dense row blocks)
      s15: m % p == 0 and r % p == 0          (column-split dense)
      d25: p/c a perfect square G^2, m,n % Gc == 0 and r % G == 0
      s25: p/c a perfect square G^2, m,n % G == 0 and r % Gc == 0
    """
    if c < 1 or p % c:
        return False
    if family == "d15":
        return m % p == 0 and n % p == 0
    if family == "s15":
        return m % p == 0 and r % p == 0
    if family in ("d25", "s25"):
        g = math.isqrt(p // c)
        if g * g * c != p:
            return False
        if family == "d25":
            return m % (g * c) == 0 and n % (g * c) == 0 and r % g == 0
        return m % g == 0 and n % g == 0 and r % (g * c) == 0
    raise ValueError(f"unknown family {family!r}")


@dataclasses.dataclass(frozen=True)
class AlgorithmChoice:
    """Result of the `algorithm="auto"` dispatch rule (paper Fig. 6)."""
    family: str       # one of FAMILIES — the executor module to use
    elision: str      # FusedMM strategy for that family
    c: int            # replication factor
    cost: CommCost    # Table-III words/messages at (family, elision, c)


def choose_algorithm(*, m: int, n: int, nnz: int, r: int, p: int,
                     c: int | None = None,
                     families=FAMILIES) -> AlgorithmChoice:
    """Pick the cheapest feasible (family, elision, c) by Table III.

    Implements the paper's bandwidth-cost dispatch: evaluate the per-
    processor word count of every Table-III algorithm at every feasible
    replication factor (or at the caller-pinned `c`), filter by the
    planners' divisibility constraints, and return the minimizer.  Low
    phi = nnz/(n*r) favors the sparse-shifting/replicating families,
    high phi the dense ones (Fig. 6).
    """
    best = None
    for name in ALGORITHMS:
        family, elision = FAMILY_ELISION[name]
        if family not in families:
            continue
        cs = [c] if c is not None else list(range(1, p + 1))
        for ci in cs:
            if p % ci or not family_feasible(family, m=m, n=n, r=r, p=p,
                                             c=ci):
                continue
            cost = words_fusedmm(name, p=p, c=ci, n=n, r=r, nnz=nnz)
            if best is None or cost.words < best.cost.words:
                best = AlgorithmChoice(family, elision, ci, cost)
    if best is None:
        raise ValueError(
            f"no feasible algorithm for m={m} n={n} r={r} p={p} c={c} "
            f"among families {families}")
    return best


def flops_fusedmm(nnz: int, r: int) -> int:
    """Local FLOPs for one FusedMM: SDDMM (2r per nnz) + SpMM (2r per nnz)."""
    return 4 * nnz * r


# ---------------------------------------------------------------------------
# Local kernel tiling model (VMEM residency + grid amortization)
# ---------------------------------------------------------------------------

# Per-core VMEM on current TPUs is ~16 MiB; leave half for Pallas double
# buffering, semaphores and the compiler's own temporaries.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

# Target contraction depth of the one-hot matmul: the MXU is 128x128, so
# K >= 256 keeps the systolic array busy; beyond ~1024 the gather cost of
# the nonzero rows dominates.
_TARGET_STEP_NNZ = 512


@dataclasses.dataclass(frozen=True)
class Tiling:
    """Static tiling knobs for the local Pallas kernels.

    r_tile           -- width of the embedding-dimension slab brought into
                        VMEM per grid step (divides r)
    blocks_per_step  -- how many nonzero blocks one grid step consumes
                        (divides nblocks; all blocks of a step must share a
                        tile_base, see sparse.pack_row_tiled(group=...))
    """
    r_tile: int
    blocks_per_step: int

    def kernel_kwargs(self) -> dict:
        """Keyword arguments for the ops.py kernel wrappers."""
        return dict(r_tile=self.r_tile, blocks_per_step=self.blocks_per_step)


def _divisors_desc(x: int):
    return sorted((d for d in range(1, x + 1) if x % d == 0), reverse=True)


def groupable_blocks_per_step(tile_base, nz_block: int, *,
                              cap: int | None = None) -> int:
    """Largest feasible blocks_per_step for a concrete pack.

    ``tile_base`` is a (..., nb) array of per-block window bases; a group
    size g is feasible iff every aligned run of g consecutive blocks (in
    every leading slot) shares one base, so a single output window covers
    the whole grid step.  Returns the largest feasible divisor of nb whose
    merged step stays near the MXU-friendly contraction depth.
    """
    tb = np.asarray(tile_base)
    nb = tb.shape[-1]
    if nb == 0:
        return 1
    flat = tb.reshape(-1, nb)
    cap = cap if cap is not None else max(_TARGET_STEP_NNZ // max(nz_block, 1),
                                          1)
    for g in _divisors_desc(nb):
        if g > cap:
            continue
        groups = flat.reshape(flat.shape[0], nb // g, g)
        if bool((groups == groups[..., :1]).all()):
            return g
    return 1


def choose_tiling(*, n_b: int, r: int, nb: int, k: int, row_tile: int,
                  itemsize: int = 4,
                  vmem_budget: int = VMEM_BUDGET_BYTES,
                  tile_base=None) -> Tiling:
    """Pick (r_tile, blocks_per_step) from VMEM budget and pack statistics.

    The dominant VMEM resident per grid step is the local B tile slab
    (n_b x r_tile) plus one (row_tile x r_tile) window each for the
    gathered-A / accumulator sides, all double-buffered by the Pallas
    pipeline.  r_tile is the largest divisor of r that fits; the lane width
    (128) is preferred as a lower bound so slabs stay MXU-aligned.

    blocks_per_step amortizes grid/dispatch overhead for small-k packs and
    deepens the one-hot matmul contraction; it is only raised when a
    concrete ``tile_base`` proves the pack groupable (traced packs fall
    back to 1 — distributed planners pass pack stats at plan time).
    """
    per_col = 2 * (n_b + 2 * row_tile) * itemsize  # x2: double buffering
    r_tile = r
    for d in _divisors_desc(r):
        r_tile = d
        if d * per_col <= vmem_budget or d <= 128:
            break
    if tile_base is None:
        bps = 1
    else:
        bps = groupable_blocks_per_step(tile_base, k)
        if nb % bps:
            bps = 1
    return Tiling(r_tile=r_tile, blocks_per_step=bps)
