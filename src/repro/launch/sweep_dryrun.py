"""Run the full dry-run sweep: every runnable (arch x shape x mesh) cell.

Each cell runs in its own subprocess (compile-memory isolation; a single
OOM or crash marks that cell failed without killing the sweep).  Results
land in results/dryrun/<arch>__<shape>__<mesh>.json plus a summary JSONL.

  PYTHONPATH=src python -m repro.launch.sweep_dryrun [--only-single-pod]

``--fusedmm`` sweeps the paper's distributed FusedMM cells instead: every
algorithm registered in repro.core.api x its supported elisions, each
cell one `dryrun_fusedmm` subprocess — the sweep itself never branches
per family.

  PYTHONPATH=src python -m repro.launch.sweep_dryrun --fusedmm \
      [--fusedmm-m 1048576] [--fusedmm-r 256]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "jamba-v0.1-52b", "stablelm-1.6b", "llama3.2-1b", "qwen3-1.7b",
    "qwen3-4b", "qwen2-vl-72b", "mamba2-1.3b", "deepseek-v2-lite-16b",
    "phi3.5-moe-42b-a6.6b", "hubert-xlarge",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# gradient-accumulation depth per arch (train cells): larger models need
# smaller micro-tokens to fit activation working sets in 16 GB HBM
MICROBATCH = {
    "jamba-v0.1-52b": 16, "qwen2-vl-72b": 16, "phi3.5-moe-42b-a6.6b": 8,
    "deepseek-v2-lite-16b": 8, "qwen3-4b": 8,
}


ELISIONS = ("none", "reuse", "fused")


def fusedmm_cells():
    """The FULL (algo, elision) grid with per-cell support status.

    Sweeps every family x {none, reuse, fused} cell — not just the
    registry-declared ones — so structurally impossible cells (s25
    "fused") appear as explicit skip records in the summary instead of
    being silently omitted; docs/algorithms.md's feasibility table is
    regenerable from the sweep output.  No per-family branching: a new
    registered algorithm appears here automatically.
    """
    from repro.core import api
    return [(name, el, el in api.ALGORITHMS[name].elisions)
            for name in sorted(api.ALGORITHMS) for el in ELISIONS]


def _print_fusedmm_summary(summary_path):
    """Render the sweep as an algo x elision status table (every cell
    reported — ok / skipped / failed / unsupported, never omitted)."""
    cells = {}
    with open(summary_path) as f:
        for line in f:
            r = json.loads(line)
            if "skipped" in r:
                status = "skipped"
            elif not r.get("ok"):
                status = "FAILED"
            else:
                status = f"ok c={r.get('c')}"
            cells[(r["algo"], r["elision"])] = status
    algos = sorted({a for a, _ in cells})
    width = max(12, *(len(v) + 2 for v in cells.values()))
    print("\nFUSEDMM SWEEP SUMMARY (algo x elision)")
    print(f"{'':6s}" + "".join(f"{el:>{width}s}" for el in ELISIONS))
    for a in algos:
        row = "".join(f"{cells.get((a, el), '-'):>{width}s}"
                      for el in ELISIONS)
        print(f"{a:6s}{row}")


def run_fusedmm_sweep(args):
    os.makedirs(args.outdir, exist_ok=True)
    summary_path = os.path.join(args.outdir, "summary_fusedmm.jsonl")
    done = set()
    if os.path.exists(summary_path):
        with open(summary_path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):     # failed/timed-out cells retry
                    done.add((r["algo"], r["elision"]))

    def emit(rec):
        with open(summary_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
        print(json.dumps(rec), flush=True)

    for algo, elision, supported in fusedmm_cells():
        if (algo, elision) in done:
            continue
        if not supported:
            # structurally impossible cell: an explicit skip record, no
            # subprocess (there is no executor to lower)
            emit(dict(algo=algo, elision=elision, ok=True, seconds=0.0,
                      error="",
                      skipped="unsupported elision (structurally "
                              "impossible; see docs/algorithms.md)"))
            continue
        tag = f"fusedmm__{algo}__{elision}"
        out = os.path.join(args.outdir, tag + ".json")
        cmd = [sys.executable, "-m", "repro.launch.dryrun_fusedmm",
               "--algo", algo, "--elision", elision,
               "--m", str(args.fusedmm_m), "--r", str(args.fusedmm_r),
               "--nnz-row", str(args.fusedmm_nnz_row), "--out", out]
        t0 = time.time()
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=args.timeout)
            ok = proc.returncode == 0
            err = proc.stderr[-2000:] if not ok else ""
        except subprocess.TimeoutExpired:
            ok, err = False, "timeout"
        rec = dict(algo=algo, elision=elision, ok=ok,
                   seconds=round(time.time() - t0, 1), error=err)
        if ok and os.path.exists(out):
            try:
                with open(out) as f:
                    r = json.load(f)
                if "skipped" in r:
                    rec["skipped"] = r["skipped"]
                else:
                    rec["c"] = r.get("c")
                    rec["paper_words"] = r.get("paper_words")
                    rec["wire_gb"] = round(
                        r["collectives"]["total_wire_bytes"] / 1e9, 3)
            except Exception as e:     # pragma: no cover
                rec["parse_error"] = str(e)
        emit(rec)
    _print_fusedmm_summary(summary_path)
    print("FUSEDMM SWEEP COMPLETE")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--only-single-pod", action="store_true")
    ap.add_argument("--timeout", type=int, default=1500)
    ap.add_argument("--archs", default=None, help="comma-separated subset")
    ap.add_argument("--fusedmm", action="store_true",
                    help="sweep distributed FusedMM cells instead of LM")
    ap.add_argument("--fusedmm-m", type=int, default=1 << 20)
    ap.add_argument("--fusedmm-r", type=int, default=256)
    ap.add_argument("--fusedmm-nnz-row", type=int, default=32)
    args = ap.parse_args(argv)

    if args.fusedmm:
        return run_fusedmm_sweep(args)

    os.makedirs(args.outdir, exist_ok=True)
    summary_path = os.path.join(args.outdir, "summary.jsonl")
    archs = args.archs.split(",") if args.archs else ARCHS
    meshes = [False] if args.only_single_pod else [False, True]

    done = set()
    if os.path.exists(summary_path):
        with open(summary_path) as f:
            for line in f:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["multi_pod"]))

    for multi_pod in meshes:
        for arch in archs:
            for shape in SHAPES:
                key = (arch, shape, multi_pod)
                if key in done:
                    continue
                tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
                out = os.path.join(args.outdir, tag + ".json")
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", out,
                       "--microbatch", str(MICROBATCH.get(arch, 4))]
                if multi_pod:
                    cmd.append("--multi-pod")
                t0 = time.time()
                try:
                    proc = subprocess.run(cmd, capture_output=True,
                                          text=True, timeout=args.timeout)
                    ok = proc.returncode == 0
                    err = proc.stderr[-2000:] if not ok else ""
                except subprocess.TimeoutExpired:
                    ok, err = False, "timeout"
                dt = time.time() - t0
                rec = dict(arch=arch, shape=shape, multi_pod=multi_pod,
                           ok=ok, seconds=round(dt, 1), error=err)
                if ok and os.path.exists(out):
                    try:
                        with open(out) as f:
                            r = json.load(f)
                        if "skipped" in r:
                            rec["skipped"] = r["skipped"]
                        else:
                            rec["temp_gb"] = round(
                                r["memory"]["temp_size_in_bytes"] / 1e9, 2)
                            rec["flops"] = r["cost"].get("flops")
                            rec["wire_gb"] = round(
                                r["collectives"]["total_wire_bytes"] / 1e9,
                                3)
                    except Exception as e:     # pragma: no cover
                        rec["parse_error"] = str(e)
                with open(summary_path, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                print(json.dumps(rec), flush=True)
    print("SWEEP COMPLETE")
    return 0


if __name__ == "__main__":
    sys.exit(main())
