"""Batched serving driver: prefill a stream of prompt batches, decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batches 3 --batch 4 --prompt-len 16 --gen 16

Production control flow: request batching, prefill+decode split, per-step
latency stats, straggler monitoring — on the local mesh.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ParallelConfig
from repro.distributed import sharding
from repro.distributed.elastic import StepMonitor
from repro.launch.mesh import make_local_mesh
from repro.launch.train import resolve_config
from repro.models import model as M
from repro.serving import decode


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batches", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = resolve_config(args.arch, args.smoke)
    mesh = make_local_mesh()
    sharding.set_mesh(mesh)
    pcfg = ParallelConfig(compute_dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    mon = StepMonitor()

    for b in range(args.batches):
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)
        t0 = time.perf_counter()
        logits, cache = decode.prefill(cfg, pcfg, params,
                                       {"tokens": prompts})
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        cache = decode.extend_cache(cache, args.gen)
        tok = jnp.argmax(logits[:, -1], -1)
        lat = []
        for i in range(args.gen - 1):
            t0 = time.perf_counter()
            logits, cache = decode.decode_step(
                cfg, pcfg, params, {"tokens": tok[:, None]}, cache)
            jax.block_until_ready(logits)
            lat.append(time.perf_counter() - t0)
            mon.observe(b * args.gen + i, lat[-1])
            tok = jnp.argmax(logits[:, -1], -1)
        print(json.dumps(dict(
            batch=b, prefill_s=round(t_prefill, 4),
            decode_p50_ms=round(float(np.median(lat)) * 1e3, 2),
            decode_p99_ms=round(float(np.quantile(lat, 0.99)) * 1e3, 2),
            tokens=args.batch * args.gen)))
    print("SERVING DONE")
    return 0


if __name__ == "__main__":
    sys.exit(main())
