import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " \
    + os.environ.get("XLA_FLAGS", "")
# ^ MUST precede any jax import: device count locks at first backend init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell this lowers the real jitted step function
(train_step for train_*, prefill for prefill_*, decode_step for
decode_*/long_*) against ShapeDtypeStruct inputs on the production mesh,
compiles it (SPMD partitioning actually runs), and records:

  memory_analysis()   -> per-device bytes (proves the cell fits a v5e)
  cost_analysis()     -> HLO FLOPs / bytes for the roofline
  collective traffic  -> loop-aware HLO parse (repro.roofline.hlo_parse)

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k \
      [--multi-pod] [--out out.json] [--opt '{"remat":"full"}']
  python -m repro.launch.dryrun --list
"""
import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (ModelConfig, ParallelConfig, ServeConfig,
                          TrainConfig, get_config)
from repro.distributed import sharding
from repro.distributed.sharding import fsdp_extend_tree, sanitize_tree
from repro.launch.mesh import make_production_mesh

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# skip ledger (see DESIGN.md §Arch-applicability)
LONG_OK = {"jamba-v0.1-52b", "mamba2-1.3b"}       # sub-quadratic families
ENCODER_ONLY = {"hubert-xlarge"}                  # no decode step


def runnable(arch: str, shape: str) -> tuple[bool, str]:
    if shape in ("decode_32k", "long_500k") and arch in ENCODER_ONLY:
        return False, "encoder-only: no decode step"
    if shape == "long_500k" and arch not in LONG_OK:
        return False, "pure full-attention: 500k decode needs sub-quadratic"
    return True, ""


def input_specs(cfg: ModelConfig, shape: str):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    kind = info["kind"]
    tb = (B, S) if kind != "decode" else (B, 1)
    specs = {}
    if cfg.embed_inputs:
        specs["tokens"] = jax.ShapeDtypeStruct(tb, jnp.int32)
    else:
        specs["embeds"] = jax.ShapeDtypeStruct(tb + (cfg.d_model,),
                                               jnp.bfloat16)
    if cfg.pos_dims == 3:
        specs["positions"] = jax.ShapeDtypeStruct(tb + (3,), jnp.int32)
    if kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return specs


def _shardings(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(pcfg, specs, axis_sizes):
    from repro.models.model import batch_axes as _ba
    batch_axes = _ba(pcfg)
    raw = {k: P(*((tuple(a for a in batch_axes if a),)
                  + (None,) * (v.ndim - 1)))
           for k, v in specs.items()}
    return sanitize_tree(raw, specs, axis_sizes)


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               overrides: dict | None = None, microbatch: int = 4):
    """Returns (lowered, meta) for one cell."""
    from repro.models import model as M
    from repro.serving import decode
    from repro.training import optimizer as opt
    from repro.training import train_step as ts

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    sharding.set_mesh(mesh)   # ambient mesh: activation constraints apply
    info = SHAPES[shape]
    kind = info["kind"]
    pcfg = ParallelConfig(
        pod_axis="pod" if multi_pod else None,
        remat="full" if kind == "train" else "none",
        seq_shard_decode=(kind in ("decode",)),
        param_dtype="float32" if kind == "train" else "bfloat16",
        compute_dtype="bfloat16",
    )
    if overrides:
        pcfg = dataclasses.replace(pcfg, **overrides)

    axis_sizes = dict(mesh.shape)
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0), pcfg.param_dtype))
    pspec = M.param_specs(cfg, pcfg, params_shape)
    if kind == "train":   # FSDP/ZeRO-3: params + moments sharded over data
        pspec = fsdp_extend_tree(pspec, params_shape, axis_sizes,
                                 pcfg.data_axis)
    pspec = sanitize_tree(pspec, params_shape, axis_sizes)
    psh = _shardings(mesh, pspec)
    specs = input_specs(cfg, shape)
    bsh = _shardings(mesh, batch_specs(pcfg, specs, axis_sizes))

    if kind == "train":
        tcfg = TrainConfig(seq_len=info["seq"], global_batch=info["batch"],
                           microbatch=microbatch)
        opt_shape = jax.eval_shape(lambda: opt.init_opt_state(params_shape))
        osh = {"mu": psh, "nu": psh, "step": NamedSharding(mesh, P())}
        step, _, jit_step = ts.make_train_step(cfg, pcfg, tcfg, mesh)
        fn = jit_step(psh, osh, bsh)
        lowered = fn.lower(params_shape, opt_shape, specs)
    elif kind == "prefill":
        cache_shape = jax.eval_shape(
            lambda: M.init_cache(cfg, info["batch"], info["seq"]))
        cspec = M.cache_specs(
            cfg, dataclasses.replace(pcfg, seq_shard_decode=True),
            cache_shape)
        csh = _shardings(mesh, sanitize_tree(cspec, cache_shape,
                                             axis_sizes))

        def step(params, batch):
            return decode.prefill(cfg, pcfg, params, batch)

        fn = jax.jit(step, in_shardings=(psh, bsh),
                     out_shardings=(None, csh))
        lowered = fn.lower(params_shape, specs)
    else:   # decode
        cache_shape = jax.eval_shape(
            lambda: M.init_cache(cfg, info["batch"], info["seq"]))
        cspec = M.cache_specs(cfg, pcfg, cache_shape)
        csh = _shardings(mesh, sanitize_tree(cspec, cache_shape,
                                             axis_sizes))

        def step(params, batch, cache):
            return decode.decode_step(cfg, pcfg, params, batch, cache)

        fn = jax.jit(step, in_shardings=(psh, bsh, csh),
                     out_shardings=(None, csh), donate_argnums=(2,))
        lowered = fn.lower(params_shape, specs, cache_shape)

    meta = dict(arch=arch, shape=shape, kind=kind,
                multi_pod=multi_pod, mesh=str(mesh.shape),
                microbatch=microbatch if kind == "train" else 0,
                params=cfg.param_count(),
                active_params=cfg.active_param_count())
    return lowered, meta


def analyse(lowered, meta, want_hlo=False):
    from repro.roofline.hlo_parse import collective_summary, program_totals
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per program
        cost = cost[0] if cost else {}
    txt = compiled.as_text()
    coll = collective_summary(txt)
    prog = program_totals(txt)
    out = dict(meta)
    out["memory"] = {
        k: getattr(mem, k) for k in
        ("argument_size_in_bytes", "output_size_in_bytes",
         "temp_size_in_bytes", "alias_size_in_bytes",
         "generated_code_size_in_bytes")}
    out["cost"] = {k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed") or
                   k.startswith("bytes accessed")}
    out["collectives"] = coll
    out["program"] = prog   # loop-aware per-device dot FLOPs / bytes
    if want_hlo:
        out["hlo"] = txt
    return out


def emit_result(result: dict, out_path: str | None) -> str:
    """Shared JSON emission for the dry-run entrypoints."""
    js = json.dumps(result, indent=1)
    print(js)
    if out_path:
        with open(out_path, "w") as f:
            f.write(js)
    return js


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=False)
    ap.add_argument("--shape", default="train_4k", choices=SHAPES)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--opt", default=None,
                    help="JSON dict of ParallelConfig overrides")
    ap.add_argument("--microbatch", type=int, default=4,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        from repro.configs import ARCH_IDS
        for a in ARCH_IDS:
            for s in SHAPES:
                ok, why = runnable(a, s)
                print(f"{a:24s} {s:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return 0

    ok, why = runnable(args.arch, args.shape)
    if not ok:
        emit_result(dict(arch=args.arch, shape=args.shape, skipped=why),
                    args.out)
        return 0

    overrides = json.loads(args.opt) if args.opt else None
    lowered, meta = lower_cell(args.arch, args.shape,
                               multi_pod=args.multi_pod,
                               overrides=overrides,
                               microbatch=args.microbatch)
    emit_result(analyse(lowered, meta), args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
