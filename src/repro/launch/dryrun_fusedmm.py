import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " \
    + os.environ.get("XLA_FLAGS", "")

"""Dry-run the PAPER'S kernels on the production mesh: distributed FusedMM
at p=256, dispatched through the unified repro.core.api registry — ANY
registered algorithm, no per-family branching here.

  PYTHONPATH=src python -m repro.launch.dryrun_fusedmm \
      [--algo auto|d15|s15|d25|s25] [--c 16] \
      [--elision auto|none|reuse|fused] \
      [--m 1048576] [--r 256] [--nnz-row 32] [--out out.json]

This is the roofline cell most representative of the paper's contribution;
the perf loop (EXPERIMENTS.md §Perf) iterates algo / c / elision through
`sweep_dryrun --fusedmm`.
"""
import argparse
import json
import sys

import numpy as np

from repro.core import api, costmodel, sparse
from repro.launch.mesh import make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="auto",
                    choices=["auto"] + sorted(api.ALGORITHMS))
    ap.add_argument("--c", type=int, default=None,
                    help="replication factor (default: cost-model best)")
    ap.add_argument("--elision", default="auto",
                    choices=["auto", "none", "reuse", "fused"])
    ap.add_argument("--m", type=int, default=1 << 20)
    ap.add_argument("--r", type=int, default=256)
    ap.add_argument("--nnz-row", type=int, default=32)
    ap.add_argument("--row-tile", type=int, default=256)
    ap.add_argument("--nz-block", type=int, default=256)
    ap.add_argument("--mtx", default=None,
                    help="Matrix Market file to dry-run instead of the "
                         "Erdos-Renyi generator (repro.core.mtx)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    mesh = make_production_mesh()          # 16 x 16 = 256 chips
    devices = np.asarray(mesh.devices).reshape(-1)
    r = args.r
    if args.mtx:
        from repro.core.mtx import load_mtx
        rows, cols, vals, (m, n) = load_mtx(args.mtx)
    else:
        m = n = args.m
        rows, cols, vals = sparse.erdos_renyi(m, n, args.nnz_row, seed=0)
    nnz = len(vals)

    from repro.launch.dryrun import analyse, emit_result
    try:
        prob = api.make_problem(rows, cols, vals, (m, n), r,
                                algorithm=args.algo, c=args.c,
                                devices=devices, row_tile=args.row_tile,
                                nz_block=args.nz_block)
        elision = prob.resolve_elision(args.elision)
    except ValueError as e:
        # structurally infeasible cell (divisibility, or an elision the
        # family does not support): a skip record, not a crash
        emit_result(dict(algo=args.algo, elision=args.elision, m=m, r=r,
                         skipped=str(e)), args.out)
        return 0
    lowered = prob.lower_fusedmm(elision)

    # the cost-model grid is full rank: every registry-declared
    # (family, elision) cell has exactly one Table-III row
    cm_name = costmodel.ELISION_COST_NAME[(prob.alg.name, elision)]
    paper_words = costmodel.words_fusedmm(cm_name, p=prob.p, c=prob.c,
                                          n=n, r=r, nnz=nnz).words
    meta = dict(arch=f"paper-fusedmm-{prob.alg.name}", shape=elision,
                kind="serve", multi_pod=False, mesh=str(mesh.shape),
                microbatch=0, params=nnz, active_params=nnz,
                algo=prob.alg.name, c=prob.c, phi=prob.phi,
                paper_words=paper_words)
    emit_result(analyse(lowered, meta), args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
