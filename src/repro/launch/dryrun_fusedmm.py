import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " \
    + os.environ.get("XLA_FLAGS", "")

"""Dry-run the PAPER'S kernels on the production mesh: distributed FusedMM
at p=256 (16x16 re-viewed as a (p/c) x c sparse grid).

  PYTHONPATH=src python -m repro.launch.dryrun_fusedmm \
      [--c 16] [--elision reuse|none|fused] [--algo d15|s15] \
      [--m 1048576] [--r 256] [--nnz-row 32] [--out out.json]

This is the roofline cell most representative of the paper's contribution;
the perf loop (EXPERIMENTS.md §Perf) iterates c / elision / block shapes.
"""
import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, d15, s15, sparse
from repro.core.grid import Grid15
from repro.launch.mesh import make_production_mesh
from jax.sharding import Mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--c", type=int, default=16)
    ap.add_argument("--elision", default="reuse",
                    choices=["none", "reuse", "fused"])
    ap.add_argument("--algo", default="d15", choices=["d15", "s15"])
    ap.add_argument("--m", type=int, default=1 << 20)
    ap.add_argument("--r", type=int, default=256)
    ap.add_argument("--nnz-row", type=int, default=32)
    ap.add_argument("--row-tile", type=int, default=256)
    ap.add_argument("--nz-block", type=int, default=256)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    mesh = make_production_mesh()          # 16 x 16 = 256 chips
    devs = np.asarray(mesh.devices).reshape(-1)
    p = devs.size
    grid = Grid15(Mesh(devs.reshape(p // args.c, args.c),
                       ("layer", "fiber")))
    m = n = args.m
    r = args.r
    rows, cols, vals = sparse.erdos_renyi(m, n, args.nnz_row, seed=0)
    nnz = len(vals)
    rng = np.random.default_rng(1)
    A = jax.device_put(jnp.zeros((m, r), jnp.float32),
                       grid.sharding(("layer", "fiber"))
                       if args.algo == "d15"
                       else grid.sharding(None, ("layer", "fiber")))
    B = jax.device_put(jnp.zeros((n, r), jnp.float32), A.sharding)

    if args.algo == "d15":
        plan = d15.plan_d15(grid, rows, cols, vals, m, n, r,
                            transpose=(args.elision == "reuse"),
                            row_tile=args.row_tile, nz_block=args.nz_block)
        lowered = d15.fusedmm_d15.lower(grid, plan, A, B,
                                        elision=args.elision)
    else:
        plan = s15.plan_s15(grid, rows, cols, vals, m, n, r,
                            row_tile=args.row_tile, nz_block=args.nz_block)
        lowered = s15.fusedmm_s15.lower(grid, plan, A, B,
                                        elision=args.elision
                                        if args.elision != "fused"
                                        else "reuse")

    from repro.launch.dryrun import analyse
    cm_name = {("d15", "none"): "d15_no_elision",
               ("d15", "reuse"): "d15_replication_reuse",
               ("d15", "fused"): "d15_local_fusion",
               ("s15", "reuse"): "s15_replication_reuse",
               ("s15", "none"): "s15_replication_reuse"}[
                   (args.algo, args.elision)]
    paper_words = costmodel.words_fusedmm(cm_name, p=p, c=args.c, n=n,
                                          r=r, nnz=nnz).words
    meta = dict(arch=f"paper-fusedmm-{args.algo}", shape=args.elision,
                kind="serve", multi_pod=False, mesh=str(mesh.shape),
                microbatch=0, params=nnz, active_params=nnz,
                c=args.c, phi=nnz / (n * r), paper_words=paper_words)
    res = analyse(lowered, meta)
    js = json.dumps(res, indent=1)
    print(js)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)
    return 0


if __name__ == "__main__":
    sys.exit(main())
