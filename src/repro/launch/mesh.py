"""Mesh construction.  Functions, never module-level constants — importing
this module must not touch jax device state."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: one v5e pod = 16x16 = 256 chips
    ("data", "model"); multi-pod = 2 pods = 512 chips with a leading
    "pod" axis for hierarchical data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Development mesh over whatever devices exist (tests, examples)."""
    devs = np.array(jax.devices())
    n = devs.size
    data = data if data is not None else n // model
    assert data * model <= n, (data, model, n)
    return Mesh(devs[:data * model].reshape(data, model), ("data", "model"))


def sparse_grid_from_production(mesh, c: int):
    """Reinterpret the production mesh for the paper's sparse kernels:
    "data" x "model" devices re-viewed as a (p/c, c) (layer, fiber) grid."""
    from repro.core.grid import Grid15
    devs = np.asarray(mesh.devices).reshape(-1)
    p = devs.size
    assert p % c == 0
    return Grid15(Mesh(devs.reshape(p // c, c), ("layer", "fiber")))
