"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 200 --seq 512 --batch 8 --ckpt-dir /tmp/ckpt [--smoke]

Production control flow on a laptop: real config system, synthetic data
pipeline, pjit'd train step with explicit shardings, checkpoint/restart
(resume is automatic if the checkpoint dir has a committed step), step
monitoring with straggler flagging, loss logging.  ``--smoke`` swaps in the
reduced config of the same family.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ParallelConfig, TrainConfig, get_config
from repro.distributed import sharding
from repro.distributed.elastic import StepMonitor, run_step_resilient
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training import data as data_mod
from repro.training import optimizer as opt
from repro.training import train_step as ts

SMOKE_MODULES = {
    "jamba-v0.1-52b": "jamba_v01_52b", "stablelm-1.6b": "stablelm_1_6b",
    "llama3.2-1b": "llama32_1b", "qwen3-1.7b": "qwen3_1_7b",
    "qwen3-4b": "qwen3_4b", "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-1.3b": "mamba2_1_3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "hubert-xlarge": "hubert_xlarge",
}


def resolve_config(arch: str, smoke: bool):
    if smoke:
        mod = importlib.import_module("repro.configs."
                                      + SMOKE_MODULES[arch])
        return mod.reduced()
    return get_config(arch)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--log-file", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = resolve_config(args.arch, args.smoke)
    mesh = make_local_mesh(model=args.model_parallel)
    sharding.set_mesh(mesh)
    pcfg = ParallelConfig(remat="none", compute_dtype="float32",
                          param_dtype="float32")
    tcfg = TrainConfig(seq_len=args.seq, global_batch=args.batch,
                       lr=args.lr, steps=args.steps,
                       microbatch=args.microbatch, seed=args.seed)

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = opt.init_opt_state(params)
    step0 = 0
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            params = ckpt.restore(args.ckpt_dir, last,
                                  {"params": params,
                                   "opt": opt_state})
            params, opt_state = params["params"], params["opt"]
            step0 = last
            print(f"resumed from step {step0}")

    _, shardings_for, jit_step = ts.make_train_step(cfg, pcfg, tcfg, mesh)
    psh, osh = shardings_for(jax.eval_shape(lambda: params))
    fn = jit_step(psh, osh, None)   # batch placement inferred on local mesh

    params = jax.device_put(params, psh)
    opt_state = jax.device_put(opt_state, osh)
    pipe = data_mod.SyntheticLM(cfg.vocab, args.seq, args.batch,
                                seed=args.seed)
    mon = StepMonitor(on_straggler=lambda s, t, m: print(
        f"[straggler] step {s}: {t:.2f}s vs median {m:.2f}s"))
    logf = open(args.log_file, "a") if args.log_file else None

    def make_batch(step):
        b = pipe.batch(step)
        if not cfg.embed_inputs:
            eb = data_mod.embeds_batch(step, args.batch, args.seq,
                                       cfg.d_model,
                                       pos3=(cfg.pos_dims == 3))
            b = dict(eb, labels=b["labels"])
        return jax.tree.map(jnp.asarray, b)

    def restore_latest():
        last = ckpt.latest_step(args.ckpt_dir)
        tree = ckpt.restore(args.ckpt_dir, last,
                            {"params": params, "opt": opt_state})
        return (jax.device_put(tree["params"], psh),
                jax.device_put(tree["opt"], osh))

    t_start = time.time()
    for step in range(step0, args.steps):
        batch = make_batch(step)

        def do(p, o, b):
            return mon.timed(step, fn, p, o, b)

        if args.ckpt_dir:
            params, opt_state, metrics = run_step_resilient(
                do, None, lambda: restore_latest() + (batch,),
                params, opt_state, batch)
        else:
            params, opt_state, metrics = do(params, opt_state, batch)

        if step % args.log_every == 0 or step == args.steps - 1:
            rec = dict(step=step, loss=float(metrics["loss"]),
                       grad_norm=float(metrics["grad_norm"]),
                       lr=float(metrics["lr"]),
                       elapsed=round(time.time() - t_start, 1))
            print(json.dumps(rec), flush=True)
            if logf:
                logf.write(json.dumps(rec) + "\n")
                logf.flush()
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1,
                      {"params": jax.device_get(params),
                       "opt": jax.device_get(opt_state)})
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps,
                  {"params": jax.device_get(params),
                   "opt": jax.device_get(opt_state)})
    print("TRAINING DONE")
    return 0


if __name__ == "__main__":
    sys.exit(main())
