"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) ff=6144 V=151936, qk_norm.

[hf:Qwen/Qwen3-8B; hf]
"""
from repro.config import LayerSpec, ModelConfig, register

A = LayerSpec("attn", "dense")

CONFIG = register(ModelConfig(
    name="qwen3-1.7b", family="dense",
    d_model=2048, vocab=151936,
    segments=(((A,), 28),),
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=6144,
    qk_norm=True, rope="rope", rope_theta=1e6,
))


def reduced():
    return ModelConfig(
        name="qwen3-1.7b-smoke", family="dense",
        d_model=128, vocab=512,
        segments=(((A,), 2),),
        n_heads=4, n_kv_heads=2, d_ff=384,
        qk_norm=True, rope="rope")
