"""A ~100M-parameter dense LM for the end-to-end training example."""
from repro.config import LayerSpec, ModelConfig, register

A = LayerSpec("attn", "dense")

CONFIG = register(ModelConfig(
    name="lm-100m", family="dense",
    d_model=768, vocab=32768,
    segments=(((A,), 12),),
    n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
    rope="rope",
))
