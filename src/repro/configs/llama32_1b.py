"""llama3.2-1b [dense]: 16L d=2048 32H (GQA kv=8) ff=8192 V=128256.

Tied embeddings, rope theta 500k.  [hf:meta-llama/Llama-3.2-1B; unverified]
"""
from repro.config import LayerSpec, ModelConfig, register

A = LayerSpec("attn", "dense")

CONFIG = register(ModelConfig(
    name="llama3.2-1b", family="dense",
    d_model=2048, vocab=128256,
    segments=(((A,), 16),),
    n_heads=32, n_kv_heads=8, head_dim=64, d_ff=8192,
    rope="rope", rope_theta=5e5, tie_embeddings=True,
))


def reduced():
    return ModelConfig(
        name="llama3.2-1b-smoke", family="dense",
        d_model=128, vocab=512,
        segments=(((A,), 2),),
        n_heads=4, n_kv_heads=2, d_ff=512,
        rope="rope", tie_embeddings=True)
