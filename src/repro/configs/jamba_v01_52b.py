"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) ff=14336 V=65536,
Mamba:attention 7:1 interleave, MoE 16 experts top-2 on alternate layers.

Super-block of 8 layers (attention at in-block index 4, per the released
model), MoE on odd in-block indices; scanned over 4 repetitions.
[arXiv:2403.19887; hf]
"""
from repro.config import LayerSpec, ModelConfig, register

def _sb(moe_ff):
    sb = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        sb.append(LayerSpec(mixer, ffn))
    return tuple(sb)

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    d_model=4096, vocab=65536,
    segments=((_sb(None), 4),),
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
    moe_experts=16, moe_top_k=2, moe_d_ff=14336,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    rope="none",          # Jamba uses no positional encoding
))


def reduced():
    sb = (LayerSpec("mamba", "dense"), LayerSpec("attn", "moe"),
          LayerSpec("mamba", "moe"))
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        d_model=128, vocab=512,
        segments=((sb, 2),),
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
        moe_experts=4, moe_top_k=2, moe_d_ff=256,
        ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_conv=4,
        rope="none",
        capacity_factor=8.0)
