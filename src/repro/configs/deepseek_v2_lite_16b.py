"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H, MLA kv_lora=512,
MoE 64 routed top-6 + 2 shared, expert ff=1408; layer 0 dense ff=10944.

Assignment note: the spec line reads both "64e top-6" and "2 shared+160
routed"; the published DeepSeek-V2-Lite config is 64 routed + 2 shared,
top-6, which is what we implement (see DESIGN.md deviations).
[arXiv:2405.04434; hf]
"""
import dataclasses

from repro.config import LayerSpec, ModelConfig, register

DENSE0 = LayerSpec("attn", "dense")
MOE = LayerSpec("attn", "moe")

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    d_model=2048, vocab=102400,
    segments=(((DENSE0,), 1), ((MOE,), 26)),
    n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944,
    mla_kv_lora=512, mla_rope_dim=64,
    moe_experts=64, moe_top_k=6, moe_shared=2, moe_d_ff=1408,
    rope="rope", rope_theta=1e4,
))


def reduced():
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        d_model=128, vocab=512,
        segments=(((DENSE0,), 1), ((MOE,), 2)),
        n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=384, mla_kv_lora=64, mla_rope_dim=16,
        moe_experts=8, moe_top_k=2, moe_shared=1, moe_d_ff=96,
        rope="rope",
        capacity_factor=8.0)
