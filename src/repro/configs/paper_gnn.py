"""The paper's own workloads: GAT forward pass + ALS collaborative
filtering, parameterized for the benchmark harness (not an LM config)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    n_nodes: int = 1 << 14
    nnz_per_row: int = 16
    r: int = 128            # embedding width
    n_heads: int = 4
    n_layers: int = 2
    algorithm: str = "auto"   # costmodel-driven selection


@dataclasses.dataclass(frozen=True)
class ALSConfig:
    m: int = 1 << 14
    n: int = 1 << 14
    nnz_per_row: int = 16
    r: int = 128
    cg_iters: int = 10
    reg: float = 0.1
