"""Assigned-architecture configs.  Importing this package registers all."""
from repro.configs import (  # noqa: F401
    jamba_v01_52b,
    stablelm_1_6b,
    llama32_1b,
    qwen3_1_7b,
    qwen3_4b,
    qwen2_vl_72b,
    mamba2_1_3b,
    deepseek_v2_lite_16b,
    phi35_moe_42b,
    hubert_xlarge,
    paper_gnn,
    lm_100m,
)

ARCH_IDS = [
    "jamba-v0.1-52b",
    "stablelm-1.6b",
    "llama3.2-1b",
    "qwen3-1.7b",
    "qwen3-4b",
    "qwen2-vl-72b",
    "mamba2-1.3b",
    "deepseek-v2-lite-16b",
    "phi3.5-moe-42b-a6.6b",
    "hubert-xlarge",
]
