"""qwen3-4b [dense]: 36L d=2560 32H (GQA kv=8) ff=9728 V=151936, qk_norm.

[hf:Qwen/Qwen3-8B; hf]
"""
from repro.config import LayerSpec, ModelConfig, register

A = LayerSpec("attn", "dense")

CONFIG = register(ModelConfig(
    name="qwen3-4b", family="dense",
    d_model=2560, vocab=151936,
    segments=(((A,), 36),),
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=9728,
    qk_norm=True, rope="rope", rope_theta=1e6,
))


def reduced():
    return ModelConfig(
        name="qwen3-4b-smoke", family="dense",
        d_model=160, vocab=512,
        segments=(((A,), 2),),
        n_heads=4, n_kv_heads=2, head_dim=40, d_ff=480,
        qk_norm=True, rope="rope")
