"""mamba2-1.3b [ssm]: 48L d=2048, attention-free, ssm_state=128, no FFN.

SSD (state-space duality) blocks only.  The paper's SDDMM/SpMM attention
technique is INAPPLICABLE to this family (no sampled-dense-dense product
anywhere) — noted in DESIGN.md; the arch runs without it.
[arXiv:2405.21060; unverified]
"""
from repro.config import LayerSpec, ModelConfig, register

M = LayerSpec("mamba", "none")

CONFIG = register(ModelConfig(
    name="mamba2-1.3b", family="ssm",
    d_model=2048, vocab=50280,
    segments=(((M,), 48),),
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    rope="none", d_ff=0,
))


def reduced():
    return ModelConfig(
        name="mamba2-1.3b-smoke", family="ssm",
        d_model=128, vocab=512,
        segments=(((M,), 2),),
        ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_conv=4,
        rope="none", d_ff=0)
