"""qwen2-vl-72b [vlm]: 80L d=8192 64H (GQA kv=8) ff=29568 V=152064, M-RoPE.

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings + (t,h,w) position triples; this config is the
transformer backbone only.  [arXiv:2409.12191; hf]
"""
from repro.config import LayerSpec, ModelConfig, register

A = LayerSpec("attn", "dense")

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    d_model=8192, vocab=152064,
    segments=(((A,), 80),),
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=29568,
    rope="mrope", rope_theta=1e6, pos_dims=3,
    embed_inputs=False,     # frontend stub feeds embeddings
))


def reduced():
    return ModelConfig(
        name="qwen2-vl-72b-smoke", family="vlm",
        d_model=128, vocab=512,
        segments=(((A,), 2),),
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=384,
        rope="mrope", pos_dims=3, embed_inputs=False)
