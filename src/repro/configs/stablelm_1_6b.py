"""stablelm-1.6b [dense]: 24L d=2048 32H (kv=32, i.e. MHA) ff=5632 V=100352.

[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.config import LayerSpec, ModelConfig, register

A = LayerSpec("attn", "dense")

CONFIG = register(ModelConfig(
    name="stablelm-1.6b", family="dense",
    d_model=2048, vocab=100352,
    segments=(((A,), 24),),
    n_heads=32, n_kv_heads=32, d_ff=5632,
    rope="rope", rope_theta=1e4,
))


def reduced():
    return ModelConfig(
        name="stablelm-1.6b-smoke", family="dense",
        d_model=128, vocab=512,
        segments=(((A,), 2),),
        n_heads=4, n_kv_heads=4, d_ff=352,
        rope="rope")
