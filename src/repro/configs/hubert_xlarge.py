"""hubert-xlarge [audio]: 48L d=1280 16H (MHA kv=16) ff=5120 V=504.

Encoder-only (bidirectional, no causal mask, no decode step — decode/long
shapes are skipped per the assignment).  The conv feature extractor is a
STUB: input_specs() provides precomputed frame embeddings.
[arXiv:2106.07447; unverified]
"""
from repro.config import LayerSpec, ModelConfig, register

A = LayerSpec("attn", "dense")

CONFIG = register(ModelConfig(
    name="hubert-xlarge", family="audio",
    d_model=1280, vocab=504,
    segments=(((A,), 48),),
    n_heads=16, n_kv_heads=16, d_ff=5120,
    rope="none", causal=False,
    embed_inputs=False,     # frame-embedding frontend stub
))


def reduced():
    return ModelConfig(
        name="hubert-xlarge-smoke", family="audio",
        d_model=128, vocab=64,
        segments=(((A,), 2),),
        n_heads=4, n_kv_heads=4, d_ff=256,
        rope="none", causal=False, embed_inputs=False)
