"""phi3.5-moe-42b-a6.6b [moe]: 32L d=4096 32H (GQA kv=8) V=32064,
MoE 16 experts top-2, expert ff=6400.  [hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.config import LayerSpec, ModelConfig, register

E = LayerSpec("attn", "moe")

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    d_model=4096, vocab=32064,
    segments=(((E,), 32),),
    n_heads=32, n_kv_heads=8, head_dim=128,
    moe_experts=16, moe_top_k=2, moe_d_ff=6400,
    rope="rope", rope_theta=1e4,
))


def reduced():
    return ModelConfig(
        name="phi3.5-moe-smoke", family="moe",
        d_model=128, vocab=512,
        segments=(((E,), 2),),
        n_heads=4, n_kv_heads=2, head_dim=32,
        moe_experts=4, moe_top_k=2, moe_d_ff=160,
        rope="rope",
        capacity_factor=8.0)
