"""Observability: per-phase comm spans, live cost-model drift, metrics.

Two orthogonal, individually-armable surfaces, both zero-cost when
disabled (the ``faults.guard`` discipline — one module attribute read on
the executor hot path, no jax imports):

* :mod:`repro.obs.tracer` — ``with obs.trace() as tr:`` spans every
  executor round at the same gather/phase/shift/reduce coordinates the
  fault harness guards, carrying modeled (``schedule_words``) vs
  measured (compiled-HLO) wire words and their ratio, **cost-model
  drift**;
* :mod:`repro.obs.metrics` — ``with obs.metrics.collect() as reg:``
  one labeled counter/gauge/histogram registry absorbing the repo's
  ad-hoc counters (Session, SessionPool, ElasticProblem, serving ticks,
  StepMonitor) with a JSON-exact snapshot.

:mod:`repro.obs.export` renders traces as Perfetto-loadable Chrome
trace JSON and fixes the ``TRACE_<tag>.json`` / ``METRICS_<tag>.json``
artifact convention.  See docs/observability.md.
"""
from repro.obs import metrics
from repro.obs.export import chrome_trace, round_summary, write_artifacts
from repro.obs.metrics import MetricsRegistry, collect
from repro.obs.tracer import EventSpan, RoundSpan, Tracer, active, trace

__all__ = [
    "EventSpan", "MetricsRegistry", "RoundSpan", "Tracer", "active",
    "chrome_trace", "collect", "metrics", "round_summary", "trace",
    "write_artifacts",
]
