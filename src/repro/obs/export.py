"""Exporters: Chrome-trace/Perfetto JSON, text summaries, artifacts.

``chrome_trace`` renders a :class:`repro.obs.tracer.Tracer` in the
Chrome trace-event format (load at ``ui.perfetto.dev`` or
``chrome://tracing``): one track (tid) per rank — the executors are SPMD,
every rank runs the same schedule, so the round's spans are duplicated
onto each rank's track with per-device word counts — with event spans
nested inside round spans by time containment.

``write_artifacts`` fixes the artifact convention consumed by
``benchmarks/run.py`` and CI: ``TRACE_<tag>.json`` (Perfetto-loadable)
and ``METRICS_<tag>.json`` (``MetricsRegistry.snapshot()``) in a chosen
directory.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import Tracer

__all__ = ["chrome_trace", "round_summary", "write_artifacts"]


def chrome_trace(tracer: Tracer) -> dict:
    """Chrome trace-event JSON for a finished trace (one track per rank)."""
    ranks = max([r.p for r in tracer.rounds], default=1)
    ev = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
           "args": {"name": "repro executors (SPMD; per-device words)"}}]
    for tid in range(ranks):
        ev.append({"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                   "args": {"name": f"rank {tid}"}})
    for r in tracer.rounds:
        name = f"{r.family}.{r.op}" + (f"[{r.elision}]"
                                       if r.op == "fusedmm" else "")
        args = {"family": r.family, "op": r.op, "elision": r.elision,
                "comm": r.comm, "round": r.round, "p": r.p, "c": r.c,
                "session": r.session}
        if r.modeled_words is not None:
            args["modeled_words"] = r.modeled_words
        if r.measured_words is not None:
            args["measured_words"] = r.measured_words["total"]
        if r.drift is not None:
            args["drift"] = r.drift
        if r.error is not None:
            args["error"] = r.error
        for tid in range(r.p):
            ev.append({"name": name, "cat": "round", "ph": "X", "pid": 0,
                       "tid": tid, "ts": r.t0 * 1e6, "dur": r.dur * 1e6,
                       "args": args})
            for s in r.events:
                a = {"point": s.point, "phase": s.phase}
                if s.kind is not None:
                    a["collective"] = s.kind
                if s.words is not None:
                    a["modeled_words"] = s.words
                ev.append({"name": f"{s.point}[{s.phase}]",
                           "cat": "event", "ph": "X", "pid": 0,
                           "tid": tid, "ts": s.t0 * 1e6,
                           "dur": s.dur * 1e6, "args": a})
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def round_summary(tracer: Tracer) -> str:
    """One line per traced round: words modeled vs measured, drift, time."""
    lines = [f"{'round':28s} {'comm':6s} {'modeled':>10s} {'measured':>10s} "
             f"{'drift':>8s} {'ms':>9s}"]
    for r in tracer.rounds:
        name = (f"{r.family}.{r.op}"
                + (f"[{r.elision}]" if r.op == "fusedmm" else "")
                + ("+sess" if r.session else "")
                + f"#{r.round}")
        mod = "-" if r.modeled_words is None else f"{r.modeled_words:.0f}"
        mea = "-" if r.measured_words is None \
            else f"{r.measured_words['total']:.0f}"
        dr = "-" if r.drift is None else f"{r.drift:.4f}"
        err = f"  ERROR={r.error}" if r.error else ""
        lines.append(f"{name:28s} {r.comm:6s} {mod:>10s} {mea:>10s} "
                     f"{dr:>8s} {r.dur * 1e3:9.3f}{err}")
    return "\n".join(lines)


def write_artifacts(out_dir: str, tag: str, *,
                    tracer: Optional[Tracer] = None,
                    registry: Optional[MetricsRegistry] = None) -> dict:
    """Write ``TRACE_<tag>.json`` / ``METRICS_<tag>.json``; returns paths."""
    os.makedirs(out_dir, exist_ok=True)
    paths = {}
    if tracer is not None:
        p = os.path.join(out_dir, f"TRACE_{tag}.json")
        with open(p, "w") as fh:
            json.dump(chrome_trace(tracer), fh)
        paths["trace"] = p
    if registry is not None:
        p = os.path.join(out_dir, f"METRICS_{tag}.json")
        with open(p, "w") as fh:
            fh.write(registry.to_json())
        paths["metrics"] = p
    return paths
