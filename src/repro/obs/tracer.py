"""Per-round communication spans with live cost-model drift.

A :class:`Tracer` hooks the same executor-round boundaries the fault
harness guards (``repro.distributed.faults``): one **round span** per
``DistProblem.sddmm/spmm/spmm_t/fusedmm`` call, subdivided into one
**event span** per entry of the family's ``schedule_events`` — the
gather/phase/shift/reduce coordinates every family module exports.  Each
event span carries the collective kind it compiles to and its *modeled*
wire words (``schedule_words``, impl-exact for dense wire formats); the
round span carries the *measured* per-device wire words parsed out of
the compiled HLO (``repro.roofline.hlo_parse.wire_words``) and their
ratio — **cost-model drift**, 1.0 when the closed-form model matches the
wire exactly.  Support-pruned (``comm="sparse"``) rounds trace without
modeled words: their volume is data-dependent and drift is undefined.

Timing: the round's wall time is measured; event spans subdivide it
proportionally to their modeled words (equal split when no model) — a
*modeled attribution* for visualization, explicitly not a per-collective
measurement (the jitted round is one XLA program; docs/observability.md).

Zero-cost when disabled, like ``faults.guard``: no tracer is installed
by default and the api layer pays one module attribute read per call.
This module imports no jax; HLO measurement happens through the
problem's own ``lower_*`` methods, cached per program signature.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import List, Optional

from repro.obs import metrics as _metrics

__all__ = ["EventSpan", "RoundSpan", "Tracer", "active", "trace"]


@dataclasses.dataclass
class EventSpan:
    """One schedule event inside a round: a fault-harness coordinate."""
    point: str                    # gather | phase | shift | reduce
    phase: int
    kind: Optional[str]           # HLO collective, None for compute
    words: Optional[float]        # modeled wire words (None: no model)
    t0: float = 0.0               # seconds since trace epoch
    dur: float = 0.0


@dataclasses.dataclass
class RoundSpan:
    """One guarded executor call, subdivided into its schedule events."""
    op: str
    family: str
    elision: str
    comm: str
    p: int
    c: int
    round: int                    # per-op call counter since tracing began
    session: bool
    t0: float
    dur: float
    events: List[EventSpan]
    modeled_words: Optional[float]      # sum of event models (dense only)
    measured_words: Optional[dict]      # wire_words() dict, if measured
    drift: Optional[float]              # measured total / modeled total
    error: Optional[str] = None         # exception type, if the round died


_LOWER = {"sddmm": "lower_sddmm", "spmm": "lower_spmm",
          "spmm_t": "lower_spmm_t"}


class Tracer:
    """Collects :class:`RoundSpan`s; arm with :func:`trace`.

    ``measure_wire=True`` (default) lowers + compiles each distinct
    program signature once to parse its actual per-device wire words —
    amortized across calls by a signature-keyed cache, but still one
    extra XLA compile per signature; long-running serving loops can pass
    ``False`` and keep modeled words only.  ``registry`` (default: the
    armed ``obs.metrics`` registry, if any) receives round latency
    histograms and live drift gauges as the trace runs.
    """

    def __init__(self, *, measure_wire: bool = True,
                 registry: Optional[_metrics.MetricsRegistry] = None,
                 clock=time.perf_counter):
        self.rounds: List[RoundSpan] = []
        self.measure_wire = measure_wire
        self._registry = registry
        self._clock = clock
        self.epoch = clock()
        self._counts: dict = {}
        self._wire_cache: dict = {}

    # -- measurement ---------------------------------------------------------
    def _measure(self, problem, op, elision, session):
        sig = (problem.alg.name, id(problem.grid), op, elision,
               problem.m, problem.n, problem.r, problem.nnz,
               problem.comm, problem.compress, session is not None)
        if sig not in self._wire_cache:
            from repro.roofline.hlo_parse import wire_words
            if op == "fusedmm":
                low = problem.lower_fusedmm(elision, session=session)
            else:
                low = getattr(problem, _LOWER[op])(session=session)
            self._wire_cache[sig] = wire_words(low.compile().as_text())
        return self._wire_cache[sig]

    # -- the round hook ------------------------------------------------------
    @contextlib.contextmanager
    def round(self, problem, op: str, elision: str = "none",
              session=None):
        """Span one executor round (called by the api layer)."""
        rnd = self._counts.get(op, 0)
        self._counts[op] = rnd + 1
        t0 = self._clock() - self.epoch
        err = None
        try:
            yield
        except BaseException as e:
            err = type(e).__name__
            raise
        finally:
            dur = self._clock() - self.epoch - t0
            self._finish(problem, op, elision, session, rnd, t0, dur, err)

    def _finish(self, problem, op, elision, session, rnd, t0, dur, err):
        events = problem.alg.schedule_events(problem, op, elision)
        words = problem.alg.schedule_words(problem, op, elision,
                                           session=session)
        measured = drift = None
        total = None if words is None else sum(w for *_, w in words)
        if err is None and self.measure_wire:
            try:
                measured = self._measure(problem, op, elision, session)
            except Exception:
                measured = None         # lowering unsupported: trace on
            if measured is not None and total:
                drift = measured["total"] / total
        # modeled-attribution timing: split the round's wall time across
        # events by modeled words (equal split when there is no model)
        if words is None:
            shares = [1.0] * len(events)
        else:
            shares = [max(w, 0.0) for *_, w in words]
        denom = sum(shares) or float(len(events) or 1)
        if sum(shares) == 0.0:
            shares = [1.0] * len(events)
        spans, t = [], t0
        for i, (point, phase) in enumerate(events):
            d = dur * shares[i] / denom
            spans.append(EventSpan(
                point=point, phase=phase,
                kind=None if words is None else words[i][2],
                words=None if words is None else words[i][3],
                t0=t, dur=d))
            t += d
        self.rounds.append(RoundSpan(
            op=op, family=problem.alg.name, elision=elision,
            comm=problem.comm, p=problem.p, c=problem.c, round=rnd,
            session=session is not None, t0=t0, dur=dur, events=spans,
            modeled_words=total, measured_words=measured, drift=drift,
            error=err))
        reg = self._registry or _metrics.active()
        if reg is not None:
            lab = dict(op=op, family=problem.alg.name)
            reg.observe("executor.round_seconds", dur, **lab)
            reg.inc("executor.rounds", 1, **lab)
            if drift is not None:
                reg.gauge("costmodel.drift", drift, **lab)

    # -- reading -------------------------------------------------------------
    def drifts(self) -> List[float]:
        """All defined per-round drift ratios, trace order."""
        return [r.drift for r in self.rounds if r.drift is not None]


_ACTIVE: Optional[Tracer] = None


def active() -> Optional[Tracer]:
    """The armed tracer, or None (the zero-cost disabled state)."""
    return _ACTIVE


@contextlib.contextmanager
def trace(tracer: Optional[Tracer] = None, **kw):
    """Arm a tracer for the dynamic extent of the context.

    Yields the :class:`Tracer`; nesting restores the previous one on
    exit — same discipline as ``faults.inject``."""
    global _ACTIVE
    tr = Tracer(**kw) if tracer is None else tracer
    prev = _ACTIVE
    _ACTIVE = tr
    try:
        yield tr
    finally:
        _ACTIVE = prev
