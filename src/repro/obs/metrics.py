"""Labeled counters / gauges / histograms with a JSON-exact snapshot.

One process-wide metric surface for everything the repo used to count
ad-hoc: ``Session.stats()``, ``SessionPool`` hit/eviction/pin counts,
``ElasticProblem`` retry/degrade/fault tallies, serving tick latency and
batch occupancy, ``StepMonitor`` straggler flags.  The push API
(:meth:`MetricsRegistry.inc` / :meth:`gauge` / :meth:`observe`) covers
event-shaped sources; :meth:`gather` absorbs an existing ``stats()``-style
dict as gauges so the owning classes keep their cheap local counters and
the registry pulls them at snapshot points.

Zero-cost when disabled, mirroring ``repro.distributed.faults``: nothing
here imports jax, no registry is installed by default, and an
instrumentation site pays exactly one module attribute read
(:func:`active` returning None) when no collection context is armed.

Snapshots round-trip: ``MetricsRegistry.from_snapshot(r.snapshot())``
reproduces ``r.snapshot()`` bit-for-bit — the ``METRICS_<tag>.json``
artifact contract (docs/observability.md).
"""
from __future__ import annotations

import contextlib
import json
import math
from typing import Dict, Optional, Tuple

__all__ = [
    "MetricsRegistry", "active", "collect", "HIST_BOUNDS",
]

#: Shared histogram bucket upper bounds: log-spaced, 4 per decade, from
#: 1 microsecond-scale to 1e6 — wide enough for latencies in seconds AND
#: batch occupancies in slots without per-metric configuration.
HIST_BOUNDS: Tuple[float, ...] = tuple(
    round(10.0 ** (e / 4.0), 10) for e in range(-24, 25))


def _label_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Counters, gauges and histograms keyed by (name, labels).

    A *series* is one (name, label-set) pair with a fixed type; mixing
    types on one series raises (the usual metrics-client contract).
    Histograms record count/sum/min/max plus :data:`HIST_BOUNDS` bucket
    counts — enough for rate, mean and coarse quantiles without storing
    samples.
    """

    def __init__(self):
        # (name, ((k, v), ...)) -> series dict
        self._series: Dict[tuple, dict] = {}

    # -- write paths ---------------------------------------------------------
    def _get(self, name: str, mtype: str, labels: Dict[str, object]) -> dict:
        key = (name, _label_key(labels))
        s = self._series.get(key)
        if s is None:
            s = dict(name=name, type=mtype,
                     labels={k: v for k, v in key[1]})
            if mtype == "histogram":
                s.update(count=0, sum=0.0, min=math.inf, max=-math.inf,
                         buckets=[0] * (len(HIST_BOUNDS) + 1))
            else:
                s["value"] = 0.0
            self._series[key] = s
        elif s["type"] != mtype:
            raise TypeError(f"series {name!r}{dict(key[1])} is "
                            f"{s['type']}, not {mtype}")
        return s

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        """Add to a monotone counter series."""
        self._get(name, "counter", labels)["value"] += float(value)

    def gauge(self, name: str, value: float, **labels) -> None:
        """Set a point-in-time gauge series."""
        self._get(name, "gauge", labels)["value"] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one sample into a histogram series."""
        s = self._get(name, "histogram", labels)
        v = float(value)
        s["count"] += 1
        s["sum"] += v
        s["min"] = min(s["min"], v)
        s["max"] = max(s["max"], v)
        lo, hi = 0, len(HIST_BOUNDS)        # first bound >= v, else overflow
        while lo < hi:
            mid = (lo + hi) // 2
            if HIST_BOUNDS[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        s["buckets"][lo] += 1

    def gather(self, prefix: str, stats: Dict[str, object], **labels) -> None:
        """Absorb a ``stats()``-style dict of numbers as gauges.

        Non-numeric values are skipped — the owning class's identity
        fields (names, digests) stay out of the metric surface."""
        for k, v in stats.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            self.gauge(f"{prefix}.{k}", float(v), **labels)

    # -- read paths ----------------------------------------------------------
    def value(self, name: str, **labels) -> Optional[float]:
        """Current value of a counter/gauge series (None if absent)."""
        s = self._series.get((name, _label_key(labels)))
        return None if s is None or s["type"] == "histogram" \
            else s["value"]

    def histogram(self, name: str, **labels) -> Optional[dict]:
        """count/sum/min/max/mean of a histogram series (None if absent)."""
        s = self._series.get((name, _label_key(labels)))
        if s is None or s["type"] != "histogram":
            return None
        return dict(count=s["count"], sum=s["sum"], min=s["min"],
                    max=s["max"],
                    mean=(s["sum"] / s["count"]) if s["count"] else 0.0)

    def series(self):
        """All series dicts, deterministically ordered."""
        return [self._series[k] for k in sorted(self._series)]

    def merge(self, other: "MetricsRegistry", **labels) -> None:
        """Fold another registry's series into this one, adding
        ``labels`` to every merged series — how a sweep accumulates its
        per-run registries into one artifact.  Counters and histogram
        cells add; gauges take the merged value."""
        for s in other.series():
            lab = dict(s["labels"], **{k: str(v) for k, v in
                                       labels.items()})
            mine = self._get(s["name"], s["type"], lab)
            if s["type"] == "histogram":
                mine["count"] += s["count"]
                mine["sum"] += s["sum"]
                mine["min"] = min(mine["min"], s["min"])
                mine["max"] = max(mine["max"], s["max"])
                mine["buckets"] = [a + b for a, b in
                                   zip(mine["buckets"], s["buckets"])]
            elif s["type"] == "counter":
                mine["value"] += s["value"]
            else:
                mine["value"] = s["value"]

    # -- snapshot ------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able full state: ``{"series": [...]}``, sorted.

        Histogram ``min``/``max`` of an empty series serialize as None
        (JSON has no inf); :meth:`from_snapshot` restores them."""
        out = []
        for s in self.series():
            d = dict(s)
            if d["type"] == "histogram":
                d["buckets"] = list(d["buckets"])
                d["min"] = None if d["count"] == 0 else d["min"]
                d["max"] = None if d["count"] == 0 else d["max"]
            out.append(d)
        return {"series": out}

    @classmethod
    def from_snapshot(cls, snap: dict) -> "MetricsRegistry":
        reg = cls()
        for d in snap.get("series", ()):
            s = reg._get(d["name"], d["type"], d.get("labels", {}))
            if d["type"] == "histogram":
                s["count"] = int(d["count"])
                s["sum"] = float(d["sum"])
                s["min"] = math.inf if d["min"] is None else float(d["min"])
                s["max"] = -math.inf if d["max"] is None else float(d["max"])
                s["buckets"] = [int(b) for b in d["buckets"]]
            else:
                s["value"] = float(d["value"])
        return reg

    def to_json(self, **dump_kw) -> str:
        dump_kw.setdefault("indent", 1)
        dump_kw.setdefault("sort_keys", True)
        return json.dumps(self.snapshot(), **dump_kw)

    def summary(self) -> str:
        """Human-readable one-line-per-series table."""
        lines = []
        for s in self.series():
            lab = ",".join(f"{k}={v}" for k, v in sorted(s["labels"].items()))
            head = f"{s['name']}{{{lab}}}" if lab else s["name"]
            if s["type"] == "histogram":
                n = s["count"]
                mean = (s["sum"] / n) if n else 0.0
                lines.append(f"{head:52s} histogram n={n} mean={mean:.6g} "
                             f"min={s['min'] if n else '-'} "
                             f"max={s['max'] if n else '-'}")
            else:
                lines.append(f"{head:52s} {s['type']} "
                             f"value={s['value']:.6g}")
        return "\n".join(lines)


_ACTIVE: Optional[MetricsRegistry] = None


def active() -> Optional[MetricsRegistry]:
    """The armed registry, or None (the zero-cost disabled state)."""
    return _ACTIVE


@contextlib.contextmanager
def collect(registry: Optional[MetricsRegistry] = None):
    """Arm a registry for the dynamic extent of the context.

    Yields the registry; nesting restores the previous one on exit —
    same discipline as ``faults.inject``."""
    global _ACTIVE
    reg = MetricsRegistry() if registry is None else registry
    prev = _ACTIVE
    _ACTIVE = reg
    try:
        yield reg
    finally:
        _ACTIVE = prev
