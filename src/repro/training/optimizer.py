"""AdamW with decoupled weight decay, cosine schedule, global grad clip.

Hand-rolled (no optax in this container); states are pytrees matching the
params, so they shard with the same PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100
    total_steps: int = 1000


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    t = jnp.clip((step - cfg.warmup) /
                 jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
