"""Lossy wire formats for distributed collectives, with error feedback.

Two compression levels share this module:

* **bf16 payload casts** — the wire format of the support-pruned sends
  (``repro.core.common.pruned_permute`` and friends ship payloads
  through :func:`to_bf16`/:func:`from_bf16` when a plan carries
  ``compress="bf16"``).  Halves every pruned channel's bytes; lossy, so
  the exactness contract drops from bitwise to ~3 decimal digits.
* **int8 block-quantized gradients** — before the data-parallel psum,
  each gradient tensor is scaled to int8 per 256-element block.  This
  4x-shrinks the dominant multi-pod collective, the classic
  distributed-optimization trick for slow inter-pod links.

Both are meant to run under **error feedback**: the compression residual
is carried to the next step and added back before compressing again, so
the *accumulated* error stays bounded and convergence is preserved
(:class:`ErrorFeedback` for the generic per-tensor form,
:func:`compressed_psum` for the fused int8+psum form).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


# ---------------------------------------------------------------------------
# bf16 wire casts (the compress="bf16" payload format of the pruned sends)
# ---------------------------------------------------------------------------

def to_bf16(x):
    """f32 payload -> bf16 wire format (half the bytes on the wire)."""
    return x.astype(jnp.bfloat16)


def from_bf16(x, dtype=jnp.float32):
    """bf16 wire payload -> compute dtype at the receiver."""
    return x.astype(dtype)


class ErrorFeedback:
    """Per-tensor compression-residual accumulator (host-side state).

    ``seen = ef(tree)`` returns what the receivers observe after the
    lossy round-trip and folds the residual ``corrected - seen`` into
    the next call, so repeated lossy steps do not accumulate drift —
    the standard error-feedback guarantee.  The default round-trip is
    the bf16 wire cast (what ``compress="bf16"`` pruned sends apply);
    pass any elementwise lossy function to model other formats.

    State lives on the host across steps, mirroring how a training loop
    owns its optimizer state; one accumulator per compressed tensor
    tree.
    """

    def __init__(self, roundtrip=None):
        self.residual = None
        self._roundtrip = roundtrip or \
            (lambda x: from_bf16(to_bf16(x), x.dtype))

    def __call__(self, tree):
        if self.residual is None:
            self.residual = jax.tree.map(
                lambda g: jnp.zeros_like(g), tree)
        corrected = jax.tree.map(lambda g, e: g + e, tree, self.residual)
        seen = jax.tree.map(self._roundtrip, corrected)
        self.residual = jax.tree.map(lambda c, s: c - s, corrected, seen)
        return seen


def _pad_to(x, mult):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % mult
    return jnp.pad(flat, (0, pad)), pad


def quantize_int8(g):
    """g -> (q int8, scales f32, meta) with per-block scaling."""
    flat, pad = _pad_to(g.astype(jnp.float32), BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), (g.shape, pad)


def dequantize_int8(q, scale, meta):
    shape, pad = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum(grads, axis_name, errors=None):
    """psum(grads) over axis_name with int8 quantization + error feedback.

    Returns (mean_grads, new_errors).  errors=None initializes feedback.
    """
    if errors is None:
        errors = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                              grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale, meta = quantize_int8(corrected)
        deq_local = dequantize_int8(q, scale, meta)
        new_e = corrected - deq_local
        # sum the *dequantized* payload (int8 wire format; psum in f32 of
        # the dequantized value models lossless accumulation at receiver)
        summed = jax.lax.psum(deq_local, axis_name)
        return summed, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])
