"""Gradient compression for the data-parallel all-reduce.

int8 block-quantized gradients with error feedback: before the DP psum,
each gradient tensor is scaled to int8 per 256-element block; the
quantization residual is carried to the next step (error feedback keeps
convergence).  This 4x-shrinks the dominant multi-pod collective, the
classic distributed-optimization trick for slow inter-pod links.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to(x, mult):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % mult
    return jnp.pad(flat, (0, pad)), pad


def quantize_int8(g):
    """g -> (q int8, scales f32, meta) with per-block scaling."""
    flat, pad = _pad_to(g.astype(jnp.float32), BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), (g.shape, pad)


def dequantize_int8(q, scale, meta):
    shape, pad = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum(grads, axis_name, errors=None):
    """psum(grads) over axis_name with int8 quantization + error feedback.

    Returns (mean_grads, new_errors).  errors=None initializes feedback.
    """
    if errors is None:
        errors = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                              grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale, meta = quantize_int8(corrected)
        deq_local = dequantize_int8(q, scale, meta)
        new_e = corrected - deq_local
        # sum the *dequantized* payload (int8 wire format; psum in f32 of
        # the dequantized value models lossless accumulation at receiver)
        summed = jax.lax.psum(deq_local, axis_name)
        return summed, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])
