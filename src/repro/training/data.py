"""Deterministic synthetic data pipeline.

Generates a reproducible mixture of Zipf-distributed tokens with local
n-gram structure (so an LM can actually reduce loss on it), sharded by
(host, step) — every host computes only its slice, the paper-standard
random-permutation load balancing applied to LM data.  Also provides the
frontend-stub streams for the audio/vlm architectures.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3

    def batch(self, step: int, lo: int = 0, hi: int | None = None):
        """Token batch rows [lo, hi) of the global batch at `step`.

        The FULL global batch is always generated then sliced, so every
        host sees identical rows for its slice regardless of shard width
        (host-count-independent determinism)."""
        hi = hi if hi is not None else self.global_batch
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) % (2 ** 63))
        # Zipf body truncated to vocab; order-2 structure via a random
        # linear-congruential mixing so next-token is partially predictable
        base = rng.zipf(self.zipf_a, size=(self.global_batch, self.seq_len))
        base = np.minimum(base, self.vocab - 1)
        mult = 6364136223846793005
        mixed = base.copy()
        mixed[:, 1:] = (base[:, 1:] + (mixed[:, :-1] * mult >> 33)) \
            % self.vocab
        # every 4th token copies its predecessor -> learnable structure
        mixed[:, 3::4] = mixed[:, 2::4]
        tok = mixed[lo:hi].astype(np.int32)
        return {"tokens": tok, "labels": tok}


def embeds_batch(step: int, batch: int, seq: int, d: int, seed: int = 0,
                 pos3: bool = False):
    """Frontend-stub batch for audio (frames) / vlm (patches)."""
    rng = np.random.default_rng((seed * 7_777_777 + step) % (2 ** 63))
    out = {"embeds": rng.standard_normal((batch, seq, d)).astype(np.float32)}
    if pos3:
        t = np.arange(seq, dtype=np.int32)
        grid = np.stack([t, t // 16, t % 16], axis=-1)
        out["positions"] = np.broadcast_to(grid, (batch, seq, 3)).copy()
    return out
