"""Fault-tolerant checkpointing: sharded npz + manifest, atomic, keep-k.

Layout:
  <dir>/step_000123/
      manifest.json          {step, leaf paths, shapes, dtypes, mesh}
      shard_h000.npz         this host's param/opt leaves (gathered locally)
      _COMMITTED             written last — restore ignores uncommitted dirs

Writes go to a tmp dir + atomic rename; a crash mid-save never corrupts the
latest checkpoint (restart-safe).  Restore rebuilds the pytree and
device_puts with the current shardings, so a run may resume on a DIFFERENT
mesh shape (elastic re-scale) as long as the global shapes divide.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def save(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    """Synchronous single-host save (per-host shards in multi-host runs)."""
    name = f"step_{step:08d}"
    final = os.path.join(ckpt_dir, name)
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves = _leaf_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(leaf) for i, (_, leaf) in
              enumerate(leaves)}
    np.savez(os.path.join(tmp, "shard_h000.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": [p for p, _ in leaves],
        "shapes": [list(np.shape(l)) for _, l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for _, l in leaves],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        if not os.path.exists(os.path.join(ckpt_dir, d, "_COMMITTED")):
            continue   # crash mid-save: ignore
        best = max(best or -1, int(d.split("_")[1]))
    return best


def restore(ckpt_dir: str, step: int, tree_like, shardings=None):
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    assert os.path.exists(os.path.join(path, "_COMMITTED")), \
        f"checkpoint {path} is not committed"
    with np.load(os.path.join(path, "shard_h000.npz")) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    flat, tdef = jax.tree_util.tree_flatten(tree_like)
    assert len(flat) == len(leaves), (len(flat), len(leaves))
    out = []
    for ref, val in zip(flat, leaves):
        val = val.astype(ref.dtype) if hasattr(ref, "dtype") else val
        out.append(val)
    tree = tdef.unflatten(out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
