"""Fault-tolerant checkpointing: sharded npz + manifest, atomic, keep-k.

Layout:
  <dir>/step_000123/
      manifest.json          {step, leaf paths, shapes, dtypes, meta}
      shard_h000.npz         this host's param/opt leaves (gathered locally)
      _COMMITTED             written last — restore ignores uncommitted dirs

Writes go to a tmp dir + atomic rename; a crash mid-save never corrupts the
latest checkpoint (restart-safe).  Restore rebuilds the pytree and
device_puts with the current shardings, so a run may resume on a DIFFERENT
mesh shape (elastic re-scale) as long as the global shapes divide.

Errors are typed so callers can distinguish *absence* (nothing to resume
from — start fresh) from *corruption* (on-disk state disagrees with its
own manifest or with the requested tree — fail loudly, never train on
garbage):

* :class:`CheckpointMissing` — the directory/step doesn't exist or was
  never committed;
* :class:`CheckpointError` — committed state that fails validation
  (missing shard, leaf-count drift, shape mismatch vs ``manifest.json``
  or vs the restore target).

``save(..., meta=...)`` embeds JSON metadata in the manifest — the
distributed trainers store ``DistProblem.meta_dict()`` there so a resume
can rebuild packs on the original mesh (pinned family/c) or re-dispatch
onto a degraded one (docs/robustness.md).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """Committed checkpoint state that fails validation (corruption or a
    restore target whose tree doesn't match what was saved)."""


class CheckpointMissing(CheckpointError):
    """No committed checkpoint at the requested location — absence, not
    corruption; callers typically start fresh."""


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def save(ckpt_dir: str, step: int, tree, keep: int = 3,
         meta: dict | None = None) -> str:
    """Synchronous single-host save (per-host shards in multi-host runs).

    ``meta`` (JSON-able) rides in the manifest — e.g. the distributed
    problem/Session metadata of :meth:`repro.core.api.DistProblem.meta_dict`.
    """
    name = f"step_{step:08d}"
    final = os.path.join(ckpt_dir, name)
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves = _leaf_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(leaf) for i, (_, leaf) in
              enumerate(leaves)}
    np.savez(os.path.join(tmp, "shard_h000.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": [p for p, _ in leaves],
        "shapes": [list(np.shape(l)) for _, l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for _, l in leaves],
    }
    if meta is not None:
        manifest["meta"] = meta
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        if not os.path.exists(os.path.join(ckpt_dir, d, "_COMMITTED")):
            continue   # crash mid-save: ignore
        best = max(best if best is not None else -1, int(d.split("_")[1]))
    return best


def load_manifest(ckpt_dir: str, step: int) -> dict:
    """The committed manifest of one step (typed errors, see module doc)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "_COMMITTED")):
        raise CheckpointMissing(f"no committed checkpoint at {path}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            return json.load(f)
    except FileNotFoundError as e:
        raise CheckpointError(f"{path} is committed but has no "
                              "manifest.json — corrupt checkpoint") from e
    except json.JSONDecodeError as e:
        raise CheckpointError(f"{path}/manifest.json is not valid JSON "
                              "— corrupt checkpoint") from e


def restore(ckpt_dir: str, step: int, tree_like, shardings=None):
    """Restore into the structure of ``tree_like``.

    Every restored leaf is validated against the shapes recorded in
    ``manifest.json`` (shard/manifest disagreement = corruption) AND
    against ``tree_like``'s leaf shapes (mismatch = wrong restore
    target); both raise :class:`CheckpointError` naming the offending
    leaf path.  Absence raises :class:`CheckpointMissing`.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = load_manifest(ckpt_dir, step)
    npz = os.path.join(path, "shard_h000.npz")
    if not os.path.exists(npz):
        raise CheckpointError(f"{path} is committed but shard_h000.npz "
                              "is missing — corrupt checkpoint")
    with np.load(npz) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    if len(leaves) != len(manifest["shapes"]):
        raise CheckpointError(
            f"{path}: shard holds {len(leaves)} leaves but the manifest "
            f"records {len(manifest['shapes'])} — corrupt checkpoint")
    flat, tdef = jax.tree_util.tree_flatten(tree_like)
    if len(flat) != len(leaves):
        raise CheckpointError(
            f"restore target has {len(flat)} leaves but {path} saved "
            f"{len(leaves)} (paths {manifest['paths'][:3]}...) — "
            "tree structure mismatch")
    out = []
    for i, (ref, val, want, p_name) in enumerate(
            zip(flat, leaves, manifest["shapes"], manifest["paths"])):
        if list(np.shape(val)) != list(want):
            raise CheckpointError(
                f"{path}: leaf {i} ({p_name}) has shape "
                f"{list(np.shape(val))} on disk but the manifest says "
                f"{want} — corrupt checkpoint")
        ref_shape = list(np.shape(ref)) if hasattr(ref, "shape") else None
        if ref_shape is not None and ref_shape != list(want):
            raise CheckpointError(
                f"{path}: leaf {i} ({p_name}) was saved with shape "
                f"{want} but the restore target expects {ref_shape} — "
                "refusing to restore mismatched state")
        val = val.astype(ref.dtype) if hasattr(ref, "dtype") else val
        out.append(val)
    tree = tdef.unflatten(out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
