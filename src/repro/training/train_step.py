"""Loss and jitted train/eval step builders.

``make_train_step`` returns a pjit'd function with explicit in/out
shardings derived from the model's PartitionSpecs; microbatch gradient
accumulation runs as a ``lax.scan`` over microbatches (activation memory /
throughput trade) and the optimizer update happens once per step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig, TrainConfig
from repro.models import model as M
from repro.training import optimizer as opt


def chunked_ce(hidden, head, targets, mask, chunk: int = 512,
               batch_axes=("data",)):
    """Cross-entropy scanning over sequence chunks.

    The (B, S, vocab) logits tensor is never materialized — essential for
    the 150k-vocab architectures where full logits at global batch would
    be terabytes.  The chunk body is checkpointed so the BACKWARD also
    recomputes per-chunk logits instead of saving them (without this the
    scan residuals re-materialize the full logits)."""
    B, S, d = hidden.shape
    if S % chunk or S <= chunk:
        chunk = S
    nc = S // chunk
    hs = jnp.moveaxis(hidden.reshape(B, nc, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, inp):
        h, t, mk = inp
        from repro.models.model import constrain
        logits = jnp.einsum("bcd,dv->bcv", h, head).astype(jnp.float32)
        logits = constrain(logits, batch_axes, None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - gold) * mk), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ts, ms))
    return tot / jnp.maximum(mask.sum(), 1.0)


def lm_loss(cfg: ModelConfig, pcfg: ParallelConfig, params, batch,
            aux_weight: float = 0.01):
    """Next-token CE in f32 (+ MoE load-balance aux)."""
    hidden, _, aux = M.forward(cfg, pcfg, params, batch, want_cache=False,
                               return_hidden=True)
    cdt = hidden.dtype
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(cdt)
    targets = batch["labels"]
    if cfg.causal:   # predict token t+1 at position t; mask the last slot
        tgt = jnp.concatenate([targets[:, 1:], targets[:, :1]], axis=1)
        mask = jnp.ones(targets.shape, jnp.float32).at[:, -1].set(0.0)
    else:            # encoder: per-frame classification
        tgt = targets
        mask = jnp.ones(targets.shape, jnp.float32)
    from repro.models.model import batch_axes as _ba
    nll = chunked_ce(hidden, head, tgt, mask, batch_axes=_ba(pcfg))
    loss = nll + aux_weight * aux
    return loss, {"loss": loss, "nll": nll, "aux": aux}


def batch_sharding(pcfg: ParallelConfig, mesh):
    batch_axes = ((pcfg.pod_axis, pcfg.data_axis) if pcfg.pod_axis
                  else (pcfg.data_axis,))

    def rule(x):
        spec = (batch_axes,) + (None,) * (x.ndim - 1)
        return NamedSharding(mesh, P(*spec))
    return rule


def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig,
                    tcfg: TrainConfig, mesh, opt_cfg: Optional[
                        opt.AdamWConfig] = None):
    """Returns (step_fn, param_shardings, opt_shardings).

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics);
    params and opt_state are donated.
    """
    opt_cfg = opt_cfg or opt.AdamWConfig(
        lr=tcfg.lr, beta1=tcfg.beta1, beta2=tcfg.beta2,
        weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip,
        warmup=tcfg.warmup, total_steps=tcfg.steps)

    def step(params, opt_state, batch):
        nmicro = tcfg.microbatch or 1
        if nmicro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: lm_loss(cfg, pcfg, p, batch), has_aux=True)(params)
        else:
            def micro(carry, mb):
                acc = carry
                (_, met), g = jax.value_and_grad(
                    lambda p: lm_loss(cfg, pcfg, p, mb),
                    has_aux=True)(params)
                acc = jax.tree.map(lambda a, b: a + b, acc, g)
                return acc, met
            split = jax.tree.map(
                lambda x: x.reshape((nmicro, x.shape[0] // nmicro)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, mets = jax.lax.scan(micro, zero, split)
            grads = jax.tree.map(lambda g: g / nmicro, grads)
            metrics = jax.tree.map(lambda m: m[-1], mets)

        new_params, new_opt, om = opt.adamw_update(opt_cfg, params, grads,
                                                   opt_state)
        metrics = dict(metrics, **om)
        return new_params, new_opt, metrics

    pspecs = None

    def shardings_for(params_shape):
        nonlocal pspecs
        pspecs = M.param_specs(cfg, pcfg, params_shape)
        to_sh = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        param_sh = to_sh(pspecs)
        opt_sh = {"mu": param_sh, "nu": param_sh,
                  "step": NamedSharding(mesh, P())}
        return param_sh, opt_sh

    def jit_step(param_sh, opt_sh, batch_sh):
        return jax.jit(
            step,
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, None),
            donate_argnums=(0, 1))

    return step, shardings_for, jit_step
