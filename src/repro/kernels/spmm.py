"""Pallas TPU kernel for blocked SpMM (S @ B) over RowTiledCOO.

The scatter-add of CPU/GPU SpMM is restructured as a one-hot matmul so it
runs on the MXU: for each nonzero block we gather the K participating rows
of B, scale by the sample values, and accumulate

    out_window += onehot(rows_local)  @  (vals[:, None] * B[cols])
      (row_tile x K)                     (K x r)

Row-sorted packing guarantees output windows are revisited consecutively,
so the accumulator stays resident in VMEM across grid steps; the output is
input/output-aliased to a zeros buffer so untouched windows are zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmm_kernel(base_ref, rows_ref, cols_ref, vals_ref, b_ref, acc_ref,
                 out_ref, *, row_tile):
    rl = rows_ref[0]
    cl = cols_ref[0]
    v = vals_ref[0].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    b_rows = jnp.take(b, cl, axis=0)                     # (K, r)
    scaled = v[:, None] * b_rows                         # (K, r)
    iota = jax.lax.broadcasted_iota(jnp.int32, (row_tile, rl.shape[0]), 0)
    onehot = (iota == rl[None, :]).astype(jnp.float32)   # (row_tile, K)
    out_ref[...] += jax.lax.dot(
        onehot, scaled, preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("row_tile", "m", "interpret"))
def spmm_pallas(tile_base_blk: jax.Array, rows_local: jax.Array,
                cols: jax.Array, vals: jax.Array, B: jax.Array, *,
                row_tile: int, m: int, interpret: bool = False) -> jax.Array:
    """Returns out (m, r) = S @ B accumulated in f32, cast to B.dtype."""
    nb, k = rows_local.shape
    r = B.shape[-1]
    n_b = B.shape[0]
    assert m % row_tile == 0, (m, row_tile)
    zeros = jnp.zeros((m, r), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, base: (i, 0)),
            pl.BlockSpec((1, k), lambda i, base: (i, 0)),
            pl.BlockSpec((1, k), lambda i, base: (i, 0)),
            pl.BlockSpec((n_b, r), lambda i, base: (0, 0)),          # B
            pl.BlockSpec((row_tile, r), lambda i, base: (base[i], 0)),  # acc
        ],
        out_specs=pl.BlockSpec((row_tile, r), lambda i, base: (base[i], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, row_tile=row_tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, r), jnp.float32),
        input_output_aliases={5: 0},   # acc zeros -> out (index incl. prefetch)
        interpret=interpret,
    )(tile_base_blk, rows_local, cols, vals, B, zeros)
    return out.astype(B.dtype)
