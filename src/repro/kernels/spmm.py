"""Pallas TPU kernel for blocked SpMM (S @ B) over RowTiledCOO.

The scatter-add of CPU/GPU SpMM is restructured as a one-hot matmul so it
runs on the MXU: for each nonzero block we gather the K participating rows
of B, scale by the sample values, and accumulate

    out_window += onehot(rows_local)  @  (vals[:, None] * B[cols])
      (row_tile x K)                     (K x r_tile)

VMEM tiling (see DESIGN.md): the grid is 2-D, ``(r // r_tile, nb // bps)``
with the step axis minor.  B enters VMEM as an ``(n_b, r_tile)`` slab that
stays resident for a whole sweep over the nonzero blocks, so the kernel
scales to embedding widths far beyond what a whole-B residency allows.
``blocks_per_step`` (bps) merges that many row-sorted nonzero blocks — all
sharing one ``tile_base`` window, guaranteed by the packer's ``group``
option — into a single grid step, deepening the one-hot contraction and
amortizing per-step dispatch overhead for small-K packs.

Output windows are input/output-aliased to a zeros buffer: on first visit
the fetched alias initializes the accumulator, on revisits (consecutive
within a sweep thanks to row-sorted packing) the partial stays resident in
VMEM; untouched windows remain zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmm_kernel(base_ref, rows_ref, cols_ref, vals_ref, b_ref, acc_ref,
                 out_ref, *, row_tile):
    rl = rows_ref[...].reshape(-1)                       # (bps*K,)
    cl = cols_ref[...].reshape(-1)
    v = vals_ref[...].reshape(-1).astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)                   # (n_b, r_tile)
    b_rows = jnp.take(b, cl, axis=0)                     # (bps*K, r_tile)
    scaled = v[:, None] * b_rows
    iota = jax.lax.broadcasted_iota(jnp.int32, (row_tile, rl.shape[0]), 0)
    onehot = (iota == rl[None, :]).astype(jnp.float32)   # (row_tile, bps*K)
    out_ref[...] += jax.lax.dot(
        onehot, scaled, preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("row_tile", "m", "r_tile",
                                    "blocks_per_step", "interpret"))
def spmm_pallas(tile_base_blk: jax.Array, rows_local: jax.Array,
                cols: jax.Array, vals: jax.Array, B: jax.Array, *,
                row_tile: int, m: int, r_tile: int | None = None,
                blocks_per_step: int = 1,
                interpret: bool = False) -> jax.Array:
    """Returns out (m, r) = S @ B accumulated in f32, cast to B.dtype."""
    nb, k = rows_local.shape
    r = B.shape[-1]
    n_b = B.shape[0]
    bps = blocks_per_step
    r_tile = r if r_tile is None else r_tile
    assert m % row_tile == 0, (m, row_tile)
    assert r % r_tile == 0, (r, r_tile)
    assert nb % bps == 0, (nb, bps)
    zeros = jnp.zeros((m, r), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        # step axis minor: one B slab stays VMEM-resident per block sweep
        grid=(r // r_tile, nb // bps),
        in_specs=[
            pl.BlockSpec((bps, k), lambda j, i, base: (i, 0)),
            pl.BlockSpec((bps, k), lambda j, i, base: (i, 0)),
            pl.BlockSpec((bps, k), lambda j, i, base: (i, 0)),
            pl.BlockSpec((n_b, r_tile), lambda j, i, base: (0, j)),    # B
            pl.BlockSpec((row_tile, r_tile),
                         lambda j, i, base: (base[i * bps], j)),       # acc
        ],
        out_specs=pl.BlockSpec((row_tile, r_tile),
                               lambda j, i, base: (base[i * bps], j)),
    )
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, row_tile=row_tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, r), jnp.float32),
        input_output_aliases={5: 0},   # acc zeros -> out (index incl. prefetch)
        interpret=interpret,
    )(tile_base_blk, rows_local, cols, vals, B, zeros)
    return out.astype(B.dtype)
