"""Pallas TPU kernel for blocked SDDMM over RowTiledCOO.

TPU adaptation (see DESIGN.md): nonzeros are pre-sorted by row and chunked
into blocks of ``nz_block`` entries confined to a ``row_tile``-row window of
A.  The grid is 2-D, ``(r // r_tile, nb // bps)`` with the step axis minor:
per grid step we bring one (row_tile x r_tile) window of A plus an
(n_b, r_tile) slab of the local B tile into VMEM, gather the participating
rows of each, and accumulate the partial sampled dot products over the
embedding-dimension slabs.  ``blocks_per_step`` (bps) merges that many
same-window nonzero blocks into one step to amortize dispatch overhead.

The window index comes from a scalar-prefetched ``tile_base`` array
(PrefetchScalarGridSpec), so block placement is data-dependent but known
before the kernel runs — the Pallas analogue of the paper's amortized
preprocessing of S.  Partial dots accumulate in f32 through an
input/output-aliased zeros buffer (revisited once per r-slab sweep) and are
cast to the sample dtype once at the end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sddmm_kernel(base_ref, rows_ref, cols_ref, vals_ref, a_ref, b_ref,
                  acc_ref, out_ref):
    rl = rows_ref[...].reshape(-1)       # int32[bps*K], window-local row ids
    cl = cols_ref[...].reshape(-1)       # int32[bps*K]
    v = vals_ref[...].astype(jnp.float32)   # f32[bps, K]
    a = a_ref[...].astype(jnp.float32)   # (row_tile, r_tile) VMEM window of A
    b = b_ref[...].astype(jnp.float32)   # (n_b, r_tile) slab of local B tile
    a_rows = jnp.take(a, rl, axis=0)     # (bps*K, r_tile) gather in window
    b_rows = jnp.take(b, cl, axis=0)     # (bps*K, r_tile)
    dots = jnp.sum(a_rows * b_rows, axis=-1).reshape(v.shape)
    # Accumulate through the out window: revisits across r-slab sweeps are
    # non-consecutive, but the aliased acc input shares the window buffer
    # and is re-fetched from HBM on every block-index change, restoring
    # the prior partial before this add (see DESIGN.md §2).
    out_ref[...] += v * dots


@functools.partial(jax.jit,
                   static_argnames=("row_tile", "r_tile", "blocks_per_step",
                                    "interpret"))
def sddmm_pallas(tile_base_blk: jax.Array, rows_local: jax.Array,
                 cols: jax.Array, vals: jax.Array, A: jax.Array,
                 B: jax.Array, *, row_tile: int, r_tile: int | None = None,
                 blocks_per_step: int = 1,
                 interpret: bool = False) -> jax.Array:
    """Returns new sampled values, shape (nblocks, nz_block)."""
    nb, k = rows_local.shape
    r = A.shape[-1]
    n_b = B.shape[0]
    bps = blocks_per_step
    r_tile = r if r_tile is None else r_tile
    assert r % r_tile == 0, (r, r_tile)
    assert nb % bps == 0, (nb, bps)
    zeros = jnp.zeros((nb, k), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r // r_tile, nb // bps),
        in_specs=[
            pl.BlockSpec((bps, k), lambda j, i, base: (i, 0)),  # rows_local
            pl.BlockSpec((bps, k), lambda j, i, base: (i, 0)),  # cols
            pl.BlockSpec((bps, k), lambda j, i, base: (i, 0)),  # vals
            pl.BlockSpec((row_tile, r_tile),
                         lambda j, i, base: (base[i * bps], j)),  # A window
            pl.BlockSpec((n_b, r_tile), lambda j, i, base: (0, j)),  # B slab
            pl.BlockSpec((bps, k), lambda j, i, base: (i, 0)),  # acc
        ],
        out_specs=pl.BlockSpec((bps, k), lambda j, i, base: (i, 0)),
    )
    out = pl.pallas_call(
        _sddmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, k), jnp.float32),
        input_output_aliases={6: 0},   # acc zeros -> out (index incl. prefetch)
        interpret=interpret,
    )(tile_base_blk, rows_local, cols, vals, A, B, zeros)
    return out.astype(vals.dtype)
