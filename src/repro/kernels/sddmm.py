"""Pallas TPU kernel for blocked SDDMM over RowTiledCOO.

TPU adaptation (see DESIGN.md): nonzeros are pre-sorted by row and chunked
into blocks of ``nz_block`` entries confined to a ``row_tile``-row window of
A.  Per grid step we bring one (row_tile x r) window of A plus the whole
local B tile into VMEM, gather the K participating rows of each, and emit
K sampled dot products.  The window index comes from a scalar-prefetched
``tile_base`` array (PrefetchScalarGridSpec), so block placement is
data-dependent but known before the kernel runs — the Pallas analogue of the
paper's amortized preprocessing of S.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sddmm_kernel(base_ref, rows_ref, cols_ref, vals_ref, a_ref, b_ref,
                  out_ref):
    rl = rows_ref[0]                     # int32[K], window-local row ids
    cl = cols_ref[0]                     # int32[K]
    v = vals_ref[0].astype(jnp.float32)  # f32[K]
    a = a_ref[...].astype(jnp.float32)   # (row_tile, r) VMEM window of A
    b = b_ref[...].astype(jnp.float32)   # (nB, r) local B tile
    a_rows = jnp.take(a, rl, axis=0)     # (K, r) gather within the window
    b_rows = jnp.take(b, cl, axis=0)     # (K, r)
    dots = jnp.sum(a_rows * b_rows, axis=-1)  # f32[K]
    out_ref[0] = (v * dots).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("row_tile", "interpret"))
def sddmm_pallas(tile_base_blk: jax.Array, rows_local: jax.Array,
                 cols: jax.Array, vals: jax.Array, A: jax.Array,
                 B: jax.Array, *, row_tile: int,
                 interpret: bool = False) -> jax.Array:
    """Returns new sampled values, shape (nblocks, nz_block)."""
    nb, k = rows_local.shape
    r = A.shape[-1]
    n_b = B.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, base: (i, 0)),        # rows_local
            pl.BlockSpec((1, k), lambda i, base: (i, 0)),        # cols
            pl.BlockSpec((1, k), lambda i, base: (i, 0)),        # vals
            pl.BlockSpec((row_tile, r), lambda i, base: (base[i], 0)),  # A win
            pl.BlockSpec((n_b, r), lambda i, base: (0, 0)),      # B (whole)
        ],
        out_specs=pl.BlockSpec((1, k), lambda i, base: (i, 0)),
    )
    return pl.pallas_call(
        _sddmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, k), vals.dtype),
        interpret=interpret,
    )(tile_base_blk, rows_local, cols, vals, A, B)
