"""Public jit'd wrappers over the Pallas kernels with ref fallback.

``backend="pallas"`` runs the Pallas kernels (interpret mode on CPU, native
on TPU); ``backend="ref"`` uses the pure-jnp oracles.  The distributed
algorithms in ``repro.core.algorithms`` call these for every local kernel
invocation, so flipping the backend flips the whole system.
"""
from __future__ import annotations

import jax

from repro.core.sparse import RowTiledCOO
from repro.kernels import ref as _ref
from repro.kernels.sddmm import sddmm_pallas
from repro.kernels.spmm import spmm_pallas
from repro.kernels.fusedmm import fusedmm_pallas

_DEFAULT_BACKEND = "pallas"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def set_default_backend(backend: str) -> None:
    global _DEFAULT_BACKEND
    assert backend in ("pallas", "ref")
    _DEFAULT_BACKEND = backend


def sddmm(A: jax.Array, B: jax.Array, S: RowTiledCOO,
          backend: str | None = None) -> RowTiledCOO:
    """R = S * (A @ B.T) sampled at nnz(S); returns S with new values."""
    backend = backend or _DEFAULT_BACKEND
    if backend == "ref":
        return _ref.sddmm(A, B, S)
    vals = sddmm_pallas(S.tile_base // S.row_tile, S.rows_local, S.cols,
                        S.vals, A, B, row_tile=S.row_tile,
                        interpret=_interpret())
    return S.with_vals(vals)


def spmm(S: RowTiledCOO, B: jax.Array, m: int | None = None,
         backend: str | None = None) -> jax.Array:
    """out = S @ B (shape (m, r))."""
    backend = backend or _DEFAULT_BACKEND
    m = m if m is not None else S.shape[0]
    if backend == "ref":
        return _ref.spmm(S, B, m)
    return spmm_pallas(S.tile_base // S.row_tile, S.rows_local, S.cols,
                       S.vals, B, row_tile=S.row_tile, m=m,
                       interpret=_interpret())


def fusedmm(A: jax.Array, B: jax.Array, S: RowTiledCOO,
            m: int | None = None, backend: str | None = None):
    """FusedMMA: out = SDDMM(A,B,S) @ B; returns (out, R)."""
    backend = backend or _DEFAULT_BACKEND
    m = m if m is not None else S.shape[0]
    if backend == "ref":
        return _ref.fusedmm(A, B, S, m)
    out, r_vals = fusedmm_pallas(S.tile_base // S.row_tile, S.rows_local,
                                 S.cols, S.vals, A, B,
                                 row_tile=S.row_tile, m=m,
                                 interpret=_interpret())
    return out, S.with_vals(r_vals)
