"""Public jit'd wrappers over the Pallas kernels with ref fallback.

``backend="pallas"`` runs the Pallas kernels (interpret mode on CPU, native
on TPU); ``backend="ref"`` uses the pure-jnp oracles.  The distributed
algorithms in ``repro.core`` call these for every local kernel invocation,
so flipping the backend flips the whole system.

Tiling knobs (see DESIGN.md): every wrapper accepts ``r_tile`` (width of
the embedding slab resident in VMEM per grid step) and ``blocks_per_step``
(nonzero blocks merged per grid step).  When left ``None`` they default via
``costmodel.choose_tiling`` — VMEM-budget-driven for ``r_tile``; pack-stat-
driven for ``blocks_per_step`` when the pack structure is concrete (inside
jit-traced callers the structure is abstract, so the default stays 1 and
planners pass explicit values chosen at plan time).
"""
from __future__ import annotations

import jax

from repro.core import costmodel
from repro.core.sparse import RowTiledCOO
from repro.kernels import ref as _ref
from repro.kernels.sddmm import sddmm_pallas
from repro.kernels.spmm import spmm_pallas
from repro.kernels.fusedmm import fusedmm_pallas

_DEFAULT_BACKEND = "pallas"

# Distributed routing hook, set by `repro.core.api.activate(problem, S)`:
# while a mesh-bound DistProblem is active, eager calls on its registered
# pack run the distributed algorithm instead of the local kernel.  The
# router returns NotImplemented for anything it does not own (other
# packs, traced values, mismatched shapes), which falls through to the
# local path unchanged.  An explicit ``backend=`` argument always wins
# over routing, preserving the ref-oracle escape hatch.
_DIST_ROUTER = None


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def set_default_backend(backend: str) -> None:
    global _DEFAULT_BACKEND
    assert backend in ("pallas", "ref")
    _DEFAULT_BACKEND = backend


def _resolve_tiling(S: RowTiledCOO, n_b: int, r: int,
                    r_tile: int | None, blocks_per_step: int | None):
    """Fill unset knobs from the cost model; never inspects traced data."""
    concrete = not isinstance(S.tile_base, jax.core.Tracer)
    derived_bps = False
    if r_tile is None or blocks_per_step is None:
        t = costmodel.choose_tiling(
            n_b=n_b, r=r, nb=S.nblocks, k=S.nz_block, row_tile=S.row_tile,
            tile_base=S.tile_base if concrete else None)
        if r_tile is None:
            r_tile = t.r_tile
        if blocks_per_step is None:
            blocks_per_step = t.blocks_per_step
            derived_bps = True   # choose_tiling already proved feasibility
    if blocks_per_step > 1 and concrete and not derived_bps:
        # merging blocks is only sound when every aligned group shares one
        # row window — a silently wrong answer otherwise, so refuse here.
        # (Traced packs can't be checked; planners validate at plan time.)
        feasible = costmodel.groupable_blocks_per_step(
            S.tile_base, S.nz_block, cap=blocks_per_step)
        if S.nblocks % blocks_per_step or feasible % blocks_per_step:
            raise ValueError(
                f"blocks_per_step={blocks_per_step} infeasible for this "
                f"pack (nblocks={S.nblocks}, largest groupable step "
                f"{feasible}); repack with pack_row_tiled(..., "
                f"group={blocks_per_step})")
    return r_tile, blocks_per_step


def sddmm(A: jax.Array, B: jax.Array, S: RowTiledCOO,
          backend: str | None = None, *, r_tile: int | None = None,
          blocks_per_step: int | None = None) -> RowTiledCOO:
    """R = S * (A @ B.T) sampled at nnz(S); returns S with new values."""
    if _DIST_ROUTER is not None and backend is None:
        routed = _DIST_ROUTER.sddmm(A, B, S)
        if routed is not NotImplemented:
            return routed
    backend = backend or _DEFAULT_BACKEND
    if backend == "ref":
        return _ref.sddmm(A, B, S)
    r_tile, bps = _resolve_tiling(S, B.shape[0], B.shape[-1],
                                  r_tile, blocks_per_step)
    vals = sddmm_pallas(S.tile_base // S.row_tile, S.rows_local, S.cols,
                        S.vals, A, B, row_tile=S.row_tile, r_tile=r_tile,
                        blocks_per_step=bps, interpret=_interpret())
    return S.with_vals(vals)


def spmm(S: RowTiledCOO, B: jax.Array, m: int | None = None,
         backend: str | None = None, *, r_tile: int | None = None,
         blocks_per_step: int | None = None) -> jax.Array:
    """out = S @ B (shape (m, r))."""
    m = m if m is not None else S.shape[0]
    if _DIST_ROUTER is not None and backend is None:
        routed = _DIST_ROUTER.spmm(S, B, m)
        if routed is not NotImplemented:
            return routed
    backend = backend or _DEFAULT_BACKEND
    if backend == "ref":
        return _ref.spmm(S, B, m)
    r_tile, bps = _resolve_tiling(S, B.shape[0], B.shape[-1],
                                  r_tile, blocks_per_step)
    return spmm_pallas(S.tile_base // S.row_tile, S.rows_local, S.cols,
                       S.vals, B, row_tile=S.row_tile, m=m, r_tile=r_tile,
                       blocks_per_step=bps, interpret=_interpret())


def fusedmm(A: jax.Array, B: jax.Array, S: RowTiledCOO,
            m: int | None = None, backend: str | None = None, *,
            r_tile: int | None = None, blocks_per_step: int | None = None):
    """FusedMMA: out = SDDMM(A,B,S) @ B; returns (out, R)."""
    m = m if m is not None else S.shape[0]
    if _DIST_ROUTER is not None and backend is None:
        routed = _DIST_ROUTER.fusedmm(A, B, S, m)
        if routed is not NotImplemented:
            return routed
    backend = backend or _DEFAULT_BACKEND
    if backend == "ref":
        return _ref.fusedmm(A, B, S, m)
    r_tile, bps = _resolve_tiling(S, B.shape[0], B.shape[-1],
                                  r_tile, blocks_per_step)
    out, r_vals = fusedmm_pallas(S.tile_base // S.row_tile, S.rows_local,
                                 S.cols, S.vals, A, B,
                                 row_tile=S.row_tile, m=m, r_tile=r_tile,
                                 blocks_per_step=bps,
                                 interpret=_interpret())
    return out, S.with_vals(r_vals)
