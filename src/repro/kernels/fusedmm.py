"""Pallas TPU kernel for the local FusedMM (SDDMM + SpMM, fused).

This is the paper's "local kernel fusion" primitive [11] adapted to TPU:
for each nonzero block the sampled dot products are computed and the scaled
rows of B aggregated into the output window *in one VMEM round trip* — the
intermediate R never travels to HBM between two kernels.  The sampled
values are still emitted (cheap, (bps, K) per step) because applications
such as GAT attention need them; the fusion win is the elided HBM round
trip and the single propagation round in the distributed algorithm.

    dots   = rowsum(A[rows] * B[cols])          (VPU)
    coeff  = vals * dots
    out   += onehot(rows_local) @ (coeff * B[cols])   (MXU)

VMEM tiling (see DESIGN.md): when the full embedding width r fits the VMEM
budget (``r_tile == r``) a single 2-D grid step does both halves fused.
For wider embeddings B enters VMEM in (n_b, r_tile) slabs; the SDDMM
coefficient then needs *all* slabs before any SpMM contribution, so the
grid grows a leading phase axis: phase 0 sweeps the slabs accumulating
partial dots into the R output, phase 1 re-sweeps them scattering
``R * B`` into the output windows.  R round-trips through HBM once —
3 words/nnz, negligible next to the dense slab traffic — while B slabs and
output windows still never exceed the VMEM budget.  ``blocks_per_step``
(bps) merges same-window nonzero blocks into one step as in spmm/sddmm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fusedmm_kernel(base_ref, rows_ref, cols_ref, vals_ref, a_ref, b_ref,
                    acc_ref, out_ref, rvals_ref, *, row_tile):
    """Single-phase variant: full r resident, one VMEM round trip."""
    rl = rows_ref[...].reshape(-1)
    cl = cols_ref[...].reshape(-1)
    v = vals_ref[...].reshape(-1).astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    a_rows = jnp.take(a, rl, axis=0)                     # (bps*K, r)
    b_rows = jnp.take(b, cl, axis=0)                     # (bps*K, r)
    coeff = v * jnp.sum(a_rows * b_rows, axis=-1)        # f32  (SDDMM)
    scaled = coeff[:, None] * b_rows
    iota = jax.lax.broadcasted_iota(jnp.int32, (row_tile, rl.shape[0]), 0)
    onehot = (iota == rl[None, :]).astype(jnp.float32)
    out_ref[...] += jax.lax.dot(                         # (SpMM)
        onehot, scaled, preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)
    rvals_ref[...] = coeff.reshape(rvals_ref.shape).astype(rvals_ref.dtype)


def _fusedmm2_kernel(base_ref, rows_ref, cols_ref, vals_ref, a_ref, b_ref,
                     acc_out_ref, acc_rv_ref, out_ref, rvals_ref, *,
                     row_tile):
    """Two-phase variant: r tiled into slabs; phase 0 SDDMM, phase 1 SpMM."""
    ph = pl.program_id(0)
    rl = rows_ref[...].reshape(-1)
    cl = cols_ref[...].reshape(-1)
    b = b_ref[...].astype(jnp.float32)                   # (n_b, r_tile)
    b_rows = jnp.take(b, cl, axis=0)                     # (bps*K, r_tile)

    @pl.when(ph == 0)
    def _sddmm_phase():
        v = vals_ref[...].astype(jnp.float32)
        a = a_ref[...].astype(jnp.float32)               # (row_tile, r_tile)
        a_rows = jnp.take(a, rl, axis=0)
        dots = jnp.sum(a_rows * b_rows, axis=-1).reshape(v.shape)
        # accumulation across non-consecutive revisits: the aliased acc
        # input restores the prior partial into the shared window buffer
        # on every block-index change (see DESIGN.md §2)
        rvals_ref[...] += v * dots

    @pl.when(ph == 1)
    def _spmm_phase():
        coeff = rvals_ref[...].reshape(-1)               # final R (f32, HBM)
        scaled = coeff[:, None] * b_rows
        iota = jax.lax.broadcasted_iota(jnp.int32,
                                        (row_tile, rl.shape[0]), 0)
        onehot = (iota == rl[None, :]).astype(jnp.float32)
        out_ref[...] += jax.lax.dot(
            onehot, scaled, preferred_element_type=jnp.float32
        ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("row_tile", "m", "r_tile",
                                    "blocks_per_step", "interpret"))
def fusedmm_pallas(tile_base_blk: jax.Array, rows_local: jax.Array,
                   cols: jax.Array, vals: jax.Array, A: jax.Array,
                   B: jax.Array, *, row_tile: int, m: int,
                   r_tile: int | None = None, blocks_per_step: int = 1,
                   interpret: bool = False):
    """Returns (out (m,r) f32->B.dtype, r_vals (nblocks, nz_block))."""
    nb, k = rows_local.shape
    r = B.shape[-1]
    n_b = B.shape[0]
    bps = blocks_per_step
    r_tile = r if r_tile is None else r_tile
    assert m % row_tile == 0, (m, row_tile)
    assert r % r_tile == 0, (r, r_tile)
    assert nb % bps == 0, (nb, bps)
    out_zeros = jnp.zeros((m, r), jnp.float32)

    if r_tile == r:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb // bps,),
            in_specs=[
                pl.BlockSpec((bps, k), lambda i, base: (i, 0)),
                pl.BlockSpec((bps, k), lambda i, base: (i, 0)),
                pl.BlockSpec((bps, k), lambda i, base: (i, 0)),
                pl.BlockSpec((row_tile, r),
                             lambda i, base: (base[i * bps], 0)),   # A
                pl.BlockSpec((n_b, r), lambda i, base: (0, 0)),     # B
                pl.BlockSpec((row_tile, r),
                             lambda i, base: (base[i * bps], 0)),   # acc
            ],
            out_specs=[
                pl.BlockSpec((row_tile, r),
                             lambda i, base: (base[i * bps], 0)),
                pl.BlockSpec((bps, k), lambda i, base: (i, 0)),
            ],
        )
        out, r_vals = pl.pallas_call(
            functools.partial(_fusedmm_kernel, row_tile=row_tile),
            grid_spec=grid_spec,
            out_shape=[jax.ShapeDtypeStruct((m, r), jnp.float32),
                       jax.ShapeDtypeStruct((nb, k), vals.dtype)],
            input_output_aliases={6: 0},   # acc zeros -> out (incl. prefetch)
            interpret=interpret,
        )(tile_base_blk, rows_local, cols, vals, A, B, out_zeros)
        return out.astype(B.dtype), r_vals

    rv_zeros = jnp.zeros((nb, k), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(2, r // r_tile, nb // bps),       # phase axis is outermost
        in_specs=[
            pl.BlockSpec((bps, k), lambda ph, j, i, base: (i, 0)),
            pl.BlockSpec((bps, k), lambda ph, j, i, base: (i, 0)),
            pl.BlockSpec((bps, k), lambda ph, j, i, base: (i, 0)),
            pl.BlockSpec((row_tile, r_tile),
                         lambda ph, j, i, base: (base[i * bps], j)),  # A
            pl.BlockSpec((n_b, r_tile),
                         lambda ph, j, i, base: (0, j)),              # B slab
            pl.BlockSpec((row_tile, r_tile),
                         lambda ph, j, i, base: (base[i * bps], j)),  # acc out
            pl.BlockSpec((bps, k), lambda ph, j, i, base: (i, 0)),    # acc rv
        ],
        out_specs=[
            pl.BlockSpec((row_tile, r_tile),
                         lambda ph, j, i, base: (base[i * bps], j)),
            pl.BlockSpec((bps, k), lambda ph, j, i, base: (i, 0)),
        ],
    )
    out, r_vals = pl.pallas_call(
        functools.partial(_fusedmm2_kernel, row_tile=row_tile),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((m, r), jnp.float32),
                   jax.ShapeDtypeStruct((nb, k), jnp.float32)],
        input_output_aliases={6: 0, 7: 1},     # indices include prefetch arg
        interpret=interpret,
    )(tile_base_blk, rows_local, cols, vals, A, B, out_zeros, rv_zeros)
    return out.astype(B.dtype), r_vals.astype(vals.dtype)
