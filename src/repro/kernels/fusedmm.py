"""Pallas TPU kernel for the local FusedMM (SDDMM + SpMM, fused).

This is the paper's "local kernel fusion" primitive [11] adapted to TPU:
for each nonzero block the sampled dot products are computed and the scaled
rows of B aggregated into the output window *in one VMEM round trip* — the
intermediate R never travels to HBM between two kernels.  The sampled
values are still emitted (cheap, (1,K) per step) because applications such
as GAT attention need them; the fusion win is the elided HBM round trip and
the single propagation round in the distributed algorithm.

    dots   = rowsum(A[rows] * B[cols])          (VPU)
    coeff  = vals * dots
    out   += onehot(rows_local) @ (coeff * B[cols])   (MXU)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fusedmm_kernel(base_ref, rows_ref, cols_ref, vals_ref, a_ref, b_ref,
                    acc_ref, out_ref, rvals_ref, *, row_tile):
    rl = rows_ref[0]
    cl = cols_ref[0]
    v = vals_ref[0].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    a_rows = jnp.take(a, rl, axis=0)                     # (K, r)
    b_rows = jnp.take(b, cl, axis=0)                     # (K, r)
    coeff = v * jnp.sum(a_rows * b_rows, axis=-1)        # f32[K]  (SDDMM)
    scaled = coeff[:, None] * b_rows                     # (K, r)
    iota = jax.lax.broadcasted_iota(jnp.int32, (row_tile, rl.shape[0]), 0)
    onehot = (iota == rl[None, :]).astype(jnp.float32)
    out_ref[...] += jax.lax.dot(                         # (SpMM)
        onehot, scaled, preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)
    rvals_ref[0] = coeff.astype(rvals_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("row_tile", "m", "interpret"))
def fusedmm_pallas(tile_base_blk: jax.Array, rows_local: jax.Array,
                   cols: jax.Array, vals: jax.Array, A: jax.Array,
                   B: jax.Array, *, row_tile: int, m: int,
                   interpret: bool = False):
    """Returns (out (m,r) f32->B.dtype, r_vals (nblocks, nz_block))."""
    nb, k = rows_local.shape
    r = B.shape[-1]
    n_b = B.shape[0]
    assert m % row_tile == 0, (m, row_tile)
    zeros = jnp.zeros((m, r), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, base: (i, 0)),
            pl.BlockSpec((1, k), lambda i, base: (i, 0)),
            pl.BlockSpec((1, k), lambda i, base: (i, 0)),
            pl.BlockSpec((row_tile, r), lambda i, base: (base[i], 0)),  # A
            pl.BlockSpec((n_b, r), lambda i, base: (0, 0)),             # B
            pl.BlockSpec((row_tile, r), lambda i, base: (base[i], 0)),  # acc
        ],
        out_specs=[
            pl.BlockSpec((row_tile, r), lambda i, base: (base[i], 0)),
            pl.BlockSpec((1, k), lambda i, base: (i, 0)),
        ],
    )
    out, r_vals = pl.pallas_call(
        functools.partial(_fusedmm_kernel, row_tile=row_tile),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((m, r), jnp.float32),
                   jax.ShapeDtypeStruct((nb, k), vals.dtype)],
        input_output_aliases={6: 0},   # acc zeros -> out (index incl. prefetch)
        interpret=interpret,
    )(tile_base_blk, rows_local, cols, vals, A, B, zeros)
    return out.astype(B.dtype), r_vals
